"""Kernel micro-benchmarks: times the jnp oracle paths on CPU (the
interpret-mode kernels are Python-looped and not timing-representative)
and reports the TPU roofline expectation for each kernel's shapes."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.kernels.extract_pack.ref import extract_pack_ref
from repro.kernels.flash_attn.ref import flash_attention_ref
from repro.kernels.verify_attn.ref import verify_attention_ref
from repro.launch.roofline import HBM_BW, PEAK_FLOPS


def run():
    ks = jax.random.split(jax.random.key(0), 3)
    # flash prefill
    B, S, Hq, Hk, D = 1, 1024, 8, 2, 64
    q = jax.random.normal(ks[0], (B, S, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hk, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hk, D), jnp.float32)
    fn = jax.jit(lambda: flash_attention_ref(q, k, v))
    t = timeit(fn)
    flops = 4 * B * Hq * S * S * D
    emit("kernel/flash_attn/oracle_cpu", t * 1e6,
         f"tpu_roofline_us={flops / PEAK_FLOPS * 1e6:.1f}")
    # verify attention (decode hot spot): bandwidth-bound on TPU
    T_, Smax = 4, 8192
    q2 = jax.random.normal(ks[0], (B, T_, Hq, D), jnp.float32)
    kc = jax.random.normal(ks[1], (B, Smax, Hk, D), jnp.float32)
    vc = jax.random.normal(ks[2], (B, Smax, Hk, D), jnp.float32)
    lengths = jnp.array([Smax - T_] * B, jnp.int32)
    fn = jax.jit(lambda: verify_attention_ref(q2, kc, vc, lengths))
    t = timeit(fn)
    cache_bytes = 2 * B * Smax * Hk * D * 2          # bf16 k+v read
    emit("kernel/verify_attn/oracle_cpu", t * 1e6,
         f"tpu_roofline_us={cache_bytes / HBM_BW * 1e6:.1f}")
    # extract pack
    feats = jax.random.normal(ks[0], (8, 4, 1536), jnp.float32)
    toks = jnp.zeros((8, 4), jnp.int32)
    mask = jnp.ones((8, 4), bool)
    fn = jax.jit(lambda: extract_pack_ref(feats, toks, mask))
    t = timeit(fn)
    emit("kernel/extract_pack/oracle_cpu", t * 1e6,
         f"bytes={feats.nbytes}")


if __name__ == "__main__":
    run()
