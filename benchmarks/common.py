"""Shared benchmark infrastructure: a cached pretrained demo system and
CSV emission (``name,us_per_call,derived``)."""
from __future__ import annotations

import functools
import time
from typing import Callable, List, Tuple

import jax
import numpy as np

ROWS: List[Tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.2f},{derived}", flush=True)


def timeit(fn: Callable, warmup: int = 1, iters: int = 3) -> float:
    """Seconds per call (blocking)."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / iters


@functools.lru_cache(maxsize=1)
def demo_target(pretrain_steps: int = 120):
    """Pretrained tide-tiny target + the paper-style synthetic domains —
    shared across all live benchmarks (pretraining is the slow part)."""
    import repro.configs as C
    from repro.data.workloads import (PAPER_BRANCHINGS, PAPER_DOMAINS,
                                      make_domains, training_corpus)
    from repro.models import transformer as T
    from repro.training.trainer import pretrain_target

    cfg = C.get("tide-tiny")
    params = T.init(cfg, jax.random.key(0))
    domains = make_domains(cfg.vocab_size, PAPER_DOMAINS,
                           branchings=PAPER_BRANCHINGS, seed=3)
    corpus = np.concatenate([
        training_corpus(d, 48, 48, seed=11 + i)
        for i, d in enumerate(domains.values())])
    params, losses = pretrain_target(cfg, params, corpus,
                                     steps=pretrain_steps, lr=3e-3)
    return cfg, params, domains


def trained_draft(domain_name: str, n_seqs: int = 48, steps: int = 90):
    """A draft trained on captures of `domain_name` traffic (cached per
    domain)."""
    return _trained_draft_cached(domain_name, n_seqs, steps)


@functools.lru_cache(maxsize=8)
def _trained_draft_cached(domain_name: str, n_seqs: int, steps: int):
    import jax.numpy as jnp

    from repro.core import eagle
    from repro.data.workloads import training_corpus
    from repro.models import transformer as T
    from repro.training.optimizer import adamw

    cfg, params, domains = demo_target()
    dcfg = eagle.draft_config(cfg)
    dparams = eagle.draft_init(dcfg, jax.random.key(100))
    corpus = training_corpus(domains[domain_name], n_seqs, 40, seed=23)
    toks = jnp.asarray(corpus)
    pre = T.prefill(cfg, params, toks)
    feats, nexts = pre["captures"][:, :-1], toks[:, 1:]
    opt = adamw(lr=2e-3, weight_decay=0.0)
    ostate = opt.init(dparams)
    lossf = jax.value_and_grad(
        lambda dp, f, t: eagle.draft_train_loss(dcfg, dp, params["embed"],
                                                f, t), has_aux=True)

    @jax.jit
    def step(dp, os_, f, t, it):
        (l, m), g = lossf(dp, f, t)
        dp, os_ = opt.update(dp, g, os_, it)
        return dp, os_, m["accuracy"]

    rng = np.random.default_rng(0)
    acc = 0.0
    for it in range(steps):
        sel = rng.integers(0, feats.shape[0], size=8)
        dparams, ostate, a = step(dparams, ostate, feats[sel], nexts[sel],
                                  jnp.int32(it))
        acc = float(a)
    return dcfg, dparams, acc
