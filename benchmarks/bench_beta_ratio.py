"""Paper Fig. 4: β(b) = T(b(γ+1))/T(b) across batch sizes — 1.0 in the
memory-bound ideal, growing as decoding turns compute-bound.  Reported
from the paper's measured Table 5 profiles and from the live CPU engine.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import demo_target, emit, timeit
from repro.core.adaptive import PAPER_PROFILES
from repro.models import transformer as T

GAMMA = 3


def run():
    for name, prof in PAPER_PROFILES.items():
        for b in (1, 4, 16, 64, 128):
            emit(f"fig4/paper/{name}/beta_b{b}", prof.t(b) * 1e3,
                 f"{prof.beta(b, GAMMA):.3f}")
    # live: time the target decode step at n and n(γ+1) "rows"
    cfg, params, _ = demo_target()
    MAX = 64
    for b in (1, 2, 4, 8):
        def step_at(rows):
            toks = jnp.zeros((rows, 8), jnp.int32)
            pre = T.prefill(cfg, params, toks, max_len=MAX,
                            want_caps=False)
            fn = jax.jit(lambda c, t: T.decode_step(
                cfg, params, c, t, want_caps=False)["logits"])
            tok = jnp.zeros((rows, 1), jnp.int32)
            return lambda: fn(pre["cache"], tok)
        t1 = timeit(step_at(b), iters=5)
        t4 = timeit(step_at(b * (GAMMA + 1)), iters=5)
        emit(f"fig4/live/beta_b{b}", t1 * 1e6, f"{t4 / t1:.3f}")


if __name__ == "__main__":
    run()
