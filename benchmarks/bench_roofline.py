"""Roofline table (§Roofline deliverable, assignment requirement (g)):
reads the dry-run JSONs produced by launch/dryrun.py and prints the
three-term roofline per (arch × shape × mesh) with the dominant
bottleneck and the useful-FLOPs ratio."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def run():
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
    if not files:
        emit("roofline/no_dryrun_results", 0.0,
             "run: python -m repro.launch.dryrun --all --multi-pod both")
        return
    for path in files:
        with open(path) as f:
            d = json.load(f)
        tag = f"{d['arch']}/{d['shape']}/{d.get('mesh', '?')}"
        if "skipped" in d:
            emit(f"roofline/{tag}", 0.0, f"SKIP:{d['skipped'][:40]}")
            continue
        if "error" in d:
            emit(f"roofline/{tag}", 0.0, f"ERROR:{d['error'][:60]}")
            continue
        r = d["roofline"]
        ratio = d["model_flops"] / max(r["flops"] * r["chips"], 1.0)
        emit(f"roofline/{tag}", r["step_s"] * 1e6,
             f"dom={r['dominant']};comp={r['compute_s']:.4f}s;"
             f"mem={r['memory_s']:.4f}s;coll={r['collective_s']:.4f}s;"
             f"useful_flops={ratio:.2f};"
             f"resident_gb={d.get('resident_bytes', 0) / 1e9:.2f}")


if __name__ == "__main__":
    run()
