"""Paper Table 2: draft-training time — TIDE (reuse serving-time hidden
states) vs SpecForge-offline (one prefill pass + train) vs
SpecForge-online (prefill re-run every epoch + train).

Measured live at tiny scale with identical training work; the metric is
the same one the paper reports: total time = prefill_time + train_time,
with TIDE's prefill_time ≡ 0 because serving already produced the
signals.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import demo_target, emit
from repro.core import eagle
from repro.data.workloads import training_corpus
from repro.models import transformer as T
from repro.training.optimizer import adamw

EPOCHS = 3
N_SEQS = 96          # prefill-heavy, like the paper's 100k-conversation run
SEQ = 40
STEPS_PER_EPOCH = 16


def _train(cfg, dcfg, params, dparams, feats, nexts, steps, seed=0):
    opt = adamw(lr=2e-3, weight_decay=0.0)
    ostate = opt.init(dparams)
    lossf = jax.value_and_grad(
        lambda dp, f, t: eagle.draft_train_loss(dcfg, dp, params["embed"],
                                                f, t), has_aux=True)

    @jax.jit
    def step(dp, os_, f, t, it):
        (l, m), g = lossf(dp, f, t)
        dp, os_ = opt.update(dp, g, os_, it)
        return dp, os_, m["accuracy"]

    rng = np.random.default_rng(seed)
    acc = 0.0
    for it in range(steps):
        sel = rng.integers(0, feats.shape[0], size=8)
        dparams, ostate, a = step(dparams, ostate, feats[sel],
                                  nexts[sel], jnp.int32(it))
        acc = float(a)
    jax.block_until_ready(jax.tree.leaves(dparams)[0])
    return dparams, acc


def run():
    cfg, params, domains = demo_target()
    dcfg = eagle.draft_config(cfg)
    corpus = jnp.asarray(training_corpus(domains["science"], N_SEQS, SEQ,
                                         seed=5))
    prefill_fn = jax.jit(lambda t: T.prefill(cfg, params, t))

    def do_prefill():
        out = prefill_fn(corpus)
        jax.block_until_ready(out["captures"])
        return out["captures"][:, :-1], corpus[:, 1:]

    # warm the compile caches so we time steady-state work, like the paper
    feats, nexts = do_prefill()
    _train(cfg, dcfg, params, eagle.draft_init(dcfg, jax.random.key(9)),
           feats, nexts, 2)

    total_steps = EPOCHS * STEPS_PER_EPOCH
    # --- TIDE: signals already exist (serving byproduct): train only
    d0 = eagle.draft_init(dcfg, jax.random.key(10))
    t0 = time.perf_counter()
    _, acc_tide = _train(cfg, dcfg, params, d0, feats, nexts, total_steps)
    t_tide = time.perf_counter() - t0

    # --- SpecForge offline: one prefill (store), then train
    d0 = eagle.draft_init(dcfg, jax.random.key(10))
    t0 = time.perf_counter()
    f2, n2 = do_prefill()
    _, acc_off = _train(cfg, dcfg, params, d0, f2, n2, total_steps)
    t_off = time.perf_counter() - t0

    # --- SpecForge online: re-prefill every epoch (no storage)
    d0 = eagle.draft_init(dcfg, jax.random.key(10))
    t0 = time.perf_counter()
    acc_on = 0.0
    for ep in range(EPOCHS):
        f3, n3 = do_prefill()
        d0, acc_on = _train(cfg, dcfg, params, d0, f3, n3,
                            STEPS_PER_EPOCH, seed=ep)
    t_on = time.perf_counter() - t0

    emit("table2/tide/total_s", t_tide * 1e6, f"acc={acc_tide:.3f}")
    emit("table2/specforge_offline/total_s", t_off * 1e6,
         f"acc={acc_off:.3f}")
    emit("table2/specforge_online/total_s", t_on * 1e6,
         f"acc={acc_on:.3f}")
    emit("table2/tide_vs_offline_speedup", 0.0, f"{t_off / t_tide:.2f}x")
    emit("table2/tide_vs_online_speedup", 0.0, f"{t_on / t_tide:.2f}x")
    emit("table2/paper_reported", 0.0,
         "offline=15.32hr;online=27.64hr;tide=9.16hr;1.67x;3.02x")


if __name__ == "__main__":
    run()
