"""Decoupled async draft training vs synchronous blocking training.

The legacy TIDE scheduler trained the draft *on the serving path*:
``run_stream`` blocked at request-completion boundaries for entire
train cycles, stalling every resident lane.  The decoupled
``TrainingService`` moves those cycles off-path (background
thread / training submesh), ships signals through the bounded
``SignalChannel``, and publishes versioned drafts into a lock-free
deploy slot the engine polls once per superstep.

Measured on ``tide_tiny`` (CPU backend, greedy) under a
*training-heavy* trace — selective gating off, a small per-cycle
signal threshold, and a domain-shifting bursty arrival mix — served
two ways by the same ``TideSystem`` machinery:

  * **sync**  — ``async_train=False``: ``service.drain()`` at
    completion boundaries (the legacy blocking schedule, byte-exact),
  * **async** — ``async_train=True``: background training, zero-sync
    deploys, deploy-time draft-cache re-seed.

Both modes are warmed over the full trace (compiling every serve and
train shape), reset with ``reset_adaptation()``, and measured once —
min-of-N would bias toward repeats that happened to train less.

Gates (CI):
  * per-request token streams byte-identical sync vs async (greedy
    decoding is draft- and scheduling-invariant) — deterministic,
  * drain parity: the sync system's warm-up and measured runs emit
    identical event streams (timing fields excluded) and identical
    token streams — the service.drain() schedule is deterministic and
    ``reset_adaptation`` is faithful — deterministic,
  * serving tokens/s: async >= BAR x sync (training-heavy trace),
  * syncs per token: async <= 1.10 x sync (the deploy slot poll and
    re-seed add zero host syncs),
  * the async service really trained and deployed (cycles >= 1,
    deploys picked up by the engine),
  * acceptance recovery no worse: after each system drains its
    leftover signals, a probe re-serve of the trace must reach
    >= 0.85 x the sync system's mean acceptance length — both drafts
    saw the same signal corpus (greedy streams are byte-identical),
    only the cycle partitioning differs.  Mid-stream tail acceptance
    is emitted as information (it races deploy landing against stream
    end, so it is not a CI gate on a loaded host).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import demo_target, emit


BAR = 1.2


def _trace(domains, n_req, seed=7):
    from repro.data.workloads import arrival_trace

    # round-robin domains (no phase schedule): every train cycle's
    # signal mix then covers the whole tail distribution, so acceptance
    # recovery is comparable between schedules that train at different
    # points of the stream
    return arrival_trace(domains, n_req, mode="bursty", burst_size=4,
                         max_new_range=(8, 24), long_frac=0.25,
                         long_range=(56, 72), seed=seed)


def _build(cfg, params, domains, *, async_train, smoke):
    from repro.core.tide import TideConfig, TideSystem

    tc = TideConfig(
        gamma=3, batch_size=4, max_len=160, greedy=True,
        adaptive_spec=False,
        # training-heavy: no Algorithm-1 gating, small per-cycle
        # threshold -> a cycle every few completed requests; short
        # cycles (low step floor) so async deploys land mid-stream
        selective_training=False,
        signal_window=16, n_threshold=10 if smoke else 12,
        train_epochs=1, train_min_steps=48 if smoke else 64, seed=0,
        async_train=async_train,
        reseed_window=32 if async_train else 0)
    return TideSystem(cfg, params, tc)


def _serve(sys_, trace):
    reqs = sys_.requests_from_trace(trace)
    sys_.run_stream(reqs)
    return [list(r.generated) for r in reqs]


def _events_key(events):
    """Event stream with wall-clock timing stripped (byte-comparable)."""
    return [{k: v for k, v in e.items() if k != "seconds"}
            for e in events]


def _tail_accept(sys_):
    tl = list(sys_.engine.stats.timeline)
    k = max(len(tl) // 3, 1)
    return float(np.mean([x["accept_len"] for x in tl[-k:]]))


def run(smoke: bool = False):
    cfg, params, domains = demo_target(30 if smoke else 120)
    n_req = 48 if smoke else 64
    trace = _trace(domains, n_req)

    results = {}
    for mode in ("sync", "async"):
        sys_ = _build(cfg, params, domains,
                      async_train=(mode == "async"), smoke=smoke)
        warm_streams = _serve(sys_, trace)      # compile every shape
        warm_events = _events_key(sys_.events)  # in-stream cycles only
        if mode == "async":
            sys_.service.drain()                # settle before reset
        sys_.reset_adaptation()
        streams = _serve(sys_, trace)
        st = sys_.engine.stats
        wall, tokens = st.wall_s, st.tokens_out
        assert tokens == sum(len(s) for s in streams)
        mid_deploys = st.deploys
        mid_reseeds = st.reseeds
        tail_accept = _tail_accept(sys_)
        events_meas = _events_key(sys_.events)  # pre-drain snapshot
        syncs_per_tok = st.dispatches / max(tokens, 1)
        syncs_per_round = st.dispatches / max(st.steps, 1)
        cycles_meas = sys_.service.cycles
        # settle leftover signals (off the measured clock), then probe:
        # re-serve the trace and measure the end-state draft's mean
        # acceptance — the timing-independent recovery metric
        sys_.service.drain()
        n_tl = len(sys_.engine.stats.timeline)
        probe_streams = _serve(sys_, trace)
        probe_tl = list(sys_.engine.stats.timeline)[n_tl:]
        probe_accept = float(np.mean([x["accept_len"] for x in probe_tl]))
        if mode == "async":
            sys_.close()
        results[mode] = {
            "streams": streams, "warm_streams": warm_streams,
            "warm_events": warm_events, "events": events_meas,
            "tok_s": tokens / max(wall, 1e-9), "tokens": tokens,
            "syncs_per_tok": syncs_per_tok,
            "syncs_per_round": syncs_per_round,
            "cycles": cycles_meas, "deploys": mid_deploys,
            "reseeds": mid_reseeds,
            "deploy_version": sys_.gate.version,
            "dropped": sys_.channel.dropped,
            "tail_accept": tail_accept,
            "probe_accept": probe_accept,
            "probe_streams": probe_streams,
            "cycles_total": sys_.service.cycles,
            "total_deploys": sys_.engine.stats.deploys,
            "train_s": sum(e["seconds"] for e in sys_.events),
        }
        r = results[mode]
        emit(f"decoupled/{mode}", 0.0,
             f"tok_per_s={r['tok_s']:.0f};tokens={tokens};"
             f"wall_s={wall:.2f};train_s={r['train_s']:.2f};"
             f"cycles={r['cycles']};deploys={r['deploys']};"
             f"reseeds={r['reseeds']};dropped={r['dropped']};"
             f"syncs_per_tok={r['syncs_per_tok']:.3f};"
             f"syncs_per_round={r['syncs_per_round']:.3f};"
             f"tail_accept={r['tail_accept']:.2f};"
             f"probe_accept={r['probe_accept']:.2f}")

    sy, an = results["sync"], results["async"]

    # --- gate 1: greedy token streams are training-schedule-invariant
    if an["streams"] != sy["streams"]:
        raise AssertionError("async token streams diverged from sync "
                             "(greedy streams must be draft-invariant)")

    # --- gate 2: drain parity — the synchronous schedule is
    # deterministic: warm run (fresh system) == measured run (reset)
    if sy["warm_events"] != sy["events"]:
        raise AssertionError(
            "sync-mode event streams diverged between the warm-up and "
            "measured runs — service.drain() parity is broken")
    if sy["warm_streams"] != sy["streams"]:
        raise AssertionError("sync-mode token streams diverged between "
                             "warm-up and measured runs")

    # --- gate 3: decoupling actually trained, off-path
    if sy["cycles"] < 1 or an["cycles_total"] < 1:
        raise AssertionError(
            f"training-heavy trace did not train: sync={sy['cycles']} "
            f"async={an['cycles_total']} cycles")

    # --- gate 4: serving throughput
    gain = an["tok_s"] / sy["tok_s"]
    emit("decoupled/ratio", 0.0,
         f"serving_gain={gain:.2f}x;bar={BAR:.1f}x;"
         f"sync_train_s={sy['train_s']:.2f};"
         f"accept_tail={sy['tail_accept']:.2f}->{an['tail_accept']:.2f}")
    if gain < BAR:
        raise AssertionError(
            f"decoupled serving {an['tok_s']:.0f} tok/s < {BAR}x "
            f"synchronous {sy['tok_s']:.0f} tok/s")

    # --- gate 5: the deploy slot adds no host syncs.  Syncs per *token*
    # is acceptance-dependent (later deploys -> more rounds for the same
    # tokens), so the structural invariant is syncs per executed round:
    # one telemetry pull per launched superstep, deploys and re-seeds
    # contributing zero
    if an["syncs_per_round"] > sy["syncs_per_round"] * 1.10 + 1e-9:
        raise AssertionError(
            f"async mode regressed host syncs per executed round: "
            f"{sy['syncs_per_round']:.3f} -> {an['syncs_per_round']:.3f}")

    # --- gate 6: acceptance recovery no worse.  Both systems trained on
    # the same signal corpus (identical greedy streams), so after each
    # drains its leftovers the probe re-serve must reach comparable
    # acceptance; the engine must also have actually picked deploys up.
    if an["total_deploys"] < 1:
        raise AssertionError("async engine never picked up a deploy")
    if an["probe_streams"] != sy["probe_streams"]:
        raise AssertionError("probe token streams diverged sync vs async")
    if an["probe_accept"] < 0.85 * sy["probe_accept"]:
        raise AssertionError(
            f"async acceptance recovery regressed: probe accept "
            f"{an['probe_accept']:.2f} < 0.85x sync "
            f"{sy['probe_accept']:.2f}")


if __name__ == "__main__":
    run()
