"""Overload resilience: deadline preemption + weighted-EDF vs plain
EDF admission under a bursty deadline trace at ~4x instantaneous
overload.

Like bench_slo, every gate lives on **deterministic round-clock
metrics**: the engine's injected clock is bound to its own executed-
round counter (``stats.steps``), so gated arrivals, deadlines, and
latency stamps are all round units that reproduce exactly run to run
on a noisy shared host (every emitted metric here is round-domain).

**The trace.**  Two loose batch residents (budget 140) occupy both
lanes from round 0 with a loose backlog queued behind them; at round
10 — while the residents are guaranteed mid-decode (140 tokens at the
<= gamma+1 = 4 tokens/round ceiling cannot drain before round 35) — a
burst of four tight interactive requests arrives, 4x the lane count.
The tight deadline (round 35) is picked so the gates are accept-rate
independent:

  * non-preemptive EDF cannot free a lane before round 35, so every
    tight request **must** miss, while
  * the preemptive engine spills both residents at the next superstep
    boundary (<= round ~14) and serves the burst pairwise at >= 1
    committed token/round, finishing by round ~31 worst case.

**Gates** (all deterministic):

  * deadline-hit-rate: preemptive weighted-EDF >= 1.3x non-preemptive
    ``DeadlineAdmission`` (measured: 2.0x — 8/8 vs 4/8),
  * preemption actually exercised: preemptions >= 1 and every spill is
    restored (restores == preemptions, zero spilled requests left),
  * bounded p99: preemption may delay the spilled residents by the
    burst's service time but must never starve them — p99
    round-latency <= 1.5x the non-preemptive baseline,
  * byte-identical restored streams, greedy AND per-request-keyed
    sampled: spilling a lane to host and restoring it (possibly onto
    different physical pages) must never change what any request
    generates — preemptive streams == non-preemptive streams,
  * zero leaked pages: the paged preemptive engine drains to a clean
    allocator (every spilled page released, every restore re-reserved)
    with ``spilled_pages`` > 0 proving pages actually moved,
  * zero added syncs: superstep dispatches per committed token
    <= 1.1x baseline — spill/restore are enqueued device ops at host
    boundaries, never an extra drain.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import demo_target, emit, trained_draft

# (arrives_at_rounds, deadline_rounds, max_new_tokens): loose residents
# + queued loose tails from round 0, 4-wide tight burst at round 10
_SPEC = [(0.0, 1000.0, 140), (0.0, 1001.0, 140),
         (10.0, 35.0, 8), (10.0, 35.5, 8),
         (10.0, 36.0, 8), (10.0, 36.5, 8),
         (0.0, 1004.0, 12), (0.0, 1005.0, 12)]
_TIGHT = 100.0     # deadlines below this are the interactive burst


def _trace(vocab, seed=3, plen=8):
    from repro.serving.request import Request
    rng = np.random.default_rng(seed)
    out = []
    for i, (a, d, m) in enumerate(_SPEC):
        r = Request(prompt=list(rng.integers(1, vocab, plen)),
                    max_new_tokens=m, deadline=d)
        r.arrives_at = a
        r.sid = i          # pre-assigned: sampled streams are
        out.append(r)      # scheduling-invariant across policies
    return out


def _run(cfg, params, dcfg, dparams, reqs, **kw):
    from repro.serving.engine import ServingEngine
    from repro.serving.policy import ServingConfig
    scfg = ServingConfig(batch_size=2, max_len=160, gamma=3, seed=11,
                         superstep_rounds=4, gate_arrivals=True,
                         admission_lookahead=8, idle_wait_s=0.0005, **kw)
    eng = ServingEngine(cfg, params, dcfg, dparams, config=scfg)
    eng._clock = lambda: float(eng.stats.steps)     # round-clock domain
    eng.serve_stream(list(reqs))
    if eng.allocator is not None:
        eng.release_prefix_cache()
        eng.allocator.assert_clean()                # zero leaked pages
    return eng


def _metrics(eng, reqs):
    st = eng.stats
    hits = float(np.mean([r.finish_round is not None
                          and r.finish_round <= r.deadline for r in reqs]))
    tight = [r for r in reqs if r.deadline < _TIGHT]
    tight_hits = float(np.mean([r.finish_round <= r.deadline
                                for r in tight]))
    lat = np.asarray([r.finish_round - r.arrives_at for r in reqs])
    p99 = float(np.percentile(lat, 99))
    tokens = sum(len(r.generated) for r in reqs)
    return dict(hit_rate=hits, tight_hit_rate=tight_hits,
                p99_rounds=p99, syncs_per_tok=st.dispatches / tokens,
                rounds=st.steps)


def _emit(name, eng, m):
    st = eng.stats
    emit(f"overload/preempt/{name}", 0.0,
         f"hit_rate={m['hit_rate']:.3f};"
         f"tight_hit_rate={m['tight_hit_rate']:.3f};"
         f"p99_rounds={m['p99_rounds']:.1f};rounds={m['rounds']};"
         f"syncs_per_tok={m['syncs_per_tok']:.3f};"
         f"preemptions={st.preemptions};restores={st.restores}")


def _preempt_scenario(cfg, params, dcfg, dparams):
    vocab = cfg.vocab_size
    base_kw = dict(admission="deadline")
    pre_kw = dict(admission="wedf", preempt="deadline")

    # --- greedy, dense: the gated comparison --------------------------
    base_reqs = _trace(vocab)
    base = _run(cfg, params, dcfg, dparams, base_reqs, **base_kw)
    mb = _metrics(base, base_reqs)
    _emit("base", base, mb)

    pre_reqs = _trace(vocab)
    pre = _run(cfg, params, dcfg, dparams, pre_reqs, **pre_kw)
    mp = _metrics(pre, pre_reqs)
    _emit("wedf", pre, mp)

    if pre.stats.preemptions < 1 or pre.stats.restores < 1:
        raise AssertionError(
            "the overload trace did not exercise preemption "
            f"(preemptions={pre.stats.preemptions}, "
            f"restores={pre.stats.restores})")
    if pre.stats.restores != pre.stats.preemptions:
        raise AssertionError(
            f"{pre.stats.preemptions - pre.stats.restores} spilled "
            "requests were never restored")
    streams = lambda rs: {r.sid: list(r.generated) for r in rs}
    if streams(pre_reqs) != streams(base_reqs):
        raise AssertionError(
            "preemption changed per-request token streams (greedy) — "
            "spill/restore must never change what a request generates")

    gain = mp["hit_rate"] / max(mb["hit_rate"], 1e-9)
    p99_ratio = mp["p99_rounds"] / max(mb["p99_rounds"], 1e-9)
    sync_ratio = mp["syncs_per_tok"] / max(mb["syncs_per_tok"], 1e-9)
    emit("overload/preempt/ratio", 0.0,
         f"hit_gain={gain:.2f}x;bar=1.3x;p99_ratio={p99_ratio:.2f};"
         f"p99_bar=1.5;sync_ratio={sync_ratio:.3f}")
    if gain < 1.3:
        raise AssertionError(
            f"preemptive wedf deadline-hit-rate {mp['hit_rate']:.3f} not "
            f">= 1.3x non-preemptive EDF {mb['hit_rate']:.3f}")
    if p99_ratio > 1.5:
        raise AssertionError(
            f"preemption starved the spilled residents: p99 "
            f"{mp['p99_rounds']:.1f} rounds > 1.5x baseline "
            f"{mb['p99_rounds']:.1f}")
    if sync_ratio > 1.1:
        raise AssertionError(
            f"preemption added host syncs: {mp['syncs_per_tok']:.3f} "
            f"dispatches/token > 1.1x baseline {mb['syncs_per_tok']:.3f}")

    # --- sampled parity: per-request keys survive spill/restore -------
    sb = _trace(vocab)
    _run(cfg, params, dcfg, dparams, sb, greedy=False, **base_kw)
    sp = _trace(vocab)
    spre = _run(cfg, params, dcfg, dparams, sp, greedy=False, **pre_kw)
    if spre.stats.preemptions < 1:
        raise AssertionError("sampled overload run did not preempt")
    if streams(sp) != streams(sb):
        raise AssertionError(
            "preemption changed sampled streams — per-request PRNG keys "
            "must survive spill/restore")
    emit("overload/preempt/sampled", 0.0,
         f"preemptions={spre.stats.preemptions};"
         f"restores={spre.stats.restores};parity=1")

    # --- paged: spilled pages released + re-reserved, none leaked -----
    pp = _trace(vocab)
    paged = _run(cfg, params, dcfg, dparams, pp, page_size=16,
                 num_pages=24, **pre_kw)
    if paged.stats.preemptions < 1:
        raise AssertionError("paged overload run did not preempt")
    if paged.allocator.spilled_pages <= 0:
        raise AssertionError("paged preemption moved no pages")
    if streams(pp) != streams(base_reqs):
        raise AssertionError(
            "paged spill/restore changed greedy streams — restores onto "
            "fresh pages must be byte-identical")
    emit("overload/preempt/paged", 0.0,
         f"preemptions={paged.stats.preemptions};"
         f"restores={paged.stats.restores};"
         f"spilled_pages={paged.allocator.spilled_pages};"
         f"pages_peak={paged.stats.pages_peak};parity=1")


def run(smoke: bool = False):
    cfg, params, _ = demo_target(30 if smoke else 120)
    dcfg, dparams, _ = trained_draft("science", steps=30 if smoke else 90)
    _preempt_scenario(cfg, params, dcfg, dparams)


if __name__ == "__main__":
    run()
