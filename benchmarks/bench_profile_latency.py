"""Paper Table 5: profiled T(n) and D0.  Prints the paper's H100
measurements (used by the adaptive model) plus the analytic TPU-v5e
profile derived from the roofline (DESIGN.md §2.4), and profiles the
live CPU engine (tide-tiny) with the actual startup profiling pass.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

import repro.configs as C
from benchmarks.common import demo_target, emit
from repro.core.adaptive import PAPER_PROFILES, analytic_tpu_profile, \
    profile_engine
from repro.models import transformer as T


def run():
    for name, prof in PAPER_PROFILES.items():
        for b, t in zip(prof.batch_sizes, prof.t_ms):
            emit(f"table5/paper/{name}/T_{b}", t * 1e3, f"{t:.3f}ms")
        emit(f"table5/paper/{name}/D0", prof.d0_ms * 1e3,
             f"{prof.d0_ms:.3f}ms")
    # analytic TPU v5e profiles for two assigned archs
    for arch in ("glm4-9b", "deepseek-v3-671b"):
        prof = analytic_tpu_profile(C.get(arch), chips=256)
        for b in (1, 16, 256):
            emit(f"table5/tpu_v5e_analytic/{arch}/T_{b}",
                 prof.t(b) * 1e3, f"{prof.t(b):.4f}ms")
    # live CPU profiling pass (the actual §4.1 startup procedure)
    cfg, params, _ = demo_target()

    def step_fn(n):
        toks = jnp.zeros((n, 8), jnp.int32)
        pre = T.prefill(cfg, params, toks, max_len=32, want_caps=False)
        fn = jax.jit(lambda c, t: T.decode_step(cfg, params, c, t,
                                                want_caps=False)["logits"])
        out = fn(pre["cache"], jnp.zeros((n, 1), jnp.int32))
        jax.block_until_ready(out)

    prof = profile_engine(step_fn, [1, 2, 4, 8], iters=3)
    for b, t in zip(prof.batch_sizes, prof.t_ms):
        emit(f"table5/live_cpu/T_{b}", t * 1e3, f"{t:.3f}ms")


if __name__ == "__main__":
    run()
