"""Tree speculation: accepted tokens per verify pass + width=1 parity.

A linear gamma-chain stakes each superstep on one draft trajectory: the
first target disagreement discards every deeper draft token.  Tree
speculation (``tree_width=W``) drafts W top-k first continuations, each
extended ``gamma`` deep, and verifies all ``W*gamma+1`` nodes in ONE
tree-masked ``verify_attn`` pass — so a wrong first guess no longer
costs the whole superstep, it just shifts acceptance to a sibling
branch.  The currency a tree buys is *accepted draft tokens per target
pass*; on this CPU backend the wider verify block costs wall time per
pass, so tokens/s is reported as an uplift with a conservative floor
rather than a >1x bar (on accelerators the block rides one fused
kernel, see kernels/verify_attn).

Scenarios (tide-tiny, CPU backend):

  * **accept** — chain vs tree at EQUAL TARGET PASSES (same superstep
    count) on a mixed-domain trace, min-of-4 walls (PR 4 discipline:
    this host's wall noise spans 0.8-2.5x).  Gates: accepted draft
    tokens per superstep >= ``ACCEPT_BAR`` (1.2x) the chain's, and
    tree tokens/s >= ``TOKS_FLOOR`` (0.35x) the chain's.
  * **parity** — width=1 is the degenerate tree: full engine streams
    (greedy AND per-request-keyed sampled, dense AND paged) must be
    byte-identical to the chain engine — deterministic.
  * **paged** — width=2 paged vs dense streams byte-identical;
    non-path verify rows route to the trash page, so the leak gate
    (zero pages outstanding after drain) is part of the scenario.
"""
from __future__ import annotations

import time

from benchmarks.common import demo_target, emit, trained_draft

GAMMA = 3
WIDTH = 2          # gate shape: W*gamma+1 = 7-node block vs 4-node chain
ACCEPT_BAR = 1.2   # accepted-draft-tokens-per-superstep ratio, tree/chain
TOKS_FLOOR = 0.35  # CPU tokens/s ratio floor (tree pass is W*gamma+1 wide)
REPEATS = 4        # min-of-N wall discipline from PR 4


def _mixed_prompts(domains, batch, width=12, seed=5):
    import numpy as np

    rng = np.random.default_rng(seed)
    doms = list(domains.values())
    prompts = [doms[i % len(doms)].sample_prompt(rng)[:width]
               for i in range(batch)]
    return [p + [0] * (width - len(p)) for p in prompts]


def _step_driver(cfg, params, dcfg, dparams, domains, width, batch,
                 n_steps):
    """Jitted chain (width=0) / tree decode step + a fresh start state,
    sized so ``n_steps`` supersteps can never overrun the cache."""
    import jax
    import jax.numpy as jnp

    from repro.core import eagle
    from repro.core import speculative as spec
    from repro.models import transformer as T

    toks = jnp.asarray(_mixed_prompts(domains, batch))
    max_len = toks.shape[1] + (GAMMA + 1) * (n_steps + 2)
    pre = T.prefill(cfg, params, toks, max_len=max_len)
    first = pre["logits"].argmax(-1).astype(jnp.int32)
    dcache = eagle.init_draft_cache(dcfg, batch, max_len)
    dcache = spec.seed_draft_cache(cfg, dcfg, params, dparams, dcache,
                                   pre, toks)
    carry = spec.init_carry(cfg, dcfg, pre, first, GAMMA)
    if width:
        fn = jax.jit(lambda c, dc, cr: spec.tree_decode_step(
            cfg, dcfg, params, dparams, c, dc, cr, gamma=GAMMA,
            width=width))
    else:
        fn = jax.jit(lambda c, dc, cr: spec.spec_decode_step(
            cfg, dcfg, params, dparams, c, dc, cr, gamma=GAMMA))
    return fn, (pre["cache"], dcache, carry)


def _run_steps(fn, start, n_steps):
    """(accepted draft tokens, committed tokens, best-of-N wall)."""
    import jax
    import numpy as np

    cache, dcache, carry = start
    best_wall, tot = float("inf"), 0
    for rep in range(REPEATS + 1):            # rep 0 warms the jit
        out = {"cache": cache, "dcache": dcache, "carry": carry}
        jax.block_until_ready(out["cache"])
        t0 = time.perf_counter()
        tot = 0
        for _ in range(n_steps):
            out = fn(out["cache"], out["dcache"], out["carry"])
            tot += int(np.asarray(out["n_commit"]).sum())
        jax.block_until_ready(out["tokens"])
        wall = time.perf_counter() - t0
        if rep and wall < best_wall:
            best_wall = wall
    return tot, best_wall


def _accept_scenario(cfg, params, dcfg, dparams, domains, smoke):
    batch = 8
    n_steps = 24 if smoke else 48
    stats = {}
    for width in (0, WIDTH):
        fn, start = _step_driver(cfg, params, dcfg, dparams, domains,
                                 width, batch, n_steps)
        committed, wall = _run_steps(fn, start, n_steps)
        # every superstep commits >= 1 token (the bonus/correction);
        # the rest are accepted draft tokens — the tree's currency
        accepted = committed - n_steps * batch
        stats[width] = dict(acc=accepted / (n_steps * batch),
                            commit=committed / (n_steps * batch),
                            toks=committed / wall, wall=wall)
    chain, tree = stats[0], stats[WIDTH]
    acc_ratio = tree["acc"] / max(chain["acc"], 1e-9)
    toks_ratio = tree["toks"] / max(chain["toks"], 1e-9)
    emit("tree/accept", 0.0,
         f"W={WIDTH};gamma={GAMMA};passes={n_steps};"
         f"acc_tok_per_pass={tree['acc']:.3f}vs{chain['acc']:.3f};"
         f"ratio={acc_ratio:.2f}x;"
         f"commit_per_pass={tree['commit']:.3f}vs{chain['commit']:.3f};"
         f"tok_s={tree['toks']:.0f}vs{chain['toks']:.0f};"
         f"tok_s_uplift={toks_ratio:.2f}x")
    if acc_ratio < ACCEPT_BAR:
        raise AssertionError(
            f"tree accepted {tree['acc']:.3f} draft tokens/pass vs chain "
            f"{chain['acc']:.3f} ({acc_ratio:.2f}x < {ACCEPT_BAR}x): the "
            f"W={WIDTH} tree is not recovering rejected first guesses")
    if toks_ratio < TOKS_FLOOR:
        raise AssertionError(
            f"tree tokens/s {tree['toks']:.0f} vs chain "
            f"{chain['toks']:.0f} ({toks_ratio:.2f}x < {TOKS_FLOOR}x): "
            f"the tree verify block costs more wall than its width "
            f"explains")


def _build_engine(cfg, params, dcfg, dparams, **kw):
    from repro.serving.engine import ServingEngine
    from repro.serving.policy import ServingConfig

    scfg = ServingConfig(gamma=GAMMA, seed=11, superstep_rounds=8,
                         **dict({"max_len": 96, "batch_size": 4}, **kw))
    return ServingEngine(cfg, params, dcfg, dparams, config=scfg)


def _requests(trace):
    from repro.serving.request import Request

    return [Request(prompt=list(ev.prompt), domain=ev.domain,
                    max_new_tokens=ev.max_new_tokens) for ev in trace]


def _serve(cfg, params, dcfg, dparams, trace, **kw):
    eng = _build_engine(cfg, params, dcfg, dparams, **kw)
    reqs = _requests(trace)
    eng.serve_stream(reqs)
    if eng.allocator is not None:
        eng.release_prefix_cache()
        eng.allocator.assert_clean()    # zero leaked pages after drain
    return [list(r.generated) for r in reqs]


def _parity_scenario(cfg, params, dcfg, dparams, domains, smoke):
    from repro.data.workloads import arrival_trace

    n_req = 12 if smoke else 20
    trace = arrival_trace(domains, n_req, mode="bursty", burst_size=4,
                          max_new_range=(6, 12), prompt_len=(8, 20),
                          seed=23)
    for greedy in (True, False):
        chain = _serve(cfg, params, dcfg, dparams, trace, greedy=greedy)
        for name, kw in (("dense", {}), ("paged", {"page_size": 8})):
            tree = _serve(cfg, params, dcfg, dparams, trace,
                          greedy=greedy, tree_width=1, **kw)
            if tree != chain:
                mode = "greedy" if greedy else "sampled"
                raise AssertionError(
                    f"width=1 tree {name} {mode} streams diverged from "
                    f"the chain engine: the degenerate tree is not "
                    f"bitwise chain-equal")
        mode = "greedy" if greedy else "sampled"
        emit(f"tree/parity/{mode}", 0.0,
             f"requests={n_req};width=1;byte_identical=1")


def _paged_scenario(cfg, params, dcfg, dparams, domains, smoke):
    from repro.data.workloads import arrival_trace

    n_req = 12 if smoke else 20
    trace = arrival_trace(domains, n_req, mode="bursty", burst_size=4,
                          max_new_range=(6, 12), prompt_len=(8, 20),
                          seed=31)
    dense = _serve(cfg, params, dcfg, dparams, trace, tree_width=WIDTH)
    paged = _serve(cfg, params, dcfg, dparams, trace, tree_width=WIDTH,
                   page_size=8)
    if paged != dense:
        raise AssertionError(
            f"width={WIDTH} paged streams diverged from dense: tree "
            f"verify rows are not landing on the same bytes")
    emit("tree/paged", 0.0,
         f"requests={n_req};width={WIDTH};byte_identical=1;leaked_pages=0")


def run(smoke: bool = False):
    cfg, params, domains = demo_target(30 if smoke else 120)
    dcfg, dparams, _ = trained_draft("science", steps=30 if smoke else 90)
    _accept_scenario(cfg, params, dcfg, dparams, domains, smoke)
    _parity_scenario(cfg, params, dcfg, dparams, domains, smoke)
    _paged_scenario(cfg, params, dcfg, dparams, domains, smoke)


if __name__ == "__main__":
    run()
