"""Paper Fig. 9: TIDE-default (speculation always on) vs TIDE-adaptive
(Eq. 5 threshold) under sequential domain shifts (the multilingual
Alpaca experiment, modeled as disjoint-vocab domain transitions).

During a shift the cold draft's acceptance collapses; adaptive control
must disable speculation and keep throughput near the plain-decoding
baseline, finishing the identical workload sooner.
"""
from __future__ import annotations


from benchmarks.common import demo_target, emit
from repro.core.adaptive import LatencyProfile
from repro.core.tide import TideConfig, TideSystem
from repro.data.workloads import MULTILINGUAL, Phase, WorkloadStream, \
    make_domains


def _run(adaptive: bool, cfg, params, domains, schedule):
    stream = WorkloadStream(domains, schedule, seed=9)
    tc = TideConfig(batch_size=4, max_len=96, n_threshold=4,
                    signal_window=16, adaptive_spec=adaptive,
                    train_epochs=2)
    # a profile where speculation only pays off above ~1.6 accepted
    # tokens/step — the cold-draft regime must fall below it
    prof = LatencyProfile([1, 2, 4, 8], [1.0, 1.1, 1.25, 1.5],
                          d0_ms=0.18)
    sys_ = TideSystem(cfg, params, tc, profile=prof if adaptive else None)
    sys_.run(stream.batches(4), max_new_tokens=24)
    return sys_


def run():
    cfg, params, _ = demo_target()
    # language domains are fresh vocab regions (max shift, paper §5.1)
    langs = make_domains(cfg.vocab_size, MULTILINGUAL,
                         branchings=[3, 3, 3, 3], seed=31)
    schedule = [Phase(m, 16) for m in MULTILINGUAL]
    for mode, adaptive in (("default", False), ("adaptive", True)):
        sys_ = _run(adaptive, cfg, params, langs, schedule)
        s = sys_.summary()
        spec_frac = s["spec_steps"] / max(s["steps"], 1)
        emit(f"fig9/{mode}/throughput_tok_s", 0.0,
             f"{s['throughput_tok_s']:.1f}")
        emit(f"fig9/{mode}/spec_step_fraction", 0.0, f"{spec_frac:.2f}")
        emit(f"fig9/{mode}/wall_s", s["steps"],
             f"{sys_.engine.stats.wall_s:.1f}")


if __name__ == "__main__":
    run()
