"""Paper Table 1: hidden-state storage — SpecForge-offline (whole-dataset
store) vs TIDE (rolling training buffer).

Exact byte math: signals are 3 capture layers × d_model × bf16 per token.
Dataset scale follows the paper's ShareGPT run (~270 M tokens, derived
from its gpt-oss-120b row: 4.66 TB / 17.28 KB per token); TIDE's buffer
holds one training window (N_threshold ≈ 11 M tokens, from its 0.19 TB).
Reported for the paper's targets and every assigned arch.
"""
from __future__ import annotations

import repro.configs as C
from benchmarks.common import emit
from repro.core.signals import storage_bytes_per_token

DATASET_TOKENS = 270e6
BUFFER_TOKENS = 11e6

PAPER_TABLE1 = {  # TB, from the paper, for reference in the CSV
    "gpt-oss-120b": (4.66, 0.19),
}


def run():
    archs = ["gpt-oss-120b"] + C.assigned()
    for arch in archs:
        cfg = C.get(arch)
        bpt = storage_bytes_per_token(cfg)
        offline_tb = bpt * DATASET_TOKENS / 1e12
        tide_tb = bpt * BUFFER_TOKENS / 1e12
        emit(f"table1/{arch}/offline_tb", bpt, f"{offline_tb:.2f}")
        emit(f"table1/{arch}/tide_tb", bpt, f"{tide_tb:.2f}")
        emit(f"table1/{arch}/ratio", bpt,
             f"{offline_tb / tide_tb:.1f}x")
        if arch in PAPER_TABLE1:
            po, pt = PAPER_TABLE1[arch]
            emit(f"table1/{arch}/paper_reported", 0.0,
                 f"offline={po}TB;tide={pt}TB;ratio={po/pt:.1f}x")


if __name__ == "__main__":
    run()
