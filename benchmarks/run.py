# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness — one module per paper table/figure:

  Fig. 4   bench_beta_ratio          β(b) verification-latency ratio
  Fig. 5/6 bench_adaptation          accept-length/throughput over time
  Fig. 8   bench_speedup_model       Eq. 5 predicted vs actual speedup
  Fig. 9   bench_adaptive_control    TIDE-default vs TIDE-adaptive
  Fig.10-12 bench_hetero             heterogeneous allocation model
  Table 1  bench_storage             hidden-state storage math
  Table 2  bench_training_time       reuse vs recompute training time
  Table 3  bench_cross_domain        cross-dataset acceptance matrix
  Table 4  bench_gamma_sweep         (batch, γ) configuration sweep
  Table 5  bench_profile_latency     T(n)/D0 profiles
  (g)      bench_roofline            dry-run roofline table
  kernels  bench_kernels             kernel oracles + TPU rooflines

Run all: ``PYTHONPATH=src python -m benchmarks.run``
Run one: ``PYTHONPATH=src python -m benchmarks.run --only table2``

Besides the CSV stream, each run writes a machine-readable report —
``BENCH_smoke.json`` / ``BENCH_full.json`` (or ``--bench-out PATH``) —
with per-bench status, wall seconds, emitted metric rows, and the
overall pass/fail gate, so CI and regression tooling can diff runs
without scraping stdout.  ``--only`` runs skip the default report (a
filtered run is not comparable) unless ``--bench-out`` names one.

``--check`` additionally compares this run's **round-domain** metrics
(the ``BASELINE_KEYS`` allowlist — deterministic hit rates, counters,
and gated ratios; never wall-clock) against the committed
``benchmarks/BENCH_baseline.json``, appends the verdict to
``benchmarks/BENCH_history.jsonl``, and exits nonzero on drift.
``--update-baseline`` rewrites the baseline from the current run:

    PYTHONPATH=src python -m benchmarks.run --only overload --check
    PYTHONPATH=src python -m benchmarks.run --only overload \\
        --update-baseline
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

MODULES = [
    ("hotloop", "benchmarks.bench_hotloop"),
    ("continuous", "benchmarks.bench_continuous"),
    ("decoupled", "benchmarks.bench_decoupled"),
    ("slo", "benchmarks.bench_slo"),
    ("overload", "benchmarks.bench_overload"),
    ("paged", "benchmarks.bench_paged"),
    ("tree", "benchmarks.bench_tree"),
    ("fleet", "benchmarks.bench_fleet"),
    ("table5", "benchmarks.bench_profile_latency"),
    ("fig4", "benchmarks.bench_beta_ratio"),
    ("table1", "benchmarks.bench_storage"),
    ("table2", "benchmarks.bench_training_time"),
    ("table3", "benchmarks.bench_cross_domain"),
    ("table4", "benchmarks.bench_gamma_sweep"),
    ("fig8", "benchmarks.bench_speedup_model"),
    ("fig5", "benchmarks.bench_adaptation"),
    ("fig9", "benchmarks.bench_adaptive_control"),
    ("fig10", "benchmarks.bench_hetero"),
    ("roofline", "benchmarks.bench_roofline"),
    ("kernels", "benchmarks.bench_kernels"),
]


# Fast CI perf-smoke gate: the serving hot-loop overhead bench (reduced
# shapes) + the continuous-batching goodput/parity gate (including the
# long-prompt chunked-refill scenario: byte parity, the deterministic
# max-prefill-op-width stall bound, and the modeled-goodput gate) + the
# decoupled async-training gate (>=1.2x serving vs blocking training +
# drain parity) + the serving-policy SLO gate (EDF deadline-hit-rate
# >= 1.2x FIFO, eager-commit short-prompt TTFT, stream byte parity, no
# added syncs) + the overload-resilience gate (preemptive weighted-EDF
# deadline-hit-rate >= 1.3x non-preemptive EDF at ~4x overload, bounded
# p99, byte-identical restored streams greedy and sampled, zero leaked
# pages, no added syncs) + the paged-KV gate (>= 4x served slots at the dense HBM
# footprint with zero deferrals, dense/paged stream byte parity greedy
# and sampled, prefix-sharing registry hits with <= 0.7x prefill
# row-token work, zero leaked pages after drain) + the tree-speculation
# gate (accepted draft tokens per target pass >= 1.2x the linear chain
# at equal passes, tokens/s uplift reported with a conservative CPU
# floor, width=1 engine streams byte-identical to the chain, zero
# leaked pages with paging on) + the disaggregation gate (N=4 replica
# fleet >= 3x single-replica critical-path rounds with byte-identical
# streams and full bus fan-out, out-of-process trainer drain-parity
# byte-identical with no added serving-path syncs, trainer-kill
# degradation completes every request) + the kernel oracles.
# ``python -m benchmarks.run --smoke``.
SMOKE_MODULES = [
    ("hotloop", "benchmarks.bench_hotloop"),
    ("continuous", "benchmarks.bench_continuous"),
    ("decoupled", "benchmarks.bench_decoupled"),
    ("slo", "benchmarks.bench_slo"),
    ("overload", "benchmarks.bench_overload"),
    ("paged", "benchmarks.bench_paged"),
    ("tree", "benchmarks.bench_tree"),
    ("fleet", "benchmarks.bench_fleet"),
    ("kernels", "benchmarks.bench_kernels"),
]

# ------------------------------------------------- baseline regression
# Round-domain metric keys pinned by ``--check`` against the committed
# ``benchmarks/BENCH_baseline.json``.  Only deterministic round-clock
# keys are eligible — never wall-clock keys (0.8-2.5x noise on this
# shared host), and never accept-rate-dependent keys like raw round
# counts (the smoke-mode draft trains fewer steps than full, so its
# makespan differs; hit rates, preempt/restore counters, and the gated
# ratios are invariant by trace design).  ``--update-baseline``
# rewrites the file from the current run restricted to these keys.
BASELINE_KEYS = {
    "overload/preempt/base": ["hit_rate", "tight_hit_rate"],
    "overload/preempt/wedf": ["hit_rate", "tight_hit_rate",
                              "preemptions", "restores"],
    "overload/preempt/ratio": ["hit_gain", "p99_ratio", "sync_ratio"],
    "overload/preempt/sampled": ["preemptions", "restores", "parity"],
    "overload/preempt/paged": ["preemptions", "restores",
                               "spilled_pages", "parity"],
    # fleet keys are structural: parity flags, the replica count, the
    # counter-derived sync ratio (~1.0 by construction), and the gated
    # round-domain speedup (trace-design-invariant up to draft accept
    # rate, hence the wider tolerance)
    "fleet/ratio": ["round_speedup", "parity", "replicas"],
    "fleet/remote": ["parity", "sync_ratio", "trainer_failures"],
    "fleet/kill": ["parity", "trainer_failures"],
}
# per-key relative tolerance overrides written into the baseline file:
# the p99/sync ratios sit near 1.0 by construction but their exact
# value shifts a little with the draft's accept rate
BASELINE_TOLS = {
    "overload/preempt/ratio:p99_ratio": 0.15,
    "overload/preempt/ratio:sync_ratio": 0.15,
    "fleet/ratio:round_speedup": 0.2,
    "fleet/remote:sync_ratio": 0.05,
}
BASELINE_PATH = "benchmarks/BENCH_baseline.json"
HISTORY_PATH = "benchmarks/BENCH_history.jsonl"
_DEFAULT_TOL = 0.02     # relative; counters compare exactly via this


def _parse_derived(derived: str) -> dict:
    """``k=v;k=v`` derived strings -> {key: float} (trailing units like
    the ``x`` of ratio values are stripped; non-numeric values skipped)."""
    out = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = float(v.rstrip("x"))
        except ValueError:
            pass
    return out


def _live_metrics(rows) -> dict:
    live = {}
    for name, _us, derived in rows:
        live.setdefault(name, {}).update(_parse_derived(derived))
    return live


def _check_baseline(path: str, rows) -> tuple:
    """Compare this run's round-domain metrics against the committed
    baseline.  Returns (failures, n_compared)."""
    with open(path) as f:
        base = json.load(f)
    live = _live_metrics(rows)
    tols = base.get("tolerances", {})
    failures, compared = [], 0
    for name, keys in base["metrics"].items():
        got_row = live.get(name)
        if got_row is None:
            failures.append(f"{name}: row missing from this run")
            continue
        for key, want in keys.items():
            compared += 1
            got = got_row.get(key)
            tol = tols.get(f"{name}:{key}", base.get("tolerance",
                                                     _DEFAULT_TOL))
            if got is None:
                failures.append(f"{name}:{key}: key missing")
            elif abs(got - want) > tol * max(abs(want), 1.0):
                failures.append(
                    f"{name}:{key}: {got:g} vs baseline {want:g} "
                    f"(tol {tol:g})")
    return failures, compared


def _update_baseline(path: str, rows) -> None:
    live = _live_metrics(rows)
    metrics = {}
    for name, keys in BASELINE_KEYS.items():
        row = live.get(name)
        if row is None:
            continue
        picked = {k: row[k] for k in keys if k in row}
        if picked:
            metrics[name] = picked
    doc = {"schema": "tide-bench-baseline/v1",
           "tolerance": _DEFAULT_TOL,
           "tolerances": {k: v for k, v in BASELINE_TOLS.items()
                          if k.split(":")[0] in metrics},
           "metrics": metrics}
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# baseline -> {path} ({sum(map(len, metrics.values()))} "
          f"keys)", flush=True)


def _append_history(path: str, mode: str, failed, check_failures,
                    compared: int) -> None:
    entry = {"ts": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
             "mode": mode, "passed": not (failed or check_failures),
             "failed_benches": failed, "checked_keys": compared,
             "check_failures": check_failures}
    with open(path, "a") as f:
        json.dump(entry, f, sort_keys=True)
        f.write("\n")
    print(f"# history -> {path}", flush=True)


def _write_report(path: str, mode: str, benches: list,
                  failed: list) -> None:
    """Write the machine-readable run report: per-bench status/seconds/
    metric rows plus the overall gate verdict."""
    doc = {
        "schema": "tide-bench-report/v1",
        "mode": mode,
        "passed": not failed,
        "failed": failed,
        "benches": benches,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# report -> {path}", flush=True)


def main() -> None:
    import inspect

    from benchmarks import common

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on the bench tag")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI perf-smoke: hotloop + kernels only, "
                         "reduced shapes")
    ap.add_argument("--bench-out", default=None, metavar="PATH",
                    help="machine-readable JSON report path (default: "
                         "BENCH_smoke.json / BENCH_full.json; --only "
                         "runs write no report unless this is given)")
    ap.add_argument("--check", action="store_true",
                    help="after the run, compare round-domain metrics "
                         f"against {BASELINE_PATH} and append the "
                         f"verdict to {HISTORY_PATH}; exits nonzero on "
                         "regression")
    ap.add_argument("--baseline", default=BASELINE_PATH, metavar="PATH",
                    help="baseline file for --check/--update-baseline")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from this run (restricted "
                         "to the BASELINE_KEYS round-domain allowlist)")
    args = ap.parse_args()
    modules = SMOKE_MODULES if args.smoke else MODULES
    mode = "smoke" if args.smoke else "full"
    out = args.bench_out
    if out is None and not args.only:
        out = f"BENCH_{mode}.json"
    print("name,us_per_call,derived")
    failed = []
    benches = []
    for tag, module in modules:
        if args.only and args.only not in tag:
            continue
        t0 = time.perf_counter()
        row0 = len(common.ROWS)
        print(f"# === {tag} ({module}) ===", flush=True)
        error = None
        try:
            fn = __import__(module, fromlist=["run"]).run
            kw = {}
            if args.smoke and "smoke" in inspect.signature(fn).parameters:
                kw["smoke"] = True
            fn(**kw)
        except Exception:
            failed.append(tag)
            error = traceback.format_exc()
            print(f"# {tag} FAILED:", file=sys.stderr)
            traceback.print_exc()
        dt = time.perf_counter() - t0
        benches.append({
            "tag": tag, "module": module,
            "status": "failed" if error else "passed",
            "seconds": round(dt, 3),
            "error": error,
            "metrics": [{"name": n, "us_per_call": round(us, 3),
                         "derived": d}
                        for n, us, d in common.ROWS[row0:]],
        })
        print(f"# === {tag} done in {dt:.1f}s ===", flush=True)
    if out:
        _write_report(out, mode, benches, failed)
    if args.update_baseline:
        _update_baseline(args.baseline, common.ROWS)
    check_failures, compared = [], 0
    if args.check:
        check_failures, compared = _check_baseline(args.baseline,
                                                   common.ROWS)
        for msg in check_failures:
            print(f"# CHECK FAILED {msg}", file=sys.stderr)
        print(f"# check: {compared} keys vs {args.baseline}, "
              f"{len(check_failures)} regressions", flush=True)
        _append_history(HISTORY_PATH, mode, failed, check_failures,
                        compared)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")
    if check_failures:
        raise SystemExit(
            f"baseline regression: {len(check_failures)} metric(s) "
            f"drifted (see CHECK FAILED lines)")


if __name__ == '__main__':
    main()
