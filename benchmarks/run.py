# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness — one module per paper table/figure:

  Fig. 4   bench_beta_ratio          β(b) verification-latency ratio
  Fig. 5/6 bench_adaptation          accept-length/throughput over time
  Fig. 8   bench_speedup_model       Eq. 5 predicted vs actual speedup
  Fig. 9   bench_adaptive_control    TIDE-default vs TIDE-adaptive
  Fig.10-12 bench_hetero             heterogeneous allocation model
  Table 1  bench_storage             hidden-state storage math
  Table 2  bench_training_time       reuse vs recompute training time
  Table 3  bench_cross_domain        cross-dataset acceptance matrix
  Table 4  bench_gamma_sweep         (batch, γ) configuration sweep
  Table 5  bench_profile_latency     T(n)/D0 profiles
  (g)      bench_roofline            dry-run roofline table
  kernels  bench_kernels             kernel oracles + TPU rooflines

Run all: ``PYTHONPATH=src python -m benchmarks.run``
Run one: ``PYTHONPATH=src python -m benchmarks.run --only table2``

Besides the CSV stream, each run writes a machine-readable report —
``BENCH_smoke.json`` / ``BENCH_full.json`` (or ``--bench-out PATH``) —
with per-bench status, wall seconds, emitted metric rows, and the
overall pass/fail gate, so CI and regression tooling can diff runs
without scraping stdout.  ``--only`` runs skip the default report (a
filtered run is not comparable) unless ``--bench-out`` names one.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

MODULES = [
    ("hotloop", "benchmarks.bench_hotloop"),
    ("continuous", "benchmarks.bench_continuous"),
    ("decoupled", "benchmarks.bench_decoupled"),
    ("slo", "benchmarks.bench_slo"),
    ("paged", "benchmarks.bench_paged"),
    ("tree", "benchmarks.bench_tree"),
    ("table5", "benchmarks.bench_profile_latency"),
    ("fig4", "benchmarks.bench_beta_ratio"),
    ("table1", "benchmarks.bench_storage"),
    ("table2", "benchmarks.bench_training_time"),
    ("table3", "benchmarks.bench_cross_domain"),
    ("table4", "benchmarks.bench_gamma_sweep"),
    ("fig8", "benchmarks.bench_speedup_model"),
    ("fig5", "benchmarks.bench_adaptation"),
    ("fig9", "benchmarks.bench_adaptive_control"),
    ("fig10", "benchmarks.bench_hetero"),
    ("roofline", "benchmarks.bench_roofline"),
    ("kernels", "benchmarks.bench_kernels"),
]


# Fast CI perf-smoke gate: the serving hot-loop overhead bench (reduced
# shapes) + the continuous-batching goodput/parity gate (including the
# long-prompt chunked-refill scenario: byte parity, the deterministic
# max-prefill-op-width stall bound, and the modeled-goodput gate) + the
# decoupled async-training gate (>=1.2x serving vs blocking training +
# drain parity) + the serving-policy SLO gate (EDF deadline-hit-rate
# >= 1.2x FIFO, eager-commit short-prompt TTFT, stream byte parity, no
# added syncs) + the paged-KV gate (>= 4x served slots at the dense HBM
# footprint with zero deferrals, dense/paged stream byte parity greedy
# and sampled, prefix-sharing registry hits with <= 0.7x prefill
# row-token work, zero leaked pages after drain) + the tree-speculation
# gate (accepted draft tokens per target pass >= 1.2x the linear chain
# at equal passes, tokens/s uplift reported with a conservative CPU
# floor, width=1 engine streams byte-identical to the chain, zero
# leaked pages with paging on) + the kernel oracles.
# ``python -m benchmarks.run --smoke``.
SMOKE_MODULES = [
    ("hotloop", "benchmarks.bench_hotloop"),
    ("continuous", "benchmarks.bench_continuous"),
    ("decoupled", "benchmarks.bench_decoupled"),
    ("slo", "benchmarks.bench_slo"),
    ("paged", "benchmarks.bench_paged"),
    ("tree", "benchmarks.bench_tree"),
    ("kernels", "benchmarks.bench_kernels"),
]


def _write_report(path: str, mode: str, benches: list,
                  failed: list) -> None:
    """Write the machine-readable run report: per-bench status/seconds/
    metric rows plus the overall gate verdict."""
    doc = {
        "schema": "tide-bench-report/v1",
        "mode": mode,
        "passed": not failed,
        "failed": failed,
        "benches": benches,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# report -> {path}", flush=True)


def main() -> None:
    import inspect

    from benchmarks import common

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on the bench tag")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI perf-smoke: hotloop + kernels only, "
                         "reduced shapes")
    ap.add_argument("--bench-out", default=None, metavar="PATH",
                    help="machine-readable JSON report path (default: "
                         "BENCH_smoke.json / BENCH_full.json; --only "
                         "runs write no report unless this is given)")
    args = ap.parse_args()
    modules = SMOKE_MODULES if args.smoke else MODULES
    mode = "smoke" if args.smoke else "full"
    out = args.bench_out
    if out is None and not args.only:
        out = f"BENCH_{mode}.json"
    print("name,us_per_call,derived")
    failed = []
    benches = []
    for tag, module in modules:
        if args.only and args.only not in tag:
            continue
        t0 = time.perf_counter()
        row0 = len(common.ROWS)
        print(f"# === {tag} ({module}) ===", flush=True)
        error = None
        try:
            fn = __import__(module, fromlist=["run"]).run
            kw = {}
            if args.smoke and "smoke" in inspect.signature(fn).parameters:
                kw["smoke"] = True
            fn(**kw)
        except Exception:
            failed.append(tag)
            error = traceback.format_exc()
            print(f"# {tag} FAILED:", file=sys.stderr)
            traceback.print_exc()
        dt = time.perf_counter() - t0
        benches.append({
            "tag": tag, "module": module,
            "status": "failed" if error else "passed",
            "seconds": round(dt, 3),
            "error": error,
            "metrics": [{"name": n, "us_per_call": round(us, 3),
                         "derived": d}
                        for n, us, d in common.ROWS[row0:]],
        })
        print(f"# === {tag} done in {dt:.1f}s ===", flush=True)
    if out:
        _write_report(out, mode, benches, failed)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == '__main__':
    main()
