"""Continuous batching vs wave scheduling: goodput + slot occupancy.

Wave scheduling (``serve_wave``) takes B requests and runs them to
completion, so a wave of ragged token budgets convoys behind its
longest member: finished slots burn full attention/MoE/drafter FLOPs as
masked lanes.  Continuous batching (``serve_stream``) refills finished
slots from the pending queue between fused supersteps without tearing
down resident device state.

Measured on ``tide_tiny`` (CPU backend), greedy, ragged
``max_new_tokens`` drawn uniformly from [8, 96] (a bursty arrival
trace), for the same request set served three ways:

  * **wave** — run-to-completion waves of B (the PR 1 baseline),
  * **continuous** — ``serve_stream`` with the fused superstep (K=8),
  * **stepwise** — ``serve_stream`` with the per-step reference loop
    (parity oracle only; not part of the speedup claim).

Reported per mode: goodput (committed tokens/s), slot occupancy
(fraction of lane-rounds that committed tokens), syncs per committed
token, and TTFT/latency percentiles for the continuous run.

Gates (CI):
  * all three modes emit byte-identical per-request token streams
    (greedy decoding makes streams scheduling-invariant) — deterministic,
  * executed decode rounds: wave >= bar x continuous — the
    load-independent core of the win (fewer rounds for the same tokens
    because lanes stay busy; both modes prefill every request exactly
    once, so rounds are the honest work ratio) — deterministic,
  * goodput (min wall over repeats): continuous >= bar x wave —
    1.2x smoke / 1.3x full run; min-of-N damps shared-CPU load spikes,
  * continuous syncs/token <= wave syncs/token (refill must not
    reintroduce per-step host syncs) — deterministic.

Long-prompt chunked-refill scenario (second half): a bimodal
*prompt-length* trace (short-chat bulk + one long prompt per burst)
served by ``serve_stream`` with one-shot refill vs chunked refill
(``prefill_chunk``).  One-shot, every long prompt stalls all resident
decode lanes for its full prefill and every co-admitted short prompt
pays the long prompt's padded width; chunked, prefill proceeds one
bounded chunk per superstep gap in per-width pipelines whose cohort
commits together.  Gates:
  * chunked == one-shot byte-identical per-request streams
    (greedy) — deterministic,
  * max uninterruptible prefill-op width: chunked <= chunk while
    one-shot >= the long-prompt tail (the resident-lane stall bound,
    measured in prompt tokens over executed dispatch gaps, not wall
    time) — deterministic,
  * prefill row-token work: chunked <= 0.7x one-shot (per-width
    pipelines must not pad short prompts to long-tail widths) —
    deterministic,
  * goodput >= 1.15x one-shot, on the deterministic device-work model:
    tokens per row-token work unit, work = prefill row-tokens +
    executed decode rounds x B x (gamma+1) verify positions.  On this
    2-vCPU serial host a refill stall costs the same wall whether it
    runs monolithic or chunked (the device is work-conserving and
    masked lanes are not free), so raw wall cannot surface the stall
    that parallel batch lanes absorb — the work model is the
    load-independent form of the claim, the same device-work modeling
    the repo's speedup benches use.  Raw min-wall-of-N goodput is
    emitted alongside and gated only as a loose sanity guard (>= 0.5x:
    identical workloads measure 0.8-2.5x apart on this shared host).
"""
from __future__ import annotations


from benchmarks.common import demo_target, emit, trained_draft


def _build_engine(cfg, params, dcfg, dparams, rounds, *, batch, max_len,
                  prefill_chunk=0):
    from repro.core.signals import SignalExtractor, SignalStore
    from repro.serving.engine import ServingEngine
    from repro.serving.policy import ServingConfig

    store = SignalStore()
    ext = SignalExtractor(store, window=32)
    scfg = ServingConfig(batch_size=batch, max_len=max_len, gamma=3,
                         seed=11, superstep_rounds=rounds,
                         prefill_chunk=prefill_chunk)
    return ServingEngine(cfg, params, dcfg, dparams, config=scfg,
                         extractor=ext)


def _requests(trace):
    from repro.serving.request import Request

    return [Request(prompt=list(ev.prompt), domain=ev.domain,
                    max_new_tokens=ev.max_new_tokens) for ev in trace]


def _serve_waves(eng, reqs, batch):
    for i in range(0, len(reqs), batch):
        eng.serve_wave(reqs[i:i + batch])
    return reqs


def _serve_stream(eng, reqs):
    eng.serve_stream(reqs)
    return reqs              # original arrival order (not completion order)


def _long_prompt_scenario(cfg, params, dcfg, dparams, domains,
                          smoke: bool):
    """Chunked vs one-shot refill prefill on a bimodal prompt trace."""
    from repro.data.workloads import arrival_trace

    batch, max_len, chunk, gamma = 4, 160, 32, 3
    n_req = 16 if smoke else 24
    # bursty co-arrivals: every burst mixes one long prompt with
    # short-chat requests — the mix where one-shot refill both stalls
    # resident lanes for the full long prefill AND pads every
    # co-admitted short prompt to the long prompt's width; narrow
    # budgets keep bursts retiring together so refills stay co-batched
    trace = arrival_trace(domains, n_req, mode="bursty", burst_size=batch,
                          max_new_range=(6, 12), prompt_len=(8, 14),
                          long_prompt_period=batch,
                          long_prompt_range=(72, 96), seed=13)
    long_tail = max(len(ev.prompt) for ev in trace)
    assert long_tail >= 72, "trace lost its long-prompt tail"

    def work_units(st):
        # deterministic device-work model: prompt row-tokens prefilled
        # + verify positions decoded (executed rounds x lanes x (γ+1))
        return st.prefill_row_tokens + st.steps * batch * (gamma + 1)

    streams, results = {}, {}
    for name, pc in (("oneshot", 0), ("chunked", chunk)):
        eng = _build_engine(cfg, params, dcfg, dparams, 8, batch=batch,
                            max_len=max_len, prefill_chunk=pc)
        _serve_stream(eng, _requests(trace))     # warm every shape
        best_wall, st = float("inf"), None
        for _ in range(3):
            eng.stats = type(eng.stats)()
            reqs = _serve_stream(eng, _requests(trace))
            if eng.stats.wall_s < best_wall:
                best_wall, st = eng.stats.wall_s, eng.stats
        streams[name] = [list(r.generated) for r in reqs]
        tokens = sum(len(r.generated) for r in reqs)
        assert tokens == st.tokens_out
        results[name] = (tokens / best_wall, tokens / work_units(st), st)
        emit(f"continuous/longprompt/{name}", 0.0,
             f"tok_per_s={tokens / best_wall:.0f};"
             f"tok_per_kwork={1e3 * tokens / work_units(st):.1f};"
             f"max_prefill_op_w={st.prefill_op_width.max:.0f};"
             f"max_gap_prefill_tokens={st.prefill_gap_tokens.max:.0f};"
             f"prefill_row_tokens={st.prefill_row_tokens};"
             f"rounds={st.steps};chunks={st.prefill_chunks};"
             f"occupancy={st.occupancy:.3f}")

    if streams["chunked"] != streams["oneshot"]:
        raise AssertionError(
            "chunked refill per-request streams diverged from one-shot "
            "(byte-parity gate)")
    wall_one, gp_one, st_one = results["oneshot"]
    wall_chk, gp_chk, st_chk = results["chunked"]
    emit("continuous/longprompt/ratio", 0.0,
         f"goodput_gain={gp_chk / gp_one:.2f}x;bar=1.15x;"
         f"wall_ratio={wall_chk / wall_one:.2f}x;"
         f"stall_bound={st_chk.prefill_op_width.max:.0f}<={chunk};"
         f"oneshot_stall={st_one.prefill_op_width.max:.0f};"
         f"row_tokens={st_one.prefill_row_tokens}->"
         f"{st_chk.prefill_row_tokens}")
    # deterministic resident-lane stall bound: the longest prefill op a
    # decode gap ever waits on is one chunk, vs the full long-tail
    # prompt one-shot
    if st_chk.prefill_op_width.max > chunk:
        raise AssertionError(
            f"chunked prefill dispatched an op "
            f"{st_chk.prefill_op_width.max:.0f} wide — stall not "
            f"bounded by the {chunk}-token chunk")
    if st_one.prefill_op_width.max < long_tail:
        raise AssertionError(
            "one-shot baseline lost its long-prompt stall "
            f"({st_one.prefill_op_width.max:.0f} < {long_tail})")
    if st_chk.prefill_row_tokens > 0.7 * st_one.prefill_row_tokens:
        raise AssertionError(
            "chunked refill prefill work not under 0.7x one-shot "
            f"({st_chk.prefill_row_tokens} vs "
            f"{st_one.prefill_row_tokens}) — width grouping broken")
    if gp_chk < 1.15 * gp_one:
        raise AssertionError(
            f"chunked refill modeled goodput {1e3 * gp_chk:.1f} not "
            f">= 1.15x one-shot {1e3 * gp_one:.1f} tok/kwork on the "
            "long-prompt trace")
    # loose sanity guard only: identical workloads measure 0.8-2.5x
    # apart on this shared 2-vCPU host, so anything tighter flakes —
    # the load-bearing gates above are the deterministic ones
    if wall_chk < 0.5 * wall_one:
        raise AssertionError(
            f"chunked refill wall goodput regressed: {wall_chk:.0f} "
            f"tok/s < 0.5x one-shot {wall_one:.0f} tok/s")


def run(smoke: bool = False):
    cfg, params, domains = demo_target(30 if smoke else 120)
    dcfg, dparams, _ = trained_draft("science", steps=30 if smoke else 90)
    batch, max_len = 4, 160
    n_req = 16 if smoke else 20

    # bimodal budgets in [8, 96]: short-chat bulk + a 25% long tail, the
    # request mix where run-to-completion waves convoy hardest
    from repro.data.workloads import arrival_trace
    trace = arrival_trace(domains, n_req, mode="bursty", burst_size=batch,
                          max_new_range=(8, 24), long_frac=0.25,
                          long_range=(80, 96), seed=7)

    modes = {
        "wave": lambda eng, reqs: _serve_waves(eng, reqs, batch),
        "continuous": _serve_stream,
        "stepwise": _serve_stream,
    }
    rounds = {"wave": 8, "continuous": 8, "stepwise": 0}
    # min-of-N needs N=4 even in smoke: this host's wall noise spans
    # 0.8-2.5x on identical workloads, and too few samples leave the
    # min itself straddling the bar
    repeats = {"wave": 4, "continuous": 4,
               "stepwise": 1}     # stepwise is the parity oracle only

    streams, results = {}, {}
    for name, serve in modes.items():
        eng = _build_engine(cfg, params, dcfg, dparams, rounds[name],
                            batch=batch, max_len=max_len)
        # warm over the SAME request sequence: prefill/refill shapes vary
        # per wave and per refill batch, so every shape must be compiled
        # before measuring
        serve(eng, _requests(trace))
        best_wall, st = float("inf"), None
        for _ in range(repeats[name]):
            eng.stats = type(eng.stats)()
            reqs = serve(eng, _requests(trace))
            if eng.stats.wall_s < best_wall:
                best_wall, st = eng.stats.wall_s, eng.stats
        streams[name] = [list(r.generated) for r in reqs]
        tokens = sum(len(r.generated) for r in reqs)
        assert tokens == st.tokens_out, \
            f"{name}: tokens_out {st.tokens_out} != emitted {tokens}"
        results[name] = (tokens / best_wall, st.occupancy,
                         st.dispatches / tokens, st.steps)
        emit(f"continuous/{name}/goodput", 0.0,
             f"tok_per_s={tokens / best_wall:.0f};tokens={tokens};"
             f"rounds={st.steps};occupancy={st.occupancy:.3f};"
             f"refills={st.refills};"
             f"syncs_per_tok={st.dispatches / tokens:.3f}")
        if name == "continuous":
            emit("continuous/latency", 0.0,
                 f"ttft_p50_s={st.ttft_p50:.3f};"
                 f"latency_p50_s={st.latency_p50:.3f};"
                 f"latency_p95_s={st.latency_p95:.3f}")

    for name in ("continuous", "stepwise"):
        if streams[name] != streams["wave"]:
            raise AssertionError(
                f"{name} per-request token streams diverged from the "
                "wave-scheduled reference")

    (g_wave, occ_wave, sync_wave, rounds_wave) = results["wave"]
    (g_cont, occ_cont, sync_cont, rounds_cont) = results["continuous"]
    bar = 1.2 if smoke else 1.3
    emit("continuous/ratio", 0.0,
         f"goodput_gain={g_cont / g_wave:.2f}x;"
         f"round_reduction={rounds_wave / rounds_cont:.2f}x;"
         f"bar={bar:.1f}x;occupancy={occ_wave:.3f}->{occ_cont:.3f}")
    if rounds_wave < 1.2 * rounds_cont:
        raise AssertionError(
            f"continuous batching executed rounds {rounds_cont} not "
            f"1.2x under the wave baseline {rounds_wave}")
    if g_cont < bar * g_wave:
        raise AssertionError(
            f"continuous batching goodput {g_cont:.0f} tok/s < {bar}x "
            f"wave baseline {g_wave:.0f} tok/s")
    if sync_cont > sync_wave * 1.05 + 1e-9:
        raise AssertionError(
            f"continuous batching regressed host syncs per token: "
            f"{sync_wave:.3f} -> {sync_cont:.3f}")

    _long_prompt_scenario(cfg, params, dcfg, dparams, domains, smoke)


if __name__ == "__main__":
    run()
