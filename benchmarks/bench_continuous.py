"""Continuous batching vs wave scheduling: goodput + slot occupancy.

Wave scheduling (``serve_wave``) takes B requests and runs them to
completion, so a wave of ragged token budgets convoys behind its
longest member: finished slots burn full attention/MoE/drafter FLOPs as
masked lanes.  Continuous batching (``serve_stream``) refills finished
slots from the pending queue between fused supersteps without tearing
down resident device state.

Measured on ``tide_tiny`` (CPU backend), greedy, ragged
``max_new_tokens`` drawn uniformly from [8, 96] (a bursty arrival
trace), for the same request set served three ways:

  * **wave** — run-to-completion waves of B (the PR 1 baseline),
  * **continuous** — ``serve_stream`` with the fused superstep (K=8),
  * **stepwise** — ``serve_stream`` with the per-step reference loop
    (parity oracle only; not part of the speedup claim).

Reported per mode: goodput (committed tokens/s), slot occupancy
(fraction of lane-rounds that committed tokens), syncs per committed
token, and TTFT/latency percentiles for the continuous run.

Gates (CI):
  * all three modes emit byte-identical per-request token streams
    (greedy decoding makes streams scheduling-invariant) — deterministic,
  * executed decode rounds: wave >= bar x continuous — the
    load-independent core of the win (fewer rounds for the same tokens
    because lanes stay busy; both modes prefill every request exactly
    once, so rounds are the honest work ratio) — deterministic,
  * goodput (min wall over repeats): continuous >= bar x wave —
    1.2x smoke / 1.3x full run; min-of-N damps shared-CPU load spikes,
  * continuous syncs/token <= wave syncs/token (refill must not
    reintroduce per-step host syncs) — deterministic.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import demo_target, emit, trained_draft


def _build_engine(cfg, params, dcfg, dparams, rounds, *, batch, max_len):
    from repro.core.signals import SignalExtractor, SignalStore
    from repro.serving.engine import ServingEngine

    store = SignalStore()
    ext = SignalExtractor(store, window=32)
    return ServingEngine(cfg, params, dcfg, dparams, batch_size=batch,
                         max_len=max_len, gamma=3, extractor=ext, seed=11,
                         superstep_rounds=rounds)


def _requests(trace):
    from repro.serving.request import Request

    return [Request(prompt=list(ev.prompt), domain=ev.domain,
                    max_new_tokens=ev.max_new_tokens) for ev in trace]


def _serve_waves(eng, reqs, batch):
    for i in range(0, len(reqs), batch):
        eng.serve_wave(reqs[i:i + batch])
    return reqs


def _serve_stream(eng, reqs):
    eng.serve_stream(reqs)
    return reqs              # original arrival order (not completion order)


def run(smoke: bool = False):
    cfg, params, domains = demo_target(30 if smoke else 120)
    dcfg, dparams, _ = trained_draft("science", steps=30 if smoke else 90)
    batch, max_len = 4, 160
    n_req = 16 if smoke else 20

    # bimodal budgets in [8, 96]: short-chat bulk + a 25% long tail, the
    # request mix where run-to-completion waves convoy hardest
    from repro.data.workloads import arrival_trace
    trace = arrival_trace(domains, n_req, mode="bursty", burst_size=batch,
                          max_new_range=(8, 24), long_frac=0.25,
                          long_range=(80, 96), seed=7)

    modes = {
        "wave": lambda eng, reqs: _serve_waves(eng, reqs, batch),
        "continuous": _serve_stream,
        "stepwise": _serve_stream,
    }
    rounds = {"wave": 8, "continuous": 8, "stepwise": 0}
    repeats = {"wave": 2 if smoke else 3, "continuous": 2 if smoke else 3,
               "stepwise": 1}     # stepwise is the parity oracle only

    streams, results = {}, {}
    for name, serve in modes.items():
        eng = _build_engine(cfg, params, dcfg, dparams, rounds[name],
                            batch=batch, max_len=max_len)
        # warm over the SAME request sequence: prefill/refill shapes vary
        # per wave and per refill batch, so every shape must be compiled
        # before measuring
        serve(eng, _requests(trace))
        best_wall, st = float("inf"), None
        for _ in range(repeats[name]):
            eng.stats = type(eng.stats)()
            reqs = serve(eng, _requests(trace))
            if eng.stats.wall_s < best_wall:
                best_wall, st = eng.stats.wall_s, eng.stats
        streams[name] = [list(r.generated) for r in reqs]
        tokens = sum(len(r.generated) for r in reqs)
        assert tokens == st.tokens_out, \
            f"{name}: tokens_out {st.tokens_out} != emitted {tokens}"
        results[name] = (tokens / best_wall, st.occupancy,
                         st.dispatches / tokens, st.steps)
        emit(f"continuous/{name}/goodput", 0.0,
             f"tok_per_s={tokens / best_wall:.0f};tokens={tokens};"
             f"rounds={st.steps};occupancy={st.occupancy:.3f};"
             f"refills={st.refills};"
             f"syncs_per_tok={st.dispatches / tokens:.3f}")
        if name == "continuous":
            emit("continuous/latency", 0.0,
                 f"ttft_p50_s={st.ttft_p50:.3f};"
                 f"latency_p50_s={st.latency_p50:.3f};"
                 f"latency_p95_s={st.latency_p95:.3f}")

    for name in ("continuous", "stepwise"):
        if streams[name] != streams["wave"]:
            raise AssertionError(
                f"{name} per-request token streams diverged from the "
                "wave-scheduled reference")

    (g_wave, occ_wave, sync_wave, rounds_wave) = results["wave"]
    (g_cont, occ_cont, sync_cont, rounds_cont) = results["continuous"]
    bar = 1.2 if smoke else 1.3
    emit("continuous/ratio", 0.0,
         f"goodput_gain={g_cont / g_wave:.2f}x;"
         f"round_reduction={rounds_wave / rounds_cont:.2f}x;"
         f"bar={bar:.1f}x;occupancy={occ_wave:.3f}->{occ_cont:.3f}")
    if rounds_wave < 1.2 * rounds_cont:
        raise AssertionError(
            f"continuous batching executed rounds {rounds_cont} not "
            f"1.2x under the wave baseline {rounds_wave}")
    if g_cont < bar * g_wave:
        raise AssertionError(
            f"continuous batching goodput {g_cont:.0f} tok/s < {bar}x "
            f"wave baseline {g_wave:.0f} tok/s")
    if sync_cont > sync_wave * 1.05 + 1e-9:
        raise AssertionError(
            f"continuous batching regressed host syncs per token: "
            f"{sync_wave:.3f} -> {sync_cont:.3f}")


if __name__ == "__main__":
    run()
