"""Latency-SLO serving policies: deadline admission + eager commit.

Two scenarios on the policy-driven control plane
(``serving.policy.ServingPolicy``), both gated on **deterministic
round-clock metrics**: a greedy stream's executed-round schedule is a
pure function of the admission order, so ``Request.finish_round`` /
``first_token_round`` (the engine's executed decode-round count at
completion / first token) reproduce exactly run to run — unlike wall
time on this shared 2-vCPU host (0.8–2.5x noise on identical
workloads, the same reason bench_continuous gates on its device-work
model).  Wall-clock equivalents are emitted alongside, ungated.

**Scenario A — deadline admission (EDF vs FIFO).**  A bursty backlog
trace where the last third of arrivals carry *tight* completion
deadlines (interactive traffic stuck behind a batch backlog — the
worst case for arrival-order admission).  Deadlines are expressed in
round units, calibrated from a FIFO reference run: tight = 45% of the
FIFO makespan, loose = 10x (never misses).  FIFO serves in trace
order, so the late-arriving tight requests blow through their
deadlines; ``DeadlineAdmission`` (EDF) pulls them ahead of the loose
backlog.  Gates:

  * deadline-hit-rate: EDF >= 1.2x FIFO — deterministic,
  * per-request token streams: EDF == FIFO byte-identical (greedy
    decoding is admission-order-invariant; a policy may only change
    *when* a request is served, never *what* it generates),
  * zero added host syncs: syncs (superstep dispatches) per committed
    token under EDF <= 1.1x FIFO — the policy hooks are host-side
    decisions between dispatches.

**Scenario B — eager vs cohort chunk-pipeline commit.**  The bimodal
prompt trace of bench_continuous's long-prompt scenario (every burst
mixes one long RAG-style prompt with short chats), served with chunked
refill prefill under both ``CommitPolicy`` built-ins.  Cohort commit
(default) holds a burst's short prompts until the long sibling's
multi-chunk pipeline finishes — densest decode rounds, but the shorts
pay the long prompt's prefill latency.  Eager commit lands each
pipeline the moment it finishes prefilling.  Gates:

  * short-prompt TTFT on the round clock, relative to slot admission
    (``first_token_round - admit_round``; absolute stamps would
    conflate eager's own executed-round inflation), p95: cohort >=
    1.15x eager — deterministic,
  * per-request token streams: eager == cohort byte-identical.

Executed-round totals are emitted for both (eager trades round density
for TTFT — that cost is the reason cohort stays the default).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import demo_target, emit, trained_draft


def _build_engine(cfg, params, dcfg, dparams, scfg):
    from repro.serving.engine import ServingEngine
    return ServingEngine(cfg, params, dcfg, dparams, config=scfg)


def _requests(trace, deadlines=None):
    from repro.serving.request import Request
    reqs = [Request(prompt=list(ev.prompt), domain=ev.domain,
                    max_new_tokens=ev.max_new_tokens,
                    priority=ev.priority) for ev in trace]
    if deadlines is not None:
        for r, d in zip(reqs, deadlines):
            r.deadline = d
    return reqs


def _deadline_scenario(cfg, params, dcfg, dparams, domains, smoke: bool):
    from repro.data.workloads import arrival_trace
    from repro.serving.policy import ServingConfig

    batch, n_req = 4, 18 if smoke else 24
    trace = arrival_trace(domains, n_req, mode="bursty", burst_size=batch,
                          max_new_range=(8, 24), prompt_len=(8, 16),
                          seed=5)
    scfg = {name: ServingConfig(batch_size=batch, max_len=160, gamma=3,
                                seed=11, admission=name)
            for name in ("fifo", "deadline")}

    # calibration: one FIFO pass measures the round-clock makespan (and
    # warms every compile); greedy rounds are deterministic, so the
    # measuring FIFO run below reproduces it exactly
    eng = _build_engine(cfg, params, dcfg, dparams, scfg["fifo"])
    cal = _requests(trace)
    eng.serve_stream(cal)
    makespan = eng.stats.steps
    # the last third of the trace is interactive traffic with tight
    # deadlines; everything earlier is loose batch backlog
    n_tight = n_req // 3
    tight_r, loose_r = 0.45 * makespan, 10.0 * makespan
    deadlines = [loose_r] * (n_req - n_tight) + [tight_r] * n_tight

    results, streams = {}, {}
    for name in ("fifo", "deadline"):
        eng = _build_engine(cfg, params, dcfg, dparams, scfg[name])
        eng.serve_stream(_requests(trace, deadlines))   # warm (EDF shapes)
        eng.stats = type(eng.stats)()
        reqs = _requests(trace, deadlines)
        eng.serve_stream(reqs)
        st = eng.stats
        hits = np.mean([r.finish_round <= r.deadline for r in reqs])
        tight_hits = np.mean([r.finish_round <= r.deadline
                              for r in reqs if r.deadline == tight_r])
        wall_hits = np.mean([(r.finish_t - r.arrival_t)
                             <= r.deadline * st.wall_s / max(st.steps, 1)
                             for r in reqs])
        streams[name] = [list(r.generated) for r in reqs]
        tokens = sum(len(r.generated) for r in reqs)
        results[name] = (hits, st.dispatches / tokens, st.steps)
        emit(f"slo/admission/{name}", 0.0,
             f"hit_rate={hits:.3f};tight_hit_rate={tight_hits:.3f};"
             f"rounds={st.steps};syncs_per_tok={st.dispatches/tokens:.3f};"
             f"wall_hit_rate={wall_hits:.3f};"
             f"latency_p95_s={st.latency_p95:.3f}")

    if streams["deadline"] != streams["fifo"]:
        raise AssertionError(
            "EDF admission changed per-request token streams — "
            "admission order must never change what a request generates")
    hit_f, sync_f, _ = results["fifo"]
    hit_d, sync_d, _ = results["deadline"]
    gain = hit_d / max(hit_f, 1e-9)
    emit("slo/admission/ratio", 0.0,
         f"hit_gain={gain:.2f}x;bar=1.2x;"
         f"sync_ratio={sync_d / sync_f:.3f}")
    if gain < 1.2:
        raise AssertionError(
            f"EDF deadline-hit-rate {hit_d:.3f} not >= 1.2x FIFO "
            f"{hit_f:.3f} on the deadline trace")
    if sync_d > 1.1 * sync_f:
        raise AssertionError(
            f"EDF syncs/token {sync_d:.3f} exceed 1.1x FIFO {sync_f:.3f}"
            " — a policy hook added host syncs")


def _commit_scenario(cfg, params, dcfg, dparams, domains, smoke: bool):
    from repro.data.workloads import arrival_trace
    from repro.serving.policy import ServingConfig

    batch, chunk, n_req = 4, 32, 16 if smoke else 24
    # every burst co-admits one long prompt with short chats; narrow
    # budgets keep bursts retiring together so refill groups stay mixed
    trace = arrival_trace(domains, n_req, mode="bursty", burst_size=batch,
                          max_new_range=(6, 12), prompt_len=(8, 14),
                          long_prompt_period=batch,
                          long_prompt_range=(72, 96), seed=13)
    short_idx = [i for i, ev in enumerate(trace) if len(ev.prompt) < 32]

    results, streams = {}, {}
    for name in ("cohort", "eager"):
        scfg = ServingConfig(batch_size=batch, max_len=160, gamma=3,
                             seed=11, prefill_chunk=chunk, commit=name)
        eng = _build_engine(cfg, params, dcfg, dparams, scfg)
        eng.serve_stream(_requests(trace))                 # warm
        eng.stats = type(eng.stats)()
        reqs = _requests(trace)
        eng.serve_stream(reqs)
        st = eng.stats
        # TTFT on the round clock, relative to slot admission (absolute
        # round stamps would conflate eager's own round inflation)
        ttft_r = [reqs[i].first_token_round - reqs[i].admit_round
                  for i in short_idx]
        p95 = float(np.percentile(np.asarray(ttft_r), 95))
        streams[name] = [list(r.generated) for r in reqs]
        results[name] = (p95, st.steps)
        emit(f"slo/commit/{name}", 0.0,
             f"short_ttft_round_p95={p95:.1f};"
             f"short_ttft_round_mean={np.mean(ttft_r):.1f};"
             f"rounds={st.steps};ttft_p50_s={st.ttft_p50:.3f};"
             f"prefill_chunks={st.prefill_chunks}")

    if streams["eager"] != streams["cohort"]:
        raise AssertionError(
            "eager commit changed per-request token streams — commit "
            "policy must only change when lanes activate")
    p95_c, rounds_c = results["cohort"]
    p95_e, rounds_e = results["eager"]
    gain = p95_c / max(p95_e, 1e-9)
    emit("slo/commit/ratio", 0.0,
         f"short_ttft_gain={gain:.2f}x;bar=1.15x;"
         f"round_cost={rounds_e / max(rounds_c, 1):.2f}x")
    if gain < 1.15:
        raise AssertionError(
            f"eager commit short-prompt TTFT p95 {p95_e:.1f} rounds not "
            f">= 1.15x better than cohort {p95_c:.1f} on the bimodal "
            "burst trace")


def run(smoke: bool = False):
    cfg, params, domains = demo_target(30 if smoke else 120)
    dcfg, dparams, _ = trained_draft("science", steps=30 if smoke else 90)
    _deadline_scenario(cfg, params, dcfg, dparams, domains, smoke)
    _commit_scenario(cfg, params, dcfg, dparams, domains, smoke)


if __name__ == "__main__":
    run()
