"""Render the §Roofline markdown table for EXPERIMENTS.md from the
dry-run JSONs.

    PYTHONPATH=src python -m benchmarks.make_roofline_table [--tag bl]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dirpath: str, tag: str):
    rows = []
    for path in sorted(glob.glob(os.path.join(dirpath, f"*_{tag}.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def fmt_row(d):
    if "skipped" in d:
        return (f"| {d['arch']} | {d['shape']} | {d.get('mesh','-')} | "
                f"SKIP | — | — | — | — | — | {d['skipped'][:46]} |")
    r = d["roofline"]
    ratio = d["model_flops"] / max(r["flops"] * r["chips"], 1.0)
    sw = " [sw]" if d.get("window") else ""
    res = d.get("resident_bytes", 0) / 1e9
    note = {
        "compute": "more tokens/chip or larger micro would help",
        "memory": "cut activation round-trips / fuse attention reads",
        "collective": "reshard or overlap the dominant collective",
    }[r["dominant"]]
    return (f"| {d['arch']}{sw} | {d['shape']} | {d['mesh']} | "
            f"{r['dominant']} | {r['compute_s']:.4f} | {r['memory_s']:.4f} |"
            f" {r['collective_s']:.4f} | {ratio:.2f} | {res:.1f} | {note} |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--tag", default="bl")
    args = ap.parse_args()
    rows = load(args.dir, args.tag)
    rows.sort(key=lambda d: (d["arch"], SHAPE_ORDER.index(d["shape"]),
                             d.get("mesh", "")))
    print("| arch | shape | mesh | bound | compute s | memory s | "
          "collective s | useful-FLOPs | resident GB/chip | "
          "what would move the dominant term |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for d in rows:
        print(fmt_row(d))


if __name__ == "__main__":
    main()
