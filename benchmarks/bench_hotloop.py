"""Serving hot-loop host overhead: per-step loop vs fused superstep.

The per-step loop pays a device→host sync (``np.asarray`` on the commit
counts) plus Python bookkeeping every decode step, so JAX async dispatch
never overlaps host and device work.  The fused superstep runs K rounds
per compiled call and syncs once per superstep, with the host unpack of
superstep t overlapping the device compute of superstep t+1.

Measured here on ``tide_tiny`` (CPU backend), for K ∈ {1, 4, 8, 16}:

  * **syncs per committed token** — host-blocking device round-trips
    (one per step in the per-step loop, one per K rounds fused) —
    deterministic, the headline ≥2x-at-K≥8 criterion and the thing a
    CI gate can trust on a noisy shared-CPU runner,
  * wall µs per committed token (informational; load-sensitive),
  * estimated host-overhead µs per token = (wall −
    executed_rounds·t_round)/tokens with t_round the jitted step /
    superstep timed standalone and blocked on all-active serving
    state (informational; the calibration is noisy on shared CPUs).

All modes must emit identical token streams (asserted).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import demo_target, emit, timeit, trained_draft


def _build_engine(cfg, params, dcfg, dparams, rounds, *, batch, max_len,
                  **obs):
    from repro.core.signals import SignalExtractor, SignalStore
    from repro.serving.engine import ServingEngine

    store = SignalStore()
    ext = SignalExtractor(store, window=32)
    return ServingEngine(cfg, params, dcfg, dparams, batch_size=batch,
                         max_len=max_len, gamma=3, extractor=ext, seed=11,
                         superstep_rounds=rounds, **obs)


def _serve(eng, domains, *, waves, batch, max_new):
    from repro.serving.request import Request

    rng = np.random.default_rng(0)
    gens = []
    for _ in range(waves):
        reqs = [Request(prompt=domains["science"].sample_prompt(rng),
                        max_new_tokens=max_new) for _ in range(batch)]
        eng.serve_wave(reqs)
        gens.extend(list(r.generated) for r in reqs)
    return gens


def _device_us_per_dispatch(eng, domains, *, batch, max_new):
    """Time the engine's own compiled hot-loop function standalone
    (blocked) on real post-prefill serving state."""
    import jax.numpy as jnp

    from repro.core import speculative as spec
    from repro.serving.request import Request

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=domains["science"].sample_prompt(rng),
                    max_new_tokens=max_new) for _ in range(batch)]
    cache, dcache, carry, first = eng._prologue(reqs)
    key = eng._next_key()
    if eng._superstep_fn is not None:
        state = spec.init_superstep_state(carry, first, key)
        # huge budgets keep every lane active across the probe calls so
        # no round is skipped (skipped rounds would flatter the timing)
        mx = jnp.asarray([10 ** 6] * batch, jnp.int32)
        # the engine donates the cache/state buffers per dispatch, so
        # the probe must chain each call's outputs into the next call
        # instead of re-passing consumed buffers
        holder = {"c": cache, "d": dcache, "s": state}

        def fn():
            out = eng._superstep_fn(eng.params, eng.dparams, holder["c"],
                                    holder["d"], holder["s"], mx)
            holder.update(c=out["cache"], d=out["dcache"],
                          s=out["state"])
            return out["rounds"]["n_eff"]
    else:
        fn = lambda: eng._spec_fn(eng.params, eng.dparams, cache, dcache,
                                  carry, eng._null_keys)
    return timeit(fn, warmup=2, iters=5) * 1e6


def _prologue_s(eng, domains, *, batch, max_new):
    import jax

    from repro.serving.request import Request

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=domains["science"].sample_prompt(rng),
                    max_new_tokens=max_new) for _ in range(batch)]
    return timeit(lambda: jax.block_until_ready(
        eng._prologue(reqs)[0]["lengths"]), warmup=1, iters=3)


def run(smoke: bool = False):
    cfg, params, domains = demo_target(30 if smoke else 120)
    dcfg, dparams, _ = trained_draft("science", steps=30 if smoke else 90)
    batch, max_len = 4, 160
    waves = 1 if smoke else 2
    max_new = 24 if smoke else 48
    ks = (1, 8) if smoke else (1, 4, 8, 16)

    results = {}
    streams = {}
    for rounds in (0,) + ks:
        eng = _build_engine(cfg, params, dcfg, dparams, rounds,
                            batch=batch, max_len=max_len)
        # warm over the same wave sequence: per-wave prompt lengths vary,
        # so every prefill shape must be compiled before measuring
        _serve(eng, domains, waves=waves, batch=batch, max_new=max_new)
        t_pro = _prologue_s(eng, domains, batch=batch, max_new=max_new)
        eng.stats = type(eng.stats)()
        streams[rounds] = _serve(eng, domains, waves=waves, batch=batch,
                                 max_new=max_new)
        tokens = eng.stats.tokens_out
        wall_loop = max(eng.stats.wall_s - waves * t_pro, 1e-9)
        t_disp = _device_us_per_dispatch(eng, domains, batch=batch,
                                         max_new=max_new)
        t_round = t_disp / max(rounds, 1)
        overhead = max(wall_loop * 1e6
                       - eng.stats.steps * t_round, 0.0) / tokens
        tag = "perstep" if rounds == 0 else f"superstep_k{rounds}"
        syncs = eng.stats.dispatches / tokens
        results[rounds] = (syncs, wall_loop * 1e6 / tokens, overhead)
        emit(f"hotloop/{tag}/syncs", syncs,
             f"per_token;dispatches={eng.stats.dispatches};"
             f"rounds={eng.stats.steps};tokens={tokens}")
        emit(f"hotloop/{tag}/wall", wall_loop * 1e6 / tokens,
             f"us_per_token")
        emit(f"hotloop/{tag}/host_overhead_est", overhead,
             f"us_per_token;t_device_round_us={t_round:.1f}")

    for rounds in ks:
        if streams[rounds] != streams[0]:
            raise AssertionError(
                f"superstep K={rounds} token stream diverged from the "
                "per-step reference")
    ref_sync, ref_wall, ref_over = results[0]
    floor = 1.0     # µs/token measurement-noise floor: below this the
    # host overhead is fully hidden behind device compute
    for rounds in ks:
        s, w, o = results[rounds]
        emit(f"hotloop/ratio_k{rounds}", 0.0,
             f"sync_reduction={ref_sync / max(s, 1e-9):.2f}x;"
             f"wall_speedup={ref_wall / max(w, 1e-9):.2f}x;"
             f"overhead_est_reduction={ref_over / max(o, floor):.1f}x")
        if rounds >= 8 and ref_sync / s < 2.0:
            raise AssertionError(
                f"K={rounds} superstep did not reduce host syncs per "
                f"token by >=2x ({ref_sync:.3f} -> {s:.3f})")

    _obs_overhead_gate(cfg, params, dcfg, dparams, domains, batch=batch,
                       max_len=max_len, waves=waves, max_new=max_new)


def _obs_overhead_gate(cfg, params, dcfg, dparams, domains, *, batch,
                       max_len, waves, max_new, rounds=8, trials=4):
    """Observability overhead gate (repro/obs, zero-sync rule).

    Serves the identical wave sequence through an obs-off K=``rounds``
    engine and an obs-on twin (live tracer + flight recorder + shared
    metrics registry) and asserts the contract:

      * token streams byte-identical and dispatches (device syncs)
        exactly equal — obs hooks are host-side only, so they cannot
        change what the device executes,
      * obs-on hot-loop wall ≤ 1.03x obs-off + a 2 µs/token absolute
        floor (min-of-``trials`` interleaved walls; the floor absorbs
        shared-CPU noise at these tiny per-token walls),
      * the trace actually covers the loop (superstep dispatch/unpack
        spans present) and ``metrics.snapshot()`` agrees with the
        legacy stats counters.
    """
    from repro.obs.metrics import MetricsRegistry
    from repro.obs.recorder import FlightRecorder
    from repro.obs.trace import Tracer

    eng_off = _build_engine(cfg, params, dcfg, dparams, rounds,
                            batch=batch, max_len=max_len)
    eng_on = _build_engine(cfg, params, dcfg, dparams, rounds,
                           batch=batch, max_len=max_len,
                           tracer=Tracer(), recorder=FlightRecorder(),
                           metrics=MetricsRegistry())
    walls = {"off": [], "on": []}
    streams = {}
    for eng, tag in ((eng_off, "off"), (eng_on, "on")):
        _serve(eng, domains, waves=waves, batch=batch,
               max_new=max_new)                      # compile warmup
    for _ in range(trials):                          # interleaved walls
        for eng, tag in ((eng_off, "off"), (eng_on, "on")):
            eng.reset_adaptation(dparams)
            streams[tag] = _serve(eng, domains, waves=waves, batch=batch,
                                  max_new=max_new)
            walls[tag].append(eng.stats.wall_s * 1e6
                              / eng.stats.tokens_out)
        if streams["on"] != streams["off"]:
            raise AssertionError(
                "obs-on token stream diverged from obs-off")
        if eng_on.stats.dispatches != eng_off.stats.dispatches:
            raise AssertionError(
                "obs-on changed device dispatch count "
                f"({eng_off.stats.dispatches} -> "
                f"{eng_on.stats.dispatches}): zero-sync rule violated")
    off_us, on_us = min(walls["off"]), min(walls["on"])
    emit("hotloop/obs_overhead", on_us - off_us,
         f"us_per_token;on={on_us:.1f};off={off_us:.1f};"
         f"ratio={on_us / max(off_us, 1e-9):.3f}")
    if on_us > off_us * 1.03 + 2.0:
        raise AssertionError(
            f"observability overhead gate: obs-on {on_us:.2f} µs/token "
            f"> obs-off {off_us:.2f} * 1.03 + 2.0")
    names = {e[1] for e in eng_on.tracer.events()}
    for span in ("superstep.dispatch", "superstep.unpack"):
        if span not in names:
            raise AssertionError(f"trace missing {span!r} spans")
    snap = eng_on.metrics.snapshot()
    if snap["serving.tokens_out"] != eng_on.stats.tokens_out:
        raise AssertionError(
            "metrics.snapshot() disagrees with ServingStats: "
            f"{snap['serving.tokens_out']} != {eng_on.stats.tokens_out}")
    want = (trials + 1) * waves * batch   # warmup serve + every trial
    if len(eng_on.recorder.timelines()) != want:
        raise AssertionError(
            f"flight recorder saw {len(eng_on.recorder.timelines())} "
            f"requests, expected {want}")


if __name__ == "__main__":
    run()
