"""Paged KV cache: slot capacity at fixed HBM + prefix sharing + parity.

Dense serving sizes HBM for the worst case: every lane owns a private
``max_len`` KV window, so a ``batch x max_len`` budget serves exactly
``batch`` slots no matter how short real requests run.  The paged
engine replaces lane windows with a fixed pool of ``page_size``-token
pages behind per-lane block tables and admits by page reservation
(prompt width + token budget + gamma + 1), so the same HBM serves as
many slots as real request footprints fit — short-request traffic packs
4-5x more concurrent lanes into the dense footprint.

Scenarios (tide-tiny, CPU backend):

  * **slots** — a short-request bursty trace served by a paged engine
    whose page pool equals the dense baseline's exact HBM footprint
    (``dense_batch x max_len / page_size`` pages) but with 5x the batch
    lanes.  Gates (deterministic): zero admission deferrals (the pool
    really covers 5x slots), peak page occupancy >= 4x the dense slot
    count's worth of reservations (the lanes were genuinely
    co-resident), zero leaked pages after drain.
  * **parity** — the same trace served dense vs paged at equal batch:
    per-request token streams must be byte-identical, greedy AND
    per-request-keyed sampled (paged lanes attend through gathered
    dense views of the same bytes, so parity is exact, not
    statistical) — deterministic.
  * **prefix** — a shared-system-prompt trace (``arrival_trace(
    shared_prefix_frac=1.0)``) served with chunked refill: committed
    prompt-prefix pages are published to the COW registry keyed by
    provenance, and later admissions adopt the donor's physical pages
    and skip the covered prefill chunks.  Gates (deterministic):
    registry hits > 0, prefix tokens saved > 0, prefill row-token work
    <= 0.7x dense, streams byte-identical to dense, zero leaks.
    TTFT percentiles are emitted for information (wall noise on this
    shared host keeps them out of the gate).
"""
from __future__ import annotations

from benchmarks.common import demo_target, emit, trained_draft

PAGE = 8
MAX_LEN = 160
DENSE_B = 4


def _build_engine(cfg, params, dcfg, dparams, **kw):
    from repro.serving.engine import ServingEngine
    from repro.serving.policy import ServingConfig

    scfg = ServingConfig(gamma=3, seed=11, superstep_rounds=8,
                         **dict({"max_len": MAX_LEN}, **kw))
    return ServingEngine(cfg, params, dcfg, dparams, config=scfg)


def _requests(trace):
    from repro.serving.request import Request

    return [Request(prompt=list(ev.prompt), domain=ev.domain,
                    max_new_tokens=ev.max_new_tokens) for ev in trace]


def _drain_and_check(eng):
    """Leak gate: after a stream drains, every page must be back on the
    free list once the prefix registry is dropped."""
    eng.release_prefix_cache()
    eng.allocator.assert_clean()


def _slots_scenario(cfg, params, dcfg, dparams, domains, smoke):
    from repro.data.workloads import arrival_trace

    pool = DENSE_B * MAX_LEN // PAGE          # the dense HBM footprint
    paged_b = 5 * DENSE_B
    n_req = 24 if smoke else 40
    # short-request traffic: prompts 10-16, budgets 6-12 -> one lane's
    # reservation is width + budget + gamma + 1 <= 32 tokens = 4 pages,
    # so the 80-page dense footprint covers 20 concurrent lanes; bursts
    # of paged_b co-arrivals make the engine actually admit them at once
    trace = arrival_trace(domains, n_req, mode="bursty",
                          burst_size=paged_b, max_new_range=(6, 12),
                          prompt_len=(10, 16), seed=19)
    eng = _build_engine(cfg, params, dcfg, dparams, batch_size=paged_b,
                        page_size=PAGE, num_pages=pool)
    reqs = _requests(trace)
    eng.serve_stream(reqs)
    st = eng.stats
    assert st.completed == n_req, f"served {st.completed}/{n_req}"
    _drain_and_check(eng)
    emit("paged/slots", 0.0,
         f"slots={paged_b};dense_slots={DENSE_B};"
         f"ratio={paged_b / DENSE_B:.1f}x;pool_pages={pool};"
         f"pages_peak={st.pages_peak};deferrals={st.admission_deferrals}")
    if st.admission_deferrals:
        raise AssertionError(
            f"{st.admission_deferrals} admissions deferred: the dense "
            f"HBM footprint did not actually cover {paged_b} slots")
    # >= 4x the dense slot count genuinely co-resident: each admitted
    # lane reserves >= 2 pages (width 16 + budget + gamma + 1 > 8), so
    # 4 x DENSE_B lanes put >= 8 x DENSE_B pages in flight together
    floor = 4 * DENSE_B * 2
    if st.pages_peak < floor:
        raise AssertionError(
            f"peak page occupancy {st.pages_peak} < {floor}: fewer "
            f"than {4 * DENSE_B} lanes were ever co-resident")


def _parity_scenario(cfg, params, dcfg, dparams, domains, smoke):
    from repro.data.workloads import arrival_trace

    n_req = 12 if smoke else 20
    trace = arrival_trace(domains, n_req, mode="bursty",
                          burst_size=DENSE_B, max_new_range=(6, 12),
                          prompt_len=(8, 20), seed=23)
    for greedy in (True, False):
        streams = {}
        for name, paged in (("dense", 0), ("paged", PAGE)):
            eng = _build_engine(cfg, params, dcfg, dparams,
                                batch_size=DENSE_B, greedy=greedy,
                                page_size=paged)
            reqs = _requests(trace)
            eng.serve_stream(reqs)
            streams[name] = [list(r.generated) for r in reqs]
            if paged:
                _drain_and_check(eng)
        mode = "greedy" if greedy else "sampled"
        if streams["paged"] != streams["dense"]:
            raise AssertionError(
                f"paged {mode} streams diverged from dense")
        emit(f"paged/parity/{mode}", 0.0,
             f"requests={n_req};byte_identical=1")


def _prefix_scenario(cfg, params, dcfg, dparams, domains, smoke):
    from repro.data.workloads import Phase, arrival_trace

    n_req = 12 if smoke else 20
    batch, max_len, chunk = 2, 96, 8
    # every request = one shared 28-token system prompt + a 4-token
    # tail: total width buckets to 32, so the provenance keys cover
    # tokens [0, 25) — inside the shared prefix — and every post-donor
    # admission can adopt the donor's first 3 pages and resume its
    # chunk pipeline past them.  Uniform lengths keep refill group
    # shapes (rows, width, pad) matching across admissions, which the
    # provenance key requires.
    dom = next(iter(domains))
    trace = arrival_trace(domains, n_req, mode="bursty", burst_size=batch,
                          max_new_range=(6, 9), prompt_len=(4, 4),
                          shared_prefix_frac=1.0, prefix_len=28,
                          prefix_pool=1,
                          schedule=[Phase(dom, n_req)], seed=29)
    assert all(len(ev.prompt) == 32 for ev in trace)
    streams, rows, ttft = {}, {}, {}
    for name, paged in (("dense", 0), ("paged", PAGE)):
        eng = _build_engine(cfg, params, dcfg, dparams, batch_size=batch,
                            max_len=max_len, prefill_chunk=chunk,
                            page_size=paged)
        # min-of-N wall discipline (PR 4): serve the trace once warm,
        # then N timed repeats against the compiled engine and keep the
        # best run's wall-derived stats — this host's wall noise spans
        # 0.8-2.5x, so single-shot TTFT numbers are not comparable.
        # Each repeat drops the prefix registry first so the COW
        # counters stay cold-start-deterministic across repeats.
        best_wall = float("inf")
        for rep in range(5):                  # rep 0 warms the jit
            if paged:
                eng.release_prefix_cache()
                # COW counters live on the allocator (stats proxies
                # them at drain) — zero them so each repeat reports a
                # cold-start registry, not an accumulated total
                eng.allocator.prefix_hits = 0
                eng.allocator.prefix_tokens_saved = 0
            eng.stats = type(eng.stats)()
            reqs = _requests(trace)
            eng.serve_stream(reqs)
            if rep == 0 or eng.stats.wall_s < best_wall:
                best_wall = eng.stats.wall_s
                streams[name] = [list(r.generated) for r in reqs]
                rows[name] = eng.stats.prefill_row_tokens
                ttft[name] = eng.stats.ttft_p50
                if paged:
                    hits = eng.stats.prefix_hits
                    saved = eng.stats.prefix_tokens_saved
        if paged:
            _drain_and_check(eng)
    emit("paged/prefix", 0.0,
         f"hits={hits};tokens_saved={saved};"
         f"row_tokens={rows['paged']}vs{rows['dense']};"
         f"ttft_p50_s={ttft['paged']:.3f}vs{ttft['dense']:.3f}")
    if streams["paged"] != streams["dense"]:
        raise AssertionError("prefix-shared paged streams diverged "
                             "from dense")
    if hits <= 0 or saved <= 0:
        raise AssertionError(
            f"prefix registry never hit (hits={hits}, saved={saved}): "
            "COW sharing is not engaging on a shared-prefix trace")
    if rows["paged"] > 0.7 * rows["dense"]:
        raise AssertionError(
            f"prefix sharing saved too little prefill work: "
            f"{rows['paged']} row-tokens paged vs {rows['dense']} dense "
            f"(bar 0.7x)")


def run(smoke: bool = False):
    cfg, params, domains = demo_target(30 if smoke else 120)
    dcfg, dparams, _ = trained_draft("science", steps=30 if smoke else 90)
    _slots_scenario(cfg, params, dcfg, dparams, domains, smoke)
    _parity_scenario(cfg, params, dcfg, dparams, domains, smoke)
    _prefix_scenario(cfg, params, dcfg, dparams, domains, smoke)


if __name__ == "__main__":
    run()
