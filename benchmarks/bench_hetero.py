"""Paper Figs. 10/11/12: heterogeneous GPU allocation — the decision
model with the paper's measured device ratios (Fig. 11), the Fig. 12
configuration grid, and the TPU-native submesh analogue (DESIGN.md §2.1).
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.hetero import (PAPER_DEVICES, TPU_DEVICES, best_split,
                               paper_figure12_grid, plan_tpu_submesh,
                               relative_throughput)


def run():
    # Fig. 11: the disproportionate inference/training gap
    for name, d in PAPER_DEVICES.items():
        emit(f"fig11/{name}", 0.0,
             f"inference={d.inference:.2f};training={d.training:.2f};"
             f"gap_ratio={d.inference / d.training:.2f}")
    # Fig. 10: paper's deployment (8×H100 serve + 4×MI250 train), per
    # dataset speedup s from §5.5
    for ds, s in (("sharegpt", 1.15), ("science", 1.30),
                  ("evolcode", 1.25), ("numinamath", 1.22)):
        r = relative_throughput(PAPER_DEVICES["H100"],
                                PAPER_DEVICES["MI250"], 8, 4, s)
        emit(f"fig10/{ds}", 0.0,
             f"rel_throughput={r:.2f};s={s}")
    # Fig. 12 grid
    for row in paper_figure12_grid():
        emit(f"fig12/{row['config'].replace(' ', '')}/s{row['s']}", 0.0,
             f"rel={row['relative_throughput']:.3f};"
             f"use_tide={row['use_tide']}")
    # TPU-native: v5p serving + v5e training, and single-pod submesh carve
    r = best_split(TPU_DEVICES["v5p"], TPU_DEVICES["v5e"], 4, 1, 1.3)
    emit("tpu/v5p_v5e_4_1_s1.3", 0.0,
         f"rel={r['relative_throughput']:.3f}")
    for s in (1.15, 1.3, 1.47):
        plan = plan_tpu_submesh(256, s)
        emit(f"tpu/submesh_256_s{s}", 0.0,
             f"serve={plan.serve_chips};train={plan.train_chips};"
             f"rel={plan.relative_throughput():.3f}")


if __name__ == "__main__":
    run()
