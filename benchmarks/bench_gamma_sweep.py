"""Paper Table 4 (Appendix A.2): speculative configuration sweep —
(batch, γ) against throughput and acceptance length on the live engine.
The paper finds γ=3–4 chain drafting optimal; larger speculative budgets
raise accept length but hurt throughput.

A second axis sweeps tree SHAPE at a fixed draft-node budget
(``width x gamma = 8`` nodes: 1x8, 2x4, 4x2, 8x1): the same verify
block spent deep on one trajectory vs wide across top-k first
continuations, printing accepted draft tokens per superstep alongside
tokens/s.  Wide-shallow shapes recover rejected first guesses; deep
chains compound first-token risk.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import demo_target, emit, trained_draft
from repro.core import eagle, speculative as spec
from repro.models import transformer as T


def _throughput(cfg, dcfg, params, dparams, domain, batch, gamma,
                n_steps=16):
    rng = np.random.default_rng(1)
    prompts = [domain.sample_prompt(rng)[:12] for _ in range(batch)]
    toks = jnp.asarray([p + [0] * (12 - len(p)) for p in prompts])
    MAX = 12 + (gamma + 1) * (n_steps + 2)
    pre = T.prefill(cfg, params, toks, max_len=MAX)
    first = pre["logits"].argmax(-1).astype(jnp.int32)
    if gamma == 0:
        fn = jax.jit(lambda c, t, k: spec.plain_decode_step(
            cfg, params, c, t, key=k))
        o = {"cache": pre["cache"], "token": first}
        o = fn(o["cache"], o["token"], jax.random.key(0))
        jax.block_until_ready(o["token"])
        t0 = time.perf_counter()
        n_tok = 0
        for i in range(n_steps):
            o = fn(o["cache"], o["token"], jax.random.key(i))
            n_tok += batch
        jax.block_until_ready(o["token"])
        return n_tok / (time.perf_counter() - t0), 1.0
    dcache = eagle.init_draft_cache(dcfg, batch, MAX)
    dcache = spec.seed_draft_cache(cfg, dcfg, params, dparams, dcache,
                                   pre, toks)
    carry = spec.init_carry(cfg, dcfg, pre, first, gamma)
    fn = jax.jit(lambda c, dc, cr, k: spec.spec_decode_step(
        cfg, dcfg, params, dparams, c, dc, cr, gamma=gamma, key=k))
    o = fn(pre["cache"], dcache, carry, jax.random.key(0))
    jax.block_until_ready(o["tokens"])
    t0 = time.perf_counter()
    n_tok, ells = 0, []
    for i in range(n_steps):
        o = fn(o["cache"], o["dcache"], o["carry"], jax.random.key(i))
        n = np.asarray(o["n_commit"])
        n_tok += int(n.sum())
        ells.append(float(n.mean()))
    jax.block_until_ready(o["tokens"])
    return n_tok / (time.perf_counter() - t0), float(np.mean(ells))


def _tree_throughput(cfg, dcfg, params, dparams, domain, batch, width,
                     gamma, n_steps=16):
    """tokens/s and accepted DRAFT tokens per superstep for a
    ``width x gamma``-node tree (width=0: the linear chain)."""
    rng = np.random.default_rng(1)
    prompts = [domain.sample_prompt(rng)[:12] for _ in range(batch)]
    toks = jnp.asarray([p + [0] * (12 - len(p)) for p in prompts])
    MAX = 12 + (gamma + 1) * (n_steps + 2) + gamma * max(width, 1) + 1
    pre = T.prefill(cfg, params, toks, max_len=MAX)
    first = pre["logits"].argmax(-1).astype(jnp.int32)
    dcache = eagle.init_draft_cache(dcfg, batch, MAX)
    dcache = spec.seed_draft_cache(cfg, dcfg, params, dparams, dcache,
                                   pre, toks)
    carry = spec.init_carry(cfg, dcfg, pre, first, gamma)
    if width:
        fn = jax.jit(lambda c, dc, cr: spec.tree_decode_step(
            cfg, dcfg, params, dparams, c, dc, cr, gamma=gamma,
            width=width))
    else:
        fn = jax.jit(lambda c, dc, cr: spec.spec_decode_step(
            cfg, dcfg, params, dparams, c, dc, cr, gamma=gamma))
    o = fn(pre["cache"], dcache, carry)
    jax.block_until_ready(o["tokens"])
    t0 = time.perf_counter()
    n_tok = 0
    for _ in range(n_steps):
        o = fn(o["cache"], o["dcache"], o["carry"])
        n_tok += int(np.asarray(o["n_commit"]).sum())
    jax.block_until_ready(o["tokens"])
    tps = n_tok / (time.perf_counter() - t0)
    acc = n_tok / (n_steps * batch) - 1.0  # minus the per-step bonus
    return tps, acc


# width x gamma tree shapes at a fixed 8-draft-node budget
TREE_SHAPES = ((1, 8), (2, 4), (4, 2), (8, 1))


def run():
    cfg, params, domains = demo_target()
    dcfg, dparams, _ = trained_draft("science")
    dom = domains["science"]
    for batch in (1, 4, 8):
        base_tps, _ = _throughput(cfg, dcfg and dcfg, params, dparams,
                                  dom, batch, 0)
        emit(f"table4/b{batch}/gamma0", 1e6 / max(base_tps, 1e-9),
             f"tps={base_tps:.1f};accept_len=1.00;speedup=1.00")
        for gamma in (2, 3, 5):
            tps, ell = _throughput(cfg, dcfg, params, dparams, dom,
                                   batch, gamma)
            emit(f"table4/b{batch}/gamma{gamma}",
                 1e6 / max(tps, 1e-9),
                 f"tps={tps:.1f};accept_len={ell:.2f};"
                 f"speedup={tps / base_tps:.2f}")
    # tree-shape axis: the same 8-node draft budget, deep vs wide
    batch = 4
    for width, gamma in TREE_SHAPES:
        tps, acc = _tree_throughput(cfg, dcfg, params, dparams, dom,
                                    batch, width, gamma)
        emit(f"table4/tree/b{batch}/w{width}g{gamma}",
             1e6 / max(tps, 1e-9),
             f"nodes={width * gamma};acc_tok_per_step={acc:.2f};"
             f"tps={tps:.1f}")


if __name__ == "__main__":
    run()
