"""Paper Figs. 5 & 6: accept-length and throughput evolution over time
during live serving with online draft adaptation (the headline TIDE
effect), per domain.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import demo_target, emit
from repro.core.tide import TideConfig, TideSystem
from repro.data.workloads import Phase, WorkloadStream

DOMAINS = ["science", "evolcode"]


def run():
    cfg, params, domains = demo_target()
    for name in DOMAINS:
        stream = WorkloadStream(domains, [Phase(name, 40)], seed=5)
        tc = TideConfig(batch_size=4, max_len=96, n_threshold=4,
                        signal_window=16, adaptive_spec=False,
                        train_epochs=2)
        sys_ = TideSystem(cfg, params, tc)
        sys_.run(stream.batches(4), max_new_tokens=32)
        tl = sys_.engine.stats.timeline
        ell = np.array([x["accept_len"] for x in tl])
        q = max(len(ell) // 4, 1)
        for i in range(4):
            seg = ell[i * q:(i + 1) * q]
            if len(seg):
                emit(f"fig5/{name}/accept_len_q{i+1}", 0.0,
                     f"{seg.mean():.3f}")
        s = sys_.summary()
        emit(f"fig6/{name}/throughput_tok_s", 0.0,
             f"{s['throughput_tok_s']:.1f}")
        emit(f"fig6/{name}/train_cycles", 0.0,
             f"{s['train_cycles']};deployed={s['deployed']}")
        emit(f"fig5/{name}/improvement", 0.0,
             f"{ell[-q:].mean() / max(ell[:q].mean(), 1e-9):.3f}x")


if __name__ == "__main__":
    run()
