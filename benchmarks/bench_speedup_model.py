"""Paper Fig. 8 + Eq. 5: practical-speedup model vs. actual measured
speedup, and the paper-profile (Table 5) predictions.

Measured part runs on the live CPU engine (tide-tiny): we profile T(n)
and D0 by timing the jitted target/draft steps, predict speedup via
Eq. 5 from the observed acceptance, and compare against the actually
measured speculative-vs-plain throughput ratio.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import demo_target, emit, trained_draft
from repro.core import eagle, speculative as spec
from repro.core.adaptive import (PAPER_PROFILES, LatencyProfile,
                                 alpha_from_accept_len, practical_speedup)
from repro.models import transformer as T


def _measure(cfg, params, dcfg, dparams, domain, batch, n_steps=20,
             gamma=3):
    """Returns (T(b) us, spec tok/s, plain tok/s, accept_len)."""
    rng = np.random.default_rng(0)
    prompts = [domain.sample_prompt(rng)[:12] for _ in range(batch)]
    toks = jnp.asarray([p + [0] * (12 - len(p)) for p in prompts])
    MAX = 12 + (gamma + 1) * (n_steps + 2)
    pre = T.prefill(cfg, params, toks, max_len=MAX)
    first = pre["logits"].argmax(-1).astype(jnp.int32)
    dcache0 = eagle.init_draft_cache(dcfg, batch, MAX)
    dcache0 = spec.seed_draft_cache(cfg, dcfg, params, dparams, dcache0,
                                    pre, toks)
    carry0 = spec.init_carry(cfg, dcfg, pre, first, gamma)

    spec_fn = jax.jit(lambda c, dc, cr, k: spec.spec_decode_step(
        cfg, dcfg, params, dparams, c, dc, cr, gamma=gamma, key=k))
    plain_fn = jax.jit(lambda c, t, k: spec.plain_decode_step(
        cfg, params, c, t, key=k))

    # plain timing
    cache = jax.tree.map(jnp.copy, pre["cache"])
    tok = first
    out = plain_fn(cache, tok, jax.random.key(0))
    jax.block_until_ready(out["token"])
    import time
    t0 = time.perf_counter()
    toks_plain = 0
    for i in range(n_steps):
        out = plain_fn(out["cache"], out["token"], jax.random.key(i))
        toks_plain += batch
    jax.block_until_ready(out["token"])
    t_plain = time.perf_counter() - t0

    # spec timing
    o = spec_fn(pre["cache"], dcache0, carry0, jax.random.key(0))
    jax.block_until_ready(o["tokens"])
    t0 = time.perf_counter()
    toks_spec = 0
    ells = []
    for i in range(n_steps):
        o = spec_fn(o["cache"], o["dcache"], o["carry"],
                    jax.random.key(i + 1))
        n = np.asarray(o["n_commit"])
        toks_spec += int(n.sum())
        ells.append(float(n.mean()))
    jax.block_until_ready(o["tokens"])
    t_spec = time.perf_counter() - t0
    return (t_plain / n_steps, toks_spec / t_spec, toks_plain / t_plain,
            float(np.mean(ells)))


def run():
    cfg, params, domains = demo_target()
    dcfg, dparams, acc = trained_draft("science")
    gamma = 3
    # profile T(n) and D0 from the live engine (paper §4.1 startup pass)
    results = {}
    for b in (1, 2, 4):
        tb, spec_tps, plain_tps, ell = _measure(
            cfg, params, dcfg, dparams, domains["science"], b)
        results[b] = (tb, spec_tps, plain_tps, ell)
    bs = sorted(results)
    prof = LatencyProfile(bs, [results[b][0] * 1e3 for b in bs],
                          d0_ms=results[1][0] * 1e3 * 0.25)
    for b in bs:
        tb, spec_tps, plain_tps, ell = results[b]
        actual = spec_tps / plain_tps
        alpha = alpha_from_accept_len(ell, gamma)
        pred = practical_speedup(alpha, gamma, prof, b)
        emit(f"fig8/live/b{b}/actual_speedup", tb * 1e6,
             f"{actual:.3f}")
        emit(f"fig8/live/b{b}/predicted_speedup", tb * 1e6,
             f"{pred:.3f};accept_len={ell:.2f}")
    # paper-profile predictions (Table 5 -> Fig. 8 curves)
    for name, prof in PAPER_PROFILES.items():
        for b in (1, 8, 64):
            pred = practical_speedup(0.65, gamma, prof, b)
            emit(f"fig8/paper/{name}/b{b}", prof.t(b) * 1e3,
                 f"pred_speedup={pred:.3f}")


if __name__ == "__main__":
    run()
