"""Paper Table 3 (Appendix A.1): cross-dataset generalization — a draft
trained on domain X is evaluated on every domain Y; the diagonal should
dominate, motivating runtime adaptation.  Acceptance length via Eq. 2
from the measured top-1 agreement α.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import demo_target, emit, trained_draft
from repro.core import eagle
from repro.core.adaptive import expected_accept_len
from repro.data.workloads import training_corpus
from repro.models import transformer as T

GAMMA = 3
DOMAINS = ["sharegpt", "science", "evolcode", "numinamath"]


def _eval_alpha(cfg, dcfg, params, dparams, domain, n=24):
    corpus = jnp.asarray(training_corpus(domain, n, 36, seed=77))
    pre = T.prefill(cfg, params, corpus)
    feats, nexts = pre["captures"][:, :-1], corpus[:, 1:]
    _, m = eagle.draft_train_loss(dcfg, dparams, params["embed"], feats,
                                  nexts, ttt=False)
    return float(m["accuracy"])


def run():
    cfg, params, domains = demo_target()
    for train_on in DOMAINS:
        dcfg, dparams, _ = trained_draft(train_on)
        for eval_on in DOMAINS:
            alpha = _eval_alpha(cfg, dcfg, params, dparams,
                                domains[eval_on])
            ell = expected_accept_len(alpha, GAMMA)
            tag = "diag" if train_on == eval_on else "xfer"
            emit(f"table3/train_{train_on}/eval_{eval_on}", 0.0,
                 f"accept_len={ell:.2f};alpha={alpha:.3f};{tag}")


if __name__ == "__main__":
    run()
