"""Disaggregated serving: data-parallel replica fleet + out-of-process
trainer (repro/fleet; docs/disaggregation.md).

Two claims are gated, both in deterministic domains:

**Fleet scale-out (round domain).**  N=4 ``ServingEngine`` replicas
behind the front-end router and draft-version bus serve the same
arrival trace as one replica.  On a single host the replicas execute
*serially* (one XLA client, shared cores), so wall-clock would measure
timeslicing, not scale-out; the scale metric is executed superstep
rounds — scheduling-exact and accept-rate-deterministic:

    round_speedup = rounds(single) / max_i rounds(replica_i)  >= 3.0x

i.e. the fleet's critical-path replica runs under a third of the single
replica's rounds, the bound a true data-parallel deployment's makespan
follows.  The modeled aggregate tokens/s (total tokens over the slowest
replica's wall) is emitted as information — wall is noisy on a shared
host.  Per-request greedy streams must be byte-identical to the single
replica's (draft- and scheduling-invariance), and every published draft
must fan out to every replica's bus subscription.

**Out-of-process trainer (parity + sync domains).**  The same
``TideSystem`` machinery with ``fleet.trainer_endpoint="spawn"`` runs
its ``TrainingService`` in a subprocess on its own XLA client, signals
and drafts crossing the ``fleet.wire`` protocol.  Gates: sync
(drain-parity) mode reproduces the in-process system's token streams
byte-for-byte with the same cycle count; the wire adds zero serving-
path syncs (host syncs per executed round <= 1.05x in-process — both
are counter-derived, not clocked); and hard-killing the trainer
subprocess mid-workload degrades gracefully — every remaining request
completes on the last deployed draft (streams still byte-identical:
greedy is draft-invariant), the failure is counted, nothing hangs.
"""
from __future__ import annotations

from benchmarks.common import demo_target, emit

ROUND_BAR = 3.0      # fleet critical path vs single replica
SYNC_BAR = 1.05      # remote serving-path syncs vs in-process
N_REPLICAS = 4


def _trace(domains, n_req, seed=13):
    from repro.data.workloads import arrival_trace

    # short budgets, no long tail: keeps the per-replica shards balanced
    # so the round-domain gate measures routing, not budget luck
    return arrival_trace(domains, n_req, mode="poisson", rate=32.0,
                         max_new_range=(8, 24), seed=seed)


def _requests(trace):
    from repro.serving.request import Request

    return [Request(prompt=list(ev.prompt), domain=ev.domain,
                    max_new_tokens=ev.max_new_tokens, arrives_at=ev.t)
            for ev in trace]


def _tide_cfg(smoke, **kw):
    from repro.core.tide import TideConfig

    base = dict(gamma=3, batch_size=4, max_len=160, greedy=True,
                adaptive_spec=False, selective_training=False,
                signal_window=16, n_threshold=10 if smoke else 12,
                train_epochs=1, train_min_steps=48 if smoke else 64,
                seed=0)
    base.update(kw)
    return TideConfig(**base)


def _fleet(cfg, params, smoke, replicas):
    from repro.fleet import FleetConfig
    from repro.fleet.router import ServingFleet

    tc = _tide_cfg(smoke, fleet=FleetConfig(replicas=replicas))
    return ServingFleet(cfg, params, tc)


def _serve_fleet(fleet, trace):
    reqs = _requests(trace)
    fleet.serve(reqs)
    return reqs, [list(r.generated) for r in reqs]


def _rounds(summary):
    return summary["replica_rounds"]


# ------------------------------------------------------------ scale-out
def _bench_scaleout(cfg, params, domains, smoke):
    trace = _trace(domains, 96 if smoke else 128)

    single = _fleet(cfg, params, smoke, replicas=1)
    _serve_fleet(single, trace)                  # warm every shape
    single.reset_adaptation()
    _, ref_streams = _serve_fleet(single, trace)
    s1 = single.summary()
    single.close()
    emit("fleet/single", 0.0,
         f"tok_per_s={s1['agg_tokens_per_s']:.0f};"
         f"tokens={s1['tokens']};rounds={s1['max_rounds']};"
         f"cycles={s1['train_cycles']};deploys={s1['deployed']}")

    fleet = _fleet(cfg, params, smoke, replicas=N_REPLICAS)
    _serve_fleet(fleet, trace)
    fleet.reset_adaptation()
    _, got_streams = _serve_fleet(fleet, trace)
    # the serial single-host schedule leaves early replicas idle after
    # their shard; one more poll each stands in for the per-superstep
    # poll an always-on replica keeps making
    for sub in fleet.subs:
        sub()
    s4 = fleet.summary()
    bus = s4["bus"]
    min_seq = min(v["delivered_seq"]
                  for v in bus["subscribers"].values())
    emit("fleet/n4", 0.0,
         f"agg_tok_per_s={s4['agg_tokens_per_s']:.0f};"
         f"tokens={s4['tokens']};max_rounds={s4['max_rounds']};"
         f"rounds={','.join(str(r) for r in _rounds(s4))};"
         f"assigned={','.join(str(a) for a in s4['router_assigned'])};"
         f"cycles={s4['train_cycles']};published={bus['published']};"
         f"min_delivered_seq={min_seq}")
    fleet.close()

    # gate: byte-identical per-request greedy streams, any replica count
    parity = int(got_streams == ref_streams)
    if not parity:
        raise AssertionError(
            "fleet token streams diverged from the single replica "
            "(greedy streams must be draft- and routing-invariant)")
    # gate: training happened and fanned out to every replica
    if s4["train_cycles"] < 1 or bus["published"] < 1:
        raise AssertionError(
            f"fleet trace never trained/published "
            f"(cycles={s4['train_cycles']} published={bus['published']})")
    if min_seq != bus["latest_seq"]:
        raise AssertionError(
            f"bus fan-out missed a replica: latest seq "
            f"{bus['latest_seq']}, subscribers {bus['subscribers']}")
    if s4["tokens"] != s1["tokens"]:
        raise AssertionError(
            f"fleet token count {s4['tokens']} != single {s1['tokens']}")
    # gate: round-domain critical path
    speedup = s1["max_rounds"] / max(max(_rounds(s4)), 1)
    emit("fleet/ratio", 0.0,
         f"round_speedup={speedup:.2f}x;bar={ROUND_BAR:.1f}x;"
         f"parity={parity};replicas={N_REPLICAS}")
    if speedup < ROUND_BAR:
        raise AssertionError(
            f"fleet critical-path rounds {max(_rounds(s4))} give only "
            f"{speedup:.2f}x over single {s1['max_rounds']} "
            f"(bar {ROUND_BAR}x)")


# ----------------------------------------------------- remote + failure
def _syncs_per_round(sys_):
    st = sys_.engine.stats
    return st.dispatches / max(st.steps, 1)


def _bench_remote(cfg, params, domains, smoke):
    from repro.core.tide import TideSystem
    from repro.fleet import FleetConfig

    trace = _trace(domains, 12 if smoke else 16, seed=29)

    # small per-cycle threshold: short budgets shed their partial
    # signal windows, and the spawn trace is deliberately short
    tkw = dict(n_threshold=4, train_min_steps=24 if smoke else 48)
    ref = TideSystem(cfg, params, _tide_cfg(smoke, **tkw))
    ref.run_stream(iter(_requests(trace)))       # warm
    ref.reset_adaptation()
    ref_reqs = _requests(trace)
    ref.run_stream(iter(ref_reqs))
    ref_streams = [list(r.generated) for r in ref_reqs]
    ref_syncs = _syncs_per_round(ref)
    ref_cycles = ref.service.cycles
    ref.close()
    if ref_cycles < 1:
        raise AssertionError("remote-parity trace never trained")

    tc = _tide_cfg(smoke, fleet=FleetConfig(trainer_endpoint="spawn"),
                   **tkw)
    rem = TideSystem(cfg, params, tc)
    rem.run_stream(iter(_requests(trace)))       # warm (serving side)
    rem.reset_adaptation()                       # round-trips RESET
    rem_reqs = _requests(trace)
    rem.run_stream(iter(rem_reqs))
    rem_streams = [list(r.generated) for r in rem_reqs]
    rem_syncs = _syncs_per_round(rem)
    sync_ratio = rem_syncs / max(ref_syncs, 1e-9)
    parity = int(rem_streams == ref_streams)
    st = rem.service.stats()
    emit("fleet/remote", 0.0,
         f"cycles={rem.service.cycles};parity={parity};"
         f"sync_ratio={sync_ratio:.3f};deploys={rem.service.deploys};"
         f"trainer_failures={st['failures']};"
         f"frames_sent={st['frames_sent']};"
         f"wire_kb={(st['bytes_sent'] + st['bytes_recv']) // 1024}")
    rem.close()
    if not parity:
        raise AssertionError(
            "out-of-process drain-parity broke: remote token streams "
            "differ from in-process")
    if rem.service.cycles != ref_cycles:
        raise AssertionError(
            f"remote trained {rem.service.cycles} cycles vs in-process "
            f"{ref_cycles} — the drain barrier is not schedule-exact")
    if st["failures"]:
        raise AssertionError(
            f"remote run recorded trainer failures: {st['last_error']}")
    if sync_ratio > SYNC_BAR:
        raise AssertionError(
            f"out-of-process trainer added serving-path syncs: "
            f"{rem_syncs:.3f}/round vs {ref_syncs:.3f} in-process "
            f"({sync_ratio:.2f}x > {SYNC_BAR}x)")

    # --- trainer kill: serve half, hard-kill, finish on the last draft
    import time

    kil = TideSystem(cfg, params, tc)
    half = len(trace) // 2
    first, second = _requests(trace[:half]), _requests(trace[half:])
    kil.run_stream(iter(first))
    kil.service.kill_trainer()
    deadline = time.monotonic() + 30.0
    while kil.service.running and time.monotonic() < deadline:
        time.sleep(0.05)
    t0 = time.monotonic()
    done = kil.run_stream(iter(second))
    wall = time.monotonic() - t0
    streams = [list(r.generated) for r in first + second]
    parity_k = int(streams == ref_streams)
    completed = len(done)
    failures = kil.summary()["trainer_failures"]
    emit("fleet/kill", 0.0,
         f"completed={completed};of={len(second)};parity={parity_k};"
         f"trainer_failures={failures};post_kill_drain="
         f"{kil.service.drain()};wall_s={wall:.1f}")
    kil.close()
    kil.close()                                  # idempotent
    if completed != len(second):
        raise AssertionError(
            f"serving lost requests after trainer kill: {completed} of "
            f"{len(second)}")
    if not parity_k:
        raise AssertionError(
            "post-kill token streams diverged (greedy serving on the "
            "last deployed draft must be byte-stable)")
    if failures < 1:
        raise AssertionError(
            "trainer kill was not surfaced in summary()")


def run(smoke: bool = False):
    cfg, params, domains = demo_target(30 if smoke else 120)
    _bench_scaleout(cfg, params, domains, smoke)
    _bench_remote(cfg, params, domains, smoke)


if __name__ == "__main__":
    run()
