"""Model substrate correctness: decode≡prefill per mixer family, pad
invariance, attention-path equivalences, MoE dispatch cross-check,
mixer oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import MIXER_CFGS, extra_for, tiny_cfg
from repro.models import attention as attn
from repro.models import transformer as T
from repro.models.config import BlockDef, MAMBA, RWKV6, FFN_SWIGLU
from repro.models import moe as moe_mod


@pytest.mark.parametrize("family", list(MIXER_CFGS))
def test_decode_matches_prefill(family, rngs):
    """Prefill(S+1) last logits == prefill(S) + one decode step."""
    cfg = MIXER_CFGS[family]
    params = T.init(cfg, rngs[0])
    B, S = 2, 24
    toks = jax.random.randint(rngs[1], (B, S + 1), 0, cfg.vocab_size)
    extra = extra_for(cfg, B, 16, rngs[2])
    ref = T.prefill(cfg, params, toks, extra=extra)
    pre = T.prefill(cfg, params, toks[:, :S], extra=extra, max_len=S + 8)
    dec = T.decode_step(cfg, params, pre["cache"], toks[:, S:])
    np.testing.assert_allclose(np.asarray(ref["logits"]),
                               np.asarray(dec["logits"][:, 0]),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("family", ["dense", "mla", "mamba", "rwkv"])
def test_left_pad_invariance(family, rngs):
    """A left-padded prompt must produce the same last-position logits as
    the unpadded prompt (pad masking in every mixer)."""
    cfg = MIXER_CFGS[family]
    params = T.init(cfg, rngs[0])
    B, S, PAD = 2, 16, 5
    toks = jax.random.randint(rngs[1], (B, S), 0, cfg.vocab_size)
    ref = T.prefill(cfg, params, toks)
    padded = jnp.pad(toks, ((0, 0), (PAD, 0)))
    out = T.prefill(cfg, params, padded,
                    pad=jnp.full((B,), PAD, jnp.int32))
    np.testing.assert_allclose(np.asarray(ref["logits"]),
                               np.asarray(out["logits"]),
                               rtol=2e-4, atol=2e-4)


def test_flash_prefill_matches_attend(rngs):
    B, S, Hq, Hk, D = 2, 64, 4, 2, 16
    q = jax.random.normal(rngs[0], (B, S, Hq, D))
    k = jax.random.normal(rngs[1], (B, S, Hk, D))
    v = jax.random.normal(rngs[2], (B, S, Hk, D))
    mask = attn.causal_mask(S, S, 0)[None, None, None]
    ref = attn.attend(q, k, v, mask)
    out = attn.flash_prefill(q, k, v, causal=True, block_q=16, block_kv=16)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                               rtol=1e-5, atol=1e-5)


def test_windowed_prefill_matches_masked(rngs):
    B, S, Hq, Hk, D, W = 1, 64, 4, 2, 16, 24
    q = jax.random.normal(rngs[0], (B, S, Hq, D))
    k = jax.random.normal(rngs[1], (B, S, Hk, D))
    v = jax.random.normal(rngs[2], (B, S, Hk, D))
    kpos = jnp.arange(S)[None, :]
    qpos = jnp.arange(S)[:, None]
    mask = ((kpos <= qpos) & (kpos > qpos - W))[None, None, None]
    ref = attn.attend(q, k, v, mask)
    out = attn.windowed_prefill(q, k, v, window=W, block_q=16)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                               rtol=1e-5, atol=1e-5)


def test_moe_sort_matches_einsum(rngs):
    cfg = MIXER_CFGS["moe"]
    params = T.init(cfg, rngs[0])
    p = params["body"]["pos0"]["moe"]
    p = jax.tree.map(lambda x: x[0], p)           # unstack layer dim
    x = jax.random.normal(rngs[1], (2, 16, cfg.d_model))
    out_s, aux_s = moe_mod.moe_sort(cfg, p, x)
    out_e, aux_e = moe_mod.moe_einsum(cfg, p, x)
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_e),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(aux_s), float(aux_e), rtol=1e-5)


def test_moe_capacity_drops_consistently(rngs):
    """With tight capacity both impls drop the same tokens."""
    import dataclasses
    cfg = dataclasses.replace(MIXER_CFGS["moe"], capacity_factor=0.5)
    params = T.init(cfg, rngs[0])
    p = jax.tree.map(lambda x: x[0], params["body"]["pos0"]["moe"])
    x = jax.random.normal(rngs[1], (2, 32, cfg.d_model))
    out_s, _ = moe_mod.moe_sort(cfg, p, x)
    out_e, _ = moe_mod.moe_einsum(cfg, p, x)
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_e),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("mixer", [MAMBA, RWKV6])
def test_ssm_chunked_matches_stepwise(mixer, rngs):
    """Chunked/parallel prefill == token-by-token decode recurrence."""
    cfg = tiny_cfg(name="ssm", pattern=(BlockDef(mixer, FFN_SWIGLU),),
                   rwkv_head_dim=16, num_layers=1)
    from repro.models import mamba as mam
    from repro.models import rwkv as rw
    params = T.init(cfg, rngs[0])
    p = jax.tree.map(lambda x: x[0], params["body"]["pos0"]["mix"])
    B, S = 2, 24
    x = jax.random.normal(rngs[1], (B, S, cfg.d_model)) * 0.5
    if mixer == MAMBA:
        out_par, state = mam.mamba_prefill(cfg, p, x)
        st0 = {"h": jnp.zeros((B, cfg.mamba_d_inner, cfg.mamba_d_state)),
               "conv": jnp.zeros((B, cfg.mamba_d_conv - 1,
                                  cfg.mamba_d_inner))}
        out_seq, states = mam.mamba_decode(cfg, p, x, st0)
        final_h = states["h"][:, -1]
        np.testing.assert_allclose(np.asarray(state["h"]),
                                   np.asarray(final_h), rtol=2e-4,
                                   atol=2e-4)
    else:
        out_par, state = rw.rwkv_prefill(cfg, p, x)
        st0 = {"s": jnp.zeros((B, cfg.rwkv_heads, cfg.rwkv_head_dim,
                               cfg.rwkv_head_dim)),
               "shift": jnp.zeros((B, 1, cfg.d_model))}
        out_seq, states = rw.rwkv_decode(cfg, p, x, st0)
        final_s = states["s"][:, -1]
        np.testing.assert_allclose(np.asarray(state["s"]),
                                   np.asarray(final_s), rtol=2e-4,
                                   atol=2e-4)
    np.testing.assert_allclose(np.asarray(out_par), np.asarray(out_seq),
                               rtol=2e-4, atol=2e-4)


def test_mla_absorbed_decode_matches_expanded(rngs):
    """The latent-space (absorbed) decode == expanded-form attention."""
    cfg = MIXER_CFGS["mla"]
    params = T.init(cfg, rngs[0])
    B, S = 2, 17
    toks = jax.random.randint(rngs[1], (B, S + 1), 0, cfg.vocab_size)
    ref = T.prefill(cfg, params, toks)              # expanded path
    pre = T.prefill(cfg, params, toks[:, :S], max_len=S + 4)
    dec = T.decode_step(cfg, params, pre["cache"], toks[:, S:])  # absorbed
    np.testing.assert_allclose(np.asarray(ref["logits"]),
                               np.asarray(dec["logits"][:, 0]),
                               rtol=2e-4, atol=2e-4)


def test_train_loss_finite_and_improves(rngs):
    cfg = MIXER_CFGS["dense"]
    from repro.training.optimizer import adamw
    from repro.training.trainer import make_train_step
    params = T.init(cfg, rngs[0])
    opt = adamw(lr=5e-3)
    ostate = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt, n_micro=2, remat=True))
    toks = jax.random.randint(rngs[1], (4, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": toks}
    losses = []
    for it in range(8):
        params, ostate, m = step(params, ostate, batch, jnp.int32(it))
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0]


def test_capture_layers_change_with_depth(rngs):
    cfg = tiny_cfg(num_layers=6)
    assert cfg.captures == (2, 3, 3)
    params = T.init(cfg, rngs[0])
    toks = jax.random.randint(rngs[1], (1, 8), 0, cfg.vocab_size)
    out = T.prefill(cfg, params, toks)
    assert out["captures"].shape == (1, 8, 3 * cfg.d_model)
    assert np.isfinite(np.asarray(out["captures"])).all()
