"""Continuous batching (serve_stream + in-flight slot refill).

Greedy decoding makes per-request token streams scheduling-invariant:
whatever slots a request shares a batch with, and whenever it is
admitted, its stream must be byte-identical.  That is the core oracle
here — wave scheduling, the superstep stream, the stepwise stream, and
serving a request alone must all agree token for token, and a saturated
single batch must reproduce ``serve_wave`` exactly (streams, SignalStore
contents, stats).

Also covers the satellite fixes: partial waves (inert slot padding),
``_unpack_superstep`` edge cases (zero valid rounds, wave done at entry,
EOS landing on the last round of a superstep, first-token EOS), and the
``ServingStats`` TTFT / completion-latency / occupancy accounting.
"""
import time

import jax
import numpy as np
import pytest

# Pretrained-fixture-heavy end-to-end parity suite: slow tier (the
# fast smoke loop runs `pytest -m "not slow"`; see ROADMAP.md).
pytestmark = pytest.mark.slow

import repro.configs as C
from repro.core import eagle
from repro.core.signals import SignalExtractor, SignalStore
from repro.data.workloads import arrival_trace, make_domains, training_corpus
from repro.models import transformer as T
from repro.serving.engine import ServingEngine
from repro.serving.policy import ServingConfig
from repro.serving.request import Request, inert_request
from repro.serving.scheduler import Scheduler
from repro.training.trainer import pretrain_target


@pytest.fixture(scope="module")
def pretrained():
    cfg = C.get("tide-tiny")
    params = T.init(cfg, jax.random.key(0))
    domains = make_domains(cfg.vocab_size, ["science"], branchings=[2],
                           seed=3)
    corpus = training_corpus(domains["science"], 64, 40, 1)
    params, _ = pretrain_target(cfg, params, corpus, steps=80, lr=3e-3)
    dcfg = eagle.draft_config(cfg)
    dparams = eagle.draft_init(dcfg, jax.random.key(7))
    return cfg, params, dcfg, dparams, domains


def _engine(pretrained, rounds, *, batch=4, extractor=True, eos_id=None,
            max_len=96, greedy=True, tree_width=0):
    cfg, params, dcfg, dparams, domains = pretrained
    store = SignalStore()
    ext = SignalExtractor(store, window=16) if extractor else None
    config = ServingConfig(batch_size=batch, max_len=max_len, gamma=3,
                           seed=5, greedy=greedy, superstep_rounds=rounds,
                           eos_id=eos_id, tree_width=tree_width)
    eng = ServingEngine(cfg, params, dcfg, dparams, extractor=ext,
                        config=config)
    return eng, store


def _requests(pretrained, budgets, seed=0):
    domains = pretrained[4]
    rng = np.random.default_rng(seed)
    return [Request(prompt=domains["science"].sample_prompt(rng),
                    max_new_tokens=m) for m in budgets]


def _signals(store):
    return [(b.tokens.tobytes(), b.feats.tobytes()) for b in store.drain()]


# ------------------------------------------------- saturated-batch parity
@pytest.mark.parametrize("rounds", [0, 8])
def test_saturated_stream_matches_wave(pretrained, rounds):
    """A saturated same-arrival batch through serve_stream must be
    byte-identical to serve_wave: streams, SignalStore, stats."""
    e_wave, s_wave = _engine(pretrained, rounds)
    r_wave = _requests(pretrained, (9, 24, 24, 15))
    e_wave.serve_wave(r_wave)

    e_str, s_str = _engine(pretrained, rounds)
    r_str = _requests(pretrained, (9, 24, 24, 15))
    done = e_str.serve_stream(r_str)

    assert [r.generated for r in r_str] == [r.generated for r in r_wave]
    assert _signals(s_str) == _signals(s_wave)
    assert len(done) == 4 and all(r.finish_t is not None for r in r_str)
    for attr in ("tokens_out", "steps", "spec_steps", "dispatches",
                 "refills"):
        assert getattr(e_str.stats, attr) == getattr(e_wave.stats, attr)
    assert e_str.accept_ema == e_wave.accept_ema
    assert e_wave.stats.tokens_out == sum(
        len(r.generated) for r in r_wave)


# --------------------------------------------------- refill-stream parity
def test_refill_stream_parity_and_alone(pretrained):
    """A ragged stream through both engine modes and through wave
    chunks: per-request streams identical everywhere, and every
    *refilled* request matches serving it alone on a fresh engine."""
    budgets = (5, 18, 7, 12, 16, 4, 9, 20, 6, 11)
    r_ss = _requests(pretrained, budgets)
    e_ss, _ = _engine(pretrained, 8)
    e_ss.serve_stream(list(r_ss))
    assert e_ss.stats.refills == len(budgets) - e_ss.batch
    assert all(r.done and r.finish_t is not None for r in r_ss)
    assert e_ss.stats.tokens_out == sum(len(r.generated) for r in r_ss)

    r_st = _requests(pretrained, budgets)
    e_st, _ = _engine(pretrained, 0)
    e_st.serve_stream(list(r_st))
    assert [r.generated for r in r_st] == [r.generated for r in r_ss]

    r_wv = _requests(pretrained, budgets)
    e_wv, _ = _engine(pretrained, 8)
    for i in range(0, len(r_wv), 4):
        e_wv.serve_wave(r_wv[i:i + 4])
    assert [r.generated for r in r_wv] == [r.generated for r in r_ss]

    # refilled slots (everything admitted after the initial batch)
    e_alone, _ = _engine(pretrained, 8, batch=1)
    for req in r_ss[e_ss.batch:]:
        solo = Request(prompt=list(req.prompt),
                       max_new_tokens=req.max_new_tokens)
        e_alone.serve_wave([solo])
        assert solo.generated == req.generated, \
            "refilled slot diverged from serving the request alone"


def test_sampled_stream_scheduling_invariant(pretrained):
    """Per-request PRNG streams (fold-in on the admission ordinal) make
    *sampled* decoding scheduling-invariant too: a ragged stream with
    in-flight refills must emit byte-identical per-request streams
    through the superstep engine, the per-step reference loop, and
    wave-chunked serving — including across the refill-timing skew that
    previously forced the sampled-parity caveat."""
    budgets = (5, 18, 7, 12, 16, 4, 9, 20, 6, 11)
    r_ss = _requests(pretrained, budgets)
    e_ss, _ = _engine(pretrained, 8, greedy=False)
    e_ss.serve_stream(list(r_ss))
    assert e_ss.stats.refills == len(budgets) - e_ss.batch

    r_st = _requests(pretrained, budgets)
    e_st, _ = _engine(pretrained, 0, greedy=False)
    e_st.serve_stream(list(r_st))
    assert [r.generated for r in r_st] == [r.generated for r in r_ss], \
        "sampled superstep stream diverged from the per-step loop"

    r_wv = _requests(pretrained, budgets)
    e_wv, _ = _engine(pretrained, 8, greedy=False)
    for i in range(0, len(r_wv), 4):
        e_wv.serve_wave(r_wv[i:i + 4])
    assert [r.generated for r in r_wv] == [r.generated for r in r_ss], \
        "sampled streams depend on scheduling (wave vs continuous)"

    # tree-sampled decoding rides the same per-lane streams: branch
    # r >= 1 folds r into the lane's acceptance key, so a width=2 tree
    # must stay refill-order-invariant across the same three schedules
    r_tr = _requests(pretrained, budgets)
    e_tr, _ = _engine(pretrained, 8, greedy=False, tree_width=2)
    e_tr.serve_stream(list(r_tr))
    assert e_tr.stats.refills == len(budgets) - e_tr.batch

    r_ts = _requests(pretrained, budgets)
    e_ts, _ = _engine(pretrained, 0, greedy=False, tree_width=2)
    e_ts.serve_stream(list(r_ts))
    assert [r.generated for r in r_ts] == [r.generated for r in r_tr], \
        "tree-sampled superstep stream diverged from the per-step loop"

    r_tw = _requests(pretrained, budgets)
    e_tw, _ = _engine(pretrained, 8, greedy=False, tree_width=2)
    for i in range(0, len(r_tw), 4):
        e_tw.serve_wave(r_tw[i:i + 4])
    assert [r.generated for r in r_tw] == [r.generated for r in r_tr], \
        "tree-sampled streams depend on scheduling (wave vs continuous)"


def test_stream_stats_and_latency(pretrained):
    """ServingStats: TTFT/latency recorded per request, occupancy in
    (0, 1], lane accounting consistent."""
    budgets = (6, 15, 8, 10, 12, 5)
    reqs = _requests(pretrained, budgets)
    eng, _ = _engine(pretrained, 8)
    eng.serve_stream(list(reqs))
    st = eng.stats
    assert st.completed == len(budgets)
    assert len(st.ttfts) == len(budgets)
    assert len(st.latencies) == len(budgets)
    assert all(t >= 0 for t in st.ttfts)
    assert st.latency_p50 <= st.latency_p95
    assert 0.0 < st.occupancy <= 1.0
    assert st.busy_lane_rounds <= st.lane_rounds
    assert st.lane_rounds == st.steps * eng.batch
    for r in reqs:
        assert r.ttft is not None and r.latency is not None
        assert r.ttft <= r.latency
    # timeline rounds carry lane-occupancy telemetry
    assert all("busy_lanes" in e for e in st.timeline)


# ----------------------------------------------------------- partial waves
@pytest.mark.parametrize("rounds", [0, 8])
def test_partial_wave(pretrained, rounds):
    """serve_wave accepts waves smaller than the engine batch: inert
    zero-budget slots pad the batch and leak nothing."""
    reqs = _requests(pretrained, (7, 11))
    eng, store = _engine(pretrained, rounds, batch=4)
    eng.serve_wave(reqs)
    assert all(r.done and len(r.generated) == r.max_new_tokens
               for r in reqs)
    assert eng.stats.tokens_out == sum(len(r.generated) for r in reqs)

    # parity: the same two requests on a batch-2 engine
    ref = _requests(pretrained, (7, 11))
    e2, _ = _engine(pretrained, rounds, batch=2)
    e2.serve_wave(ref)
    assert [r.generated for r in ref] == [r.generated for r in reqs]


def test_zero_budget_request(pretrained):
    """A zero-budget request completes immediately with no tokens."""
    reqs = _requests(pretrained, (0, 8))
    eng, _ = _engine(pretrained, 8)
    eng.serve_wave(reqs)
    assert reqs[0].generated == [] and reqs[0].finish_t is not None
    assert len(reqs[1].generated) == 8
    assert eng.stats.tokens_out == 8


# ----------------------------------------------------------- EOS handling
def test_first_token_eos_stream(pretrained):
    """EOS as the very first sampled token: one-token stream, immediate
    finish, identical across modes, and the slot is refilled."""
    probe = _requests(pretrained, (12, 12, 12, 12, 12, 12))
    ref = [Request(prompt=list(r.prompt), max_new_tokens=12)
           for r in probe]
    e1, _ = _engine(pretrained, 8)
    e1.serve_stream(ref)
    # request 0's first sampled token as EOS: its stream collapses to a
    # single token, freeing the slot for an immediate refill
    eos = ref[0].generated[0]

    outs = {}
    for rounds in (0, 8):
        reqs = [Request(prompt=list(r.prompt), max_new_tokens=12)
                for r in probe]
        eng, _ = _engine(pretrained, rounds, eos_id=eos)
        eng.serve_stream(reqs)
        outs[rounds] = [list(r.generated) for r in reqs]
        assert reqs[0].generated == [eos], \
            "first-token EOS must cut the stream to one token"
        assert reqs[0].finish_t is not None
        for r in reqs:
            assert eos not in r.generated[:-1], "tokens emitted past EOS"
            assert r.done
    assert outs[0] == outs[8]


# ----------------------------------------- _unpack_superstep edge cases
def _bare_engine(pretrained):
    eng, _ = _engine(pretrained, 8, batch=2, extractor=False)
    return eng


def _ys(valid, n_eff, tokens, active_after, K, B, gp1):
    """Craft a superstep telemetry dict as _materialize would return."""
    return {
        "valid": np.asarray(valid, bool),
        "use_spec": np.ones((K,), bool),
        "ell": np.full((K,), 2.0, np.float32),
        "alpha": np.full((K,), 0.5, np.float32),
        "n_eff": np.asarray(n_eff, np.int32),
        "n_commit": np.asarray(n_eff, np.int32),
        "tokens": np.asarray(tokens, np.int32),
        "active_after": np.asarray(active_after, bool),
        "n_sig": np.zeros((K,), np.int32),
        "ema": np.full((K,), 1.5, np.float32),
    }


def test_unpack_zero_valid_rounds(pretrained):
    """A superstep dispatched after the wave finished: every round is
    skipped; nothing may change host-side."""
    eng = _bare_engine(pretrained)
    reqs = [Request(prompt=[1, 2], max_new_tokens=4) for _ in range(2)]
    K, B, gp1 = 3, 2, 4
    ys = _ys([False] * K, np.zeros((K, B)), np.zeros((K, B, gp1)),
             np.ones((K, B)), K, B, gp1)
    progressed = eng._unpack_superstep(ys, reqs, [r.rid for r in reqs], 0.0)
    assert progressed is False
    assert eng.stats.steps == 0 and eng.stats.tokens_out == 0
    assert all(r.generated == [] and r.finish_t is None for r in reqs)


def test_unpack_wave_done_at_entry_engine_level(pretrained):
    """Budgets small enough that the wave completes inside the first
    superstep: the pipelined second superstep must contribute zero
    rounds (valid=False throughout)."""
    eng, _ = _engine(pretrained, 8)
    reqs = _requests(pretrained, (3, 3, 3, 3))
    eng.serve_wave(reqs)
    assert all(len(r.generated) == 3 for r in reqs)
    # every *valid* round committed tokens; the trailing all-done
    # superstep contributed none
    assert eng.stats.steps < 8
    assert eng.stats.dispatches >= 2


def test_unpack_eos_on_last_round(pretrained):
    """EOS cut landing on the final round of a superstep: truncation and
    finish must apply on that very round, not the next superstep."""
    eng = _bare_engine(pretrained)
    reqs = [Request(prompt=[1, 2], max_new_tokens=10) for _ in range(2)]
    for r in reqs:
        # decoding requests always have their first token committed
        # before any drained telemetry mentions them; an unset
        # first_token_t marks a mid-chunk-prefill lane, which decode
        # telemetry must never retire
        r.first_token_t = time.perf_counter()
    K, B, gp1 = 2, 2, 4
    n_eff = [[2, 2], [1, 3]]
    tokens = np.arange(K * B * gp1).reshape(K, B, gp1) % 97
    active_after = [[True, True], [False, True]]   # req0 EOS-cut on last
    ys = _ys([True, True], n_eff, tokens, active_after, K, B, gp1)
    progressed = eng._unpack_superstep(ys, reqs, [r.rid for r in reqs], 0.0)
    assert progressed is True
    assert eng.stats.steps == 2
    assert len(reqs[0].generated) == 3 and reqs[0].finish_t is not None
    assert len(reqs[1].generated) == 5 and reqs[1].finish_t is None
    assert eng.stats.tokens_out == 8
    assert eng.stats.completed == 1


def test_unpack_free_slot_rows_ignored(pretrained):
    """Telemetry rows of free lanes (None residency snapshot) must not
    be attributed to anyone."""
    eng = _bare_engine(pretrained)
    req = Request(prompt=[1, 2], max_new_tokens=10)
    K, B, gp1 = 1, 2, 4
    # a free lane is inactive on device, so its n_eff is always 0
    ys = _ys([True], [[2, 0]], np.ones((K, B, gp1)), [[True, False]],
             K, B, gp1)
    eng._unpack_superstep(ys, [req, None], [req.rid, -1], 0.0)
    assert len(req.generated) == 2
    assert eng.stats.tokens_out == 2


# ------------------------------------------------- chunked refill prefill
def _chunk_requests(pretrained, n=10, seed=11):
    """Bimodal long-tail *prompt* trace: short-chat bulk + long prompts
    that would stall every resident lane for their full prefill under
    one-shot refill."""
    domains = pretrained[4]
    trace = arrival_trace(domains, n, mode="bursty", burst_size=4,
                          max_new_range=(5, 14), prompt_len=(8, 16),
                          long_prompt_frac=0.3, long_prompt_range=(48, 80),
                          seed=seed)
    return [Request(prompt=list(ev.prompt),
                    max_new_tokens=ev.max_new_tokens) for ev in trace]


def _chunk_engine(pretrained, rounds, *, chunk, batch=4, greedy=True):
    cfg, params, dcfg, dparams, _ = pretrained
    return ServingEngine(cfg, params, dcfg, dparams, batch_size=batch,
                         max_len=160, gamma=3, seed=5, greedy=greedy,
                         superstep_rounds=rounds, prefill_chunk=chunk)


def test_chunked_long_prompt_stream_invariance(pretrained):
    """Long-prompt bimodal trace with chunking on: the superstep
    stream, the per-step stream, wave chunks, serving each refill
    alone, AND the unchunked engine all emit byte-identical per-request
    streams — chunking changes when prefill work happens, never what is
    decoded.  The chunked engines' longest uninterruptible prefill op is
    bounded by the chunk width; the one-shot engine's is the long-tail
    prompt.  (Finer-grained chunk edge cases — prompt shorter than one
    chunk, exact chunk multiples, first-token EOS, zero-budget
    admission mid-chunk, deploy/reseed mid-prefill — are pinned in
    tests/test_chunked_prefill.py.)"""
    chunk = 32
    r_ss = _chunk_requests(pretrained)
    e_ss = _chunk_engine(pretrained, 8, chunk=chunk)
    e_ss.serve_stream(list(r_ss))
    assert all(r.done and r.finish_t is not None for r in r_ss)
    assert e_ss.stats.tokens_out == sum(len(r.generated) for r in r_ss)
    assert e_ss.stats.prefill_op_width.max <= chunk
    assert e_ss.stats.prefill_chunks >= len(r_ss)
    # mid-prefill lanes were accounted separately, not as idle capacity
    assert e_ss.stats.prefill_lane_rounds > 0
    assert e_ss.stats.lane_rounds == e_ss.stats.steps * e_ss.batch

    r_one = _chunk_requests(pretrained)
    e_one = _chunk_engine(pretrained, 8, chunk=0)
    e_one.serve_stream(list(r_one))
    assert [r.generated for r in r_one] == [r.generated for r in r_ss], \
        "chunked stream diverged from one-shot refill"
    assert e_one.stats.prefill_op_width.max >= 48   # the long-tail stall

    r_st = _chunk_requests(pretrained)
    e_st = _chunk_engine(pretrained, 0, chunk=chunk)
    e_st.serve_stream(list(r_st))
    assert [r.generated for r in r_st] == [r.generated for r in r_ss], \
        "chunked per-step loop diverged from the chunked superstep"

    r_wv = _chunk_requests(pretrained)
    e_wv = _chunk_engine(pretrained, 8, chunk=chunk)
    for i in range(0, len(r_wv), 4):
        e_wv.serve_wave(r_wv[i:i + 4])
    assert [r.generated for r in r_wv] == [r.generated for r in r_ss], \
        "chunked serve_wave diverged (compat wrapper bypassed chunking?)"
    assert e_wv.stats.prefill_op_width.max <= chunk

    e_alone = _chunk_engine(pretrained, 8, chunk=chunk, batch=1)
    for req in r_ss[e_ss.batch:]:
        solo = Request(prompt=list(req.prompt),
                       max_new_tokens=req.max_new_tokens)
        e_alone.serve_wave([solo])
        assert solo.generated == req.generated, \
            "chunk-refilled slot diverged from serving the request alone"


# -------------------------------------------------------------- scheduler
def test_scheduler_fifo_and_lazy_pull():
    pulled = []

    def gen():
        for i in range(6):
            pulled.append(i)
            yield Request(prompt=[1, 2], max_new_tokens=4)

    s = Scheduler(2, gen())
    first = s.admit()
    assert [slot for slot, _ in first] == [0, 1]
    assert len(pulled) == 2, "scheduler must pull lazily"
    assert s.has_work()
    # nothing free -> no admission
    assert s.admit() == []
    # finish slot 1 -> exactly one refill, FIFO order
    s.slots[1].finish()
    freed = s.release_finished()
    assert len(freed) == 1
    nxt = s.admit()
    assert [slot for slot, _ in nxt] == [1]
    assert len(pulled) <= 4


def test_inert_request():
    r = inert_request()
    assert r.done and r.finish_t is not None and r.generated == []
    assert r.max_new_tokens == 0
