"""EAGLE-3 draft model: shapes, cache contiguity, trainability, and the
signal-convention alignment between serving capture and training loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.core import eagle
from repro.models import transformer as T
from repro.training.optimizer import adamw


@pytest.fixture(scope="module")
def setup():
    cfg = C.get("tide-tiny")
    dcfg = eagle.draft_config(cfg)
    params = T.init(cfg, jax.random.key(0))
    dparams = eagle.draft_init(dcfg, jax.random.key(1))
    return cfg, dcfg, params, dparams


def test_draft_is_single_layer(setup):
    cfg, dcfg, params, dparams = setup
    assert dcfg.num_layers == 1
    # params: fuse + fc + 1 decoder layer + head only
    assert set(dparams) == {"fuse", "fc", "norm1", "attn", "norm2", "ffn",
                            "final_norm", "head"}
    n = eagle.draft_param_count(dcfg)
    assert n < cfg.param_count()  # strictly smaller than the target


def test_extend_shapes_and_lengths(setup):
    cfg, dcfg, params, dparams = setup
    B, T_, D = 2, 5, cfg.d_model
    dcache = eagle.init_draft_cache(dcfg, B, 32)
    feats = jax.random.normal(jax.random.key(2), (B, T_, 3 * D))
    toks = jax.random.randint(jax.random.key(3), (B, T_), 0,
                              cfg.vocab_size)
    adv = jnp.array([3, 5], jnp.int32)
    logits, h, dcache = eagle.draft_extend(dcfg, dparams, params["embed"],
                                           dcache, feats, toks, adv)
    assert logits.shape == (B, T_, cfg.vocab_size)
    assert h.shape == (B, T_, D)
    assert dcache["lengths"].tolist() == [3, 5]


def test_propose_chain(setup):
    cfg, dcfg, params, dparams = setup
    B, G = 2, 3
    dcache = eagle.init_draft_cache(dcfg, B, 32)
    h = jax.random.normal(jax.random.key(4), (B, dcfg.d_model))
    logits = jax.random.normal(jax.random.key(5), (B, cfg.vocab_size))
    toks, dlogits, dcache2 = eagle.draft_propose(
        dcfg, dparams, params["embed"], dcache, h, logits, G)
    assert toks.shape == (B, G)
    assert dlogits.shape == (B, G, cfg.vocab_size)
    assert dcache2["lengths"].tolist() == [G, G]
    # first draft token is the argmax of the provided logits
    np.testing.assert_array_equal(np.asarray(toks[:, 0]),
                                  np.asarray(logits.argmax(-1)))
    rolled = eagle.reset_propose(dcache2, G)
    assert rolled["lengths"].tolist() == [0, 0]


@pytest.mark.slow
def test_draft_learns_target_behaviour(setup):
    """Core TIDE premise: training on (capture, next-token) pairs raises
    the draft's top-1 agreement with the target (paper Fig. 7)."""
    cfg, dcfg, params, dparams = setup
    corpus = jax.random.randint(jax.random.key(6), (32, 33), 0,
                                cfg.vocab_size)
    pre = T.prefill(cfg, params, corpus)
    feats = pre["captures"][:, :-1]
    nexts = corpus[:, 1:]
    opt = adamw(lr=2e-3, weight_decay=0.0)
    ostate = opt.init(dparams)
    lossf = jax.value_and_grad(
        lambda dp, f, t: eagle.draft_train_loss(dcfg, dp, params["embed"],
                                                f, t, ttt=True),
        has_aux=True)

    @jax.jit
    def step(dp, os_, f, t, it):
        (l, m), g = lossf(dp, f, t)
        dp, os_ = opt.update(dp, g, os_, it)
        return dp, os_, l, m["accuracy"]

    acc0 = None
    dp = dparams
    for it in range(60):
        dp, ostate, l, a = step(dp, ostate, feats, nexts, jnp.int32(it))
        if acc0 is None:
            acc0 = float(a)
    assert float(a) > acc0 + 0.1, f"draft did not learn: {acc0} -> {a}"
    assert np.isfinite(float(l))


def test_draft_config_divisibility():
    """draft_config must produce valid head geometry for every arch."""
    for arch in C.ARCHS:
        cfg = C.get(arch)
        dcfg = eagle.draft_config(cfg)
        assert dcfg.num_heads % dcfg.num_kv_heads == 0, arch
        assert dcfg.num_heads * dcfg.head_dim == dcfg.d_model, arch
