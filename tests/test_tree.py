"""Tree speculation: tree-masked kernels vs oracles, tree acceptance
units, and the width=1 == chain bitwise-parity property tier.

The tree engine's load-bearing invariant is the degenerate-shape
contract: ``tree_width=1`` IS the linear gamma-chain — branch 0 drafts
with the chain's exact randomness, the 1-branch tree mask reduces to
the causal chain mask, depth-1 acceptance consumes the chain's uniform
stream against the unmasked target density, and ``compact_tree_cache``
at sel == 0 is a byte-preserving same-position copy.  So width=1 must
be *bitwise* identical to the chain engine on full emitted streams —
greedy and per-request-keyed sampled, superstep and stepwise, dense
and paged.  The property tier here pins exactly that over random
prompt lengths, budgets, and seeds.

Wider trees change WHAT is accepted (longest root path instead of one
chain prefix) but not WHERE bytes land: paged tree serving must stay
byte-identical to dense tree serving, and every page (including the
scratch rows the rejected branches wrote through the trash page) must
be back on the free list after drain.

All tests run on randomly initialized weights (parity is a property of
the computation, not the model), so the file stays in the fast tier.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # not in the container image - deterministic shim
    from _hypothesis_shim import given, settings, strategies as st

import repro.configs as C
from repro.core import eagle, speculative as spec
from repro.models import transformer as T
from repro.serving.engine import ServingEngine
from repro.serving.policy import ServingConfig, SpeculationPolicy
from repro.serving.request import Request

from conftest import MIXER_CFGS


@pytest.fixture(scope="module", autouse=True)
def _fresh_compile_state():
    """Drop every executable the preceding ~200 tests compiled before
    this module's engine compiles run.  Late in the full-tier session
    the accumulated LLVM-JIT state makes ``backend_compile`` segfault
    on this host when the stream-superstep program compiles; the same
    compiles are rock-solid from a fresh process, and clearing the jit
    caches here reproduces those standalone conditions."""
    import gc
    jax.clear_caches()
    gc.collect()
    yield


# ========================================== tree kernels vs CPU oracles
TREE_SHAPES = [(1, 3, 0), (2, 3, 0), (3, 2, 0), (2, 4, 6), (4, 2, 5)]


@pytest.mark.parametrize("w,g,window", TREE_SHAPES)
def test_verify_attn_tree_kernel_vs_ref(w, g, window):
    """The tree-masked Pallas kernel (interpret mode) against the dense
    gather oracle, including sliding-window shapes."""
    from repro.kernels.verify_attn import ops
    from repro.kernels.verify_attn.ref import verify_attention_tree_ref

    t = w * g + 1
    b, hq, hk, d, s = 2, 4, 2, 16, 64
    ks = jax.random.split(jax.random.fold_in(jax.random.key(0),
                                             w * 10 + g), 3)
    q = jax.random.normal(ks[0], (b, t, hq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hk, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hk, d), jnp.float32)
    lengths = jnp.array([17, 30], jnp.int32)
    pad = jnp.array([3, 0], jnp.int32)
    ref = verify_attention_tree_ref(q, k, v, lengths, pad, tree=(w, g),
                                    window=window)
    out = ops.verify_attn(q, k, v, lengths, pad, window=window,
                          force_kernel=True, tree=(w, g), block_kv=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("w,g,window", TREE_SHAPES)
def test_verify_attn_tree_paged_kernel_vs_ref(w, g, window):
    """Paged tree kernel: same bytes behind a block table + trash page."""
    from repro.kernels.verify_attn import ops
    from repro.kernels.verify_attn.ref import (
        verify_attention_tree_paged_ref)

    t = w * g + 1
    b, hq, hk, d, s, p = 2, 4, 2, 16, 64, 16
    n_pg = s // p
    ks = jax.random.split(jax.random.fold_in(jax.random.key(1),
                                             w * 10 + g), 3)
    q = jax.random.normal(ks[0], (b, t, hq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hk, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hk, d), jnp.float32)
    k_pool = jnp.concatenate([k.reshape(b * n_pg, p, hk, d),
                              jnp.zeros((1, p, hk, d), jnp.float32)], 0)
    v_pool = jnp.concatenate([v.reshape(b * n_pg, p, hk, d),
                              jnp.zeros((1, p, hk, d), jnp.float32)], 0)
    tbl = jnp.arange(b * n_pg, dtype=jnp.int32).reshape(b, n_pg)
    lengths = jnp.array([17, 30], jnp.int32)
    pad = jnp.array([3, 0], jnp.int32)
    ref = verify_attention_tree_paged_ref(q, k_pool, v_pool, tbl, lengths,
                                          pad, tree=(w, g), window=window)
    out = ops.verify_attn_paged(q, k_pool, v_pool, tbl, lengths, pad,
                                window=window, force_kernel=True,
                                tree=(w, g))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# =============================================== tree acceptance units
def _onehot_logits(ids, v=8):
    """(..., V) logits whose argmax/softmax mass sits on ``ids``."""
    return 10.0 * jax.nn.one_hot(jnp.asarray(ids), v, dtype=jnp.float32)


def test_tree_path_slots_layout():
    """Root at slot 0; branch sel's depth-j node at 1 + sel*γ + (j-1)."""
    slots = spec.tree_path_slots(jnp.array([0, 1], jnp.int32), 3)
    assert slots.tolist() == [[0, 1, 2, 3], [0, 4, 5, 6]]
    # width=1 trees only have branch 0: the identity chain layout
    one = spec.tree_path_slots(jnp.zeros((4,), jnp.int32), 3)
    assert (np.asarray(one) == np.arange(4)).all()


def test_verify_tree_greedy_accepts_longest_branch():
    """The target's greedy walk rejects branch 0 at depth 1 but matches
    branch 1 to the leaf: full accept on branch 1 with the leaf-slot
    bonus."""
    draft = jnp.asarray([[[1, 2], [3, 4]]], jnp.int32)   # (1, w=2, γ=2)
    # slots: 0=root, 1-2=branch0, 3-4=branch1
    tgt = _onehot_logits([[3, 7, 7, 4, 6]])              # (1, 5, V)
    n_acc, sel, bonus = spec.verify_tree_greedy(tgt, draft)
    assert (int(n_acc[0]), int(sel[0]), int(bonus[0])) == (2, 1, 6)


def test_verify_tree_greedy_rejects_all_branches():
    """No sibling matches the root argmax: n_acc=0, the bonus is the
    target's root correction (chain semantics)."""
    draft = jnp.asarray([[[1, 2], [3, 4]]], jnp.int32)
    tgt = _onehot_logits([[5, 7, 7, 7, 7]])
    n_acc, sel, bonus = spec.verify_tree_greedy(tgt, draft)
    assert (int(n_acc[0]), int(bonus[0])) == (0, 5)


def test_verify_tree_greedy_partial_depth():
    """Branch 0 matches depth 1 only: accept 1, bonus from its slot."""
    draft = jnp.asarray([[[1, 2], [3, 4]]], jnp.int32)
    tgt = _onehot_logits([[1, 6, 7, 7, 7]])   # slot1 argmax 6 != 2
    n_acc, sel, bonus = spec.verify_tree_greedy(tgt, draft)
    assert (int(n_acc[0]), int(sel[0]), int(bonus[0])) == (1, 0, 6)


def test_verify_tree_width1_matches_chain_rules():
    """width=1 tree acceptance == the chain verifiers, greedy and
    sampled, on random logits (op-for-op reduction)."""
    ks = jax.random.split(jax.random.key(3), 4)
    b, g, v = 4, 3, 32
    tgt = jax.random.normal(ks[0], (b, g + 1, v), jnp.float32)
    dlog = jax.random.normal(ks[1], (b, g, v), jnp.float32)
    dtok = jax.random.randint(ks[2], (b, g), 0, v, jnp.int32)
    n_c, bonus_c = spec.verify_greedy(tgt, dtok)
    n_t, sel, bonus_t = spec.verify_tree_greedy(tgt, dtok[:, None, :])
    assert (np.asarray(n_c) == np.asarray(n_t)).all()
    assert (np.asarray(bonus_c) == np.asarray(bonus_t)).all()
    assert (np.asarray(sel) == 0).all()
    n_c, bonus_c = spec.verify_sample(ks[3], tgt, dlog, dtok)
    n_t, _, bonus_t = spec.verify_tree_sample(
        ks[3], tgt, dlog[:, None], dtok[:, None, :])
    assert (np.asarray(n_c) == np.asarray(n_t)).all()
    assert (np.asarray(bonus_c) == np.asarray(bonus_t)).all()


# ============================================= draft tree + step level
_MODEL = None


def _get_model():
    global _MODEL
    if _MODEL is None:
        cfg = C.get("tide-tiny")
        params = T.init(cfg, jax.random.key(0))
        dcfg = eagle.draft_config(cfg)
        dparams = eagle.draft_init(dcfg, jax.random.key(7))
        _MODEL = (cfg, params, dcfg, dparams)
    return _MODEL


def _spec_start(b=3, s=12, g=3, max_len=96):
    cfg, params, dcfg, dparams = _get_model()
    toks = jax.random.randint(jax.random.key(2), (b, s), 0,
                              cfg.vocab_size)
    pre = T.prefill(cfg, params, toks, max_len=max_len)
    first = pre["logits"].argmax(-1).astype(jnp.int32)
    dcache = eagle.init_draft_cache(dcfg, b, max_len)
    dcache = jax.jit(lambda dc, p, t: spec.seed_draft_cache(
        cfg, dcfg, params, dparams, dc, p, t))(dcache, pre, toks)
    carry = spec.init_carry(cfg, dcfg, pre, first, g)
    return pre["cache"], dcache, carry


def _propose_inputs(g=3):
    """(h_last, first_logits, dcache) at the post-extend frontier."""
    cfg, params, dcfg, dparams = _get_model()
    cache, dcache, carry = _spec_start(g=g)
    ext_logits, ext_h, dcache = jax.jit(
        lambda dc, f, t, a: eagle.draft_extend(
            dcfg, dparams, params["embed"], dc, f, t, a))(
        dcache, carry.feats, carry.tokens, carry.advance)
    last = (carry.advance - 1)[:, None, None]
    h_last = jnp.take_along_axis(ext_h, last, axis=1)[:, 0]
    first_logits = jnp.take_along_axis(ext_logits, last, axis=1)[:, 0]
    return h_last, first_logits, dcache


def _propose_fn(width=0, gamma=3):
    """Jitted propose entry point (the compile path the engine uses —
    eager scan compiles proved flaky on this host's 8MB-stack LLVM)."""
    cfg, params, dcfg, dparams = _get_model()
    if width:
        return jax.jit(lambda dc, h, fl: eagle.draft_propose_tree(
            dcfg, dparams, params["embed"], dc, h, fl, gamma, width))
    return jax.jit(lambda dc, h, fl: eagle.draft_propose(
        dcfg, dparams, params["embed"], dc, h, fl, gamma))


def test_draft_propose_tree_width1_is_chain():
    h, fl, dc = _propose_inputs()
    ct, cl, cc = _propose_fn()(dc, h, fl)
    tt, tl, tc = _propose_fn(width=1)(dc, h, fl)
    assert (np.asarray(ct) == np.asarray(tt[:, 0])).all()
    assert (np.asarray(cl) == np.asarray(tl[:, 0])).all()
    eq = jax.tree.map(lambda x, y: bool((np.asarray(x)
                                         == np.asarray(y)).all()), cc, tc)
    assert all(jax.tree.leaves(eq))


def test_draft_propose_tree_sibling_roots_distinct():
    """Sibling depth-1 tokens are distinct per lane (top-k first
    continuations, not k copies of the argmax)."""
    h, fl, dc = _propose_inputs()
    toks, _, _ = _propose_fn(width=4)(dc, h, fl)
    first = np.asarray(toks[:, :, 0])                       # (B, w)
    for lane in first:
        assert len(set(lane.tolist())) == len(lane), lane


def _step_fns(greedy, width):
    cfg, params, dcfg, dparams = _get_model()
    chain = jax.jit(lambda c, dc, cr, k: spec.spec_decode_step(
        cfg, dcfg, params, dparams, c, dc, cr, gamma=3, greedy=greedy,
        keys=k))
    tree = jax.jit(lambda c, dc, cr, k: spec.tree_decode_step(
        cfg, dcfg, params, dparams, c, dc, cr, gamma=3, width=width,
        greedy=greedy, keys=k))
    return chain, tree


@pytest.mark.parametrize("greedy", [True, False])
def test_tree_step_width1_bitwise_chain(greedy):
    """Multi-round step-level parity: width=1 ``tree_decode_step``
    produces byte-identical caches, carries, and commits to
    ``spec_decode_step`` under per-lane keys."""
    start = _spec_start()
    sa, sb = start, start
    b = start[2].tokens.shape[0]
    chain_fn, tree_fn = _step_fns(greedy, 1)
    for i in range(4):
        keys = jax.vmap(lambda s, _i=i: jax.random.fold_in(
            jax.random.fold_in(jax.random.key(7), s), _i))(jnp.arange(b))
        oa = chain_fn(*sa, keys)
        ob = tree_fn(*sb, keys)
        for field in ("tokens", "n_commit", "n_acc", "target_logits",
                      "captures"):
            np.testing.assert_array_equal(
                np.asarray(oa[field]), np.asarray(ob[field]),
                err_msg=f"round {i} field {field}")
        for part in ("cache", "dcache"):
            eq = jax.tree.map(
                lambda x, y: bool((np.asarray(x) == np.asarray(y)).all()),
                oa[part], ob[part])
            assert all(jax.tree.leaves(eq)), (i, part, eq)
        sa = (oa["cache"], oa["dcache"], oa["carry"])
        sb = (ob["cache"], ob["dcache"], ob["carry"])


def test_tree_step_wider_never_shorter_greedy():
    """A wider greedy tree can only add accepted tokens: branch 0 IS
    the chain draft, so the longest root path is >= the chain prefix,
    round for round from the same state."""
    sa = sb = _spec_start()
    b = sa[2].tokens.shape[0]
    chain_fn, tree_fn = _step_fns(True, 3)
    keys = jax.vmap(lambda s: jax.random.fold_in(jax.random.key(7), s))(
        jnp.arange(b))
    for _ in range(3):
        oa = chain_fn(*sa, keys)
        ob = tree_fn(*sb, keys)
        assert (np.asarray(ob["n_acc"]) >= np.asarray(oa["n_acc"])).all()
        sa = (oa["cache"], oa["dcache"], oa["carry"])
        sb = (ob["cache"], ob["dcache"], ob["carry"])


# ================================== engine: tree streams == chain/dense
_ENGINES = {}


def _cached_engine(**kw):
    """Engines shared across tests (compile time dominates otherwise);
    ``reset_adaptation`` restores post-construction serving state."""
    key = tuple(sorted(kw.items()))
    eng = _ENGINES.get(key)
    if eng is None:
        cfg, params, dcfg, dparams = _get_model()
        config = ServingConfig(batch_size=2, max_len=96, gamma=3, seed=5,
                               **dict({"superstep_rounds": 4}, **kw))
        eng = _ENGINES[key] = ServingEngine(cfg, params, dcfg, dparams,
                                            config=config)
    eng.reset_adaptation(eng.dparams)
    eng.deploy_source = None
    return eng


def _requests(cfg, lens, budgets, seed=3):
    rng = np.random.default_rng(seed)
    return [Request(prompt=list(rng.integers(1, cfg.vocab_size, L)),
                    max_new_tokens=m) for L, m in zip(lens, budgets)]


def _streams(eng, reqs):
    eng.serve_stream(list(reqs))
    if eng.allocator is not None:
        eng.release_prefix_cache()
        eng.allocator.assert_clean()
    return {i: list(r.generated) for i, r in enumerate(reqs)}


def _parity_case(lens, budgets, seed, *, greedy=True, rounds=4,
                 page_size=0):
    cfg, *_ = _get_model()
    base_kw = dict(greedy=greedy, superstep_rounds=rounds,
                   page_size=page_size)
    chain = _streams(_cached_engine(**base_kw),
                     _requests(cfg, lens, budgets, seed=seed))
    tree = _streams(_cached_engine(tree_width=1, **base_kw),
                    _requests(cfg, lens, budgets, seed=seed))
    assert chain == tree


@pytest.mark.slow
@settings(max_examples=5)
@given(st.integers(0, 1), st.integers(0, 1), st.integers(0, 10 ** 6))
def test_tree_width1_stream_parity_property(greedy_idx, paged_idx, seed):
    """Property: for random prompt lengths, budgets, and decode modes,
    a width=1 tree engine emits byte-identical full streams to the
    chain engine, dense and paged."""
    rng = np.random.default_rng(seed)
    lens = [int(rng.integers(2, 40)) for _ in range(6)]
    budgets = [int(rng.integers(2, 9)) for _ in range(6)]
    _parity_case(lens, budgets, seed, greedy=bool(greedy_idx),
                 page_size=8 * paged_idx)


@pytest.mark.slow
@pytest.mark.parametrize("greedy", [True, False])
def test_tree_width1_stream_parity_stepwise(greedy):
    """The per-step reference loop (superstep_rounds=0) takes the
    stepwise dispatch path — same width=1 parity contract."""
    _parity_case([5, 30, 11, 23], [6, 4, 8, 5], seed=21, greedy=greedy,
                 rounds=0)


@pytest.mark.slow
@pytest.mark.parametrize("greedy", [True, False])
def test_tree_width2_paged_equals_dense(greedy):
    """Wider trees: paged streams byte-identical to dense, zero pages
    leaked after drain (rejected-branch scratch rows route through the
    trash page and never pin allocations)."""
    cfg, *_ = _get_model()
    lens, budgets = [5, 30, 11, 23, 8, 17], [6, 4, 8, 5, 7, 6]
    dense = _streams(_cached_engine(greedy=greedy, tree_width=2),
                     _requests(cfg, lens, budgets))
    paged = _streams(_cached_engine(greedy=greedy, tree_width=2,
                                    page_size=8),
                     _requests(cfg, lens, budgets))
    assert dense == paged
    assert [len(v) for v in dense.values()] == budgets


# ======================================================= config guards
def test_tree_check_rejects_non_attention_mixers():
    """Tree verification needs the tree-causal attention mask; linear
    recurrences (mamba) have no per-row mask to thread it through."""
    cfg = MIXER_CFGS["mamba"]
    with pytest.raises(ValueError, match="tree"):
        T.tree_check(cfg)
    params = T.init(cfg, jax.random.key(0))
    dcfg = eagle.draft_config(cfg)
    dparams = eagle.draft_init(dcfg, jax.random.key(1))
    with pytest.raises(ValueError, match="tree"):
        ServingEngine(cfg, params, dcfg, dparams,
                      config=ServingConfig(batch_size=2, max_len=96,
                                           tree_width=2))


def test_policy_owns_tree_shape():
    """The tree shape is a speculation-policy knob: the config seeds it
    through ``make_policy``, and an explicit policy wins over the
    config field (the learned-controller extension seam)."""
    assert ServingConfig(tree_width=3).make_policy().speculation \
        .tree_width == 3
    assert SpeculationPolicy(tree_width=2).tree_width == 2
    cfg, params, dcfg, dparams = _get_model()
    eng = _cached_engine(tree_width=2)
    assert eng.tree_width == 2
    assert eng.policy.speculation.tree_width == 2
