"""Config-surface totality: every ``ServingConfig`` knob must be
reachable from every front door.

Three surfaces expose the same knobs — the ``ServingConfig`` dataclass
(engine API), the flat ``TideConfig`` mirror (system API), and the
``launch/serve`` CLI flags — and they drift silently: adding a field to
``ServingConfig`` without a ``_SHARED_FIELDS`` entry or a flag leaves a
knob that exists but cannot be set from the system/CLI layer.  These
tests make the drift loud by asserting totality structurally, so the
failure message IS the checklist for wiring a new knob.

``completion_sink`` is the one exempt field: it is a host callback
handed to the engine by the system layer, not a serializable knob.

All checks are pure dataclass/argparse introspection — no models, no
jit — so the file runs in milliseconds in the fast tier.
"""
import dataclasses

from repro.core.tide import TideConfig
from repro.fleet import FleetConfig
from repro.launch import serve
from repro.serving.policy import ServingConfig

# host-callback field: not a knob, no flat mirror, no CLI flag
EXEMPT = {"completion_sink"}

SERVING_FIELDS = {f.name: f for f in dataclasses.fields(ServingConfig)}
KNOBS = {n: f for n, f in SERVING_FIELDS.items() if n not in EXEMPT}


def test_shared_fields_cover_every_serving_knob():
    shared = set(TideConfig._SHARED_FIELDS)
    missing = set(KNOBS) - shared
    assert not missing, (
        f"ServingConfig fields {sorted(missing)} have no TideConfig "
        f"flat mirror: add them to TideConfig._SHARED_FIELDS (and a "
        f"matching flat field)")
    stale = shared - set(KNOBS)
    assert not stale, (
        f"TideConfig._SHARED_FIELDS names {sorted(stale)} which are "
        f"not ServingConfig fields")


def test_flat_mirror_defaults_match_serving_defaults():
    """The mirror logic only forwards flat values, so a flat default
    that drifts from the serving default would silently override an
    explicit ``serving=``-side choice (or vice versa)."""
    tide_fields = {f.name: f for f in dataclasses.fields(TideConfig)}
    for name in TideConfig._SHARED_FIELDS:
        sf, tf = SERVING_FIELDS[name], tide_fields[name]
        assert sf.default == tf.default, (
            f"default mismatch for {name}: ServingConfig={sf.default!r} "
            f"TideConfig={tf.default!r}")


def test_flat_fields_mirror_into_serving():
    """Setting the flat TideConfig field lands on tc.serving.<field>."""
    probe = {"gamma": 5, "batch_size": 7, "max_len": 320, "greedy": False,
             "superstep_rounds": 3, "eos_id": 9, "ema": 0.5, "seed": 13,
             "admission": "deadline", "commit": "eager",
             "admission_lookahead": 17, "gate_arrivals": True,
             "idle_wait_s": 0.25, "prefill_chunk": 16, "page_size": 8,
             "num_pages": 40, "share_prefix": False,
             "spec_park_patience": 6, "spec_probe_interval": 4,
             "tree_width": 2, "reseed_window": 8, "trainer_threads": 2,
             "preempt": "deadline", "shed": "expired",
             "shed_queue_depth": 9}
    assert set(probe) == set(TideConfig._SHARED_FIELDS), (
        "probe table out of date: update it alongside _SHARED_FIELDS")
    for name, value in probe.items():
        tc = TideConfig(**{name: value})
        assert getattr(tc.serving, name) == value, name
        # and back: an explicit serving= config populates the flat view
        tc2 = TideConfig(serving=ServingConfig(**{name: value}))
        assert getattr(tc2, name) == value, name


def test_serve_flags_cover_every_serving_knob():
    """Every knob must be settable from the launch/serve CLI: parse a
    known argv per field and assert it lands on the assembled
    ServingConfig.  The table's key set is pinned to the field set, so
    a new field fails here until it grows a flag AND a table row."""
    flag_cases = {
        "gamma": (["--gamma", "5"], 5),
        "batch_size": (["--batch", "7"], 7),
        "max_len": (["--max-len", "320"], 320),
        "greedy": (["--sample"], False),
        "superstep_rounds": (["--superstep-rounds", "3"], 3),
        "eos_id": (["--eos-id", "9"], 9),
        "ema": (["--accept-ema", "0.5"], 0.5),
        "seed": (["--seed", "13"], 13),
        "admission": (["--policy", "deadline"], "deadline"),
        "commit": (["--commit", "eager"], "eager"),
        "admission_lookahead": (["--admission-lookahead", "17"], 17),
        "gate_arrivals": (["--gate-arrivals"], True),
        "idle_wait_s": (["--idle-wait-s", "0.25"], 0.25),
        "prefill_chunk": (["--prefill-chunk", "16"], 16),
        "page_size": (["--page-size", "8"], 8),
        "num_pages": (["--num-pages", "40"], 40),
        "share_prefix": (["--no-share-prefix"], False),
        "spec_park_patience": (["--spec-park", "6"], 6),
        "spec_probe_interval": (["--spec-probe-interval", "4"], 4),
        "tree_width": (["--tree-width", "2"], 2),
        "reseed_window": (["--reseed-window", "8"], 8),
        "trainer_threads": (["--trainer-threads", "2"], 2),
        "preempt": (["--preempt", "deadline"], "deadline"),
        "shed": (["--shed", "expired"], "expired"),
        "shed_queue_depth": (["--shed-queue-depth", "9"], 9),
    }
    missing = set(KNOBS) - set(flag_cases)
    assert not missing, (
        f"ServingConfig fields {sorted(missing)} have no launch/serve "
        f"flag case: add the flag to serve.build_parser, wire it in "
        f"serve.config_from_args, and add a row here")
    stale = set(flag_cases) - set(KNOBS)
    assert not stale, f"flag cases for non-fields: {sorted(stale)}"
    parser = serve.build_parser()
    for name, (argv, expected) in flag_cases.items():
        scfg = serve.config_from_args(parser.parse_args(argv))
        assert getattr(scfg, name) == expected, (
            f"flag {argv} did not land on ServingConfig.{name}")


def test_fleet_flags_cover_every_fleet_knob():
    """Same totality contract for the disaggregation surface: every
    ``FleetConfig`` field needs a launch/serve flag that lands on the
    assembled config (``fleet_config_from_args``).  The table's key set
    is pinned to the field set, so a new fleet knob fails here until it
    grows a flag AND a row."""
    fleet_fields = {f.name for f in dataclasses.fields(FleetConfig)}
    flag_cases = {
        "replicas": (["--fleet-replicas", "4"], 4),
        "trainer_endpoint": (["--trainer-endpoint", "unix:/tmp/t.sock"],
                             "unix:/tmp/t.sock"),
        "route": (["--fleet-replicas", "2", "--fleet-route", "rr"], "rr"),
    }
    missing = fleet_fields - set(flag_cases)
    assert not missing, (
        f"FleetConfig fields {sorted(missing)} have no launch/serve flag "
        f"case: add the flag to serve.build_parser, wire it in "
        f"serve.fleet_config_from_args, and add a row here")
    stale = set(flag_cases) - fleet_fields
    assert not stale, f"flag cases for non-fields: {sorted(stale)}"
    parser = serve.build_parser()
    for name, (argv, expected) in flag_cases.items():
        fc = serve.fleet_config_from_args(parser.parse_args(argv))
        assert fc is not None and getattr(fc, name) == expected, (
            f"flag {argv} did not land on FleetConfig.{name}")


def test_fleet_flags_default_to_no_fleet():
    """Bare argv must not build a FleetConfig (single engine,
    in-process trainer — the byte-pinned legacy topology), and
    TideConfig carries the same default."""
    args = serve.build_parser().parse_args([])
    assert serve.fleet_config_from_args(args) is None
    assert TideConfig().fleet is None


def test_serve_flag_defaults_assemble_serving_defaults():
    """Bare argv builds the default config (modulo the documented
    context-dependent fields: max_len auto-sizes by serving mode and
    reseed_window by training mode)."""
    scfg = serve.config_from_args(serve.build_parser().parse_args([]))
    context_dependent = {"max_len", "reseed_window"}
    for name in KNOBS:
        if name in context_dependent:
            continue
        assert getattr(scfg, name) == SERVING_FIELDS[name].default, name
