"""Chunked refill prefill: the parity/property test tier.

The chunk pipeline's load-bearing invariant is **byte parity**: splitting
a refill's prompt prefill into chunks that interleave with resident
supersteps must change *when* work happens, never *what* is computed —
chunked == one-shot bitwise on the target KV cache lanes, the draft
cache lanes, the first sampled token, and the full emitted stream
(greedy and per-request-keyed sampled).  Two engine-design choices make
this exact rather than approximate, both pinned here:

  * continuation chunks run through the decode path, whose per-position
    projections/attention are bitwise width-stable on this backend (the
    one-shot prefill computes the identical values at a different
    sequence width), and
  * the draft's 3D→D capture fuse is computed as a sum of three
    D-contraction matmuls (``eagle._fuse_inputs``) because a single
    3D-wide contraction tiles differently per row count and would break
    draft-cache parity in ulps.

Batch width is *not* bitwise-stable (ulp-level), so op-level tests
compare at equal refill-batch width — the same robustness contract the
existing refill==serving-alone tests already rely on for argmax /
per-request-keyed categorical sampling.

All tests here run on randomly initialized weights (parity is a property
of the computation, not the model), so the file stays in the fast tier.
"""
import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # not in the container image - deterministic shim
    from _hypothesis_shim import given, settings, strategies as st

import repro.configs as C
from repro.core import eagle
from repro.models import transformer as T
from repro.serving.engine import ServingEngine
from repro.serving.request import Request
from repro.serving.scheduler import Scheduler
from repro.serving.stats import Peak


_MODEL = None


def _get_model():
    """Lazily-built module model (plain function, not a fixture, so the
    hypothesis-shim property tests — whose wrapper hides the original
    signature from pytest — can reach it too)."""
    global _MODEL
    if _MODEL is None:
        cfg = C.get("tide-tiny")
        params = T.init(cfg, jax.random.key(0))
        dcfg = eagle.draft_config(cfg)
        dparams = eagle.draft_init(dcfg, jax.random.key(7))
        _MODEL = (cfg, params, dcfg, dparams)
    return _MODEL


@pytest.fixture(scope="module")
def model():
    return _get_model()


def _engine(model, *, rounds=8, chunk=0, greedy=True, batch=4, max_len=96,
            seed=5, **kw):
    cfg, params, dcfg, dparams = model
    return ServingEngine(cfg, params, dcfg, dparams, batch_size=batch,
                         max_len=max_len, gamma=3, seed=seed, greedy=greedy,
                         superstep_rounds=rounds, prefill_chunk=chunk, **kw)


_ENGINES = {}


def _cached_engine(**kw):
    """Engines are shared across tests (jit caches stay warm — compile
    time dominates this file otherwise); ``reset_adaptation`` restores
    the post-construction serving state between uses."""
    key = tuple(sorted(kw.items()))
    eng = _ENGINES.get(key)
    if eng is None:
        eng = _ENGINES[key] = _engine(_get_model(), **kw)
    eng.reset_adaptation(eng.dparams)
    eng.deploy_source = None
    return eng


def _requests(cfg, lens, budgets, seed=3):
    rng = np.random.default_rng(seed)
    return [Request(prompt=list(rng.integers(1, cfg.vocab_size, L)),
                    max_new_tokens=m) for L, m in zip(lens, budgets)]


def _run_pipeline(eng, admitted):
    """Drive one chunk pipeline to completion, as the stream loop would
    (one advance call per gap), and return its staging state."""
    pl = eng._make_pipeline(admitted)
    while not pl.done:
        eng._advance_pipeline(pl)
    return pl


def _valid_region_equal(one_shot, chunked, pad, lengths, seq_axis):
    """Bitwise equality on the per-lane valid region [pad_b, lengths_b)
    along ``seq_axis`` (the masked left-pad region holds
    width-dependent garbage by design — it is never read)."""
    # buffer widths may differ (staging caches are prompt-width, the
    # one-shot reference max_len-width); only the valid region matters
    a, b = np.asarray(one_shot), np.asarray(chunked)
    pos = np.arange(min(a.shape[seq_axis], b.shape[seq_axis]))
    for lane in range(len(pad)):
        sel = np.nonzero((pos >= pad[lane]) & (pos < lengths[lane]))[0]
        av = np.take(np.take(a, sel, axis=seq_axis), lane,
                     axis=seq_axis - 1)
        bv = np.take(np.take(b, sel, axis=seq_axis), lane,
                     axis=seq_axis - 1)
        if not np.array_equal(av, bv):
            return False
    return True


# ------------------------------------------------------ op-level parity
@pytest.mark.slow
@settings(max_examples=5)
@given(st.integers(1, 4), st.integers(0, 10 ** 6))
def test_chunked_refill_op_parity(chunk_idx, seed):
    """Property: for random prompt lengths and chunk sizes, the chunk
    pipeline's staging caches, last-position logits, and first token
    (greedy *and* per-request-keyed sampled) are bitwise identical to a
    one-shot refill prefill of the same batch."""
    model = _get_model()
    cfg, params, dcfg, dparams = model
    chunk = 8 * chunk_idx
    rng = np.random.default_rng(seed)
    lens = [int(rng.integers(2, 57)) for _ in range(4)]
    reqs = _requests(cfg, lens, [8] * 4, seed=seed)
    for i, r in enumerate(reqs):
        r.sid = i
    admitted = list(enumerate(reqs))

    eng = _cached_engine(chunk=chunk)
    pl = _run_pipeline(eng, admitted)

    # one-shot reference: same padded shapes (_refill_arrays), the
    # prefill + draft-seed exactly as the legacy _refill_core runs them
    toks, pad, _, _, _, sids = eng._refill_arrays(admitted)
    pre = eng._prefill_fn(params, toks, pad)
    rdc = jax.jit(lambda c, t, p: eagle.seed_refill_cache(
        dcfg, dparams, params["embed"], c, t, p, eng.max_len))(
            pre["captures"], toks, pad)

    pad_np = np.asarray(pad)
    width = toks.shape[1]
    assert pl.width == width
    # target KV lanes, all stacked layer groups (leaves are (R, B, S, ...))
    for key in ("k", "v"):
        assert _valid_region_equal(
            pre["cache"]["body"]["pos0"][key],
            pl.cache["body"]["pos0"][key],
            pad_np, [width] * 4, seq_axis=2), \
            f"target {key} lanes diverged (chunk={chunk}, lens={lens})"
    assert np.array_equal(np.asarray(pre["cache"]["lengths"]),
                          np.asarray(pl.cache["lengths"]))
    # draft cache lanes (batch at axis 0, seq at axis 1)
    dlen = np.asarray(rdc["lengths"])
    assert np.array_equal(dlen, np.asarray(pl.dcache["lengths"]))
    for key in ("k", "v"):
        assert _valid_region_equal(rdc[key], pl.dcache[key], pad_np, dlen,
                                   seq_axis=1), \
            f"draft {key} lanes diverged (chunk={chunk}, lens={lens})"
    # last-position logits and both first-token flavours
    assert np.array_equal(np.asarray(pre["logits"]), np.asarray(pl.logits))
    assert np.array_equal(np.asarray(pre["captures"][:, -1]),
                          np.asarray(pl.caps_last))
    assert np.array_equal(np.asarray(pre["logits"].argmax(-1)),
                          np.asarray(pl.logits.argmax(-1)))
    s1 = eng._pick_sampled_fn(pre["logits"], sids)
    s2 = eng._pick_sampled_fn(pl.logits, sids)
    assert np.array_equal(np.asarray(s1), np.asarray(s2))


def test_chunk_sizes_agree_bitwise(model):
    """Any two chunk sizes produce bitwise-identical staging state (both
    equal the one-shot values; this pins them against each other
    directly, including the ragged-first-chunk alignment)."""
    cfg, params, dcfg, dparams = model
    lens = [40, 9, 22, 13]
    reqs = _requests(cfg, lens, [8] * 4)
    for i, r in enumerate(reqs):
        r.sid = i
    admitted = list(enumerate(reqs))
    pls = {}
    for chunk in (8, 16, 32):
        pls[chunk] = _run_pipeline(_cached_engine(chunk=chunk), admitted)
    ref = pls[8]
    for chunk in (16, 32):
        pl = pls[chunk]
        assert np.array_equal(np.asarray(ref.logits),
                              np.asarray(pl.logits))
        pad_np = np.asarray(ref.pad)
        dlen = np.asarray(ref.dcache["lengths"])
        for key in ("k", "v"):
            assert _valid_region_equal(
                ref.cache["body"]["pos0"][key],
                pl.cache["body"]["pos0"][key],
                pad_np, [ref.width] * 4, seq_axis=2)
            assert _valid_region_equal(ref.dcache[key], pl.dcache[key],
                                       pad_np, dlen, seq_axis=1)


# --------------------------------------------------- stream-level parity
BUDGETS = (5, 12, 7, 9, 11, 4, 8, 6)
LENS = (40, 9, 22, 13, 55, 8, 17, 30)   # covers < chunk, == chunk
#                                         multiple, and multi-chunk


def _serve(model, *, rounds, chunk, greedy, budgets=BUDGETS, lens=LENS,
           wave=None, deploy_source=None, **kw):
    cfg = model[0]
    reqs = _requests(cfg, lens, budgets)
    eng = _cached_engine(rounds=rounds, chunk=chunk, greedy=greedy, **kw)
    if deploy_source is not None:
        eng.deploy_source = deploy_source
    if wave:
        for i in range(0, len(reqs), wave):
            eng.serve_wave(reqs[i:i + wave])
    else:
        eng.serve_stream(list(reqs))
    return [list(r.generated) for r in reqs], eng, reqs


@pytest.mark.slow
@pytest.mark.parametrize("greedy", [True, False])
def test_chunked_stream_matches_one_shot(model, greedy):
    """Full emitted streams, chunked vs legacy one-shot refill: byte
    identical — greedy and per-request-keyed sampled.  (chunk=32
    engine-level streams are additionally pinned by the slow-tier
    long-prompt invariance test in test_continuous.py.)"""
    ref, e_ref, _ = _serve(model, rounds=8, chunk=0, greedy=greedy)
    for chunk in ((16,) if greedy else (16, 32)):
        out, eng, reqs = _serve(model, rounds=8, chunk=chunk, greedy=greedy)
        assert out == ref, f"chunk={chunk} greedy={greedy} diverged"
        assert all(r.finish_t is not None for r in reqs)
        assert eng.stats.tokens_out == sum(len(g) for g in out)
        # the pipeline bounded every prefill op by the chunk width
        assert eng.stats.prefill_op_width.max <= chunk
        assert eng.stats.prefill_chunks > eng.stats.refills / 2
    assert e_ref.stats.prefill_op_width.max >= max(LENS)


@pytest.mark.slow
def test_chunked_stepwise_matches_superstep(model):
    """The per-step reference loop with chunking emits the same streams
    as the fused superstep with chunking."""
    ss, _, _ = _serve(model, rounds=8, chunk=16, greedy=True)
    step, _, _ = _serve(model, rounds=0, chunk=16, greedy=True)
    assert step == ss


def test_chunked_wave_matches_stream_with_stats(model):
    """``serve_wave`` on a chunked engine routes through the same chunk
    pipelines (legacy callers cannot silently bypass chunking): same
    streams AND the same serving stats as the equivalent stream."""
    out_w, e_w, _ = _serve(model, rounds=8, chunk=16, greedy=True,
                           budgets=BUDGETS[:4], lens=LENS[:4], wave=4)
    out_s, e_s, _ = _serve(model, rounds=8, chunk=16, greedy=True,
                           budgets=BUDGETS[:4], lens=LENS[:4])
    assert out_w == out_s
    for attr in ("tokens_out", "steps", "dispatches", "refills",
                 "prefill_chunks", "prefill_row_tokens", "completed"):
        assert getattr(e_w.stats, attr) == getattr(e_s.stats, attr), attr
    assert e_w.stats.prefill_op_width.max == e_s.stats.prefill_op_width.max
    # chunking engaged for the wave prologue too
    assert e_w.stats.prefill_op_width.max <= 16
    assert e_w.stats.prefill_chunks > 0


@pytest.mark.slow
def test_chunked_serving_alone_parity(model):
    """Every refilled request under chunking matches serving it alone on
    a fresh chunked batch-1 engine (greedy scheduling invariance)."""
    out, eng, reqs = _serve(model, rounds=8, chunk=16, greedy=True)
    alone = _cached_engine(chunk=16, batch=1)
    for req in reqs[eng.batch:]:
        solo = Request(prompt=list(req.prompt),
                       max_new_tokens=req.max_new_tokens)
        alone.serve_wave([solo])
        assert solo.generated == req.generated


# ------------------------------------------------------------ edge cases
def test_zero_budget_admitted_mid_chunk(model):
    """A zero-budget request admitted while a long prompt is mid-chunk:
    finishes with no tokens, without disturbing neighbouring streams."""
    lens = (55, 8, 8, 8, 9, 10)
    budgets = (12, 3, 4, 3, 0, 6)
    out, eng, reqs = _serve(model, rounds=8, chunk=16, greedy=True,
                            budgets=budgets, lens=lens)
    assert reqs[4].generated == [] and reqs[4].finish_t is not None
    ref, _, _ = _serve(model, rounds=8, chunk=0, greedy=True,
                       budgets=budgets, lens=lens)
    assert out == ref


@pytest.mark.slow
def test_eos_on_first_post_prefill_token(model):
    """EOS as the first token sampled at a pipeline commit: one-token
    stream, immediate finish, slot refilled — chunked == one-shot."""
    lens, budgets = LENS[:6], (6,) * 6
    probe, _, _ = _serve(model, rounds=8, chunk=16, greedy=True,
                         budgets=budgets, lens=lens)
    eos = probe[4][0]   # request 4 is a refill (batch=4): its first
    #                     token commits at a pipeline commit mid-stream
    outs = {}
    for chunk in (0, 16):
        out, eng, reqs = _serve(model, rounds=8, chunk=chunk, greedy=True,
                                budgets=budgets, lens=lens, eos_id=eos)
        outs[chunk] = out
        for r in reqs:
            assert r.done and eos not in r.generated[:-1]
        assert eng.stats.tokens_out == sum(len(g) for g in out)
    assert outs[16] == outs[0]
    assert any(g == [eos] for g in outs[16]), \
        "expected at least one first-token-EOS stream in the probe set"


@pytest.mark.slow
def test_deploy_reseed_lands_mid_prefill(model):
    """A draft deploy (with reseed ring) arriving while lanes are
    mid-prefill must neither crash nor change greedy streams (greedy
    speculative decoding is draft-invariant)."""
    cfg, params, dcfg, dparams = model

    class _Ver:
        def __init__(self, seq, dparams):
            self.seq, self.dparams, self.eval_acc = seq, dparams, 0.0

    new_draft = eagle.draft_init(dcfg, jax.random.key(99))
    calls = {"n": 0}

    def deploy_source():
        calls["n"] += 1
        # publish once, early — while the first long-prompt pipeline is
        # still chunking
        return _Ver(1, new_draft) if calls["n"] >= 2 else None

    ref, _, _ = _serve(model, rounds=8, chunk=16, greedy=True)
    out, eng, _ = _serve(model, rounds=8, chunk=16, greedy=True,
                         deploy_source=deploy_source, reseed_window=12)
    assert out == ref, "deploy mid-prefill changed greedy streams"
    assert eng.stats.deploys == 1 and eng.stats.reseeds == 1


# ---------------------------------------------------- scheduler grouping
def test_refill_groups_partition():
    reqs = [Request(prompt=[1] * n, max_new_tokens=4)
            for n in (3, 9, 40, 12, 33)]
    admitted = list(enumerate(reqs))
    groups = Scheduler.refill_groups(admitted, 16)
    # buckets: 8, 16, 40, 16, 40 -> three groups, FIFO order kept inside
    assert sorted(len(g) for g in groups) == [1, 2, 2]
    flat = [slot for g in groups for slot, _ in g]
    assert sorted(flat) == [0, 1, 2, 3, 4]
    for g in groups:
        widths = {max(8, -(-len(r.prompt) // 8) * 8) for _, r in g}
        assert len(widths) == 1, "group mixes padded-width buckets"
    # disabled chunking: one legacy group
    assert Scheduler.refill_groups(admitted, 0) == [admitted]
    assert Scheduler.refill_groups([], 16) == []


def test_peak_tracker():
    p = Peak()
    assert p.max == 0 and p.mean == 0 and p.n == 0
    for x in (4, 9, 2):
        p.add(x)
    assert p.max == 9 and p.n == 3 and abs(p.mean - 5.0) < 1e-9


def test_ttft_clock_starts_at_admission(model):
    """Admission stamps ``admit_t``; TTFT is measured from it (>= 0 and
    never larger than the arrival-based latency)."""
    out, eng, reqs = _serve(model, rounds=8, chunk=16, greedy=True)
    for r in reqs:
        assert r.admit_t is not None and r.admit_t >= r.arrival_t
        assert r.ttft is not None and r.ttft >= 0.0
        assert r.ttft <= r.latency
