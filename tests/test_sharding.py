"""Sharding rule table: divisibility auto-drop, axis-reuse protection,
cache/param tree alignment (hypothesis property tests)."""
import jax
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # not in the container image - deterministic shim
    from _hypothesis_shim import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.launch import sharding as sh
from repro.launch.mesh import make_demo_mesh


def _amesh(sizes, names):
    # fake abstract mesh: axis *names* drive the rule logic
    try:
        return jax.sharding.AbstractMesh(sizes, names)
    except TypeError:   # jax<=0.4.x signature: ((name, size), ...)
        return jax.sharding.AbstractMesh(tuple(zip(names, sizes)))


def _mesh_2d():
    # 1 real device, but axis *names* drive the rule logic; use a fake
    # abstract mesh for spec computation via jax.sharding.AbstractMesh
    return _amesh((16, 16), ("data", "model"))


def test_spec_basic_rules():
    mesh = _mesh_2d()
    spec = sh.spec_for((256, 4096), ("batch", None), mesh, sh.BASE_RULES)
    assert spec == P("data", None)      # no pod axis in this mesh
    spec = sh.spec_for((4096, 14336), ("embed", "mlp"), mesh,
                       sh.BASE_RULES)
    assert spec == P("data", "model")


def test_spec_divisibility_autodrop():
    mesh = _mesh_2d()
    # 40 experts don't divide 16 -> replicate
    spec = sh.spec_for((40, 64, 64), ("experts", "embed", "mlp"), mesh,
                       sh.BASE_RULES)
    assert spec[0] is None
    # batch 8 divides 16? no -> drop ("pod","data")->("pod")->none
    spec = sh.spec_for((8,), ("batch",), mesh, sh.BASE_RULES)
    assert spec == P(None)


def test_spec_axis_reuse_protection():
    mesh = _mesh_2d()
    # two dims both wanting "model": second one must drop
    spec = sh.spec_for((64, 64), ("mlp", "vocab"), mesh, sh.BASE_RULES)
    assert spec == P("model", None)


def test_multi_pod_batch_rule():
    mesh = _amesh((2, 16, 16), ("pod", "data", "model"))
    spec = sh.spec_for((256,), ("batch",), mesh, sh.BASE_RULES)
    assert spec == P(("pod", "data"))
    # batch=1 (long_500k) -> fully replicated
    spec = sh.spec_for((1,), ("batch",), mesh, sh.BASE_RULES)
    assert spec == P(None)


@given(st.integers(1, 4096), st.integers(1, 64))
@settings(max_examples=60, deadline=None)
def test_autodrop_always_divides(dim, other):
    """Whatever sharding is chosen, the dim must be divisible by the
    total shards (NamedSharding validity invariant)."""
    mesh = _amesh((2, 16, 16), ("pod", "data", "model"))
    for rules in (sh.BASE_RULES, sh.EXPERT_PARALLEL_RULES,
                  sh.LONG_CONTEXT_RULES):
        spec = sh.spec_for((dim, other), ("batch", "kv_seq"), mesh, rules)
        sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
        for d, entry in zip((dim, other), spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = int(np.prod([sizes[a] for a in axes]))
            assert d % total == 0


def test_param_tree_sharding_alignment():
    """Every param leaf gets a sharding and they lower on a 1-device
    mesh (structure check with real NamedSharding)."""
    import repro.configs as C
    from repro.models import param as P_
    from repro.models import transformer as T
    cfg = C.get_reduced("jamba-1.5-large-398b")
    specs = T.param_specs(cfg)
    ab = P_.abstract_params(specs)
    axes = P_.logical_axes(specs)
    mesh = make_demo_mesh()
    shardings = sh.logical_to_sharding(ab, axes, mesh)
    assert jax.tree.structure(shardings) == jax.tree.structure(ab)


def test_cache_axes_structure_matches():
    import repro.configs as C
    from repro.models import transformer as T
    for arch in ("jamba-1.5-large-398b", "whisper-base",
                 "deepseek-v3-671b"):
        cfg = C.get_reduced(arch)
        cache_ab = T.cache_abstract(cfg, 2, 32, 8)
        axes = T.cache_axes(cfg)
        mesh = make_demo_mesh()
        shardings = sh.logical_to_sharding(cache_ab, axes, mesh)
        assert jax.tree.structure(shardings) == \
            jax.tree.structure(cache_ab)
