"""Policy-driven serving control plane (serving/policy.py).

Covers the api_redesign checklist: admission-policy ordering units
(EDF, priority, FIFO ties), the admission-order permutation property
(any admission policy leaves greedy per-request streams byte-identical
— scheduling may change *when* a request is served, never *what* it
generates), eager-vs-cohort commit (round counts + deterministic
short-prompt TTFT), speculation park/resume via the acceptance probe,
deprecated-kwarg shims (byte parity with the new default
``ServingPolicy``), the unified ``ServingConfig`` plumbing through
``TideConfig``, and the ``trainer_threads`` contention knob.

Everything here runs on randomly initialized weights (policy behavior
is a property of the control plane, not the model), so the file stays
in the fast tier; the pretrained-fixture end-to-end parity suite at
the bottom carries the ``slow`` marker (see ROADMAP test tiers).
"""

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # not in the container image - deterministic shim
    from _hypothesis_shim import given, settings, strategies as st

import repro.configs as C
from repro.core import eagle
from repro.core.adaptive import AdaptiveDrafter, LatencyProfile
from repro.models import transformer as T
from repro.serving.engine import ServingEngine
from repro.serving.policy import (CohortCommit, DeadlineAdmission,
                                  EagerCommit, FifoAdmission,
                                  PriorityAdmission, ServingConfig,
                                  ServingPolicy, SpeculationPolicy)
from repro.serving.request import Request
from repro.serving.scheduler import Scheduler

_MODEL = None


def _get_model():
    global _MODEL
    if _MODEL is None:
        cfg = C.get("tide-tiny")
        params = T.init(cfg, jax.random.key(0))
        dcfg = eagle.draft_config(cfg)
        dparams = eagle.draft_init(dcfg, jax.random.key(7))
        _MODEL = (cfg, params, dcfg, dparams)
    return _MODEL


@pytest.fixture(scope="module")
def model():
    return _get_model()


_ENGINES = {}


def _cached_engine(**cfg_kw):
    """One engine per ServingConfig variant (compiles stay warm across
    tests and property examples); ``reset_adaptation`` restores the
    post-construction state between uses."""
    key = tuple(sorted(cfg_kw.items()))
    eng = _ENGINES.get(key)
    if eng is None:
        cfg, params, dcfg, dparams = _get_model()
        scfg = ServingConfig(batch_size=4, max_len=96, gamma=3, seed=5,
                             **cfg_kw)
        eng = _ENGINES[key] = ServingEngine(cfg, params, dcfg, dparams,
                                            config=scfg)
    eng.reset_adaptation(eng.dparams)
    eng.deploy_source = None
    return eng


def _requests(lens, budgets, seed=3, deadlines=None, prios=None):
    cfg = _get_model()[0]
    rng = np.random.default_rng(seed)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab_size, L)),
                    max_new_tokens=m) for L, m in zip(lens, budgets)]
    if deadlines is not None:
        for r, d in zip(reqs, deadlines):
            r.deadline = d
    if prios is not None:
        for r, p in zip(reqs, prios):
            r.priority = p
    return reqs


# ================================================= admission ordering
def test_edf_ordering():
    """EDF admits earliest deadline first; None sorts last; deadline
    ties break on priority then FIFO order."""
    reqs = _requests([4] * 5, [2] * 5,
                     deadlines=[9.0, 1.0, None, 1.0, 4.0])
    reqs[3].priority = 1     # deadline tie with reqs[1] — priority wins
    s = Scheduler(2, reqs, policy=DeadlineAdmission())
    adm = s.admit()
    assert [r.rid for _, r in adm] == [reqs[3].rid, reqs[1].rid]
    for _, r in adm:
        r.finish()
    s.release_finished()
    adm2 = s.admit()
    assert [r.rid for _, r in adm2] == [reqs[4].rid, reqs[0].rid]
    for _, r in adm2:
        r.finish()
    s.release_finished()
    assert [r.rid for _, r in s.admit()] == [reqs[2].rid]   # None last


def test_priority_ordering_ties_fifo():
    reqs = _requests([4] * 4, [2] * 4, prios=[0, 2, 1, 2])
    s = Scheduler(1, reqs, policy=PriorityAdmission())
    order = []
    while s.has_pending():
        (slot, r), = s.admit()
        order.append(r.rid)
        r.finish()
        s.release_finished()
    assert order == [reqs[1].rid, reqs[3].rid, reqs[2].rid, reqs[0].rid]


def test_fifo_ignores_slo_annotations():
    """The default policy admits in arrival order no matter the
    annotations (SLO fields are free to carry everywhere)."""
    reqs = _requests([4] * 3, [2] * 3, deadlines=[1.0, 0.1, 0.5],
                     prios=[0, 9, 3])
    s = Scheduler(3, reqs)     # default FifoAdmission
    assert [r.rid for _, r in s.admit()] == [r.rid for r in reqs]


def test_reorder_policies_bound_materialization():
    """A reordering policy's lookahead window bounds how much of an
    unbounded stream is materialized."""
    pulled = []

    def gen():
        for i in range(100):
            pulled.append(i)
            yield Request(prompt=[1, 2], max_new_tokens=2)

    s = Scheduler(2, gen(), policy=PriorityAdmission(lookahead=4))
    s.admit()
    assert len(pulled) <= 6, "lookahead must bound the queue pull"


def test_edf_gated_arrivals_skip_unarrived():
    """Unlike strict-FIFO gating, EDF admits any *arrived* candidate —
    an unarrived head must not block an arrived tight-deadline one."""
    now = {"t": 0.0}
    reqs = [Request(prompt=[1, 2], max_new_tokens=2, arrives_at=t)
            for t in (5.0, 0.0)]
    reqs[1].deadline = 1.0
    s = Scheduler(1, reqs, policy=DeadlineAdmission(),
                  gate_arrivals=True, clock=lambda: now["t"])
    assert s.has_pending()
    (slot, r), = s.admit()
    assert r.rid == reqs[1].rid
    assert s.next_arrival_in() == pytest.approx(5.0)


# ============================== admission-order stream invariance
@pytest.mark.slow
@settings(max_examples=4)
@given(st.integers(0, 10 ** 6))
def test_admission_permutation_stream_invariance(seed):
    """Property: under greedy decoding, ANY admission-order permutation
    (fifo / priority / deadline over random annotations) leaves every
    request's emitted token stream byte-identical — the load-bearing
    invariant that makes scheduling policy a pure performance knob."""
    rng = np.random.default_rng(seed)
    n = 7
    lens = [int(rng.integers(3, 20)) for _ in range(n)]
    budgets = [int(rng.integers(2, 12)) for _ in range(n)]
    deadlines = [float(rng.uniform(0, 50)) if rng.random() < 0.7 else None
                 for _ in range(n)]
    prios = [int(rng.integers(0, 4)) for _ in range(n)]

    streams = {}
    for name in ("fifo", "priority", "deadline"):
        eng = _cached_engine(admission=name)
        reqs = _requests(lens, budgets, seed=seed, deadlines=deadlines,
                         prios=prios)
        eng.serve_stream(reqs)
        streams[name] = [list(r.generated) for r in reqs]
        assert all(r.finish_t is not None for r in reqs)
    assert streams["priority"] == streams["fifo"], \
        f"priority admission changed a stream (seed={seed})"
    assert streams["deadline"] == streams["fifo"], \
        f"EDF admission changed a stream (seed={seed})"


# ======================================= commit policy: eager vs cohort
@pytest.mark.slow
def test_eager_vs_cohort_commit(model):
    """A short prompt co-admitted (mid-decode) with a long-tail sibling:
    cohort commit holds its lane until the long pipeline finishes —
    eager activates it as soon as its own chunk is staged — so the
    short's deterministic TTFT (rounds from admission) must drop under
    eager, at an executed-round-density cost.  Streams are
    byte-identical either way (greedy scheduling invariance).

    The scenario keeps two big-budget residents decoding while two
    early-retiring lanes free up for the mixed [long, short] refill —
    the refill must land mid-decode, because with no resident decoding
    both policies run chunks back-to-back to the next commit and the
    distinction vanishes (the cold-start fast path)."""
    lens = [6, 7, 5, 8, 72, 6, 9, 10]
    budgets = [40, 40, 8, 8, 6, 6, 6, 6]
    out = {}
    for commit in ("cohort", "eager"):
        eng = _cached_engine(prefill_chunk=16, commit=commit)
        reqs = _requests(lens, budgets)
        eng.serve_stream(reqs)
        short = reqs[5]           # co-admitted with the 72-token prompt
        out[commit] = ([list(r.generated) for r in reqs],
                       short.first_token_round - short.admit_round,
                       eng.stats.steps)
    assert out["eager"][0] == out["cohort"][0], \
        "commit policy changed per-request streams"
    assert out["eager"][1] < out["cohort"][1], \
        "eager commit did not improve the co-admitted short prompt's " \
        f"TTFT rounds (eager {out['eager'][1]} vs cohort " \
        f"{out['cohort'][1]})"
    assert out["eager"][2] >= out["cohort"][2], \
        "cohort commit lost its round-density advantage"


def test_commit_policy_refill_groups_delegation():
    """CommitPolicy.refill_groups defaults to the scheduler's per-width
    bucketing (the grouping the chunk pipelines are built from)."""
    reqs = _requests([6, 40, 7, 38], [4] * 4)
    admitted = list(enumerate(reqs))
    for pol in (CohortCommit(), EagerCommit()):
        groups = pol.refill_groups(admitted, 16)
        assert groups == Scheduler.refill_groups(admitted, 16)
    assert CohortCommit().cohort and not EagerCommit().cohort


# ===================================== speculation park / resume probe
# threshold ≈ 2.0 at every batch size: with a near-zero-acceptance
# draft the EMA decays below it and the Eq. 5 gate turns speculation
# off — the latch-off state the park control exists for
_FLAT_PROFILE = LatencyProfile([1, 2, 4, 8], [1.0, 1.0, 1.0, 1.0],
                               d0_ms=0.33)


def test_park_resume_unit():
    pol = SpeculationPolicy(AdaptiveDrafter(_FLAT_PROFILE, gamma=3),
                            park_patience=3, probe_interval=4)
    pol.prepare(4)
    gate, park, probe = pol._tables
    assert pol.dispatch_table() is gate
    # three consecutive gated-off rounds -> parked
    for _ in range(3):
        pol.observe_round(4, 1.0, use_spec=False)
    assert pol.parked and pol.parks == 1
    assert not pol.blocks_capture or pol.parked   # capture parks too
    # parked dispatches serve the never-speculate table, except every
    # probe_interval-th which forces speculation (the acceptance probe)
    tables = [pol.dispatch_table() for _ in range(4)]
    assert all(t is park for t in tables[:3])
    assert tables[3] is probe and pol.probing
    # a probe that still measures low acceptance leaves it parked...
    pol.observe_round(4, 1.0, use_spec=True)
    assert pol.parked
    # ...a probe whose refreshed EMA clears the Eq. 5 gate resumes
    for _ in range(4):
        pol.dispatch_table()
    assert pol.probing
    pol.observe_round(4, 2.5, use_spec=True)
    assert not pol.parked and pol.resumes == 1
    assert pol.dispatch_table() is gate
    assert not pol.blocks_capture
    # park control refuses to run blind (no Eq. 5 profile to probe)
    with pytest.raises(ValueError, match="park"):
        SpeculationPolicy(None, park_patience=2).prepare(4)


@pytest.mark.slow
def test_park_engine_integration(model):
    """End-to-end park: a drafter whose break-even threshold the
    observed acceptance can never clear gates speculation off, the
    policy parks after ``park_patience`` rounds, signal capture parks
    with it, and forced-speculation probes keep firing at the probe
    cadence (spec rounds while parked == probes).  Streams match the
    default engine's byte for byte — park only moves work, greedy
    verification fixes the tokens."""
    from repro.core.signals import SignalExtractor, SignalStore

    cfg, params, dcfg, dparams = model
    lens, budgets = [8, 6, 9, 7] * 3, [12] * 12
    ref = _cached_engine()
    ref_reqs = _requests(lens, budgets)
    ref.serve_stream(ref_reqs)

    scfg = ServingConfig(batch_size=4, max_len=96, gamma=3, seed=5,
                         spec_park_patience=2, spec_probe_interval=3)
    store = SignalStore()
    eng = ServingEngine(cfg, params, dcfg, dparams, config=scfg,
                        drafter=AdaptiveDrafter(_FLAT_PROFILE, gamma=3),
                        extractor=SignalExtractor(store, window=16))
    eng.accept_ema = 1.0       # below the ~2.0 threshold from round one
    reqs = _requests(lens, budgets)
    eng.serve_stream(reqs)
    pol = eng.policy.speculation
    assert pol.parks >= 1 and pol.parked, \
        "engine never parked under a hopeless Eq. 5 gate"
    assert eng.extractor.enabled is False, "capture did not park"
    # speculative rounds after the park are exactly the probes
    assert eng.stats.spec_steps < eng.stats.steps
    assert [list(r.generated) for r in reqs] == \
        [list(r.generated) for r in ref_reqs], \
        "park control changed token streams"
    # resume must restore capture even with no controller to re-drive
    # ``extractor.enabled`` (the park control owns it then); pin the
    # policy un-parked so the hopeless gate can't immediately re-park
    pol.parked = False
    pol._idle = -10 ** 9
    more = _requests([6, 5, 8, 7], [8] * 4, seed=9)
    eng.serve_stream(more)
    assert eng.extractor.enabled is True, \
        "capture not restored after speculation resumed"


def test_park_stepwise_mode(model):
    """The per-step reference loop runs the same park/probe schedule
    through ``step_decision`` — and still emits identical streams."""
    cfg, params, dcfg, dparams = model
    scfg = ServingConfig(batch_size=2, max_len=96, gamma=3, seed=5,
                         superstep_rounds=0, spec_park_patience=2,
                         spec_probe_interval=3)
    eng = ServingEngine(cfg, params, dcfg, dparams, config=scfg,
                        drafter=AdaptiveDrafter(_FLAT_PROFILE, gamma=3))
    eng.accept_ema = 1.0
    reqs = _requests([7, 5], [16, 16])
    eng.serve_stream(reqs)
    assert eng.policy.speculation.parks >= 1
    ref_reqs = _requests([7, 5], [16, 16])
    eng2 = ServingEngine(cfg, params, dcfg, dparams,
                         config=ServingConfig(batch_size=2, max_len=96,
                                              gamma=3, seed=5,
                                              superstep_rounds=0))
    eng2.serve_stream(ref_reqs)
    assert [list(r.generated) for r in reqs] == \
        [list(r.generated) for r in ref_reqs]


# ================================================ deprecated-kwarg shims
@pytest.mark.slow
def test_deprecated_kwargs_warn_and_match_policy_path(model):
    """The legacy control kwargs still work (DeprecationWarning) and
    are byte-identical to the new default ServingPolicy/ServingConfig
    path: streams, stats, completion-sink delivery."""
    cfg, params, dcfg, dparams = model
    lens = [40, 6, 9, 7, 5, 30, 4, 8]
    budgets = [6, 9, 4, 8, 7, 5, 6, 4]

    sink_old, sink_new = [], []
    with pytest.warns(DeprecationWarning):
        eng_old = ServingEngine(
            cfg, params, dcfg, dparams, batch_size=4, max_len=96,
            gamma=3, seed=5, prefill_chunk=16,
            completion_sink=sink_old.append)
    eng_new = ServingEngine(
        cfg, params, dcfg, dparams,
        config=ServingConfig(batch_size=4, max_len=96, gamma=3, seed=5,
                             prefill_chunk=16,
                             completion_sink=sink_new.append))
    r_old = _requests(lens, budgets)
    r_new = _requests(lens, budgets)
    eng_old.serve_stream(r_old)
    eng_new.serve_stream(r_new)
    assert [list(r.generated) for r in r_old] == \
        [list(r.generated) for r in r_new]
    assert [r.rid - r_old[0].rid for r in sink_old] == \
        [r.rid - r_new[0].rid for r in sink_new]
    for f in ("tokens_out", "steps", "spec_steps", "refills",
              "prefill_chunks", "prefill_row_tokens", "completed"):
        assert getattr(eng_old.stats, f) == getattr(eng_new.stats, f), f
    assert eng_old.accept_ema == eng_new.accept_ema


def test_gate_arrivals_kwarg_warns(model):
    cfg, params, dcfg, dparams = model
    with pytest.warns(DeprecationWarning, match="gate_arrivals"):
        eng = ServingEngine(cfg, params, dcfg, dparams, batch_size=2,
                            max_len=96, gate_arrivals=True)
    assert eng.gate_arrivals and eng.config.gate_arrivals


# ================================================== unified ServingConfig
def test_serving_config_tide_mirror():
    from repro.core.tide import TideConfig

    tc = TideConfig(serving=ServingConfig(batch_size=8, admission="deadline",
                                          commit="eager", prefill_chunk=16,
                                          trainer_threads=2))
    # legacy flat fields mirror the unified config
    assert tc.batch_size == 8 and tc.prefill_chunk == 16
    assert tc.admission == "deadline" and tc.commit == "eager"
    assert tc.trainer_threads == 2
    # and the flat convenience layer still assembles a ServingConfig
    tc2 = TideConfig(batch_size=2, prefill_chunk=8, admission="priority")
    assert tc2.serving.batch_size == 2
    assert tc2.serving.prefill_chunk == 8
    assert tc2.serving.admission == "priority"

    pol = tc.serving.make_policy()
    assert isinstance(pol.admission, DeadlineAdmission)
    assert isinstance(pol.commit, EagerCommit)
    assert isinstance(pol, ServingPolicy)
    with pytest.raises(KeyError):
        ServingConfig(admission="nope").make_policy()

    # dataclasses.replace on a constructed TideConfig must honor a
    # replaced flat field (post-construction, serving is always set)
    import dataclasses as dc
    tc3 = dc.replace(tc, batch_size=16)
    assert tc3.batch_size == 16 and tc3.serving.batch_size == 16
    assert tc3.serving.commit == "eager"      # untouched fields mirror
    # an explicit non-default flat field overrides the serving config
    tc4 = TideConfig(gamma=5,
                     serving=ServingConfig(batch_size=8))
    assert tc4.gamma == 5 and tc4.serving.gamma == 5
    assert tc4.batch_size == 8


def test_engine_config_attr_propagation(model):
    cfg, params, dcfg, dparams = model
    scfg = ServingConfig(batch_size=2, max_len=64, gamma=2, greedy=False,
                         superstep_rounds=4, seed=9, prefill_chunk=8)
    eng = ServingEngine(cfg, params, dcfg, dparams, config=scfg)
    assert (eng.batch, eng.max_len, eng.gamma) == (2, 64, 2)
    assert not eng.greedy and eng.superstep_rounds == 4
    assert eng.prefill_chunk == 8
    assert isinstance(eng.policy.admission, FifoAdmission)
    # engine takes a private copy: caller mutation can't skew it
    scfg.prefill_chunk = 0
    assert eng.config.prefill_chunk == 8
    # a knob kwarg passed alongside config= would be silently ignored —
    # it must fail loudly instead
    with pytest.raises(ValueError, match="knob kwargs"):
        ServingEngine(cfg, params, dcfg, dparams,
                      config=ServingConfig(), greedy=False)


def test_workload_slo_annotations():
    from repro.data.workloads import arrival_trace, make_domains

    domains = make_domains(97, ["a", "b"], seed=1)
    trace = arrival_trace(domains, 40, mode="poisson", rate=8.0,
                          deadline_slack=(10.0, 20.0), tight_frac=0.5,
                          tight_slack=(0.1, 0.5), priority_levels=3,
                          seed=2)
    slacks = [ev.deadline - ev.t for ev in trace]
    assert all(d > 0 for d in slacks)
    assert any(d <= 0.5 for d in slacks) and any(d >= 10.0 for d in slacks)
    assert {ev.priority for ev in trace} <= {0, 1, 2}
    assert len({ev.priority for ev in trace}) > 1
    # FIFO replay of an annotated trace is unchanged
    plain = arrival_trace(domains, 40, mode="poisson", rate=8.0, seed=2)
    assert [ev.prompt for ev in trace] == [ev.prompt for ev in plain]
    assert [ev.t for ev in trace] == [ev.t for ev in plain]


# ================================================= trainer_threads knob
def test_trainer_threads_knob(model):
    import time as _time

    from repro.checkpoint.ckpt import DraftDeployGate
    from repro.core.transport import SignalChannel
    from repro.training.draft_trainer import DraftTrainer
    from repro.training.service import TrainingService

    cfg, params, dcfg, dparams = model
    svc = TrainingService(DraftTrainer(cfg, dcfg, params["embed"]),
                          DraftDeployGate(dparams), SignalChannel(8),
                          n_threshold=1, signal_window=1,
                          trainer_threads=2)
    assert svc.stats()["trainer_threads"] == 2
    svc.start()
    try:
        for _ in range(100):          # wait for the loop to stamp the cap
            if svc.stats()["thread_cap"] is not None:
                break
            _time.sleep(0.01)
        # on this Linux container per-thread deprioritization must
        # engage (raising one's own nice needs no privilege)
        assert svc.stats()["thread_cap"] == "thread_nice"
    finally:
        svc.close()
    # 0 = unpinned: no cap recorded
    svc0 = TrainingService(DraftTrainer(cfg, dcfg, params["embed"]),
                           DraftDeployGate(dparams), SignalChannel(8),
                           n_threshold=1, signal_window=1)
    assert svc0.stats()["thread_cap"] is None


# ===================== pretrained end-to-end parity suite (slow tier)
@pytest.fixture(scope="module")
def pretrained():
    from repro.data.workloads import make_domains, training_corpus
    from repro.training.trainer import pretrain_target

    cfg = C.get("tide-tiny")
    params = T.init(cfg, jax.random.key(0))
    domains = make_domains(cfg.vocab_size, ["science"], branchings=[2],
                           seed=3)
    corpus = training_corpus(domains["science"], 64, 40, 1)
    params, _ = pretrain_target(cfg, params, corpus, steps=80, lr=3e-3)
    dcfg = eagle.draft_config(cfg)
    dparams = eagle.draft_init(dcfg, jax.random.key(7))
    return cfg, params, dcfg, dparams, domains


@pytest.mark.slow
@pytest.mark.parametrize("greedy", [True, False])
def test_default_policy_parity_pretrained(pretrained, greedy):
    """Acceptance gate: the default ServingPolicy (FIFO + cohort +
    Eq. 5 gate) is bitwise-identical to the pre-redesign kwarg path on
    a realistic pretrained engine — streams (greedy AND sampled),
    stats, accept-EMA, and SignalStore contents."""
    from repro.core.controller import TrainingController
    from repro.core.signals import SignalExtractor, SignalStore

    cfg, params, dcfg, dparams, domains = pretrained
    rng = np.random.default_rng(4)
    prompts = [domains["science"].sample_prompt(rng) for _ in range(10)]
    budgets = [int(b) for b in
               np.random.default_rng(5).integers(4, 28, size=10)]

    def _serve(use_config):
        store = SignalStore()
        ctrl = TrainingController(n_init=4, n_threshold=64)
        ctrl.collection_enabled = True
        kw = dict(controller=ctrl,
                  extractor=SignalExtractor(store, window=16),
                  drafter=AdaptiveDrafter(_FLAT_PROFILE, gamma=3))
        if use_config:
            eng = ServingEngine(cfg, params, dcfg, dparams,
                                config=ServingConfig(
                                    batch_size=4, max_len=96, gamma=3,
                                    seed=5, greedy=greedy), **kw)
        else:
            eng = ServingEngine(cfg, params, dcfg, dparams, batch_size=4,
                                max_len=96, gamma=3, seed=5,
                                greedy=greedy, **kw)
        eng.accept_ema = 3.0          # decays through the Eq. 5 gate
        reqs = [Request(prompt=list(p), max_new_tokens=m)
                for p, m in zip(prompts, budgets)]
        eng.serve_stream(reqs)
        sigs = [(b.tokens.tobytes(), b.feats.tobytes())
                for b in store.drain()]
        return [list(r.generated) for r in reqs], sigs, eng

    g_kw, s_kw, e_kw = _serve(use_config=False)
    g_cf, s_cf, e_cf = _serve(use_config=True)
    assert g_cf == g_kw, "default-policy streams diverged from kwargs"
    assert s_cf == s_kw, "default-policy SignalStore diverged"
    assert e_cf.accept_ema == e_kw.accept_ema
    for f in ("tokens_out", "steps", "spec_steps", "refills",
              "dispatches", "completed"):
        assert getattr(e_cf.stats, f) == getattr(e_kw.stats, f), f
