"""Sharding-hints layer (§Perf): inert without a context, correct specs
with one, and the replication-guard no-op."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import sharding as sh
from repro.launch.mesh import make_demo_mesh
from repro.models import hints
from repro.models import attention as attn


def test_hint_noop_without_context():
    x = jnp.ones((4, 4))
    assert hints.hint(x, ("batch", None)) is x
    assert not hints.active()


def test_hint_applies_under_context():
    mesh = make_demo_mesh()
    x = jnp.ones((4, 4))
    with hints.activate(mesh, sh.BASE_RULES):
        assert hints.active()
        y = hints.hint(x, ("batch", None))
        # on a 1-device mesh everything resolves to replicated -> no-op
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    assert not hints.active()


def test_hint_replication_guard():
    """A spec that resolves fully-replicated must not constrain."""
    mesh = make_demo_mesh()
    x = jnp.ones((3, 5))   # 3 and 5 divide nothing on a 16-way axis
    with hints.activate(mesh, sh.BASE_RULES):
        y = hints.hint(x, ("experts", "mlp"))
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_mixed_precision_attend_matches_fp32():
    """§Perf H-A1: bf16-operand attention == fp32-upcast attention."""
    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (2, 8, 4, 32), jnp.float32)
    k = jax.random.normal(ks[1], (2, 16, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (2, 16, 2, 32), jnp.float32)
    mask = jnp.ones((1, 1, 1, 8, 16), bool)
    old = attn.MIXED_PRECISION
    try:
        attn.MIXED_PRECISION = True
        a = attn.attend(q, k, v, mask)
        attn.MIXED_PRECISION = False
        b = attn.attend(q, k, v, mask)
    finally:
        attn.MIXED_PRECISION = old
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


def test_flash_decode_blockwise_matches_full():
    ks = jax.random.split(jax.random.key(5), 3)
    B, T, S = 2, 4, 4096
    q = jax.random.normal(ks[0], (B, T, 4, 32), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, 2, 32), jnp.float32)
    lengths = jnp.array([1000, 3000], jnp.int32)
    pad = jnp.array([7, 0], jnp.int32)
    ref = attn.decode_attend(q, k, v, lengths, pad)
    out = attn.decode_attend_blockwise(q, k, v, lengths, pad,
                                       block_kv=512)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                               rtol=2e-5, atol=2e-5)
