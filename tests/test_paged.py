"""Paged KV cache: allocator units, paged == dense property tier, and
paged kernel-vs-ref sweeps.

The paged memory model's load-bearing invariant is the same one the
chunk pipeline pins: changing *where* KV bytes live (fixed pages behind
a block table instead of a private dense lane) must never change *what*
is computed.  The engine achieves this by construction — paged decode
scatters through the table, gathers the dense per-lane view back, and
runs the identical attention dispatch — so paged serving is bitwise
equal to dense serving on full emitted streams, greedy and
per-request-keyed sampled, one-shot and chunked refill, superstep and
stepwise.  The property tier here pins exactly that, over random prompt
lengths, budgets, and chunk sizes.

The host-side allocator is plain numpy bookkeeping, so its invariants
(refcounts, reservation atomicity, registry eviction, COW forks, leak
freedom) are pinned by direct unit tests.  The Pallas paged kernels are
swept against their gather-densely oracles in interpret mode, the same
contract as tests/test_kernels.py.

All tests run on randomly initialized weights (parity is a property of
the computation, not the model), so the file stays in the fast tier.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # not in the container image - deterministic shim
    from _hypothesis_shim import given, settings, strategies as st

import repro.configs as C
from repro.core import eagle, paging
from repro.models import transformer as T
from repro.serving.engine import ServingEngine
from repro.serving.policy import ServingConfig
from repro.serving.request import Request


# ==================================================== allocator units
def _alloc(num_pages=16, page_size=8, batch=4, max_len=64, **kw):
    return paging.PageAllocator(num_pages, page_size, batch, max_len, **kw)


def test_allocator_reserve_free_roundtrip():
    a = _alloc()
    assert a.reserve(0, 20)                      # 3 pages of 8
    assert a.pages_in_use == 3 and a.peak_in_use == 3
    assert (a.table[0, :3] != a.trash).all()
    assert (a.table[0, 3:] == a.trash).all()
    a.free_lane(0)
    a.free_lane(0)                               # idempotent
    a.assert_clean()


def test_allocator_reserve_atomic_on_oom():
    a = _alloc(num_pages=4)
    assert a.reserve(0, 32)                      # takes the whole pool
    assert not a.can_reserve(8)
    assert not a.reserve(1, 8)                   # fails...
    assert (a.table[1] == a.trash).all()         # ...leaving lane 1 untouched
    a.free_lane(0)
    assert a.reserve(1, 8)                       # freed pages come back
    a.free_lane(1)
    a.assert_clean()


def test_allocator_double_reserve_raises():
    a = _alloc()
    assert a.reserve(0, 8)
    with pytest.raises(AssertionError):
        a.reserve(0, 8)
    a.free_lane(0)
    a.assert_clean()


def test_prefix_publish_lookup_adopt_refcounts():
    a = _alloc()
    assert a.reserve(0, 24)
    key = a.prefix_key(2, 24, 0, list(range(17)), 2)
    a.publish(key, 0, 2)
    donor = tuple(int(p) for p in a.table[0, :2])
    assert a.lookup(key) == donor
    assert [int(a.ref[p]) for p in donor] == [2, 2]   # lane + registry
    # a borrower with its own private reservation adopts: the duplicate
    # pages for the shared range return to the free list
    assert a.reserve(1, 24)
    free_before = a.free_pages
    a.adopt(1, donor)
    assert tuple(int(p) for p in a.table[1, :2]) == donor
    assert a.free_pages == free_before + 2
    assert a.prefix_hits == 1
    assert a.prefix_tokens_saved == 2 * a.page_size
    # the donor retires: shared pages survive through the registry ref
    a.free_lane(0)
    assert a.lookup(key) == donor
    a.free_lane(1)
    a.release_prefix_cache()
    a.assert_clean()


def test_prefix_key_covers_provenance():
    a = _alloc()
    toks = list(range(20))
    k1 = a.prefix_key(2, 24, 0, toks, 2)
    # tokens past column n_pages * P + 1 are outside the provenance
    assert k1 == a.prefix_key(2, 24, 0, toks[:17] + [99, 99, 99], 2)
    # everything the page bytes depend on changes the key
    assert k1 != a.prefix_key(4, 24, 0, toks, 2)          # refill rows
    assert k1 != a.prefix_key(2, 32, 0, toks, 2)          # op width
    assert k1 != a.prefix_key(2, 24, 1, toks, 2)          # left pad
    assert k1 != a.prefix_key(2, 24, 0, toks, 2, salt=1)  # deploy seq
    t2 = list(toks)
    t2[16] = 77                   # the draft's one-token lookahead column
    assert k1 != a.prefix_key(2, 24, 0, t2, 2)


def test_registry_lru_eviction_under_pressure():
    a = _alloc(num_pages=4)
    assert a.reserve(0, 16)
    key = a.prefix_key(1, 16, 0, list(range(17)), 2)
    a.publish(key, 0, 2)
    a.free_lane(0)                # registry is now the pages' sole owner
    assert a.free_pages == 2
    assert a.can_reserve(32)      # an eviction sweep covers the deficit
    assert a.reserve(1, 32)       # forces the sweep
    assert a.evictions == 1
    assert a.lookup(key) is None
    a.free_lane(1)
    a.assert_clean()


def test_eviction_skips_lane_mapped_entries():
    a = _alloc(num_pages=4)
    assert a.reserve(0, 16)
    key = a.prefix_key(1, 16, 0, list(range(17)), 2)
    a.publish(key, 0, 2)          # lane 0 still maps these pages
    assert not a.can_reserve(24)  # 3 pages wanted, 2 free, none evictable
    assert not a.reserve(1, 24)
    assert a.lookup(key) is not None   # the mapped entry survived
    a.free_lane(0)
    a.release_prefix_cache()
    a.assert_clean()


def test_cow_fork_and_copy_page():
    a = _alloc()
    assert a.reserve(0, 24)       # 3 pages; publish the first 2
    key = a.prefix_key(1, 24, 0, list(range(17)), 2)
    a.publish(key, 0, 2)
    # exclusively-owned page: write in place
    assert a.fork_for_write(0, 2) is None
    # shared page (ref 2): fork repoints the lane at a fresh page
    src, dst = a.fork_for_write(0, 0)
    assert int(a.table[0, 0]) == dst and src != dst
    assert a.cow_forks == 1 and int(a.ref[src]) == 1
    # the device half duplicates the bytes
    pool = jnp.arange(17 * 8, dtype=jnp.float32).reshape(17, 8, 1, 1)
    pool = paging.copy_page(pool, src, dst)
    assert np.array_equal(np.asarray(pool[dst]), np.asarray(pool[src]))
    a.free_lane(0)
    a.release_prefix_cache()
    a.assert_clean()


# ================================================= device page helpers
def test_write_gather_roundtrip_and_mask():
    pool = jnp.zeros((7, 4, 2, 3))                       # 6 pages + trash
    tbl = jnp.array([[0, 1, 2], [3, 4, 5]], jnp.int32)   # max_len 12
    rows = jnp.arange(2 * 10 * 2 * 3, dtype=jnp.float32
                      ).reshape(2, 10, 2, 3) + 1.0
    pool = paging.write_rows_paged(pool, tbl, rows,
                                   jnp.array([True, True]))
    view = paging.gather_view(pool, tbl)
    assert view.shape == (2, 12, 2, 3)
    assert np.array_equal(np.asarray(view[:, :10]), np.asarray(rows))
    # masked lanes write to the trash page; mapped pages stay untouched
    pool2 = paging.write_rows_paged(pool, tbl, rows * 7.0,
                                    jnp.array([False, True]))
    view2 = paging.gather_view(pool2, tbl)
    assert np.array_equal(np.asarray(view2[0, :10]), np.asarray(rows[0]))
    assert np.array_equal(np.asarray(view2[1, :10]),
                          np.asarray(rows[1] * 7.0))
    # explicit-row gather (the prefix-resume read path)
    got = paging.gather_rows_paged(pool, jnp.array([[3, 4]], jnp.int32), 6)
    assert np.array_equal(np.asarray(got[0]), np.asarray(view[1, :6]))


def test_scatter_kv_paged_drops_out_of_bounds():
    pool = jnp.zeros((3, 4, 1, 1))                        # 2 pages + trash
    tbl = jnp.array([[0, 1]], jnp.int32)                  # max_len 8
    new = jnp.ones((1, 4, 1, 1))
    out = paging.scatter_kv_paged(pool, tbl, new,
                                  jnp.array([6], jnp.int32))
    lane = np.asarray(paging.gather_view(out, tbl))[0, :, 0, 0]
    # positions 6, 7 land; 8, 9 overflow the lane window -> trash page,
    # exactly where dense scatter's clamped writes get dropped
    assert lane.tolist() == [0, 0, 0, 0, 0, 0, 1, 1]
    assert np.asarray(out[:2]).sum() == 2.0               # real pages clean


# =============================================== paged kernels vs refs
def test_flash_attn_paged_kernel_vs_ref():
    from repro.kernels.flash_attn.kernel import flash_attention_paged
    from repro.kernels.flash_attn.ref import flash_attention_paged_ref
    rng = np.random.default_rng(11)
    b, s, hq, hk, d, p = 2, 64, 4, 2, 64, 16
    n_pg = s // p
    pool_shape = (b * n_pg + 1, p, hk, d)
    k_pool = jnp.asarray(rng.normal(size=pool_shape), jnp.float32)
    v_pool = jnp.asarray(rng.normal(size=pool_shape), jnp.float32)
    q = jnp.asarray(rng.normal(size=(b, s, hq, d)), jnp.float32)
    perm = rng.permutation(b * n_pg)             # non-contiguous mapping
    tbl = jnp.asarray(perm.reshape(b, n_pg), jnp.int32)
    out = flash_attention_paged(q, k_pool, v_pool, tbl, causal=True,
                                block_q=32, interpret=True)
    ref = flash_attention_paged_ref(q, k_pool, v_pool, tbl, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_verify_attn_paged_kernel_vs_ref():
    from repro.kernels.verify_attn.kernel import verify_attention_paged
    from repro.kernels.verify_attn.ref import verify_attention_paged_ref
    rng = np.random.default_rng(13)
    b, t, hq, hk, d, p, n_tbl = 2, 4, 4, 2, 64, 16, 8
    trash = b * n_tbl
    k_pool = jnp.asarray(rng.normal(size=(trash + 1, p, hk, d)), jnp.float32)
    v_pool = jnp.asarray(rng.normal(size=(trash + 1, p, hk, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(b, t, hq, d)), jnp.float32)
    lengths = jnp.asarray(rng.integers(t + 1, 90, size=(b,)), jnp.int32)
    pad = jnp.minimum(jnp.asarray(rng.integers(0, 16, size=(b,)),
                                  jnp.int32), lengths - 1)
    # map pages covering [0, lengths + t); point the rest at trash (the
    # allocator's reservation invariant — trash keys are masked anyway)
    perm = rng.permutation(trash)
    tbl = np.full((b, n_tbl), trash, np.int32)
    for lane in range(b):
        need = -(-int(lengths[lane] + t) // p)
        tbl[lane, :need] = perm[lane * n_tbl:lane * n_tbl + need]
    tbl = jnp.asarray(tbl)
    out = verify_attention_paged(q, k_pool, v_pool, tbl, lengths, pad,
                                 interpret=True)
    ref = verify_attention_paged_ref(q, k_pool, v_pool, tbl, lengths, pad)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_paged_ops_dispatch():
    """CPU dispatch goes to the oracle; force_kernel runs interpret."""
    from repro.kernels.flash_attn.ops import flash_attn_paged
    from repro.kernels.verify_attn.ops import verify_attn_paged
    rng = np.random.default_rng(17)
    k_pool = jnp.asarray(rng.normal(size=(9, 16, 2, 64)), jnp.float32)
    v_pool = jnp.asarray(rng.normal(size=(9, 16, 2, 64)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(1, 64, 2, 64)), jnp.float32)
    tbl = jnp.asarray(rng.permutation(8).reshape(1, 8), jnp.int32)
    a = flash_attn_paged(q, k_pool, v_pool, tbl[:, :4])
    b = flash_attn_paged(q, k_pool, v_pool, tbl[:, :4], force_kernel=True,
                         block_q=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)
    lengths = jnp.array([100], jnp.int32)
    a = verify_attn_paged(q[:, :4], k_pool, v_pool, tbl, lengths)
    b = verify_attn_paged(q[:, :4], k_pool, v_pool, tbl, lengths,
                          force_kernel=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


# ====================================== engine: paged == dense streams
_MODEL = None


def _get_model():
    """Lazily-built module model (plain function, not a fixture, so the
    hypothesis-shim property tests — whose wrapper hides the original
    signature from pytest — can reach it too)."""
    global _MODEL
    if _MODEL is None:
        cfg = C.get("tide-tiny")
        params = T.init(cfg, jax.random.key(0))
        dcfg = eagle.draft_config(cfg)
        dparams = eagle.draft_init(dcfg, jax.random.key(7))
        _MODEL = (cfg, params, dcfg, dparams)
    return _MODEL


_ENGINES = {}


def _cached_engine(**kw):
    """Engines are shared across tests (jit caches stay warm — compile
    time dominates this file otherwise); ``reset_adaptation`` restores
    the post-construction serving state between uses."""
    key = tuple(sorted(kw.items()))
    eng = _ENGINES.get(key)
    if eng is None:
        cfg, params, dcfg, dparams = _get_model()
        config = ServingConfig(batch_size=2, max_len=96, gamma=3, seed=5,
                               **dict({"superstep_rounds": 4}, **kw))
        eng = _ENGINES[key] = ServingEngine(cfg, params, dcfg, dparams,
                                            config=config)
    eng.reset_adaptation(eng.dparams)
    eng.deploy_source = None
    return eng


def _requests(cfg, lens, budgets, seed=3):
    rng = np.random.default_rng(seed)
    return [Request(prompt=list(rng.integers(1, cfg.vocab_size, L)),
                    max_new_tokens=m) for L, m in zip(lens, budgets)]


def _streams(eng, reqs):
    """Serve, leak-check, and key streams by creation index (request ids
    are globally monotonic, so dense/paged runs would never collide)."""
    eng.serve_stream(list(reqs))
    if eng.allocator is not None:
        eng.release_prefix_cache()
        eng.allocator.assert_clean()
    return {i: list(r.generated) for i, r in enumerate(reqs)}


def _parity_case(lens, budgets, seed, *, chunk=0, greedy=True, rounds=4,
                 **paged_kw):
    cfg, *_ = _get_model()
    dense = _streams(
        _cached_engine(greedy=greedy, superstep_rounds=rounds,
                       prefill_chunk=chunk),
        _requests(cfg, lens, budgets, seed=seed))
    eng = _cached_engine(greedy=greedy, superstep_rounds=rounds,
                         prefill_chunk=chunk, page_size=8, **paged_kw)
    paged = _streams(eng, _requests(cfg, lens, budgets, seed=seed))
    assert dense == paged
    return eng


@pytest.mark.slow
@settings(max_examples=5)
@given(st.integers(0, 1), st.integers(0, 1), st.integers(0, 10 ** 6))
def test_paged_stream_parity_property(chunk_idx, greedy_idx, seed):
    """Property: for random prompt lengths, budgets, chunk modes, and
    greedy/per-request-keyed sampled decoding, a paged engine emits
    byte-identical streams to the dense engine and returns every page
    to the free list at drain."""
    rng = np.random.default_rng(seed)
    lens = [int(rng.integers(2, 40)) for _ in range(6)]
    budgets = [int(rng.integers(2, 9)) for _ in range(6)]
    _parity_case(lens, budgets, seed, chunk=8 * chunk_idx,
                 greedy=bool(greedy_idx))


@pytest.mark.slow
def test_paged_stream_parity_stepwise():
    """The per-step reference loop (superstep_rounds=0) takes the
    stepwise dispatch path — same parity contract."""
    _parity_case([5, 30, 11, 23], [6, 4, 8, 5], seed=21, rounds=0)


def test_paged_admission_defers_under_page_pressure():
    """A pool too small for two concurrent reservations serves the
    same trace by deferring admissions (never by corrupting lanes):
    streams stay byte-identical, every request completes, and the
    deferral counter records the backpressure."""
    lens = [int(x) for x in
            np.random.default_rng(9).integers(3, 13, size=8)]
    budgets = [int(x) for x in
               np.random.default_rng(10).integers(3, 9, size=8)]
    # P=8, num_pages=4: one lane's reservation (width + budget + gamma
    # + 1 <= 28 tokens = 4 pages) fills the pool, so lanes serialize
    eng = _parity_case(lens, budgets, seed=33, num_pages=4)
    assert eng.stats.admission_deferrals > 0
    assert eng.stats.completed == 8
    assert eng.stats.pages_peak <= 4


@pytest.mark.slow
def test_paged_prefix_sharing_hits_and_parity():
    """Requests sharing a long system prompt: chunked paged serving
    adopts the published prefix pages (registry hits, prefill row-token
    work saved) while streams stay byte-identical to dense."""
    prefix = [7] * 20

    def reqs():
        rng = np.random.default_rng(3)
        return [Request(prompt=prefix + [int(t) for t in
                                         rng.integers(1, 500, 3)],
                        max_new_tokens=6 + (i % 3))
                for i in range(8)]


    dense = _streams(_cached_engine(greedy=True, superstep_rounds=4,
                                    prefill_chunk=8), reqs())
    eng = _cached_engine(greedy=True, superstep_rounds=4, prefill_chunk=8,
                         page_size=8)
    paged = _streams(eng, reqs())
    assert dense == paged
    assert eng.stats.prefix_hits > 0
    assert eng.stats.prefix_tokens_saved > 0


# ============================================= paged deploy re-seed
@pytest.mark.slow
def test_paged_reseed_deploy_stream_parity():
    """reseed_window + paged serving compose (the old exclusivity is
    lifted): the paged re-seed op rewrites resident lanes' draft rows
    through their block-table rows in place.  A mid-stream deploy with
    re-seed on a paged engine leaves greedy streams byte-identical to
    the same deploy on a dense engine (and both to the deploy-free
    run, since the target verifies every draft)."""
    cfg, params, dcfg, dparams = _get_model()
    draft_b = eagle.draft_init(dcfg, jax.random.key(99))

    class _AfterN:
        def __init__(self, n):
            self.n, self.polls = n, 0

        def __call__(self):
            from repro.training.service import DraftVersion
            self.polls += 1
            return (DraftVersion(1, draft_b, 0.9)
                    if self.polls >= self.n else None)

    lens, budgets = [6, 9, 5, 8], [16, 12, 14, 10]
    dense = _streams(_cached_engine(greedy=True, reseed_window=12),
                     _requests(cfg, lens, budgets))

    eng = _cached_engine(greedy=True, reseed_window=12, page_size=8)
    eng.deploy_source = _AfterN(3)
    paged = _streams(eng, _requests(cfg, lens, budgets))
    assert eng.stats.deploys == 1 and eng.stats.reseeds == 1
    assert paged == dense


# ======================================================= config guards


def test_paged_rejects_indivisible_max_len():
    cfg, params, dcfg, dparams = _get_model()
    with pytest.raises(ValueError):
        ServingEngine(cfg, params, dcfg, dparams,
                      config=ServingConfig(batch_size=2, max_len=96,
                                           page_size=7))


def test_tide_config_mirrors_paging_knobs():
    from repro.core.tide import TideConfig
    tc = TideConfig(page_size=8, num_pages=40)
    assert tc.serving.page_size == 8 and tc.serving.num_pages == 40
    tc2 = TideConfig(serving=ServingConfig(page_size=16, num_pages=24,
                                           share_prefix=False))
    assert (tc2.page_size, tc2.num_pages, tc2.share_prefix) == (16, 24,
                                                                False)
