"""End-to-end serving engine + TIDE system integration (CPU, tiny
target).  The heavier adaptation test reproduces the paper's Fig. 5
dynamic: acceptance length must RISE as the draft trains online."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Pretrained-fixture-heavy end-to-end parity suite: slow tier (the
# fast smoke loop runs `pytest -m "not slow"`; see ROADMAP.md).
pytestmark = pytest.mark.slow

import repro.configs as C
from repro.core import eagle
from repro.core.adaptive import AdaptiveDrafter, LatencyProfile
from repro.core.tide import TideConfig, TideSystem
from repro.data.workloads import (Phase, WorkloadStream, make_domains,
                                  training_corpus)
from repro.models import transformer as T
from repro.serving.engine import ServingEngine
from repro.serving.request import Request
from repro.training.trainer import pretrain_target


@pytest.fixture(scope="module")
def pretrained():
    cfg = C.get("tide-tiny")
    params = T.init(cfg, jax.random.key(0))
    domains = make_domains(cfg.vocab_size, ["science"], branchings=[2],
                           seed=3)
    corpus = training_corpus(domains["science"], 64, 40, 1)
    params, _ = pretrain_target(cfg, params, corpus, steps=80, lr=3e-3)
    return cfg, params, domains


def test_wave_serving_matches_plain_generation(pretrained):
    """Engine output (greedy, spec on) == direct greedy generation."""
    cfg, params, domains = pretrained
    dcfg = eagle.draft_config(cfg)
    dparams = eagle.draft_init(dcfg, jax.random.key(7))
    eng = ServingEngine(cfg, params, dcfg, dparams, batch_size=2,
                        max_len=96, gamma=3)
    rng = np.random.default_rng(0)
    prompts = [domains["science"].sample_prompt(rng) for _ in range(2)]
    reqs = [Request(prompt=p, max_new_tokens=12) for p in prompts]
    eng.serve_wave(reqs)
    # reference: greedy autoregressive, per request, unbatched
    from repro.core import speculative as spec
    for r in reqs:
        toks = jnp.asarray(r.prompt)[None]
        pre = T.prefill(cfg, params, toks, max_len=96)
        cur = pre["logits"].argmax(-1).astype(jnp.int32)
        cache = pre["cache"]
        ref = [int(cur[0])]
        for _ in range(11):
            o = spec.plain_decode_step(cfg, params, cache, cur)
            cache, cur = o["cache"], o["token"]
            ref.append(int(cur[0]))
        assert r.generated == ref, "engine diverged from greedy reference"
        assert r.done and r.finish_t is not None


def test_adaptive_drafter_disables_speculation(pretrained):
    """With a profile that makes speculation never worthwhile, the engine
    must fall back to plain decoding (and still serve correctly)."""
    cfg, params, domains = pretrained
    dcfg = eagle.draft_config(cfg)
    dparams = eagle.draft_init(dcfg, jax.random.key(8))
    # T(n) flat and draft slow -> threshold unreachable
    prof = LatencyProfile([1, 2, 4, 8], [1.0, 2.0, 4.0, 8.0], d0_ms=5.0)
    eng = ServingEngine(cfg, params, dcfg, dparams, batch_size=2,
                        max_len=96, gamma=3,
                        drafter=AdaptiveDrafter(prof, gamma=3))
    rng = np.random.default_rng(1)
    reqs = [Request(prompt=domains["science"].sample_prompt(rng),
                    max_new_tokens=8) for _ in range(2)]
    eng.serve_wave(reqs)
    assert eng.stats.spec_steps == 0
    assert all(r.done for r in reqs)


@pytest.mark.slow
def test_tide_adaptation_raises_acceptance(pretrained):
    """Paper Fig. 5/6: online draft training raises acceptance length
    during live serving."""
    cfg, params, domains = pretrained
    stream = WorkloadStream(domains, [Phase("science", 56)], seed=1)
    tc = TideConfig(batch_size=4, max_len=96, n_threshold=4,
                    signal_window=16, adaptive_spec=False, train_epochs=2)
    sys_ = TideSystem(cfg, params, tc)
    sys_.run(stream.batches(4), max_new_tokens=32)
    s = sys_.summary()
    assert s["train_cycles"] >= 1
    assert s["deployed"] >= 1, "no draft ever passed the deploy gate"
    tl = sys_.engine.stats.timeline
    ell = np.array([x["accept_len"] for x in tl])
    k = max(len(ell) // 4, 1)
    first, last = ell[:k].mean(), ell[-k:].mean()
    assert last > first + 0.15, \
        f"acceptance did not rise: {first:.2f} -> {last:.2f}"
    assert s["signals_collected"] > 0
