"""Pallas kernel allclose sweeps vs pure-jnp oracles (interpret mode on
CPU): shapes × dtypes per assignment requirement (c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.extract_pack.kernel import extract_pack
from repro.kernels.extract_pack.ref import extract_pack_ref
from repro.kernels.flash_attn.kernel import flash_attention
from repro.kernels.flash_attn.ref import flash_attention_ref
from repro.kernels.verify_attn.kernel import verify_attention
from repro.kernels.verify_attn.ref import verify_attention_ref

DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dt):
    return dict(rtol=2e-2, atol=2e-2) if dt == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize(
    "b,s,hq,hk,d,causal,window",
    [(1, 128, 2, 1, 64, True, 0),
     (2, 256, 4, 2, 64, True, 0),
     (1, 128, 4, 4, 128, False, 0),
     (1, 256, 2, 2, 64, True, 96),
     (2, 128, 8, 2, 32, True, 0)])
def test_flash_attn_sweep(b, s, hq, hk, d, causal, window, dtype):
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (b, s, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, s, hk, d), dtype)
    v = jax.random.normal(ks[2], (b, s, hk, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_kv=64, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize(
    "b,t,hq,hk,d,s,window",
    [(2, 4, 4, 2, 64, 512, 0),
     (1, 4, 8, 8, 128, 256, 0),
     (3, 1, 2, 1, 64, 512, 0),          # plain decode T=1
     (2, 8, 4, 2, 32, 1024, 0),
     (2, 4, 4, 2, 64, 1024, 256)])      # sliding window
def test_verify_attn_sweep(b, t, hq, hk, d, s, window, dtype):
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(b, t, hq, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, s, hk, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, s, hk, d)), dtype)
    lengths = jnp.asarray(rng.integers(t + 1, s - t, size=(b,)), jnp.int32)
    pad = jnp.minimum(jnp.asarray(rng.integers(0, s // 4, size=(b,)),
                                  jnp.int32), lengths - 1)
    out = verify_attention(q, k, v, lengths, pad, window=window,
                           block_kv=128, interpret=True)
    ref = verify_attention_ref(q, k, v, lengths, pad, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("b,t,f,p", [(2, 4, 512, 0.5), (3, 8, 1024, 0.25),
                                     (1, 4, 1536, 1.0), (2, 4, 512, 0.0)])
def test_extract_pack_sweep(b, t, f, p, dtype):
    rng = np.random.default_rng(3)
    feats = jnp.asarray(rng.normal(size=(b, t, f)), dtype)
    toks = jnp.asarray(rng.integers(0, 999, size=(b, t)), jnp.int32)
    mask = jnp.asarray(rng.random((b, t)) < p)
    pf, pt, cnt = extract_pack(feats, toks, mask, interpret=True)
    rf, rt, rc = extract_pack_ref(feats, toks, mask)
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(rc))
    np.testing.assert_array_equal(np.asarray(pt), np.asarray(rt))
    np.testing.assert_allclose(np.asarray(pf, np.float32),
                               np.asarray(rf, np.float32), **_tol(dtype))


def test_ops_wrappers_dispatch():
    """CPU dispatch goes to the oracle; force_kernel runs interpret."""
    from repro.kernels.flash_attn.ops import flash_attn
    from repro.kernels.verify_attn.ops import verify_attn
    ks = jax.random.split(jax.random.key(5), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 64))
    k = jax.random.normal(ks[1], (1, 128, 2, 64))
    v = jax.random.normal(ks[2], (1, 128, 2, 64))
    a = flash_attn(q, k, v)
    b = flash_attn(q, k, v, force_kernel=True, block_q=64, block_kv=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)
    lengths = jnp.array([100], jnp.int32)
    out_ref = verify_attn(q[:, :4], k, v, lengths)
    out_ker = verify_attn(q[:, :4], k, v, lengths, force_kernel=True,
                          block_kv=128)
    np.testing.assert_allclose(np.asarray(out_ref), np.asarray(out_ker),
                               rtol=1e-5, atol=1e-5)
