"""Training-signal extraction: store/extractor mechanics, deferred
transfer, storage accounting (paper Table 1 math)."""
import numpy as np
import jax.numpy as jnp

import repro.configs as C
from repro.core.signals import (SignalBatch, SignalExtractor, SignalStore,
                                storage_bytes_per_token)


def _offer(ex, rid, n, fdim=6, accept=None):
    feats = jnp.arange(n * fdim, dtype=jnp.float32).reshape(1, n, fdim)
    toks = jnp.arange(n, dtype=jnp.int32)[None]
    mask = jnp.ones((1, n), bool) if accept is None else jnp.asarray(
        accept)[None]
    ex.offer([rid], feats, toks, mask)


def test_extractor_windows_and_flush():
    store = SignalStore()
    ex = SignalExtractor(store, window=8)
    for _ in range(5):
        _offer(ex, rid=1, n=4)
    ex.flush()
    assert store.peek_count() == 2          # 20 accepted -> 2 full windows
    batches = store.drain()
    assert all(b.feats.shape == (8, 6) for b in batches)
    assert store.peek_count() == 0


def test_extractor_deferred_one_step():
    """The offer() at step t is collected at step t+1 (overlap model)."""
    store = SignalStore()
    ex = SignalExtractor(store, window=4)
    _offer(ex, 1, 4)
    assert store.peek_count() == 0          # still pending on device
    _offer(ex, 1, 4)
    assert store.peek_count() == 1          # previous step collected


def test_extractor_respects_mask_and_enable():
    store = SignalStore()
    ex = SignalExtractor(store, window=4)
    _offer(ex, 1, 4, accept=[True, False, True, False])
    ex.enabled = False
    _offer(ex, 1, 4)                        # collects previous (2 rows)
    ex.flush()
    assert store.total_added == 0           # 2 rows < window, no force emit


def test_store_spill(tmp_path):
    store = SignalStore(spill_dir=str(tmp_path))
    for i in range(3):
        store.add(SignalBatch(np.ones((4, 6), np.float32),
                              np.arange(4, dtype=np.int32)))
    path = store.spill("t0")
    assert path is not None
    data = np.load(path)
    assert data["feats"].shape == (3, 4, 6)
    assert store.peek_count() == 0


def test_storage_math_matches_paper_scale():
    """Table 1: per-token hidden-state bytes = 3 · d_model · 2 (bf16).
    gpt-oss-120b: 2880·3·2 = 17.3 KB/token — TIDE's 0.19 TB buffer vs
    SpecForge's 4.66 TB full-dataset store is a ~24× ratio, matching the
    ratio reproduced in benchmarks/bench_storage.py."""
    cfg = C.get("gpt-oss-120b")
    assert storage_bytes_per_token(cfg) == 3 * 2880 * 2
    big = C.get("llama-3.2-vision-11b")
    assert storage_bytes_per_token(big) == 3 * 4096 * 2


def test_checkpoint_roundtrip(tmp_path):
    import jax
    from repro.checkpoint import ckpt
    from repro.models import transformer as T
    cfg = C.get_reduced("glm4-9b")
    params = T.init(cfg, jax.random.key(0))
    p = str(tmp_path / "m.npz")
    ckpt.save(p, params, metadata={"arch": cfg.name})
    loaded = ckpt.load(p, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_deploy_gate():
    from repro.checkpoint.ckpt import DraftDeployGate
    gate = DraftDeployGate({"w": 1})
    assert gate.offer({"w": 2}, eval_acc=0.6, baseline_acc=0.5)
    assert gate.current()[0] == {"w": 2} and gate.version == 1
    assert not gate.offer({"w": 3}, eval_acc=0.4, baseline_acc=0.5)
    assert gate.current()[0] == {"w": 2} and gate.version == 1
