"""Training-signal extraction: store/extractor mechanics, deferred
transfer, storage accounting (paper Table 1 math)."""
import numpy as np
import jax.numpy as jnp
import pytest

import repro.configs as C
from repro.core.signals import (SIGNAL_SCHEMA, SignalBatch, SignalExtractor,
                                SignalStore, load_shard, pack_batches,
                                storage_bytes_per_token, unpack_batches)


def _offer(ex, rid, n, fdim=6, accept=None):
    feats = jnp.arange(n * fdim, dtype=jnp.float32).reshape(1, n, fdim)
    toks = jnp.arange(n, dtype=jnp.int32)[None]
    mask = jnp.ones((1, n), bool) if accept is None else jnp.asarray(
        accept)[None]
    ex.offer([rid], feats, toks, mask)


def test_extractor_windows_and_flush():
    store = SignalStore()
    ex = SignalExtractor(store, window=8)
    for _ in range(5):
        _offer(ex, rid=1, n=4)
    ex.flush()
    assert store.peek_count() == 2          # 20 accepted -> 2 full windows
    batches = store.drain()
    assert all(b.feats.shape == (8, 6) for b in batches)
    assert store.peek_count() == 0


def test_extractor_deferred_one_step():
    """The offer() at step t is collected at step t+1 (overlap model)."""
    store = SignalStore()
    ex = SignalExtractor(store, window=4)
    _offer(ex, 1, 4)
    assert store.peek_count() == 0          # still pending on device
    _offer(ex, 1, 4)
    assert store.peek_count() == 1          # previous step collected


def test_extractor_respects_mask_and_enable():
    store = SignalStore()
    ex = SignalExtractor(store, window=4)
    _offer(ex, 1, 4, accept=[True, False, True, False])
    ex.enabled = False
    _offer(ex, 1, 4)                        # collects previous (2 rows)
    ex.flush()
    assert store.total_added == 0           # 2 rows < window, no force emit


def test_store_spill_roundtrip_lossless(tmp_path):
    """spill → load is a lossless, schema-tagged round trip: ragged
    window lengths and per-batch dtypes survive bit-exactly (the old
    stacked format required uniform shapes and one dtype)."""
    store = SignalStore(spill_dir=str(tmp_path))
    batches = [
        SignalBatch(np.arange(24, dtype=np.float32).reshape(4, 6),
                    np.arange(4, dtype=np.int32)),
        SignalBatch(np.arange(54, dtype=np.float16).reshape(9, 6),
                    np.arange(9, dtype=np.int64)),     # ragged residual
        SignalBatch(np.zeros((2, 6), np.float64),
                    np.array([7, 9], np.int32)),
    ]
    for b in batches:
        store.add(b)
    path = store.spill("t0")
    assert path is not None and store.peek_count() == 0
    with np.load(path) as data:
        assert str(np.asarray(data["__schema__"])) == SIGNAL_SCHEMA
    loaded = load_shard(path)
    assert len(loaded) == len(batches)
    for orig, back in zip(batches, loaded):
        np.testing.assert_array_equal(orig.feats, back.feats)
        np.testing.assert_array_equal(orig.tokens, back.tokens)
        assert orig.feats.dtype == back.feats.dtype
        assert orig.tokens.dtype == back.tokens.dtype
    # and back into a store (offline replay path)
    store2 = SignalStore()
    assert store2.load(path) == 3 and store2.peek_count() == 3


def test_spill_empty_store_and_no_dir(tmp_path):
    assert SignalStore().spill("t") is None          # no spill dir
    assert SignalStore(spill_dir=str(tmp_path)).spill("t") is None


def test_pack_unpack_validation():
    batches = [SignalBatch(np.ones((4, 6), np.float32),
                           np.arange(4, dtype=np.int32))]
    arrays = pack_batches(batches)
    # truncated shard: counted batch missing
    broken = dict(arrays)
    del broken["feats_000000"]
    with pytest.raises(ValueError, match="truncated"):
        unpack_batches(broken)
    # unknown schema tag
    wrong = dict(arrays)
    wrong["__schema__"] = np.asarray("tide-signals/v999")
    with pytest.raises(ValueError, match="schema"):
        unpack_batches(wrong)
    # not a shard at all
    with pytest.raises(ValueError, match="not a signal shard"):
        unpack_batches({"junk": np.zeros(3)})


def test_legacy_stacked_shard_still_loads(tmp_path):
    """Pre-schema shards (one stacked feats/tokens pair) keep loading."""
    path = str(tmp_path / "legacy.npz")
    np.savez_compressed(path,
                        feats=np.ones((3, 4, 6), np.float32),
                        tokens=np.tile(np.arange(4, dtype=np.int32), (3, 1)))
    loaded = load_shard(path)
    assert len(loaded) == 3
    assert all(b.feats.shape == (4, 6) for b in loaded)


def test_storage_math_matches_paper_scale():
    """Table 1: per-token hidden-state bytes = 3 · d_model · 2 (bf16).
    gpt-oss-120b: 2880·3·2 = 17.3 KB/token — TIDE's 0.19 TB buffer vs
    SpecForge's 4.66 TB full-dataset store is a ~24× ratio, matching the
    ratio reproduced in benchmarks/bench_storage.py."""
    cfg = C.get("gpt-oss-120b")
    assert storage_bytes_per_token(cfg) == 3 * 2880 * 2
    big = C.get("llama-3.2-vision-11b")
    assert storage_bytes_per_token(big) == 3 * 4096 * 2


def test_checkpoint_roundtrip(tmp_path):
    import jax
    from repro.checkpoint import ckpt
    from repro.models import transformer as T
    cfg = C.get_reduced("glm4-9b")
    params = T.init(cfg, jax.random.key(0))
    p = str(tmp_path / "m.npz")
    ckpt.save(p, params, metadata={"arch": cfg.name})
    loaded = ckpt.load(p, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_deploy_gate():
    from repro.checkpoint.ckpt import DraftDeployGate
    gate = DraftDeployGate({"w": 1})
    assert gate.offer({"w": 2}, eval_acc=0.6, baseline_acc=0.5)
    assert gate.current()[0] == {"w": 2} and gate.version == 1
    assert not gate.offer({"w": 3}, eval_acc=0.4, baseline_acc=0.5)
    assert gate.current()[0] == {"w": 2} and gate.version == 1
