"""Shared test fixtures. NOTE: no XLA_FLAGS here — tests must see the
real single CPU device (the dry-run sets its own flags in-process)."""
import resource

import jax
import pytest

from repro.models.config import (ATTN, CROSS, FFN_GELU, FFN_MOE, FFN_SWIGLU,
                                 MAMBA, MLA, RWKV6, BlockDef, ModelConfig)

# LLVM's backend_compile recurses deeply on large fused programs; with
# the default 8 MB soft stack limit a big compile late in the full-tier
# session segfaults the interpreter.  The main-thread stack grows on
# demand against the soft limit, so raising it here (hard limit permits)
# covers every compile the suite triggers.  512 MB proved insufficient
# once the suite grew past ~300 tests (the depth LLVM reaches scales
# with how much the session has already compiled), so take the hard
# limit outright — unlimited where the container allows it.
_soft, _hard = resource.getrlimit(resource.RLIMIT_STACK)
_want = 512 * 1024 * 1024
if _soft != resource.RLIM_INFINITY:
    if _hard == resource.RLIM_INFINITY:
        resource.setrlimit(resource.RLIMIT_STACK, (_hard, _hard))
    elif _hard >= _want and _soft < _want:
        resource.setrlimit(resource.RLIMIT_STACK, (_want, _hard))


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running end-to-end test (full TIDE "
        "adaptation dynamics / dry-run lowering)")


def tiny_cfg(**kw):
    base = dict(name="t", num_layers=2, d_model=64, num_heads=4,
                num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=97,
                dtype="float32", chunk_len=8, attn_block_q=32,
                attn_block_kv=32)
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="session")
def rngs():
    return jax.random.split(jax.random.key(0), 8)


MIXER_CFGS = {
    "dense": tiny_cfg(),
    "mla": tiny_cfg(name="mla", pattern=(BlockDef(MLA, FFN_SWIGLU),),
                    q_lora_rank=32, kv_lora_rank=32, qk_nope_head_dim=16,
                    qk_rope_head_dim=8, v_head_dim=16, num_kv_heads=4),
    # capacity_factor 8 → no token dropping, so decode ≡ prefill exactly
    # (capacity-based MoE drops are batch-composition-dependent by design)
    "moe": tiny_cfg(name="moe", pattern=(BlockDef(ATTN, FFN_MOE),),
                    num_experts=4, experts_per_tok=2, moe_d_ff=64,
                    num_shared_experts=1, capacity_factor=8.0),
    "mamba": tiny_cfg(name="mamba", pattern=(BlockDef(MAMBA, FFN_SWIGLU),)),
    "rwkv": tiny_cfg(name="rwkv", pattern=(BlockDef(RWKV6, FFN_SWIGLU),),
                     rwkv_head_dim=16),
    "vlm": tiny_cfg(name="vlm", num_layers=2,
                    pattern=(BlockDef(ATTN), BlockDef(CROSS)),
                    num_image_tokens=8),
    "audio": tiny_cfg(name="audio",
                      pattern=(BlockDef(ATTN, FFN_GELU, cross=True),),
                      encoder_layers=2, decoder_len=16),
}


def extra_for(cfg, batch, seq, key):
    if cfg.num_image_tokens:
        return {"image_embeds": jax.random.normal(
            key, (batch, cfg.num_image_tokens, cfg.d_model),
            cfg.act_dtype)}
    if cfg.encoder_layers:
        return {"frames": jax.random.normal(key, (batch, seq, cfg.d_model),
                                            cfg.act_dtype)}
    return {}
