"""shard_map MoE (§Perf H-B3): correctness vs the SPMD sort baseline.

On the single-CPU test mesh the shard_map path is degenerate (one token
shard, no expert exchange) and must match moe_sort EXACTLY in the
no-drop regime; the multi-shard behaviour is exercised by the dry-run
(granite/deepseek prefill with --moe-impl shard_map)."""
import dataclasses

import jax
import numpy as np
import pytest

from conftest import MIXER_CFGS
from repro.launch.mesh import make_demo_mesh
from repro.models import moe as moe_mod
from repro.models import transformer as T
from repro.models.moe_sm import moe_shard_map


@pytest.fixture(scope="module")
def setup():
    cfg = MIXER_CFGS["moe"]
    params = T.init(cfg, jax.random.key(0))
    p = jax.tree.map(lambda x: x[0], params["body"]["pos0"]["moe"])
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    return cfg, p, x


def test_matches_sort_no_expert_parallel(setup):
    cfg, p, x = setup
    ref, aux_r = moe_mod.moe_sort(cfg, p, x)
    out, aux = moe_shard_map(cfg, p, x, make_demo_mesh(),
                             token_axes=("data",), expert_axis=None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    assert float(aux) == pytest.approx(float(aux_r), rel=1e-5)


def test_matches_sort_with_expert_axis(setup):
    """expert_axis of size 1 == degenerate expert parallelism: the
    all_to_all round-trip must be an identity."""
    cfg, p, x = setup
    mesh = make_demo_mesh((1, 1), ("data", "model"))
    ref, _ = moe_mod.moe_sort(cfg, p, x)
    out, _ = moe_shard_map(cfg, p, x, mesh, token_axes=("data",),
                           expert_axis="model")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_dispatch_through_moe_entry(setup):
    """moe(impl='shard_map') uses the hints context's mesh; without one
    it falls back to the sort path."""
    cfg, p, x = setup
    ref, _ = moe_mod.moe_sort(cfg, p, x)
    out, _ = moe_mod.moe(cfg, p, x, impl="shard_map")   # no context
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    from repro.launch import sharding as sh
    from repro.models import hints
    with hints.activate(make_demo_mesh(), sh.EXPERT_PARALLEL_RULES):
        out2, _ = moe_mod.moe(cfg, p, x, impl="shard_map")
    np.testing.assert_allclose(np.asarray(out2), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_tight_capacity_drops_locally(setup):
    """Under tight capacity the local-dispatch drops are per-shard; on a
    single shard they must equal the global-sort drops."""
    cfg, p, x = setup
    cfg2 = dataclasses.replace(cfg, capacity_factor=0.5)
    ref, _ = moe_mod.moe_sort(cfg2, p, x)
    out, _ = moe_shard_map(cfg2, p, x, make_demo_mesh(),
                           token_axes=("data",), expert_axis=None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
