"""Algorithm 1 controller + Eq. 2–5 adaptive model: unit + hypothesis
property tests on the system's control invariants."""

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # not in the container image - deterministic shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.adaptive import (AdaptiveDrafter,
                                 alpha_from_accept_len,
                                 expected_accept_len, min_accept_len_for_gain,
                                 practical_speedup,
                                 PAPER_PROFILES)
from repro.core.controller import Decision, TrainingController


# ------------------------------------------------------------- Eq. 2–5
@given(st.floats(0.0, 0.999), st.integers(1, 8))
def test_expected_accept_len_bounds(alpha, gamma):
    ell = expected_accept_len(alpha, gamma)
    assert 1.0 <= ell <= gamma + 1 + 1e-9


@given(st.floats(0.0, 0.99), st.floats(0.0, 0.99), st.integers(1, 8))
def test_expected_accept_len_monotone(a1, a2, gamma):
    lo, hi = sorted((a1, a2))
    assert expected_accept_len(lo, gamma) <= \
        expected_accept_len(hi, gamma) + 1e-9


@given(st.floats(1.001, 3.9), st.integers(3, 6))
def test_alpha_inversion_roundtrip(ell, gamma):
    alpha = alpha_from_accept_len(ell, gamma)
    assert abs(expected_accept_len(alpha, gamma) - ell) < 1e-3


def test_practical_speedup_matches_paper_regime():
    """With the paper's gpt-oss-120b profile (Table 5), speculation helps
    at small batch and fades at large batch (Figs. 4/8)."""
    prof = PAPER_PROFILES["gpt-oss-120b"]
    alpha = 0.65                      # ~accept len 2.4 at γ=3 (Table 4)
    s1 = practical_speedup(alpha, 3, prof, 1)
    s64 = practical_speedup(alpha, 3, prof, 64)
    s512 = practical_speedup(alpha, 3, prof, 512)
    assert s1 > 1.15                  # clear win at b=1
    assert s1 > s64 > s512            # degrades with batch (Fig. 4)


def test_beta_grows_with_batch():
    prof = PAPER_PROFILES["gpt-oss-120b"]
    betas = [prof.beta(b, 3) for b in (1, 8, 64, 128)]
    assert betas[0] < betas[-1]
    assert betas[-1] > 1.5            # decidedly not memory-bound at 128


def test_min_accept_len_threshold_consistency():
    prof = PAPER_PROFILES["llama-3.3-70b-instruct"]
    for b in (1, 16, 128):
        thr = min_accept_len_for_gain(3, prof, b)
        alpha = alpha_from_accept_len(min(thr, 3.99), 3)
        s = practical_speedup(alpha, 3, prof, b)
        assert abs(s - 1.0) < 0.05    # threshold sits at breakeven


def test_adaptive_drafter_toggles():
    prof = PAPER_PROFILES["gpt-oss-120b"]
    d = AdaptiveDrafter(prof, gamma=3)
    assert d.update(batch=1, accept_len_ema=2.5) is True
    assert d.update(batch=256, accept_len_ema=1.05) is False


# --------------------------------------------------------- Algorithm 1
def test_controller_init_phase():
    c = TrainingController(n_init=4)
    for _ in range(3):
        assert c.observe(0.5) == Decision.NONE
        assert c.alpha_short is None
    c.observe(0.5)
    assert c.alpha_short == pytest.approx(0.5)
    assert c.alpha_long == pytest.approx(0.5)


def test_controller_detects_shift_and_triggers():
    c = TrainingController(n_init=2, epsilon=0.02, n_threshold=10,
                           lambda_short=0.5, lambda_long=0.99)
    c.observe(0.8)
    c.observe(0.8)
    # distribution shift: acceptance collapses
    decisions = [c.observe(0.1, n_new_samples=0) for _ in range(4)]
    assert Decision.START_COLLECTION in decisions
    assert c.collection_enabled
    # samples accumulate -> training triggers
    d = None
    for _ in range(5):
        d = c.observe(0.1, n_new_samples=4)
        if d == Decision.TRIGGER_TRAINING:
            break
    assert d == Decision.TRIGGER_TRAINING


def test_controller_deploy_gate():
    c = TrainingController(n_init=1)
    c.observe(0.5)
    c.collection_enabled = True
    c.observe(0.2, n_new_samples=8)
    base = c.alpha_train
    assert base == pytest.approx(0.2)
    assert c.training_result(alpha_eval=0.5) is True       # improved
    assert c.stored_samples == 0                            # buffer reset
    c.collection_enabled = True
    c.observe(0.4, n_new_samples=8)
    assert c.training_result(alpha_eval=0.1) is False       # regressed
    assert c.collection_enabled is False                    # Alg.1 disable


@given(st.lists(st.floats(0.0, 1.0), min_size=8, max_size=60),
       st.floats(0.5, 0.95), st.floats(0.96, 0.999))
@settings(max_examples=40, deadline=None)
def test_controller_ema_invariants(alphas, lam_s, lam_l):
    """EMAs stay within [0, 1]; short EMA tracks recent values faster."""
    c = TrainingController(n_init=4, lambda_short=lam_s, lambda_long=lam_l,
                           n_threshold=10**9)
    for a in alphas:
        c.observe(a)
    if c.alpha_short is not None:
        assert 0.0 <= c.alpha_short <= 1.0
        assert 0.0 <= c.alpha_long <= 1.0
    # a sustained collapse must eventually flip collection on
    for _ in range(200):
        c.observe(0.0)
    if max(alphas[:4] or [0]) > 0.2:
        assert c.collection_enabled


def test_hetero_allocation_model():
    from repro.core.hetero import (PAPER_DEVICES, best_split,
                                   paper_figure12_grid, plan_tpu_submesh)
    # paper Fig. 12 anchor points
    r = best_split(PAPER_DEVICES["H100"], PAPER_DEVICES["MI250"], 4, 1,
                   1.3)
    assert r["relative_throughput"] == pytest.approx(1.26, abs=0.02)
    r2 = best_split(PAPER_DEVICES["MI300X"], PAPER_DEVICES["MI250"], 2, 1,
                    1.1)
    assert r2["relative_throughput"] == pytest.approx(0.99, abs=0.02)
    assert not r2["use_tide"]
    grid = paper_figure12_grid()
    assert len(grid) == 12
    plan = plan_tpu_submesh(256, s=1.3)
    assert plan.train_chips > 0 and plan.relative_throughput() > 1.0
