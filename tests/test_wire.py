"""Wire-protocol codec: frame round trips, transactional rejection of
malformed frames (truncated / corrupt / oversize / interleaved), and the
payload codecs shared with the signal-shard schema.

Everything here is pure bytes + numpy — no sockets, no jit — so the
whole file runs in the fast tier.  The live socket/subprocess protocol
is exercised in ``test_fleet.py``.
"""
import zlib

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                     # pragma: no cover
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.signals import SignalBatch
from repro.fleet import wire
from repro.fleet.wire import (FRAME_NAMES, FT_BYE, FT_DRAFT, FT_HELLO,
                              FT_SIGNALS, HEADER, MAX_PAYLOAD, WIRE_VERSION,
                              FrameReader, WireError, decode_draft,
                              decode_json, decode_npz, decode_signals,
                              draft_payload, encode_frame, json_payload,
                              signals_payload)


def _drain(reader, data):
    return list(reader.feed(data))


# ------------------------------------------------------------ round trips
def test_frame_roundtrip_all_types_and_empty_payload():
    reader = FrameReader()
    frames = []
    for ftype in FRAME_NAMES:
        payload = b"" if ftype == FT_BYE else bytes([ftype]) * (7 * ftype)
        frames.append((ftype, payload))
    blob = b"".join(encode_frame(f, p) for f, p in frames)
    out = _drain(reader, blob)
    assert [(f, p) for f, _, p in out] == frames
    assert all(flags == 0 for _, flags, _ in out)
    assert reader.pending_bytes == 0


def test_frame_roundtrip_byte_at_a_time():
    """Arbitrary chunking must not matter: feeding one byte at a time
    yields exactly the same frames, each completing only on its final
    byte (no partial yields)."""
    blob = encode_frame(FT_HELLO, b"x" * 37) + encode_frame(FT_BYE)
    reader = FrameReader()
    out = []
    for i, b in enumerate(blob):
        got = _drain(reader, bytes([b]))
        out.extend(got)
        if got:
            assert i in (len(blob) - 17, len(blob) - 1)
    assert [(f, p) for f, _, p in out] == [(FT_HELLO, b"x" * 37),
                                           (FT_BYE, b"")]


def test_interleaved_frames_one_buffer_split_mid_header():
    """Multiple frames in one feed, with the cut landing mid-header of
    the trailing frame: the complete frames come out, the tail stays
    buffered, and the next feed completes it."""
    a = encode_frame(FT_HELLO, b"one")
    b = encode_frame(FT_SIGNALS, b"two-two")
    c = encode_frame(FT_BYE)
    blob = a + b + c
    cut = len(a) + len(b) + 9           # 9 bytes into c's 16-byte header
    reader = FrameReader()
    out = _drain(reader, blob[:cut])
    assert [(f, p) for f, _, p in out] == [(FT_HELLO, b"one"),
                                           (FT_SIGNALS, b"two-two")]
    assert reader.pending_bytes == 9    # untouched partial header
    out = _drain(reader, blob[cut:])
    assert [(f, p) for f, _, p in out] == [(FT_BYE, b"")]


def test_truncated_frame_consumes_nothing_and_is_not_an_error():
    reader = FrameReader()
    blob = encode_frame(FT_HELLO, b"payload")
    assert _drain(reader, blob[:-1]) == []
    assert reader.pending_bytes == len(blob) - 1
    out = _drain(reader, blob[-1:])     # truncation is just backpressure
    assert [(f, p) for f, _, p in out] == [(FT_HELLO, b"payload")]


# --------------------------------------------------------- malformed input
def _header(magic=wire.MAGIC, version=WIRE_VERSION, ftype=FT_HELLO,
            flags=0, length=0, crc=zlib.crc32(b"")):
    return HEADER.pack(magic, version, ftype, flags, length, crc)


@pytest.mark.parametrize("blob,match", [
    (_header(magic=b"EDIT"), "bad magic"),
    (_header(version=WIRE_VERSION + 1), "unsupported wire version"),
    (_header(ftype=99), "unknown frame type"),
    (_header(flags=0x8000), "reserved flags"),
    (_header(length=MAX_PAYLOAD + 1), "exceeds MAX_PAYLOAD"),
])
def test_bad_headers_rejected_and_poison(blob, match):
    reader = FrameReader()
    with pytest.raises(WireError, match=match):
        _drain(reader, blob)
    # poisoned: nothing after the corruption is trusted
    with pytest.raises(WireError, match="poisoned"):
        _drain(reader, encode_frame(FT_BYE))


def test_crc_mismatch_rejected():
    blob = bytearray(encode_frame(FT_HELLO, b"hello wire"))
    blob[-3] ^= 0xFF                    # flip a payload byte
    reader = FrameReader()
    with pytest.raises(WireError, match="CRC"):
        _drain(reader, bytes(blob))
    with pytest.raises(WireError, match="poisoned"):
        _drain(reader, b"")


def test_valid_frames_before_corruption_still_yielded():
    """A corrupt frame must not smear backwards: frames fully decoded
    from the same feed() call before the bad header still come out
    (generator yields them before raising)."""
    good = encode_frame(FT_HELLO, b"ok")
    reader = FrameReader()
    out = []
    with pytest.raises(WireError, match="bad magic"):
        for frame in reader.feed(good + _header(magic=b"XXXX")):
            out.append(frame)
    assert [(f, p) for f, _, p in out] == [(FT_HELLO, b"ok")]


def test_encode_frame_rejects_bad_type_and_oversize():
    with pytest.raises(WireError, match="unknown frame type"):
        encode_frame(42)
    # fake an oversize payload without allocating 256 MiB
    class _Huge(bytes):
        def __len__(self):
            return MAX_PAYLOAD + 1
    with pytest.raises(WireError, match="exceeds"):
        encode_frame(FT_HELLO, _Huge())


@settings(max_examples=30)
@given(st.integers(min_value=0, max_value=2048),
       st.integers(min_value=1, max_value=64),
       st.integers(min_value=0, max_value=10_000))
def test_fuzz_roundtrip_any_chunking(size, chunk, seed):
    """Property: any payload, cut into any chunk size, round-trips."""
    rng = np.random.RandomState(seed)
    payload = rng.bytes(size)
    blob = encode_frame(FT_SIGNALS, payload)
    reader = FrameReader()
    out = []
    for i in range(0, len(blob), chunk):
        out.extend(_drain(reader, blob[i:i + chunk]))
    assert [(f, p) for f, _, p in out] == [(FT_SIGNALS, payload)]
    assert reader.pending_bytes == 0


@settings(max_examples=30)
@given(st.integers(min_value=1, max_value=512),
       st.integers(min_value=0, max_value=10_000))
def test_fuzz_payload_corruption_never_yields(size, seed):
    """Property: flipping any payload byte kills the frame — WireError,
    zero frames yielded, reader poisoned.  (Header fields have their own
    dedicated rejection tests above.)"""
    rng = np.random.RandomState(seed)
    payload = rng.bytes(size)
    blob = bytearray(encode_frame(FT_DRAFT, payload))
    blob[HEADER.size + rng.randint(size)] ^= 1 + rng.randint(255)
    reader = FrameReader()
    out = []
    with pytest.raises(WireError):
        for frame in reader.feed(bytes(blob)):
            out.append(frame)
    assert out == []


# ---------------------------------------------------------------- payloads
def test_json_payload_roundtrip_and_rejection():
    obj = {"a": 1, "b": [1, 2], "c": {"d": None}}
    assert decode_json(json_payload(obj)) == obj
    with pytest.raises(WireError, match="bad json"):
        decode_json(b"\xff\xfe not json")
    with pytest.raises(WireError, match="must be an object"):
        decode_json(b"[1, 2]")


def test_npz_payload_rejects_garbage():
    with pytest.raises(WireError, match="bad npz"):
        decode_npz(b"PK\x03\x04 definitely not an npz archive")


def test_signals_payload_matches_shard_schema():
    """A SIGNALS frame body IS a spill shard plus ``__baseline__`` —
    dtypes and ragged shapes survive, and the baseline rides along."""
    batches = [
        SignalBatch(np.arange(24, dtype=np.float32).reshape(4, 6),
                    np.arange(4, dtype=np.int32)),
        SignalBatch(np.ones((9, 6), np.float16),
                    np.arange(9, dtype=np.int64)),
    ]
    back, baseline = decode_signals(signals_payload(batches, baseline=0.625))
    assert baseline == 0.625
    assert len(back) == 2
    for orig, got in zip(batches, back):
        np.testing.assert_array_equal(orig.feats, got.feats)
        np.testing.assert_array_equal(orig.tokens, got.tokens)
        assert orig.feats.dtype == got.feats.dtype
        assert orig.tokens.dtype == got.tokens.dtype
    # a non-shard npz is a wire error, not a ValueError leak
    with pytest.raises(WireError, match="not a signal shard"):
        decode_signals(wire.npz_payload({"junk": np.zeros(3)}))


def test_draft_payload_roundtrip_nested_tree():
    dparams = {"fc": {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
                      "b": np.zeros(3, np.float32)},
               "norm": {"scale": np.ones(3, np.float16)}}
    seq, tree, acc = decode_draft(draft_payload(11, dparams, 0.75))
    assert seq == 11 and acc == 0.75
    assert set(tree) == {"fc", "norm"}
    np.testing.assert_array_equal(tree["fc"]["w"], dparams["fc"]["w"])
    np.testing.assert_array_equal(tree["fc"]["b"], dparams["fc"]["b"])
    assert tree["norm"]["scale"].dtype == np.float16


def test_draft_payload_missing_fields_rejected():
    with pytest.raises(WireError, match="missing"):
        decode_draft(wire.npz_payload(
            {"p/w": np.zeros(2), "__eval_acc__": np.asarray(0.5)}))
    with pytest.raises(WireError, match="no parameters"):
        decode_draft(wire.npz_payload(
            {"__seq__": np.asarray(1), "__eval_acc__": np.asarray(0.5)}))


def test_config_dict_roundtrip():
    from conftest import tiny_cfg
    from repro.models.config import MLA, BlockDef, FFN_SWIGLU
    cfg = tiny_cfg(name="wire", pattern=(BlockDef(MLA, FFN_SWIGLU),),
                   capture_layers=(0, 1, 1))
    back = wire.config_from_dict(wire.config_to_dict(cfg))
    assert back == cfg


def test_parse_endpoint():
    assert wire.parse_endpoint("unix:/tmp/x.sock") == ("unix", "/tmp/x.sock")
    assert wire.parse_endpoint("tcp:127.0.0.1:9000") == \
        ("tcp", ("127.0.0.1", 9000))
    for bad in ("unix:", "tcp:nohostport", "http://x", "spawn"):
        with pytest.raises(ValueError):
            wire.parse_endpoint(bad)
