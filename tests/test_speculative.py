"""Speculative decoding correctness: greedy exactness, stochastic
distribution preservation, verification/commit bookkeeping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.core import eagle, speculative as spec
from repro.models import transformer as T


@pytest.fixture(scope="module")
def setup():
    cfg = C.get("tide-tiny")
    dcfg = eagle.draft_config(cfg)
    params = T.init(cfg, jax.random.key(0))
    dparams = eagle.draft_init(dcfg, jax.random.key(1))
    return cfg, dcfg, params, dparams


def _spec_generate(cfg, dcfg, params, dparams, toks, n_steps, gamma=3,
                   greedy=True, seed=0):
    B, S = toks.shape
    MAX = S + (gamma + 1) * (n_steps + 2)
    pre = T.prefill(cfg, params, toks, max_len=MAX)
    first = pre["logits"].argmax(-1).astype(jnp.int32)
    dcache = eagle.init_draft_cache(dcfg, B, MAX)
    dcache = spec.seed_draft_cache(cfg, dcfg, params, dparams, dcache, pre,
                                   toks)
    carry = spec.init_carry(cfg, dcfg, pre, first, gamma)
    cache = pre["cache"]
    seqs = [[int(first[b])] for b in range(B)]
    for i in range(n_steps):
        out = spec.spec_decode_step(cfg, dcfg, params, dparams, cache,
                                    dcache, carry, gamma=gamma,
                                    greedy=greedy,
                                    key=jax.random.key(seed + i))
        cache, dcache, carry = out["cache"], out["dcache"], out["carry"]
        for b in range(B):
            n = int(out["n_commit"][b])
            seqs[b].extend(int(t) for t in out["tokens"][b, :n])
    return seqs


def _greedy_generate(cfg, params, toks, n_tokens):
    B, S = toks.shape
    pre = T.prefill(cfg, params, toks, max_len=S + n_tokens + 4)
    cache = pre["cache"]
    cur = pre["logits"].argmax(-1).astype(jnp.int32)
    seqs = [[int(cur[b])] for b in range(B)]
    for _ in range(n_tokens):
        out = spec.plain_decode_step(cfg, params, cache, cur)
        cache, cur = out["cache"], out["token"]
        for b in range(B):
            seqs[b].append(int(cur[b]))
    return seqs


@pytest.mark.slow
def test_greedy_spec_exactness(setup):
    """Speculative greedy output ≡ autoregressive greedy output."""
    cfg, dcfg, params, dparams = setup
    toks = jax.random.randint(jax.random.key(2), (3, 20), 0,
                              cfg.vocab_size)
    spec_seqs = _spec_generate(cfg, dcfg, params, dparams, toks, 8)
    ref_seqs = _greedy_generate(cfg, params, toks, 40)
    for b in range(3):
        n = len(spec_seqs[b])
        assert spec_seqs[b] == ref_seqs[b][:n], f"req {b} diverged"


def test_verify_greedy_unit():
    V = 11
    tl = jnp.zeros((1, 4, V)).at[0, 0, 3].set(9.).at[0, 1, 5].set(9.) \
        .at[0, 2, 7].set(9.).at[0, 3, 2].set(9.)
    # drafts match at 0,1 then diverge
    n, bonus = spec.verify_greedy(tl, jnp.array([[3, 5, 9]]))
    assert int(n[0]) == 2 and int(bonus[0]) == 7
    # all match -> bonus from the last position
    n, bonus = spec.verify_greedy(tl, jnp.array([[3, 5, 7]]))
    assert int(n[0]) == 3 and int(bonus[0]) == 2
    # immediate mismatch
    n, bonus = spec.verify_greedy(tl, jnp.array([[4, 5, 7]]))
    assert int(n[0]) == 0 and int(bonus[0]) == 3


def test_verify_sample_preserves_distribution():
    """Committed first tokens from stochastic verification follow the
    target distribution regardless of a (mismatched) draft."""
    V, N = 8, 4000
    key = jax.random.key(0)
    t_logits = jnp.array([0.5, 2.0, -1.0, 0.0, 1.0, -2.0, 0.3, 0.7])
    d_logits = jnp.array([2.0, -1.0, 0.5, 1.5, -0.5, 0.0, 1.0, -2.0])
    tl = jnp.broadcast_to(t_logits, (N, 4, V))
    dl = jnp.broadcast_to(d_logits, (N, 3, V))
    keys = jax.random.split(key, N)

    def one(k):
        kd, kv = jax.random.split(k)
        draft = jax.random.categorical(kd, dl[0])       # (3,)
        n_acc, bonus = spec.verify_sample(kv, tl[:1], dl[:1],
                                          draft[None])
        first = jnp.where(n_acc[0] > 0, draft[0], bonus[0])
        return first

    firsts = jax.vmap(one)(keys)
    emp = np.bincount(np.asarray(firsts), minlength=V) / N
    expected = np.asarray(jax.nn.softmax(t_logits))
    # chi-square-ish bound: max deviation small for N=4000
    assert np.max(np.abs(emp - expected)) < 0.035, (emp, expected)


def test_spec_commit_bookkeeping(setup):
    cfg, dcfg, params, dparams = setup
    B, S, G = 2, 12, 3
    toks = jax.random.randint(jax.random.key(3), (B, S), 0, cfg.vocab_size)
    pre = T.prefill(cfg, params, toks, max_len=64)
    first = pre["logits"].argmax(-1).astype(jnp.int32)
    dcache = eagle.init_draft_cache(dcfg, B, 64)
    dcache = spec.seed_draft_cache(cfg, dcfg, params, dparams, dcache, pre,
                                   toks)
    assert dcache["lengths"].tolist() == [S - 1, S - 1]
    carry = spec.init_carry(cfg, dcfg, pre, first, G)
    out = spec.spec_decode_step(cfg, dcfg, params, dparams, pre["cache"],
                                dcache, carry, gamma=G)
    n = np.asarray(out["n_commit"])
    assert ((1 <= n) & (n <= G + 1)).all()
    assert np.asarray(out["cache"]["lengths"]).tolist() == \
        (S + n).tolist()
    # draft cache advanced by exactly the pairs ingested (1 first round)
    assert out["dcache"]["lengths"].tolist() == [S, S]
    # accept_mask consistent with n_commit
    am = np.asarray(out["accept_mask"])
    assert (am.sum(1) == n).all()


@pytest.mark.slow
def test_sampled_spec_runs(setup):
    cfg, dcfg, params, dparams = setup
    toks = jax.random.randint(jax.random.key(4), (2, 16), 0,
                              cfg.vocab_size)
    seqs = _spec_generate(cfg, dcfg, params, dparams, toks, 4,
                          greedy=False, seed=11)
    assert all(len(s) >= 5 for s in seqs)
    assert all(0 <= t < cfg.vocab_size for s in seqs for t in s)
