"""Bounded serving-statistics primitives (serving/stats.py).

Pins the ``Peak`` lazy-max regression (all-negative streams must report
their true negative max, not 0.0) and checks the P² streaming quantile
estimator against ``np.percentile`` — exactly on the first five
observations (the estimator's exact path), by rank error afterwards
(P² keeps five markers, so its estimate must sit at the right *rank*
of the stream even though the height is approximate).
"""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # not in the container image - deterministic shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.serving.stats import P2Quantile, Peak, Ring


# ------------------------------------------------------------------ Peak
def test_peak_all_negative_stream():
    p = Peak()
    for x in (-5.0, -2.0, -9.0):
        p.add(x)
    assert p.max == -2.0          # not 0.0: the max must come from data
    assert p.mean == (-5.0 - 2.0 - 9.0) / 3
    assert p.n == 3


def test_peak_empty_is_stable():
    p = Peak()
    assert p.max == 0.0 and p.n == 0
    assert "Peak(" in repr(p)     # repr must not divide by zero


def test_peak_positive_stream():
    p = Peak()
    for x in (1.0, 7.0, 3.0):
        p.add(x)
    assert p.max == 7.0 and p.n == 3 and p.total == 11.0


@settings(max_examples=30)
@given(st.lists(st.floats(-1e3, 1e3), min_size=1, max_size=40))
def test_peak_matches_numpy(xs):
    p = Peak()
    for x in xs:
        p.add(x)
    assert p.max == max(xs)
    assert abs(p.mean - np.mean(xs)) < 1e-6 * max(1.0, abs(np.mean(xs)))


# ------------------------------------------------------------------ Ring
def test_ring_drops_oldest():
    r = Ring(maxlen=4)
    for i in range(10):
        r.append(i)
    assert list(r) == [6, 7, 8, 9]


# ------------------------------------------------------- P2Quantile exact
def test_p2_exact_small_sample():
    """n <= 5 takes the exact path: linear interpolation identical to
    np.percentile's default method."""
    rng = np.random.default_rng(3)
    for n in range(1, 6):
        for q in (0.25, 0.5, 0.95):
            xs = rng.normal(size=n)
            est = P2Quantile(q)
            for x in xs:
                est.add(float(x))
            np.testing.assert_allclose(est.value,
                                       np.percentile(xs, 100 * q),
                                       rtol=1e-12, atol=1e-12)


def test_p2_empty_is_zero():
    assert P2Quantile(0.5).value == 0.0


# ---------------------------------------------------- P2Quantile property
def _rank_error(xs, q, est):
    """|empirical CDF at the estimate - q| — the natural accuracy metric
    for a quantile estimator (height error is distribution-dependent)."""
    xs = np.asarray(xs)
    return abs(np.mean(xs <= est) - q)


@settings(max_examples=15)
@given(st.integers(0, 2 ** 31 - 1), st.floats(0.1, 0.9))
def test_p2_tracks_numpy_rank(seed, q):
    """On continuous distributions the P² estimate must land within a
    few percentile points of ``np.percentile``'s rank (measured worst
    case over 1500 seed/quantile pairs: 0.038)."""
    rng = np.random.default_rng(seed)
    n = 400
    xs = (rng.uniform(-10, 10, n) if seed % 2 == 0
          else rng.lognormal(0.0, 1.0, n))      # heavy tail
    est = P2Quantile(float(q))
    for x in xs:
        est.add(float(x))
    assert _rank_error(xs, q, est.value) <= 0.06
    assert xs.min() <= est.value <= xs.max()
    ref = np.percentile(xs, 100 * q)
    assert abs(np.mean(xs <= est.value) - np.mean(xs <= ref)) <= 0.06


def test_p2_bimodal_stays_in_range():
    """Gapped (bimodal) streams are P²'s documented weak spot — the
    markers interpolate across the density gap, so rank error can reach
    ~0.2 there.  Pin only the containment contract: the estimate stays
    inside the sample range and on the correct side of the far
    cluster."""
    rng = np.random.default_rng(7)
    xs = np.concatenate([rng.normal(-5, 0.5, 200),
                         rng.normal(5, 0.5, 200)])
    for q, lo, hi in ((0.1, xs.min(), 0.0), (0.9, 0.0, xs.max())):
        est = P2Quantile(q)
        for x in xs:
            est.add(float(x))
        assert lo <= est.value <= hi


def test_p2_sorted_adversarial_stream():
    """Monotone input is the P² worst case; the markers must still
    track the quantile's rank."""
    xs = np.arange(1000, dtype=float)
    for q in (0.5, 0.95):
        est = P2Quantile(q)
        for x in xs:
            est.add(x)
        assert _rank_error(xs, q, est.value) <= 0.08
