"""Per-assigned-architecture smoke tests: a reduced same-family variant
runs one forward/train step and one decode step on CPU with finite
outputs and the right shapes (assignment requirement (f))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import transformer as T

ARCHS = configs.assigned()


def _extra(cfg, b, s, key):
    if cfg.num_image_tokens:
        return {"image_embeds": jax.random.normal(
            key, (b, cfg.num_image_tokens, cfg.d_model), cfg.act_dtype)}
    if cfg.encoder_layers:
        return {"frames": jax.random.normal(key, (b, s, cfg.d_model),
                                            cfg.act_dtype)}
    return {}


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_variant_limits(arch):
    cfg = configs.get_reduced(arch)
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4
    assert cfg.num_layers <= max(2, len(cfg.pattern) + len(cfg.prologue))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = configs.get_reduced(arch)
    key = jax.random.key(0)
    params = T.init(cfg, key)
    B, S = 2, 32
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": toks,
             **_extra(cfg, B, S, jax.random.key(2))}
    loss, metrics = T.forward_train(cfg, params, batch, remat=True)
    assert np.isfinite(float(loss)), f"{arch}: NaN train loss"
    assert 0.0 <= float(metrics["accuracy"]) <= 1.0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode(arch):
    cfg = configs.get_reduced(arch)
    params = T.init(cfg, jax.random.key(0))
    B, S, G = 2, 32, 3
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    extra = _extra(cfg, B, S, jax.random.key(2))
    out = T.prefill(cfg, params, toks, extra=extra, max_len=S + 8)
    assert out["logits"].shape == (B, cfg.vocab_size)
    assert out["captures"].shape == (B, S, 3 * cfg.d_model)
    assert np.isfinite(np.asarray(out["logits"])).all(), f"{arch}: NaN"
    blk = jax.random.randint(jax.random.key(3), (B, G + 1), 0,
                             cfg.vocab_size)
    dec = T.decode_step(cfg, params, out["cache"], blk)
    assert dec["logits"].shape == (B, G + 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(dec["logits"])).all(), f"{arch}: NaN"
    committed = T.commit_cache(cfg, dec["cache"],
                               jnp.array([1, G + 1], jnp.int32))
    assert committed["lengths"].tolist() == [S + 1, S + G + 1]


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The full (non-reduced) config must carry the exact assigned dims."""
    spec = {
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
        "deepseek-v3-671b": (61, 7168, 128, 128, None, 129280),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "rwkv6-3b": (32, 2560, None, None, 8960, 65536),
        "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
    }[arch]
    cfg = configs.get(arch)
    L, d, h, kv, ff, v = spec
    assert cfg.num_layers == L
    assert cfg.d_model == d
    if h is not None:
        assert cfg.num_heads == h
    if kv is not None:
        assert cfg.num_kv_heads == kv
    if ff is not None:
        assert (cfg.d_ff == ff or cfg.moe_hidden == ff)
    assert cfg.vocab_size == v
    assert cfg.citation


def test_moe_expert_counts():
    ds = configs.get("deepseek-v3-671b")
    assert ds.num_experts == 256 and ds.experts_per_tok == 8
    assert ds.num_shared_experts == 1 and ds.moe_hidden == 2048
    ja = configs.get("jamba-1.5-large-398b")
    assert ja.num_experts == 16 and ja.experts_per_tok == 2
    gr = configs.get("granite-moe-3b-a800m")
    assert gr.num_experts == 40 and gr.experts_per_tok == 8


def test_jamba_interleave_ratio():
    cfg = configs.get("jamba-1.5-large-398b")
    kinds = cfg.layer_kinds
    attn_layers = [i for i, b in enumerate(kinds) if b.mixer == "attn"]
    assert len(attn_layers) == 9            # 1:7 in every superblock of 8
    moe_layers = [b for b in kinds if b.ffn == "moe"]
    assert len(moe_layers) == 36            # every other layer


def test_vision_cross_layer_count():
    cfg = configs.get("llama-3.2-vision-11b")
    cross = [b for b in cfg.layer_kinds if b.mixer == "cross"]
    assert len(cross) == 8                  # every 5th of 40
