"""Disaggregated serving subsystem (repro/fleet): router + bus units,
the TrainerHost wire protocol over a socketpair (stub service — no
XLA), RemoteTrainingService against an in-process trainer host, and the
TrainingService failure paths the remote topology leans on.

Slow tier: real spawned trainer subprocess (drain parity, kill
degradation) and the N-replica ServingFleet end-to-end.
"""
import socket
import threading
import time
import types

import numpy as np
import pytest

from repro.core.signals import SignalBatch
from repro.core.transport import SignalChannel
from repro.fleet import FleetConfig, wire
from repro.fleet.bus import DraftVersionBus
from repro.fleet.remote import (RemoteDeploySource, RemoteSignalChannel,
                                RemoteTrainingService, _GateView)
from repro.fleet.router import FleetRouter, request_cost
from repro.fleet.trainer_main import TrainerHost
from repro.serving.request import Request
from repro.training.service import DraftVersion, TrainingService


def _batch(i, s=8, f=6):
    return SignalBatch(feats=np.full((s, f), i, np.float32),
                       tokens=np.full((s,), i, np.int32))


# ===================================================== config + router
def test_fleet_config_validation():
    assert not FleetConfig().enabled
    assert FleetConfig(replicas=2).enabled
    assert FleetConfig(trainer_endpoint="spawn").enabled
    with pytest.raises(ValueError):
        FleetConfig(replicas=-1)
    with pytest.raises(ValueError):
        FleetConfig(route="random")


def test_router_least_loaded_balances():
    r = FleetRouter(2, "least")
    big = Request(prompt=[1] * 8, max_new_tokens=100)
    small = Request(prompt=[1] * 8, max_new_tokens=10)
    assert r.assign(big) == 0           # tie -> lowest index
    assert r.assign(small) == 1
    assert r.assign(small) == 1         # 11 + 11 < 101
    assert r.assign(small) == 1
    assert r.load[0] == pytest.approx(request_cost(big))
    assert r.assigned == [1, 3]


def test_router_round_robin_and_split_order():
    r = FleetRouter(3, "rr")
    reqs = [Request(prompt=[1], max_new_tokens=i + 1) for i in range(7)]
    shards = r.split(reqs)
    assert [len(s) for s in shards] == [3, 2, 2]
    # arrival order preserved within each shard
    assert [q.max_new_tokens for q in shards[0]] == [1, 4, 7]
    assert [q.max_new_tokens for q in shards[1]] == [2, 5]


def test_router_validation():
    with pytest.raises(ValueError, match="replica"):
        FleetRouter(0)
    with pytest.raises(ValueError, match="policy"):
        FleetRouter(2, "hash")


# ============================================================== the bus
def test_bus_newest_wins_fanout_and_idempotent_subscribe():
    bus = DraftVersionBus()
    a, b = bus.subscribe("r0"), bus.subscribe("r1")
    assert bus.subscribe("r0") is a
    assert a() is None
    bus.publish(DraftVersion(2, {"w": 2}, 0.5))
    bus.publish(DraftVersion(1, {"w": 1}, 0.4))   # stale: ignored
    assert bus.published == 1
    assert a().seq == 2 and b().seq == 2
    assert a().seq == 2                           # repeat poll: same version
    assert a.deliveries == 1 and a.delivered_seq == 2
    bus.publish(DraftVersion(3, {"w": 3}, 0.6))
    assert b().seq == 3 and b.deliveries == 2
    st = bus.stats()
    assert st["latest_seq"] == 3 and st["published"] == 2
    assert st["subscribers"]["r0"]["delivered_seq"] == 2


def test_bus_pulls_from_upstream_source():
    slot = RemoteDeploySource()
    bus = DraftVersionBus(source=slot.poll)
    sub = bus.subscribe("r0")
    assert sub() is None
    slot.publish(DraftVersion(1, {"w": 1}, 0.5))
    assert sub().seq == 1 and bus.published == 1
    slot.publish(DraftVersion(5, {"w": 5}, 0.9))
    slot.publish(DraftVersion(4, {"w": 4}, 0.8))  # stale at the slot too
    assert sub().seq == 5


def test_remote_deploy_source_and_gate_view():
    slot = RemoteDeploySource()
    slot.publish(DraftVersion(3, {"w": 3}, 0.5))
    assert slot() is slot.poll() and slot().seq == 3
    slot.reset()
    assert slot.poll() is None
    gate = _GateView()
    gate.observe(2)
    gate.observe(1)
    assert gate.version == 2
    gate.reset()
    assert gate.version == 0


def test_remote_signal_channel_keeps_host_arrays():
    ch = RemoteSignalChannel(capacity=2)
    for i in range(3):
        ch.add(_batch(i))
    assert ch.dropped == 1 and ch.peek_count() == 2
    kept = ch.drain()
    assert isinstance(kept[0].feats, np.ndarray), \
        "remote channel must not device_put onto a local device"
    assert [int(b.tokens[0]) for b in kept] == [1, 2]


# ============================= TrainingService failure paths (satellite)
class _RaisingTrainer:
    def __init__(self, exc):
        self.exc = exc

    def train_cycle(self, dparams, batches, **kw):
        raise self.exc


class _BlockingTrainer:
    def __init__(self):
        self.started = threading.Event()
        self.release = threading.Event()

    def train_cycle(self, dparams, batches, **kw):
        self.started.set()
        self.release.wait(timeout=30.0)
        return {"dparams": dparams, "train_acc": 0.0, "eval_acc": 0.0,
                "steps": 1, "seconds": 0.0}


def _gate():
    from repro.checkpoint.ckpt import DraftDeployGate
    return DraftDeployGate({"w": np.zeros(2, np.float32)})


def test_service_drain_survives_trainer_death():
    """drain() after the trainer dies mid-cycle: the failure is counted,
    the buffered signals are consumed, serving-side state stays usable,
    and close() is clean — never a hang or a propagated exception."""
    ch = SignalChannel(capacity=8)
    svc = TrainingService(_RaisingTrainer(RuntimeError("trainer died")),
                          _gate(), ch, n_threshold=8, signal_window=8,
                          selective=False)
    ch.add(_batch(0))
    assert svc.drain() == 0
    assert svc.failures == 1
    assert "RuntimeError: trainer died" in svc.last_error
    assert svc.drain() == 0 and svc.failures == 1   # signals consumed
    st = svc.stats()
    assert st["failures"] == 1 and "trainer died" in st["last_error"]
    svc.close()
    svc.close()                                     # idempotent
    svc.reset()
    assert svc.failures == 0 and svc.last_error is None


def test_service_background_loop_stops_on_trainer_death():
    ch = SignalChannel(capacity=8)
    svc = TrainingService(_RaisingTrainer(ValueError("boom")), _gate(),
                          ch, n_threshold=8, signal_window=8,
                          selective=False, poll_s=0.01)
    svc.start()
    ch.add(_batch(0))
    for _ in range(200):
        if not svc.running:
            break
        time.sleep(0.02)
    assert not svc.running, "loop must stop after the trainer raises"
    assert svc.failures == 1 and "boom" in svc.last_error
    svc.close()


def test_service_close_abandons_wedged_thread():
    """A cycle wedged inside a dead trainer must not hang shutdown:
    close() times out the join, counts a failure, and returns."""
    trainer = _BlockingTrainer()
    ch = SignalChannel(capacity=8)
    svc = TrainingService(trainer, _gate(), ch, n_threshold=8,
                          signal_window=8, selective=False, poll_s=0.01)
    svc.start()
    ch.add(_batch(0))
    assert trainer.started.wait(timeout=10.0), "cycle never started"
    svc.close(timeout=0.2)
    assert svc.failures == 1 and "abandoned" in svc.last_error
    svc.close(timeout=0.2)                          # idempotent
    trainer.release.set()                           # let the daemon die


# =========================== TrainerHost protocol (socketpair, no XLA)
class _StubChannel:
    def __init__(self):
        self.batches = []

    def add(self, b):
        self.batches.append(b)

    def reset(self):
        self.batches.clear()


class _StubService:
    """Protocol-level stand-in for TrainingService inside TrainerHost:
    drain publishes one draft + one event back through the host, so the
    DRAFT/EVENT-before-DRAIN_ACK ordering is observable."""

    def __init__(self, hello, embed, dparams0, host):
        self.hello, self.embed, self.dparams0 = hello, embed, dparams0
        self.host = host
        self.channel = _StubChannel()
        self.gate = types.SimpleNamespace(version=0,
                                          reset=lambda dp=None: None)
        self.failures = 0
        self._train_lock = threading.RLock()
        self.drains = self.resets = self.closed = 0
        self.started = False

    def drain(self):
        self.drains += 1
        self.gate.version += 1
        self.host.send_draft(DraftVersion(
            self.gate.version,
            {"fc": {"w": np.full(3, self.gate.version, np.float32)}},
            0.75))
        self.host.send_event({"kind": "train_cycle", "eval_acc": 0.75,
                              "train_acc": 0.7,
                              "baseline": self.host.baseline,
                              "deployed": True, "steps": 3,
                              "seconds": 0.01, "dropme": object()})
        return 1

    def reset(self):
        self.resets += 1

    def start(self):
        self.started = True

    def close(self):
        self.closed += 1


def _handshake_frames(async_train=False):
    from conftest import tiny_cfg
    from repro.core import eagle
    cfg = tiny_cfg()
    hello = {"tcfg": wire.config_to_dict(cfg),
             "dcfg": wire.config_to_dict(eagle.draft_config(cfg)),
             "train": {"n_threshold": 8, "signal_window": 8,
                       "train_epochs": 1, "train_min_steps": 2,
                       "seed": 0},
             "async": async_train}
    init = {"e/w": np.zeros((4, 2), np.float32),
            "p/fc/w": np.ones(3, np.float32)}
    return (wire.encode_frame(wire.FT_HELLO, wire.json_payload(hello))
            + wire.encode_frame(wire.FT_INIT, wire.npz_payload(init)))


def _run_host(conn, holder):
    host = TrainerHost(conn, service_factory=_StubService)
    holder["host"] = host
    try:
        host.run()
    except Exception as exc:            # surfaced by the test
        holder["err"] = exc
    finally:
        conn.close()


def _recv_n(sock, reader, n, timeout=10.0):
    sock.settimeout(timeout)
    out = []
    while len(out) < n:
        out.extend(reader.feed(sock.recv(1 << 16)))
    return out


def test_trainer_host_protocol_roundtrip():
    """Full protocol over a socketpair: handshake ack, SIGNALS ingest
    with the baseline riding along, DRAFT + EVENT strictly before the
    DRAIN_ACK on the same stream, RESET round trip, BYE shutdown (which
    closes the service)."""
    client, server = socket.socketpair()
    holder = {}
    t = threading.Thread(target=_run_host, args=(server, holder),
                         daemon=True)
    t.start()
    reader = wire.FrameReader()
    client.sendall(_handshake_frames())
    (ftype, _f, payload), = _recv_n(client, reader, 1)
    assert ftype == wire.FT_HELLO and wire.decode_json(payload)["ok"]
    stub = holder["host"].service
    assert stub.hello["train"]["n_threshold"] == 8
    assert stub.embed["w"].shape == (4, 2)
    assert not stub.started                     # sync handshake

    client.sendall(wire.encode_frame(
        wire.FT_SIGNALS,
        wire.signals_payload([_batch(3)], baseline=0.375)))
    client.sendall(wire.encode_frame(
        wire.FT_DRAIN, wire.json_payload({"token": 7})))
    frames = _recv_n(client, reader, 3)
    assert [f[0] for f in frames] == \
        [wire.FT_DRAFT, wire.FT_EVENT, wire.FT_DRAIN_ACK], \
        "drafts/events must precede the drain ack on the stream"
    seq, dparams, acc = wire.decode_draft(frames[0][2])
    assert seq == 1 and acc == 0.75
    np.testing.assert_array_equal(dparams["fc"]["w"],
                                  np.full(3, 1, np.float32))
    event = wire.decode_json(frames[1][2])
    assert event["kind"] == "train_cycle"
    assert event["baseline"] == 0.375           # shipped with SIGNALS
    assert "dropme" not in event                # non-scalars filtered
    ack = wire.decode_json(frames[2][2])
    assert ack == {"token": 7, "cycles": 1, "version": 1, "failures": 0}
    # the SIGNALS frame landed in the trainer-side channel, losslessly
    assert len(stub.channel.batches) == 1
    np.testing.assert_array_equal(stub.channel.batches[0].feats,
                                  _batch(3).feats)

    client.sendall(wire.encode_frame(
        wire.FT_RESET, wire.json_payload({"token": 8})))
    (ftype, _f, payload), = _recv_n(client, reader, 1)
    assert ftype == wire.FT_RESET_ACK
    assert wire.decode_json(payload)["token"] == 8
    assert stub.resets == 1 and stub.channel.batches == []
    assert holder["host"].baseline == 0.0       # reset clears it

    client.sendall(wire.encode_frame(wire.FT_BYE))
    t.join(timeout=10.0)
    assert not t.is_alive() and "err" not in holder
    assert stub.closed == 1
    client.close()


def test_trainer_host_async_handshake_starts_service():
    client, server = socket.socketpair()
    holder = {}
    t = threading.Thread(target=_run_host, args=(server, holder),
                         daemon=True)
    t.start()
    reader = wire.FrameReader()
    client.sendall(_handshake_frames(async_train=True))
    _recv_n(client, reader, 1)
    assert holder["host"].service.started
    client.sendall(wire.encode_frame(wire.FT_BYE))
    t.join(timeout=10.0)
    client.close()


def test_trainer_host_rejects_out_of_order_handshake():
    client, server = socket.socketpair()
    holder = {}
    t = threading.Thread(target=_run_host, args=(server, holder),
                         daemon=True)
    t.start()
    client.sendall(wire.encode_frame(
        wire.FT_SIGNALS, wire.signals_payload([_batch(0)])))
    t.join(timeout=10.0)
    assert isinstance(holder.get("err"), wire.WireError)
    assert "expected HELLO" in str(holder["err"])
    client.close()


def test_trainer_host_eof_before_handshake():
    client, server = socket.socketpair()
    holder = {}
    t = threading.Thread(target=_run_host, args=(server, holder),
                         daemon=True)
    t.start()
    client.close()
    t.join(timeout=10.0)
    assert isinstance(holder.get("err"), wire.WireError)
    assert "closed before HELLO" in str(holder["err"])


# ================== RemoteTrainingService against an in-process host
def _tiny_handshake_args():
    from conftest import tiny_cfg
    from repro.core import eagle
    cfg = tiny_cfg()
    return dict(tcfg=cfg, dcfg=eagle.draft_config(cfg),
                embed_params={"w": np.zeros((4, 2), np.float32)},
                dparams0={"fc": {"w": np.ones(3, np.float32)}},
                n_threshold=8, signal_window=8, connect_timeout=30.0,
                drain_timeout=30.0)


def _host_thread(endpoint, holder):
    srv = wire.listen(endpoint)
    holder["srv"] = srv

    def serve():
        conn, _ = srv.accept()
        _run_host(conn, holder)

    t = threading.Thread(target=serve, daemon=True)
    t.start()
    return t


def test_remote_service_drain_draft_pickup_and_close(tmp_path):
    """The serving-side endpoint against a live (stub) trainer host:
    drain() flushes signals + barrier, and by the time it returns the
    DRAFT published during the barrier is in the deploy slot and the
    event/cycle mirrors are updated — the drain-parity ordering
    contract.  close() is idempotent and tears the host down via BYE."""
    ep = f"unix:{tmp_path}/t.sock"
    holder = {}
    t = _host_thread(ep, holder)
    svc = RemoteTrainingService(ep, engine_steps_fn=lambda: 42,
                                **_tiny_handshake_args())
    try:
        assert svc.running and svc.poll() is None
        svc.channel.add(_batch(5))
        assert svc.drain() == 1
        ver = svc.poll()
        assert ver is not None and ver.seq == 1 and ver.eval_acc == 0.75
        np.testing.assert_array_equal(np.asarray(ver.dparams["fc"]["w"]),
                                      np.full(3, 1, np.float32))
        assert svc.gate.version == 1 and svc.deploys == 1
        assert svc.cycles == 1
        assert svc.events[0]["kind"] == "train_cycle"
        assert svc.events[0]["engine_steps"] == 42
        stub = holder["host"].service
        assert len(stub.channel.batches) == 1
        assert svc.drain() == 1                  # empty flush still cycles
        st = svc.stats()
        assert st["thread_cap"] == "process" and st["trainer_threads"] == 0
        assert st["frames_sent"] >= 4 and st["frames_recv"] >= 4
        assert st["failures"] == 0

        svc.reset()
        assert svc.poll() is None and svc.cycles == 0
        assert svc.gate.version == 0
        assert holder["host"].service.resets == 1
    finally:
        svc.close()
        svc.close()                              # idempotent
        t.join(timeout=10.0)
        holder["srv"].close()
    assert holder["host"].service.closed == 1
    assert not svc.running


def test_remote_service_trainer_death_degrades_not_hangs(tmp_path):
    """Abrupt trainer death after the handshake: the receiver marks the
    service dead, drain() returns 0 promptly, reset() degrades to a
    local clear, the failure is counted, and close() stays clean."""
    ep = f"unix:{tmp_path}/t.sock"
    holder = {}
    t = _host_thread(ep, holder)
    svc = RemoteTrainingService(ep, **_tiny_handshake_args())
    try:
        holder["host"].conn.shutdown(socket.SHUT_RDWR)   # trainer "dies"
        for _ in range(200):
            if not svc.running:
                break
            time.sleep(0.02)
        assert not svc.running
        assert svc.failures >= 1 and svc.last_error is not None
        svc.channel.add(_batch(0))
        t0 = time.monotonic()
        assert svc.drain() == 0
        assert time.monotonic() - t0 < 5.0, "dead drain must not hang"
        svc.reset()                                      # local-only clear
        assert svc.poll() is None
    finally:
        svc.close()
        t.join(timeout=10.0)
        holder["srv"].close()


def test_remote_service_connect_failure_is_clean(tmp_path):
    with pytest.raises(RuntimeError, match="could not reach"):
        RemoteTrainingService(f"unix:{tmp_path}/nobody.sock",
                              **{**_tiny_handshake_args(),
                                 "connect_timeout": 0.3})


# ======================================================= slow: real e2e
@pytest.fixture(scope="module")
def pretrained():
    import jax
    import repro.configs as C
    from repro.core import eagle
    from repro.data.workloads import make_domains, training_corpus
    from repro.models import transformer as T
    from repro.training.trainer import pretrain_target

    cfg = C.get("tide-tiny")
    params = T.init(cfg, jax.random.key(0))
    domains = make_domains(cfg.vocab_size, ["science"], branchings=[2],
                           seed=3)
    corpus = training_corpus(domains["science"], 64, 40, 1)
    params, _ = pretrain_target(cfg, params, corpus, steps=80, lr=3e-3)
    dcfg = eagle.draft_config(cfg)
    dparams = eagle.draft_init(dcfg, jax.random.key(7))
    return cfg, params, dcfg, dparams, domains


_FLEET_TCFG = dict(gamma=3, batch_size=2, max_len=96, adaptive_spec=False,
                   selective_training=False, signal_window=8, n_threshold=4,
                   train_epochs=1, train_min_steps=6, seed=0)


def _reqs(domains, budgets, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(prompt=domains["science"].sample_prompt(rng),
                    max_new_tokens=m, domain="science") for m in budgets]


def _strip(events):
    return [{k: v for k, v in e.items() if k != "seconds"}
            for e in events]


@pytest.mark.slow
def test_fleet_streams_match_single_engine(pretrained):
    """Two data-parallel replicas behind the router/bus serve the exact
    per-request greedy streams a single engine serves (draft- and
    scheduling-invariance), the replicas share compiled step functions,
    and reset_adaptation makes the fleet run reproducible."""
    from repro.core.tide import TideConfig, TideSystem
    from repro.fleet.router import ServingFleet

    cfg, params, dcfg, dparams, domains = pretrained
    budgets = (24, 16, 24, 12, 20, 24, 16, 24)

    single = TideSystem(cfg, params, TideConfig(**_FLEET_TCFG),
                        dparams=dparams)
    ref = _reqs(domains, budgets, seed=11)
    single.run_stream(iter(ref))
    single.close()

    tc = TideConfig(**_FLEET_TCFG, fleet=FleetConfig(replicas=2))
    fleet = ServingFleet(cfg, params, tc, dparams=dparams)
    assert fleet.engines[1]._superstep_fn is fleet.engines[0]._superstep_fn
    assert fleet.engines[1]._prefill_fn is fleet.engines[0]._prefill_fn
    got = _reqs(domains, budgets, seed=11)
    done = fleet.serve(got)
    assert len(done) == len(ref)
    assert sorted((tuple(r.prompt), tuple(r.generated)) for r in got) == \
        sorted((tuple(r.prompt), tuple(r.generated)) for r in ref)

    s = fleet.summary()
    assert s["replicas"] == 2
    assert all(n > 0 for n in s["router_assigned"]), \
        "least-loaded routing must use both replicas"
    assert s["tokens"] == sum(s["replica_tokens"])
    assert s["train_cycles"] >= 1 and s["deployed"] >= 1
    assert s["bus"]["published"] >= 1
    assert s["trainer_failures"] == 0

    fleet.reset_adaptation()
    again = _reqs(domains, budgets, seed=11)
    fleet.serve(again)
    assert [tuple(r.generated) for r in again] == \
        [tuple(r.generated) for r in got]
    s2 = fleet.summary()
    assert s2["router_assigned"] == s["router_assigned"]
    fleet.close()


@pytest.mark.slow
def test_remote_spawn_drain_parity(pretrained):
    """The acceptance gate: a spawned out-of-process trainer in sync
    (drain-parity) mode reproduces the in-process system byte-for-byte —
    token streams, cycle counts, deploy versions, and the train-cycle
    event stream (timing excluded)."""
    from repro.core.tide import TideConfig, TideSystem

    cfg, params, dcfg, dparams, domains = pretrained
    budgets = (24, 16, 24, 20)

    ref_sys = TideSystem(cfg, params, TideConfig(**_FLEET_TCFG),
                         dparams=dparams)
    ref = _reqs(domains, budgets, seed=5)
    ref_sys.run_stream(iter(ref))
    assert ref_sys.service.cycles >= 1, "scenario never trained"

    tc = TideConfig(**_FLEET_TCFG,
                    fleet=FleetConfig(trainer_endpoint="spawn"))
    rem_sys = TideSystem(cfg, params, tc, dparams=dparams)
    got = _reqs(domains, budgets, seed=5)
    try:
        rem_sys.run_stream(iter(got))
        assert [r.generated for r in got] == [r.generated for r in ref]
        assert rem_sys.service.cycles == ref_sys.service.cycles
        assert rem_sys.gate.version == ref_sys.gate.version
        ref_ev, rem_ev = _strip(ref_sys.events), _strip(rem_sys.events)
        assert len(rem_ev) == len(ref_ev)
        for a, b in zip(rem_ev, ref_ev):
            assert a["deployed"] == b["deployed"]
            assert a["steps"] == b["steps"]
            assert a["engine_steps"] == b["engine_steps"]
            assert a["baseline"] == b["baseline"]
            assert a["eval_acc"] == pytest.approx(b["eval_acc"], abs=1e-6)
            assert a["train_acc"] == pytest.approx(b["train_acc"],
                                                   abs=1e-6)
        assert rem_sys.summary()["trainer_failures"] == 0
    finally:
        rem_sys.close()
        ref_sys.close()


@pytest.mark.slow
def test_remote_spawn_trainer_kill_degrades(pretrained):
    """Kill the trainer subprocess mid-workload: serving completes every
    request on the last deployed draft, drain() never hangs, and the
    degradation is visible in summary()."""
    from repro.core.tide import TideConfig, TideSystem

    cfg, params, dcfg, dparams, domains = pretrained
    tc = TideConfig(**_FLEET_TCFG,
                    fleet=FleetConfig(trainer_endpoint="spawn"))
    sys_ = TideSystem(cfg, params, tc, dparams=dparams)
    try:
        first = _reqs(domains, (24, 16), seed=9)
        sys_.run_stream(iter(first))
        sys_.service.kill_trainer()
        for _ in range(300):
            if not sys_.service.running:
                break
            time.sleep(0.05)
        assert not sys_.service.running
        second = _reqs(domains, (20, 24, 12), seed=10)
        t0 = time.monotonic()
        done = sys_.run_stream(iter(second))
        assert len(done) == 3
        assert all(len(r.generated) > 0 for r in second)
        assert time.monotonic() - t0 < 120.0
        assert sys_.service.drain() == 0
        assert sys_.summary()["trainer_failures"] >= 1
    finally:
        sys_.close()
        sys_.close()                             # idempotent
