"""Overload resilience: lane spill/restore, deadline preemption,
weighted-EDF admission, and load shedding (docs/overload.md).

The tentpole invariant mirrors the paged/chunked ones: changing *where*
a request's serving state lives (spilled to the host-side SpillStore
and restored onto a different/same lane, possibly onto different
physical pages) must never change *what* it generates.  Preemption only
reorders service; restored streams are byte-identical to never-evicted
runs, greedy and per-request-keyed sampled, dense and paged.

Overload is an arrival-dynamics phenomenon — in backlog mode EDF simply
admits the tight requests first — so the end-to-end tests replay gated
traces on the engine's injected clock bound to its own executed-round
counter (``stats.steps``): arrivals, deadlines, and latency stamps all
live in deterministic round units, reproducible on noisy shared hosts.

All tests run on randomly initialized weights (overload behavior is a
property of the control plane, not the model); the sampled parity
combos and the randomized property sweep carry the ``slow`` mark, the
rest stays in the fast tier.
"""
import dataclasses

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # not in the container image - deterministic shim
    from _hypothesis_shim import given, settings, strategies as st

import repro.configs as C
from repro.core import eagle, paging
from repro.models import transformer as T
from repro.serving.engine import ServingEngine
from repro.serving.policy import (DeadlinePreemption, ExpiredShed,
                                  PreemptionPolicy, QueueDepthShed,
                                  ServingConfig, WeightedEdfAdmission)
from repro.serving.request import Request

_MODEL = None


def _get_model():
    global _MODEL
    if _MODEL is None:
        cfg = C.get("tide-tiny")
        params = T.init(cfg, jax.random.key(0))
        dcfg = eagle.draft_config(cfg)
        dparams = eagle.draft_init(dcfg, jax.random.key(7))
        _MODEL = (cfg, params, dcfg, dparams)
    return _MODEL


_ENGINES = {}


def teardown_module():
    """Free the cached engines (and their compiled executables) once
    the module finishes: the full-tier session compiles enough programs
    that late-session LLVM compiles are sensitive to resident state."""
    _ENGINES.clear()


def _cached_engine(**kw):
    """One engine per config variant (compiles stay warm across tests
    and property examples); ``reset_adaptation`` restores the
    post-construction state between uses."""
    key = tuple(sorted(kw.items()))
    eng = _ENGINES.get(key)
    if eng is None:
        cfg, params, dcfg, dparams = _get_model()
        config = ServingConfig(batch_size=2, max_len=96, gamma=3, seed=5,
                               superstep_rounds=4, idle_wait_s=0.0005,
                               **kw)
        eng = _ENGINES[key] = ServingEngine(cfg, params, dcfg, dparams,
                                            config=config)
    eng.reset_adaptation(eng.dparams)
    eng.deploy_source = None
    return eng


def _round_clock(eng):
    """Bind the engine's injected clock to its own executed-round
    counter: gated arrivals, deadlines, and every latency stamp become
    deterministic round units."""
    eng._clock = lambda: float(eng.stats.steps)
    return eng


def _trace(spec, seed=3, plen=6):
    """Build a gated trace from (arrives_at, deadline, budget) rows,
    with sids pre-assigned in creation order so sampled streams are
    scheduling-invariant across engines and policies."""
    cfg = _get_model()[0]
    rng = np.random.default_rng(seed)
    out = []
    for i, (a, d, m) in enumerate(spec):
        r = Request(prompt=list(rng.integers(1, cfg.vocab_size, plen)),
                    max_new_tokens=m, deadline=d)
        r.arrives_at = a
        r.sid = i
        out.append(r)
    return out


# loose pair resident from round 0, tight burst at round 10 (while the
# loose pair is still mid-decode), one loose tail: EDF without
# preemption parks the burst behind the loose residents; preemption
# spills both residents and restores them after the burst drains
_BURST = [(0.0, 1000.0, 60), (0.0, 1001.0, 60),
          (10.0, 40.0, 8), (10.0, 41.0, 8), (0.0, 1004.0, 10)]


def _serve(eng, reqs):
    _round_clock(eng).serve_stream(list(reqs))
    if eng.allocator is not None:
        eng.release_prefix_cache()
        eng.allocator.assert_clean()
    return {r.sid: list(r.generated) for r in reqs}


# ================================================= policy-layer units
def test_weighted_edf_ordering():
    """wedf ranks by priority-relaxed deadline: a high-priority request
    beats an earlier plain deadline when the weight covers the gap."""
    pol = WeightedEdfAdmission(weight=10.0)
    a = Request(prompt=[1], deadline=20.0, priority=0)
    b = Request(prompt=[1], deadline=25.0, priority=1)   # 25-10 = 15
    c = Request(prompt=[1], deadline=None, priority=5)   # inf stays last
    assert pol.select([a, b, c], 0.0) == 1
    assert pol.select([a, c], 0.0) == 0
    # zero weight degenerates to plain EDF
    assert WeightedEdfAdmission(weight=0.0).select([a, b], 0.0) == 0


def test_preemption_policy_selects_loosest_victim():
    pol = DeadlinePreemption()
    cand = Request(prompt=[1], deadline=5.0)
    r1 = Request(prompt=[1], deadline=100.0)
    r2 = Request(prompt=[1], deadline=900.0)
    r3 = Request(prompt=[1], deadline=None)     # loosest of all
    assert pol.select_victim(cand, [(0, r1), (1, r2), (2, r3)], 0) == 2
    assert pol.select_victim(cand, [(0, r1), (1, r2)], 0) == 1
    # a candidate without a deadline never evicts anyone
    assert pol.select_victim(Request(prompt=[1]), [(0, r2)], 0) is None
    # no resident looser than the candidate -> decline
    tight = Request(prompt=[1], deadline=4.0)
    assert pol.select_victim(cand, [(0, tight)], 0) is None
    # margin: the win must exceed it
    assert DeadlinePreemption(margin=1000.0).select_victim(
        cand, [(0, r2)], 0) is None


def test_preemption_policy_respects_max_evictions():
    pol = DeadlinePreemption(max_evictions=2)
    cand = Request(prompt=[1], deadline=5.0)
    r = Request(prompt=[1], deadline=900.0)
    r.evictions = 2
    assert pol.select_victim(cand, [(0, r)], 0) is None
    r.evictions = 1
    assert pol.select_victim(cand, [(0, r)], 0) == 0


def test_shed_policy_units():
    now = 50.0
    live = Request(prompt=[1], deadline=90.0)
    dead = Request(prompt=[1], deadline=10.0)
    none = Request(prompt=[1])
    assert ExpiredShed().pick([live, dead, none], now) == [dead]
    assert PreemptionPolicy().shed.pick([dead], now) == []
    # queue-depth shed drops the loosest beyond the bound
    q = [Request(prompt=[1], deadline=float(d)) for d in (5, 99, 40)]
    picked = QueueDepthShed(depth=2).pick(q, now)
    assert picked == [q[1]]
    assert QueueDepthShed(depth=8).pick(q, now) == []


def test_spill_store_units():
    store = paging.SpillStore()
    assert not store and len(store) == 0
    r1, r2 = Request(prompt=[1]), Request(prompt=[2])
    store.put(paging.SpilledLane(r1, {"x": 1}, 3))
    store.put(paging.SpilledLane(r2, {"x": 2}, 0))
    with pytest.raises(AssertionError):
        store.put(paging.SpilledLane(r1, {}, 0))     # double spill
    assert [e.request is r for e, r in zip(store.pending(), (r1, r2))] \
        == [True, True]
    e = store.pop(r1.rid)
    assert e.pages == 3 and store.restores == 1
    store.drop(r2.rid)
    assert store.dropped == 1 and not store
    assert store.spills == 2


def test_allocator_spill_lane_accounting():
    a = paging.PageAllocator(16, 8, 4, 64)
    assert a.reserve(0, 20)                       # 3 pages
    assert a.lane_pages(0) == 3
    assert a.spill_lane(0) == 3
    assert a.spilled_pages == 3
    assert a.lane_pages(0) == 0 and a.pages_in_use == 0
    a.assert_clean()


# ====================================== engine guards + null parity
def test_preempt_requires_superstep_mode():
    cfg, params, dcfg, dparams = _get_model()
    with pytest.raises(ValueError, match="superstep"):
        ServingEngine(cfg, params, dcfg, dparams,
                      config=ServingConfig(batch_size=2, max_len=96,
                                           superstep_rounds=0,
                                           preempt="deadline"))


def test_preempt_enabled_idle_is_byte_identical():
    """A preemption-enabled engine on a trace that never overloads must
    be indistinguishable from the baseline: same streams, same round
    stamps, zero preemption activity."""
    spec = [(0.0, 1000.0, 8), (0.0, 1001.0, 8), (0.0, 1002.0, 6)]
    kw = dict(admission="deadline", admission_lookahead=4,
              gate_arrivals=True)
    base = _cached_engine(**kw)
    a = _trace(spec)
    _serve(base, a)
    eng = _cached_engine(**kw, preempt="deadline")
    b = _trace(spec)
    _serve(eng, b)
    assert eng.stats.preemptions == 0 and eng.stats.restores == 0
    for ra, rb in zip(a, b):
        assert rb.generated == ra.generated
        assert (rb.admit_round, rb.first_token_round, rb.finish_round) \
            == (ra.admit_round, ra.first_token_round, ra.finish_round)


# ========================= spill/restore end-to-end byte parity
@pytest.mark.parametrize(
    "greedy", [True, pytest.param(False, marks=pytest.mark.slow)])
@pytest.mark.parametrize(
    "page_size", [0, pytest.param(16, marks=pytest.mark.slow)])
def test_preempt_restore_stream_parity(greedy, page_size):
    """The tentpole pin: a tight-deadline burst preempts loose resident
    lanes (spill to host), the burst drains, the victims restore and
    resume mid-stream — and every stream is byte-identical to the
    never-evicted baseline, with zero leaked pages."""
    kw = dict(greedy=greedy, admission="deadline", admission_lookahead=4,
              gate_arrivals=True, page_size=page_size,
              num_pages=12 if page_size else 0)
    base = _serve(_cached_engine(**kw), _trace(_BURST))
    eng = _cached_engine(**kw, preempt="deadline")
    reqs = _trace(_BURST)
    out = _serve(eng, reqs)
    assert eng.stats.preemptions >= 1, "trace must force preemption"
    assert eng.stats.restores >= 1, "victims must restore mid-stream"
    assert out == base, "restored streams must be byte-identical"
    assert sum(r.evictions for r in reqs) == eng.stats.preemptions
    if page_size:
        assert eng.allocator.spilled_pages > 0
    # the preemption won: the burst's deadline-hit rate can only improve
    hits = lambda rs: sum(r.finish_round is not None
                          and r.finish_round <= r.deadline for r in rs)
    assert hits([r for r in reqs if r.deadline < 100]) == 2


def test_preempted_victim_finishing_in_flight_is_dropped():
    """A victim whose final tokens were already in flight at spill time
    finishes from that superstep's telemetry: the spill entry is
    dropped (never restored) and the request still routes to
    ``completed`` exactly once."""
    # small loose budgets: when the burst preempts, the in-flight
    # superstep often completes the victims while they sit parked (the
    # round clock only advances while lanes are busy, so the burst must
    # arrive before the loose pair can possibly drain: >= 1 token per
    # round makes round 2 safe for budget-10 lanes)
    spec = [(0.0, 1000.0, 10), (0.0, 1001.0, 10),
            (2.0, 30.0, 8), (2.0, 31.0, 8)]
    kw = dict(admission="deadline", admission_lookahead=4,
              gate_arrivals=True, preempt="deadline")
    eng = _cached_engine(**kw)
    reqs = _trace(spec)
    completed = _round_clock(eng).serve_stream(list(reqs))
    assert sorted(r.rid for r in completed) == sorted(r.rid for r in reqs)
    assert len(completed) == len(reqs)
    assert eng.stats.completed == len(reqs)
    assert all(len(r.generated) == r.max_new_tokens for r in reqs)


@pytest.mark.slow
@settings(max_examples=4)
@given(st.integers(6, 14), st.integers(40, 70), st.integers(0, 10 ** 6))
def test_preempt_parity_property(burst_round, loose_budget, seed):
    """Randomized overload traces (random burst timing, loose budgets,
    prompts): preemption-enabled serving stays byte-identical to the
    baseline, dense and paged, with clean allocators."""
    rng = np.random.default_rng(seed)
    spec = [(0.0, 1000.0, int(loose_budget)),
            (0.0, 1001.0, int(loose_budget)),
            (float(burst_round), 40.0, int(rng.integers(4, 10))),
            (float(burst_round), 41.0, int(rng.integers(4, 10))),
            (0.0, 1004.0, int(rng.integers(6, 14)))]
    for page_size in (0, 16):
        kw = dict(admission="deadline", admission_lookahead=4,
                  gate_arrivals=True, page_size=page_size,
                  num_pages=12 if page_size else 0)
        base = _serve(_cached_engine(**kw), _trace(spec, seed=seed % 97))
        eng = _cached_engine(**kw, preempt="deadline")
        out = _serve(eng, _trace(spec, seed=seed % 97))
        assert out == base


# ======================================================= load shedding
def test_expired_shed_drops_hopeless_requests():
    """Queued requests whose deadline already passed are dropped (shed
    flag + counter), finish with empty streams, and still route to
    ``completed``; survivors stream byte-identically."""
    spec = [(0.0, 1000.0, 30), (0.0, 1001.0, 30),
            (2.0, 4.0, 8),        # expires in queue long before a lane
            (0.0, 1002.0, 8)]
    kw = dict(admission="deadline", admission_lookahead=4,
              gate_arrivals=True)
    base_reqs = _trace(spec)
    _serve(_cached_engine(**kw), base_reqs)
    eng = _cached_engine(**kw, shed="expired")
    reqs = _trace(spec)
    completed = _round_clock(eng).serve_stream(list(reqs))
    assert eng.stats.shed_requests == 1
    shed = [r for r in reqs if r.shed]
    assert [r.sid for r in shed] == [2]
    assert shed[0].generated == [] and shed[0].finish_round is not None
    assert len(completed) == len(reqs)
    for rb, ra in zip(reqs, base_reqs):
        if not rb.shed:
            assert rb.generated == ra.generated


def test_queue_depth_shed_bounds_backlog():
    spec = ([(0.0, 1000.0, 24), (0.0, 1001.0, 24)]
            + [(4.0, 500.0 + i, 6) for i in range(6)])
    eng = _cached_engine(admission="deadline", admission_lookahead=8,
                         gate_arrivals=True, shed="queue",
                         shed_queue_depth=2)
    reqs = _trace(spec)
    _round_clock(eng).serve_stream(list(reqs))
    assert eng.stats.shed_requests > 0
    # the loosest deadlines shed first
    shed = sorted(r.deadline for r in reqs if r.shed)
    kept = sorted(r.deadline for r in reqs if not r.shed and r.sid >= 2)
    assert not kept or not shed or min(shed) >= max(kept)


# ============================================= clock-domain regression
def test_engine_single_clock_domain():
    """The clock-domain bugfix: with a fake clock injected, every
    latency stamp (admit/first-token/finish, scheduler re-anchored
    arrival, wall_s) lives in the fake domain — no stamp may leak from
    ``time.perf_counter``."""
    eng = _cached_engine(gate_arrivals=True)
    tick = {"t": 1000.0}

    def fake():
        tick["t"] += 1.0
        return tick["t"]

    eng._clock = fake
    cfg = _get_model()[0]
    rng = np.random.default_rng(3)
    reqs = []
    for i in range(3):
        r = Request(prompt=list(rng.integers(1, cfg.vocab_size, 5)),
                    max_new_tokens=4)
        r.arrives_at = 0.0
        reqs.append(r)
    eng.serve_stream(list(reqs))
    for r in reqs:
        for stamp in (r.arrival_t, r.admit_t, r.first_token_t,
                      r.finish_t):
            assert stamp is not None and 1000.0 < stamp < 2000.0, (
                "stamp outside the fake clock domain: a wall-clock "
                f"read leaked into the latency path ({stamp})")
        assert r.ttft is not None and r.ttft >= 0.0
        assert r.latency is not None and r.latency >= 0.0
    assert 0.0 < eng.stats.wall_s < 1000.0


# ================================================ observability wiring
def test_overload_metrics_registered():
    eng = _cached_engine(admission="deadline", gate_arrivals=True,
                         admission_lookahead=4, preempt="deadline")
    _serve(eng, _trace(_BURST))
    snap = eng.metrics.snapshot()
    assert snap["serving.preemptions"] == eng.stats.preemptions >= 1
    assert snap["serving.restores"] == eng.stats.restores >= 1
    assert snap["serving.shed_requests"] == 0
    assert snap["serving.spilled_requests"] == 0    # all restored
    assert "paging.spilled_pages" in snap           # dense: zero gauge
    assert snap["paging.spilled_pages"] == 0


def test_config_make_policy_overload_wiring():
    cfg = ServingConfig(preempt="deadline", shed="queue",
                        shed_queue_depth=7)
    pol = cfg.make_policy()
    assert isinstance(pol.preemption, DeadlinePreemption)
    assert isinstance(pol.preemption.shed, QueueDepthShed)
    assert pol.preemption.shed.depth == 7
    base = ServingConfig().make_policy()
    assert type(base.preemption) is PreemptionPolicy
    assert not base.preemption.enabled
    rt = dataclasses.replace(cfg, preempt="none", shed="none")
    assert not rt.make_policy().preemption.enabled
