"""Fused decode superstep vs the per-step reference loop.

The superstep engine (``superstep_rounds=K``) must emit byte-identical
token streams and identical SignalStore contents to the legacy per-step
host loop (``superstep_rounds=0``) — greedy and sampled verification,
heterogeneous per-request budgets, EOS early-exit, Algorithm 1
controller replay, and the mid-wave Adaptive-Drafter fallback from
speculation to plain decode (Eq. 5 EMA crossing the threshold between
rounds of one wave)."""
import jax
import numpy as np
import pytest

# Pretrained-fixture-heavy end-to-end parity suite: slow tier (the
# fast smoke loop runs `pytest -m "not slow"`; see ROADMAP.md).
pytestmark = pytest.mark.slow

import repro.configs as C
from repro.core import eagle
from repro.core.adaptive import (AdaptiveDrafter, LatencyProfile,
                                 accept_threshold_table)
from repro.core.controller import TrainingController
from repro.core.signals import SignalExtractor, SignalStore
from repro.data.workloads import make_domains, training_corpus
from repro.models import transformer as T
from repro.serving.engine import ServingEngine
from repro.serving.request import Request
from repro.training.trainer import pretrain_target


@pytest.fixture(scope="module")
def pretrained():
    cfg = C.get("tide-tiny")
    params = T.init(cfg, jax.random.key(0))
    domains = make_domains(cfg.vocab_size, ["science"], branchings=[2],
                           seed=3)
    corpus = training_corpus(domains["science"], 64, 40, 1)
    params, _ = pretrain_target(cfg, params, corpus, steps=80, lr=3e-3)
    dcfg = eagle.draft_config(cfg)
    dparams = eagle.draft_init(dcfg, jax.random.key(7))
    return cfg, params, dcfg, dparams, domains


# threshold ≈ 2.0 at every batch size (flat T(n), slow-ish draft):
# an engine seeded with accept_ema=3.0 starts speculating, decays
# towards the observed E[l]≈1.2 and falls back to plain mid-wave.
_FLAT_PROFILE = LatencyProfile([1, 2, 4, 8], [1.0, 1.0, 1.0, 1.0],
                               d0_ms=0.33)


def _serve(pretrained, rounds, *, greedy=True, drafter=False, ctrl=False,
           ema0=None, eos_id=None, n_waves=2, max_new=(24, 24)):
    cfg, params, dcfg, dparams, domains = pretrained
    store = SignalStore()
    ext = SignalExtractor(store, window=16)
    controller = None
    if ctrl:
        controller = TrainingController(n_init=4, n_threshold=64)
        controller.collection_enabled = True
    dr = AdaptiveDrafter(_FLAT_PROFILE, gamma=3) if drafter else None
    eng = ServingEngine(cfg, params, dcfg, dparams, batch_size=len(max_new),
                        max_len=96, gamma=3, greedy=greedy, drafter=dr,
                        controller=controller, extractor=ext, seed=5,
                        superstep_rounds=rounds, eos_id=eos_id)
    if ema0 is not None:
        eng.accept_ema = ema0
    rng = np.random.default_rng(0)
    gens = []
    for _ in range(n_waves):
        reqs = [Request(prompt=domains["science"].sample_prompt(rng),
                        max_new_tokens=m) for m in max_new]
        eng.serve_wave(reqs)
        gens.append([list(r.generated) for r in reqs])
        assert all(r.finish_t is not None for r in reqs)
    signals = [(b.tokens.tobytes(), b.feats.tobytes())
               for b in store.drain()]
    return gens, signals, eng


def _assert_parity(pretrained, **kw):
    g_ref, s_ref, e_ref = _serve(pretrained, 0, **kw)
    g_ss, s_ss, e_ss = _serve(pretrained, 8, **kw)
    assert g_ss == g_ref, "superstep token stream diverged from per-step"
    assert s_ss == s_ref, "superstep SignalStore contents diverged"
    assert e_ss.stats.steps == e_ref.stats.steps
    assert e_ss.stats.spec_steps == e_ref.stats.spec_steps
    assert e_ss.stats.tokens_out == e_ref.stats.tokens_out
    # the acceptance EMA drives the Eq. 5 decision — it must be
    # bit-identical or threshold compares could diverge between modes
    assert e_ss.accept_ema == e_ref.accept_ema
    return e_ref, e_ss


def test_parity_greedy(pretrained):
    _assert_parity(pretrained)


def test_parity_sampled(pretrained):
    _assert_parity(pretrained, greedy=False)


def test_parity_midwave_drafter_fallback(pretrained):
    """EMA decays across the Eq. 5 threshold *inside* a wave: the engine
    must switch spec → plain mid-wave, identically in both modes."""
    e_ref, e_ss = _assert_parity(pretrained, drafter=True, ema0=3.0)
    assert 0 < e_ref.stats.spec_steps < e_ref.stats.steps, \
        "scenario did not actually exercise a mid-wave fallback"


def test_parity_controller_and_signals(pretrained):
    _assert_parity(pretrained, ctrl=True)


def test_parity_heterogeneous_budgets(pretrained):
    _assert_parity(pretrained, max_new=(9, 24))


def test_parity_eos(pretrained):
    # find a token the greedy run actually emits mid-stream, then use it
    # as EOS: both engines must cut the stream right after it
    g, _, _ = _serve(pretrained, 0, n_waves=1)
    stream = g[0][0]
    eos = stream[len(stream) // 2]
    g_ref, _, _ = _serve(pretrained, 0, eos_id=eos, n_waves=1)
    g_ss, _, _ = _serve(pretrained, 8, eos_id=eos, n_waves=1)
    assert g_ss == g_ref
    for r in g_ref[0]:
        assert eos not in r[:-1], "tokens emitted past EOS"


def test_superstep_various_k(pretrained):
    """Token-stream parity must hold for any superstep depth."""
    g_ref, s_ref, _ = _serve(pretrained, 0, n_waves=1)
    for k in (1, 3, 16):
        g_k, s_k, _ = _serve(pretrained, k, n_waves=1)
        assert g_k == g_ref, f"K={k} diverged"
        assert s_k == s_ref, f"K={k} signal divergence"


def test_threshold_table_matches_host_drafter():
    table = accept_threshold_table(_FLAT_PROFILE, 3, 8)
    dr = AdaptiveDrafter(_FLAT_PROFILE, gamma=3)
    for b in range(1, 9):
        dr.update(b, 0.0)
        from repro.core.adaptive import min_accept_len_for_gain
        assert table[b] == pytest.approx(
            min_accept_len_for_gain(3, _FLAT_PROFILE, b), rel=1e-6)
