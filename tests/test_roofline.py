"""Roofline extraction: HLO collective parser + term math + workload
generator sanity."""
import pytest

from repro.launch import roofline as rf

HLO_SAMPLE = """
ENTRY %main {
  %ag = bf16[8,2048,128]{2,1,0} all-gather(%p0), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = f32[1024]{0} all-reduce(%x), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add
  %rs = f32[512,16]{1,0} reduce-scatter(%y), replica_groups=[16,16]<=[256], dimensions={0}
  %cp = bf16[4,128]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %ags = (bf16[64,64]{1,0}, u32[]) all-gather-start(%w), replica_groups={{0,1}}
  %agd = bf16[64,64]{1,0} all-gather-done(%ags)
  %not_a_collective = f32[2,2]{1,0} add(%a, %b)
}
"""


def test_parse_collectives_counts():
    st = rf.parse_collectives(HLO_SAMPLE)
    assert st.counts["all-gather"] == 2       # ag + ag-start (done skipped)
    assert st.counts["all-reduce"] == 1
    assert st.counts["reduce-scatter"] == 1
    assert st.counts["collective-permute"] == 1


def test_parse_collectives_traffic():
    st = rf.parse_collectives(HLO_SAMPLE)
    ag_bytes = 8 * 2048 * 128 * 2
    assert st.bytes_by_kind["all-gather"] == pytest.approx(
        ag_bytes * 3 / 4 + 64 * 64 * 2 * 1 / 2)
    ar = 1024 * 4
    assert st.bytes_by_kind["all-reduce"] == pytest.approx(
        ar * 2 * 7 / 8)
    rs = 512 * 16 * 4
    assert st.bytes_by_kind["reduce-scatter"] == pytest.approx(
        rs * 15 / 16)
    assert st.bytes_by_kind["collective-permute"] == 4 * 128 * 2


def test_roofline_terms_and_dominance():
    r = rf.Roofline(flops=197e12, hbm_bytes=819e9 * 2,
                    collective_bytes=50e9 * 0.5, chips=256)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(2.0)
    assert r.collective_s == pytest.approx(0.5)
    assert r.dominant == "memory"
    assert r.step_s == pytest.approx(2.0)


def test_model_flops():
    import repro.configs as C
    cfg = C.get("deepseek-v3-671b")
    mf = rf.model_flops(cfg, "train", 1000)
    assert mf == pytest.approx(6 * cfg.active_param_count() * 1000)
    mf_dec = rf.model_flops(cfg, "decode", 4)
    assert mf_dec == pytest.approx(2 * cfg.active_param_count() * 4)


def test_workload_domains_disjoint_and_shifting():
    from repro.data.workloads import Phase, WorkloadStream, make_domains
    doms = make_domains(512, ["a", "b", "c", "d"], seed=0)
    ranges = [(d.vocab_lo, d.vocab_hi) for d in doms.values()]
    for i, (lo1, hi1) in enumerate(ranges):
        for lo2, hi2 in ranges[i + 1:]:
            assert hi1 <= lo2 or hi2 <= lo1     # disjoint vocab regions
    stream = WorkloadStream(doms, [Phase("a", 6), Phase("b", 6)], seed=1)
    items = list(stream)
    assert len(items) == 12
    for name, prompt in items[:6]:
        assert name == "a"
        assert all(doms["a"].vocab_lo <= t < doms["a"].vocab_hi
                   for t in prompt)
    waves = list(stream.batches(4))
    assert len(waves) == 3 and all(len(w) == 4 for w in waves)


def test_shape_applicability_rules():
    import repro.configs as C
    from repro.configs import shapes as shp
    ok, why = shp.applicable(C.get("whisper-base"), "long_500k")
    assert not ok and "capped" in why
    ok, _ = shp.applicable(C.get("rwkv6-3b"), "long_500k")
    assert ok
    # dense arch gets a sliding window for long_500k
    cfg = shp.shape_cfg(C.get("glm4-9b"), "long_500k")
    assert cfg.window == shp.LONG_CONTEXT_WINDOW
    # but not for decode_32k
    assert shp.shape_cfg(C.get("glm4-9b"), "decode_32k").window == 0
    # ssm needs no window
    assert shp.shape_cfg(C.get("rwkv6-3b"), "long_500k").window == 0


def test_input_specs_shapes():
    import repro.configs as C
    from repro.configs import shapes as shp
    cfg = C.get("glm4-9b")
    tr = shp.input_specs(cfg, "train_4k")
    assert tr["batch"]["tokens"].shape == (256, 4096)
    pf = shp.input_specs(cfg, "prefill_32k")
    assert pf["tokens"].shape == (32, 32768)
    dc = shp.input_specs(cfg, "decode_32k")
    assert dc["tokens"].shape == (128, 4)
    kv = dc["cache"]["body"]["pos0"]["k"]
    assert kv.shape == (40, 128, 32768 + 16, 2, 128)
    assert kv.shape[2] % 16 == 0        # model-axis divisibility
    # audio: frames stand in for the stubbed conv frontend
    au = shp.input_specs(C.get("whisper-base"), "train_4k")
    assert au["batch"]["frames"].shape == (256, 4096, 512)
    assert au["batch"]["tokens"].shape == (256, 448)
    # vlm: image embeds stand in for the stubbed ViT
    vl = shp.input_specs(C.get("llama-3.2-vision-11b"), "prefill_32k")
    assert vl["extra"]["image_embeds"].shape == (32, 4096, 4096)
