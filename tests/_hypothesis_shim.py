"""Minimal deterministic stand-in for ``hypothesis``.

The container image does not ship hypothesis (and we may not pip
install).  This shim implements just the surface the test-suite uses —
``given``, ``settings`` and the ``floats``/``integers``/``lists``
strategies — by running each property test over a fixed number of
seeded pseudo-random draws (plus the interval endpoints, which is where
property violations usually live).  Install ``hypothesis``
(requirements-dev.txt) to get real shrinking/fuzzing; the tests import
the genuine library when it is available.
"""
from __future__ import annotations

import random

_DEFAULT_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw, endpoints=()):
        self._draw = draw
        self.endpoints = tuple(endpoints)

    def example(self, rng):
        return self._draw(rng)


class strategies:
    """Namespace mirroring ``hypothesis.strategies`` (``st.`` alias)."""

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                         endpoints=(min_value, max_value))

    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value),
                         endpoints=(min_value, max_value))

    @staticmethod
    def lists(elements, min_size=0, max_size=None):
        hi = max_size if max_size is not None else min_size + 10

        def draw(rng):
            n = rng.randint(min_size, hi)
            return [elements.example(rng) for _ in range(n)]

        return _Strategy(draw)


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(*strats):
    def deco(fn):
        # NOT functools.wraps: copying __wrapped__ would make pytest
        # introspect fn's signature and demand fixtures for the
        # strategy-supplied parameters.
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples", _DEFAULT_EXAMPLES)
            rng = random.Random(0x71DE)
            # endpoint combinations first (aligned, not the full product —
            # enough to hit the classic boundary bugs cheaply)
            n_ep = max(len(s.endpoints) for s in strats) if strats else 0
            for j in range(n_ep):
                vals = [s.endpoints[min(j, len(s.endpoints) - 1)]
                        if s.endpoints else s.example(rng) for s in strats]
                fn(*args, *vals, **kwargs)
            for _ in range(n):
                vals = [s.example(rng) for s in strats]
                fn(*args, *vals, **kwargs)
        wrapper.__name__ = fn.__name__
        wrapper.__module__ = fn.__module__
        wrapper.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
        wrapper.__doc__ = fn.__doc__
        wrapper.__dict__.update(fn.__dict__)
        return wrapper
    return deco
