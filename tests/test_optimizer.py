"""Optimizer correctness (AdamW / Adafactor built from scratch)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # not in the container image - deterministic shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.training.optimizer import (adafactor, adamw,
                                      clip_by_global_norm, global_norm)


@pytest.mark.parametrize("make_opt", [lambda: adamw(lr=0.1),
                                      lambda: adafactor(lr=0.3)])
def test_optimizers_minimize_quadratic(make_opt):
    opt = make_opt()
    params = {"w": jnp.array([3.0, -2.0, 1.5]),
              "m": jnp.ones((4, 5)) * 2.0}

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["m"] ** 2)

    state = opt.init(params)
    l0 = float(loss(params))
    for it in range(150):
        g = jax.grad(loss)(params)
        params, state = opt.update(params, g, state, jnp.int32(it))
    assert float(loss(params)) < 0.05 * l0


def test_adafactor_state_is_factored():
    opt = adafactor()
    params = {"w": jnp.zeros((64, 128)), "b": jnp.zeros((128,))}
    st_ = opt.init(params)
    assert st_["w"]["vr"].shape == (64,)
    assert st_["w"]["vc"].shape == (128,)
    assert st_["b"]["v"].shape == (128,)
    # O(rows+cols) vs O(rows*cols): the paper-scale HBM argument
    n_state = sum(x.size for x in jax.tree.leaves(st_))
    n_param = sum(x.size for x in jax.tree.leaves(params))
    assert n_state < 0.05 * n_param


@given(st.floats(0.1, 10.0))
@settings(max_examples=20, deadline=None)
def test_clip_by_global_norm_bound(max_norm):
    g = {"a": jnp.full((8,), 7.0), "b": jnp.full((3, 3), -4.0)}
    clipped = clip_by_global_norm(g, max_norm)
    assert float(global_norm(clipped)) <= max_norm * (1 + 1e-4)


def test_clip_noop_below_threshold():
    g = {"a": jnp.array([0.1, 0.2])}
    out = clip_by_global_norm(g, 10.0)
    np.testing.assert_allclose(np.asarray(out["a"]),
                               np.asarray(g["a"]), rtol=1e-6)


def test_train_step_microbatch_equivalence():
    """Grad accumulation over microbatches == single big batch (fp32)."""
    from conftest import tiny_cfg
    from repro.models import transformer as T
    from repro.training.trainer import make_train_step
    cfg = tiny_cfg()
    params = T.init(cfg, jax.random.key(0))
    opt = adamw(lr=1e-2, grad_clip=0.0)
    toks = jax.random.randint(jax.random.key(1), (4, 16), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "targets": toks}
    s1 = make_train_step(cfg, opt, n_micro=1, remat=False)
    s4 = make_train_step(cfg, opt, n_micro=4, remat=False)
    p1, _, m1 = s1(params, opt.init(params), batch, jnp.int32(0))
    p4, _, m4 = s4(params, opt.init(params), batch, jnp.int32(0))
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        # atol covers fp32 reduction-order noise amplified by adamw's
        # m/sqrt(v) normalization on near-zero gradient entries
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=5e-4)
