"""Zero-sync observability layer (repro/obs): tracer, metrics registry,
flight recorder, and their engine/system integration.

The load-bearing contract is **inertness**: observability-on serving
must emit byte-identical token streams and the exact same device
dispatch count as observability-off (hooks are host-side, at existing
telemetry boundaries), and the null singletons must make the disabled
path one attribute check.  On top of that: exported Chrome trace JSON
must be loadable and well-nested, ``metrics.snapshot()`` must agree
with the legacy ``ServingStats`` / ``TideSystem.summary()`` counters,
and the flight recorder must tell each request's whole story
(admit -> chunks -> first token -> commits -> finish).

Unit tests run weight-free; the engine tests use randomly initialized
weights (inertness is a property of the computation, not the model) so
the file stays in the fast tier.  The train-cycle trace test needs a
pretrained target and is slow-marked.
"""
import json
import threading
import time

import jax
import numpy as np
import pytest

import repro.configs as C
from repro.core import eagle
from repro.core.tide import TideConfig, TideSystem
from repro.models import transformer as T
from repro.obs import ObsConfig
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.recorder import NULL_RECORDER, FlightRecorder
from repro.obs.trace import NULL_TRACER, Tracer
from repro.serving.engine import ServingEngine, ServingStats
from repro.serving.request import Request


# ================================================================ tracer
def test_tracer_spans_nest_and_export(tmp_path):
    tr = Tracer()
    with tr.span("outer", k=1):
        with tr.span("inner"):
            pass
        tr.instant("tick", n=3)
    tr.counter("depth", queue=2)
    path = tmp_path / "trace.json"
    doc = tr.export(str(path))
    # the written file is valid JSON and identical to the return value
    assert json.loads(path.read_text()) == doc
    evs = doc["traceEvents"]
    by_name = {e["name"]: e for e in evs}
    outer, inner = by_name["outer"], by_name["inner"]
    assert outer["ph"] == "X" and inner["ph"] == "X"
    # nesting: inner lies within [outer.ts, outer.ts + outer.dur]
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
    assert outer["args"] == {"k": 1}
    assert by_name["tick"]["ph"] == "i" and by_name["tick"]["s"] == "t"
    assert by_name["depth"]["ph"] == "C"
    # thread metadata row present, same tid as the spans
    meta = [e for e in evs if e["ph"] == "M"]
    assert meta and meta[0]["name"] == "thread_name"
    assert {e["tid"] for e in (outer, inner)} <= {m["tid"] for m in meta}


def test_tracer_ring_bounded():
    tr = Tracer(capacity=8)
    for i in range(100):
        tr.instant(f"e{i}")
    assert len(tr.events()) == 8
    names = [e[1] for e in tr.events()]
    assert names == [f"e{i}" for i in range(92, 100)]


def test_tracer_thread_safe_spans():
    tr = Tracer()
    barrier = threading.Barrier(4)   # all 4 alive together -> distinct
    #                                  native thread ids on the spans

    def worker(tag):
        barrier.wait()
        for _ in range(50):
            with tr.span(tag):
                pass

    ts = [threading.Thread(target=worker, args=(f"w{i}",))
          for i in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    evs = tr.export()["traceEvents"]
    assert sum(e["ph"] == "X" for e in evs) == 200
    # per-thread rows carry distinct tids
    assert len({e["tid"] for e in evs if e["ph"] == "X"}) == 4


def test_null_tracer_is_inert():
    assert not NULL_TRACER.enabled
    with NULL_TRACER.span("x", a=1):
        NULL_TRACER.instant("y")
    assert NULL_TRACER.export()["traceEvents"] == []


# ============================================================== registry
def test_registry_counter_gauge_histogram():
    reg = MetricsRegistry()
    c = reg.counter("serving.tokens_out")
    c.inc(5)
    c.inc()
    assert reg.counter("serving.tokens_out") is c       # get-or-create
    g = reg.gauge("spec.gamma")
    g.set(3)
    reg.gauge("train.cycles", fn=lambda: 7)             # callback gauge
    h = reg.histogram("serving.ttft_s", quantiles=(0.5,))
    for x in (0.1, 0.2, 0.3):
        h.observe(x)
    snap = reg.snapshot()
    assert snap["serving.tokens_out"] == 6
    assert snap["spec.gamma"] == 3
    assert snap["train.cycles"] == 7
    assert snap["serving.ttft_s.count"] == 3
    assert abs(snap["serving.ttft_s.p50"] - 0.2) < 1e-9
    assert abs(snap["serving.ttft_s.max"] - 0.3) < 1e-9
    assert set(reg.namespaces()) == {"serving", "spec", "train"}


def test_registry_gauge_fn_rebind():
    """A fresh ServingStats must be able to re-register its derived
    gauges against a long-lived registry: gauge(fn=...) rebinds."""
    reg = MetricsRegistry()
    reg.gauge("serving.throughput", fn=lambda: 1.0)
    reg.gauge("serving.throughput", fn=lambda: 2.0)
    assert reg.snapshot()["serving.throughput"] == 2.0


def test_registry_prometheus_text():
    reg = MetricsRegistry()
    reg.counter("serving.tokens_out").inc(9)
    h = reg.histogram("serving.latency_s", quantiles=(0.5, 0.95))
    h.observe(1.0)
    text = reg.to_prometheus()
    assert "# TYPE serving_tokens_out counter" in text
    assert "serving_tokens_out 9" in text
    assert 'serving_latency_s{quantile="0.5"}' in text
    assert "serving_latency_s_count 1" in text


def test_registry_to_json(tmp_path):
    reg = MetricsRegistry()
    reg.counter("a.b").inc(2)
    p = tmp_path / "m.json"
    text = reg.to_json(str(p))
    assert json.loads(p.read_text()) == json.loads(text) == {"a.b": 2}


# ======================================================= flight recorder
def _req(prompt=(1, 2, 3), **kw):
    r = Request(prompt=list(prompt), max_new_tokens=8, **kw)
    r.rid = kw.get("rid", r.rid)
    return r


def test_recorder_lifecycle():
    rec = FlightRecorder()
    r = Request(prompt=[1, 2], max_new_tokens=4, domain="science")
    r.sid = 0
    rec.admit(r, round_=2)
    rec.note(r.rid, "first_token", round_=3)
    rec.note(r.rid, "commit", round_=4, n=3, spec=True)
    r.generated = [5, 6, 7]
    r.arrival_t, r.admit_t = 1.0, 1.0
    r.first_token_t, r.finish_t = 1.5, 2.0
    rec.finish(r, round_=5)
    tl = rec.timeline(r.rid)
    assert tl["domain"] == "science" and tl["prompt_len"] == 2
    kinds = [e["kind"] for e in tl["events"]]
    assert kinds == ["admit", "first_token", "commit", "finish"]
    assert tl["events"][2]["n"] == 3 and tl["events"][2]["spec"] is True
    assert tl["events"][-1]["tokens"] == 3
    assert tl["ttft_s"] == pytest.approx(0.5)
    assert tl["latency_s"] == pytest.approx(1.0)
    doc = rec.export()
    assert doc["requests"] == [tl]


def test_recorder_notes_for_unknown_rid_are_dropped():
    rec = FlightRecorder()
    rec.note("nope", "commit", round_=1, n=2)   # must not raise
    assert rec.timeline("nope") is None


def test_null_recorder_is_inert():
    assert not NULL_RECORDER.enabled
    r = Request(prompt=[1], max_new_tokens=1)
    NULL_RECORDER.admit(r, 0)
    NULL_RECORDER.note(r.rid, "commit", 1, n=1)
    NULL_RECORDER.finish(r, 2)
    assert NULL_RECORDER.timelines() == []
    assert NULL_RECORDER.export() == {"requests": [], "events": []}


# =============================================== ServingStats <-> registry
def test_serving_stats_is_registry_backed():
    reg = MetricsRegistry()
    st = ServingStats(registry=reg)
    st.tokens_out += 10
    st.steps += 2
    st.wall_s += 0.5
    st.record_ttft(0.1)
    st.record_latency(0.9)
    snap = reg.snapshot()
    assert snap["serving.tokens_out"] == 10
    assert snap["serving.steps"] == 2
    assert snap["serving.wall_s"] == 0.5
    assert snap["serving.throughput_tok_s"] == st.throughput == 20.0
    assert snap["serving.ttft_s.count"] == 1
    assert snap["serving.latency_s.count"] == 1
    assert st.ttft_p50 == pytest.approx(0.1)
    # a fresh stats object over the same registry re-zeroes serving.*
    st2 = ServingStats(registry=reg)
    snap2 = reg.snapshot()
    assert snap2["serving.tokens_out"] == 0
    assert snap2["serving.ttft_s.count"] == 0
    assert snap2["serving.throughput_tok_s"] == st2.throughput == 0.0


def test_serving_stats_private_registry_default():
    a, b = ServingStats(), ServingStats()
    a.tokens_out += 3
    assert b.tokens_out == 0            # no shared hidden state


# ========================================================== engine parity
_MODEL = None


def _get_model():
    global _MODEL
    if _MODEL is None:
        cfg = C.get("tide-tiny")
        params = T.init(cfg, jax.random.key(0))
        dcfg = eagle.draft_config(cfg)
        dparams = eagle.draft_init(dcfg, jax.random.key(7))
        _MODEL = (cfg, params, dcfg, dparams)
    return _MODEL


def _serve(eng, *, waves=2, batch=2, max_new=12, seed=0):
    rng = np.random.default_rng(seed)
    gens = []
    for _ in range(waves):
        reqs = [Request(prompt=list(rng.integers(1, 50, 7)),
                        max_new_tokens=max_new) for _ in range(batch)]
        eng.serve_wave(reqs)
        gens.extend(list(r.generated) for r in reqs)
    return gens


def test_engine_obs_on_streams_byte_identical():
    cfg, params, dcfg, dparams = _get_model()
    kw = dict(batch_size=2, max_len=96, gamma=3, seed=5,
              superstep_rounds=8)
    off = ServingEngine(cfg, params, dcfg, dparams, **kw)
    on = ServingEngine(cfg, params, dcfg, dparams, **kw,
                       tracer=Tracer(), recorder=FlightRecorder(),
                       metrics=MetricsRegistry())
    s_off = _serve(off)
    s_on = _serve(on)
    assert s_on == s_off
    assert on.stats.dispatches == off.stats.dispatches
    assert on.stats.tokens_out == off.stats.tokens_out
    # the trace covers the loop
    names = {e[1] for e in on.tracer.events()}
    assert {"superstep.dispatch", "superstep.unpack"} <= names
    # the registry agrees with the stats view
    snap = on.metrics.snapshot()
    assert snap["serving.tokens_out"] == on.stats.tokens_out
    assert snap["serving.dispatches"] == on.stats.dispatches
    # spec/paging namespaces are registered (zero gauges on dense)
    assert {"serving", "spec", "paging"} <= set(on.metrics.namespaces())
    # every request has a full flight timeline
    tls = on.recorder.timelines()
    assert len(tls) == 4
    for tl in tls:
        kinds = [e["kind"] for e in tl["events"]]
        assert kinds[0] == "admit" and kinds[-1] == "finish"
        assert "first_token" in kinds and "commit" in kinds
        # commit notes account for every token except (at most) the
        # first, which the prefill prologue emits outside the unpack
        committed = sum(e.get("n", 0) for e in tl["events"]
                        if e["kind"] == "commit")
        assert tl["events"][-1]["tokens"] - committed in (0, 1)


def test_engine_recorder_covers_chunked_prefill():
    cfg, params, dcfg, dparams = _get_model()
    eng = ServingEngine(cfg, params, dcfg, dparams, batch_size=2,
                        max_len=96, gamma=3, seed=5, superstep_rounds=8,
                        prefill_chunk=8, recorder=FlightRecorder(),
                        tracer=Tracer())
    rng = np.random.default_rng(1)
    reqs = [Request(prompt=list(rng.integers(1, 50, 20)),
                    max_new_tokens=8) for _ in range(2)]
    list(eng.serve_stream(iter(reqs)))
    for tl in eng.recorder.timelines():
        kinds = [e["kind"] for e in tl["events"]]
        assert "prefill_chunk" in kinds
    names = {e[1] for e in eng.tracer.events()}
    assert "prefill.chunk" in names


# ======================================================== system parity
_SYS_TCFG = dict(gamma=3, batch_size=2, max_len=96, adaptive_spec=False,
                 selective_training=True, signal_window=8,
                 n_threshold=4, train_epochs=1, train_min_steps=6,
                 seed=0)


def _waves(n_waves=2, batch=2, seed=1):
    rng = np.random.default_rng(seed)
    return [[("science", list(rng.integers(1, 50, 7)))
             for _ in range(batch)] for _ in range(n_waves)]


@pytest.mark.slow
def test_system_snapshot_matches_summary():
    """`metrics.snapshot()` must agree with every counter the legacy
    ``summary()`` dict reports, across all four namespaces."""
    cfg, params, dcfg, dparams = _get_model()
    tc = TideConfig(**_SYS_TCFG,
                    obs=ObsConfig(trace=True, record=True))
    sys_ = TideSystem(cfg, params, tc, dparams=dparams)
    off = TideSystem(cfg, params, TideConfig(**_SYS_TCFG),
                     dparams=dparams)
    waves = _waves()
    a = sys_.run(iter(waves), max_new_tokens=12)
    b = off.run(iter(waves), max_new_tokens=12)
    assert [r.generated for r in a] == [r.generated for r in b]

    s, snap = sys_.summary(), sys_.snapshot()
    for summary_key, metric in [
            ("tokens", "serving.tokens_out"),
            ("steps", "serving.steps"),
            ("spec_steps", "serving.spec_steps"),
            ("refills", "serving.refills"),
            ("idle_supersteps", "serving.idle_supersteps"),
            ("deploys", "serving.deploys"),
            ("reseeds", "serving.reseeds"),
            ("spec_parks", "spec.parks"),
            ("spec_resumes", "spec.resumes"),
            ("train_cycles", "train.cycles"),
            ("deployed", "train.deploy_version"),
            ("signals_collected", "train.signals_pushed"),
            ("signal_bytes", "train.signal_bytes"),
            ("signals_dropped", "train.signals_dropped"),
    ]:
        assert snap[metric] == s[summary_key], (summary_key, metric)
    assert snap["serving.throughput_tok_s"] == s["throughput_tok_s"]
    assert snap["serving.accept_len"] == s["accept_len"]
    assert snap["serving.occupancy"] == s["occupancy"]
    # obs-off system has null instruments
    assert not off.tracer.enabled and not off.recorder.enabled
    assert sys_.tracer.enabled and sys_.recorder.enabled


@pytest.mark.slow
def test_system_trace_covers_training(tmp_path):
    """A stream that actually trains must leave train.cycle spans,
    train.publish + deploy instants, and matching train.* gauges."""
    from repro.data.workloads import make_domains, training_corpus
    from repro.training.trainer import pretrain_target

    cfg = C.get("tide-tiny")
    params = T.init(cfg, jax.random.key(0))
    domains = make_domains(cfg.vocab_size, ["science"], branchings=[2],
                           seed=3)
    corpus = training_corpus(domains["science"], 64, 40, 1)
    params, _ = pretrain_target(cfg, params, corpus, steps=80, lr=3e-3)
    dcfg = eagle.draft_config(cfg)
    dparams = eagle.draft_init(dcfg, jax.random.key(7))

    tc = TideConfig(**_SYS_TCFG, obs=ObsConfig(trace=True))
    sys_ = TideSystem(cfg, params, tc, dparams=dparams)
    rng = np.random.default_rng(1)
    waves = [[("science", domains["science"].sample_prompt(rng))
              for _ in range(2)] for _ in range(4)]
    sys_.run(iter(waves), max_new_tokens=24)
    assert sys_.summary()["train_cycles"] >= 1, "scenario never trained"

    path = tmp_path / "trace.json"
    doc = sys_.export_trace(str(path))
    assert json.loads(path.read_text()) == doc
    evs = doc["traceEvents"]
    names = {e["name"] for e in evs}
    assert {"superstep.dispatch", "superstep.unpack",
            "train.cycle", "train.publish", "deploy"} <= names
    # train.cycle runs on the service side, publish nested within a run
    cyc = next(e for e in evs if e["name"] == "train.cycle")
    assert cyc["ph"] == "X" and cyc["dur"] > 0
    snap = sys_.snapshot()
    assert snap["train.cycles"] == sys_.summary()["train_cycles"]
    assert snap["train.deploy_version"] == sys_.gate.version
