"""Dry-run launcher end-to-end (deliverable e): lower + compile one
(arch × shape) on the production mesh in a subprocess (the 512-device
XLA flag must be set before jax initializes, hence not in-process)."""
import json
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_dryrun_subprocess(tmp_path):
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "whisper-base", "--shape", "decode_32k",
         "--out", str(tmp_path)],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stdout + out.stderr
    files = list(tmp_path.glob("*.json"))
    assert len(files) == 1
    d = json.loads(files[0].read_text())
    assert d["mesh"] == "16x16"
    assert d["roofline"]["dominant"] in ("compute", "memory", "collective")
    assert d["roofline"]["step_s"] > 0
    assert "resident_bytes" in d
