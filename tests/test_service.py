"""Decoupled draft-training subsystem: transport, service, deploys.

Covers the new-subsystem checklist: SignalChannel overflow/drop-oldest
and blocking/close semantics, deploy-version monotonicity through the
gate, ``service.drain()`` parity with the legacy synchronous
``TideSystem`` training schedule (hand-rolled reference), deploy-time
draft-cache re-seed (idempotence + acceptance effect), arrival gating /
idle supersteps, bounded stats (Ring + P² sketch), the scheduler
completion sink, and clean thread shutdown.
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Pretrained-fixture-heavy end-to-end parity suite: slow tier (the
# fast smoke loop runs `pytest -m "not slow"`; see ROADMAP.md).
pytestmark = pytest.mark.slow

import repro.configs as C
from repro.core import eagle
from repro.core import speculative as spec
from repro.core.signals import SignalBatch
from repro.core.tide import TideConfig, TideSystem
from repro.core.transport import SignalChannel
from repro.data.workloads import make_domains, training_corpus
from repro.models import transformer as T
from repro.serving.engine import ServingEngine
from repro.serving.request import Request
from repro.serving.scheduler import Scheduler
from repro.serving.stats import P2Quantile, Ring
from repro.training.service import DraftVersion, TrainingService


@pytest.fixture(scope="module")
def pretrained():
    from repro.training.trainer import pretrain_target

    cfg = C.get("tide-tiny")
    params = T.init(cfg, jax.random.key(0))
    domains = make_domains(cfg.vocab_size, ["science"], branchings=[2],
                           seed=3)
    corpus = training_corpus(domains["science"], 64, 40, 1)
    params, _ = pretrain_target(cfg, params, corpus, steps=80, lr=3e-3)
    dcfg = eagle.draft_config(cfg)
    dparams = eagle.draft_init(dcfg, jax.random.key(7))
    return cfg, params, dcfg, dparams, domains


def _batch(i, s=8, f=6):
    return SignalBatch(feats=np.full((s, f), i, np.float32),
                       tokens=np.full((s,), i, np.int32))


# ================================================== SignalChannel
def test_channel_overflow_drop_oldest():
    ch = SignalChannel(capacity=4)
    for i in range(7):
        ch.add(_batch(i))
    assert ch.peek_count() == 4
    assert ch.dropped == 3
    assert ch.total_added == 7
    kept = [int(b.tokens[0]) for b in ch.drain()]
    assert kept == [3, 4, 5, 6], "must keep the freshest batches"
    st = ch.stats()
    assert st["pushed"] == 7 and st["dropped"] == 3 and st["depth"] == 0


def test_channel_wait_and_close_wakes_consumer():
    ch = SignalChannel(capacity=8)
    got = {}

    def consumer():
        got["n"] = ch.wait(min_count=2, timeout=5.0)

    t = threading.Thread(target=consumer)
    t.start()
    ch.add(_batch(0))
    ch.add(_batch(1))
    t.join(timeout=5.0)
    assert not t.is_alive() and got["n"] == 2

    # a consumer blocked on an impossible count must be woken by close
    t2 = threading.Thread(target=lambda: ch.wait(min_count=99,
                                                 timeout=10.0))
    t2.start()
    time.sleep(0.05)
    ch.close()
    t2.join(timeout=2.0)
    assert not t2.is_alive(), "close() must wake blocked waiters"


def test_channel_add_after_close_drops_and_counts():
    """The shutdown bugfix: a straggling producer (a superstep unpacked
    after service shutdown) must not grow a ring nobody drains — the
    closed channel drops the batch and counts it, and the drained set
    stays exactly the pre-close buffer."""
    ch = SignalChannel(capacity=8)
    ch.add(_batch(0))
    ch.add(_batch(1))
    ch.close()
    ch.add(_batch(2))            # post-close: dropped, not buffered
    ch.add(_batch(3))
    assert ch.peek_count() == 2
    assert ch.rejected_after_close == 2
    assert ch.stats()["rejected_after_close"] == 2
    kept = [int(b.tokens[0]) for b in ch.drain()]
    assert kept == [0, 1], "drain must see exactly the pre-close batches"
    assert ch.drain() == []      # deterministic: later drains are empty
    ch.add(_batch(4))
    assert ch.drain() == [] and ch.rejected_after_close == 3
    # total_added never counts rejected batches
    assert ch.total_added == 2


def test_channel_reset_clears_rejection_counter():
    ch = SignalChannel(capacity=4)
    ch.close()
    ch.add(_batch(0))
    assert ch.rejected_after_close == 1
    ch.reset()
    assert ch.rejected_after_close == 0 and ch.peek_count() == 0


def test_service_rejects_starving_channel(pretrained):
    """A per-cycle threshold the bounded channel can never buffer must
    fail loudly at construction, not silently never train."""
    cfg, params, dcfg, dparams, _ = pretrained
    from repro.checkpoint.ckpt import DraftDeployGate
    from repro.training.draft_trainer import DraftTrainer

    with pytest.raises(ValueError, match="starve"):
        TrainingService(DraftTrainer(cfg, dcfg, params["embed"]),
                        DraftDeployGate(dparams),
                        SignalChannel(capacity=4),
                        n_threshold=100, signal_window=10)


# ================================================== deploy versioning
def test_deploy_version_monotonic(pretrained):
    cfg, params, dcfg, dparams, _ = pretrained
    from repro.checkpoint.ckpt import DraftDeployGate
    from repro.training.draft_trainer import DraftTrainer

    gate = DraftDeployGate(dparams)
    ch = SignalChannel(capacity=8)
    svc = TrainingService(DraftTrainer(cfg, dcfg, params["embed"]), gate,
                          ch, n_threshold=1, signal_window=1,
                          train_epochs=1, train_min_steps=2)
    assert svc.poll() is None
    # publish through the gate path directly: accepted offers bump seq
    gate.offer(dparams, 0.5, 0.1)
    svc._latest = DraftVersion(gate.version, dparams, 0.5)
    v1 = svc.poll()
    assert v1.seq == 1
    # a losing offer must not advance the version
    assert not gate.offer(dparams, 0.05, 0.5)
    assert gate.version == 1
    gate.offer(dparams, 0.9, 0.1)
    svc._latest = DraftVersion(gate.version, dparams, 0.9)
    assert svc.poll().seq == 2 > v1.seq


# ====================================== drain() parity vs legacy sync
def _waves(domains, n_waves, batch, seed=1, max_new=24):
    rng = np.random.default_rng(seed)
    return [[("science", domains["science"].sample_prompt(rng))
             for _ in range(batch)] for _ in range(n_waves)]


_TCFG = dict(gamma=3, batch_size=2, max_len=96, adaptive_spec=False,
             selective_training=True, signal_window=8, n_threshold=4,
             train_epochs=1, train_min_steps=6, seed=0)


def _legacy_maybe_train(sys_: TideSystem, events):
    """The pre-service synchronous trainer, verbatim (old
    ``TideSystem._maybe_train``), driving the same components."""
    tcfg = sys_.tcfg
    need = sys_.store.peek_count() * tcfg.signal_window
    if need < sys_.controller.n_threshold:
        return
    batches = sys_.store.drain()
    baseline = sys_.controller.alpha_train
    dparams, _ = sys_.gate.current()
    result = sys_.trainer.train_cycle(dparams, batches,
                                      epochs=tcfg.train_epochs,
                                      min_steps=tcfg.train_min_steps,
                                      seed=tcfg.seed)
    deployed = sys_.gate.offer(result["dparams"], result["eval_acc"],
                               baseline)
    if tcfg.selective_training:
        sys_.controller.training_result(result["eval_acc"])
    if deployed:
        sys_.engine.deploy_draft(result["dparams"])
    events.append({
        "kind": "train_cycle", "eval_acc": result["eval_acc"],
        "train_acc": result["train_acc"], "baseline": baseline,
        "deployed": deployed, "steps": result["steps"],
        "engine_steps": sys_.engine.stats.steps,
    })


def _strip(events):
    return [{k: v for k, v in e.items() if k != "seconds"}
            for e in events]


def test_drain_parity_with_legacy_synchronous(pretrained):
    """The service-based sync mode must reproduce the legacy blocking
    scheduler byte-for-byte: token streams, deploy versions, and the
    train-cycle event stream (timing excluded)."""
    cfg, params, dcfg, dparams, domains = pretrained
    waves = _waves(domains, 4, 2)

    ref = TideSystem(cfg, params, TideConfig(**_TCFG), dparams=dparams)
    ref_events = []
    ref_done = []
    for wave in waves:
        reqs = [Request(prompt=list(p), domain=d, max_new_tokens=24)
                for d, p in wave]
        ref.engine.serve_wave(reqs)
        ref_done.extend(reqs)
        _legacy_maybe_train(ref, ref_events)

    new = TideSystem(cfg, params, TideConfig(**_TCFG), dparams=dparams)
    new_done = new.run(iter(waves), max_new_tokens=24)

    assert [r.generated for r in new_done] == \
        [r.generated for r in ref_done]
    assert len(ref_events) >= 1, "scenario never trained"
    assert _strip(new.events) == ref_events
    assert new.gate.version == ref.gate.version
    assert new.summary()["train_cycles"] == len(ref_events)


def test_reset_adaptation_reproduces_run(pretrained):
    """reset_adaptation must restore the post-construction adaptive
    state exactly: a re-run emits identical events and streams."""
    cfg, params, dcfg, dparams, domains = pretrained
    waves = _waves(domains, 3, 2)
    sys_ = TideSystem(cfg, params, TideConfig(**_TCFG), dparams=dparams)
    a = sys_.run(iter(waves))
    ev_a = _strip(sys_.events)
    assert len(ev_a) >= 1
    sys_.reset_adaptation()
    b = sys_.run(iter(waves))
    assert [r.generated for r in b] == [r.generated for r in a]
    assert _strip(sys_.events) == ev_a


# ====================================== async service end-to-end
def test_async_service_trains_and_streams_match(pretrained):
    """Async mode: identical greedy token streams, training happens on
    the background thread, deploys version monotonically, shutdown is
    clean (no dangling thread)."""
    cfg, params, dcfg, dparams, domains = pretrained
    waves = _waves(domains, 4, 2)
    reqs_of = lambda: iter([Request(prompt=list(p), domain=d,
                                    max_new_tokens=24)
                            for wave in waves for d, p in wave])

    sync = TideSystem(cfg, params, TideConfig(**_TCFG), dparams=dparams)
    done_sync = sync.run_stream(reqs_of())

    tc = TideConfig(**_TCFG, async_train=True, reseed_window=16)
    asy = TideSystem(cfg, params, tc, dparams=dparams)
    assert asy.service.running
    done_asy = asy.run_stream(reqs_of())
    # settle whatever the thread had not consumed by stream end
    asy.service.drain()
    assert asy.service.cycles >= 1, "async service never trained"
    assert asy.gate.version >= 1
    # per-request greedy streams are training-schedule-invariant
    # (completion *order* may differ — deploys change round counts)
    assert sorted((tuple(r.prompt), tuple(r.generated))
                  for r in done_asy) == \
        sorted((tuple(r.prompt), tuple(r.generated))
               for r in done_sync)
    thread = asy.service._thread
    asy.close()
    assert not asy.service.running
    assert thread is None or not thread.is_alive(), \
        "service thread still alive after close()"
    asy.close()          # idempotent


# ====================================== deploy re-seed (capture ring)
def _engine(pretrained, **kw):
    cfg, params, dcfg, dparams, domains = pretrained
    kw.setdefault("batch_size", 2)
    kw.setdefault("max_len", 96)
    kw.setdefault("superstep_rounds", 8)
    dp = kw.pop("dparams", dparams)
    return ServingEngine(cfg, params, dcfg, dp, gamma=3, seed=5, **kw)


def _reqs(pretrained, budgets, seed=0):
    domains = pretrained[4]
    rng = np.random.default_rng(seed)
    return [Request(prompt=domains["science"].sample_prompt(rng),
                    max_new_tokens=m) for m in budgets]


def test_reseed_idempotent_same_draft(pretrained):
    """Re-seeding with the *same* draft params must leave the draft
    cache bit-identical on the window (the re-seed recomputes exactly
    what serving computed)."""
    cfg, params, dcfg, dparams, domains = pretrained
    eng = _engine(pretrained, reseed_window=16,
                  deploy_source=lambda: None)
    reqs = _reqs(pretrained, (40, 40))
    sched = Scheduler(2, reqs)
    adm = sched.admit()
    eng._assign_sids(adm)
    cache, dcache, carry, first = eng._prologue(reqs)
    state = spec.init_superstep_state(carry, first, eng._base_key,
                                      sids=eng._slot_sids(reqs),
                                      capture_window=eng.reseed_window)
    mx = jnp.asarray([40, 40], jnp.int32)
    out = eng._superstep_fn(eng.params, eng.dparams, cache, dcache,
                            state, mx)
    dcache, state = out["dcache"], out["state"]
    assert int(np.asarray(state.cap_count).min()) > 0
    keep = {k: jnp.array(v) for k, v in dcache.items()}
    dc2 = eng._reseed_fn(eng.dparams, keep, state)
    np.testing.assert_array_equal(np.asarray(dc2["k"]),
                                  np.asarray(dcache["k"]))
    np.testing.assert_array_equal(np.asarray(dc2["v"]),
                                  np.asarray(dcache["v"]))


def test_reseed_matches_new_draft_serving(pretrained):
    """Re-seed-on-deploy acceptance semantics: after deploying draft B
    onto lanes served so far by draft A, the re-seeded window of the
    draft cache must equal — position for position — the cache an
    engine serving with draft B *from the start* holds.  (Greedy
    commits are draft-invariant, so both engines ingest the identical
    (feature, token) pair sequence; draft K/V is a pure per-position
    function of pair and position.)  The new draft's acceptance on
    resident lanes is then exactly its from-scratch acceptance over the
    window."""
    cfg, params, dcfg, dparams, domains = pretrained
    draft_b = eagle.draft_init(dcfg, jax.random.key(99))

    def _drive(dp, window):
        eng = _engine(pretrained, reseed_window=window, dparams=dp,
                      deploy_source=lambda: None)
        reqs = _reqs(pretrained, (64, 64), seed=4)
        sched = Scheduler(2, reqs)
        eng._assign_sids(sched.admit())
        cache, dcache, carry, first = eng._prologue(reqs)
        state = spec.init_superstep_state(
            carry, first, eng._base_key, sids=eng._slot_sids(reqs),
            capture_window=window)
        mx = jnp.asarray([64, 64], jnp.int32)
        for _ in range(3):
            out = eng._superstep_fn(eng.params, eng.dparams, cache,
                                    dcache, state, mx)
            cache, dcache, state = (out["cache"], out["dcache"],
                                    out["state"])
        return eng, dcache, state

    eng_a, dcache_a, state_a = _drive(dparams, 24)     # served by A
    eng_b, dcache_b, state_b = _drive(draft_b, 24)     # served by B

    # snapshot before the re-seed donates (consumes) A's cache buffers
    k_a = np.array(dcache_a["k"])
    # deploy B onto A's lanes and re-seed from the ring
    reseeded = eng_a._reseed_fn(draft_b, dcache_a, state_a)

    k_r, v_r = np.asarray(reseeded["k"]), np.asarray(reseeded["v"])
    k_b, v_b = np.asarray(dcache_b["k"]), np.asarray(dcache_b["v"])
    len_a = np.asarray(reseeded["lengths"])
    len_b = np.asarray(dcache_b["lengths"])
    n = np.minimum(np.asarray(state_a.cap_count), 24)
    assert (n > 0).all(), "capture ring never filled"
    changed = False
    for lane in range(2):
        lo = int(len_a[lane] - n[lane])
        hi = int(min(len_a[lane], len_b[lane]))
        assert hi > lo, "no overlapping re-seeded region to compare"
        # ULP-level tolerance: serving built these entries in (γ+1)-wide
        # extends, the re-seed in one W-wide pass — XLA may tile the
        # projection differently per width
        np.testing.assert_allclose(k_r[lane, lo:hi], k_b[lane, lo:hi],
                                   rtol=2e-5, atol=1e-5)
        np.testing.assert_allclose(v_r[lane, lo:hi], v_b[lane, lo:hi],
                                   rtol=2e-5, atol=1e-5)
        changed |= bool(np.max(np.abs(k_r[lane, lo:hi]
                                      - k_a[lane, lo:hi])) > 1e-2)
    assert changed, "re-seed was a no-op (drafts differ, K/V must too)"


def test_reseed_deploy_stream_invariant(pretrained):
    """End-to-end: a mid-stream deploy with re-seed leaves greedy token
    streams byte-identical (the target verifies every draft) while the
    engine records the deploy and the re-seed dispatch."""
    cfg, params, dcfg, dparams, domains = pretrained
    draft_b = eagle.draft_init(dcfg, jax.random.key(99))

    class _AfterN:
        def __init__(self, n):
            self.n, self.polls = n, 0

        def __call__(self):
            self.polls += 1
            return (DraftVersion(1, draft_b, 0.9)
                    if self.polls >= self.n else None)

    ref = _engine(pretrained)
    r_ref = _reqs(pretrained, (40, 40), seed=4)
    ref.serve_stream(r_ref)

    eng = _engine(pretrained, reseed_window=24, deploy_source=_AfterN(3))
    r_new = _reqs(pretrained, (40, 40), seed=4)
    eng.serve_stream(r_new)
    assert eng.stats.deploys == 1 and eng.stats.reseeds == 1
    assert [r.generated for r in r_new] == [r.generated for r in r_ref]


# ====================================== arrival gating + idle supersteps
def test_scheduler_arrival_gating_fake_clock():
    now = {"t": 0.0}
    clock = lambda: now["t"]
    reqs = [Request(prompt=[1, 2], max_new_tokens=4, arrives_at=t)
            for t in (0.0, 0.5, 1.5)]
    s = Scheduler(2, reqs, gate_arrivals=True, clock=clock)
    assert s.has_pending()
    assert [slot for slot, _ in s.admit()] == [0]
    assert not s.has_pending()           # t=0.5 not arrived yet
    assert s.more_coming()
    assert s.next_arrival_in() == pytest.approx(0.5)
    now["t"] = 0.6
    assert s.has_pending()
    assert [slot for slot, _ in s.admit()] == [1]
    now["t"] = 0.7
    assert s.next_arrival_in() == pytest.approx(0.8)
    s.slots[0].finish()
    s.release_finished()
    assert s.admit() == []               # third still in the future
    now["t"] = 2.0
    assert [slot for slot, _ in s.admit()] == [0]
    # next_arrival_in probes the (lazy) iterator and discovers exhaustion
    assert s.next_arrival_in() is None
    assert not s.more_coming()


def test_engine_idle_supersteps_and_gated_serving(pretrained):
    """Arrival gaps produce idle supersteps (no dispatch), every request
    is still served exactly, and token streams match the ungated run."""
    budgets = (6, 9, 5, 8)
    base = _reqs(pretrained, budgets, seed=2)
    ref_eng = _engine(pretrained)
    ref = [Request(prompt=list(r.prompt), max_new_tokens=r.max_new_tokens)
           for r in base]
    ref_eng.serve_stream(ref)

    gated = [Request(prompt=list(r.prompt),
                     max_new_tokens=r.max_new_tokens,
                     arrives_at=[0.0, 0.0, 0.35, 0.55][i])
             for i, r in enumerate(base)]
    eng = _engine(pretrained, gate_arrivals=True)
    # warm the jits first: a cold compile inside the gated serve would
    # swallow the arrival gaps and leave nothing to idle on
    warm = [Request(prompt=list(r.prompt),
                    max_new_tokens=r.max_new_tokens) for r in base]
    eng.serve_stream(warm)
    eng.stats = type(eng.stats)()
    done = eng.serve_stream(gated)
    assert len(done) == 4
    assert [r.generated for r in gated] == [r.generated for r in ref]
    assert eng.stats.idle_supersteps > 0, \
        "arrival gaps must surface as idle supersteps"
    for r in gated[2:]:
        # latency clock re-anchored to the gated arrival instant
        assert r.ttft is not None and r.ttft < 10.0


# ====================================== bounded stats + completion sink
def test_ring_and_p2_sketch():
    r = Ring(maxlen=8)
    for i in range(20):
        r.append(i)
    assert list(r) == list(range(12, 20))
    assert r[:3] == [12, 13, 14]        # slicing still works

    rng = np.random.default_rng(0)
    xs = rng.exponential(1.0, size=5000)
    for q in (0.5, 0.95):
        sk = P2Quantile(q)
        for x in xs:
            sk.add(float(x))
        exact = float(np.quantile(xs, q))
        assert abs(sk.value - exact) / exact < 0.08, \
            f"P2 q={q}: {sk.value:.3f} vs exact {exact:.3f}"
    # exact for small n
    sk = P2Quantile(0.5)
    for x in (5.0, 1.0, 3.0):
        sk.add(x)
    assert sk.value == pytest.approx(3.0)


def test_stats_retention_bounded_and_sketch_percentiles(pretrained):
    from repro.serving.engine import ServingStats

    st = ServingStats(retain=16)
    rng = np.random.default_rng(1)
    lats = rng.uniform(0.1, 2.0, size=400)
    for x in lats:
        st.record_latency(float(x))
        st.record_ttft(float(x) / 2)
    assert len(st.latencies) == 16 and len(st.ttfts) == 16
    assert st.timeline.maxlen == 16
    p95 = float(np.quantile(lats, 0.95))
    assert abs(st.latency_p95 - p95) / p95 < 0.15
    assert st.latency_p50 <= st.latency_p95


def test_completion_sink_bounds_scheduler(pretrained):
    sunk = []
    eng = _engine(pretrained, completion_sink=sunk.append)
    reqs = _reqs(pretrained, (5, 7, 4, 6), seed=3)
    out = eng.serve_stream(reqs)
    assert out == [], "sink mode must not retain completions"
    assert sorted(r.rid for r in sunk) == sorted(r.rid for r in reqs)
    assert all(r.finish_t is not None for r in sunk)
