"""Out-of-process draft trainer entrypoint.

``python -m repro.fleet.trainer_main --listen unix:/path`` (or
``tcp:host:port``) accepts one serving-side connection and runs the
real ``training.service.TrainingService`` on this process's *own* XLA
client — the true thread/device isolation the in-process
``trainer_threads`` nice-level hack could only approximate: the
trainer's jitted cycles compile and run in a separate process with a
separate intra-op thread pool, and the serving process's XLA client
never executes a training op.

Protocol (see ``fleet.wire``): the serving side opens with HELLO
(model/draft configs + train kwargs + async flag) and INIT (frozen
embeddings + initial draft params); the host builds the trainer stack,
acks HELLO, then loops on SIGNALS / DRAIN / RESET / BYE.  Published
drafts and cycle events stream back as DRAFT / EVENT frames through the
service's ``on_publish``/``on_event`` hooks — in async mode from the
background cycle loop, in sync (drain-parity) mode inline before the
DRAIN_ACK, which is what makes the remote drain barrier byte-
deterministic for the serving engine.

``TrainerHost`` is transport-agnostic (any connected stream socket), so
tests drive the full protocol over ``socket.socketpair()`` with a stub
service factory — no subprocess, no XLA warm-up.
"""
from __future__ import annotations

import argparse
import os
import threading
from typing import Callable, Dict, Optional

from repro.fleet import wire


def default_service_factory(hello: Dict, embed, dparams0,
                            host: "TrainerHost"):
    """Build the real trainer stack from the handshake: DraftTrainer on
    this process's XLA client, a deploy gate seeded with the shipped
    draft, and a TrainingService whose baseline comes from the wire
    (the serving side ships its controller's ``alpha_train`` with each
    SIGNALS frame) and whose publish/event hooks frame straight back
    onto the socket."""
    from repro.checkpoint.ckpt import DraftDeployGate
    from repro.core.transport import SignalChannel
    from repro.training.draft_trainer import DraftTrainer
    from repro.training.service import TrainingService

    import jax

    tcfg = wire.config_from_dict(hello["tcfg"])
    dcfg = wire.config_from_dict(hello["dcfg"])
    t = hello["train"]
    # off the wire the trees are numpy; the embed is *captured* by the
    # jitted train step (not a traced argument), so it must be a device
    # array or tracing fails on the first cycle
    embed = jax.device_put(embed)
    dparams0 = jax.device_put(dparams0)
    trainer = DraftTrainer(tcfg, dcfg, embed)
    gate = DraftDeployGate(dparams0)
    min_batches = -(-int(t["n_threshold"]) // max(int(t["signal_window"]),
                                                 1))
    channel = SignalChannel(capacity=max(512, min_batches))
    return TrainingService(
        trainer, gate, channel,
        controller=None, selective=False,
        n_threshold=int(t["n_threshold"]),
        signal_window=int(t["signal_window"]),
        train_epochs=int(t["train_epochs"]),
        train_min_steps=int(t["train_min_steps"]),
        seed=int(t["seed"]),
        baseline_fn=lambda: host.baseline,
        on_publish=host.send_draft,
        on_event=host.send_event)


class TrainerHost:
    """One serving connection's trainer: handshake, then frame loop.

    Transport-agnostic — ``conn`` is any connected stream socket.
    ``service_factory(hello, embed, dparams0, host)`` builds the
    service; tests substitute a stub to exercise the protocol without
    XLA."""

    def __init__(self, conn, service_factory: Optional[Callable] = None):
        self.conn = conn
        self.service_factory = service_factory or default_service_factory
        self.baseline = 0.0       # freshest serving-side deploy baseline
        self.service = None
        self.dparams0 = None
        self._send_lock = threading.Lock()

    # ------------------------------------------------------------- frames
    def _send(self, ftype: int, payload: bytes = b""):
        with self._send_lock:
            self.conn.sendall(wire.encode_frame(ftype, payload))

    def send_draft(self, ver):
        self._send(wire.FT_DRAFT,
                   wire.draft_payload(ver.seq, ver.dparams, ver.eval_acc))

    def send_event(self, event: Dict):
        self._send(wire.FT_EVENT, wire.json_payload(
            {k: v for k, v in event.items()
             if isinstance(v, (str, int, float, bool)) or v is None}))

    # --------------------------------------------------------------- loop
    def run(self):
        reader = wire.FrameReader()
        frames = wire.recv_frames(self.conn, reader)
        try:
            self._handshake(frames)
            for ftype, _flags, payload in frames:
                if ftype == wire.FT_SIGNALS:
                    batches, baseline = wire.decode_signals(payload)
                    self.baseline = baseline
                    for b in batches:
                        self.service.channel.add(b)
                elif ftype == wire.FT_DRAIN:
                    token = wire.decode_json(payload).get("token", -1)
                    cycles = self.service.drain()
                    self._send(wire.FT_DRAIN_ACK, wire.json_payload(
                        {"token": token, "cycles": cycles,
                         "version": self.service.gate.version,
                         "failures": self.service.failures}))
                elif ftype == wire.FT_RESET:
                    token = wire.decode_json(payload).get("token", -1)
                    with self.service._train_lock:
                        self.service.channel.reset()
                        self.service.gate.reset(self.dparams0)
                        self.service.reset()
                    self.baseline = 0.0
                    self._send(wire.FT_RESET_ACK,
                               wire.json_payload({"token": token}))
                elif ftype == wire.FT_BYE:
                    break
                else:
                    raise wire.WireError(
                        f"unexpected frame "
                        f"{wire.FRAME_NAMES.get(ftype, ftype)} "
                        "from serving side")
        finally:
            if self.service is not None:
                self.service.close()   # never raises (abandons on wedge)

    def _handshake(self, frames):
        ftype, _flags, payload = self._next(frames, wire.FT_HELLO)
        hello = wire.decode_json(payload)
        ftype, _flags, payload = self._next(frames, wire.FT_INIT)
        arrays = wire.decode_npz(payload)
        embed = wire.unflatten_tree(
            {k[2:]: v for k, v in arrays.items() if k.startswith("e/")})
        self.dparams0 = wire.unflatten_tree(
            {k[2:]: v for k, v in arrays.items() if k.startswith("p/")})
        self.service = self.service_factory(hello, embed, self.dparams0,
                                            self)
        self._send(wire.FT_HELLO, wire.json_payload({"ok": True}))
        if hello.get("async"):
            self.service.start()

    @staticmethod
    def _next(frames, expect: int):
        for frame in frames:
            if frame[0] != expect:
                raise wire.WireError(
                    f"handshake expected {wire.FRAME_NAMES[expect]}, got "
                    f"{wire.FRAME_NAMES.get(frame[0], frame[0])}")
            return frame
        raise wire.WireError(
            f"connection closed before {wire.FRAME_NAMES[expect]}")


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        description="TIDE out-of-process draft trainer")
    parser.add_argument("--listen", required=True,
                        help="unix:/path or tcp:host:port to listen on")
    args = parser.parse_args(argv)
    srv = wire.listen(args.listen)
    try:
        conn, _addr = srv.accept()
        try:
            TrainerHost(conn).run()
        finally:
            conn.close()
    finally:
        srv.close()
        kind, addr = wire.parse_endpoint(args.listen)
        if kind == "unix":
            try:
                os.unlink(addr)
            except OSError:
                pass
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
