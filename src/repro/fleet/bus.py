"""Draft-version bus: fan one trainer's published drafts out to N
data-parallel serving replicas.

The bus keeps only the *newest* ``DraftVersion`` (deploys are
cumulative — a replica that missed seq 2 and picks up seq 3 is exactly
as current as one that saw both), and every subscriber is itself a
valid engine ``deploy_source``: calling it is a lock-free attribute
read returning the newest version, and ``ServingEngine._poll_deploy``
already ignores versions at-or-below its own deploy seq.  So fan-out
adds nothing to the serving path — each replica still pays one Python
attribute read per superstep, same as the single-engine deploy slot.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

from repro.training.service import DraftVersion


class _Subscriber:
    """One replica's view of the bus.  Callable, so it plugs straight
    into ``ServingEngine(deploy_source=...)``."""

    def __init__(self, bus: "DraftVersionBus", name: str):
        self._bus = bus
        self.name = name
        self.delivered_seq = 0   # newest seq this replica has *seen*
        self.deliveries = 0      # times a poll returned a new version

    def __call__(self) -> Optional[DraftVersion]:
        ver = self._bus.pull()
        if ver is not None and ver.seq > self.delivered_seq:
            self.delivered_seq = ver.seq
            self.deliveries += 1
        return ver

    poll = __call__


class DraftVersionBus:
    """Newest-wins fan-out of ``DraftVersion``s to named subscribers.

    ``source`` is an optional upstream poll (e.g.
    ``TrainingService.poll`` or a ``RemoteDeploySource``) checked on
    every subscriber pull, so the bus needs no thread of its own — the
    replicas' own per-superstep polls drive it.  ``publish`` pushes a
    version directly (the remote receiver thread uses this)."""

    def __init__(self, source: Optional[Callable[[], Optional[DraftVersion]]]
                 = None):
        self._source = source
        self._latest: Optional[DraftVersion] = None   # lock-free slot
        self.published = 0
        self.subscribers: Dict[str, _Subscriber] = {}

    def publish(self, ver: DraftVersion):
        cur = self._latest
        if cur is None or ver.seq > cur.seq:
            self._latest = ver
            self.published += 1

    def pull(self) -> Optional[DraftVersion]:
        if self._source is not None:
            ver = self._source()
            if ver is not None:
                self.publish(ver)
        return self._latest

    def subscribe(self, name: str) -> _Subscriber:
        if name in self.subscribers:
            return self.subscribers[name]
        sub = _Subscriber(self, name)
        self.subscribers[name] = sub
        return sub

    def stats(self) -> Dict:
        return {"published": self.published,
                "latest_seq": self._latest.seq if self._latest else 0,
                "subscribers": {n: {"delivered_seq": s.delivered_seq,
                                    "deliveries": s.deliveries}
                                for n, s in self.subscribers.items()}}
