"""Length-prefixed, versioned wire codec for the disaggregated trainer.

Frame layout (network byte order, 16-byte header)::

    !4s  magic     b"TIDE"
    B    version   WIRE_VERSION (1)
    B    ftype     frame type (FT_*)
    H    flags     reserved (must be 0)
    I    length    payload byte count (<= MAX_PAYLOAD)
    I    crc32     zlib.crc32 of the payload

Payloads are either JSON control dicts or .npz tensor containers.  The
tensor container is *exactly* the ``core.signals`` shard schema
(``pack_batches`` — per-batch keys, ``__schema__`` tag), so a spilled
.npz shard and a SIGNALS frame payload are interchangeable: the trainer
can replay offline shards over the wire and a captured frame can be
written down as a shard.  Draft payloads flatten the param pytree with
the checkpoint module's "/"-joined keys.

Decoding is strict and transactional: bad magic, unknown version,
nonzero flags, oversize length, or CRC mismatch raise ``WireError``
*without consuming partial frames* — a ``FrameReader`` either yields a
complete valid frame or leaves the stream untouched after the error, so
one corrupt frame can't smear into the next.
"""
from __future__ import annotations

import dataclasses
import io
import json
import socket
import struct
import zipfile
import zlib
from typing import Any, Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.signals import SignalBatch, pack_batches, unpack_batches
from repro.models.config import BlockDef, ModelConfig

MAGIC = b"TIDE"
WIRE_VERSION = 1
HEADER = struct.Struct("!4sBBHII")   # magic, version, ftype, flags, len, crc
MAX_PAYLOAD = 256 * 1024 * 1024      # 256 MiB — far beyond any draft/shard

# Frame types.
FT_HELLO = 1        # json: handshake (configs + train kwargs), serving→trainer
FT_INIT = 2         # npz: frozen embed + initial draft params
FT_SIGNALS = 3      # npz: signal batches (+ __baseline__), serving→trainer
FT_DRAFT = 4        # npz: published DraftVersion, trainer→serving
FT_DRAIN = 5        # json: run-all-cycles barrier request {token}
FT_DRAIN_ACK = 6    # json: {token, cycles, version} after DRAIN completes
FT_EVENT = 7        # json: one train_cycle event dict, trainer→serving
FT_RESET = 8        # json: reset trainer-side adaptation state {token}
FT_RESET_ACK = 9    # json: {token}
FT_BYE = 10         # empty: orderly shutdown

FRAME_NAMES = {
    FT_HELLO: "HELLO", FT_INIT: "INIT", FT_SIGNALS: "SIGNALS",
    FT_DRAFT: "DRAFT", FT_DRAIN: "DRAIN", FT_DRAIN_ACK: "DRAIN_ACK",
    FT_EVENT: "EVENT", FT_RESET: "RESET", FT_RESET_ACK: "RESET_ACK",
    FT_BYE: "BYE",
}


class WireError(Exception):
    """Malformed frame (bad magic/version/flags/length/CRC) or protocol
    violation.  The stream is not advanced past the offending header."""


# ---------------------------------------------------------------- framing
def encode_frame(ftype: int, payload: bytes = b"", flags: int = 0) -> bytes:
    if ftype not in FRAME_NAMES:
        raise WireError(f"unknown frame type {ftype}")
    if len(payload) > MAX_PAYLOAD:
        raise WireError(f"payload {len(payload)} bytes exceeds "
                        f"MAX_PAYLOAD {MAX_PAYLOAD}")
    return HEADER.pack(MAGIC, WIRE_VERSION, ftype, flags, len(payload),
                       zlib.crc32(payload) & 0xFFFFFFFF) + payload


class FrameReader:
    """Incremental frame decoder over an arbitrary chunking of bytes.

    ``feed(data)`` buffers and yields every complete ``(ftype, flags,
    payload)`` frame.  Validation is all-or-nothing: an invalid header
    or CRC raises ``WireError`` and poisons the reader (no partial frame
    is ever yielded, and nothing after the corruption is trusted)."""

    def __init__(self):
        self._buf = bytearray()
        self._dead: Optional[str] = None

    def feed(self, data: bytes) -> Iterator[Tuple[int, int, bytes]]:
        if self._dead is not None:
            raise WireError(f"reader poisoned by earlier error: "
                            f"{self._dead}")
        self._buf.extend(data)
        while len(self._buf) >= HEADER.size:
            magic, version, ftype, flags, length, crc = HEADER.unpack_from(
                self._buf)
            try:
                if magic != MAGIC:
                    raise WireError(f"bad magic {bytes(magic)!r}")
                if version != WIRE_VERSION:
                    raise WireError(f"unsupported wire version {version} "
                                    f"(speak {WIRE_VERSION})")
                if ftype not in FRAME_NAMES:
                    raise WireError(f"unknown frame type {ftype}")
                if flags != 0:
                    raise WireError(f"nonzero reserved flags {flags:#x}")
                if length > MAX_PAYLOAD:
                    raise WireError(f"payload length {length} exceeds "
                                    f"MAX_PAYLOAD {MAX_PAYLOAD}")
            except WireError as exc:
                self._dead = str(exc)
                raise
            if len(self._buf) < HEADER.size + length:
                return   # incomplete — wait for more bytes, consume nothing
            payload = bytes(self._buf[HEADER.size:HEADER.size + length])
            if (zlib.crc32(payload) & 0xFFFFFFFF) != crc:
                self._dead = "payload CRC mismatch"
                raise WireError(self._dead)
            del self._buf[:HEADER.size + length]
            yield ftype, flags, payload

    @property
    def pending_bytes(self) -> int:
        return len(self._buf)


def send_frame(sock: socket.socket, ftype: int, payload: bytes = b""):
    sock.sendall(encode_frame(ftype, payload))


def recv_frames(sock: socket.socket, reader: FrameReader,
                bufsize: int = 1 << 16) -> Iterator[Tuple[int, int, bytes]]:
    """Generator over frames on a blocking socket; returns on EOF."""
    while True:
        data = sock.recv(bufsize)
        if not data:
            return
        yield from reader.feed(data)


# --------------------------------------------------------------- payloads
def json_payload(obj: Dict) -> bytes:
    return json.dumps(obj, sort_keys=True).encode("utf-8")


def decode_json(payload: bytes) -> Dict:
    try:
        obj = json.loads(payload.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as exc:
        raise WireError(f"bad json payload: {exc}") from exc
    if not isinstance(obj, dict):
        raise WireError("json payload must be an object")
    return obj


def npz_payload(arrays: Dict[str, np.ndarray]) -> bytes:
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    return buf.getvalue()


def decode_npz(payload: bytes) -> Dict[str, np.ndarray]:
    try:
        with np.load(io.BytesIO(payload), allow_pickle=False) as data:
            return {k: np.asarray(data[k]) for k in data.files}
    except (ValueError, OSError, zlib.error, zipfile.BadZipFile) as exc:
        raise WireError(f"bad npz payload: {exc}") from exc


# ------------------------------------------------------- signals payloads
def signals_payload(batches: List[SignalBatch],
                    baseline: float = 0.0) -> bytes:
    """SIGNALS frame body: the shard schema + the serving side's current
    deploy baseline (best-effort fresh — the trainer-side gate compares
    eval accuracy against it, standing in for the in-process
    controller's ``alpha_train``)."""
    arrays = pack_batches(batches)
    arrays["__baseline__"] = np.asarray(float(baseline), np.float64)
    return npz_payload(arrays)


def decode_signals(payload: bytes) -> Tuple[List[SignalBatch], float]:
    arrays = decode_npz(payload)
    baseline = float(arrays.pop("__baseline__", 0.0))
    try:
        return unpack_batches(arrays), baseline
    except ValueError as exc:
        raise WireError(str(exc)) from exc


# --------------------------------------------------------- draft payloads
def flatten_tree(tree, prefix: str = "") -> Dict[str, np.ndarray]:
    """Flatten a nested-dict param pytree into "/"-joined keys (the
    checkpoint module's layout; draft params are nested dicts only)."""
    out: Dict[str, np.ndarray] = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(flatten_tree(v, f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def unflatten_tree(flat: Dict[str, np.ndarray]):
    tree: Dict[str, Any] = {}
    for key, arr in flat.items():
        parts = key.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    return tree


def draft_payload(seq: int, dparams, eval_acc: float) -> bytes:
    """DRAFT frame body: one published ``DraftVersion``."""
    arrays = {f"p/{k}": v for k, v in flatten_tree(dparams).items()}
    arrays["__seq__"] = np.asarray(int(seq), np.int64)
    arrays["__eval_acc__"] = np.asarray(float(eval_acc), np.float64)
    return npz_payload(arrays)


def decode_draft(payload: bytes) -> Tuple[int, Any, float]:
    arrays = decode_npz(payload)
    try:
        seq = int(arrays.pop("__seq__"))
        eval_acc = float(arrays.pop("__eval_acc__"))
    except KeyError as exc:
        raise WireError(f"draft payload missing {exc}") from exc
    flat = {k[2:]: v for k, v in arrays.items() if k.startswith("p/")}
    if not flat:
        raise WireError("draft payload has no parameters")
    return seq, unflatten_tree(flat), eval_acc


# ---------------------------------------------------------- config codec
def config_to_dict(cfg: ModelConfig) -> Dict:
    """JSON-safe dict for a ``ModelConfig`` (BlockDef tuples become
    lists of dicts)."""
    d = dataclasses.asdict(cfg)
    for f in ("pattern", "prologue"):
        d[f] = [dataclasses.asdict(b) if not isinstance(b, dict) else b
                for b in d[f]]
    d["capture_layers"] = list(d["capture_layers"])
    return d


def config_from_dict(d: Dict) -> ModelConfig:
    d = dict(d)
    for f in ("pattern", "prologue"):
        d[f] = tuple(BlockDef(**b) for b in d.get(f, ()))
    d["capture_layers"] = tuple(d.get("capture_layers", (-1, -1, -1)))
    return ModelConfig(**d)


# ------------------------------------------------------------- endpoints
def parse_endpoint(endpoint: str) -> Tuple[str, Any]:
    """``unix:/path`` → ("unix", path); ``tcp:host:port`` →
    ("tcp", (host, port))."""
    if endpoint.startswith("unix:"):
        path = endpoint[len("unix:"):]
        if not path:
            raise ValueError("empty unix socket path")
        return "unix", path
    if endpoint.startswith("tcp:"):
        rest = endpoint[len("tcp:"):]
        host, sep, port = rest.rpartition(":")
        if not sep or not host:
            raise ValueError(f"tcp endpoint {endpoint!r} needs host:port")
        return "tcp", (host, int(port))
    raise ValueError(f"unknown endpoint scheme {endpoint!r} "
                     "(expected unix:/path or tcp:host:port)")


def connect(endpoint: str, timeout: Optional[float] = None) -> socket.socket:
    kind, addr = parse_endpoint(endpoint)
    fam = socket.AF_UNIX if kind == "unix" else socket.AF_INET
    sock = socket.socket(fam, socket.SOCK_STREAM)
    if timeout is not None:
        sock.settimeout(timeout)
    sock.connect(addr)
    sock.settimeout(None)
    return sock


def listen(endpoint: str, backlog: int = 1) -> socket.socket:
    kind, addr = parse_endpoint(endpoint)
    fam = socket.AF_UNIX if kind == "unix" else socket.AF_INET
    sock = socket.socket(fam, socket.SOCK_STREAM)
    if kind == "tcp":
        sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    sock.bind(addr)
    sock.listen(backlog)
    return sock
