"""Front-end router + data-parallel serving fleet.

One trainer amortized across N ``ServingEngine`` replicas — the
production shape of the paper's disaggregation story.  The
``FleetRouter`` load-balances an arrival trace across replicas
deterministically (cost-estimate least-loaded by default, so the
round-domain benchmarks reproduce exactly); ``ServingFleet`` wires the
shared trainer stack (in-process ``TrainingService`` or out-of-process
``RemoteTrainingService``), a ``DraftVersionBus`` fanning every
published draft out to all replicas, and N engines that share one set
of compiled step functions (``ServingEngine.adopt_compiled`` — XLA
traces once per fleet, not once per replica).

Per-replica determinism: greedy token streams are draft- and
scheduling-invariant (the target verifies every draft token), so a
request's stream is byte-identical whether it lands on replica 0 of 1
or replica 3 of 8 — the property the drain-parity gates in
``benchmarks/bench_fleet.py`` pin.

On a single host the replicas serve *serially* (one XLA client, shared
cores — concurrent engines would just timeslice), so fleet wall-clock
is modeled, not measured: per-replica wall and executed rounds are
tracked separately and ``summary()`` reports the aggregate over
``max``-of-replicas, the bound a true data-parallel deployment sees.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax

from repro.checkpoint.ckpt import DraftDeployGate
from repro.core import eagle
from repro.core.adaptive import AdaptiveDrafter, LatencyProfile
from repro.core.controller import TrainingController
from repro.core.signals import SignalExtractor
from repro.core.transport import SignalChannel
from repro.fleet import FleetConfig
from repro.fleet.bus import DraftVersionBus
from repro.serving.engine import ServingEngine
from repro.serving.request import Request
from repro.training.draft_trainer import DraftTrainer
from repro.training.service import TrainingService


def request_cost(req: Request) -> float:
    """Deterministic per-request work estimate for load balancing:
    decode rounds scale with the token budget, prefill with prompt
    width (8 = the refill shape bucket)."""
    return len(req.prompt) / 8.0 + float(req.max_new_tokens)


class FleetRouter:
    """Deterministic request→replica assignment.

    ``least``: cost-estimate least-loaded (ties to the lowest replica
    index), the default — balances mixed prompt/budget traces so no
    replica becomes the fleet's critical path.  ``rr``: round-robin,
    the oblivious baseline."""

    def __init__(self, n: int, policy: str = "least"):
        if n < 1:
            raise ValueError(f"router needs >= 1 replica, got {n}")
        if policy not in ("least", "rr"):
            raise ValueError(f"unknown route policy {policy!r}")
        self.n = n
        self.policy = policy
        self.load = [0.0] * n
        self.assigned = [0] * n
        self._rr = 0

    def assign(self, req: Request) -> int:
        if self.policy == "rr":
            idx = self._rr % self.n
            self._rr += 1
        else:
            idx = min(range(self.n), key=lambda i: (self.load[i], i))
        self.load[idx] += request_cost(req)
        self.assigned[idx] += 1
        return idx

    def split(self, requests: Sequence[Request]) -> List[List[Request]]:
        """Shard a trace, preserving arrival order within each shard."""
        shards: List[List[Request]] = [[] for _ in range(self.n)]
        for req in requests:
            shards[self.assign(req)].append(req)
        return shards


class ServingFleet:
    """N data-parallel serving replicas fed by one shared trainer.

    Mirrors ``TideSystem``'s wiring (channel → service → deploy
    pickup) with two substitutions: published drafts fan out through a
    ``DraftVersionBus`` (each replica subscribes; its subscription IS
    its ``deploy_source``), and when
    ``TideConfig.fleet.trainer_endpoint`` is set the trainer stack is a
    ``RemoteTrainingService`` in another process.  Signals from every
    replica funnel into the one shared channel — N replicas' traffic
    amortizes one trainer, the point of the topology."""

    def __init__(self, cfg, params, tide_cfg,
                 profile: Optional[LatencyProfile] = None, dparams=None):
        fleet = tide_cfg.fleet if tide_cfg.fleet is not None \
            else FleetConfig(replicas=1)
        self.fleet_cfg = fleet
        self.n = max(fleet.replicas, 1)
        self.cfg = cfg
        self.tcfg = tide_cfg
        self.dcfg = eagle.draft_config(cfg)
        if dparams is None:
            dparams = eagle.draft_init(self.dcfg,
                                       jax.random.key(tide_cfg.seed + 7))
        self._dparams0 = dparams
        self.async_train = tide_cfg.async_train
        n_threshold = tide_cfg.n_threshold * tide_cfg.signal_window
        self.controller = TrainingController(n_threshold=n_threshold,
                                             n_init=4)
        self.controller.collection_enabled = True

        if fleet.trainer_endpoint is not None:
            from repro.fleet.remote import RemoteTrainingService
            self.service = RemoteTrainingService(
                fleet.trainer_endpoint, tcfg=cfg, dcfg=self.dcfg,
                embed_params=params["embed"], dparams0=dparams,
                n_threshold=n_threshold,
                signal_window=tide_cfg.signal_window,
                train_epochs=tide_cfg.train_epochs,
                train_min_steps=tide_cfg.train_min_steps,
                seed=tide_cfg.seed, async_train=tide_cfg.async_train,
                channel_capacity=max(tide_cfg.channel_capacity,
                                     tide_cfg.n_threshold),
                controller=self.controller,
                selective=tide_cfg.selective_training,
                engine_steps_fn=self._total_steps)
            self.channel = self.service.channel
            self.gate = self.service.gate
            self.trainer = None
        else:
            self.channel = SignalChannel(
                capacity=max(tide_cfg.channel_capacity,
                             tide_cfg.n_threshold))
            self.trainer = DraftTrainer(cfg, self.dcfg, params["embed"])
            self.gate = DraftDeployGate(dparams)
            self.service = TrainingService(
                self.trainer, self.gate, self.channel,
                controller=self.controller,
                selective=tide_cfg.selective_training,
                n_threshold=n_threshold,
                signal_window=tide_cfg.signal_window,
                train_epochs=tide_cfg.train_epochs,
                train_min_steps=tide_cfg.train_min_steps,
                seed=tide_cfg.seed)
        self.bus = DraftVersionBus(source=self.service.poll)
        self.router = FleetRouter(self.n, fleet.route)
        self.events = self.service.events

        scfg = dataclasses.replace(
            tide_cfg.serving,
            reseed_window=(tide_cfg.reseed_window if tide_cfg.async_train
                           else 0))
        self.extractors: List[SignalExtractor] = []
        self.engines: List[ServingEngine] = []
        self.subs = []
        for i in range(self.n):
            extractor = SignalExtractor(self.channel,
                                        window=tide_cfg.signal_window)
            sub = self.bus.subscribe(f"replica{i}")
            drafter = (AdaptiveDrafter(profile, gamma=tide_cfg.gamma)
                       if tide_cfg.adaptive_spec and profile is not None
                       else None)
            engine = ServingEngine(
                cfg, params, self.dcfg, dparams, config=scfg,
                policy=scfg.make_policy(drafter),
                controller=(self.controller
                            if tide_cfg.selective_training else None),
                extractor=extractor,
                deploy_source=(sub if tide_cfg.async_train else None))
            if i > 0:
                engine.adopt_compiled(self.engines[0])
            self.extractors.append(extractor)
            self.engines.append(engine)
            self.subs.append(sub)
        if tide_cfg.async_train:
            self.service.start()

    def _total_steps(self) -> int:
        return sum(e.stats.steps for e in getattr(self, "engines", []))

    # ------------------------------------------------------------ serving
    def serve(self, requests: Sequence[Request]) -> List[Request]:
        """Route the trace across replicas and serve every shard.

        Single-host execution is serial (see module docstring) — each
        replica runs its shard to completion with the standard stream
        loop; in sync-training mode every replica drains the shared
        trainer at its request-completion boundaries and each engine
        picks published drafts up from its bus subscription (the same
        pickup protocol as ``TideSystem._drain_train``)."""
        shards = self.router.split(list(requests))
        done: List[Request] = []
        for engine, sub, shard in zip(self.engines, self.subs, shards):
            if not shard:
                continue
            engine._poll_deploy(sub)   # deploys won while others served
            on_complete = None
            if not self.async_train:
                def on_complete(_req=None, engine=engine, sub=sub):
                    self.service.drain()
                    engine._poll_deploy(sub)
            done.extend(engine.serve_stream(shard,
                                            on_complete=on_complete))
        return done

    # ---------------------------------------------------------- lifecycle
    def close(self):
        self.service.close()

    def reset_adaptation(self):
        """Fleet-wide adaptation reset (cf. ``TideSystem
        .reset_adaptation``): every replica, the shared channel /
        controller / gate / service, and the bus, under the service's
        train lock."""
        with self.service._train_lock:
            self.channel.reset()
            self.controller.reset()
            self.controller.collection_enabled = True
            self.gate.reset(self._dparams0)
            self.service.reset()
            self.bus._latest = None
            self.bus.published = 0
            for extractor in self.extractors:
                extractor.reset()
            for sub in self.subs:
                sub.delivered_seq = 0
                sub.deliveries = 0
            for engine in self.engines:
                engine.reset_adaptation(self._dparams0)
        self.router = FleetRouter(self.n, self.fleet_cfg.route)

    # ------------------------------------------------------------- stats
    def summary(self) -> Dict:
        """Aggregate fleet summary.  Wall-clock is modeled for the
        serial single-host run: ``agg_tokens_per_s`` divides total
        tokens by the *slowest replica's* wall (what a true
        data-parallel deployment is bounded by); ``round_speedup`` vs a
        single replica is the deterministic round-domain version of the
        same quantity (rounds are scheduling-exact, wall is not)."""
        tokens = sum(e.stats.tokens_out for e in self.engines)
        walls = [e.stats.wall_s for e in self.engines]
        rounds = [e.stats.steps for e in self.engines]
        service_stats = self.service.stats()
        return {
            "replicas": self.n,
            "tokens": tokens,
            "replica_tokens": [e.stats.tokens_out for e in self.engines],
            "replica_rounds": rounds,
            "max_rounds": max(rounds) if rounds else 0,
            "replica_wall_s": walls,
            "max_wall_s": max(walls) if walls else 0.0,
            "agg_tokens_per_s": tokens / max(max(walls, default=0.0),
                                             1e-9),
            "deploys": sum(e.stats.deploys for e in self.engines),
            "bus": self.bus.stats(),
            "router_load": list(self.router.load),
            "router_assigned": list(self.router.assigned),
            "train_cycles": self.service.cycles,
            "deployed": self.gate.version,
            "trainer_failures": service_stats.get("failures", 0),
            "signals_collected": self.channel.total_added,
            "signals_dropped": self.channel.dropped,
        }
