"""Disaggregated serving: out-of-process trainer + engine replica fleet.

The paper's heterogeneous-cluster story maps the decoupled serving and
training engines onto *different* machines: one continuously-updating
draft trainer amortized across N data-parallel serving replicas.  This
package is that production shape:

- ``wire``         length-prefixed, versioned frame codec carrying
                   ``SignalBatch`` tensors and ``DraftVersion`` payloads
                   (one schema with ``SignalStore.spill``'s .npz shards);
- ``remote``       ``RemoteSignalChannel`` / ``RemoteTrainingService`` —
                   the serving-side endpoints keeping the engine's
                   ``SignalChannel`` and ``deploy_source`` interfaces
                   (zero serving-path syncs, drop-oldest backpressure
                   over the socket);
- ``trainer_main`` the out-of-process trainer entrypoint
                   (``python -m repro.fleet.trainer_main``) running
                   ``TrainingService`` on its own XLA client;
- ``bus``          draft-version fan-out to N replica subscribers;
- ``router``       front-end request router + ``ServingFleet`` running
                   N data-parallel ``ServingEngine`` replicas off one
                   trainer.

``FleetConfig`` lives here (and only here) so ``core.tide`` can accept
``TideConfig(fleet=...)`` without importing any socket/subprocess
machinery until a fleet is actually requested.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass
class FleetConfig:
    """Disaggregation knobs (CLI: ``--fleet-replicas``,
    ``--trainer-endpoint``, ``--fleet-route``).

    ``replicas=0`` (default) means no fleet — single engine, in-process
    trainer; ``trainer_endpoint`` alone moves training out of process
    for a single engine.  ``trainer_endpoint`` accepts
    ``spawn`` (fork a trainer subprocess on a private unix socket),
    ``unix:/path`` or ``tcp:host:port`` (connect to a running
    ``repro.fleet.trainer_main``)."""
    replicas: int = 0
    trainer_endpoint: Optional[str] = None
    route: str = "least"     # "least" (least-loaded) | "rr" (round-robin)

    def __post_init__(self):
        if self.replicas < 0:
            raise ValueError(f"fleet replicas must be >= 0, "
                             f"got {self.replicas}")
        if self.route not in ("least", "rr"):
            raise ValueError(f"unknown fleet route {self.route!r} "
                             "(expected 'least' or 'rr')")

    @property
    def enabled(self) -> bool:
        return self.replicas > 0 or self.trainer_endpoint is not None
