"""Serving-side endpoints of the out-of-process trainer.

``RemoteTrainingService`` is a drop-in for the in-process
``training.service.TrainingService`` from the serving engine's point of
view — same ``poll``/``drain``/``reset``/``close``/``stats`` surface,
same ``events``/``cycles`` telemetry, same ``_train_lock`` reset
protocol — but every training cycle runs in another process
(``repro.fleet.trainer_main``) on its own XLA client, connected by the
``fleet.wire`` frame protocol.

Serving-path contract (the whole point of disaggregation):

- **signals out** go through ``RemoteSignalChannel`` — the same bounded
  drop-oldest ring as in-process (``SignalChannel`` subclass whose
  ``_prepare`` skips device placement), drained onto the socket by a
  sender thread (async mode) or by ``drain()`` (sync parity mode).
  ``add()`` is an append under a host lock: zero syncs, never blocks on
  the wire, backpressure drops oldest exactly as in-process.
- **drafts in** arrive as DRAFT frames on a receiver thread, which
  ``device_put``s the params off-path and publishes into a
  ``RemoteDeploySource`` — a lock-free newest-wins slot the engine
  polls once per superstep, identical to the in-process deploy slot.

Determinism: in sync parity mode ``drain()`` flushes buffered signals
and a DRAIN barrier over the socket *in one critical section*, and the
trainer emits every DRAFT/EVENT for the barrier's cycles **before** the
DRAIN_ACK on the same ordered stream — so when ``drain()`` returns, the
deploy slot holds exactly what the in-process schedule would have
published, and the serving streams are byte-identical.

Failure model: trainer death (EOF, ECONNRESET, corrupt frame) marks the
service dead, counts a failure, and wakes every waiter — serving
degrades to the last published draft and never hangs; ``close()`` is
idempotent and never raises.
"""
from __future__ import annotations

import os
import shutil
import subprocess
import sys
import tempfile
import threading
import time
from typing import Callable, Dict, List, Optional

from repro.core.signals import SignalBatch
from repro.core.transport import SignalChannel
from repro.fleet import wire
from repro.training.service import DraftVersion


class RemoteSignalChannel(SignalChannel):
    """The in-process drop-oldest signal ring, reused as the socket
    send queue.  Producers (the signal extractor) are unchanged;
    ``_prepare`` keeps batches as host arrays for the sender instead of
    ``device_put``-ing onto a trainer device that lives in another
    process."""

    def __init__(self, capacity: int = 512,
                 spill_dir: Optional[str] = None):
        super().__init__(capacity=capacity, device=None,
                         spill_dir=spill_dir)

    def _prepare(self, batch: SignalBatch) -> SignalBatch:
        return batch    # host arrays; the wire is the placement


class RemoteDeploySource:
    """Lock-free newest-wins slot for drafts received off the wire.
    Callable, so it is a valid engine ``deploy_source`` and a valid
    ``DraftVersionBus`` source."""

    def __init__(self):
        self._latest: Optional[DraftVersion] = None

    def publish(self, ver: DraftVersion):
        cur = self._latest
        if cur is None or ver.seq > cur.seq:
            self._latest = ver

    def poll(self) -> Optional[DraftVersion]:
        return self._latest

    __call__ = poll

    def reset(self):
        self._latest = None


class _GateView:
    """Serving-side mirror of the trainer-process deploy gate: tracks
    the highest published version so ``summary()['deployed']`` and the
    reset protocol keep working without the gate's params."""

    def __init__(self):
        self.version = 0

    def observe(self, seq: int):
        if seq > self.version:
            self.version = seq

    def reset(self, dparams0=None):
        self.version = 0


class RemoteTrainingService:
    """Out-of-process ``TrainingService`` over the fleet wire protocol.

    ``endpoint``: ``"spawn"`` forks a private trainer subprocess on a
    tmp unix socket; ``unix:/path`` / ``tcp:host:port`` connect to a
    running ``python -m repro.fleet.trainer_main``."""

    def __init__(self, endpoint: str, *, tcfg, dcfg, embed_params,
                 dparams0,
                 n_threshold: int = 2048, signal_window: int = 24,
                 train_epochs: int = 2, train_min_steps: int = 80,
                 seed: int = 0, async_train: bool = False,
                 channel_capacity: int = 512,
                 controller=None, selective: bool = False,
                 engine_steps_fn: Optional[Callable[[], int]] = None,
                 poll_s: float = 0.01,
                 connect_timeout: float = 180.0,
                 drain_timeout: float = 600.0,
                 tracer=None, registry=None):
        self.endpoint = endpoint
        self.async_train = async_train
        self.controller = controller
        self.selective = selective
        self.engine_steps_fn = engine_steps_fn or (lambda: -1)
        self.poll_s = poll_s
        self.drain_timeout = drain_timeout
        from repro.obs.trace import NULL_TRACER
        self.tracer = tracer if tracer is not None else NULL_TRACER

        self.channel = RemoteSignalChannel(
            capacity=max(channel_capacity,
                         -(-n_threshold // max(signal_window, 1))))
        self.deploy_source = RemoteDeploySource()
        self.gate = _GateView()
        self.events: List[Dict] = []
        self.cycles = 0
        self.deploys = 0
        self.failures = 0
        self.last_error: Optional[str] = None
        self._trainer_failures = 0   # high-water mark off DRAIN_ACKs
        self.frames_sent = 0
        self.bytes_sent = 0
        self.frames_recv = 0
        self.bytes_recv = 0

        self._train_lock = threading.RLock()
        self._send_lock = threading.Lock()
        self._stop = threading.Event()
        self._closed = threading.Event()
        self._closing = False
        self._dead = False
        self._ready = threading.Event()
        self._acks: Dict[int, Dict] = {}
        self._ack_cond = threading.Condition()
        self._token = 0
        self._sender: Optional[threading.Thread] = None
        self._proc: Optional[subprocess.Popen] = None
        self._tmpdir: Optional[str] = None

        if endpoint == "spawn":
            endpoint = self._spawn()
        self._sock = self._connect_retry(endpoint, connect_timeout)
        hello = {
            "tcfg": wire.config_to_dict(tcfg),
            "dcfg": wire.config_to_dict(dcfg),
            "train": {"n_threshold": int(n_threshold),
                      "signal_window": int(signal_window),
                      "train_epochs": int(train_epochs),
                      "train_min_steps": int(train_min_steps),
                      "seed": int(seed)},
            "async": bool(async_train),
        }
        self._send(wire.FT_HELLO, wire.json_payload(hello))
        init = {f"e/{k}": v
                for k, v in wire.flatten_tree(embed_params).items()}
        init.update({f"p/{k}": v
                     for k, v in wire.flatten_tree(dparams0).items()})
        self._send(wire.FT_INIT, wire.npz_payload(init))
        self._receiver = threading.Thread(target=self._recv_loop,
                                          name="tide-fleet-recv",
                                          daemon=True)
        self._receiver.start()
        if not self._ready.wait(connect_timeout):
            err = self.last_error or "no HELLO ack"
            self.close()
            raise RuntimeError(
                f"trainer at {endpoint} not ready within "
                f"{connect_timeout}s ({err})")
        if registry is not None:
            self.register_metrics(registry)

    # ---------------------------------------------------------- transport
    def _spawn(self) -> str:
        self._tmpdir = tempfile.mkdtemp(prefix="tide-fleet-")
        endpoint = f"unix:{os.path.join(self._tmpdir, 'trainer.sock')}"
        env = dict(os.environ)
        env.setdefault("JAX_PLATFORMS", "cpu")
        import repro
        # namespace package: no __file__, locate via __path__
        src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
        env["PYTHONPATH"] = src + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        self._proc = subprocess.Popen(
            [sys.executable, "-m", "repro.fleet.trainer_main",
             "--listen", endpoint],
            env=env, stdin=subprocess.DEVNULL)
        return endpoint

    def _connect_retry(self, endpoint: str, timeout: float):
        deadline = time.monotonic() + timeout
        while True:
            if self._proc is not None and self._proc.poll() is not None:
                raise RuntimeError(
                    f"trainer subprocess exited with code "
                    f"{self._proc.returncode} before accepting")
            try:
                return wire.connect(endpoint, timeout=1.0)
            except OSError as exc:
                if time.monotonic() >= deadline:
                    raise RuntimeError(
                        f"could not reach trainer at {endpoint} within "
                        f"{timeout}s: {exc}") from exc
                time.sleep(0.05)

    def _send(self, ftype: int, payload: bytes = b""):
        frame = wire.encode_frame(ftype, payload)
        with self._send_lock:
            self._sock.sendall(frame)
            self.frames_sent += 1
            self.bytes_sent += len(frame)

    def _baseline(self) -> float:
        return (self.controller.alpha_train
                if self.controller is not None else 0.0)

    def _mark_dead(self, exc):
        if self._dead or self._closing:
            self._dead = True
        else:
            self._dead = True
            self.failures += 1
            self.last_error = f"{type(exc).__name__}: {exc}"
        self._ready.set()
        with self._ack_cond:
            self._ack_cond.notify_all()

    # ----------------------------------------------------------- receiver
    def _recv_loop(self):
        reader = wire.FrameReader()
        try:
            for ftype, _flags, payload in wire.recv_frames(self._sock,
                                                           reader):
                self.frames_recv += 1
                self.bytes_recv += wire.HEADER.size + len(payload)
                self._handle(ftype, payload)
        except (wire.WireError, OSError, ValueError) as exc:
            self._mark_dead(exc)
            return
        self._mark_dead(RuntimeError("trainer connection closed"))

    def _handle(self, ftype: int, payload: bytes):
        if ftype == wire.FT_HELLO:
            self._ready.set()
        elif ftype == wire.FT_DRAFT:
            seq, dparams, eval_acc = wire.decode_draft(payload)
            import jax
            dparams = jax.device_put(dparams)   # off the serving path
            self.deploy_source.publish(DraftVersion(seq, dparams, eval_acc))
            self.gate.observe(seq)
            self.deploys += 1
            if self.tracer.enabled:
                self.tracer.instant("train.publish", seq=seq,
                                    eval_acc=eval_acc)
        elif ftype == wire.FT_EVENT:
            ev = wire.decode_json(payload)
            if ev.get("kind") == "train_cycle":
                ev["engine_steps"] = self.engine_steps_fn()
                self.events.append(ev)
                self.cycles += 1
                if self.selective and self.controller is not None:
                    self.controller.training_result(ev["eval_acc"])
        elif ftype in (wire.FT_DRAIN_ACK, wire.FT_RESET_ACK):
            ack = wire.decode_json(payload)
            with self._ack_cond:
                self._acks[int(ack.get("token", -1))] = ack
                self._ack_cond.notify_all()
        # HELLO/BYE/others: nothing to do

    def _await_ack(self, token: int, timeout: float) -> Optional[Dict]:
        deadline = time.monotonic() + timeout
        with self._ack_cond:
            while token not in self._acks:
                if self._dead:
                    return None
                left = deadline - time.monotonic()
                if left <= 0:
                    self.failures += 1
                    self.last_error = (f"timed out after {timeout}s "
                                       "waiting for trainer ack")
                    return None
                self._ack_cond.wait(timeout=min(left, 1.0))
            return self._acks.pop(token)

    # ------------------------------------------------------------- sender
    def start(self):
        """Start the background signal sender (async mode).  The
        trainer-side cycle loop was armed by the handshake."""
        if self._sender is not None and self._sender.is_alive():
            return
        self._sender = threading.Thread(target=self._send_loop,
                                        name="tide-fleet-send",
                                        daemon=True)
        self._sender.start()

    def _send_loop(self):
        while not self._stop.is_set() and not self._dead:
            self.channel.wait(1, timeout=self.poll_s)
            if self._stop.is_set() or self._dead:
                break
            try:
                with self._send_lock:
                    batches = self.channel.drain()
                    if batches:
                        self._send_unlocked(
                            wire.FT_SIGNALS,
                            wire.signals_payload(batches,
                                                 self._baseline()))
            except OSError as exc:
                self._mark_dead(exc)
                break

    def _send_unlocked(self, ftype: int, payload: bytes = b""):
        frame = wire.encode_frame(ftype, payload)
        self._sock.sendall(frame)
        self.frames_sent += 1
        self.bytes_sent += len(frame)

    # ------------------------------------------------- service interface
    def poll(self) -> Optional[DraftVersion]:
        """Lock-free read of the latest received deploy (or None)."""
        return self.deploy_source.poll()

    def drain(self) -> int:
        """Deterministic parity barrier: flush buffered signals and run
        every cycle they allow in the trainer process, blocking until
        its DRAIN_ACK.  The trainer emits all DRAFT/EVENT frames for
        those cycles before the ack on the same ordered stream, so the
        deploy slot is final when this returns.  Returns cycles run;
        0 (never a hang) if the trainer is dead."""
        with self._train_lock:
            if self._dead or self._closing:
                return 0
            self._token += 1
            token = self._token
            try:
                with self._send_lock:
                    batches = self.channel.drain()
                    if batches:
                        self._send_unlocked(
                            wire.FT_SIGNALS,
                            wire.signals_payload(batches,
                                                 self._baseline()))
                    self._send_unlocked(
                        wire.FT_DRAIN, wire.json_payload({"token": token}))
            except OSError as exc:
                self._mark_dead(exc)
                return 0
            ack = self._await_ack(token, self.drain_timeout)
            if ack is None:
                return 0
            # trainer-side cycle failures ride back on the ack — mirror
            # them so summary()/stats() make the degradation visible
            # even though the trainer process caught the exception
            tf = int(ack.get("failures", 0))
            if tf > self._trainer_failures:
                self.failures += tf - self._trainer_failures
                self._trainer_failures = tf
                self.last_error = ("trainer-side cycle failure "
                                   "(see trainer process log)")
            return int(ack["cycles"])

    def reset(self):
        """Round-trip reset: clear serving-side mirrors, then reset the
        trainer process (gate back to the initial draft, channel and
        cycle history cleared).  Degrades to a local-only clear if the
        trainer is dead."""
        with self._train_lock:
            self.channel.reset()
            self.deploy_source.reset()
            self.gate.reset()
            self.events.clear()
            self.cycles = 0
            self.deploys = 0
            self.failures = 0
            self.last_error = None
            self._trainer_failures = 0
            if self._dead or self._closing:
                return
            self._token += 1
            token = self._token
            try:
                self._send(wire.FT_RESET, wire.json_payload(
                    {"token": token}))
            except OSError as exc:
                self._mark_dead(exc)
                return
            self._await_ack(token, self.drain_timeout)

    @property
    def running(self) -> bool:
        return (not self._dead and self._receiver.is_alive())

    def kill_trainer(self):
        """Hard-kill a spawned trainer subprocess (failure injection —
        the resilience bench uses this).  Serving must degrade to the
        last published draft, never hang."""
        if self._proc is not None:
            self._proc.kill()

    def close(self, timeout: float = 10.0):
        """Idempotent, never raises, never hangs: best-effort BYE,
        close the socket, join threads with a bound, reap any spawned
        subprocess."""
        if self._closed.is_set():
            return
        self._closed.set()
        self._closing = True
        self._stop.set()
        self.channel.close()
        try:
            self._send(wire.FT_BYE)
        except OSError:
            pass
        try:
            self._sock.shutdown(2)   # SHUT_RDWR — wakes the receiver
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass
        for t in (self._sender, self._receiver):
            if t is not None and t.is_alive():
                t.join(timeout=timeout)
        if self._proc is not None:
            try:
                self._proc.wait(timeout=timeout)
            except subprocess.TimeoutExpired:
                self._proc.terminate()
                try:
                    self._proc.wait(timeout=5.0)
                except subprocess.TimeoutExpired:
                    self._proc.kill()
                    self._proc.wait()
        if self._tmpdir is not None:
            shutil.rmtree(self._tmpdir, ignore_errors=True)
            self._tmpdir = None

    # --------------------------------------------------------------- stats
    def stats(self) -> Dict:
        return {"cycles": self.cycles, "deploy_version": self.gate.version,
                "running": self.running, "trainer_threads": 0,
                "thread_cap": "process",
                "failures": self.failures, "last_error": self.last_error,
                "frames_sent": self.frames_sent,
                "bytes_sent": self.bytes_sent,
                "frames_recv": self.frames_recv,
                "bytes_recv": self.bytes_recv,
                **self.channel.stats()}

    def register_metrics(self, registry):
        registry.gauge("train.cycles", fn=lambda: self.cycles)
        registry.gauge("train.deploy_version",
                       fn=lambda: self.gate.version)
        registry.gauge("train.running", fn=lambda: int(self.running))
        registry.gauge("train.trainer_failures", fn=lambda: self.failures)
        registry.gauge("train.wire_bytes_sent", fn=lambda: self.bytes_sent)
        registry.gauge("train.wire_bytes_recv", fn=lambda: self.bytes_recv)
        self.channel.register_metrics(registry)
