"""Zero-sync observability: tracing, metrics, per-request recording.

Three host-side instruments share one rule — they attach **only at
existing host telemetry boundaries** (superstep unpack, scheduler
admission, trainer publish, deploy poll) and therefore add **zero
device<->host synchronizations** to the serving path:

- :mod:`repro.obs.trace` — a ring-buffered span/event tracer exporting
  Chrome/Perfetto trace-event JSON (``chrome://tracing`` / ui.perfetto.dev).
- :mod:`repro.obs.metrics` — a namespaced Counter/Gauge/Histogram
  registry (``serving.*``, ``train.*``, ``paging.*``, ``spec.*``) with
  one ``snapshot()`` and Prometheus-style text exposition.
- :mod:`repro.obs.recorder` — a per-request flight recorder that
  reconstructs each request's lifecycle (admit -> prefill chunks ->
  first token -> commits/parks/probes -> finish) from rounds the engine
  already unpacks.

The disabled path is the default: ``NULL_TRACER`` / ``NULL_RECORDER``
singletons answer ``.enabled == False`` so hot-loop guards are a single
attribute check and the off configuration stays byte-identical to a
build without this package.
"""
import dataclasses
from typing import Optional

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry)
from repro.obs.recorder import FlightRecorder, NullRecorder, NULL_RECORDER
from repro.obs.trace import NullTracer, NULL_TRACER, Tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "FlightRecorder", "NullRecorder", "NULL_RECORDER",
    "NullTracer", "NULL_TRACER", "Tracer",
    "ObsConfig",
]


@dataclasses.dataclass
class ObsConfig:
    """Observability toggles for :class:`repro.core.tide.TideSystem`.

    This is a *system-layer* config (a ``TideConfig`` field), not a
    ``ServingConfig`` knob: it builds runtime instrument objects that
    are handed to the engine/trainer as collaborators.
    """
    trace: bool = False                 # enable the span tracer
    trace_capacity: int = 65536         # ring capacity (events)
    trace_path: Optional[str] = None    # export trace JSON here on close
    record: bool = False                # enable the flight recorder
    record_capacity: int = 1024         # finished-request timelines kept

    def build(self):
        """Return ``(tracer, recorder)`` per the toggles (null when off)."""
        on = self.trace or self.trace_path is not None
        tracer = Tracer(self.trace_capacity) if on else NULL_TRACER
        rec = FlightRecorder(self.record_capacity) if self.record \
            else NULL_RECORDER
        return tracer, rec
