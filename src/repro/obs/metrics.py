"""Namespaced metrics registry: Counter / Gauge / Histogram.

One ``MetricsRegistry`` holds every metric the system exposes, keyed
by dotted name in four namespaces — ``serving.*`` (engine counters and
latency sketches), ``train.*`` (training service + signal channel),
``paging.*`` (page allocator), ``spec.*`` (speculation policy state).
The legacy surfaces (``ServingStats`` attributes,
``TrainingService.stats()``, ``TideSystem.summary()``) remain as thin
views over the same objects, so old and new reads always agree.

Metric kinds:

- :class:`Counter` — a monotonically-growing number (int or float).
- :class:`Gauge` — a point-in-time value; either set directly or bound
  to a zero-argument callback evaluated at snapshot time (so derived
  values like occupancy or a policy's park count need no push path).
- :class:`Histogram` — a streaming distribution built on the existing
  bounded primitives: one :class:`repro.serving.stats.Peak` (max /
  mean / count) plus one :class:`repro.serving.stats.P2Quantile` per
  requested quantile.  O(1) memory, no sample retention.

``snapshot()`` returns one flat ``{name: value}`` dict (histograms
expand to ``.count/.mean/.max/.pNN`` sub-keys); ``to_json()`` and
``to_prometheus()`` render it as JSON / Prometheus text exposition.
All mutation is lock-guarded so the background training thread can
register and bump metrics concurrently with serving.
"""
from __future__ import annotations

import json
import threading
from typing import Callable, Dict, Optional, Sequence

from repro.serving.stats import P2Quantile, Peak


class Counter:
    """Monotonic counter.  ``value`` is plain attribute access so the
    serving loop can keep ``stats.tokens_out += n`` idioms."""
    kind = "counter"
    __slots__ = ("value",)

    def __init__(self, value: float = 0):
        self.value = value

    def inc(self, n: float = 1):
        self.value += n

    def __repr__(self):
        return f"Counter({self.value})"


class Gauge:
    """Point-in-time value: settable, or computed by a bound callback."""
    kind = "gauge"
    __slots__ = ("_value", "fn")

    def __init__(self, value: float = 0.0,
                 fn: Optional[Callable[[], float]] = None):
        self._value = value
        self.fn = fn

    def set(self, value: float):
        self._value = value

    @property
    def value(self) -> float:
        if self.fn is not None:
            return self.fn()
        return self._value

    def __repr__(self):
        return f"Gauge({self.value})"


class Histogram:
    """Streaming distribution over scalar observations.

    Composition of the bounded sketches from ``serving/stats.py``: a
    ``Peak`` for max/mean/count and one ``P2Quantile`` per requested
    quantile.  The ``add``/``max``/``mean``/``n`` surface matches
    ``Peak`` so existing ``ServingStats`` consumers (tests, benches)
    read a Histogram exactly like the Peak it replaces.
    """
    kind = "histogram"
    __slots__ = ("peak", "sketches")

    def __init__(self, quantiles: Sequence[float] = (0.5, 0.95)):
        self.peak = Peak()
        self.sketches: Dict[float, P2Quantile] = {
            float(q): P2Quantile(float(q)) for q in quantiles}

    def add(self, x: float):
        self.peak.add(x)
        for s in self.sketches.values():
            s.add(x)

    observe = add

    @property
    def n(self) -> int:
        return self.peak.n

    @property
    def total(self) -> float:
        return self.peak.total

    @property
    def mean(self) -> float:
        return self.peak.mean

    @property
    def max(self) -> float:
        return self.peak.max

    def quantile(self, q: float) -> float:
        return self.sketches[float(q)].value

    def __repr__(self):
        qs = ", ".join(f"p{int(q * 100)}={s.value:.4g}"
                       for q, s in sorted(self.sketches.items()))
        return f"Histogram(n={self.n}, max={self.max:.4g}, {qs})"


class MetricsRegistry:
    """Get-or-create registry of named metrics with one ``snapshot()``.

    Names are dotted (``serving.tokens_out``); the segment before the
    first dot is the namespace.  Re-registering an existing name
    returns the existing object (or rebinds a gauge callback), so
    components can idempotently declare their metrics at construction.
    """

    def __init__(self):
        self._metrics: Dict[str, object] = {}
        self._lock = threading.Lock()

    # -- declaration ---------------------------------------------------
    def counter(self, name: str) -> Counter:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = Counter()
            return m

    def gauge(self, name: str,
              fn: Optional[Callable[[], float]] = None) -> Gauge:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = Gauge(fn=fn)
            elif fn is not None:
                # rebind: a fresh ServingStats re-registers its derived
                # gauges against the same long-lived registry
                m.fn = fn
            return m

    def histogram(self, name: str,
                  quantiles: Sequence[float] = (0.5, 0.95),
                  reset: bool = False) -> Histogram:
        with self._lock:
            m = self._metrics.get(name)
            if m is None or reset:
                m = self._metrics[name] = Histogram(quantiles)
            return m

    def get(self, name: str):
        return self._metrics.get(name)

    def names(self):
        with self._lock:
            return sorted(self._metrics)

    def namespaces(self):
        return sorted({n.split(".", 1)[0] for n in self.names()})

    # -- exposition ----------------------------------------------------
    def snapshot(self) -> Dict[str, float]:
        """One flat dict of every metric's current value.  Histograms
        expand to ``name.count``, ``name.mean``, ``name.max`` and one
        ``name.pNN`` per quantile."""
        with self._lock:
            items = sorted(self._metrics.items())
        out: Dict[str, float] = {}
        for name, m in items:
            if m.kind == "histogram":
                out[f"{name}.count"] = m.n
                out[f"{name}.mean"] = m.mean
                out[f"{name}.max"] = m.max
                for q, s in sorted(m.sketches.items()):
                    out[f"{name}.p{int(round(q * 100))}"] = s.value
            else:
                out[name] = m.value
        return out

    def to_json(self, path: Optional[str] = None) -> str:
        text = json.dumps(self.snapshot(), indent=2, sort_keys=True)
        if path is not None:
            with open(path, "w") as f:
                f.write(text + "\n")
        return text

    def to_prometheus(self) -> str:
        """Prometheus text exposition (names flattened: dots -> ``_``)."""
        with self._lock:
            items = sorted(self._metrics.items())
        lines = []
        for name, m in items:
            flat = name.replace(".", "_").replace("-", "_")
            if m.kind == "histogram":
                lines.append(f"# TYPE {flat} summary")
                for q, s in sorted(m.sketches.items()):
                    lines.append(
                        f'{flat}{{quantile="{q:g}"}} {s.value:g}')
                lines.append(f"{flat}_count {m.n}")
                lines.append(f"{flat}_sum {m.total:g}")
                lines.append(f"{flat}_max {m.max:g}")
            else:
                lines.append(f"# TYPE {flat} {m.kind}")
                lines.append(f"{flat} {float(m.value):g}")
        return "\n".join(lines) + "\n"
