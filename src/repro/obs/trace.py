"""Ring-buffered span/event tracer with Chrome/Perfetto export.

The tracer records host-side *spans* (named intervals: superstep
dispatch, unpack, refill, prefill chunk, train cycle, reseed) and
*instants* (deploy pickup, admissions, park/probe/resume transitions)
into a bounded deque of tuples.  Recording is allocation-light — one
tuple append under a lock — so it is safe on the serving hot loop and
in the background ``TrainingService`` thread; timestamps come from
``time.perf_counter_ns`` (monotonic), never the device.

``export()`` converts the ring into Chrome trace-event JSON (the
format read by ``chrome://tracing`` and https://ui.perfetto.dev):
spans become ``"ph": "X"`` complete events with microsecond ``ts`` /
``dur``, instants become ``"ph": "i"``, and thread names are emitted
as ``"ph": "M"`` metadata so the serving loop and the training thread
render as separate tracks.  Spans recorded on one thread nest by
construction (begin/end are LIFO per thread).

``NULL_TRACER`` is the default collaborator: ``enabled`` is False and
``span()`` returns a shared no-op context manager, so the disabled
path costs one attribute check (or one trivially-inlined call) and
allocates nothing.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Optional


class _NullSpan:
    """Shared no-op context manager returned by ``NullTracer.span``."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled tracer: every operation is a cheap no-op."""
    enabled = False

    def span(self, name: str, **args):
        return _NULL_SPAN

    def instant(self, name: str, **args):
        pass

    def counter(self, name: str, **values):
        pass

    def events(self):
        return []

    def export(self, path: Optional[str] = None):
        doc = {"traceEvents": [], "displayTimeUnit": "ms"}
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f)
        return doc


NULL_TRACER = NullTracer()


class _Span:
    """Context manager recording one complete ("X") event on exit."""
    __slots__ = ("_tr", "name", "args", "_t0")

    def __init__(self, tr: "Tracer", name: str, args: dict):
        self._tr = tr
        self.name = name
        self.args = args

    def __enter__(self):
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        self._tr._complete(self.name, self._t0,
                           time.perf_counter_ns(), self.args)
        return False


class Tracer:
    """Bounded, thread-safe span/instant recorder.

    Events are stored as tuples ``(ph, name, ts_ns, dur_ns, tid,
    args)``; the deque drops the oldest events beyond ``capacity`` so
    an endless serving run keeps the trailing window.  All clocks are
    host-monotonic: recording never touches the device.
    """
    enabled = True

    def __init__(self, capacity: int = 65536):
        self.capacity = int(capacity)
        self._buf: deque = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self._t0 = time.perf_counter_ns()
        self._tid_names: dict = {}

    # -- recording -----------------------------------------------------
    def span(self, name: str, **args) -> _Span:
        """Open a named span; use as ``with tracer.span("unpack"): ...``."""
        return _Span(self, name, args)

    def _complete(self, name, t0, t1, args):
        tid = threading.get_ident()
        if tid not in self._tid_names:
            self._tid_names[tid] = threading.current_thread().name
        with self._lock:
            self._buf.append(("X", name, t0, t1 - t0, tid, args))

    def instant(self, name: str, **args):
        """Record a zero-duration event (deploy pickup, admission, ...)."""
        tid = threading.get_ident()
        if tid not in self._tid_names:
            self._tid_names[tid] = threading.current_thread().name
        with self._lock:
            self._buf.append(("i", name, time.perf_counter_ns(),
                              0, tid, args))

    def counter(self, name: str, **values):
        """Record a counter sample (renders as a track in Perfetto)."""
        tid = threading.get_ident()
        with self._lock:
            self._buf.append(("C", name, time.perf_counter_ns(),
                              0, tid, values))

    # -- export --------------------------------------------------------
    def events(self):
        """Snapshot of the raw event tuples (oldest first)."""
        with self._lock:
            return list(self._buf)

    def export(self, path: Optional[str] = None) -> dict:
        """Render the ring as a Chrome trace-event JSON document.

        Returns the document (``{"traceEvents": [...]}``); when
        ``path`` is given it is also written there.
        """
        pid = os.getpid()
        out = []
        for tid, tname in sorted(self._tid_names.items()):
            out.append({"ph": "M", "name": "thread_name", "pid": pid,
                        "tid": tid, "args": {"name": tname}})
        for ph, name, ts_ns, dur_ns, tid, args in self.events():
            ev = {"ph": ph, "name": name, "pid": pid, "tid": tid,
                  "ts": (ts_ns - self._t0) / 1e3, "cat": "tide"}
            if ph == "X":
                ev["dur"] = dur_ns / 1e3
            elif ph == "i":
                ev["s"] = "t"  # thread-scoped instant
            if args:
                ev["args"] = dict(args)
            out.append(ev)
        doc = {"traceEvents": out, "displayTimeUnit": "ms"}
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f)
        return doc
