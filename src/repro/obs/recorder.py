"""Per-request flight recorder.

Reconstructs each request's lifecycle purely from events the engine
already observes host-side — admission (``_assign_sids``), prefill
chunk dispatches, the first committed token, per-round token commits
replayed during superstep unpack, speculation park/probe/resume
transitions, deploy pickups, and finish — so recording adds zero
device syncs.  Each request accumulates a timeline of
``{"kind", "round", "t", ...}`` events stamped with both the
deterministic executed-round clock (reproducible across runs) and a
host monotonic time (for wall postmortems).

Timeline schema (per request)::

    {"rid": str, "sid": int, "domain": str, "prompt_len": int,
     "budget": int, "priority": int, "deadline": float|None,
     "events": [{"kind": "admit" | "prefill_chunk" | "first_token" |
                 "commit" | "finish" | ..., "round": int, "t": s, ...}],
     "ttft_s": float|None, "latency_s": float|None}   # stamped at finish

Global (non-request) events — deploys, park/probe/resume, admission
deferrals — land in a separate bounded event ring with the same
``kind``/``round``/``t`` stamps.

Memory is bounded: at most ``capacity`` finished timelines are kept
(drop-oldest) plus the live set and ``4 * capacity`` global events.
``NULL_RECORDER`` (default) answers ``enabled == False`` so the
disabled hot path is one attribute check.
"""
from __future__ import annotations

import json
import time
from collections import deque
from typing import Optional


class NullRecorder:
    """Disabled recorder: every hook is a no-op."""
    enabled = False

    def admit(self, req, round_: int):
        pass

    def note(self, rid, kind: str, round_: int = -1, **fields):
        pass

    def finish(self, req, round_: int):
        pass

    def global_event(self, kind: str, round_: int = -1, **fields):
        pass

    def timeline(self, rid):
        return None

    def timelines(self):
        return []

    def export(self, path: Optional[str] = None):
        doc = {"requests": [], "events": []}
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f)
        return doc


NULL_RECORDER = NullRecorder()


class FlightRecorder:
    """Bounded per-request lifecycle recorder (host clocks only).

    Single-writer by design: all hooks are called from the serving
    thread (the engine's unpack/admission path), so no lock is taken
    on the hot path.  ``export`` snapshots via list copies.
    """
    enabled = True

    def __init__(self, capacity: int = 1024):
        self.capacity = int(capacity)
        self._t0 = time.perf_counter()
        self._live: dict = {}                      # rid -> timeline dict
        self._done: deque = deque(maxlen=self.capacity)
        self._events: deque = deque(maxlen=4 * self.capacity)

    def _now(self) -> float:
        return time.perf_counter() - self._t0

    # -- hooks (engine-facing) -----------------------------------------
    def admit(self, req, round_: int):
        tl = {
            "rid": req.rid, "sid": req.sid, "domain": req.domain,
            "prompt_len": len(req.prompt), "budget": req.max_new_tokens,
            "priority": getattr(req, "priority", 0),
            "deadline": getattr(req, "deadline", None),
            "events": [{"kind": "admit", "round": round_,
                        "t": self._now()}],
        }
        self._live[req.rid] = tl

    def note(self, rid, kind: str, round_: int = -1, **fields):
        tl = self._live.get(rid)
        if tl is None:
            return
        ev = {"kind": kind, "round": round_, "t": self._now()}
        if fields:
            ev.update(fields)
        tl["events"].append(ev)

    def finish(self, req, round_: int):
        tl = self._live.pop(req.rid, None)
        if tl is None:
            return
        tl["events"].append({"kind": "finish", "round": round_,
                             "t": self._now(),
                             "tokens": len(req.generated)})
        tl["ttft_s"] = req.ttft
        tl["latency_s"] = req.latency
        self._done.append(tl)

    def global_event(self, kind: str, round_: int = -1, **fields):
        ev = {"kind": kind, "round": round_, "t": self._now()}
        if fields:
            ev.update(fields)
        self._events.append(ev)

    # -- inspection / export -------------------------------------------
    def timeline(self, rid) -> Optional[dict]:
        """The timeline for ``rid`` (live or finished), else None."""
        tl = self._live.get(rid)
        if tl is not None:
            return tl
        for tl in self._done:
            if tl["rid"] == rid:
                return tl
        return None

    def timelines(self):
        """All finished timelines (oldest first) then live ones."""
        return list(self._done) + list(self._live.values())

    def export(self, path: Optional[str] = None) -> dict:
        doc = {"requests": self.timelines(),
               "events": list(self._events)}
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f, indent=1)
        return doc
