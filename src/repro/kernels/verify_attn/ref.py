"""Pure-jnp oracle for the speculative verification attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def verify_attention_ref(q, k_cache, v_cache, lengths, pad=None, *,
                         window: int = 0):
    """q: (B, T, Hq, D) — γ+1 verify queries at cache positions
    lengths[b] + [0..T); k/v_cache: (B, Smax, Hk, D) with the new block's
    K/V already written. Valid region is [pad[b], lengths[b] + t].
    Returns (B, T, Hq, D)."""
    b, t, hq, d = q.shape
    smax, hk = k_cache.shape[1], k_cache.shape[2]
    g = hq // hk
    qf = q.astype(jnp.float32).reshape(b, t, hk, g, d)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    scores = jnp.einsum("btkgd,bskd->bkgts", qf, kf) / jnp.sqrt(d)
    qpos = lengths[:, None] + jnp.arange(t)[None, :]          # (B, T)
    kpos = jnp.arange(smax)
    mask = kpos[None, None, :] <= qpos[:, :, None]
    if pad is not None:
        mask &= kpos[None, None, :] >= pad[:, None, None]
    if window:
        mask &= kpos[None, None, :] > qpos[:, :, None] - window
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", p, vf)
    return out.reshape(b, t, hq, d).astype(q.dtype)


def verify_attention_paged_ref(q, k_pool, v_pool, tbl, lengths, pad=None, *,
                               window: int = 0):
    """Paged oracle: gather each lane's dense (B, n_tbl * P) view
    through the block table, then run the dense reference — the same
    gather-then-attend structure the serving engine's XLA paged path
    uses, so kernel-vs-ref agreement transfers to the engine."""
    b = q.shape[0]
    n_tbl, p = tbl.shape[1], k_pool.shape[1]
    kv_shape = (b, n_tbl * p) + k_pool.shape[2:]
    k_cache = k_pool[tbl].reshape(kv_shape)
    v_cache = v_pool[tbl].reshape(kv_shape)
    return verify_attention_ref(q, k_cache, v_cache, lengths, pad,
                                window=window)


def verify_attention_tree_ref(q, k_cache, v_cache, lengths, pad=None, *,
                              tree, window: int = 0):
    """Tree-masked oracle: the T = width*gamma + 1 queries are a
    flattened draft tree (slot 0 root, then branch-major chains of
    depth gamma) written at cache positions lengths[b] + [0..T); each
    query attends committed history [pad, lengths) plus its own
    root-path ancestors inside the block.  width == 1 degenerates to
    ``verify_attention_ref`` boolean-for-boolean."""
    width, gamma = tree
    b, t, hq, d = q.shape
    smax, hk = k_cache.shape[1], k_cache.shape[2]
    g = hq // hk
    qf = q.astype(jnp.float32).reshape(b, t, hk, g, d)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    scores = jnp.einsum("btkgd,bskd->bkgts", qf, kf) / jnp.sqrt(d)
    qi = jnp.arange(t)[None, :, None]                         # (1, T, 1)
    kpos = jnp.arange(smax)[None, None, :]                    # (1, 1, S)
    length_b = lengths[:, None, None]
    kslot = kpos - length_b
    committed = kpos < length_b
    if pad is not None:
        committed = committed & (kpos >= pad[:, None, None])
    in_block = (kpos >= length_b) & (kpos < length_b + t)
    same_branch = (kslot - 1) // gamma == (qi - 1) // gamma
    anc = ((kslot == 0)
           | ((qi > 0) & (kslot > 0) & (kslot < t) & same_branch
              & ((kslot - 1) % gamma <= (qi - 1) % gamma)))
    mask = committed | (in_block & anc)
    if window:
        qdepth = jnp.where(qi == 0, 0, (qi - 1) % gamma + 1)
        kdepth = jnp.where(kslot == 0, 0, (kslot - 1) % gamma + 1)
        k_logical = jnp.where(in_block, length_b + kdepth, kpos)
        mask = mask & (k_logical > length_b + qdepth - window)
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", p, vf)
    return out.reshape(b, t, hq, d).astype(q.dtype)


def verify_attention_tree_paged_ref(q, k_pool, v_pool, tbl, lengths,
                                    pad=None, *, tree, window: int = 0):
    """Paged tree oracle: gather-dense through the block table, then the
    dense tree reference (same structure as the non-tree paged oracle)."""
    b = q.shape[0]
    n_tbl, p = tbl.shape[1], k_pool.shape[1]
    kv_shape = (b, n_tbl * p) + k_pool.shape[2:]
    k_cache = k_pool[tbl].reshape(kv_shape)
    v_cache = v_pool[tbl].reshape(kv_shape)
    return verify_attention_tree_ref(q, k_cache, v_cache, lengths, pad,
                                     tree=tree, window=window)
