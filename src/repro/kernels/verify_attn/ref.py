"""Pure-jnp oracle for the speculative verification attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def verify_attention_ref(q, k_cache, v_cache, lengths, pad=None, *,
                         window: int = 0):
    """q: (B, T, Hq, D) — γ+1 verify queries at cache positions
    lengths[b] + [0..T); k/v_cache: (B, Smax, Hk, D) with the new block's
    K/V already written. Valid region is [pad[b], lengths[b] + t].
    Returns (B, T, Hq, D)."""
    b, t, hq, d = q.shape
    smax, hk = k_cache.shape[1], k_cache.shape[2]
    g = hq // hk
    qf = q.astype(jnp.float32).reshape(b, t, hk, g, d)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    scores = jnp.einsum("btkgd,bskd->bkgts", qf, kf) / jnp.sqrt(d)
    qpos = lengths[:, None] + jnp.arange(t)[None, :]          # (B, T)
    kpos = jnp.arange(smax)
    mask = kpos[None, None, :] <= qpos[:, :, None]
    if pad is not None:
        mask &= kpos[None, None, :] >= pad[:, None, None]
    if window:
        mask &= kpos[None, None, :] > qpos[:, :, None] - window
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", p, vf)
    return out.reshape(b, t, hq, d).astype(q.dtype)


def verify_attention_paged_ref(q, k_pool, v_pool, tbl, lengths, pad=None, *,
                               window: int = 0):
    """Paged oracle: gather each lane's dense (B, n_tbl * P) view
    through the block table, then run the dense reference — the same
    gather-then-attend structure the serving engine's XLA paged path
    uses, so kernel-vs-ref agreement transfers to the engine."""
    b = q.shape[0]
    n_tbl, p = tbl.shape[1], k_pool.shape[1]
    kv_shape = (b, n_tbl * p) + k_pool.shape[2:]
    k_cache = k_pool[tbl].reshape(kv_shape)
    v_cache = v_pool[tbl].reshape(kv_shape)
    return verify_attention_ref(q, k_cache, v_cache, lengths, pad,
                                window=window)
