"""Jitted public wrapper for the speculative verification attention."""
from __future__ import annotations

import functools

import jax

from repro.kernels.verify_attn.kernel import (verify_attention,
                                              verify_attention_paged)
from repro.kernels.verify_attn.ref import (verify_attention_paged_ref,
                                           verify_attention_ref,
                                           verify_attention_tree_paged_ref,
                                           verify_attention_tree_ref)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("window", "block_kv",
                                             "force_kernel", "tree"))
def verify_attn(q, k_cache, v_cache, lengths, pad=None, *, window: int = 0,
                block_kv: int = 512, force_kernel: bool = False,
                tree: tuple = (0, 0)):
    """``tree=(width, gamma)`` with width > 0 scores a flattened draft
    tree block (T = width*gamma + 1 rows) under the tree-causal mask;
    (0, 0) is the linear verify chain."""
    if _on_tpu() or force_kernel:
        return verify_attention(q, k_cache, v_cache, lengths, pad,
                                window=window, block_kv=block_kv,
                                interpret=not _on_tpu(), tree=tree)
    if tree[0]:
        return verify_attention_tree_ref(q, k_cache, v_cache, lengths, pad,
                                         tree=tree, window=window)
    return verify_attention_ref(q, k_cache, v_cache, lengths, pad,
                                window=window)


@functools.partial(jax.jit, static_argnames=("window", "force_kernel",
                                             "tree"))
def verify_attn_paged(q, k_pool, v_pool, tbl, lengths, pad=None, *,
                      window: int = 0, force_kernel: bool = False,
                      tree: tuple = (0, 0)):
    """Block-table verify attention: KV pages are DMA'd through the
    scalar-prefetched table (TPU) or gathered densely (oracle).
    ``tree=(width, gamma)`` as in ``verify_attn``."""
    if _on_tpu() or force_kernel:
        return verify_attention_paged(q, k_pool, v_pool, tbl, lengths, pad,
                                      window=window,
                                      interpret=not _on_tpu(), tree=tree)
    if tree[0]:
        return verify_attention_tree_paged_ref(q, k_pool, v_pool, tbl,
                                               lengths, pad, tree=tree,
                                               window=window)
    return verify_attention_paged_ref(q, k_pool, v_pool, tbl, lengths, pad,
                                      window=window)
