"""Pallas TPU speculative-verification attention (flash-decoding style).

The γ+1 verify queries of each request attend to its KV cache (new block
already written).  This is the target-model hot spot of TIDE's serving
step: tiny query block, huge KV — so the kernel tiles the *KV sequence*
into VMEM blocks (grid-innermost) and carries an online softmax in
scratch, exactly flash-decoding on TPU.  Per-request valid windows
(lengths/pad) arrive as small int refs in VMEM; fully-masked KV blocks
are skipped with ``pl.when`` (no MXU work issued).

The query block (γ+1 = 4 rows) is padded to 8 rows (fp32 sublane tile);
masking keeps the pad rows inert.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _tree_mask(qi, kpos, length, pad, *, t: int, window: int,
               tree_w: int, tree_g: int):
    """Tree-causal verify mask from iota arithmetic alone: the t =
    tree_w*tree_g + 1 block rows at cache positions [length, length+t)
    are a flattened draft tree (slot 0 root, branch-major chains of
    depth tree_g); a query sees committed history plus its own
    root-path ancestors.  Static (tree_w, tree_g) means no mask arrays
    cross the kernel boundary."""
    kslot = kpos - length
    committed = (kpos < length) & (kpos >= pad)
    in_block = (kpos >= length) & (kpos < length + t)
    anc = (kslot == 0) | (
        (qi > 0) & (kslot > 0) & (kslot < t)
        & ((kslot - 1) // tree_g == (qi - 1) // tree_g)
        & ((kslot - 1) % tree_g <= (qi - 1) % tree_g))
    mask = committed | (in_block & anc)
    if window:
        qdepth = jnp.where(qi == 0, 0, (qi - 1) % tree_g + 1)
        kdepth = jnp.where(kslot == 0, 0, (kslot - 1) % tree_g + 1)
        k_logical = jnp.where(in_block, length + kdepth, kpos)
        mask &= k_logical > length + qdepth - window
    return mask


def _kernel(len_ref, pad_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
            acc_scr, *, t: int, t_pad: int, block_kv: int, nkv: int,
            window: int, scale: float, tree_w: int = 0, tree_g: int = 0):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0]
    pad = pad_ref[0]
    blk_lo = ik * block_kv
    # last readable position for any query in this request:
    max_kpos = length + t - 1

    @pl.when(blk_lo <= max_kpos)
    def _work():
        q = q_ref[0, :, 0, :].astype(jnp.float32)       # (t_pad, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)       # (bkv, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jnp.dot(q, k.T) * scale                     # (t_pad, bkv)
        qi = jax.lax.broadcasted_iota(jnp.int32, (t_pad, block_kv), 0)
        kpos = blk_lo + jax.lax.broadcasted_iota(jnp.int32,
                                                 (t_pad, block_kv), 1)
        if tree_w:
            mask = _tree_mask(qi, kpos, length, pad, t=t, window=window,
                              tree_w=tree_w, tree_g=tree_g)
        else:
            qpos = length + qi
            mask = (kpos <= qpos) & (kpos >= pad)
            if window:
                mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(p, v)
        m_scr[...] = m_new

    @pl.when(ik == nkv - 1)
    def _fin():
        o_ref[0, :, 0, :] = (acc_scr[...]
                             / jnp.maximum(l_scr[...], 1e-30)[:, None]
                             ).astype(o_ref.dtype)


def _paged_kernel(tbl_ref, len_ref, pad_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, t: int, t_pad: int,
                  page_size: int, n_tbl: int, window: int, scale: float,
                  tree_w: int = 0, tree_g: int = 0):
    """Paged flash-decoding step: one block table *page* per kv-grid
    step.  The page id was scalar-prefetched from the block table by
    the BlockSpec index_map, so k_ref/v_ref already hold this page's
    rows — the kernel body is the dense online-softmax step with
    ``kpos`` derived from the table slot, not the buffer offset."""
    b = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    pad = pad_ref[b]
    blk_lo = ik * page_size
    max_kpos = length + t - 1

    @pl.when(blk_lo <= max_kpos)
    def _work():
        q = q_ref[0, :, 0, :].astype(jnp.float32)       # (t_pad, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)       # (P, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jnp.dot(q, k.T) * scale                     # (t_pad, P)
        qi = jax.lax.broadcasted_iota(jnp.int32, (t_pad, page_size), 0)
        kpos = blk_lo + jax.lax.broadcasted_iota(jnp.int32,
                                                 (t_pad, page_size), 1)
        if tree_w:
            mask = _tree_mask(qi, kpos, length, pad, t=t, window=window,
                              tree_w=tree_w, tree_g=tree_g)
        else:
            qpos = length + qi
            mask = (kpos <= qpos) & (kpos >= pad)
            if window:
                mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(p, v)
        m_scr[...] = m_new

    @pl.when(ik == n_tbl - 1)
    def _fin():
        o_ref[0, :, 0, :] = (acc_scr[...]
                             / jnp.maximum(l_scr[...], 1e-30)[:, None]
                             ).astype(o_ref.dtype)


def verify_attention_paged(q, k_pool, v_pool, tbl, lengths, pad=None, *,
                           window: int = 0, interpret: bool = False,
                           tree=(0, 0)):
    """Block-table variant: q (B, T, Hq, D); k/v_pool (num_pages + 1,
    P, Hk, D); tbl (B, n_tbl) int32 page ids.  Each kv-grid step DMAs
    the page the table names (scalar-prefetched index_map) — the paged
    lane's cache never materializes densely.  Caller contract: every
    position in [pad[b], lengths[b] + T) maps a real page (the
    allocator's reservation invariant); other table entries may be the
    trash page, whose garbage keys are masked out."""
    b, t, hq, d = q.shape
    npg1, page_size, hk = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    n_tbl = tbl.shape[1]
    g = hq // hk
    if pad is None:
        pad = jnp.zeros((b,), jnp.int32)
    t_pad = max(8, t)            # fp32 sublane tile
    if t != t_pad:
        q = jnp.pad(q, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
    grid = (b, hq, n_tbl)
    kern = functools.partial(
        _paged_kernel, t=t, t_pad=t_pad, page_size=page_size, n_tbl=n_tbl,
        window=window, scale=1.0 / math.sqrt(d),
        tree_w=tree[0], tree_g=tree[1])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,           # tbl, lengths, pad
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, t_pad, 1, d),
                         lambda b_, h, ik, tbl_ref, len_ref, pad_ref:
                         (b_, 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, d),
                         lambda b_, h, ik, tbl_ref, len_ref, pad_ref:
                         (tbl_ref[b_, ik], 0, h // g, 0)),
            pl.BlockSpec((1, page_size, 1, d),
                         lambda b_, h, ik, tbl_ref, len_ref, pad_ref:
                         (tbl_ref[b_, ik], 0, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, t_pad, 1, d),
                               lambda b_, h, ik, tbl_ref, len_ref, pad_ref:
                               (b_, 0, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((t_pad,), jnp.float32),
            pltpu.VMEM((t_pad,), jnp.float32),
            pltpu.VMEM((t_pad, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, t_pad, hq, d), q.dtype),
        interpret=interpret,
    )(tbl.astype(jnp.int32), lengths.astype(jnp.int32),
      pad.astype(jnp.int32), q, k_pool, v_pool)
    return out[:, :t]


def verify_attention(q, k_cache, v_cache, lengths, pad=None, *,
                     window: int = 0, block_kv: int = 512,
                     interpret: bool = False, tree=(0, 0)):
    """q: (B, T, Hq, D); k/v_cache: (B, Smax, Hk, D); lengths/pad: (B,).
    Returns (B, T, Hq, D)."""
    b, t, hq, d = q.shape
    smax, hk = k_cache.shape[1], k_cache.shape[2]
    g = hq // hk
    if pad is None:
        pad = jnp.zeros((b,), jnp.int32)
    block_kv = min(block_kv, smax)
    if smax % block_kv:
        raise ValueError(f"cache len {smax} % block_kv {block_kv} != 0")
    nkv = smax // block_kv
    t_pad = max(8, t)            # fp32 sublane tile
    if t != t_pad:
        q = jnp.pad(q, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
    grid = (b, hq, nkv)
    kern = functools.partial(
        _kernel, t=t, t_pad=t_pad, block_kv=block_kv, nkv=nkv,
        window=window, scale=1.0 / math.sqrt(d),
        tree_w=tree[0], tree_g=tree[1])
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b_, h, ik: (b_,)),
            pl.BlockSpec((1,), lambda b_, h, ik: (b_,)),
            pl.BlockSpec((1, t_pad, 1, d), lambda b_, h, ik: (b_, 0, h, 0)),
            pl.BlockSpec((1, block_kv, 1, d),
                         lambda b_, h, ik: (b_, ik, h // g, 0)),
            pl.BlockSpec((1, block_kv, 1, d),
                         lambda b_, h, ik: (b_, ik, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, t_pad, 1, d),
                               lambda b_, h, ik: (b_, 0, h, 0)),
        out_shape=jax.ShapeDtypeStruct((b, t_pad, hq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((t_pad,), jnp.float32),
            pltpu.VMEM((t_pad,), jnp.float32),
            pltpu.VMEM((t_pad, d), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), pad.astype(jnp.int32), q, k_cache, v_cache)
    return out[:, :t]
