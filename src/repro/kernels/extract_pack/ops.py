"""Jitted public wrapper for the training-signal pack kernel.

``pack_signals`` is the superstep's per-round signal compactor
(core/speculative.decode_superstep): inside the fused scan it squeezes
accepted-position (feature, token) pairs to the front of each row so a
single dense (counts, feats, tokens) buffer per superstep crosses to
the host.  On TPU it lowers to the Pallas kernel; elsewhere the jnp
oracle is byte-exact and fuses into the surrounding XLA program.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.extract_pack.kernel import extract_pack
from repro.kernels.extract_pack.ref import extract_pack_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _fit_block(f: int, block_f: int) -> int:
    """Largest divisor of ``f`` that is ≤ ``block_f``, preferring
    lane-aligned (×128) blocks so arbitrary capture widths (3·d_model)
    work without caller-side tuning."""
    b = min(block_f, f)
    for cand in range(b - b % 128, 0, -128):
        if f % cand == 0:
            return cand
    while f % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_f", "force_kernel"))
def pack_signals(feats, tokens, mask, *, block_f: int = 512,
                 force_kernel: bool = False):
    if _on_tpu() or force_kernel:
        return extract_pack(feats, tokens, mask,
                            block_f=_fit_block(feats.shape[-1], block_f),
                            interpret=not _on_tpu())
    return extract_pack_ref(feats, tokens, mask)
