"""Jitted public wrapper for the training-signal pack kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.extract_pack.kernel import extract_pack
from repro.kernels.extract_pack.ref import extract_pack_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_f", "force_kernel"))
def pack_signals(feats, tokens, mask, *, block_f: int = 512,
                 force_kernel: bool = False):
    if _on_tpu() or force_kernel:
        return extract_pack(feats, tokens, mask, block_f=block_f,
                            interpret=not _on_tpu())
    return extract_pack_ref(feats, tokens, mask)
