"""Pure-jnp oracle for the signal extraction pack kernel."""
from __future__ import annotations

import jax.numpy as jnp


def extract_pack_ref(feats, tokens, mask):
    """Compact accepted positions to the front of each row.

    feats: (B, T, F); tokens: (B, T) int32; mask: (B, T) bool.
    Returns (packed_feats (B,T,F), packed_tokens (B,T), counts (B,)):
    row b holds the masked entries in order at [0, counts[b]); the tail is
    zero."""
    b, t, f = feats.shape
    pos = jnp.cumsum(mask, axis=1) - mask.astype(jnp.int32)   # target slot
    slot = jnp.where(mask, pos, t)                            # t = dropped
    pf = jnp.zeros((b, t + 1, f), feats.dtype)
    pt = jnp.zeros((b, t + 1), jnp.int32)
    bidx = jnp.arange(b)[:, None].repeat(t, 1)
    pf = pf.at[bidx, slot].set(feats)
    pt = pt.at[bidx, slot].set(tokens)
    return pf[:, :t], pt[:, :t], mask.sum(axis=1).astype(jnp.int32)
