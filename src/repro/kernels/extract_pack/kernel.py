"""Pallas TPU kernel for zero-overhead training-signal packing (TIDE §3.2).

After verification, accepted-position capture features must be compacted
(per request) into the contiguous host-transfer buffer.  Fused into one
VMEM pass, this is the device half of the paper's "overlap extraction
with the next verification step": the packed buffer is the only thing the
host copies, and producing it costs one (T, F) tile per request.

T = γ+1 is tiny; F = 3·d_model is the wide axis.  Grid: (B, F_blocks).
The per-row compaction is a T-step select loop (T ≤ 8), vectorized over
the F lane dimension — no gathers, MXU untouched, pure VPU work.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(mask_ref, feat_ref, tok_ref, pf_ref, pt_ref, cnt_ref, *,
            t: int, block_f: int):
    jf = pl.program_id(1)
    mask = mask_ref[0, :]                       # (t,) int32
    feats = feat_ref[0, :, :]                   # (t, block_f)
    pf_ref[0, :, :] = jnp.zeros_like(pf_ref[0, :, :])
    # slot[i] = exclusive prefix sum of mask
    slots = jnp.cumsum(mask) - mask
    # write row i into slot[i] where accepted: T-step select loop
    for dst in range(t):
        # row that lands at dst (at most one): mask[i] & slots[i]==dst
        sel = ((mask == 1) & (slots == dst)).astype(feats.dtype)   # (t,)
        pf_ref[0, dst, :] = jnp.sum(sel[:, None] * feats, axis=0)

    @pl.when(jf == 0)
    def _tok():
        toks = tok_ref[0, :]
        pt_ref[0, :] = jnp.zeros_like(pt_ref[0, :])
        for dst in range(t):
            sel = ((mask == 1) & (slots == dst)).astype(jnp.int32)
            pt_ref[0, dst] = jnp.sum(sel * toks)
        cnt_ref[0] = jnp.sum(mask)


def extract_pack(feats, tokens, mask, *, block_f: int = 512,
                 interpret: bool = False):
    """feats: (B, T, F); tokens: (B, T) int32; mask: (B, T) bool.
    Returns (packed_feats, packed_tokens, counts) — accepted entries
    compacted to the front per row, zero tail."""
    b, t, f = feats.shape
    block_f = min(block_f, f)
    if f % block_f:
        raise ValueError(f"feature dim {f} % block_f {block_f} != 0")
    nf = f // block_f
    kern = functools.partial(_kernel, t=t, block_f=block_f)
    pf, pt, cnt = pl.pallas_call(
        kern,
        grid=(b, nf),
        in_specs=[
            pl.BlockSpec((1, t), lambda b_, jf: (b_, 0)),
            pl.BlockSpec((1, t, block_f), lambda b_, jf: (b_, 0, jf)),
            pl.BlockSpec((1, t), lambda b_, jf: (b_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, t, block_f), lambda b_, jf: (b_, 0, jf)),
            pl.BlockSpec((1, t), lambda b_, jf: (b_, 0)),
            pl.BlockSpec((1,), lambda b_, jf: (b_,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, f), feats.dtype),
            jax.ShapeDtypeStruct((b, t), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        ],
        interpret=interpret,
    )(mask.astype(jnp.int32), feats, tokens.astype(jnp.int32))
    return pf, pt, cnt
