"""Pure-jnp oracle for the flash prefill attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        softcap: float = 0.0):
    """q: (B, S, Hq, D); k, v: (B, S, Hk, D); GQA by head grouping.
    Returns (B, S, Hq, D) in q.dtype; math in fp32."""
    b, s, hq, d = q.shape
    hk = k.shape[2]
    g = hq // hk
    qf = q.astype(jnp.float32).reshape(b, s, hk, g, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bskgd,btkd->bkgst", qf, kf) / jnp.sqrt(d)
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, vf)
    return out.reshape(b, s, hq, d).astype(q.dtype)


def flash_attention_paged_ref(q, k_pool, v_pool, tbl, *, causal: bool = True,
                              window: int = 0):
    """Paged oracle: gather the dense per-lane K/V view through the
    block table (truncated to the query width), then run the dense
    reference."""
    b, s = q.shape[0], q.shape[1]
    p = k_pool.shape[1]
    n_pg = -(-s // p)
    kv_shape = (b, n_pg * p) + k_pool.shape[2:]
    k = k_pool[tbl[:, :n_pg]].reshape(kv_shape)[:, :s]
    v = v_pool[tbl[:, :n_pg]].reshape(kv_shape)[:, :s]
    return flash_attention_ref(q, k, v, causal=causal, window=window)
