"""Jitted public wrapper for the flash prefill attention kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attn.kernel import (flash_attention,
                                             flash_attention_paged)
from repro.kernels.flash_attn.ref import (flash_attention_paged_ref,
                                          flash_attention_ref)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv", "force_kernel"))
def flash_attn(q, k, v, *, causal: bool = True, window: int = 0,
               block_q: int = 128, block_kv: int = 128,
               force_kernel: bool = False):
    """Dispatch: Pallas kernel on TPU (or forced, in interpret mode on
    CPU — used by the allclose sweeps); jnp oracle elsewhere."""
    if _on_tpu() or force_kernel:
        return flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_kv=block_kv,
                               interpret=not _on_tpu())
    return flash_attention_ref(q, k, v, causal=causal, window=window)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "force_kernel"))
def flash_attn_paged(q, k_pool, v_pool, tbl, *, causal: bool = True,
                     window: int = 0, block_q: int = 128,
                     force_kernel: bool = False):
    """Block-table prefill attention: KV pages DMA'd through the
    scalar-prefetched table (TPU) or gathered densely (oracle)."""
    if _on_tpu() or force_kernel:
        return flash_attention_paged(q, k_pool, v_pool, tbl, causal=causal,
                                     window=window, block_q=block_q,
                                     interpret=not _on_tpu())
    return flash_attention_paged_ref(q, k_pool, v_pool, tbl, causal=causal,
                                     window=window)
