"""Pallas TPU flash prefill attention (GQA, causal, optional sliding
window).

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks) — the kv dimension is
innermost ("arbitrary" semantics) so the online-softmax scratch carries
across kv steps.  Blocks are VMEM-resident via BlockSpec; accumulation is
fp32 in scratch; the output block is written once, on the last kv step.

TPU shape notes: block_q/block_kv multiples of 128 keep the MXU fed
(8×128 VREGs); head_dim is the contracted dim of both matmuls, so the
working set per step is (bq + 2·bkv + bq)·d fp32 ≈ 0.5 MB at the default
128/128/128 blocks — far inside the ~16 MB v5e VMEM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            block_q: int, block_kv: int, nkv: int, causal: bool,
            window: int, scale: float):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32)          # (bq, d)
    k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bkv, d)
    v = v_ref[0, :, 0, :].astype(jnp.float32)          # (bkv, d)
    s = jnp.dot(q, k.T) * scale                        # (bq, bkv)
    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_kv), 0)
    kpos = ik * block_kv + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_kv), 1)
    mask = jnp.ones((block_q, block_kv), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_scr[...] * alpha + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(p, v)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ik == nkv - 1)
    def _fin():
        o_ref[0, :, 0, :] = (acc_scr[...]
                             / jnp.maximum(l_scr[...], 1e-30)[:, None]
                             ).astype(o_ref.dtype)


def _paged_kernel(tbl_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                  acc_scr, *, block_q: int, page_size: int, n_pg: int,
                  causal: bool, window: int, scale: float):
    """Paged prefill step: the kv grid walks block-table *pages* (one
    page per step, id scalar-prefetched into the k/v index_maps); the
    softmax carry and masking are the dense kernel's with ``kpos``
    derived from the table slot."""
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32)          # (bq, d)
    k = k_ref[0, :, 0, :].astype(jnp.float32)          # (P, d)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jnp.dot(q, k.T) * scale                        # (bq, P)
    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, page_size), 0)
    kpos = ik * page_size + jax.lax.broadcasted_iota(jnp.int32,
                                                     (block_q, page_size), 1)
    mask = jnp.ones((block_q, page_size), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_scr[...] * alpha + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(p, v)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ik == n_pg - 1)
    def _fin():
        o_ref[0, :, 0, :] = (acc_scr[...]
                             / jnp.maximum(l_scr[...], 1e-30)[:, None]
                             ).astype(o_ref.dtype)


def flash_attention_paged(q, k_pool, v_pool, tbl, *, causal: bool = True,
                          window: int = 0, block_q: int = 128,
                          interpret: bool = False):
    """Block-table prefill attention: q (B, S, Hq, D) at positions
    [0, S); k/v_pool (num_pages + 1, P, Hk, D); tbl (B, n_tbl).  Each
    kv step DMAs the page the table names — the kv block size *is* the
    page size.  Pages covering [0, S) must be mapped (trash entries
    beyond S are never unmasked: causal keeps kpos <= qpos < S)."""
    b, s, hq, d = q.shape
    page_size, hk = k_pool.shape[1], k_pool.shape[2]
    g = hq // hk
    block_q = min(block_q, s)
    if s % block_q:
        raise ValueError(f"seq {s} must divide block_q {block_q}")
    n_pg = -(-s // page_size)
    if n_pg > tbl.shape[1]:
        raise ValueError(f"seq {s} overruns the block table "
                         f"({tbl.shape[1]} pages of {page_size})")
    grid = (b, hq, s // block_q, n_pg)
    kern = functools.partial(
        _paged_kernel, block_q=block_q, page_size=page_size, n_pg=n_pg,
        causal=causal, window=window, scale=1.0 / math.sqrt(d))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d),
                         lambda b_, h, iq, ik, tbl_ref: (b_, iq, h, 0)),
            pl.BlockSpec((1, page_size, 1, d),
                         lambda b_, h, iq, ik, tbl_ref:
                         (tbl_ref[b_, ik], 0, h // g, 0)),
            pl.BlockSpec((1, page_size, 1, d),
                         lambda b_, h, iq, ik, tbl_ref:
                         (tbl_ref[b_, ik], 0, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, d),
                               lambda b_, h, iq, ik, tbl_ref:
                               (b_, iq, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, s, hq, d), q.dtype),
        interpret=interpret,
    )(tbl.astype(jnp.int32), q, k_pool, v_pool)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool = False):
    """q: (B, S, Hq, D); k, v: (B, S, Hk, D) -> (B, S, Hq, D)."""
    b, s, hq, d = q.shape
    hk = k.shape[2]
    g = hq // hk
    block_q = min(block_q, s)
    block_kv = min(block_kv, s)
    if s % block_q or s % block_kv:
        raise ValueError(f"seq {s} must divide block sizes "
                         f"({block_q}, {block_kv})")
    nq, nkv = s // block_q, s // block_kv
    grid = (b, hq, nq, nkv)
    kern = functools.partial(
        _kernel, block_q=block_q, block_kv=block_kv, nkv=nkv,
        causal=causal, window=window, scale=1.0 / math.sqrt(d))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d),
                         lambda b_, h, iq, ik: (b_, iq, h, 0)),
            pl.BlockSpec((1, block_kv, 1, d),
                         lambda b_, h, iq, ik: (b_, ik, h // g, 0)),
            pl.BlockSpec((1, block_kv, 1, d),
                         lambda b_, h, iq, ik: (b_, ik, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, d),
                               lambda b_, h, iq, ik: (b_, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, hq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
