"""Pallas TPU flash prefill attention (GQA, causal, optional sliding
window).

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks) — the kv dimension is
innermost ("arbitrary" semantics) so the online-softmax scratch carries
across kv steps.  Blocks are VMEM-resident via BlockSpec; accumulation is
fp32 in scratch; the output block is written once, on the last kv step.

TPU shape notes: block_q/block_kv multiples of 128 keep the MXU fed
(8×128 VREGs); head_dim is the contracted dim of both matmuls, so the
working set per step is (bq + 2·bkv + bq)·d fp32 ≈ 0.5 MB at the default
128/128/128 blocks — far inside the ~16 MB v5e VMEM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            block_q: int, block_kv: int, nkv: int, causal: bool,
            window: int, scale: float):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32)          # (bq, d)
    k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bkv, d)
    v = v_ref[0, :, 0, :].astype(jnp.float32)          # (bkv, d)
    s = jnp.dot(q, k.T) * scale                        # (bq, bkv)
    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_kv), 0)
    kpos = ik * block_kv + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_kv), 1)
    mask = jnp.ones((block_q, block_kv), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_scr[...] * alpha + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(p, v)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ik == nkv - 1)
    def _fin():
        o_ref[0, :, 0, :] = (acc_scr[...]
                             / jnp.maximum(l_scr[...], 1e-30)[:, None]
                             ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool = False):
    """q: (B, S, Hq, D); k, v: (B, S, Hk, D) -> (B, S, Hq, D)."""
    b, s, hq, d = q.shape
    hk = k.shape[2]
    g = hq // hk
    block_q = min(block_q, s)
    block_kv = min(block_kv, s)
    if s % block_q or s % block_kv:
        raise ValueError(f"seq {s} must divide block sizes "
                         f"({block_q}, {block_kv})")
    nq, nkv = s // block_q, s // block_kv
    grid = (b, hq, nq, nkv)
    kern = functools.partial(
        _kernel, block_q=block_q, block_kv=block_kv, nkv=nkv,
        causal=causal, window=window, scale=1.0 / math.sqrt(d))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d),
                         lambda b_, h, iq, ik: (b_, iq, h, 0)),
            pl.BlockSpec((1, block_kv, 1, d),
                         lambda b_, h, iq, ik: (b_, ik, h // g, 0)),
            pl.BlockSpec((1, block_kv, 1, d),
                         lambda b_, h, iq, ik: (b_, ik, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, d),
                               lambda b_, h, iq, ik: (b_, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, hq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
