"""Selective draft-training control (paper §4.2, Algorithm 1).

Maintains short/long EMAs of the acceptance rate; a short-EMA drop below
the long EMA (minus ε) signals distribution shift and enables training-
signal collection.  When enough samples accumulate, a training cycle is
triggered; the new draft deploys only if eval acceptance beats the
collection-time average, otherwise collection is disabled until the next
shift.  This module is pure host-side control logic (no jax), driven by
the serving engine.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional


class Decision(enum.Enum):
    NONE = "none"
    START_COLLECTION = "start_collection"
    TRIGGER_TRAINING = "trigger_training"


@dataclasses.dataclass
class TrainingController:
    """Algorithm 1 state machine."""
    lambda_short: float = 0.9
    lambda_long: float = 0.99
    epsilon: float = 0.02
    n_init: int = 8
    n_threshold: int = 2048          # stored samples to trigger training

    collection_enabled: bool = False
    alpha_short: Optional[float] = None
    alpha_long: Optional[float] = None
    stored_samples: int = 0
    collected_alpha_sum: float = 0.0
    collected_alpha_n: int = 0
    _init_buf: List[float] = dataclasses.field(default_factory=list)
    # bookkeeping for experiments
    history: List[dict] = dataclasses.field(default_factory=list)

    def reset(self):
        """Back to the post-construction state (fresh shift detector,
        empty collection window)."""
        self.collection_enabled = False
        self.alpha_short = None
        self.alpha_long = None
        self.stored_samples = 0
        self.collected_alpha_sum = 0.0
        self.collected_alpha_n = 0
        self._init_buf = []
        self.history = []

    # ---- Algorithm 1, line by line -------------------------------------
    def observe(self, alpha: float, n_new_samples: int = 0) -> Decision:
        """Feed one acceptance-rate measurement (per engine step).
        ``n_new_samples`` = training-signal rows stored this step if
        collection is on.  Returns the control decision."""
        if self.alpha_short is None:
            # initialization phase: plain average of the first N_init
            self._init_buf.append(alpha)
            if len(self._init_buf) >= self.n_init:
                mean = sum(self._init_buf) / len(self._init_buf)
                self.alpha_short = mean
                self.alpha_long = mean
            return Decision.NONE

        self.alpha_short = (self.lambda_short * self.alpha_short
                            + (1 - self.lambda_short) * alpha)
        self.alpha_long = (self.lambda_long * self.alpha_long
                           + (1 - self.lambda_long) * alpha)

        decision = Decision.NONE
        if (not self.collection_enabled
                and self.alpha_short < self.alpha_long - self.epsilon):
            self.collection_enabled = True
            decision = Decision.START_COLLECTION

        if self.collection_enabled and n_new_samples > 0:
            self.stored_samples += n_new_samples
            self.collected_alpha_sum += alpha * n_new_samples
            self.collected_alpha_n += n_new_samples

        if (self.collection_enabled
                and self.stored_samples >= self.n_threshold):
            decision = Decision.TRIGGER_TRAINING

        self.history.append({
            "alpha": alpha,
            "short": self.alpha_short,
            "long": self.alpha_long,
            "collecting": self.collection_enabled,
            "stored": self.stored_samples,
        })
        return decision

    def observe_gated(self, alpha: float, n_new_samples: int) -> Decision:
        """`observe` with the serving-loop gating applied internally:
        signal rows only count if collection was already enabled *before*
        this observation.  The per-step loop and the fused superstep's
        deferred telemetry replay share this entry point so Algorithm 1
        sees an identical measurement sequence in both modes."""
        collecting_before = self.collection_enabled
        return self.observe(alpha, n_new_samples if collecting_before else 0)

    @property
    def alpha_train(self) -> float:
        """Average acceptance over the collected window (Alg. 1's
        \\bar{alpha}_train)."""
        if self.collected_alpha_n == 0:
            return 0.0
        return self.collected_alpha_sum / self.collected_alpha_n

    def training_result(self, alpha_eval: float) -> bool:
        """Deploy gate: returns True (deploy M_new) iff eval acceptance
        beats the collection-window average; on a strict regression,
        collection is disabled until the next detected shift."""
        deploy = alpha_eval > self.alpha_train
        if alpha_eval < self.alpha_train:
            self.collection_enabled = False
        # either way the buffer was consumed by this cycle
        self.stored_samples = 0
        self.collected_alpha_sum = 0.0
        self.collected_alpha_n = 0
        # reset the shift detector baseline so the same dip doesn't
        # immediately re-trigger
        if deploy:
            self.alpha_long = self.alpha_short
        return deploy
