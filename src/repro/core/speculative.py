"""Speculative decoding: draft-propose + target-verify + acceptance.

Implements both greedy (exact-match) verification and Leviathan-style
stochastic speculative sampling (accept w.p. min(1, p/q), residual
resample), plus the fused ``spec_decode_step`` used by the serving engine
and lowered by the dry-run (the paper's serve step).

Token/position bookkeeping (see core/eagle.py for the draft side):
the verify block fed to the target is ``[t0, d1, …, dγ]`` where t0 is the
last committed token; target logits at block index j give the distribution
of the token after block[j].  ``n_acc`` drafts are accepted and one
bonus/correction token is sampled from logits[n_acc], so each step commits
``n_acc + 1`` tokens.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import eagle
from repro.models import transformer as T
from repro.models.config import ModelConfig


# ------------------------------------------------------------ verification
def verify_greedy(target_logits, draft_tokens):
    """target_logits: (B, γ+1, V); draft_tokens: (B, γ).
    Returns (n_acc (B,), bonus_token (B,))."""
    b, t, _ = target_logits.shape
    gamma = t - 1
    tgt = target_logits[:, :gamma].argmax(-1).astype(jnp.int32)   # (B, γ)
    match = tgt == draft_tokens
    # accepted = longest matching prefix
    n_acc = jnp.cumprod(match.astype(jnp.int32), axis=1).sum(axis=1)
    # bonus/correction token from logits[n_acc]
    bonus_logits = jnp.take_along_axis(
        target_logits, n_acc[:, None, None], axis=1)[:, 0]
    bonus = bonus_logits.argmax(-1).astype(jnp.int32)
    return n_acc, bonus


def verify_sample(key, target_logits, draft_logits, draft_tokens,
                  temperature: float = 1.0):
    """Stochastic speculative sampling (Leviathan et al. 2023).

    target_logits: (B, γ+1, V); draft_logits: (B, γ, V);
    draft_tokens: (B, γ).  Returns (n_acc, bonus) with the guarantee that
    committed tokens are distributed exactly as target samples.
    """
    b, gp1, v = target_logits.shape
    gamma = gp1 - 1
    p = jax.nn.softmax(target_logits[:, :gamma] / temperature, axis=-1)
    q = jax.nn.softmax(draft_logits / temperature, axis=-1)
    p_tok = jnp.take_along_axis(p, draft_tokens[..., None], axis=-1)[..., 0]
    q_tok = jnp.take_along_axis(q, draft_tokens[..., None], axis=-1)[..., 0]
    k_acc, k_res = jax.random.split(key)
    u = jax.random.uniform(k_acc, (b, gamma))
    ok = u < jnp.minimum(1.0, p_tok / jnp.maximum(q_tok, 1e-20))
    n_acc = jnp.cumprod(ok.astype(jnp.int32), axis=1).sum(axis=1)
    # residual distribution at the first rejected slot (or plain target
    # sample at slot γ when everything was accepted)
    sel = jnp.minimum(n_acc, gamma)
    p_rej = jax.nn.softmax(
        jnp.take_along_axis(target_logits, sel[:, None, None], axis=1)[:, 0]
        / temperature, axis=-1)
    q_rej = jnp.take_along_axis(
        jnp.pad(q, ((0, 0), (0, 1), (0, 0))),   # dummy row for the all-acc case
        sel[:, None, None], axis=1)[:, 0]
    residual = jnp.maximum(p_rej - q_rej, 0.0)
    use_residual = (n_acc < gamma)[:, None]
    dist = jnp.where(use_residual, residual, p_rej)
    dist = dist / jnp.maximum(dist.sum(-1, keepdims=True), 1e-20)
    bonus = jax.random.categorical(k_res, jnp.log(dist + 1e-20)
                                   ).astype(jnp.int32)
    return n_acc, bonus


# --------------------------------------------------------------- carry
class SpecCarry(NamedTuple):
    """Pending (feature, token) pairs the draft must ingest next round.

    Pair j is (feats[:, j], tokens[:, j]): the target capture at a
    committed position and the token that *followed* it.  Only the first
    ``advance[b]`` pairs are valid per request (tail entries are scratch
    and get overwritten in the draft cache)."""
    feats: jnp.ndarray      # (B, γ+1, 3D)
    tokens: jnp.ndarray     # (B, γ+1)
    advance: jnp.ndarray    # (B,)


def init_carry(cfg: ModelConfig, dcfg: ModelConfig, prefill_out,
               first_token, gamma: int = 3) -> SpecCarry:
    """Carry after target prefill: one pending pair — the capture of the
    last prompt position with the first sampled token."""
    b = first_token.shape[0]
    feat = prefill_out["captures"][:, -1]
    feats = jnp.zeros((b, gamma + 1, feat.shape[-1]), feat.dtype
                      ).at[:, 0].set(feat)
    tokens = jnp.zeros((b, gamma + 1), jnp.int32
                       ).at[:, 0].set(first_token.astype(jnp.int32))
    return SpecCarry(feats, tokens, jnp.ones((b,), jnp.int32))


def seed_draft_cache(cfg: ModelConfig, dcfg: ModelConfig, tparams, dparams,
                     dcache, prefill_out, prompt_tokens):
    """Draft 'prefill': ingest the prompt pairs (caps[i], t_{i+1}) for
    i < S-1 so the draft has full context before the first propose."""
    caps = prefill_out["captures"]                         # (B, S, 3D)
    b, s, _ = caps.shape
    _, _, dcache = eagle.draft_extend(
        dcfg, dparams, tparams["embed"], dcache,
        caps[:, :s - 1], prompt_tokens[:, 1:],
        jnp.full((b,), s - 1, jnp.int32))
    return dcache


# ------------------------------------------------------------ fused step
def spec_decode_step(cfg: ModelConfig, dcfg: ModelConfig, tparams, dparams,
                     cache, dcache, carry: SpecCarry, *, gamma: int = 3,
                     greedy: bool = True, key=None,
                     moe_impl: str = "sort"):
    """One full speculative serving step (paper Fig. 2 inner loop).

    1. draft-extend with the pairs committed last round (true features),
    2. chain-draft γ tokens from the last valid position,
    3. target verify block [t0, d1..dγ],
    4. accept, commit caches, emit training-signal captures.

    Returns dict(tokens (B, γ+1) committed tokens (scratch beyond
    n_commit), n_commit (B,), cache, dcache, carry, captures, accept_mask).
    """
    b = carry.tokens.shape[0]
    if key is None:
        key = jax.random.key(0)
    k_draft, k_ver = jax.random.split(key)

    # 1) draft catches up on everything committed last round
    ext_logits, ext_h, dcache = eagle.draft_extend(
        dcfg, dparams, tparams["embed"], dcache,
        carry.feats, carry.tokens, carry.advance)
    last = (carry.advance - 1)[:, None, None]
    h_last = jnp.take_along_axis(ext_h, last, axis=1)[:, 0]
    first_logits = jnp.take_along_axis(ext_logits, last, axis=1)[:, 0]

    # 2) chain-draft γ tokens
    draft_tokens, draft_logits, dcache = eagle.draft_propose(
        dcfg, dparams, tparams["embed"], dcache, h_last, first_logits,
        gamma, greedy=greedy, key=k_draft)

    # 3) target verify: t0 = last committed token (pair index advance-1)
    t0 = jnp.take_along_axis(carry.tokens, (carry.advance - 1)[:, None],
                             axis=1)
    block = jnp.concatenate([t0, draft_tokens], axis=1)
    out = T.decode_step(cfg, tparams, cache, block, moe_impl=moe_impl)
    tl = out["logits"]                                     # (B, γ+1, V)

    # 4) acceptance
    if greedy:
        n_acc, bonus = verify_greedy(tl, draft_tokens)
    else:
        n_acc, bonus = verify_sample(k_ver, tl, draft_logits, draft_tokens)
    n_commit = n_acc + 1

    # commit target cache (per-request rollback for SSM states)
    cache = T.commit_cache(cfg, out["cache"], n_commit)
    # draft cache: roll the speculative lengths back (stale entries get
    # overwritten by next round's extend)
    dcache = eagle.reset_propose(dcache, gamma)

    # committed tokens this round: [d1..d_{n_acc}, bonus, scratch...]
    idx = jnp.arange(gamma + 1)[None, :]
    accept_mask = idx < n_commit[:, None]
    committed = jnp.where(idx < n_acc[:, None],
                          jnp.pad(draft_tokens, ((0, 0), (0, 1))),
                          bonus[:, None])
    committed = jnp.where(accept_mask, committed, 0)
    # next round's pending pairs: (caps[j], committed[j]) for j < n_commit
    caps = out["captures"]                                  # (B, γ+1, 3D)
    carry = SpecCarry(caps, committed, n_commit)

    return {"tokens": committed, "n_commit": n_commit, "cache": cache,
            "dcache": dcache, "carry": carry, "captures": caps,
            "accept_mask": accept_mask, "n_acc": n_acc, "block": block,
            "target_logits": tl}


def plain_decode_step(cfg: ModelConfig, tparams, cache, carry_token, *,
                      greedy: bool = True, key=None, moe_impl: str = "sort"):
    """Baseline autoregressive step (speculation disabled — the TIDE
    Adaptive Drafter falls back to this when Eq. 5 predicts no gain)."""
    out = T.decode_step(cfg, tparams, cache, carry_token[:, None],
                        moe_impl=moe_impl)
    logits = out["logits"][:, 0]
    if greedy:
        nxt = logits.argmax(-1).astype(jnp.int32)
    else:
        nxt = jax.random.categorical(key, logits).astype(jnp.int32)
    cache = T.commit_cache(cfg, out["cache"],
                           jnp.ones(carry_token.shape, jnp.int32))
    return {"token": nxt, "cache": cache, "captures": out["captures"],
            "logits": logits}
