"""Speculative decoding: draft-propose + target-verify + acceptance.

Implements both greedy (exact-match) verification and Leviathan-style
stochastic speculative sampling (accept w.p. min(1, p/q), residual
resample), plus the fused ``spec_decode_step`` used by the serving engine
and lowered by the dry-run (the paper's serve step).

Token/position bookkeeping (see core/eagle.py for the draft side):
the verify block fed to the target is ``[t0, d1, …, dγ]`` where t0 is the
last committed token; target logits at block index j give the distribution
of the token after block[j].  ``n_acc`` drafts are accepted and one
bonus/correction token is sampled from logits[n_acc], so each step commits
``n_acc + 1`` tokens.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import eagle
from repro.models import transformer as T
from repro.models.config import ModelConfig


# ------------------------------------------------------------ verification
def verify_greedy(target_logits, draft_tokens):
    """target_logits: (B, γ+1, V); draft_tokens: (B, γ).
    Returns (n_acc (B,), bonus_token (B,))."""
    b, t, _ = target_logits.shape
    gamma = t - 1
    tgt = target_logits[:, :gamma].argmax(-1).astype(jnp.int32)   # (B, γ)
    match = tgt == draft_tokens
    # accepted = longest matching prefix
    n_acc = jnp.cumprod(match.astype(jnp.int32), axis=1).sum(axis=1)
    # bonus/correction token from logits[n_acc]
    bonus_logits = jnp.take_along_axis(
        target_logits, n_acc[:, None, None], axis=1)[:, 0]
    bonus = bonus_logits.argmax(-1).astype(jnp.int32)
    return n_acc, bonus


def verify_sample(key, target_logits, draft_logits, draft_tokens,
                  temperature: float = 1.0, keys=None):
    """Stochastic speculative sampling (Leviathan et al. 2023).

    target_logits: (B, γ+1, V); draft_logits: (B, γ, V);
    draft_tokens: (B, γ).  Returns (n_acc, bonus) with the guarantee that
    committed tokens are distributed exactly as target samples.

    ``keys`` — optional (B,) per-lane key array (per-request PRNG
    streams): every random draw for lane b derives from ``keys[b]``
    only, so a request's acceptance/resample randomness is independent
    of which lanes it shares a batch with.  When omitted, the scalar
    ``key`` is consumed batch-globally (legacy behaviour).
    """
    b, gp1, v = target_logits.shape
    gamma = gp1 - 1
    p = jax.nn.softmax(target_logits[:, :gamma] / temperature, axis=-1)
    q = jax.nn.softmax(draft_logits / temperature, axis=-1)
    p_tok = jnp.take_along_axis(p, draft_tokens[..., None], axis=-1)[..., 0]
    q_tok = jnp.take_along_axis(q, draft_tokens[..., None], axis=-1)[..., 0]
    if keys is None:
        k_acc, k_res = jax.random.split(key)
        u = jax.random.uniform(k_acc, (b, gamma))
    else:
        k_acc = jax.vmap(lambda k: jax.random.fold_in(k, 0))(keys)
        k_res = jax.vmap(lambda k: jax.random.fold_in(k, 1))(keys)
        u = jax.vmap(lambda k: jax.random.uniform(k, (gamma,)))(k_acc)
    ok = u < jnp.minimum(1.0, p_tok / jnp.maximum(q_tok, 1e-20))
    n_acc = jnp.cumprod(ok.astype(jnp.int32), axis=1).sum(axis=1)
    # residual distribution at the first rejected slot (or plain target
    # sample at slot γ when everything was accepted)
    sel = jnp.minimum(n_acc, gamma)
    p_rej = jax.nn.softmax(
        jnp.take_along_axis(target_logits, sel[:, None, None], axis=1)[:, 0]
        / temperature, axis=-1)
    q_rej = jnp.take_along_axis(
        jnp.pad(q, ((0, 0), (0, 1), (0, 0))),   # dummy row for the all-acc case
        sel[:, None, None], axis=1)[:, 0]
    residual = jnp.maximum(p_rej - q_rej, 0.0)
    use_residual = (n_acc < gamma)[:, None]
    dist = jnp.where(use_residual, residual, p_rej)
    dist = dist / jnp.maximum(dist.sum(-1, keepdims=True), 1e-20)
    logd = jnp.log(dist + 1e-20)
    if keys is None:
        bonus = jax.random.categorical(k_res, logd).astype(jnp.int32)
    else:
        bonus = jax.vmap(jax.random.categorical)(k_res, logd
                                                 ).astype(jnp.int32)
    return n_acc, bonus


# ------------------------------------------------------ tree verification
def tree_path_slots(sel, gamma: int):
    """Block slots of the accepted root path: position 0 is the root
    (slot 0), position j >= 1 is branch ``sel``'s depth-j node at slot
    1 + sel*gamma + (j-1).  sel: (B,).  Returns (B, γ+1) int32."""
    j = jnp.arange(gamma + 1)[None, :]
    return jnp.where(j == 0, 0,
                     1 + sel[:, None] * gamma + (j - 1)).astype(jnp.int32)


def verify_tree_greedy(target_logits, draft_tokens):
    """Greedy tree acceptance: walk every branch's exact-match prefix
    under the tree-scored logits and keep the longest root path.

    target_logits: (B, width*γ + 1, V) from the tree-masked verify pass
    (slot layout of ``tree_path_slots``); draft_tokens: (B, width, γ).
    Returns (n_acc (B,), sel (B,) winning branch, bonus (B,)).  Sibling
    roots are distinct, so at most one branch survives depth 1 and the
    walk is exactly "descend the matching child".  width == 1 computes
    ``verify_greedy`` op-for-op."""
    b, w, gamma = draft_tokens.shape
    r = jnp.arange(w)[:, None]
    # parent slot of node (r, j): the root for j=1, else (r, j-1)
    pslots = jnp.concatenate(
        [jnp.zeros((w, 1), jnp.int32),
         (1 + r * gamma + jnp.arange(max(gamma - 1, 0))[None, :]
          ).astype(jnp.int32)], axis=1)                       # (w, γ)
    tgt = target_logits[:, pslots.reshape(-1)].argmax(-1).astype(
        jnp.int32).reshape(b, w, gamma)
    match = tgt == draft_tokens
    n_branch = jnp.cumprod(match.astype(jnp.int32), axis=2).sum(axis=2)
    n_acc = n_branch.max(axis=1)
    sel = n_branch.argmax(axis=1).astype(jnp.int32)
    last_slot = jnp.where(n_acc == 0, 0, 1 + sel * gamma + (n_acc - 1))
    bonus_logits = jnp.take_along_axis(
        target_logits, last_slot[:, None, None], axis=1)[:, 0]
    bonus = bonus_logits.argmax(-1).astype(jnp.int32)
    return n_acc, sel, bonus


def verify_tree_sample(key, target_logits, draft_logits, draft_tokens,
                       temperature: float = 1.0, keys=None):
    """Stochastic tree acceptance: sequential sibling tests with residual
    updates at depth 1 (SpecInfer-style k-sequential verification), then
    the per-chain Leviathan rule down the selected branch.

    target_logits: (B, width*γ + 1, V); draft_logits: (B, width, γ, V)
    where branch r's depth-1 row is the sibling-masked proposal density
    ``draft_propose_tree`` actually sampled from; draft_tokens:
    (B, width, γ).  Depth-1 walk: test branch r with
    u_r < min(1, p(x_r)/q_r(x_r)) against the running residual
    p ← max(p − q_r, 0) of the previously rejected siblings, so
    committed tokens stay distributed exactly as target samples.  The
    bonus draws from the residual at the first failing depth (or the
    target at the last path slot on full accept).  Randomness: branch 0
    consumes the chain's exact uniform stream; branch r >= 1 folds r
    into the acceptance key — width == 1 is bit-for-bit
    ``verify_sample``.  Returns (n_acc, sel, bonus)."""
    b, t, v = target_logits.shape
    _, w, gamma = draft_tokens.shape
    q = jax.nn.softmax(draft_logits / temperature, axis=-1)   # (B,w,γ,V)
    if keys is None:
        k_acc, k_res = jax.random.split(key)
        u = jnp.stack(
            [jax.random.uniform(k_acc, (b, gamma)) if r == 0 else
             jax.random.uniform(jax.random.fold_in(k_acc, r), (b, gamma))
             for r in range(w)], axis=1)                      # (B,w,γ)
    else:
        k_acc = jax.vmap(lambda k: jax.random.fold_in(k, 0))(keys)
        k_res = jax.vmap(lambda k: jax.random.fold_in(k, 1))(keys)
        u = jnp.stack(
            [jax.vmap(lambda k: jax.random.uniform(k, (gamma,)))(k_acc)
             if r == 0 else
             jax.vmap(lambda k, _r=r: jax.random.uniform(
                 jax.random.fold_in(k, _r), (gamma,)))(k_acc)
             for r in range(w)], axis=1)

    # --- depth 1: sequential sibling tests with residual updates
    p_root = jax.nn.softmax(target_logits[:, 0] / temperature, axis=-1)
    p_cur = p_root
    found = jnp.zeros((b,), bool)
    sel = jnp.zeros((b,), jnp.int32)
    for r in range(w):
        x_r = draft_tokens[:, r, 0]
        q_r = q[:, r, 0]
        q_x = jnp.take_along_axis(q_r, x_r[:, None], axis=-1)[:, 0]
        if r == 0:
            p_test = p_cur          # exactly the chain's first test
        else:
            p_test = p_cur / jnp.maximum(p_cur.sum(-1, keepdims=True),
                                         1e-20)
        p_x = jnp.take_along_axis(p_test, x_r[:, None], axis=-1)[:, 0]
        ok_r = u[:, r, 0] < jnp.minimum(1.0, p_x / jnp.maximum(q_x, 1e-20))
        sel = jnp.where(ok_r & ~found, r, sel)
        upd = ~(found | ok_r)
        p_cur = jnp.where(upd[:, None], jnp.maximum(p_cur - q_r, 0.0),
                          p_cur)
        found = found | ok_r

    # --- depths 2..γ: per-chain rule down the selected branch
    tok_sel = jnp.take_along_axis(draft_tokens, sel[:, None, None],
                                  axis=1)[:, 0]               # (B, γ)
    q_sel = jnp.take_along_axis(q, sel[:, None, None, None], axis=1)[:, 0]
    u_sel = jnp.take_along_axis(u, sel[:, None, None], axis=1)[:, 0]
    if gamma > 1:
        deep_slots = (1 + sel[:, None] * gamma
                      + jnp.arange(gamma - 1)[None, :])       # (B, γ-1)
        p_deep = jax.nn.softmax(
            jnp.take_along_axis(target_logits, deep_slots[..., None],
                                axis=1) / temperature, axis=-1)
        p_tok = jnp.take_along_axis(p_deep, tok_sel[:, 1:, None],
                                    axis=-1)[..., 0]
        q_tok = jnp.take_along_axis(q_sel[:, 1:], tok_sel[:, 1:, None],
                                    axis=-1)[..., 0]
        ok_deep = u_sel[:, 1:] < jnp.minimum(
            1.0, p_tok / jnp.maximum(q_tok, 1e-20))
        ok_full = jnp.concatenate([found[:, None], ok_deep], axis=1)
    else:
        ok_full = found[:, None]
    n_acc = jnp.cumprod(ok_full.astype(jnp.int32), axis=1).sum(axis=1)

    # --- bonus: residual at the first failing depth, or the target at
    # the last path slot on full accept; n_acc == 0 uses the depth-1
    # residual accumulated over every rejected sibling
    bslot = jnp.where(n_acc == 0, 0, 1 + sel * gamma + (n_acc - 1))
    p_rej = jax.nn.softmax(
        jnp.take_along_axis(target_logits, bslot[:, None, None],
                            axis=1)[:, 0] / temperature, axis=-1)
    sel_depth = jnp.minimum(n_acc, gamma)
    q_rej = jnp.take_along_axis(
        jnp.pad(q_sel, ((0, 0), (0, 1), (0, 0))),
        sel_depth[:, None, None], axis=1)[:, 0]
    residual = jnp.maximum(p_rej - q_rej, 0.0)
    residual = jnp.where((n_acc == 0)[:, None], p_cur, residual)
    use_residual = (n_acc < gamma)[:, None]
    dist = jnp.where(use_residual, residual, p_rej)
    dist = dist / jnp.maximum(dist.sum(-1, keepdims=True), 1e-20)
    logd = jnp.log(dist + 1e-20)
    if keys is None:
        bonus = jax.random.categorical(k_res, logd).astype(jnp.int32)
    else:
        bonus = jax.vmap(jax.random.categorical)(k_res, logd
                                                 ).astype(jnp.int32)
    return n_acc, sel, bonus


def compact_tree_cache(cache, sel, gamma: int):
    """Rewrite the accepted branch's K/V rows into chain order before
    ``commit_cache``: the tree verify pass wrote width*γ + 1 rows at
    cache positions lengths + [0..T); the accepted path's rows (slots
    1 + sel*γ + [0..γ)) move to positions lengths + [1..γ], after which
    the cache looks exactly like a linear-chain verify block and the
    ordinary commit applies.  Rows past the path are stale-but-masked
    (same contract as the chain's uncommitted tail).  sel == 0 is a
    same-position copy — the width == 1 path is byte-preserving.

    Paged caches move rows *through* the block table: positions resolve
    via ``paging.page_slot``, so unreserved/inactive lanes route to the
    trash page and allocator invariants hold."""
    lengths = cache["lengths"]
    b = lengths.shape[0]
    src = lengths[:, None] + 1 + sel[:, None] * gamma \
        + jnp.arange(gamma)[None, :]                           # (B, γ)
    dst = lengths[:, None] + 1 + jnp.arange(gamma)[None, :]    # (B, γ)
    page_tbl = cache.get("page_tbl")
    if page_tbl is not None:
        from repro.core import paging

        def move(pool):
            # pool: (repeats, num_pages + 1, P, Hk, D)
            p = pool.shape[2]
            trash = pool.shape[1] - 1
            pg_s, sl_s = paging.page_slot(page_tbl, p, src, trash)
            pg_d, sl_d = paging.page_slot(page_tbl, p, dst, trash)
            rows = pool[:, pg_s, sl_s]
            return pool.at[:, pg_d, sl_d].set(rows)
    else:
        bidx = jnp.arange(b)[:, None]

        def move(leaf):
            # leaf: (repeats, B, Smax, ...)
            rows = leaf[:, bidx, src]
            return leaf.at[:, bidx, dst].set(rows)

    out = {}
    for k, v in cache.items():
        if k in ("lengths", "pad", "page_tbl"):
            out[k] = v
        else:
            out[k] = jax.tree.map(move, v)
    return out


# --------------------------------------------------------------- carry
class SpecCarry(NamedTuple):
    """Pending (feature, token) pairs the draft must ingest next round.

    Pair j is (feats[:, j], tokens[:, j]): the target capture at a
    committed position and the token that *followed* it.  Only the first
    ``advance[b]`` pairs are valid per request (tail entries are scratch
    and get overwritten in the draft cache)."""
    feats: jnp.ndarray      # (B, γ+1, 3D)
    tokens: jnp.ndarray     # (B, γ+1)
    advance: jnp.ndarray    # (B,)


def init_carry_from_caps(last_caps, first_token,
                         gamma: int = 3) -> SpecCarry:
    """Carry after target prefill, from the capture of the last prompt
    position: one pending pair (last_caps, first_token).  The chunked
    refill pipeline builds its commit carry from the final chunk's last
    capture column through here — same recipe as the one-shot path."""
    b = first_token.shape[0]
    feats = jnp.zeros((b, gamma + 1, last_caps.shape[-1]), last_caps.dtype
                      ).at[:, 0].set(last_caps)
    tokens = jnp.zeros((b, gamma + 1), jnp.int32
                       ).at[:, 0].set(first_token.astype(jnp.int32))
    return SpecCarry(feats, tokens, jnp.ones((b,), jnp.int32))


def init_carry(cfg: ModelConfig, dcfg: ModelConfig, prefill_out,
               first_token, gamma: int = 3) -> SpecCarry:
    """Carry after target prefill: one pending pair — the capture of the
    last prompt position with the first sampled token."""
    return init_carry_from_caps(prefill_out["captures"][:, -1], first_token,
                                gamma)


def seed_draft_cache(cfg: ModelConfig, dcfg: ModelConfig, tparams, dparams,
                     dcache, prefill_out, prompt_tokens):
    """Draft 'prefill': ingest the prompt pairs (caps[i], t_{i+1}) for
    i < S-1 so the draft has full context before the first propose
    (delegates to the shared ``eagle.seed_prompt_pairs`` recipe)."""
    return eagle.seed_prompt_pairs(
        dcfg, dparams, tparams["embed"], dcache,
        prefill_out["captures"], prompt_tokens, dcache["pad"])


# ------------------------------------------------------------ fused step
def spec_decode_step(cfg: ModelConfig, dcfg: ModelConfig, tparams, dparams,
                     cache, dcache, carry: SpecCarry, *, gamma: int = 3,
                     greedy: bool = True, key=None, keys=None,
                     moe_impl: str = "sort"):
    """One full speculative serving step (paper Fig. 2 inner loop).

    1. draft-extend with the pairs committed last round (true features),
    2. chain-draft γ tokens from the last valid position,
    3. target verify block [t0, d1..dγ],
    4. accept, commit caches, emit training-signal captures.

    ``keys`` — optional (B,) per-lane key array; all sampling for lane b
    (draft picks, acceptance, resample) derives from ``keys[b]``, making
    sampled streams per-request deterministic regardless of batch
    composition.  ``key`` is the legacy batch-global scalar chain.

    Returns dict(tokens (B, γ+1) committed tokens (scratch beyond
    n_commit), n_commit (B,), cache, dcache, carry, captures, accept_mask).
    """
    b = carry.tokens.shape[0]
    if keys is not None:
        k_draft = jax.vmap(lambda k: jax.random.fold_in(k, 0))(keys)
        k_ver = jax.vmap(lambda k: jax.random.fold_in(k, 1))(keys)
    else:
        if key is None:
            key = jax.random.key(0)
        k_draft, k_ver = jax.random.split(key)

    # 1) draft catches up on everything committed last round
    ext_logits, ext_h, dcache = eagle.draft_extend(
        dcfg, dparams, tparams["embed"], dcache,
        carry.feats, carry.tokens, carry.advance)
    last = (carry.advance - 1)[:, None, None]
    h_last = jnp.take_along_axis(ext_h, last, axis=1)[:, 0]
    first_logits = jnp.take_along_axis(ext_logits, last, axis=1)[:, 0]

    # 2) chain-draft γ tokens
    draft_tokens, draft_logits, dcache = eagle.draft_propose(
        dcfg, dparams, tparams["embed"], dcache, h_last, first_logits,
        gamma, greedy=greedy,
        key=None if keys is not None else k_draft,
        keys=k_draft if keys is not None else None)

    # 3) target verify: t0 = last committed token (pair index advance-1)
    t0 = jnp.take_along_axis(carry.tokens, (carry.advance - 1)[:, None],
                             axis=1)
    block = jnp.concatenate([t0, draft_tokens], axis=1)
    out = T.decode_step(cfg, tparams, cache, block, moe_impl=moe_impl)
    tl = out["logits"]                                     # (B, γ+1, V)

    # 4) acceptance
    if greedy:
        n_acc, bonus = verify_greedy(tl, draft_tokens)
    elif keys is not None:
        n_acc, bonus = verify_sample(None, tl, draft_logits, draft_tokens,
                                     keys=k_ver)
    else:
        n_acc, bonus = verify_sample(k_ver, tl, draft_logits, draft_tokens)
    n_commit = n_acc + 1

    # commit target cache (per-request rollback for SSM states)
    cache = T.commit_cache(cfg, out["cache"], n_commit)
    # draft cache: roll the speculative lengths back (stale entries get
    # overwritten by next round's extend)
    dcache = eagle.reset_propose(dcache, gamma)

    # committed tokens this round: [d1..d_{n_acc}, bonus, scratch...]
    idx = jnp.arange(gamma + 1)[None, :]
    accept_mask = idx < n_commit[:, None]
    committed = jnp.where(idx < n_acc[:, None],
                          jnp.pad(draft_tokens, ((0, 0), (0, 1))),
                          bonus[:, None])
    committed = jnp.where(accept_mask, committed, 0)
    # next round's pending pairs: (caps[j], committed[j]) for j < n_commit
    caps = out["captures"]                                  # (B, γ+1, 3D)
    carry = SpecCarry(caps, committed, n_commit)

    return {"tokens": committed, "n_commit": n_commit, "cache": cache,
            "dcache": dcache, "carry": carry, "captures": caps,
            "accept_mask": accept_mask, "n_acc": n_acc, "block": block,
            "target_logits": tl}


def tree_decode_step(cfg: ModelConfig, dcfg: ModelConfig, tparams, dparams,
                     cache, dcache, carry: SpecCarry, *, gamma: int = 3,
                     width: int = 1, greedy: bool = True, key=None,
                     keys=None, moe_impl: str = "sort"):
    """One speculative serving step over a draft token *tree*.

    Identical contract to ``spec_decode_step`` — same carry/telemetry
    shapes (γ+1), same key discipline — but the draft proposes ``width``
    sibling chains sharing the root, the target scores all of them in
    one tree-masked verify pass (T = width*γ + 1 rows), acceptance
    walks the tree and keeps the longest root path, and only that
    path's K/V rows are compacted into chain order and committed
    (``compact_tree_cache``).  Captures/carry hold accepted-path
    features only, so SignalStore semantics are unchanged.  width == 1
    runs the chain computation op-for-op (bitwise parity pinned by
    tests/test_tree.py).

    Returns the ``spec_decode_step`` dict plus ``sel`` (winning
    branch); ``block`` is the full flattened tree block (B, T) and
    ``target_logits`` the path-gathered (B, γ+1, V) rows.
    """
    b = carry.tokens.shape[0]
    if keys is not None:
        k_draft = jax.vmap(lambda k: jax.random.fold_in(k, 0))(keys)
        k_ver = jax.vmap(lambda k: jax.random.fold_in(k, 1))(keys)
    else:
        if key is None:
            key = jax.random.key(0)
        k_draft, k_ver = jax.random.split(key)

    # 1) draft catches up on everything committed last round
    ext_logits, ext_h, dcache = eagle.draft_extend(
        dcfg, dparams, tparams["embed"], dcache,
        carry.feats, carry.tokens, carry.advance)
    last = (carry.advance - 1)[:, None, None]
    h_last = jnp.take_along_axis(ext_h, last, axis=1)[:, 0]
    first_logits = jnp.take_along_axis(ext_logits, last, axis=1)[:, 0]

    # 2) draft the token tree (branch 0 == the chain proposal)
    toks_tree, logits_tree, dcache = eagle.draft_propose_tree(
        dcfg, dparams, tparams["embed"], dcache, h_last, first_logits,
        gamma, width, greedy=greedy,
        key=None if keys is not None else k_draft,
        keys=k_draft if keys is not None else None)

    # 3) one tree-masked target pass over [t0, flat nodes]
    t0 = jnp.take_along_axis(carry.tokens, (carry.advance - 1)[:, None],
                             axis=1)
    block = jnp.concatenate([t0, toks_tree.reshape(b, width * gamma)],
                            axis=1)
    out = T.decode_step(cfg, tparams, cache, block, moe_impl=moe_impl,
                        tree=(width, gamma))
    tl = out["logits"]                                     # (B, T, V)

    # 4) tree acceptance: longest root path
    if greedy:
        n_acc, sel, bonus = verify_tree_greedy(tl, toks_tree)
    elif keys is not None:
        n_acc, sel, bonus = verify_tree_sample(None, tl, logits_tree,
                                               toks_tree, keys=k_ver)
    else:
        n_acc, sel, bonus = verify_tree_sample(k_ver, tl, logits_tree,
                                               toks_tree)
    n_commit = n_acc + 1

    # 5) compact the accepted path into chain slots, then commit
    cache = T.commit_cache(cfg, compact_tree_cache(out["cache"], sel,
                                                   gamma), n_commit)
    dcache = eagle.reset_propose(dcache, gamma)

    # 6) committed tokens / carry from the accepted path only
    path = tree_path_slots(sel, gamma)                     # (B, γ+1)
    tok_sel = jnp.take_along_axis(toks_tree, sel[:, None, None],
                                  axis=1)[:, 0]            # (B, γ)
    idx = jnp.arange(gamma + 1)[None, :]
    accept_mask = idx < n_commit[:, None]
    committed = jnp.where(idx < n_acc[:, None],
                          jnp.pad(tok_sel, ((0, 0), (0, 1))),
                          bonus[:, None])
    committed = jnp.where(accept_mask, committed, 0)
    caps = jnp.take_along_axis(out["captures"], path[..., None], axis=1)
    tl_path = jnp.take_along_axis(tl, path[..., None], axis=1)
    carry = SpecCarry(caps, committed, n_commit)

    return {"tokens": committed, "n_commit": n_commit, "cache": cache,
            "dcache": dcache, "carry": carry, "captures": caps,
            "accept_mask": accept_mask, "n_acc": n_acc, "sel": sel,
            "block": block, "target_logits": tl_path}


def plain_decode_step(cfg: ModelConfig, tparams, cache, carry_token, *,
                      greedy: bool = True, key=None, keys=None,
                      moe_impl: str = "sort"):
    """Baseline autoregressive step (speculation disabled — the TIDE
    Adaptive Drafter falls back to this when Eq. 5 predicts no gain).
    ``keys``: optional (B,) per-lane keys (see ``spec_decode_step``)."""
    out = T.decode_step(cfg, tparams, cache, carry_token[:, None],
                        moe_impl=moe_impl)
    logits = out["logits"][:, 0]
    if greedy:
        nxt = logits.argmax(-1).astype(jnp.int32)
    elif keys is not None:
        nxt = jax.vmap(jax.random.categorical)(keys, logits
                                               ).astype(jnp.int32)
    else:
        nxt = jax.random.categorical(key, logits).astype(jnp.int32)
    cache = T.commit_cache(cfg, out["cache"],
                           jnp.ones(carry_token.shape, jnp.int32))
    return {"token": nxt, "cache": cache, "captures": out["captures"],
            "logits": logits}


def plain_step_from_carry(cfg: ModelConfig, tparams, cache,
                          carry: SpecCarry, *, gamma: int = 3,
                          greedy: bool = True, key=None, keys=None,
                          moe_impl: str = "sort"):
    """Plain decode step driven by the spec carry (not a separate
    last-token variable): t0 is pair index ``advance-1`` of the carry, so
    the step is correct even directly after a speculative round (where a
    separately-tracked plain token would be stale).  Returns the same
    pytree layout as ``spec_decode_step`` so the two are `lax.cond`-
    compatible inside the fused superstep."""
    b, gp1 = carry.tokens.shape
    t0 = jnp.take_along_axis(carry.tokens, (carry.advance - 1)[:, None],
                             axis=1)[:, 0]
    out = plain_decode_step(cfg, tparams, cache, t0, greedy=greedy,
                            key=key, keys=keys, moe_impl=moe_impl)
    nxt, caps1 = out["token"], out["captures"]            # (B,), (B,1,3D)
    feats = jnp.zeros((b, gp1, caps1.shape[-1]), caps1.dtype
                      ).at[:, 0].set(caps1[:, 0])
    tokens = jnp.zeros((b, gp1), jnp.int32).at[:, 0].set(nxt)
    n_commit = jnp.ones((b,), jnp.int32)
    accept_mask = jnp.arange(gp1)[None, :] < n_commit[:, None]
    new_carry = SpecCarry(feats, tokens, n_commit)
    return {"tokens": tokens, "n_commit": n_commit, "cache": out["cache"],
            "carry": new_carry, "captures": feats,
            "accept_mask": accept_mask}


# ===================================================== fused superstep
class SuperstepState(NamedTuple):
    """Device-resident serving state threaded across fused supersteps.

    Everything the per-step host loop used to keep in Python lives here
    so K speculative rounds run inside one compiled function with zero
    host syncs.

    PRNG: ``key_data`` holds the engine's *base* key (constant — never
    split); lane b's sampling key for a round is
    ``fold_in(fold_in(base, sid[b]), step_idx[b])``, so a request's
    sampled stream depends only on its admission ordinal and per-request
    step count, never on batch composition or refill timing.

    ``cap_*`` (present only when deploy re-seeding is enabled) is a
    rolling per-lane ring of the (feature, token) pairs the draft cache
    ingested — enough to rebuild the last-window draft K/V rows under a
    freshly deployed draft (``eagle.reseed_draft_rows_from_ring``)."""
    carry: SpecCarry
    active: jnp.ndarray       # (B,) bool — request still generating
    gen_count: jnp.ndarray    # (B,) int32 — committed tokens (incl. first)
    accept_ema: jnp.ndarray   # () f32 — EMA of acceptance length E[l]
    key_data: jnp.ndarray     # raw base-key data (per-request streams)
    sid: jnp.ndarray          # (B,) int32 — sampling-stream id per lane
    step_idx: jnp.ndarray     # (B,) int32 — per-lane decode-step counter
    cap_feats: Optional[jnp.ndarray] = None   # (B, W, F) ring of pair feats
    cap_toks: Optional[jnp.ndarray] = None    # (B, W) ring of pair tokens
    cap_count: Optional[jnp.ndarray] = None   # (B,) pairs ingested


def init_superstep_state(carry: SpecCarry, first_token, key, *,
                         accept_ema: float = 1.0,
                         eos_id: Optional[int] = None,
                         active0=None, sids=None,
                         capture_window: int = 0) -> SuperstepState:
    """``active0`` (B,) bool masks slots that are born finished (inert
    padding of a partial wave, pre-finished requests); default all-on.
    ``sids``: per-lane sampling-stream ids (default ``arange(B)``);
    ``capture_window`` > 0 allocates the deploy-re-seed capture ring."""
    b = first_token.shape[0]
    active = jnp.ones((b,), bool) if active0 is None else \
        jnp.asarray(active0, bool)
    if eos_id is not None:
        active = active & (first_token != eos_id)
    sid = (jnp.arange(b, dtype=jnp.int32) if sids is None
           else jnp.asarray(sids, jnp.int32))
    ring = {}
    if capture_window:
        ring = dict(
            cap_feats=jnp.zeros((b, capture_window, carry.feats.shape[-1]),
                                carry.feats.dtype),
            cap_toks=jnp.zeros((b, capture_window), jnp.int32),
            cap_count=jnp.zeros((b,), jnp.int32))
    return SuperstepState(
        carry=carry, active=active,
        gen_count=jnp.ones((b,), jnp.int32),
        accept_ema=jnp.float32(accept_ema),
        # copy: the engine donates the state buffers per dispatch, and
        # the caller's key (a long-lived engine attribute) must survive
        key_data=jnp.array(jax.random.key_data(key)),
        sid=sid, step_idx=jnp.ones((b,), jnp.int32), **ring)


# ============================================== slot refill (continuous)
# the masked row-replace primitive lives in eagle (this module already
# depends on it); re-exported here for the target-cache/carry scatters
scatter_rows = eagle.scatter_batch_rows


def pad_target_cache(cache, ref):
    """Zero-pad a staging prefill cache (allocated at the refill's
    padded prompt width) out to the live cache geometry described by the
    abstract pytree ``ref`` (``transformer.cache_abstract``).

    The chunked-refill pipeline keeps its staging cache at prompt width
    so continuation chunks attend over exactly the key width the
    one-shot prefill reduces over — attention reductions are *not*
    bitwise stable across buffer widths once enough keys are live, so
    attending over a max_len buffer mid-prefill would break the
    chunked == one-shot byte-parity invariant.  The pad to max_len
    happens here, at commit time, exactly where the one-shot path's
    ``_place`` pads — zero padding is exact.

    Paged path: pass ``ref=None`` — a paged commit writes the staging
    rows *through* the block table (``scatter_target_cache_paged``), so
    repadding the staging to max_len would be a pure wasted copy; this
    is an explicit no-op passthrough instead of a silent full-width
    repad.  On the dense path the shapes are asserted: staging must be
    elementwise coverable by the live geometry."""
    if ref is None:
        return cache

    def pad(leaf, r):
        if leaf.ndim != len(r.shape) or any(
                ls > rs for ls, rs in zip(leaf.shape, r.shape)):
            raise ValueError(
                f"staging leaf {leaf.shape} does not embed in live "
                f"cache geometry {r.shape}")
        pads = [(0, rs - ls) for ls, rs in zip(leaf.shape, r.shape)]
        if any(hi for _, hi in pads):
            return jnp.pad(leaf, pads)
        return leaf

    return jax.tree.map(pad, cache, ref)


def scatter_target_cache(cache, new, mask, src):
    """Replace the masked batch lanes of a live target decode cache with
    lanes from a freshly prefilled cache (same max_len).  ``lengths`` /
    ``pad`` carry batch at axis 0; stacked layer-group leaves at axis 1
    (leaves are (repeats, B, ...))."""
    out = {}
    for k, v in cache.items():
        if k in ("lengths", "pad"):
            out[k] = scatter_rows(v, new[k], mask, src, axis=0)
        else:
            out[k] = jax.tree.map(
                lambda l, n: scatter_rows(l, n, mask, src, axis=1),
                v, new[k])
    return out


def scatter_target_cache_paged(cache, new, mask, src):
    """Paged twin of ``scatter_target_cache``: ``cache`` is a paged live
    cache (page-pool leaves (repeats, num_pages + 1, P, Hk, D) plus the
    shared ``page_tbl``), ``new`` is a dense staging prefill cache with
    leaves (repeats, R, W, Hk, D).  Masked lanes' rows are written
    through the block table (the allocator has already mapped their
    reservations; positions past a lane's reservation route to the
    trash page exactly like dense scatter's dropped OOB writes);
    unmasked lanes write nothing (trash-routed)."""
    from repro.core import paging
    tbl = cache["page_tbl"]

    def write(pool, staged):
        rows = jnp.take(staged, src, axis=1)        # (repeats, B, W, ...)
        return jax.vmap(
            lambda p, r: paging.write_rows_paged(p, tbl, r, mask)
        )(pool, rows)

    out = {}
    for k, v in cache.items():
        if k in ("lengths", "pad"):
            out[k] = scatter_rows(v, new[k], mask, src, axis=0)
        elif k == "page_tbl":
            out[k] = v
        else:
            out[k] = jax.tree.map(write, v, new[k])
    return out


def scatter_carry(live: SpecCarry, new: SpecCarry, mask, src) -> SpecCarry:
    """Replace the masked lanes of the spec carry (batch at axis 0)."""
    return SpecCarry(*(scatter_rows(l, n, mask, src, axis=0)
                       for l, n in zip(live, new)))


def refill_superstep_state(state: SuperstepState, carry_new: SpecCarry,
                           first_token, budgets, mask, src, *,
                           eos_id: Optional[int] = None,
                           sids=None) -> SuperstepState:
    """Reset the masked slots of the superstep state for freshly admitted
    requests: carry ← prefill carry, gen_count ← 1 (the first sampled
    token), active ← alive unless first token is EOS or the budget is
    zero, sampling stream ← (sid, step 1), capture ring ← empty.  The
    acceptance EMA and the base PRNG key are engine-global and pass
    through untouched."""
    carry = scatter_carry(state.carry, carry_new, mask, src)
    alive = budgets >= 1
    if eos_id is not None:
        alive = alive & (first_token != eos_id)
    active = jnp.where(mask, jnp.take(alive, src), state.active)
    gen_count = jnp.where(mask, 1, state.gen_count)
    repl = dict(carry=carry, active=active, gen_count=gen_count,
                step_idx=jnp.where(mask, 1, state.step_idx))
    if sids is not None:
        repl["sid"] = jnp.where(mask, jnp.take(jnp.asarray(sids, jnp.int32),
                                               src), state.sid)
    if state.cap_count is not None:
        # ring content is garbage once count resets — never gathered
        repl["cap_count"] = jnp.where(mask, 0, state.cap_count)
    return state._replace(**repl)


def decode_superstep(cfg: ModelConfig, dcfg: ModelConfig, tparams, dparams,
                     cache, dcache, state: SuperstepState, max_new,
                     threshold_table=None, *, rounds: int = 8,
                     gamma: int = 3, greedy: bool = True,
                     ema_decay: float = 0.9, eos_id: Optional[int] = None,
                     collect_signals: bool = True, moe_impl: str = "sort",
                     tree_width: int = 0):
    """K speculative rounds fused into one compiled function.

    ``tree_width`` >= 1 swaps the speculative arm for
    ``tree_decode_step`` (a ``tree_width``-branch token tree verified in
    one tree-masked pass) — carry, telemetry and signal shapes are all
    γ+1 either way, so nothing downstream changes; 0 is the linear
    chain.

    ``lax.scan`` over ``rounds``; each round
      1. decides speculate-vs-plain in-graph (Eq. 5 threshold table +
         acceptance-EMA, ``lax.cond``) — no host round-trip,
      2. runs the selected step (``spec_decode_step`` or
         ``plain_step_from_carry``),
      3. commits tokens on device: per-request max-token clamp, optional
         EOS cut, active-mask update,
      4. compacts accepted-position training signals with the
         ``extract_pack`` kernel so one (counts, feats, tokens) buffer
         per round crosses to the host per *superstep*, not per step.

    Rounds after all requests finish are skipped via an outer
    ``lax.cond`` (state, caches and the PRNG chain pass through
    untouched, so host-side key accounting matches the per-step loop).

    max_new: (B,) int32 per-request budgets; threshold_table: (B+1,) f32
    from ``adaptive.accept_threshold_table`` or None (always speculate).
    Returns dict(cache, dcache, state, rounds) where ``rounds`` holds
    (K, ...)-stacked per-round telemetry + packed signal buffers.
    """
    from repro.kernels.extract_pack.ops import pack_signals

    gp1 = gamma + 1

    def _round(carry_in, _):
        cache, dcache, st = carry_in

        def _skip(op):
            cache, dcache, st = op
            b = st.active.shape[0]
            f = st.carry.feats.shape[-1]
            ys = {
                "tokens": jnp.zeros((b, gp1), jnp.int32),
                "n_commit": jnp.zeros((b,), jnp.int32),
                "n_eff": jnp.zeros((b,), jnp.int32),
                "active_after": st.active,
                "use_spec": jnp.bool_(False),
                "alpha": jnp.float32(0.0),
                "ell": jnp.float32(0.0),
                "n_sig": jnp.int32(0),
                "ema": st.accept_ema,
            }
            if collect_signals:
                ys["sig_feats"] = jnp.zeros((b, gp1, f), st.carry.feats.dtype)
                ys["sig_tokens"] = jnp.zeros((b, gp1), jnp.int32)
                ys["sig_counts"] = jnp.zeros((b,), jnp.int32)
            return (cache, dcache, st), ys

        def _run(op):
            cache, dcache, st = op
            n_active = st.active.sum().astype(jnp.int32)
            if greedy:
                keys = None
            else:
                # per-request streams: fold the constant base key by
                # (sid, per-lane step counter) — identical to the
                # per-step loop's host-side derivation, bit for bit
                base = jax.random.wrap_key_data(st.key_data)
                keys = jax.vmap(
                    lambda s, c: jax.random.fold_in(
                        jax.random.fold_in(base, s), c))(st.sid,
                                                         st.step_idx)

            def _spec(args):
                cache, dcache, carry = args
                if tree_width:
                    out = tree_decode_step(cfg, dcfg, tparams, dparams,
                                           cache, dcache, carry,
                                           gamma=gamma, width=tree_width,
                                           greedy=greedy, keys=keys,
                                           moe_impl=moe_impl)
                else:
                    out = spec_decode_step(cfg, dcfg, tparams, dparams,
                                           cache, dcache, carry,
                                           gamma=gamma, greedy=greedy,
                                           keys=keys, moe_impl=moe_impl)
                return (out["cache"], out["dcache"], out["carry"],
                        out["tokens"], out["n_commit"], out["captures"],
                        out["accept_mask"])

            def _plain(args):
                cache, dcache, carry = args
                out = plain_step_from_carry(cfg, tparams, cache, carry,
                                            gamma=gamma, greedy=greedy,
                                            keys=keys, moe_impl=moe_impl)
                return (out["cache"], dcache, out["carry"], out["tokens"],
                        out["n_commit"], out["captures"],
                        out["accept_mask"])

            if threshold_table is None:
                use_spec = jnp.bool_(True)
                sel = _spec((cache, dcache, st.carry))
            else:
                from repro.core.adaptive import drafter_decide
                use_spec = drafter_decide(threshold_table, n_active,
                                          st.accept_ema)
                sel = jax.lax.cond(use_spec, _spec, _plain,
                                   (cache, dcache, st.carry))
            cache, dcache, carry, tokens, n_commit, captures, accept_mask \
                = sel

            # rolling capture ring (deploy re-seed): mirror the pairs the
            # draft cache just ingested (spec rounds run draft_extend on
            # the previous round's carry; plain rounds ingest nothing)
            cap_feats, cap_toks, cap_count = (st.cap_feats, st.cap_toks,
                                              st.cap_count)
            if cap_feats is not None:
                w = cap_toks.shape[1]
                bsz = cap_toks.shape[0]
                adv = jnp.where(use_spec, st.carry.advance, 0)
                j = jnp.arange(gp1)[None, :]
                slot = (cap_count[:, None] + j) % w
                slot = jnp.where(j < adv[:, None], slot, w)  # OOB → drop
                bidx = jnp.arange(bsz)[:, None]
                cap_feats = cap_feats.at[bidx, slot].set(
                    st.carry.feats.astype(cap_feats.dtype), mode="drop")
                cap_toks = cap_toks.at[bidx, slot].set(
                    st.carry.tokens, mode="drop")
                cap_count = cap_count + adv

            act = st.active
            n_act_f = jnp.maximum(n_active.astype(jnp.float32), 1.0)
            ncf = n_commit.astype(jnp.float32)
            ell = jnp.where(act, ncf, 0.0).sum() / n_act_f
            alpha = jnp.where(act, ncf - 1.0, 0.0).sum() / n_act_f / gamma
            # EMA tracks acceptance of *speculative* rounds only (a plain
            # round's l=1 carries no draft-quality information)
            ema = jnp.where(use_spec,
                            ema_decay * st.accept_ema
                            + (1.0 - ema_decay) * ell,
                            st.accept_ema)

            remaining = jnp.maximum(max_new - st.gen_count, 0)
            n_eff = jnp.where(act, jnp.minimum(n_commit, remaining), 0)
            if eos_id is not None:
                pos = jnp.arange(gp1)[None, :]
                is_eos = (tokens == eos_id) & (pos < n_eff[:, None])
                has_eos = is_eos.any(axis=1)
                n_eff = jnp.where(has_eos, is_eos.argmax(axis=1) + 1, n_eff)
            else:
                has_eos = jnp.zeros_like(act)
            gen_new = st.gen_count + n_eff
            active_after = act & (gen_new < max_new) & ~has_eos
            n_sig = jnp.where(active_after, n_commit, 0).sum()

            ys = {"tokens": tokens, "n_commit": n_commit, "n_eff": n_eff,
                  "active_after": active_after, "use_spec": use_spec,
                  "alpha": alpha, "ell": ell,
                  "n_sig": n_sig.astype(jnp.int32), "ema": ema}
            if collect_signals:
                # only tokens actually kept (post EOS/budget cut) become
                # training signals — never continuations past the end
                sig_mask = jnp.arange(gp1)[None, :] < n_eff[:, None]
                pf, pt, cnt = pack_signals(captures, tokens, sig_mask)
                ys["sig_feats"], ys["sig_tokens"], ys["sig_counts"] = \
                    pf, pt, cnt
            st = SuperstepState(carry, active_after, gen_new, ema,
                                st.key_data, st.sid,
                                jnp.where(st.active, st.step_idx + 1,
                                          st.step_idx),
                                cap_feats, cap_toks, cap_count)
            return (cache, dcache, st), ys

        valid = st.active.any()
        (cache, dcache, st), ys = jax.lax.cond(valid, _run, _skip,
                                               (cache, dcache, st))
        ys["valid"] = valid
        return (cache, dcache, st), ys

    (cache, dcache, state), rounds_out = jax.lax.scan(
        _round, (cache, dcache, state), None, length=rounds)
    return {"cache": cache, "dcache": dcache, "state": state,
            "rounds": rounds_out}
