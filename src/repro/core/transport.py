"""Signal transport between the serving and training engines.

The decoupled training service (``training/service.py``) consumes
training signals *off the serving path*.  The ``SignalChannel`` is the
seam: the serving engine's superstep unpack pushes packed
``SignalBatch`` windows into a bounded, drop-oldest ring; the training
service blocks on the other end.  Dropping oldest under backpressure is
the correct policy for online adaptation — a slow trainer should see
the *freshest* distribution, and serving must never block on training
(TIDE's decoupling contribution).

Placement: on a single-device host the channel is a host ring buffer
and the trainer interleaves as a background thread (jitted train steps
release the GIL, so train compute overlaps serving host work at
superstep boundaries).  When the local jax platform exposes more than
one device, ``pick_training_device`` carves a training submesh with the
``core/hetero`` allocation model and the channel ``device_put``s each
batch onto the trainer's device as it is enqueued — the copy happens
asynchronously, off the serving path, and the train loop never touches
serving-device memory.  When the trainer lives in another *process*
(``repro.fleet``), ``RemoteSignalChannel`` subclasses this channel: the
same bounded drop-oldest ring becomes the socket send queue (the
``_prepare`` hook skips device placement) and a sender thread frames
batches over the wire, so the serving-path contract — never block,
never sync — is identical in-process and out.
"""
from __future__ import annotations

import threading
from typing import Optional

from repro.core.signals import SignalBatch, SignalStore


def pick_training_device(s: float = 1.2):
    """Place the draft trainer: carve a training submesh out of the
    local device set with the paper's allocation model
    (``hetero.plan_tpu_submesh``), or return None on a single-device
    host (→ background-thread interleaving).  ``s`` is the speculative
    speedup assumed unlocked by online training (paper Fig. 12)."""
    import jax

    from repro.core.hetero import plan_tpu_submesh

    devs = jax.devices()
    if len(devs) < 2:
        return None
    plan = plan_tpu_submesh(len(devs), s)
    n_train = max(plan.train_chips, 1)   # ≥1 chip once we decide to train
    return devs[len(devs) - n_train]


class SignalChannel(SignalStore):
    """Bounded drop-oldest channel from the signal extractor to the
    training service.

    Duck-types ``SignalStore`` (``add``/``drain``/``peek_count``) so the
    ``SignalExtractor`` writes into it unchanged, and adds: a capacity
    bound with drop-oldest semantics + drop accounting (backpressure
    stats), a condition variable so a consumer can block for samples
    (``wait``), optional producer-side ``device_put`` onto the trainer's
    device, and ``close`` to wake blocked consumers at shutdown."""

    def __init__(self, capacity: int = 512, device=None,
                 spill_dir: Optional[str] = None):
        super().__init__(spill_dir=spill_dir, max_samples=capacity)
        self.capacity = capacity
        self.device = device
        self.dropped = 0
        self.rejected_after_close = 0
        self.closed = False
        self._cond = threading.Condition(self._lock)

    # ------------------------------------------------------------- produce
    def _prepare(self, batch: SignalBatch) -> SignalBatch:
        """Producer-side placement hook, run outside the lock.  The base
        channel ``device_put``s onto the trainer's device (async enqueue
        — the serving thread never blocks on the copy); subclasses
        override to stage for other transports (e.g. the fleet's
        ``RemoteSignalChannel`` keeps batches as host arrays for the
        socket sender)."""
        if self.device is None:
            return batch
        import jax
        return SignalBatch(
            feats=jax.device_put(batch.feats, self.device),
            tokens=jax.device_put(batch.tokens, self.device))

    def add(self, batch: SignalBatch):
        if self.closed:
            # a closed channel has no consumer left — buffering would
            # grow a ring nobody drains.  Drop-and-count so a straggling
            # producer (e.g. a superstep unpacked after shutdown) is
            # visible in stats() instead of silently retained.
            with self._cond:
                self.rejected_after_close += 1
            return
        batch = self._prepare(batch)
        with self._cond:
            if self.closed:   # close() raced the device_put above
                self.rejected_after_close += 1
                return
            self._buf.append(batch)
            self.total_added += 1
            self.total_bytes += batch.feats.nbytes + batch.tokens.nbytes
            while len(self._buf) > self.capacity:
                self._buf.pop(0)
                self.dropped += 1
            self._cond.notify_all()

    # ------------------------------------------------------------- consume
    def drain(self, n=None):
        """Pop up to ``n`` (default: all) buffered batches.  On a closed
        channel this is deterministic: ``add`` rejects post-``close``
        writes, so the drained set is exactly the batches buffered
        before ``close`` — one final drain empties the channel and every
        later drain returns ``[]``."""
        return super().drain(n)

    def wait(self, min_count: int = 1,
             timeout: Optional[float] = None) -> int:
        """Block until at least ``min_count`` batches are buffered, the
        channel is closed, or ``timeout`` elapses.  Returns the buffered
        count at wake-up."""
        with self._cond:
            self._cond.wait_for(
                lambda: self.closed or len(self._buf) >= min_count,
                timeout=timeout)
            return len(self._buf)

    def close(self):
        with self._cond:
            self.closed = True
            self._cond.notify_all()

    def reset(self):
        """Back to the post-construction state: empty buffer, zeroed
        push/drop/byte counters (``closed`` is preserved)."""
        with self._cond:
            self._buf.clear()
            self.total_added = 0
            self.total_bytes = 0
            self.dropped = 0
            self.rejected_after_close = 0

    # --------------------------------------------------------------- stats
    @property
    def depth(self) -> int:
        return self.peek_count()

    def stats(self) -> dict:
        return {"pushed": self.total_added, "dropped": self.dropped,
                "depth": self.peek_count(), "bytes": self.total_bytes,
                "rejected_after_close": self.rejected_after_close}

    def register_metrics(self, registry):
        """Expose the channel under the ``train.*`` metrics namespace as
        callback gauges (evaluated at snapshot time only — recording
        adds nothing to the push/drain paths)."""
        registry.gauge("train.signals_pushed", fn=lambda: self.total_added)
        registry.gauge("train.signals_dropped", fn=lambda: self.dropped)
        registry.gauge("train.signals_rejected",
                       fn=lambda: self.rejected_after_close)
        registry.gauge("train.signal_bytes", fn=lambda: self.total_bytes)
        registry.gauge("train.channel_depth", fn=self.peek_count)
