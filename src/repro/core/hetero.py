"""Heterogeneous resource allocation model (paper §5.5, Figs. 10–12).

TIDE decouples inference serving from draft training and maps them to
different accelerator classes.  This module captures the decision problem:
given per-class inference/training throughput ratios and the speculative
speedup *s* unlocked by draft training, should low-end devices train the
draft or serve?  It reproduces the paper's GPU numbers and adds TPU
presets (the TPU-native analogue is disjoint submesh allocation —
DESIGN.md §2.1).

This model is now *live*, not just analytical:
``core.transport.pick_training_device`` calls ``plan_tpu_submesh`` over
the local jax device set to place the decoupled training service
(``training/service.py``) on its own device(s), falling back to
background-thread interleaving on single-device hosts.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List


@dataclasses.dataclass(frozen=True)
class DeviceClass:
    name: str
    # throughput relative to the reference class (paper Fig. 11:
    # normalized to MI250)
    inference: float
    training: float


# Paper Fig. 11 measurements (normalized to MI250).
PAPER_DEVICES = {
    "MI250": DeviceClass("MI250", 1.0, 1.0),
    "MI300X": DeviceClass("MI300X", 4.42, 1.77),
    "H100": DeviceClass("H100", 6.76, 2.44),
}

# TPU preset: v5e as the low class; v5p-class chip as the high class.
# Inference gap ≈ HBM-bandwidth ratio (2765/819 ≈ 3.4); training gap ≈
# bf16-FLOPs ratio (459/197 ≈ 2.3) — same disproportionality the paper
# exploits (decode is bandwidth-bound, training is compute-bound).
TPU_DEVICES = {
    "v5e": DeviceClass("v5e", 1.0, 1.0),
    "v5p": DeviceClass("v5p", 3.38, 2.33),
}


def relative_throughput(high: DeviceClass, low: DeviceClass,
                        n_high: int, n_low: int, s: float) -> float:
    """Fig. 12 model: relative throughput of TIDE's split (high GPUs serve
    with speculative speedup s, low GPUs train) vs. the all-inference
    baseline (everything serves, no speculation).

    baseline  = n_high·I_high + n_low·I_low
    tide      = n_high·I_high·s          (low class is busy training)
    """
    baseline = n_high * high.inference + n_low * low.inference
    tide = n_high * high.inference * s
    return tide / baseline


def best_split(high: DeviceClass, low: DeviceClass, n_high: int, n_low: int,
               s: float) -> Dict:
    """Compare TIDE's split against all-inference; the paper's decision."""
    rel = relative_throughput(high, low, n_high, n_low, s)
    return {
        "relative_throughput": rel,
        "use_tide": rel > 1.0,
        "config": f"{high.name}:{low.name} ({n_high}:{n_low})",
        "s": s,
    }


def paper_figure12_grid() -> List[Dict]:
    """All configurations evaluated in paper Fig. 12."""
    out = []
    for hi, lo, nh, nl in [("H100", "MI250", 4, 1), ("H100", "MI250", 2, 1),
                           ("MI300X", "MI250", 4, 1), ("MI300X", "MI250", 2, 1)]:
        for s in (1.1, 1.2, 1.3):
            out.append(best_split(PAPER_DEVICES[hi], PAPER_DEVICES[lo],
                                  nh, nl, s))
    return out


@dataclasses.dataclass(frozen=True)
class SubmeshPlan:
    """TPU-native deployment: carve a training submesh out of the pod."""
    serve_chips: int
    train_chips: int
    s: float                   # speculative speedup from online adaptation

    def relative_throughput(self) -> float:
        total = self.serve_chips + self.train_chips
        return (self.serve_chips * self.s) / total


def plan_tpu_submesh(total_chips: int, s: float,
                     train_fraction_grid=(0.0, 1 / 64, 1 / 32, 1 / 16, 1 / 8)
                     ) -> SubmeshPlan:
    """Pick the training submesh size maximizing serving throughput.
    The draft is 1 layer — a few chips suffice (paper uses 4 MI250s of a
    12-GPU total); fractions beyond 1/8 never pay off."""
    best = None
    for f in train_fraction_grid:
        tc = max(int(total_chips * f), 0) if f else 0
        eff_s = s if tc > 0 else 1.0     # no training -> draft goes stale
        plan = SubmeshPlan(total_chips - tc, tc, eff_s)
        if best is None or plan.relative_throughput() > \
                best.relative_throughput():
            best = plan
    return best
