"""TIDE system orchestrator (paper Fig. 1): wires the Inference Serving
Engine, Training Signal Extractor, Acceptance Length Monitor, Adaptive
Drafter, and Draft Model Training Engine into the full adaptive loop.

On real hardware the two engines live on disjoint device sets (serving
submesh / training submesh — DESIGN.md §2.1); in this CPU container the
trainer runs interleaved between serving waves, which preserves every
control decision of the paper (the asynchrony is an interface property:
the serving engine never blocks on training, it just receives deploys).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import DraftDeployGate
from repro.core import eagle
from repro.core.adaptive import AdaptiveDrafter, LatencyProfile
from repro.core.controller import Decision, TrainingController
from repro.core.signals import SignalExtractor, SignalStore
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving.engine import ServingEngine
from repro.serving.request import Request
from repro.training.draft_trainer import DraftTrainer


@dataclasses.dataclass
class TideConfig:
    gamma: int = 3
    batch_size: int = 4
    max_len: int = 160
    greedy: bool = True
    adaptive_spec: bool = True        # False = TIDE-default (paper §5.4)
    selective_training: bool = True
    signal_window: int = 24
    n_threshold: int = 96             # samples per training cycle (tiny scale)
    train_epochs: int = 2
    seed: int = 0


class TideSystem:
    def __init__(self, cfg: ModelConfig, params, tide_cfg: TideConfig,
                 profile: Optional[LatencyProfile] = None,
                 dparams=None):
        self.cfg = cfg
        self.tcfg = tide_cfg
        self.dcfg = eagle.draft_config(cfg)
        if dparams is None:
            dparams = eagle.draft_init(self.dcfg,
                                       jax.random.key(tide_cfg.seed + 7))
        self.store = SignalStore()
        self.extractor = SignalExtractor(self.store,
                                         window=tide_cfg.signal_window)
        self.controller = TrainingController(
            n_threshold=tide_cfg.n_threshold * tide_cfg.signal_window,
            n_init=4)
        drafter = None
        if tide_cfg.adaptive_spec and profile is not None:
            drafter = AdaptiveDrafter(profile, gamma=tide_cfg.gamma)
        self.engine = ServingEngine(
            cfg, params, self.dcfg, dparams, gamma=tide_cfg.gamma,
            max_len=tide_cfg.max_len, batch_size=tide_cfg.batch_size,
            greedy=tide_cfg.greedy, drafter=drafter,
            controller=self.controller if tide_cfg.selective_training
            else None,
            extractor=self.extractor, seed=tide_cfg.seed)
        self.trainer = DraftTrainer(cfg, self.dcfg, params["embed"])
        self.gate = DraftDeployGate(dparams)
        self.events: List[Dict] = []
        # start in collection mode so the cold draft trains immediately
        self.controller.collection_enabled = True

    # ----------------------------------------------------------- training
    def _maybe_train(self):
        need = self.store.peek_count() * self.tcfg.signal_window
        if need < self.controller.n_threshold:
            return
        batches = self.store.drain()
        baseline = self.controller.alpha_train
        dparams, _ = self.gate.current()
        result = self.trainer.train_cycle(dparams, batches,
                                          epochs=self.tcfg.train_epochs,
                                          seed=self.tcfg.seed)
        deployed = self.gate.offer(result["dparams"], result["eval_acc"],
                                   baseline)
        if self.tcfg.selective_training:
            self.controller.training_result(result["eval_acc"])
        if deployed:
            self.engine.deploy_draft(result["dparams"])
        self.events.append({
            "kind": "train_cycle", "eval_acc": result["eval_acc"],
            "train_acc": result["train_acc"], "baseline": baseline,
            "deployed": deployed, "steps": result["steps"],
            "seconds": result["seconds"],
            "engine_steps": self.engine.stats.steps,
        })

    # ------------------------------------------------------------ serving
    def run(self, waves: Iterable[List], max_new_tokens: int = 48
            ) -> List[Request]:
        """Serve a workload stream (already grouped into waves of
        (domain, prompt) pairs). Returns all completed requests."""
        done: List[Request] = []
        for wave in waves:
            reqs = [Request(prompt=p, domain=d,
                            max_new_tokens=max_new_tokens)
                    for d, p in wave]
            self.engine.serve_wave(reqs)
            done.extend(reqs)
            self._maybe_train()
        return done

    def run_stream(self, requests: Iterable[Request]) -> List[Request]:
        """Serve a request stream with continuous batching: the engine
        keeps its device state resident and refills slots in-flight;
        the training engine is polled at request-completion boundaries,
        so a passing draft hot-swaps in mid-stream (C2) instead of
        waiting for a wave boundary."""
        return self.engine.serve_stream(
            requests, on_complete=lambda _r: self._maybe_train())

    def requests_from_trace(self, trace) -> List[Request]:
        """Materialize ``data.workloads.ArrivalEvent`` records as engine
        requests.  Arrival *order* is preserved; arrival *times* are
        not replayed — every request's ``arrival_t`` is its
        materialization time, so the trace is served as a backlog and
        the reported TTFT/latency measure queueing + drain from stream
        start, not wall-clock arrival-relative latency (arrival-time
        gating is a ROADMAP open item; ``ArrivalEvent.t`` is retained
        for it)."""
        return [Request(prompt=ev.prompt, domain=ev.domain,
                        max_new_tokens=ev.max_new_tokens)
                for ev in trace]

    # ------------------------------------------------------------- stats
    def summary(self) -> Dict:
        st = self.engine.stats
        return {
            "tokens": st.tokens_out,
            "throughput_tok_s": st.throughput,
            "accept_len": st.accept_len,
            "steps": st.steps,
            "spec_steps": st.spec_steps,
            "refills": st.refills,
            "occupancy": st.occupancy,
            "ttft_p50_s": st.ttft_p50,
            "latency_p95_s": st.latency_p95,
            "train_cycles": len([e for e in self.events
                                 if e["kind"] == "train_cycle"]),
            "deployed": self.gate.version,
            "signals_collected": self.store.total_added,
            "signal_bytes": self.store.total_bytes,
        }
