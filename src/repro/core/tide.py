"""TIDE system orchestrator (paper Fig. 1): wires the Inference Serving
Engine, Training Signal Extractor, Acceptance Length Monitor, Adaptive
Drafter, and Draft Model Training Engine into the full adaptive loop.

Decoupled architecture (paper §3.3/§5.5): serving and training are
separate engines joined by two one-way, never-blocking seams —

  * **signals out**: the engine's superstep unpack pushes packed
    hidden-state windows into a bounded drop-oldest
    ``core.transport.SignalChannel`` (backpressure drops oldest, never
    stalls serving);
  * **drafts in**: the ``training.service.TrainingService`` runs
    ``DraftTrainer.train_cycle`` off-path — on its own device/submesh
    when the host has one (``transport.pick_training_device``), else on
    a background thread whose jitted train steps release the GIL and
    fill superstep-boundary + arrival-gap slack — and publishes each
    gate-accepted draft as a versioned ``DraftVersion`` into a
    lock-free deploy slot that the engine polls once per superstep
    (zero extra host↔device syncs; resident lanes' draft caches are
    re-seeded in place from the rolling capture ring).

Two training modes: ``async_train=False`` (default) calls
``service.drain()`` at request-completion boundaries — blocking, fully
deterministic, byte-compatible with the legacy synchronous scheduler —
while ``async_train=True`` starts the background loop and serving never
waits on training.  Every control decision of the paper (Algorithm 1
collection gating, deploy-if-improved) is identical in both modes; the
asynchrony is an interface property.

Disaggregation (``TideConfig(fleet=FleetConfig(...))``, repro/fleet,
docs/disaggregation.md): with ``fleet.trainer_endpoint`` set, the same
two seams cross a *process* boundary — signals flow through a
``RemoteSignalChannel`` (identical bounded drop-oldest ring, drained
onto a socket off-path) to a ``TrainingService`` running in its own
process on its own XLA client (``repro.fleet.trainer_main``), and
published drafts come back as wire frames into the same lock-free
deploy slot the engine already polls.  Both training modes survive the
move: sync mode's ``drain()`` becomes a wire barrier whose ack is
ordered after every DRAFT frame it caused (byte-identical streams),
async mode stays zero-sync.  Trainer death degrades serving to the
last published draft (``summary()['trainer_failures']``), never a
hang.  ``fleet.replicas > 0`` scales out to a data-parallel engine
fleet behind a draft-version bus + front-end router — that topology
is served by ``repro.fleet.router.ServingFleet``; TideSystem itself
stays single-engine.

Serving control plane: all runtime scheduling decisions (admission
order, chunk-pipeline commit, the Eq. 5 speculate-vs-plain gate and
its park/resume control) are delegated to a composed
``serving.policy.ServingPolicy`` built from the unified
``ServingConfig`` — ``TideConfig(serving=ServingConfig(...))`` is the
one place to select FIFO/priority/EDF admission, cohort/eager commit,
speculation parking, chunked prefill, arrival gating, and the trainer
thread-contention cap (``trainer_threads``); the flat legacy
``TideConfig`` fields remain as a convenience/back-compat layer.

Memory scale: ``page_size``/``num_pages``/``share_prefix`` switch the
engine's per-lane dense KV caches to the paged memory model
(``core.paging``): fixed-size page pools behind per-lane block tables,
admission-time page reservations (slot count bounded by HBM actually
used, not ``batch x max_len``), and provenance-keyed copy-on-write
sharing of committed prompt-prefix pages across lanes — byte-identical
streams to dense serving, pinned in tests/test_paged.py.

Tree speculation: ``tree_width`` >= 1 swaps the linear gamma-chain
draft for a token tree — width top-k first continuations each extended
to a gamma-deep branch, flattened branch-major into one fixed
``width * gamma + 1``-row block (slot 0 = the committed token, branch
r's depth-j node at ``1 + r*gamma + (j-1)``) and verified in a single
tree-masked target pass.  The acceptance rule walks every branch and
commits the longest accepted root path; the commit compacts that
branch's K/V rows into the chain layout, so caches, telemetry, and
signal capture (accepted-path features only) keep their chain shapes
— the training loop and SignalStore semantics are unchanged.
``tree_width=1`` is bitwise identical to the chain engine
(tests/test_tree.py); the shape is carried by the SpeculationPolicy,
the seam a learned speculation controller would tune it through.

Observability: the system owns one ``repro.obs`` instrument set shared
by every component — a ``MetricsRegistry`` (``self.metrics``) whose
``serving.* / train.* / paging.* / spec.*`` namespaces are fed by the
engine's ServingStats, the training service/channel, the page
allocator, and the speculation policy (``summary()`` remains a thin
view over the same registry state); plus an optional span tracer and
per-request flight recorder built from ``TideConfig.obs``
(``ObsConfig``) and handed to the engine/service as collaborators.
All hooks are host-side at existing telemetry boundaries — superstep
unpack, admission, trainer publish, deploy poll — so observability-on
serving adds **zero** device syncs and observability-off is
byte-identical (nulls; gated in benchmarks/bench_hotloop.py).  See
docs/observability.md.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional

import jax

from repro.checkpoint.ckpt import DraftDeployGate
from repro.core import eagle
from repro.core.adaptive import AdaptiveDrafter, LatencyProfile
from repro.core.controller import TrainingController
from repro.core.signals import SignalExtractor
from repro.core.transport import SignalChannel, pick_training_device
from repro.fleet import FleetConfig
from repro.models.config import ModelConfig
from repro.obs import ObsConfig
from repro.obs.metrics import MetricsRegistry
from repro.serving.engine import ServingEngine
from repro.serving.policy import ServingConfig
from repro.serving.request import Request
from repro.training.draft_trainer import DraftTrainer
from repro.training.service import TrainingService


@dataclasses.dataclass
class TideConfig:
    """System configuration.  Serving knobs live in one unified
    ``serving.policy.ServingConfig`` (``TideConfig(serving=...)``); the
    flat legacy fields remain as a convenience layer — when ``serving``
    is omitted they assemble one, when it is given they mirror its
    values so legacy readers keep working."""
    gamma: int = 3
    batch_size: int = 4
    max_len: int = 160
    greedy: bool = True
    superstep_rounds: int = 8         # 0 = per-step reference loop
    eos_id: Optional[int] = None
    ema: float = 0.9                  # acceptance-EMA decay
    tree_width: int = 0               # >=1: draft token trees, verified
    #                                   in one tree-masked target pass
    #                                   (width=1 == chain, bitwise)
    adaptive_spec: bool = True        # False = TIDE-default (paper §5.4)
    selective_training: bool = True
    signal_window: int = 24
    n_threshold: int = 96             # samples per training cycle (tiny scale)
    train_epochs: int = 2
    train_min_steps: int = 80         # optimizer-step floor per cycle
    seed: int = 0
    # ---- decoupled-training subsystem
    async_train: bool = False         # background service vs drain-at-
    #                                   completion-boundaries (sync parity)
    channel_capacity: int = 512       # SignalChannel bound (batches)
    reseed_window: int = 0            # >0: re-seed resident draft caches
    #                                   on deploy from a W-pair ring
    gate_arrivals: bool = False       # respect trace arrival timestamps
    prefill_chunk: int = 0            # >0: chunked refill prefill (bound
    #                                   the long-prompt refill stall to
    #                                   one chunk per superstep gap);
    #                                   applies to waves and streams alike
    # ---- paged KV cache (core/paging.py; 0 = dense per-lane caches)
    page_size: int = 0                # >0: block-table page pools with
    #                                   admission-time reservations
    num_pages: int = 0                # pool size (0 = dense footprint)
    share_prefix: bool = True         # COW prompt-prefix sharing
    # ---- serving control plane (see serving/policy.py)
    admission: str = "fifo"           # fifo | priority | deadline (EDF)
    #                                   | wedf (priority-weighted EDF)
    commit: str = "cohort"            # cohort | eager chunk-pipeline commit
    admission_lookahead: int = 64     # reorder window (non-FIFO policies)
    # ---- overload resilience (docs/overload.md)
    preempt: str = "none"             # none | deadline: spill a loose
    #                                   resident lane when a tighter-
    #                                   deadline candidate is deferred
    shed: str = "none"                # none | expired | queue: drop
    #                                   hopeless queued requests
    shed_queue_depth: int = 64        # queue-shed depth bound
    idle_wait_s: float = 0.005        # gated-arrival idle-tick bound
    spec_park_patience: int = 0       # >0: park speculation + capture
    #                                   after N gated-off rounds
    spec_probe_interval: int = 8      # parked dispatches between probes
    trainer_threads: int = 0          # >0: pin/deprioritize the trainer
    #                                   client's host threads
    # ---- observability (repro/obs; host-side only, zero device syncs).
    #      Not a ServingConfig knob: the engine takes the built
    #      tracer/recorder as collaborators, never a config field.
    obs: Optional[ObsConfig] = None
    # ---- disaggregation (repro/fleet; docs/disaggregation.md).
    #      fleet.trainer_endpoint moves the TrainingService out of
    #      process over the fleet wire protocol (TideSystem handles
    #      this transparently: same sync/async modes, same summary);
    #      fleet.replicas > 0 selects the data-parallel replica fleet,
    #      served through repro.fleet.router.ServingFleet (TideSystem
    #      itself stays single-engine).
    fleet: Optional[FleetConfig] = None
    serving: Optional[ServingConfig] = None

    # knobs shared (by name) with ServingConfig: assembled into one
    # when ``serving`` is omitted, mirrored back when it is given — one
    # list, so a knob added to either side cannot silently desync
    # (tests/test_config_mirror.py asserts the list covers every
    # ServingConfig field)
    _SHARED_FIELDS = ("gamma", "batch_size", "max_len", "greedy", "seed",
                      "superstep_rounds", "eos_id", "ema", "tree_width",
                      "gate_arrivals", "prefill_chunk", "reseed_window",
                      "page_size", "num_pages", "share_prefix",
                      "admission", "commit", "admission_lookahead",
                      "preempt", "shed", "shed_queue_depth",
                      "idle_wait_s", "spec_park_patience",
                      "spec_probe_interval", "trainer_threads")

    def __post_init__(self):
        if self.serving is None:
            self.serving = ServingConfig(**{
                f: getattr(self, f) for f in self._SHARED_FIELDS})
            return
        # Flat fields explicitly set away from their TideConfig default
        # override the serving config; the rest become read mirrors of
        # it.  Because a constructed TideConfig's flat fields equal its
        # serving values (mirrored below), this also makes
        # ``dataclasses.replace(tc, batch_size=8)`` behave: the changed
        # field differs from both default and serving -> override; the
        # untouched ones equal serving -> no-op mirror.
        defaults = {f.name: f.default
                    for f in dataclasses.fields(type(self))}
        over = {f: getattr(self, f) for f in self._SHARED_FIELDS
                if getattr(self, f) != defaults[f]
                and getattr(self, f) != getattr(self.serving, f)}
        if over:
            self.serving = dataclasses.replace(self.serving, **over)
        for f in self._SHARED_FIELDS:
            setattr(self, f, getattr(self.serving, f))


class TideSystem:
    def __init__(self, cfg: ModelConfig, params, tide_cfg: TideConfig,
                 profile: Optional[LatencyProfile] = None,
                 dparams=None):
        self.cfg = cfg
        self.tcfg = tide_cfg
        self.dcfg = eagle.draft_config(cfg)
        if dparams is None:
            dparams = eagle.draft_init(self.dcfg,
                                       jax.random.key(tide_cfg.seed + 7))
        self._dparams0 = dparams
        # one shared instrument set for every component (see module
        # docstring, "Observability"); tracer/recorder default to the
        # null singletons when TideConfig.obs is unset
        self.obs = tide_cfg.obs if tide_cfg.obs is not None else ObsConfig()
        self.metrics = MetricsRegistry()
        self.tracer, self.recorder = self.obs.build()
        self.controller = TrainingController(
            n_threshold=tide_cfg.n_threshold * tide_cfg.signal_window,
            n_init=4)
        drafter = None
        if tide_cfg.adaptive_spec and profile is not None:
            drafter = AdaptiveDrafter(profile, gamma=tide_cfg.gamma)
        # --- training stack: in-process (thread / submesh) or
        # out-of-process over the fleet wire (docs/disaggregation.md).
        # Both expose the same poll/drain/reset/close surface, so every
        # serving-side mode below is transport-agnostic.
        remote = (tide_cfg.fleet is not None
                  and tide_cfg.fleet.trainer_endpoint is not None)
        if remote:
            from repro.fleet.remote import RemoteTrainingService
            self.service = RemoteTrainingService(
                tide_cfg.fleet.trainer_endpoint,
                tcfg=cfg, dcfg=self.dcfg,
                embed_params=params["embed"], dparams0=dparams,
                n_threshold=tide_cfg.n_threshold * tide_cfg.signal_window,
                signal_window=tide_cfg.signal_window,
                train_epochs=tide_cfg.train_epochs,
                train_min_steps=tide_cfg.train_min_steps,
                seed=tide_cfg.seed, async_train=tide_cfg.async_train,
                channel_capacity=max(tide_cfg.channel_capacity,
                                     tide_cfg.n_threshold),
                controller=self.controller,
                selective=tide_cfg.selective_training,
                engine_steps_fn=lambda: self.engine.stats.steps,
                tracer=self.tracer, registry=self.metrics)
            self.channel = self.service.channel
            self.trainer = None        # lives in the trainer process
            self.gate = self.service.gate   # serving-side version mirror
        else:
            train_device = (pick_training_device()
                            if tide_cfg.async_train else None)
            serve_device = (jax.devices()[0]
                            if train_device is not None else None)
            # the channel must be able to buffer at least one cycle's
            # worth of windows or training starves behind the
            # drop-oldest bound
            self.channel = SignalChannel(
                capacity=max(tide_cfg.channel_capacity,
                             tide_cfg.n_threshold),
                device=train_device)
            self.trainer = DraftTrainer(cfg, self.dcfg, params["embed"])
            self.gate = DraftDeployGate(dparams)
            self.service = TrainingService(
                self.trainer, self.gate, self.channel,
                controller=self.controller,
                selective=tide_cfg.selective_training,
                n_threshold=tide_cfg.n_threshold * tide_cfg.signal_window,
                signal_window=tide_cfg.signal_window,
                train_epochs=tide_cfg.train_epochs,
                train_min_steps=tide_cfg.train_min_steps,
                seed=tide_cfg.seed,
                device=train_device, publish_device=serve_device,
                trainer_threads=tide_cfg.trainer_threads,
                engine_steps_fn=lambda: self.engine.stats.steps,
                tracer=self.tracer, registry=self.metrics)
        self.store = self.channel     # back-compat alias (shared storage)
        self.extractor = SignalExtractor(self.channel,
                                         window=tide_cfg.signal_window)
        self.events = self.service.events
        # the engine consumes one unified ServingConfig + the composed
        # ServingPolicy it names (re-seed only makes sense with the
        # async deploy path, so sync mode zeroes it)
        scfg = dataclasses.replace(
            tide_cfg.serving,
            reseed_window=(tide_cfg.reseed_window if tide_cfg.async_train
                           else 0))
        self.engine = ServingEngine(
            cfg, params, self.dcfg, dparams, config=scfg,
            policy=scfg.make_policy(drafter),
            controller=self.controller if tide_cfg.selective_training
            else None,
            extractor=self.extractor,
            deploy_source=(self.service.poll if tide_cfg.async_train
                           else None),
            tracer=self.tracer, recorder=self.recorder,
            metrics=self.metrics)
        # start in collection mode so the cold draft trains immediately
        self.controller.collection_enabled = True
        if tide_cfg.async_train:
            self.service.start()

    # ----------------------------------------------------------- training
    def _drain_train(self, _req=None):
        """Synchronous parity mode: run every cycle the buffered signals
        allow, blocking serving (the legacy training schedule), then
        deploy immediately so the next dispatch uses the new draft
        (same pickup protocol as the async per-superstep poll)."""
        self.service.drain()
        self.engine._poll_deploy(self.service.poll)

    # ------------------------------------------------------------ serving
    def run(self, waves: Iterable[List], max_new_tokens: int = 48
            ) -> List[Request]:
        """Serve a workload stream (already grouped into waves of
        (domain, prompt) pairs). Returns all completed requests."""
        done: List[Request] = []
        sync = not self.tcfg.async_train
        for wave in waves:
            reqs = [Request(prompt=p, domain=d,
                            max_new_tokens=max_new_tokens)
                    for d, p in wave]
            self.engine.serve_wave(reqs)
            done.extend(reqs)
            if sync:
                self._drain_train()
        return done

    def run_stream(self, requests: Iterable[Request]) -> List[Request]:
        """Serve a request stream with continuous batching.  In sync
        mode the training service is drained at request-completion
        boundaries (blocking, deterministic — a passing draft hot-swaps
        in mid-stream exactly as the legacy scheduler did); in async
        mode serving never waits — the service trains in the
        background and the engine picks deploys up from the lock-free
        slot once per superstep."""
        on_complete = (self._drain_train if not self.tcfg.async_train
                       else None)
        return self.engine.serve_stream(requests, on_complete=on_complete)

    def requests_from_trace(self, trace) -> List[Request]:
        """Materialize ``data.workloads.ArrivalEvent`` records as engine
        requests.  Trace *order* is always preserved (admission order is
        then the admission policy's call); arrival *times*
        (``ArrivalEvent.t`` → ``Request.arrives_at``) are replayed only
        when ``gate_arrivals`` is set — otherwise the trace is served as
        a backlog, as fast as slots free up.  SLO annotations
        (``deadline``, ``priority``) ride along for the
        deadline/priority admission policies."""
        return [Request(prompt=ev.prompt, domain=ev.domain,
                        max_new_tokens=ev.max_new_tokens,
                        arrives_at=ev.t,
                        deadline=getattr(ev, "deadline", None),
                        priority=getattr(ev, "priority", 0))
                for ev in trace]

    # ----------------------------------------------------------- lifecycle
    def close(self):
        """Stop the background training service (async mode); buffered
        signals remain drainable.  Idempotent."""
        self.service.close()

    def reset_adaptation(self):
        """Reset every adaptation-side component to its
        post-construction state — draft params, deploy gate, controller,
        channel, signal windows, serving stats — while keeping all
        compiled functions warm.  Benchmarks use this to measure a cold
        adaptive run without paying recompilation.  Holds the service's
        train lock throughout, so an in-flight background cycle
        completes (against the pre-reset gate) before anything is
        cleared and can never publish a stale draft into the fresh
        run."""
        with self.service._train_lock:
            self.channel.reset()
            self.extractor.reset()
            self.controller.reset()
            self.controller.collection_enabled = True   # as in __init__
            self.gate.reset(self._dparams0)
            self.service.reset()
            self.engine.reset_adaptation(self._dparams0)

    # ------------------------------------------------------------- stats
    def export_trace(self, path: Optional[str] = None) -> Dict:
        """Export the span tracer's buffer as a Chrome/Perfetto
        trace-event JSON document, writing it to ``path`` (default:
        ``ObsConfig.trace_path``) when one is known."""
        return self.tracer.export(path if path is not None
                                  else self.obs.trace_path)

    def snapshot(self) -> Dict:
        """Flat metrics snapshot across every registry namespace
        (``serving.* / train.* / paging.* / spec.*``).  The legacy
        ``summary()`` keys are views over the same state."""
        return self.metrics.snapshot()

    def summary(self) -> Dict:
        st = self.engine.stats
        return {
            "tokens": st.tokens_out,
            "throughput_tok_s": st.throughput,
            "accept_len": st.accept_len,
            "steps": st.steps,
            "spec_steps": st.spec_steps,
            "refills": st.refills,
            "occupancy": st.occupancy,
            "ttft_p50_s": st.ttft_p50,
            "latency_p95_s": st.latency_p95,
            "idle_supersteps": st.idle_supersteps,
            "deploys": st.deploys,
            "reseeds": st.reseeds,
            "spec_parks": self.engine.policy.speculation.parks,
            "spec_resumes": self.engine.policy.speculation.resumes,
            "train_cycles": len([e for e in self.events
                                 if e["kind"] == "train_cycle"]),
            "deployed": self.gate.version,
            "trainer_failures": getattr(self.service, "failures", 0),
            "signals_collected": self.channel.total_added,
            "signal_bytes": self.channel.total_bytes,
            "signals_dropped": self.channel.dropped,
        }
