"""Adaptive speculative-decoding control (paper §4.1, Eqs. 2–5).

The Adaptive Drafter profiles target decode latency T(n) across batch
sizes and the (batch-independent) draft latency D0 at startup, then
estimates the *practical speedup* of speculation at runtime:

    E[l]      = (1 - α^{γ+1}) / (1 - α)                       (Eq. 2)
    SD(b)     = (γ·D(b) + T(b·(γ+1))) / E[l]                  (Eq. 3)
    Speedup   = T(b) / SD(b)                                  (Eq. 4)
              = (1 - α^{γ+1}) / ((1-α)(c(b)·γ + β(b)))        (Eq. 5)

with c(b) = D0 / T(b) and β(b) = T(b(γ+1)) / T(b).  Speculation is
enabled only when the estimate exceeds 1 (+ hysteresis margin).

T(n) is interpolated log-linearly between profiled batch sizes; an
analytic roofline-based latency model is also provided for the TPU
dry-run targets where wall-clock profiling is impossible in this
container (DESIGN.md §2.4).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class LatencyProfile:
    """Profiled T(n) curve + D0 (paper Table 5)."""
    batch_sizes: List[int]
    t_ms: List[float]
    d0_ms: float

    def t(self, n: float) -> float:
        """Log-linear interpolation of T(n) in ms, with log-linear
        extrapolation beyond the profiled range (np.interp would clamp,
        which wrongly makes β(b) → 1 at large batch)."""
        bs = np.log(np.asarray(self.batch_sizes, dtype=np.float64))
        ts = np.log(np.asarray(self.t_ms, dtype=np.float64))
        x = np.log(max(float(n), 1.0))
        if x <= bs[0]:
            slope = (ts[1] - ts[0]) / (bs[1] - bs[0])
            return float(np.exp(ts[0] + slope * (x - bs[0])))
        if x >= bs[-1]:
            slope = (ts[-1] - ts[-2]) / (bs[-1] - bs[-2])
            return float(np.exp(ts[-1] + slope * (x - bs[-1])))
        return float(np.exp(np.interp(x, bs, ts)))

    def c(self, b: int) -> float:
        return self.d0_ms / self.t(b)

    def beta(self, b: int, gamma: int) -> float:
        return self.t(b * (gamma + 1)) / self.t(b)


def expected_accept_len(alpha: float, gamma: int) -> float:
    """Eq. 2. alpha in [0, 1)."""
    alpha = min(max(alpha, 0.0), 0.999999)
    if alpha == 0.0:
        return 1.0
    return (1.0 - alpha ** (gamma + 1)) / (1.0 - alpha)


def alpha_from_accept_len(ell: float, gamma: int) -> float:
    """Invert Eq. 2 numerically (monotone in alpha)."""
    lo, hi = 0.0, 0.999999
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if expected_accept_len(mid, gamma) < ell:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def theoretical_speedup(alpha: float, gamma: int, c: float) -> float:
    """Eq. 1 (memory-bound assumption β = 1)."""
    return expected_accept_len(alpha, gamma) / (c * gamma + 1.0)


def practical_speedup(alpha: float, gamma: int, profile: LatencyProfile,
                      batch: int) -> float:
    """Eq. 5."""
    return expected_accept_len(alpha, gamma) / (
        profile.c(batch) * gamma + profile.beta(batch, gamma))


def min_accept_len_for_gain(gamma: int, profile: LatencyProfile,
                            batch: int, margin: float = 1.0) -> float:
    """Minimum E[l] at which speculation wins at this batch size
    (used by the Adaptive Drafter's runtime threshold, paper §5.4)."""
    return margin * (profile.c(batch) * gamma + profile.beta(batch, gamma))


def accept_threshold_table(profile: LatencyProfile, gamma: int,
                           max_batch: int, margin: float = 1.0) -> np.ndarray:
    """Eq. 5 break-even E[l] for every possible active-request count.

    The pure functional core of the Adaptive Drafter: index ``b`` holds
    ``min_accept_len_for_gain(gamma, profile, b)``, so the speculate-vs-
    plain choice becomes a device-side table lookup + compare — the
    fused decode superstep evaluates it in-graph with ``lax.cond``
    instead of syncing to the host every step.  Index 0 is a sentinel
    (no active requests → the round is skipped anyway)."""
    return np.array(
        [min_accept_len_for_gain(gamma, profile, max(b, 1), margin)
         for b in range(max_batch + 1)], np.float32)


def drafter_decide(threshold_table, n_active, accept_len_ema):
    """In-graph Eq. 5 decision (jnp; traceable).

    threshold_table: (B+1,) from ``accept_threshold_table``;
    n_active: () int32 active-request count; accept_len_ema: () f32.
    Returns a traced bool: speculate iff the EMA acceptance length
    clears the break-even threshold at this effective batch size."""
    import jax.numpy as jnp
    idx = jnp.clip(n_active, 0, threshold_table.shape[0] - 1)
    return accept_len_ema >= threshold_table[idx]


@dataclasses.dataclass
class AdaptiveDrafter:
    """Runtime enable/disable decision for speculative decoding."""
    profile: LatencyProfile
    gamma: int = 3
    margin: float = 1.0          # hysteresis: require speedup > margin
    enabled: bool = True

    def update(self, batch: int, accept_len_ema: float) -> bool:
        """Decide from the *observed* EMA acceptance length (E[l]).
        The compare runs in float32 to match the in-graph decision of
        the fused superstep (``drafter_decide`` on the f32 table)."""
        threshold = min_accept_len_for_gain(self.gamma, self.profile, batch,
                                            self.margin)
        self.enabled = bool(np.float32(accept_len_ema)
                            >= np.float32(threshold))
        return self.enabled

    def threshold_table(self, max_batch: int) -> np.ndarray:
        """Device-side decision table for the fused superstep."""
        return accept_threshold_table(self.profile, self.gamma, max_batch,
                                      self.margin)

    def predicted_speedup(self, batch: int, accept_len: float) -> float:
        alpha = alpha_from_accept_len(accept_len, self.gamma)
        return practical_speedup(alpha, self.gamma, self.profile, batch)


# --------------------------------------------------------- profiling
def profile_engine(step_fn: Callable[[int], None],
                   batch_sizes: Sequence[int],
                   draft_fn: Optional[Callable[[], None]] = None,
                   warmup: int = 1, iters: int = 3) -> LatencyProfile:
    """Measure T(n) by timing ``step_fn(n)`` (which must block until the
    device finishes, e.g. via ``jax.block_until_ready``) and D0 via
    ``draft_fn``.  This is the startup profiling pass of paper §4.1."""
    t_ms = []
    for n in batch_sizes:
        for _ in range(warmup):
            step_fn(n)
        t0 = time.perf_counter()
        for _ in range(iters):
            step_fn(n)
        t_ms.append((time.perf_counter() - t0) / iters * 1e3)
    d0 = 0.0
    if draft_fn is not None:
        draft_fn()
        t0 = time.perf_counter()
        for _ in range(iters):
            draft_fn()
        d0 = (time.perf_counter() - t0) / iters * 1e3
    return LatencyProfile(list(batch_sizes), t_ms, d0)


# Paper Table 5: measured T(n)/D0 on H100 nodes (ms) — used by the
# paper-faithful benchmarks to reproduce Figs. 4/8 without H100s.
PAPER_PROFILES: Dict[str, LatencyProfile] = {
    "gpt-oss-120b": LatencyProfile(
        [1, 2, 4, 8, 16, 32, 64, 128, 256, 512],
        [3.416, 3.844, 4.341, 5.236, 6.123, 7.637, 9.345, 11.79, 15.50,
         21.50], 0.393),
    "qwen3-235b-a22b": LatencyProfile(
        [1, 2, 4, 8, 16, 32, 64, 128],
        [9.057, 10.07, 11.86, 14.68, 17.84, 23.47, 26.68, 31.46], 0.137),
    "llama-4-scout-17b-16e": LatencyProfile(
        [1, 2, 4, 8, 16, 32, 64, 128, 256, 512],
        [6.461, 7.953, 8.932, 11.01, 13.61, 16.82, 19.58, 23.82, 27.89,
         40.86], 0.330),
    "llama-3.3-70b-instruct": LatencyProfile(
        [1, 2, 4, 8, 16, 32, 64, 128, 256, 512],
        [15.50, 16.00, 16.11, 16.36, 17.10, 18.45, 19.00, 21.38, 27.54,
         64.76], 0.843),
}


def analytic_tpu_profile(cfg, chips: int = 256, *, hbm_gbps: float = 819.0,
                         peak_tflops: float = 197.0,
                         dispatch_us: float = 150.0) -> LatencyProfile:
    """Roofline-derived T(n) for a TPU v5e slice (dry-run targets): decode
    latency = max(weight-read time, compute time) + dispatch floor."""
    n_active = cfg.active_param_count()
    bytes_w = n_active * 2                       # bf16 weights touched/token
    t_ms = []
    batches = [1, 2, 4, 8, 16, 32, 64, 128, 256, 512]
    for b in batches:
        mem_s = bytes_w / (hbm_gbps * 1e9 * chips)
        comp_s = 2 * n_active * b / (peak_tflops * 1e12 * chips)
        t_ms.append((max(mem_s, comp_s) + dispatch_us * 1e-6) * 1e3)
    # draft = 1 layer: dispatch dominated (paper §4.1 observation)
    return LatencyProfile(batches, t_ms, dispatch_us * 1e-3 * 2)
