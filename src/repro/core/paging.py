"""Paged KV cache: block-table allocator + copy-on-write prefix sharing.

Dense serving gives every batch lane a private ``max_len`` target *and*
draft cache, so slot count is bounded by ``slots x max_len`` worst-case
HBM no matter how short real sequences run.  This module replaces that
with the vLLM PagedAttention memory model, adapted to the engine's
byte-parity constraints:

  * **Page pools** — each attention K/V leaf becomes a pool of
    ``num_pages + 1`` fixed-size pages ``(num_pages + 1, page_size, Hk,
    D)``; page ``num_pages`` is the *trash page*, the explicit
    destination for every write that dense decoding would silently drop
    (positions past ``max_len``, masked refill lanes, unreserved table
    slots).  Routing the drops instead of relying on scatter clamping
    keeps real pages unclobberable by inactive lanes.
  * **One block table per lane** — a single host-authoritative
    ``(batch, max_len // page_size)`` int32 table maps token ranges to
    pages.  A page id is a lease on a token *range*: the same table
    drives every target layer's K and V pool and the draft pools, so
    refcounts stay per-range, not per-leaf.  The engine ships fresh
    device copies of the table between dispatches whenever the
    allocator mutates it (a host->device upload, never a sync).
  * **Admission by pages** — lanes reserve ``ceil(tokens / page_size)``
    pages at admission (prompt width + token budget + gamma + 1).  The
    scheduler defers admission when the pool cannot cover a reservation
    (see ``Scheduler(admission_guard=...)``), so batch width is bounded
    by HBM, not by ``slots x max_len``.
  * **Refcounted COW prefix sharing** — committed prompt-prefix pages
    are published to a registry keyed by *provenance*, not just
    content: ``(rows, op width, pad, token prefix)``.  Because refill
    row values are independent of sibling-row content but *do* depend
    on the refill op's row-count/width tiling, two lanes whose keys
    match are guaranteed bitwise-identical page bytes — so a borrower
    can adopt the donor's physical pages (refcount++) with no device
    compare, and a borrower's own commit rewriting a shared page is
    benign (same bytes).  A divergent write forks first
    (``fork_for_write``), vLLM-style copy-on-write; the serving engine
    never needs to by construction (shared pages cover only the prompt
    prefix strictly below the first per-lane-divergent position).

Byte parity: a paged lane attends through a gathered ``(B, max_len)``
view of its pool — structurally the same dense attention over the same
valid bytes, with garbage (trash/stale) keys landing exactly where
dense garbage lands and getting the same exact-zero softmax weight.
``tests/test_paged.py`` pins paged == dense on streams, logits, and
cache valid regions.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np


# ===================================================== device helpers
def gather_view(pool: jnp.ndarray, tbl: jnp.ndarray) -> jnp.ndarray:
    """Materialize the dense per-lane view of a page pool.

    pool: (num_pages + 1, P, ...); tbl: (B, n_tbl) int32.
    Returns (B, n_tbl * P, ...) — the paged lane's ``max_len`` window,
    bitwise equal to the dense cache on every position whose page was
    written through the same table.
    """
    npg1, p = pool.shape[0], pool.shape[1]
    b, n_tbl = tbl.shape
    view = pool[tbl]                          # (B, n_tbl, P, ...)
    return view.reshape((b, n_tbl * p) + pool.shape[2:])


def page_slot(tbl: jnp.ndarray, page_size: int, pos: jnp.ndarray,
              trash: int, valid: Optional[jnp.ndarray] = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Map token positions to (page, slot) through the block table.

    ``pos``: (B, T) absolute positions; writes at ``pos >= n_tbl * P``
    (dense scatter's dropped out-of-bounds writes) or with ``valid``
    False are routed to the trash page.  Returns ((B, T), (B, T)).
    """
    b, n_tbl = tbl.shape
    max_len = n_tbl * page_size
    idx = jnp.clip(pos // page_size, 0, n_tbl - 1)
    page = jnp.take_along_axis(tbl, idx, axis=1)
    ok = pos < max_len
    if valid is not None:
        ok = ok & valid
    page = jnp.where(ok, page, trash)
    return page, pos % page_size


def scatter_kv_paged(pool: jnp.ndarray, tbl: jnp.ndarray,
                     new: jnp.ndarray, lengths: jnp.ndarray) -> jnp.ndarray:
    """Paged twin of ``attention.scatter_kv``: write the decode block's
    K/V rows at positions ``lengths + [0, T)`` through the block table.
    pool: (num_pages + 1, P, Hk, D); new: (B, T, Hk, D)."""
    npg1, p = pool.shape[0], pool.shape[1]
    b, t = new.shape[:2]
    pos = lengths[:, None].astype(jnp.int32) + jnp.arange(t, dtype=jnp.int32)
    page, slot = page_slot(tbl, p, pos, npg1 - 1)
    return pool.at[page, slot].set(new.astype(pool.dtype))


def write_rows_paged(pool: jnp.ndarray, tbl: jnp.ndarray, rows: jnp.ndarray,
                     mask: jnp.ndarray) -> jnp.ndarray:
    """Write whole per-lane rows (refill/commit scatter) through the
    table.  rows: (B, W, Hk, D) dense staging already gathered to lane
    order; lanes with ``mask`` False write to the trash page (the paged
    twin of ``scatter_batch_rows``'s where-keep)."""
    npg1, p = pool.shape[0], pool.shape[1]
    b, w = rows.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(w, dtype=jnp.int32)[None], (b, w))
    page, slot = page_slot(tbl, p, pos, npg1 - 1,
                           valid=jnp.broadcast_to(mask[:, None], (b, w)))
    return pool.at[page, slot].set(rows.astype(pool.dtype))


def gather_rows_paged(pool: jnp.ndarray, tbl_rows: jnp.ndarray,
                      width: int) -> jnp.ndarray:
    """Gather the first ``width`` positions of each table row into a
    dense staging block (skip-mode resume: seed a chunk pipeline's
    staging from already-shared prefix pages).  tbl_rows: (R, m) with
    m * P >= width."""
    p = pool.shape[1]
    m = -(-width // p)
    view = pool[tbl_rows[:, :m]]               # (R, m, P, ...)
    view = view.reshape((tbl_rows.shape[0], m * p) + pool.shape[2:])
    return view[:, :width]


def copy_page(pool: jnp.ndarray, src: int, dst: int) -> jnp.ndarray:
    """COW fork's device half: duplicate one page's bytes."""
    return pool.at[dst].set(pool[src])


# ================================================== host-side allocator
class PageAllocator:
    """Free-list page allocator + refcounted prefix registry.

    All state is host-side numpy/int bookkeeping; the device only ever
    sees immutable snapshots of ``table`` (shipped by the engine
    between dispatches).  Pages are refcounted: a lane's table row
    holds one reference per mapped page, and every registry entry holds
    one reference per published page, so a shared prefix page survives
    its donor lane's retirement until the registry evicts it.
    """

    def __init__(self, num_pages: int, page_size: int, batch: int,
                 max_len: int, *, share_prefix: bool = True,
                 registry_cap: int = 256):
        if max_len % page_size:
            raise ValueError(
                f"max_len {max_len} must be a multiple of page_size "
                f"{page_size} (block tables cover exact token ranges)")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.batch = int(batch)
        self.max_len = int(max_len)
        self.n_tbl = max_len // page_size
        self.trash = self.num_pages
        self.share_prefix = bool(share_prefix)
        self.registry_cap = int(registry_cap)
        self.reset()

    def reset(self):
        self.table = np.full((self.batch, self.n_tbl), self.trash,
                             dtype=np.int32)
        self.ref = np.zeros((self.num_pages,), dtype=np.int64)
        self._free: List[int] = list(range(self.num_pages - 1, -1, -1))
        # provenance key -> (page ids, n_pages); insertion order = LRU
        self._registry: "OrderedDict[bytes, Tuple[Tuple[int, ...], int]]" \
            = OrderedDict()
        self.dirty = True          # table changed since last device ship
        # telemetry
        self.peak_in_use = 0
        self.prefix_hits = 0
        self.prefix_tokens_saved = 0
        self.evictions = 0
        self.cow_forks = 0
        # pages released by lane preemption (spill-to-host); restores
        # re-reserve through the normal path, so this counts spill
        # events' page traffic, not a live balance
        self.spilled_pages = 0

    # ------------------------------------------------------------ stats
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - len(self._free)

    def register_metrics(self, registry):
        """Expose allocator telemetry under the ``paging.*`` metrics
        namespace as callback gauges over this (host-side numpy)
        bookkeeping — evaluated only at snapshot time."""
        registry.gauge("paging.pages_in_use", fn=lambda: self.pages_in_use)
        registry.gauge("paging.pages_free", fn=lambda: self.free_pages)
        registry.gauge("paging.pages_peak", fn=lambda: self.peak_in_use)
        registry.gauge("paging.prefix_hits", fn=lambda: self.prefix_hits)
        registry.gauge("paging.prefix_tokens_saved",
                       fn=lambda: self.prefix_tokens_saved)
        registry.gauge("paging.evictions", fn=lambda: self.evictions)
        registry.gauge("paging.cow_forks", fn=lambda: self.cow_forks)
        registry.gauge("paging.spilled_pages",
                       fn=lambda: self.spilled_pages)

    def _note_use(self):
        self.peak_in_use = max(self.peak_in_use, self.pages_in_use)

    # ---------------------------------------------------------- refcount
    def _incref(self, page: int):
        self.ref[page] += 1

    def _decref(self, page: int):
        self.ref[page] -= 1
        if self.ref[page] < 0:
            raise AssertionError(f"page {page} double-freed")
        if self.ref[page] == 0:
            self._free.append(page)

    def _alloc(self, n: int) -> List[int]:
        pages = [self._free.pop() for _ in range(n)]
        for pg in pages:
            self._incref(pg)
        self._note_use()
        return pages

    # -------------------------------------------------------- reservations
    def pages_for(self, tokens: int) -> int:
        """Pages covering a ``tokens``-position reservation (clamped to
        the lane window)."""
        return -(-min(tokens, self.max_len) // self.page_size)

    def can_reserve(self, tokens: int) -> bool:
        """Admission guard: can a ``tokens`` reservation be satisfied
        right now (evicting idle registry prefixes if needed)?"""
        return self.can_fit(self.pages_for(tokens))

    def can_fit(self, pages: int) -> bool:
        """Could ``pages`` fresh pages be allocated right now (counting
        idle registry prefixes an eviction sweep would free)?  The
        engine's multi-lane admission guard sums its candidates'
        reservations through this."""
        return len(self._free) + self._evictable() >= pages

    def reserve(self, lane: int, tokens: int) -> bool:
        """Map fresh pages over positions [0, tokens) of ``lane``.
        Returns False (lane untouched) when the pool cannot cover it —
        the admission-defer signal."""
        if (self.table[lane] != self.trash).any():
            raise AssertionError(f"lane {lane} already holds pages")
        need = self.pages_for(tokens)
        if len(self._free) < need:
            self._evict(need - len(self._free))
        if len(self._free) < need:
            return False
        self.table[lane, :need] = self._alloc(need)
        self.dirty = True
        return True

    def free_lane(self, lane: int):
        """Release every page the lane maps (idempotent)."""
        row = self.table[lane]
        for i in range(self.n_tbl):
            if row[i] != self.trash:
                self._decref(int(row[i]))
                row[i] = self.trash
                self.dirty = True

    def lane_pages(self, lane: int) -> int:
        """Pages the lane currently maps (a restore must re-reserve
        exactly this many to cover the same token range)."""
        return int((self.table[lane] != self.trash).sum())

    def spill_lane(self, lane: int) -> int:
        """Preemption's allocator half: release the victim lane's pages
        after its bytes were gathered out to the host SpillStore.
        Returns the page count released (the restore's reservation
        size) and accounts it under ``spilled_pages``."""
        pages = self.lane_pages(lane)
        self.free_lane(lane)
        self.spilled_pages += pages
        return pages

    # ------------------------------------------------------------- sharing
    def prefix_key(self, rows: int, width: int, pad: int,
                   tokens: Sequence[int], n_pages: int,
                   salt: int = 0) -> bytes:
        """Provenance key for one lane's first ``n_pages`` prompt pages.

        Covers everything the page bytes depend on: the refill op's row
        count and width (tiling changes ULP), the lane's left-pad, and
        the token columns [0, n_pages * P + 1) — one column past the
        page range because the draft cache stores (capture_i, token_{i+1})
        pairs, so draft page bytes read one token ahead.  ``salt``
        extends the provenance with caller-side dependencies the
        allocator cannot see — the engine passes its draft deploy
        sequence number, since draft page bytes depend on ``dparams``.
        """
        n_tok = n_pages * self.page_size + 1
        h = hashlib.sha256()
        h.update(np.asarray([rows, width, pad, n_pages, self.page_size,
                             salt], dtype=np.int64).tobytes())
        h.update(np.asarray(list(tokens[:n_tok]), dtype=np.int64).tobytes())
        return h.digest()

    def publish(self, key: bytes, lane: int, n_pages: int):
        """Register the lane's first ``n_pages`` pages under ``key``
        (one registry reference per page).  First writer wins: a
        duplicate key keeps the existing entry (bytes are identical by
        provenance) and the caller should ``adopt`` instead."""
        if not self.share_prefix or n_pages <= 0:
            return
        if key in self._registry:
            self._registry.move_to_end(key)
            return
        pages = tuple(int(p) for p in self.table[lane, :n_pages])
        if any(p == self.trash for p in pages):
            raise AssertionError("publishing unmapped pages")
        for pg in pages:
            self._incref(pg)
        self._registry[key] = (pages, n_pages)
        if len(self._registry) > self.registry_cap:
            self._evict(0, force_one=True)

    def lookup(self, key: bytes) -> Optional[Tuple[int, ...]]:
        """Shared pages for ``key`` (LRU-touched), or None."""
        if not self.share_prefix:
            return None
        hit = self._registry.get(key)
        if hit is None:
            return None
        self._registry.move_to_end(key)
        return hit[0]

    def adopt(self, lane: int, pages: Sequence[int]):
        """Repoint the lane's leading table entries at shared pages,
        releasing the lane's own pages for that range."""
        for i, pg in enumerate(pages):
            old = int(self.table[lane, i])
            if old == int(pg):
                continue
            self._incref(int(pg))
            if old != self.trash:
                self._decref(old)
            self.table[lane, i] = int(pg)
            self.dirty = True
        self.prefix_hits += 1
        self.prefix_tokens_saved += len(pages) * self.page_size
        self._note_use()

    def fork_for_write(self, lane: int, idx: int
                       ) -> Optional[Tuple[int, int]]:
        """Copy-on-write fork: ensure ``table[lane, idx]`` is exclusively
        owned before a divergent write.  Returns (src, dst) page ids to
        ``copy_page`` on device, or None when the page was already
        exclusive (write in place).  Raises on pool exhaustion — callers
        gate writes behind reservations, so this is a logic error."""
        page = int(self.table[lane, idx])
        if page == self.trash:
            raise AssertionError("forking an unmapped table entry")
        if self.ref[page] == 1:
            return None
        if not self._free:
            self._evict(1)
        if not self._free:
            raise RuntimeError("page pool exhausted during COW fork")
        (new,) = self._alloc(1)
        self._decref(page)
        self.table[lane, idx] = new
        self.dirty = True
        self.cow_forks += 1
        return page, new

    # ------------------------------------------------------------ eviction
    def _evictable(self) -> int:
        """Pages an LRU registry sweep could free right now (entries
        whose pages are held by no lane)."""
        n = 0
        for pages, _ in self._registry.values():
            if all(self.ref[pg] == 1 for pg in pages):
                n += len(pages)
        return n

    def _evict(self, want_free: int, force_one: bool = False):
        """Drop LRU registry entries until ``want_free`` pages could be
        freed (only entries no lane still maps actually free pages)."""
        freed = 0
        dropped = False
        for key in list(self._registry):
            if freed >= want_free and not (force_one and not dropped):
                break
            pages, _ = self._registry[key]
            if not all(self.ref[pg] == 1 for pg in pages):
                continue      # a lane still maps it; eviction frees nothing
            del self._registry[key]
            for pg in pages:
                self._decref(pg)
            freed += len(pages)
            dropped = True
            self.evictions += 1

    def release_prefix_cache(self):
        """Drop every registry entry (stream drain / leak check)."""
        for key in list(self._registry):
            pages, _ = self._registry.pop(key)
            for pg in pages:
                self._decref(pg)

    # ---------------------------------------------------------- invariants
    def assert_clean(self):
        """Leak check: every lane released, registry empty, every page
        back on the free list with refcount zero."""
        if self._registry:
            raise AssertionError(
                f"{len(self._registry)} prefix registry entries leaked")
        if (self.table != self.trash).any():
            held = int((self.table != self.trash).sum())
            raise AssertionError(f"{held} table entries still mapped")
        if (self.ref != 0).any():
            raise AssertionError(
                f"nonzero refcounts: {np.nonzero(self.ref)[0].tolist()}")
        if len(self._free) != self.num_pages:
            raise AssertionError(
                f"free list holds {len(self._free)}/{self.num_pages} pages")

    def table_device(self) -> jnp.ndarray:
        """A fresh immutable device snapshot of the block table.  Each
        call materializes a new buffer, so the target cache and draft
        cache can each own one without double-donation."""
        return jnp.asarray(np.array(self.table, copy=True))


# ==================================================== lane spill store
class SpilledLane:
    """One preempted request parked off-lane.

    ``slices`` is the engine's opaque per-lane snapshot — a pytree of
    device arrays gathered out of the live caches/superstep state by a
    jitted spill op (target KV groups + lengths/pad, draft KV +
    lengths/pad, per-lane carry/PRNG/capture-ring state, remaining
    token budget).  The arrays stay device-resident: the gather is
    enqueued like any other superstep op and never synced, so spilling
    adds zero host round-trips.  ``pages`` is the page count the lane
    mapped at spill time (paged serving re-reserves exactly that many
    at restore; dense serving records 0)."""

    __slots__ = ("request", "slices", "pages")

    def __init__(self, request, slices, pages: int = 0):
        self.request = request
        self.slices = slices
        self.pages = pages


class SpillStore:
    """Host-side parking lot for preempted lanes (rid-keyed, insertion
    ordered).  Pure bookkeeping: the engine decides when to spill and
    restore; the store only tracks the parked set and the traffic
    counters (``spills``/``restores``/``dropped`` — dropped entries
    are spilled requests that finished from already-in-flight
    telemetry before any restore happened)."""

    def __init__(self):
        self._entries: "OrderedDict[int, SpilledLane]" = OrderedDict()
        self.spills = 0
        self.restores = 0
        self.dropped = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __bool__(self) -> bool:
        return bool(self._entries)

    def put(self, entry: SpilledLane):
        if entry.request.rid in self._entries:
            raise AssertionError(
                f"request {entry.request.rid} spilled twice")
        self._entries[entry.request.rid] = entry
        self.spills += 1

    def pop(self, rid: int) -> SpilledLane:
        self.restores += 1
        return self._entries.pop(rid)

    def drop(self, rid: int) -> SpilledLane:
        self.dropped += 1
        return self._entries.pop(rid)

    def pending(self) -> List[SpilledLane]:
        """Parked entries in spill order (the engine re-ranks by its
        restore policy before claiming lanes)."""
        return list(self._entries.values())
