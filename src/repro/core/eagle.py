"""EAGLE-3 draft model (Li et al., arXiv:2503.01840), as used by TIDE §3.2.

One decoder layer + LM head.  The draft predicts the next token from the
*target model's* concatenated low/mid/high hidden states (3·D "capture
features") fused to D, combined with the embedding of the most recent
token.  During chain drafting the draft's own hidden state substitutes for
the target feature (EAGLE-3 "training-time test" behaviour), so training
includes a TTT step on self-generated features.

The draft shares the target's token embedding (read-only), so its own
parameters are just: fuse (3D→D), fc (2D→D), one decoder layer, head.
DeepSeek-V3's MTP head (``cfg.mtp_depth``) is this same structure trained
jointly — we expose it through the identical module.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.config import ATTN, FFN_SWIGLU, BlockDef, ModelConfig
from repro.models.layers import (EMBED, MLP, embed, ffn, ffn_specs, rmsnorm,
                                 rmsnorm_specs)
from repro.models.param import ParamSpec, init_params
from repro.models.transformer import BATCH, KV_SEQ


def draft_config(tcfg: ModelConfig) -> ModelConfig:
    """Draft architecture derived from the target: 1 decoder layer, same
    d_model/vocab, small GQA."""
    # pick a head count that divides d_model with head_dim >= 64
    bound = max(min(tcfg.num_heads, tcfg.d_model // 64), 1)
    heads = next(h for h in range(bound, 0, -1) if tcfg.d_model % h == 0)
    kv = min(tcfg.num_kv_heads, heads)
    while heads % kv:
        kv -= 1
    return dataclasses.replace(
        tcfg,
        name=tcfg.name + "-eagle3",
        family="dense",
        num_layers=1,
        prologue=(),
        pattern=(BlockDef(ATTN, FFN_SWIGLU),),
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=tcfg.d_model // heads,
        d_ff=2 * tcfg.d_model,
        num_experts=0,
        experts_per_tok=0,
        num_shared_experts=0,
        encoder_layers=0,
        num_image_tokens=0,
        q_lora_rank=0,
        kv_lora_rank=0,
        window=0,
        capture_layers=(0, 0, 0),
    )


def draft_specs(dcfg: ModelConfig) -> dict:
    d, v = dcfg.d_model, dcfg.vocab_size
    return {
        "fuse": ParamSpec((3 * d, d), (MLP, EMBED)),
        "fc": ParamSpec((2 * d, d), (MLP, EMBED)),
        "norm1": rmsnorm_specs(d),
        "attn": attn.attn_specs(dcfg),
        "norm2": rmsnorm_specs(d),
        "ffn": ffn_specs(dcfg, FFN_SWIGLU),
        "final_norm": rmsnorm_specs(d),
        "head": {"w": ParamSpec((d, v), (EMBED, "vocab"))},
    }


def draft_init(dcfg: ModelConfig, key):
    return init_params(key, draft_specs(dcfg))


def draft_param_count(dcfg: ModelConfig) -> int:
    from repro.models.param import count_params
    return count_params(draft_specs(dcfg))


# ------------------------------------------------------------ core layer
def _layer(dcfg: ModelConfig, p, x, k_cache, v_cache, lengths, pad,
           page_tbl=None):
    """One decoder layer over new positions (decode form, cache write).
    With ``page_tbl``, k_cache/v_cache are page pools (paged serving)."""
    h = rmsnorm(p["norm1"], x, dcfg.norm_eps)
    out, (kc, vc) = attn.self_attention_decode(
        dcfg, p["attn"], h, k_cache, v_cache, lengths, pad,
        page_tbl=page_tbl)
    x = x + out
    h2 = rmsnorm(p["norm2"], x, dcfg.norm_eps)
    x = x + ffn(p["ffn"], h2, FFN_SWIGLU)
    return x, kc, vc


def _layer_full(dcfg: ModelConfig, p, x):
    """Training form: full causal self-attention, no cache."""
    h = rmsnorm(p["norm1"], x, dcfg.norm_eps)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    out, _ = attn.self_attention_prefill(dcfg, p["attn"], h, positions)
    x = x + out
    h2 = rmsnorm(p["norm2"], x, dcfg.norm_eps)
    return x + ffn(p["ffn"], h2, FFN_SWIGLU)


def _head(dcfg, dparams, x):
    return (x @ dparams["head"]["w"].astype(x.dtype)).astype(jnp.float32)


def _fuse_inputs(dcfg, dparams, feats, tok_emb):
    """feats: (B,T,3D) target captures (or (B,T,D) self features pre-fused);
    tok_emb: (B,T,D). Returns fc([fused; emb]).

    The 3D→D fuse is computed as the sum of three D-contraction matmuls
    (one per capture level) instead of a single 3D-contraction dot: XLA's
    CPU tiling of a 3D-wide contraction depends on the row count, which
    would make the fused features — and so the draft K/V — differ in ulps
    between a chunked prompt ingestion and a one-shot one.  Splitting at
    the capture-level boundary keeps every contraction width-stable, so
    chunked draft seeding is bit-identical to one-shot seeding
    (tests/test_chunked_prefill.py pins this)."""
    dt = tok_emb.dtype
    d = dcfg.d_model
    if feats.shape[-1] == 3 * d:
        w = dparams["fuse"].astype(dt)
        f = feats.astype(dt)
        fused = sum(f[..., i * d:(i + 1) * d] @ w[i * d:(i + 1) * d]
                    for i in range(3))
    else:
        fused = feats.astype(dt)
    x = jnp.concatenate([fused, tok_emb], axis=-1)
    return x @ dparams["fc"].astype(dt)


# ------------------------------------------------------------- cache
def init_draft_cache(dcfg: ModelConfig, batch: int, max_len: int, *,
                     page_size: int = 0, num_pages: int = 0) -> dict:
    """Zeroed draft cache.  With ``page_size > 0`` the K/V leaves are
    page pools (num_pages + 1, P, Hk, D) plus a per-lane block table
    ``tbl`` — same layout and trash-page convention as the target
    cache's pools, but a *separate* device table copy so the engine can
    donate target and draft caches independently."""
    hk, hd = dcfg.num_kv_heads, dcfg.head_dim
    if page_size > 0:
        if max_len % page_size:
            raise ValueError(f"max_len {max_len} % page_size {page_size}")
        return {
            "k": jnp.zeros((num_pages + 1, page_size, hk, hd),
                           dcfg.act_dtype),
            "v": jnp.zeros((num_pages + 1, page_size, hk, hd),
                           dcfg.act_dtype),
            "tbl": jnp.full((batch, max_len // page_size), num_pages,
                            jnp.int32),
            "lengths": jnp.zeros((batch,), jnp.int32),
            "pad": jnp.zeros((batch,), jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, max_len, hk, hd), dcfg.act_dtype),
        "v": jnp.zeros((batch, max_len, hk, hd), dcfg.act_dtype),
        "lengths": jnp.zeros((batch,), jnp.int32),
        "pad": jnp.zeros((batch,), jnp.int32),
    }


def draft_cache_axes() -> dict:
    return {"k": (BATCH, KV_SEQ, "kv_heads", "qkv"),
            "v": (BATCH, KV_SEQ, "kv_heads", "qkv"),
            "lengths": (BATCH,), "pad": (BATCH,)}


def draft_cache_abstract(dcfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: init_draft_cache(dcfg, batch, max_len))


# ------------------------------------------------------- serving functions
def draft_extend(dcfg: ModelConfig, dparams, embed_params, dcache,
                 feats, tokens, advance):
    """Append ``T`` (feature, token) pairs to the draft cache.

    feats: (B, T, 3D) true target captures for the accepted positions;
    tokens: (B, T) the tokens *following* each feature position;
    advance: (B,) how many of the T entries are valid (cache lengths
    advance by this; trailing entries are scratch and get overwritten).

    Returns (logits (B,T,V), h (B,T,D), dcache').
    """
    dt = dcfg.act_dtype
    tok_emb = embed(embed_params, tokens, dt)
    x = _fuse_inputs(dcfg, dparams, feats, tok_emb)
    x, kc, vc = _layer(dcfg, dparams, x, dcache["k"], dcache["v"],
                       dcache["lengths"], dcache["pad"],
                       page_tbl=dcache.get("tbl"))
    h = rmsnorm(dparams["final_norm"], x, dcfg.norm_eps)
    logits = _head(dcfg, dparams, h)
    new_cache = dict(dcache, k=kc, v=vc,
                     lengths=dcache["lengths"] + advance)
    return logits, h, new_cache


def draft_propose(dcfg: ModelConfig, dparams, embed_params, dcache,
                  h_last, first_logits, gamma: int, *,
                  greedy: bool = True, key=None, keys=None):
    """Chain-draft γ tokens.  h_last: (B, D) draft hidden at the last
    verified position; first_logits: (B, V) draft logits there.

    ``keys``: optional (B,) per-lane key array — chain-step j for lane b
    samples with ``fold_in(keys[b], j)``, so draft randomness is
    per-request (scheduling-invariant); ``key`` is the legacy
    batch-global scalar.

    Returns (draft_tokens (B, γ), draft_logits (B, γ, V), dcache') —
    dcache' has the speculative entries written but its *lengths advanced
    by γ* so the target-verify block can be compared; the caller resets
    lengths on commit (stale entries are overwritten next round).
    """
    dt = dcfg.act_dtype
    b = h_last.shape[0]

    def pick(logits, k):
        if greedy:
            return logits.argmax(-1).astype(jnp.int32)
        if keys is not None:
            kj = jax.vmap(lambda kk: jax.random.fold_in(kk, k))(keys)
            return jax.vmap(jax.random.categorical)(kj, logits
                                                    ).astype(jnp.int32)
        return jax.random.categorical(k, logits).astype(jnp.int32)

    if keys is not None:
        xs = jnp.arange(gamma)                    # fold-in indices
    else:
        xs = (jax.random.split(key, gamma) if key is not None
              else jnp.zeros((gamma, 2), jnp.uint32))

    def step(carry, k):
        h, logits, cache = carry
        tok = pick(logits, k)
        tok_emb = embed(embed_params, tok[:, None], dt)
        x = _fuse_inputs(dcfg, dparams, h[:, None], tok_emb)
        x, kc, vc = _layer(dcfg, dparams, x, cache["k"], cache["v"],
                           cache["lengths"], cache["pad"],
                           page_tbl=cache.get("tbl"))
        h_new = rmsnorm(dparams["final_norm"], x, dcfg.norm_eps)[:, 0]
        logits_new = _head(dcfg, dparams, h_new[:, None])[:, 0]
        cache = dict(cache, k=kc, v=vc, lengths=cache["lengths"] + 1)
        return (h_new, logits_new, cache), (tok, logits)

    (h_f, logits_f, cache_f), (toks, logitss) = jax.lax.scan(
        step, (h_last, first_logits, dcache), xs)
    draft_tokens = toks.T                                    # (B, γ)
    draft_logits = logitss.transpose(1, 0, 2)                # (B, γ, V)
    return draft_tokens, draft_logits, cache_f


def draft_propose_tree(dcfg: ModelConfig, dparams, embed_params, dcache,
                       h_last, first_logits, gamma: int, width: int, *,
                       greedy: bool = True, key=None, keys=None):
    """Draft a token *tree*: ``width`` parallel chains of depth ``gamma``
    sharing the root position, for one tree-masked target verify pass.

    Branch 0 is the verbatim ``draft_propose`` chain (same randomness,
    same tokens — width == 1 is bitwise the chain).  Branch r >= 1
    re-proposes from the same post-extend cache with the previously
    picked depth-1 siblings masked to NEG_INF and a greedy
    continuation, so sibling roots are distinct and each branch is the
    draft's best completion of its alternative first token.  Every
    branch writes its speculative K/V at the same cache slots
    [lengths, lengths + gamma) — isolation comes from the causal
    frontier (each propose starts at the same base lengths, so a
    branch never reads a prior branch's stale rows), and the propose
    K/V is scratch that the next ``draft_extend`` overwrites anyway.

    Returns (tokens (B, width, γ), logits (B, width, γ, V), dcache')
    where dcache' is branch 0's propose cache (lengths advanced by γ,
    reset by the caller on commit).  Branch r's depth-1 logits row is
    the sibling-masked distribution — exactly the proposal density the
    residual-sampling acceptance must divide by.
    """
    b = h_last.shape[0]
    toks_all, logits_all = [], []
    masked = first_logits
    cache0 = None
    bidx = jnp.arange(b)
    for r in range(width):
        if r == 0:
            toks, logitss, cache0 = draft_propose(
                dcfg, dparams, embed_params, dcache, h_last, first_logits,
                gamma, greedy=greedy, key=key, keys=keys)
        else:
            toks, logitss, _ = draft_propose(
                dcfg, dparams, embed_params, dcache, h_last, masked,
                gamma, greedy=True)
        masked = masked.at[bidx, toks[:, 0]].set(attn.NEG_INF)
        toks_all.append(toks)
        logits_all.append(logitss)
    tokens = jnp.stack(toks_all, axis=1)              # (B, w, γ)
    logits = jnp.stack(logits_all, axis=1)            # (B, w, γ, V)
    return tokens, logits, cache0


def reset_propose(dcache, gamma: int):
    """Roll the speculative lengths back after verification."""
    return dict(dcache, lengths=dcache["lengths"] - gamma)


def seed_prompt_pairs(dcfg: ModelConfig, dparams, embed_params, dcache,
                      captures, tokens, pad):
    """The draft 'prefill' recipe, in one place: set the cache's pad and
    ingest the prompt pairs (caps[i], t_{i+1}) for i < S-1 so the draft
    has full context before the first propose.  Every seeding path (wave
    prologue, slot refill, offline tools) must go through this — the
    pair/advance convention here is load-bearing for the refilled-slot
    == served-alone parity."""
    b, s, _ = captures.shape
    dcache = dict(dcache, pad=pad)
    _, _, dcache = draft_extend(
        dcfg, dparams, embed_params, dcache,
        captures[:, :s - 1], tokens[:, 1:],
        jnp.full((b,), s - 1, jnp.int32))
    return dcache


def seed_chunk_pairs(dcfg: ModelConfig, dparams, embed_params, dcache,
                     captures, next_tokens, advance):
    """One chunk of the draft 'prefill': ingest the pairs
    (captures[:, j], next_tokens[:, j]) for j < advance.

    The chunked-refill pipeline splits ``seed_prompt_pairs`` across
    prompt chunks: chunk k passes its own target captures plus the
    *lookahead-shifted* token columns (token i+1 for capture i — the
    host slices them from the full prompt, so the chunk boundary never
    needs a device-side shift).  ``advance`` is ``chunk_width`` for
    interior chunks and ``chunk_width - 1`` for the final chunk (pair
    S-1 does not exist); trailing columns are scratch and get
    overwritten, exactly as in ``draft_extend``.  The caller must have
    set ``dcache['pad']`` before the first chunk (``seed_prompt_pairs``
    does the same).  Chunked == one-shot seeding is bitwise on the
    valid cache region (see ``_fuse_inputs``)."""
    _, _, dcache = draft_extend(dcfg, dparams, embed_params, dcache,
                                captures, next_tokens, advance)
    return dcache


def seed_refill_cache(dcfg: ModelConfig, dparams, embed_params, captures,
                      tokens, pad, max_len: int):
    """Build a fresh draft cache for a refill batch and seed it — the
    per-slot equivalent of the wave prologue's draft seed, batched over
    the refilled slots only.

    captures: (R, S, 3D) target prefill captures; tokens: (R, S) padded
    prompt; pad: (R,) left-pad lengths.  Returns the seeded cache
    (R-batch), ready to be scattered into the live cache lanes."""
    dcache = init_draft_cache(dcfg, captures.shape[0], max_len)
    return seed_prompt_pairs(dcfg, dparams, embed_params, dcache,
                             captures, tokens, pad)


def scatter_batch_rows(live, new, mask, src, axis: int = 0):
    """Overwrite the batch rows of ``live`` selected by ``mask`` with
    rows gathered from ``new`` at ``src``; batch dimension at ``axis``.

    A gather+where instead of a scatter: the refill count varies per
    call but the live batch is fixed, so the compiled graph has fixed
    shapes and never depends on scatter ordering.  ``src`` is arbitrary
    where ``mask`` is False."""
    rows = jnp.take(new, src, axis=axis)
    shp = [1] * rows.ndim
    shp[axis] = mask.shape[0]
    return jnp.where(mask.reshape(shp), rows.astype(live.dtype), live)


def scatter_draft_rows(live, new, mask, src):
    """Replace the masked batch lanes of a live draft cache with lanes of
    a refill-batch cache (all draft-cache leaves carry batch at axis 0)."""
    return jax.tree.map(
        lambda l, n: scatter_batch_rows(l, n, mask, src, axis=0),
        live, new)


def scatter_draft_rows_paged(live, new, mask, src):
    """Paged twin of ``scatter_draft_rows``: ``live`` is a paged draft
    cache (pools + ``tbl``); ``new`` is a dense R-batch staging cache.
    K/V rows are written *through* the live block table (unmasked lanes
    route to the trash page); lengths/pad scatter as rows; the table
    itself is host-authoritative and passes through unchanged."""
    from repro.core import paging
    tbl = live["tbl"]
    out = dict(live)
    for leaf in ("k", "v"):
        rows = jnp.take(new[leaf], src, axis=0)      # (B, W, Hk, D)
        out[leaf] = paging.write_rows_paged(live[leaf], tbl, rows, mask)
    for leaf in ("lengths", "pad"):
        out[leaf] = scatter_batch_rows(live[leaf], new[leaf], mask, src,
                                       axis=0)
    return out


def reseed_draft_rows_from_ring(dcfg: ModelConfig, dparams, embed_params,
                                dcache, cap_feats, cap_toks, cap_count):
    """Rebuild the trailing draft-cache K/V rows under new ``dparams``
    from the rolling capture ring (deploy-time in-place re-seed).

    The draft's K/V at cache slot p is a pure per-position function of
    the ingested pair (f_p, u_p) and its RoPE position, so the last
    ``n = min(cap_count, W)`` slots — exactly the pairs the ring holds —
    can be recomputed exactly for a freshly deployed draft.  Slots older
    than the window (and the prompt-seed region) keep the previous
    draft's K/V: token streams stay correct either way (the target
    verifies every draft), this only restores the new draft's acceptance
    gain on resident lanes immediately instead of at lane retirement.

    cap_feats: (B, W, 3D) ring of pair features; cap_toks: (B, W) ring
    of pair tokens; cap_count: (B,) pairs ingested since lane admission
    (ring write head).  Returns the re-seeded draft cache."""
    b, w = cap_toks.shape
    dt = dcfg.act_dtype
    lengths = dcache["lengths"]
    n = jnp.minimum(cap_count, w)
    j = jnp.arange(w)[None, :]
    slot = ((cap_count - n)[:, None] + j) % w      # ring → time order
    feats = jnp.take_along_axis(cap_feats, slot[..., None], axis=1)
    toks = jnp.take_along_axis(cap_toks, slot, axis=1)
    start = lengths - n
    x = _fuse_inputs(dcfg, dparams, feats, embed(embed_params, toks, dt))
    # run the decode layer purely for its K/V cache writes: entries land
    # at slots start + [0..W) with the exact RoPE positions the original
    # ingestion used (lengths=start, same pad); the attention output and
    # any out-of-range scratch writes are discarded
    _, kc, vc = _layer(dcfg, dparams, x,
                       jnp.zeros_like(dcache["k"]),
                       jnp.zeros_like(dcache["v"]),
                       start, dcache["pad"])
    pos = jnp.arange(dcache["k"].shape[1])[None, :]
    sel = ((pos >= start[:, None])
           & (pos < lengths[:, None]))[..., None, None]
    return dict(dcache,
                k=jnp.where(sel, kc, dcache["k"]),
                v=jnp.where(sel, vc, dcache["v"]))


def reseed_draft_rows_from_ring_paged(dcfg: ModelConfig, dparams,
                                      embed_params, dcache, cap_feats,
                                      cap_toks, cap_count, max_len: int):
    """Paged twin of ``reseed_draft_rows_from_ring``: recompute the
    ring-covered draft K/V rows in a dense scratch cache, then write
    them back through the lane block table (``dcache["tbl"]``) into the
    page pools.  Row values are identical to the dense re-seed — the
    draft layer runs on the same (B, W) fused inputs at the same RoPE
    positions — so paged+reseed streams stay bitwise equal to
    dense+reseed ones.  This is what lifts the PR 6 reseed_window x
    paging exclusivity: deploy-time re-seed writes through tables like
    any other draft-cache commit."""
    from repro.core import paging

    b, w = cap_toks.shape
    dt = dcfg.act_dtype
    lengths = dcache["lengths"]
    pool_k, pool_v = dcache["k"], dcache["v"]
    page_size = pool_k.shape[1]
    trash = pool_k.shape[0] - 1
    n = jnp.minimum(cap_count, w)
    j = jnp.arange(w)[None, :]
    slot = ((cap_count - n)[:, None] + j) % w      # ring → time order
    feats = jnp.take_along_axis(cap_feats, slot[..., None], axis=1)
    toks = jnp.take_along_axis(cap_toks, slot, axis=1)
    start = lengths - n
    x = _fuse_inputs(dcfg, dparams, feats, embed(embed_params, toks, dt))
    zeros = jnp.zeros((b, max_len) + pool_k.shape[2:], pool_k.dtype)
    _, kc, vc = _layer(dcfg, dparams, x, zeros, jnp.zeros_like(zeros),
                       start, dcache["pad"])
    # the layer wrote the W recomputed rows at positions start + [0, W);
    # gather exactly the n valid ones per lane and commit them through
    # the block table (invalid columns route to the trash page)
    pos = start[:, None] + j                        # (B, W)
    idx = jnp.clip(pos, 0, max_len - 1)
    rows_k = jnp.take_along_axis(kc, idx[..., None, None], axis=1)
    rows_v = jnp.take_along_axis(vc, idx[..., None, None], axis=1)
    valid = j < n[:, None]
    page, pslot = paging.page_slot(dcache["tbl"], page_size, pos, trash,
                                   valid=valid)
    return dict(dcache,
                k=pool_k.at[page, pslot].set(rows_k.astype(pool_k.dtype)),
                v=pool_v.at[page, pslot].set(rows_v.astype(pool_v.dtype)))


# ------------------------------------------------------------- training
def draft_train_loss(dcfg: ModelConfig, dparams, embed_params, feats, tokens,
                     *, ttt: bool = True, mask=None):
    """EAGLE-3 training loss on captured signals.

    Signal convention (SignalStore / draft_extend): pair i is
    (f_i, u_i) where f_i is the target capture at a committed position
    and u_i the token that followed it.  Draft input at i:
    (f_i, e(u_i)); label u_{i+1}.  The TTT term replays with the draft's
    own hidden as the feature (chain-step distribution matching).
    Returns (loss, metrics{accuracy}).
    """
    dt = dcfg.act_dtype
    b, s, _ = feats.shape
    f_in = feats[:, :s - 1]
    tok_in = tokens[:, :s - 1]
    labels = tokens[:, 1:]
    tok_emb = embed(embed_params, tok_in, dt)
    x = _fuse_inputs(dcfg, dparams, f_in, tok_emb)
    x = _layer_full(dcfg, dparams, x)
    h = rmsnorm(dparams["final_norm"], x, dcfg.norm_eps)
    logits = _head(dcfg, dparams, h)

    if mask is None:
        m = jnp.ones(labels.shape, jnp.float32)
    else:
        m = mask[:, 1:].astype(jnp.float32)

    def ce(lg, lb, mm):
        logz = jax.nn.logsumexp(lg, axis=-1)
        ll = jnp.take_along_axis(lg, lb[..., None], axis=-1)[..., 0]
        return ((logz - ll) * mm).sum() / jnp.maximum(mm.sum(), 1.0)

    loss = ce(logits, labels, m)
    acc = (((logits.argmax(-1) == labels) * m).sum()
           / jnp.maximum(m.sum(), 1.0))
    if ttt and s >= 3:
        # step-2 (TTT): feature = draft's own hidden at i, token u_{i+1},
        # label u_{i+2} — matches the propose-chain input distribution
        f2 = h[:, :-1]
        tok2 = tokens[:, 1:s - 1]
        lab2 = tokens[:, 2:]
        m2 = m[:, 1:]
        x2 = _fuse_inputs(dcfg, dparams, f2, embed(embed_params, tok2, dt))
        x2 = _layer_full(dcfg, dparams, x2)
        h2 = rmsnorm(dparams["final_norm"], x2, dcfg.norm_eps)
        loss = loss + 0.5 * ce(_head(dcfg, dparams, h2), lab2, m2)
    return loss, {"accuracy": acc}
