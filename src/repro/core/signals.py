"""Zero-overhead training-signal extraction (paper §3.2 + Fig. 3).

The target's capture features (concatenated low/mid/high hidden states)
are produced *inside* the already-running prefill/verify step — zero extra
forward passes (TIDE's C2 contribution).  This module is the host side:
a double-buffered ring that receives (features, tokens, mask) for accepted
positions, overlapping device→host transfer with the next step (JAX
dispatch is asynchronous; ``jax.device_get`` on the previous step's
donated outputs runs while the next step computes), and spills full
buffers to the shared store consumed by the training engine.
"""
from __future__ import annotations

import dataclasses
import os
import threading
from typing import Dict, List, Optional

import jax
import numpy as np


@dataclasses.dataclass
class SignalBatch:
    """One training sample: a contiguous token window with its features."""
    feats: np.ndarray       # (S, 3D)
    tokens: np.ndarray      # (S,)


# One schema for every serialized signal container: the offline .npz
# spill shards (``SignalStore.spill``/``load_shard``) and the fleet wire
# frames (``repro.fleet.wire.signals_payload``) both carry exactly this
# key layout, so a spilled shard can be replayed over the wire and a
# captured wire payload can be written down as a shard.  Per-batch keys
# (instead of one stacked array) keep the round trip lossless: window
# lengths may be ragged (residual windows at stream end) and dtypes are
# preserved exactly as captured.
SIGNAL_SCHEMA = "tide-signals/v1"


def pack_batches(batches: List[SignalBatch]) -> Dict[str, np.ndarray]:
    """Serialize batches into a flat ``{key: array}`` dict (the shared
    shard/wire schema).  Lossless: per-batch arrays keep their own
    shapes and dtypes; ``__schema__``/``__n__`` tag and count them."""
    out: Dict[str, np.ndarray] = {
        "__schema__": np.asarray(SIGNAL_SCHEMA),
        "__n__": np.asarray(len(batches), np.int64),
    }
    for i, b in enumerate(batches):
        out[f"feats_{i:06d}"] = np.asarray(b.feats)
        out[f"tokens_{i:06d}"] = np.asarray(b.tokens)
    return out


def unpack_batches(arrays) -> List[SignalBatch]:
    """Inverse of ``pack_batches`` (accepts any mapping of arrays — an
    open .npz file or a plain dict).  Validates the schema tag and that
    every counted batch is present; also accepts the legacy pre-schema
    stacked-shard layout (``feats``/``tokens`` only) for old shards."""
    keys = set(getattr(arrays, "files", None) or arrays.keys())
    if "__schema__" not in keys:
        if not {"feats", "tokens"} <= keys:
            raise ValueError(f"not a signal shard (keys {sorted(keys)})")
        feats, tokens = arrays["feats"], arrays["tokens"]   # legacy stack
        return [SignalBatch(feats=np.asarray(feats[i]),
                            tokens=np.asarray(tokens[i]))
                for i in range(feats.shape[0])]
    schema = str(np.asarray(arrays["__schema__"]))
    if schema != SIGNAL_SCHEMA:
        raise ValueError(f"unknown signal schema {schema!r} "
                         f"(expected {SIGNAL_SCHEMA!r})")
    n = int(np.asarray(arrays["__n__"]))
    out = []
    for i in range(n):
        fk, tk = f"feats_{i:06d}", f"tokens_{i:06d}"
        if fk not in keys or tk not in keys:
            raise ValueError(f"truncated signal shard: batch {i}/{n} "
                             "missing")
        out.append(SignalBatch(feats=np.asarray(arrays[fk]),
                               tokens=np.asarray(arrays[tk])))
    return out


def load_shard(path: str) -> List[SignalBatch]:
    """Load one spilled .npz shard back into batches (lossless inverse
    of ``SignalStore.spill``; legacy stacked shards still load)."""
    with np.load(path, allow_pickle=False) as data:
        return unpack_batches(data)


class SignalStore:
    """The 'shared storage' between the serving and training engines.

    In-memory FIFO with an optional .npz spill directory; the training
    engine polls ``drain``/``peek_count``.  Thread-safe (the serving loop
    and trainer may run in different threads in the live demo).
    """

    def __init__(self, spill_dir: Optional[str] = None,
                 max_samples: int = 100_000):
        self._lock = threading.Lock()
        self._buf: List[SignalBatch] = []
        self.spill_dir = spill_dir
        self.max_samples = max_samples
        self.total_added = 0
        self.total_bytes = 0
        if spill_dir:
            os.makedirs(spill_dir, exist_ok=True)

    def add(self, batch: SignalBatch):
        with self._lock:
            self._buf.append(batch)
            self.total_added += 1
            self.total_bytes += batch.feats.nbytes + batch.tokens.nbytes
            if len(self._buf) > self.max_samples:
                self._buf.pop(0)

    def peek_count(self) -> int:
        with self._lock:
            return len(self._buf)

    def drain(self, n: Optional[int] = None) -> List[SignalBatch]:
        with self._lock:
            if n is None:
                out, self._buf = self._buf, []
            else:
                out, self._buf = self._buf[:n], self._buf[n:]
            return out

    def spill(self, tag: str):
        """Flush the buffer to a schema-tagged .npz shard
        (offline-training parity).  Lossless and versioned: the shard
        uses the ``pack_batches`` schema (per-batch keys, exact shapes
        and dtypes, ``__schema__`` tag), so ragged residual windows
        survive and ``load_shard``/``load`` restore the batches
        bit-exactly."""
        if not self.spill_dir:
            return None
        batches = self.drain()
        if not batches:
            return None
        path = os.path.join(self.spill_dir, f"signals_{tag}.npz")
        np.savez_compressed(path, **pack_batches(batches))
        return path

    def load(self, path: str) -> int:
        """Re-ingest a spilled shard (inverse of ``spill``).  Returns
        the number of batches added."""
        batches = load_shard(path)
        for b in batches:
            self.add(b)
        return len(batches)


class SignalExtractor:
    """Per-request sliding windows of accepted-position signals.

    The serving engine calls ``offer`` each step with the step outputs
    (still on device — retrieval is deferred one step so the D2H copy of
    step t overlaps with the compute of step t+1, the paper's Fig. 3
    overlap, expressed through JAX's async dispatch).
    """

    def __init__(self, store: SignalStore, window: int = 64,
                 feat_dim: int = 0):
        self.store = store
        self.window = window
        self._pending = None     # device arrays from the previous step
        self._acc: Dict[int, List] = {}   # rid -> [(feat, tok), ...]
        self.enabled = True

    def reset(self):
        """Drop pending device arrays and partial windows (fresh run)."""
        self._pending = None
        self._acc = {}
        self.enabled = True

    def offer(self, rids, feats, tokens, mask):
        """feats (B,T,3D), tokens (B,T), mask (B,T) — device arrays for the
        just-dispatched step; the previous step's arrays are collected now
        (they are guaranteed complete once this step is enqueued)."""
        prev, self._pending = self._pending, (list(rids), feats, tokens, mask)
        if prev is not None:
            self._collect(*prev)

    # ------------------------------------------------- superstep path
    def ingest_packed(self, rids, feats, tokens, counts):
        """Ingest one round of kernel-packed signals (host arrays).

        Row layout per the ``extract_pack`` kernel: accepted entries
        compacted to the front — ``counts[i]`` valid rows of
        ``feats[i]``/``tokens[i]`` for request ``rids[i]``, in original
        step order, so windows match the per-step ``offer`` path
        byte-for-byte.  Rows are copied out: a view would pin the whole
        superstep telemetry buffer until the window fills."""
        if not self.enabled:
            return
        for i, rid in enumerate(rids):
            n = int(counts[i])
            if n == 0:
                continue
            acc = self._acc.setdefault(rid, [])
            acc.extend(zip(np.array(feats[i, :n]), np.array(tokens[i, :n])))
            if len(acc) >= self.window:
                self._emit(rid)

    def flush(self):
        if self._pending is not None:
            prev, self._pending = self._pending, None
            self._collect(*prev)
        # emit all residual windows (end of workload)
        for rid in list(self._acc):
            self._emit(rid, force=True)

    def _collect(self, rids, feats, tokens, mask):
        if not self.enabled:
            return
        f = np.asarray(jax.device_get(feats))
        t = np.asarray(jax.device_get(tokens))
        m = np.asarray(jax.device_get(mask))
        for i, rid in enumerate(rids):
            sel = m[i].astype(bool)
            if not sel.any():
                continue
            acc = self._acc.setdefault(rid, [])
            acc.extend(zip(f[i][sel], t[i][sel]))
            if len(acc) >= self.window:
                self._emit(rid)

    def _emit(self, rid, force: bool = False):
        acc = self._acc.get(rid, [])
        while len(acc) >= self.window:
            chunk, acc = acc[:self.window], acc[self.window:]
            self.store.add(SignalBatch(
                feats=np.stack([c[0] for c in chunk]),
                tokens=np.array([c[1] for c in chunk], np.int32)))
        if force and len(acc) >= 8:   # short residual windows still usable
            self.store.add(SignalBatch(
                feats=np.stack([c[0] for c in acc]),
                tokens=np.array([c[1] for c in acc], np.int32)))
            acc = []
        self._acc[rid] = acc
        if force:
            self._acc.pop(rid, None)


def storage_bytes_per_token(cfg) -> int:
    """Hidden-state bytes stored per token (3 capture layers, bf16) —
    the per-token cost behind paper Table 1."""
    return 3 * cfg.d_model * 2
