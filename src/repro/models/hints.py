"""Activation-sharding hints (§Perf optimization layer).

Model code is mesh-agnostic; under a production mesh, XLA's sharding
propagation sometimes picks pathological layouts (full rematerialization
of scattered KV caches, all-gathered MoE dispatch intermediates).  The
launcher can *activate* a (mesh, rules) context; model code then marks
key intermediates with ``hint(x, logical_axes)`` which lowers to
``with_sharding_constraint`` — a no-op when no context is active (tests,
single-device demo).
"""
from __future__ import annotations

import contextlib

import jax

_CTX = {"mesh": None, "rules": None}


@contextlib.contextmanager
def activate(mesh, rules):
    prev = dict(_CTX)
    _CTX.update(mesh=mesh, rules=rules)
    try:
        yield
    finally:
        _CTX.update(prev)


@contextlib.contextmanager
def suspend():
    """Disable hints while tracing a shard_map region (mesh axes are
    manual there; with_sharding_constraint over them is illegal)."""
    prev = dict(_CTX)
    _CTX.update(mesh=None, rules=None)
    try:
        yield
    finally:
        _CTX.update(prev)


def active() -> bool:
    return _CTX["mesh"] is not None


def hint(x, logical, force: bool = False):
    """x: array/tracer; logical: tuple of logical axis names (or None).

    No-op when the rules resolve to nothing (constraining to a fully
    replicated spec would *force* replication — worse than leaving XLA
    free to propagate), unless ``force`` — used by weight-gather hints
    where replication IS the intent."""
    mesh, rules = _CTX["mesh"], _CTX["rules"]
    if mesh is None:
        return x
    from jax.sharding import NamedSharding
    from repro.launch.sharding import spec_for
    spec = spec_for(x.shape, logical, mesh, rules)
    if not force and all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def weight_gather(w, tp_axes):
    """ZeRO-3 use-site weight gather (§Perf H-C3): constrain a weight to
    its tensor-parallel-only sharding (FSDP axis dropped), so XLA gathers
    the (small) weight over the data axis instead of all-reducing the
    (huge) activation output of a contraction against the sharded dim.
    ``tp_axes``: logical axes with the FSDP/embed entries already None.

    Measured effect (EXPERIMENTS.md §Perf H-C3): memory term −3× on
    train shapes, but per-microbatch re-gathers under remat cost more
    ICI than the activation all-reduces they remove — so rule tables can
    opt out via ``__weight_gather__: False`` (training does)."""
    rules = _CTX["rules"]
    if rules is not None and not rules.get("__weight_gather__", True):
        return w
    return hint(w, tp_axes, force=True)
