"""Shared layers: RMSNorm, FFNs, embeddings — functional, spec-declared."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, FFN_SWIGLU, FFN_GELU
from repro.models.param import ParamSpec

# Logical axis names (mapped to mesh axes in launch/sharding.py).
EMBED = "embed"      # d_model dim of weights (FSDP-sharded)
MLP = "mlp"          # ffn hidden dim (tensor-parallel)
HEADS = "heads"      # attention head dim (tensor-parallel)
KV_HEADS = "kv_heads"
QKV = "qkv"          # per-head feature dim (replicated)
VOCAB = "vocab"      # vocab dim (tensor-parallel)
EXPERTS = "experts"  # MoE expert dim (expert-parallel)
LAYERS = "layers"    # stacked scan dim (replicated)
STATE = "state"      # ssm state dims (replicated)


def rmsnorm_specs(d: int) -> dict:
    return {"scale": ParamSpec((d,), (EMBED,), init="ones")}


def rmsnorm(params, x, eps: float):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def ffn_specs(cfg: ModelConfig, kind: str, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, (d_ff or cfg.d_ff)
    if kind == FFN_SWIGLU:
        return {
            "w_gate": ParamSpec((d, f), (EMBED, MLP)),
            "w_up": ParamSpec((d, f), (EMBED, MLP)),
            "w_down": ParamSpec((f, d), (MLP, EMBED)),
        }
    if kind == FFN_GELU:
        return {
            "w_up": ParamSpec((d, f), (EMBED, MLP)),
            "b_up": ParamSpec((f,), (MLP,), init="zeros"),
            "w_down": ParamSpec((f, d), (MLP, EMBED)),
            "b_down": ParamSpec((d,), (EMBED,), init="zeros"),
        }
    raise ValueError(kind)


def ffn(params, x, kind: str):
    from repro.models.hints import weight_gather as wg
    dt = x.dtype
    if kind == FFN_SWIGLU:
        g = x @ wg(params["w_gate"].astype(dt), (None, MLP))
        u = x @ wg(params["w_up"].astype(dt), (None, MLP))
        return (jax.nn.silu(g) * u) @ wg(params["w_down"].astype(dt),
                                         (MLP, None))
    if kind == FFN_GELU:
        h = jax.nn.gelu(x @ wg(params["w_up"].astype(dt), (None, MLP))
                        + params["b_up"].astype(dt), approximate=True)
        return (h @ wg(params["w_down"].astype(dt), (MLP, None))
                + params["b_down"].astype(dt))
    raise ValueError(kind)


def embed_specs(cfg: ModelConfig) -> dict:
    specs = {"tok": ParamSpec((cfg.vocab_size, cfg.d_model), (VOCAB, EMBED),
                              init="embed")}
    return specs


def embed(params, tokens, dtype):
    return params["tok"].astype(dtype)[tokens]


def head_specs(cfg: ModelConfig) -> dict:
    if cfg.tie_embeddings:
        return {}
    return {"w": ParamSpec((cfg.d_model, cfg.vocab_size), (EMBED, VOCAB))}


def lm_head(params, embed_params, x, tie: bool):
    from repro.models.hints import weight_gather as wg
    if tie:
        return x @ embed_params["tok"].astype(x.dtype).T
    return x @ wg(params["w"].astype(x.dtype), (None, VOCAB))
