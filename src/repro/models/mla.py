"""Multi-head Latent Attention (DeepSeek-V3, arXiv:2412.19437).

KV is compressed into a per-token latent c_kv (kv_lora_rank) plus a single
shared RoPE key (qk_rope_head_dim); the decode path uses the *absorbed*
formulation (W_uk folded into the query, W_uv applied after the attention
read) so the cache stays in latent space — the TPU-native deployment form.
Prefill uses the expanded form for clarity; both are cross-checked in tests.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.param import ParamSpec
from repro.models.layers import EMBED, HEADS, QKV, rmsnorm, rmsnorm_specs
from repro.models.attention import apply_rope, NEG_INF
from repro.models.hints import weight_gather as wg

LATENT = "latent"


def mla_specs(cfg: ModelConfig) -> dict:
    d, h = cfg.d_model, cfg.num_heads
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    return {
        "wq_a": ParamSpec((d, qr), (EMBED, LATENT)),
        "q_norm": rmsnorm_specs(qr)["scale"],
        "wq_b": ParamSpec((qr, h, dn + dr), (LATENT, HEADS, QKV)),
        "wkv_a": ParamSpec((d, kr + dr), (EMBED, LATENT)),
        "kv_norm": rmsnorm_specs(kr)["scale"],
        "wkv_b": ParamSpec((kr, h, dn + dv), (LATENT, HEADS, QKV)),
        "wo": ParamSpec((h, dv, d), (HEADS, QKV, EMBED)),
    }


def _queries(cfg: ModelConfig, params, x, positions):
    dt = x.dtype
    qa = rmsnorm({"scale": params["q_norm"]},
                 x @ wg(params["wq_a"].astype(dt), (None, LATENT)),
                 cfg.norm_eps)
    q = jnp.einsum("btr,rhk->bthk", qa,
                   wg(params["wq_b"].astype(dt), (None, HEADS, None)))
    q_nope = q[..., :cfg.qk_nope_head_dim]
    q_rope = apply_rope(q[..., cfg.qk_nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def latent_kv(cfg: ModelConfig, params, x, positions):
    """Per-token latent cache entries: (c_kv normed, k_rope)."""
    dt = x.dtype
    kv = x @ wg(params["wkv_a"].astype(dt), (None, LATENT))
    ckv = rmsnorm({"scale": params["kv_norm"]}, kv[..., :cfg.kv_lora_rank],
                  cfg.norm_eps)
    k_rope = apply_rope(kv[..., None, cfg.kv_lora_rank:], positions,
                        cfg.rope_theta)[:, :, 0]        # (B, T, dr) shared head
    return ckv, k_rope


def _scale(cfg: ModelConfig):
    return 1.0 / jnp.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)


def mla_prefill(cfg: ModelConfig, params, x, positions, pad=None):
    """Expanded-form causal MLA. Returns (out, (ckv, k_rope)) for the cache.

    positions: (B, S) RoPE positions; causality is by sequence index, with
    an optional left-pad mask.
    """
    dt = x.dtype
    b, s, _ = x.shape
    q_nope, q_rope = _queries(cfg, params, x, positions)
    ckv, k_rope = latent_kv(cfg, params, x, positions)
    kv = jnp.einsum("bsr,rhk->bshk", ckv,
                    wg(params["wkv_b"].astype(dt), (None, HEADS, None)))
    k_nope = kv[..., :cfg.qk_nope_head_dim]
    v = kv[..., cfg.qk_nope_head_dim:]
    if pad is None and s > cfg.attn_block_kv:
        # long-sequence path: expand to combined (nope ‖ rope) q/k and run
        # blockwise flash attention (flash scales by sqrt(dn + dr) = _scale)
        from repro.models.attention import flash_prefill
        h = cfg.num_heads
        qc = jnp.concatenate([q_nope, q_rope], axis=-1)
        kc = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None],
                                      (b, s, h, cfg.qk_rope_head_dim))],
            axis=-1)
        o = flash_prefill(qc, kc, v, causal=True,
                          block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv)
        out = jnp.einsum("bthk,hkd->btd", o,
                     wg(params["wo"].astype(dt), (HEADS, None, None)))
        return out, (ckv, k_rope)
    scores = (jnp.einsum("bthk,bshk->bhts", q_nope, k_nope)
              + jnp.einsum("bthk,bsk->bhts", q_rope, k_rope)) * _scale(cfg)
    qpos = jnp.arange(s)[None, None, :, None]
    kpos = jnp.arange(s)[None, None, None, :]
    mask = kpos <= qpos
    if pad is not None:
        mask = mask & (kpos >= pad[:, None, None, None])
    scores = jnp.where(mask, scores.astype(jnp.float32), NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(dt)
    o = jnp.einsum("bhts,bshk->bthk", p, v)
    out = jnp.einsum("bthk,hkd->btd", o,
                     wg(params["wo"].astype(dt), (HEADS, None, None)))
    return out, (ckv, k_rope)


def mla_decode(cfg: ModelConfig, params, x, ckv_cache, krope_cache, lengths,
               pad=None):
    """Absorbed-form decode: attention runs entirely in latent space.

    x: (B, T, D) new tokens; ckv_cache: (B, Smax, kv_lora_rank);
    krope_cache: (B, Smax, qk_rope_head_dim). New latents are scattered in.
    """
    dt = x.dtype
    b, t, _ = x.shape
    positions = lengths[:, None] + jnp.arange(t)[None, :]
    rope_pos = positions if pad is None else positions - pad[:, None]
    q_nope, q_rope = _queries(cfg, params, x, rope_pos)
    ckv_new, krope_new = latent_kv(cfg, params, x, rope_pos)
    bidx = jnp.arange(b)[:, None].repeat(t, 1)
    sidx = positions
    ckv_cache = ckv_cache.at[bidx, sidx].set(ckv_new.astype(ckv_cache.dtype))
    krope_cache = krope_cache.at[bidx, sidx].set(
        krope_new.astype(krope_cache.dtype))
    # absorb W_uk into the query:  q_lat = q_nope @ W_uk  -> (B, T, H, kr)
    w_uk = wg(params["wkv_b"].astype(dt),
              (None, HEADS, None))[..., :cfg.qk_nope_head_dim]  # (kr, H, dn)
    q_lat = jnp.einsum("bthk,rhk->bthr", q_nope, w_uk)
    smax = ckv_cache.shape[1]
    scores = (jnp.einsum("bthr,bsr->bhts", q_lat, ckv_cache.astype(dt))
              + jnp.einsum("bthk,bsk->bhts", q_rope, krope_cache.astype(dt))
              ) * _scale(cfg)
    kpos = jnp.arange(smax)[None, None, None, :]
    mask = kpos <= positions[:, None, :, None]
    if pad is not None:
        mask = mask & (kpos >= pad[:, None, None, None])
    scores = jnp.where(mask, scores.astype(jnp.float32), NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(dt)
    o_lat = jnp.einsum("bhts,bsr->bthr", p, ckv_cache.astype(dt))
    # apply W_uv on the latent read:  (kr, H, dv)
    w_uv = wg(params["wkv_b"].astype(dt),
              (None, HEADS, None))[..., cfg.qk_nope_head_dim:]
    o = jnp.einsum("bthr,rhk->bthk", o_lat, w_uv)
    out = jnp.einsum("bthk,hkd->btd", o,
                     wg(params["wo"].astype(dt), (HEADS, None, None)))
    return out, (ckv_cache, krope_cache)
