"""shard_map MoE dispatch (§Perf H-B3): per-shard local sort + explicit
all-to-all — the production TPU expert-parallel path.

The SPMD `moe_sort` baseline routes with a *global* argsort/capacity
scatter, which XLA resolves with activation-sized gathers across the
mesh (the dominant collective of the MoE prefill shapes).  Here each
token shard:

  1. routes and sorts its *local* tokens (65k, not 1M),
  2. slots them into per-expert capacity buffers with *local* capacity
     C_loc = n_loc·k/E·cf,
  3. if experts are sharded over the token axis (expert parallelism):
     regroups the buffer expert-major with one ``all_to_all`` so each
     shard holds all shards' rows for *its* experts, runs its local
     experts, and ``all_to_all``s back,
  4. combines locally with gate weights.

Per-chip ICI traffic is 2 × (E·C_loc·D) ≈ 2 × n_loc·k·cf·D bytes — the
napkin in EXPERIMENTS.md §Perf (~75× less than the baseline's gathers).

Capacity-drop semantics differ from the global sort under load
imbalance (drops are per-shard here); tests check exact equality in the
no-drop regime and bounded disagreement under tight capacity.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.moe import _capacity, _experts_ffn, _route
from repro.models.layers import ffn
from repro.models.config import FFN_SWIGLU


def _shard_map_compat(f, *, mesh, in_specs, out_specs, check_vma=False):
    """``shard_map`` moved (experimental → jax.*) and renamed its
    replication-check kwarg (check_rep → check_vma) across JAX versions;
    resolve whichever this JAX provides."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=check_vma)


def _local_dispatch(cfg: ModelConfig, params, xf):
    """The local-shard part of moe_sort. xf: (n_loc, D)."""
    n, d = xf.shape
    dt = xf.dtype
    idx, gate, aux = _route(cfg, params, xf)
    k, e = cfg.experts_per_tok, cfg.num_experts
    cap = _capacity(cfg, n)
    flat_e = idx.reshape(-1)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(n * k) - starts[sorted_e]
    keep = rank < cap
    slot = jnp.where(keep, sorted_e * cap + rank, e * cap)
    token_of = order // k
    buf = jnp.zeros((e * cap + 1, d), dt)
    buf = buf.at[slot].set(xf[token_of].astype(dt), mode="drop")
    return buf[:e * cap].reshape(e, cap, d), (slot, token_of, order,
                                              gate, aux, cap)


def _local_combine(cfg: ModelConfig, ys, meta, n, d, dt):
    slot, token_of, order, gate, aux, cap = meta
    e = cfg.num_experts
    ysf = jnp.concatenate([ys.reshape(e * cap, d),
                           jnp.zeros((1, d), ys.dtype)])
    contrib = ysf[slot] * gate.reshape(-1)[order, None].astype(ys.dtype)
    out = jnp.zeros((n, d), dt).at[token_of].add(contrib.astype(dt))
    return out


def moe_shard_map(cfg: ModelConfig, params, x, mesh, *,
                  token_axes=("pod", "data"),
                  expert_axis: Optional[str] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, T, D) batch-sharded over ``token_axes``.  Expert weights
    either replicated (expert_axis=None) or sharded over ``expert_axis``
    (must be one of token_axes, expert-parallel).  Returns (out, aux)."""
    dt = x.dtype
    b, t, d = x.shape
    taxes = tuple(a for a in token_axes if a in mesh.axis_names)
    e = cfg.num_experts
    ep = expert_axis if (expert_axis and expert_axis in mesh.axis_names
                         and e % mesh.shape[expert_axis] == 0) else None
    nshard = mesh.shape[ep] if ep else 1

    def local(px, pw):
        xf = px.reshape(-1, d)
        n = xf.shape[0]
        xs, meta = _local_dispatch(cfg, pw, xf)        # (E, C_loc, D)
        if ep:
            # regroup expert-major: (nshard, E_loc, C_loc, D) --a2a-->
            # rows of MY experts from every shard
            e_loc, cap = e // nshard, xs.shape[1]
            xs = xs.reshape(nshard, e_loc, cap, d)
            xs = jax.lax.all_to_all(xs, ep, split_axis=0, concat_axis=0,
                                    tiled=False)
            # (nshard, E_loc, C_loc, D) -> (E_loc, nshard*C_loc, D)
            xs = xs.transpose(1, 0, 2, 3).reshape(e_loc, nshard * cap, d)
            ys = _experts_ffn(pw, xs, dt)              # local experts
            ys = ys.reshape(e_loc, nshard, cap, d).transpose(1, 0, 2, 3)
            ys = jax.lax.all_to_all(ys, ep, split_axis=0, concat_axis=0,
                                    tiled=False)
            ys = ys.reshape(e, cap, d)
        else:
            ys = _experts_ffn(pw, xs, dt)
        out = _local_combine(cfg, ys, meta, n, d, dt)
        aux = meta[4]
        if taxes:
            aux = jax.lax.pmean(aux, taxes)
        return out.reshape(px.shape), aux

    in_x = P(taxes if taxes else None)
    # expert weights: sharded on the expert dim iff expert-parallel
    def wspec(w):
        if w.ndim == 3 and w.shape[0] == e and ep:
            return P(ep)
        return P()
    wspecs = jax.tree.map(wspec, {k: v for k, v in params.items()
                                  if k != "shared"})
    shard_params = {k: params[k] for k in wspecs}
    from repro.models import hints
    with hints.suspend():     # mesh axes are manual inside shard_map
        out, aux = _shard_map_compat(
            local, mesh=mesh,
            in_specs=(in_x, wspecs),
            out_specs=(in_x, P()),
            check_vma=False,
        )(x, shard_params)
    if cfg.num_shared_experts:
        out = out + ffn(params["shared"], x, FFN_SWIGLU)
    return out, aux
