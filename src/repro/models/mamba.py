"""Mamba-1 selective SSM mixer (Jamba's sequence layer, arXiv:2403.19887).

Prefill uses a chunked associative scan (memory O(B·C·Di·N) per chunk);
decode advances the recurrence token-by-token over the γ+1 verify block and
returns per-step states so speculative rollback can select the accepted one.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.param import ParamSpec
from repro.models.layers import EMBED, MLP, STATE

CONV = "conv"


def mamba_specs(cfg: ModelConfig) -> dict:
    d, di, n, r, dc = (cfg.d_model, cfg.mamba_d_inner, cfg.mamba_d_state,
                       cfg.dt_rank, cfg.mamba_d_conv)
    return {
        "in_proj": ParamSpec((d, 2 * di), (EMBED, MLP)),
        "conv_w": ParamSpec((dc, di), (CONV, MLP), scale=1.0),
        "conv_b": ParamSpec((di,), (MLP,), init="zeros"),
        "x_proj": ParamSpec((di, r + 2 * n), (MLP, STATE)),
        "dt_w": ParamSpec((r, di), (STATE, MLP)),
        "dt_b": ParamSpec((di,), (MLP,), init="zeros"),
        "a_log": ParamSpec((di, n), (MLP, STATE), init="alog"),
        "d_skip": ParamSpec((di,), (MLP,), init="ones"),
        "out_proj": ParamSpec((di, d), (MLP, EMBED)),
    }


def _conv_full(params, x):
    """Causal depthwise conv over seq. x: (B, S, Di)."""
    dc = params["conv_w"].shape[0]
    w = params["conv_w"].astype(x.dtype)
    xp = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    s = x.shape[1]
    out = sum(xp[:, i:i + s] * w[i] for i in range(dc))
    return out + params["conv_b"].astype(x.dtype)


def _ssm_inputs(cfg: ModelConfig, params, xc):
    """From conv output xc (B, T, Di) derive (decay a, input b, C, D·x)."""
    dt_bc = xc @ params["x_proj"].astype(xc.dtype)
    r, n = cfg.dt_rank, cfg.mamba_d_state
    dt = jax.nn.softplus(dt_bc[..., :r] @ params["dt_w"].astype(xc.dtype)
                         + params["dt_b"].astype(xc.dtype))       # (B,T,Di)
    bmat = dt_bc[..., r:r + n]                                    # (B,T,N)
    cmat = dt_bc[..., r + n:]                                     # (B,T,N)
    a_coef = -jnp.exp(params["a_log"].astype(jnp.float32))        # (Di,N)
    dt32 = dt.astype(jnp.float32)
    a = jnp.exp(dt32[..., None] * a_coef)                         # (B,T,Di,N)
    b = (dt32[..., None] * bmat.astype(jnp.float32)[:, :, None, :]
         * xc.astype(jnp.float32)[..., None])                     # (B,T,Di,N)
    return a, b, cmat, dt


def _assoc(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a1 * a2, a2 * b1 + b2


def mamba_prefill(cfg: ModelConfig, params, x, pad=None
                  ) -> Tuple[jnp.ndarray, dict]:
    """x: (B, S, D). Returns (out, state) with state = {"h", "conv"}.
    pad: optional (B,) left-pad widths; padded steps leave the state
    untouched (decay 1, input 0)."""
    dt_ = x.dtype
    b, s, _ = x.shape
    di, n = cfg.mamba_d_inner, cfg.mamba_d_state
    if pad is not None:
        # zero padded positions so conv windows of the first real tokens
        # see zeros, exactly like the unpadded case
        vx = (jnp.arange(s)[None, :] >= pad[:, None])[..., None]
        x = jnp.where(vx, x, 0.0)
    from repro.models.hints import weight_gather as wg
    xz = x @ wg(params["in_proj"].astype(dt_), (None, MLP))
    xin, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_conv_full(params, xin))
    # §Perf H-C1: the associative scan makes O(log2 c_len) passes over the
    # (c_len, B, Di, N) fp32 chunk — smaller chunks cut HBM traffic per
    # element (c=64 -> 6 passes vs c=256 -> 8) at more scan iterations.
    c_len = min(cfg.chunk_len, s)
    while s % c_len:
        c_len -= 1
    nc = s // c_len
    a, bb, cmat, _ = _ssm_inputs(cfg, params, xc)
    if pad is not None:
        valid = (jnp.arange(s)[None, :] >= pad[:, None])[..., None, None]
        a = jnp.where(valid, a, 1.0)
        bb = jnp.where(valid, bb, 0.0)

    def chunk_step(h_in, ab):
        ac, bc = ab                                  # (C, B, Di, N)
        bc0 = bc.at[0].add(ac[0] * h_in)
        ah, bh = jax.lax.associative_scan(_assoc, (ac, bc0), axis=0)
        return bh[-1], bh                            # carry h, all prefix h

    a_c = a.transpose(1, 0, 2, 3).reshape(nc, c_len, b, di, n)
    b_c = bb.transpose(1, 0, 2, 3).reshape(nc, c_len, b, di, n)
    h0 = jnp.zeros((b, di, n), jnp.float32)
    h_last, hs = jax.lax.scan(chunk_step, h0, (a_c, b_c))
    hs = hs.reshape(s, b, di, n).transpose(1, 0, 2, 3)           # (B,S,Di,N)
    y = jnp.einsum("bsdn,bsn->bsd", hs, cmat.astype(jnp.float32))
    y = (y + params["d_skip"].astype(jnp.float32) * xc.astype(jnp.float32)
         ).astype(dt_)
    out = (y * jax.nn.silu(z)) @ wg(params["out_proj"].astype(dt_),
                                    (MLP, None))
    dc = cfg.mamba_d_conv
    conv_state = xin[:, -(dc - 1):, :] if s >= dc - 1 else \
        jnp.pad(xin, ((0, 0), (dc - 1 - s, 0), (0, 0)))
    return out, {"h": h_last, "conv": conv_state.astype(dt_)}


def mamba_decode(cfg: ModelConfig, params, x, state) -> Tuple[jnp.ndarray, dict]:
    """x: (B, T, D) verify block. Returns (out, states-per-step dict) where
    each state leaf has a leading T axis for speculative rollback."""
    dt_ = x.dtype
    b, t, _ = x.shape
    dc = cfg.mamba_d_conv
    xz = x @ params["in_proj"].astype(dt_)
    xin, z = jnp.split(xz, 2, axis=-1)

    def step(carry, xt):
        h, conv = carry                              # (B,Di,N), (B,dc-1,Di)
        win = jnp.concatenate([conv, xt[:, None]], axis=1)       # (B,dc,Di)
        w = params["conv_w"].astype(dt_)
        xc = jax.nn.silu(jnp.einsum("bcd,cd->bd", win, w)
                         + params["conv_b"].astype(dt_))
        a, bb, cmat, _ = _ssm_inputs(cfg, params, xc[:, None])
        h_new = a[:, 0] * h + bb[:, 0]
        y = jnp.einsum("bdn,bn->bd", h_new, cmat[:, 0].astype(jnp.float32))
        y = y + params["d_skip"].astype(jnp.float32) * xc.astype(jnp.float32)
        conv_new = win[:, 1:]
        return (h_new, conv_new), (y.astype(dt_), h_new, conv_new)

    (h_f, conv_f), (ys, hs, convs) = jax.lax.scan(
        step, (state["h"], state["conv"]), xin.transpose(1, 0, 2))
    y = ys.transpose(1, 0, 2)                                    # (B,T,Di)
    out = (y * jax.nn.silu(z)) @ params["out_proj"].astype(dt_)
    states = {"h": hs.transpose(1, 0, 2, 3),                     # (B,T,Di,N)
              "conv": convs.transpose(1, 0, 2, 3)}               # (B,T,dc-1,Di)
    return out, states


def select_state(states: dict, accept_idx) -> dict:
    """Pick the state at the accepted position. accept_idx: (B,) int32."""
    def pick(leaf):
        idx = accept_idx.reshape((-1,) + (1,) * (leaf.ndim - 1))
        return jnp.take_along_axis(leaf, idx, axis=1)[:, 0]
    return jax.tree.map(pick, states)
