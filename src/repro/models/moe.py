"""Mixture-of-Experts FFN with top-k routing.

Two dispatch implementations, cross-checked in tests:

* ``sort``  — production path: flatten (token, choice) assignments, sort by
  expert id, scatter into per-expert capacity slots, run a batched expert
  einsum, and combine with gather + gate weighting.  O(T·k·D) memory; the
  expert dim shards on the ``model``/expert axis (all-to-all inserted by SPMD).
* ``einsum`` — GShard-style dense one-hot dispatch (T, E, C) einsums;
  simple, fully SPMD-safe, memory-heavier.  Used as the oracle.

Supports shared experts (DeepSeek-V3) and a load-balance auxiliary loss.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.param import ParamSpec
from repro.models.layers import EMBED, MLP, EXPERTS, ffn_specs, ffn
from repro.models.config import FFN_SWIGLU


def moe_specs(cfg: ModelConfig) -> dict:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_hidden
    specs = {
        "router": ParamSpec((d, e), (EMBED, EXPERTS), scale=0.1),
        "w_gate": ParamSpec((e, d, f), (EXPERTS, EMBED, MLP)),
        "w_up": ParamSpec((e, d, f), (EXPERTS, EMBED, MLP)),
        "w_down": ParamSpec((e, f, d), (EXPERTS, MLP, EMBED)),
    }
    if cfg.num_shared_experts:
        specs["shared"] = ffn_specs(cfg, FFN_SWIGLU,
                                    cfg.moe_hidden * cfg.num_shared_experts)
    return specs


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(n_tokens * cfg.experts_per_tok / cfg.num_experts
            * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)   # round up to 8


def _route(cfg: ModelConfig, params, x):
    """Returns (topk_idx (N,k), topk_gate (N,k), aux_loss) for x (N, D)."""
    logits = (x.astype(jnp.float32)
              @ params["router"].astype(jnp.float32))        # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, cfg.experts_per_tok)    # (N, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss.
    e = cfg.num_experts
    me = probs.mean(0)                                       # (E,)
    one_hot = jax.nn.one_hot(idx[:, 0], e)                   # primary choice
    ce = one_hot.mean(0)
    aux = e * jnp.sum(me * ce) * cfg.router_aux_weight
    return idx, gate, aux


def _experts_ffn(params, xs, dtype):
    """xs: (E, C, D) -> (E, C, D) SwiGLU per expert.  Weight-gather hints
    pin the ZeRO-3 choice: gather the FSDP-sharded weight dim at use
    instead of all-reducing the (much larger) (E, C, F) activations
    (§Perf H-C3)."""
    from repro.models.hints import weight_gather as wg
    g = jnp.einsum("ecd,edf->ecf", xs,
                   wg(params["w_gate"].astype(dtype),
                      (EXPERTS, None, MLP)))
    u = jnp.einsum("ecd,edf->ecf", xs,
                   wg(params["w_up"].astype(dtype),
                      (EXPERTS, None, MLP)))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                      wg(params["w_down"].astype(dtype),
                         (EXPERTS, MLP, None)))


def moe_sort(cfg: ModelConfig, params, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sort-based dispatch. x: (B, T, D) -> (out, aux_loss)."""
    dt = x.dtype
    b, t, d = x.shape
    n = b * t
    xf = x.reshape(n, d)
    idx, gate, aux = _route(cfg, params, xf)
    k, e = cfg.experts_per_tok, cfg.num_experts
    cap = _capacity(cfg, n)
    flat_e = idx.reshape(-1)                                  # (N*k,)
    order = jnp.argsort(flat_e)                               # stable
    sorted_e = flat_e[order]
    # rank within expert among sorted assignments
    counts = jnp.bincount(flat_e, length=e)                   # (E,)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(n * k) - starts[sorted_e]
    keep = rank < cap
    slot = jnp.where(keep, sorted_e * cap + rank, e * cap)    # overflow bucket
    token_of = order // k                                     # source token
    # scatter tokens into (E*C [+1 overflow], D).  NOTE (§Perf, refuted
    # hypothesis H-B1): pinning the gathered rows to token sharding made
    # the collective term *worse* (2.42s -> 3.42s on granite prefill) —
    # the rows are expert-sorted, so forcing batch-order sharding inserts
    # an extra global resharding.  The winning fix is moe_shard_map
    # (local dispatch + explicit all-to-all); hints here stay off.
    buf = jnp.zeros((e * cap + 1, d), dt)
    buf = buf.at[slot].set(xf[token_of].astype(dt), mode="drop")
    xs = buf[:e * cap].reshape(e, cap, d)
    ys = _experts_ffn(params, xs, dt)
    ysf = jnp.concatenate([ys.reshape(e * cap, d), jnp.zeros((1, d), dt)])
    # combine: each assignment reads its slot, weighted by its gate
    contrib = ysf[slot] * gate.reshape(-1)[order, None].astype(dt)
    out = jnp.zeros((n, d), dt).at[token_of].add(contrib)
    out = out.reshape(b, t, d)
    if cfg.num_shared_experts:
        out = out + ffn(params["shared"], x, FFN_SWIGLU)
    return out, aux


def moe_einsum(cfg: ModelConfig, params, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """GShard one-hot dispatch oracle. x: (B, T, D) -> (out, aux_loss)."""
    dt = x.dtype
    b, t, d = x.shape
    n = b * t
    xf = x.reshape(n, d)
    idx, gate, aux = _route(cfg, params, xf)
    e, k = cfg.num_experts, cfg.experts_per_tok
    cap = _capacity(cfg, n)
    # position of each (token, choice) within its expert
    choice_oh = jax.nn.one_hot(idx, e, dtype=jnp.int32)       # (N, k, E)
    flat_oh = choice_oh.reshape(n * k, e)
    pos = jnp.cumsum(flat_oh, axis=0) - flat_oh               # (N*k, E)
    pos_in_e = (pos * flat_oh).sum(-1).reshape(n, k)          # (N, k)
    keep = pos_in_e < cap
    disp = (jax.nn.one_hot(idx, e) * keep[..., None]
            )[..., None] * jax.nn.one_hot(pos_in_e, cap)[:, :, None, :]
    disp = disp.sum(1)                                        # (N, E, C)
    xs = jnp.einsum("nd,nec->ecd", xf.astype(jnp.float32), disp).astype(dt)
    ys = _experts_ffn(params, xs, dt)
    comb = (disp * (gate[..., None, None]
                    * jax.nn.one_hot(idx, e)[..., None]).sum(1))
    out = jnp.einsum("nec,ecd->nd", comb, ys.astype(jnp.float32))
    out = out.astype(dt).reshape(b, t, d)
    if cfg.num_shared_experts:
        out = out + ffn(params["shared"], x, FFN_SWIGLU)
    return out, aux


def moe(cfg: ModelConfig, params, x, impl: str = "sort"):
    if impl == "einsum":
        return moe_einsum(cfg, params, x)
    if impl == "shard_map":
        # §Perf H-B3: local dispatch + explicit all-to-all; needs a mesh
        # (taken from the active hints context); falls back to the SPMD
        # sort path on a single device / outside a launcher context.
        from repro.models import hints
        mesh = hints._CTX["mesh"]
        if mesh is not None:
            from repro.models.moe_sm import moe_shard_map
            rules = hints._CTX["rules"] or {}
            erule = rules.get("experts")
            eaxis = None
            if isinstance(erule, str) and erule in mesh.axis_names \
                    and cfg.num_experts % mesh.shape[erule] == 0:
                eaxis = erule
            taxes = tuple(a for a in ("pod", "data")
                          if a in mesh.axis_names)
            return moe_shard_map(cfg, params, x, mesh, token_axes=taxes,
                                 expert_axis=eaxis)
    return moe_sort(cfg, params, x)
