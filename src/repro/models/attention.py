"""GQA attention: RoPE, blockwise-flash prefill (jnp), decode w/ KV cache,
sliding-window variants, and cross attention.

All functions are pure; the Pallas kernels in ``repro.kernels`` mirror the
prefill/decode entry points and are swapped in via ``cfg.use_pallas``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.param import ParamSpec
from repro.models.layers import EMBED, HEADS, KV_HEADS, QKV

NEG_INF = -1e30

# §Perf A/B switch: True (default) keeps attention operands in their
# storage dtype with fp32 MXU accumulation; False reproduces the
# baseline implementation that upcast K/V to fp32 (extra HBM traffic).
MIXED_PRECISION = True


# ---------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (B, T, H, D); positions: (B, T) int32."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                      # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (B, T, d/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------- helpers
def _expand_kv(k, q_per_kv: int):
    """(B, S, Hk, D) -> (B, S, Hk*G, D) by repeat (jnp path; einsum keeps it lazy)."""
    return jnp.repeat(k, q_per_kv, axis=2)


def _softcap(scores, cap: float):
    if cap and cap > 0.0:
        return cap * jnp.tanh(scores / cap)
    return scores


# -------------------------------------------------- full masked attention
def attend(q, k, v, mask, *, softcap: float = 0.0):
    """Reference masked attention.

    q: (B, T, Hq, D); k, v: (B, S, Hk, D); mask: broadcastable to
    (B, Hk, G, T, S) or (B, 1, 1, T, S). Returns (B, T, Hq, D).
    """
    b, t, hq, d = q.shape
    s, hk = k.shape[1], k.shape[2]
    g = hq // hk
    if MIXED_PRECISION:
        # operands stay in their storage dtype (bf16 on TPU); the MXU
        # accumulates in fp32 via preferred_element_type — avoids
        # materializing fp32 copies of the (huge) KV cache [§Perf H-A1]
        qr = q.reshape(b, t, hk, g, d)
        scores = jnp.einsum("btkgd,bskd->bkgts", qr, k,
                            preferred_element_type=jnp.float32
                            ) / jnp.sqrt(d)
        scores = _softcap(scores, softcap)
        scores = jnp.where(mask, scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgts,bskd->btkgd", p.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        return out.reshape(b, t, hq, d).astype(q.dtype)
    qf = q.astype(jnp.float32).reshape(b, t, hk, g, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("btkgd,bskd->bkgts", qf, kf) / jnp.sqrt(d)
    scores = _softcap(scores, softcap)
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", p, vf)
    return out.reshape(b, t, hq, d).astype(q.dtype)


def causal_mask(t: int, s: int, q_offset) -> jnp.ndarray:
    """(T, S) causal mask where query i sits at position q_offset + i."""
    qpos = q_offset + jnp.arange(t)[:, None]
    kpos = jnp.arange(s)[None, :]
    return kpos <= qpos


# ------------------------------------------- blockwise flash (jnp) prefill
def flash_prefill(q, k, v, *, causal: bool = True, window: int = 0,
                  block_q: int = 512, block_kv: int = 1024,
                  softcap: float = 0.0, seg_ids: Optional[jnp.ndarray] = None):
    """Memory-O(S·block) flash attention via lax.scan over KV blocks.

    q: (B, S, Hq, D), k/v: (B, S, Hk, D). Runs all query blocks against each
    KV block with an online-softmax carry — peak memory per step is
    (B, Hq, S, block_kv) scores instead of (B, Hq, S, S).
    """
    b, s, hq, d = q.shape
    hk = k.shape[2]
    dv = v.shape[-1]
    g = hq // hk
    bkv = min(block_kv, s)
    if s % bkv:
        # pad kv to a block multiple; padded keys masked out
        pad = bkv - s % bkv
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_valid = s
    else:
        pad = 0
        kv_valid = s
    nkv = k.shape[1] // bkv
    if MIXED_PRECISION:
        kb = k.reshape(b, nkv, bkv, hk, d)
        vb = v.reshape(b, nkv, bkv, hk, dv)
        qf = q.reshape(b, s, hk, g, d)
    else:
        kb = k.reshape(b, nkv, bkv, hk, d).astype(jnp.float32)
        vb = v.reshape(b, nkv, bkv, hk, dv).astype(jnp.float32)
        qf = q.astype(jnp.float32).reshape(b, s, hk, g, d)
    qpos = jnp.arange(s)

    def step(carry, inputs):
        m, l, acc = carry
        kblk, vblk, blk_idx = inputs
        kpos = blk_idx * bkv + jnp.arange(bkv)
        sc = jnp.einsum("bskgd,bukd->bkgsu", qf, kblk,
                        preferred_element_type=jnp.float32) / jnp.sqrt(d)
        sc = _softcap(sc, softcap)
        msk = kpos[None, :] < kv_valid
        if causal:
            msk = msk & (kpos[None, :] <= qpos[:, None])
        if window:
            msk = msk & (kpos[None, :] > qpos[:, None] - window)
        sc = jnp.where(msk[None, None, None], sc, NEG_INF)
        m_new = jnp.maximum(m, sc.max(axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgsu,bukd->bkgsd", p.astype(vblk.dtype), vblk,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hk, g, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hk, g, s), jnp.float32)
    a0 = jnp.zeros((b, hk, g, s, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4),
         jnp.arange(nkv)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, s, hq, dv)
    return out.astype(q.dtype)


def windowed_prefill(q, k, v, *, window: int, block_q: int = 512,
                     softcap: float = 0.0):
    """True sub-quadratic sliding-window prefill: scan over query blocks,
    each attending a static-size KV slice of length window + block_q."""
    b, s, hq, d = q.shape
    hk = k.shape[2]
    bq = min(block_q, s)
    if s % bq:
        raise ValueError(f"seq {s} % block_q {bq} != 0")
    nq = s // bq
    span = window + bq
    # pad kv on the left by `window` so slices never clip
    kp = jnp.pad(k, ((0, 0), (window, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, 0), (0, 0), (0, 0)))

    def blk(i):
        qi = jax.lax.dynamic_slice_in_dim(q, i * bq, bq, axis=1)
        ki = jax.lax.dynamic_slice_in_dim(kp, i * bq, span, axis=1)
        vi = jax.lax.dynamic_slice_in_dim(vp, i * bq, span, axis=1)
        # positions: query j (global i*bq+j) attends keys with global pos
        # in (qpos-window, qpos]; key slice covers global [i*bq-window, i*bq+bq)
        qpos = jnp.arange(bq)[:, None] + window      # local coords in slice
        kpos = jnp.arange(span)[None, :]
        valid = (kpos <= qpos) & (kpos > qpos - window) \
            & (kpos + i * bq - window >= 0)
        return attend(qi, ki, vi, valid[None, None, None], softcap=softcap)

    out = jax.lax.map(blk, jnp.arange(nq))           # (nq, B, bq, Hq, D)
    return out.transpose(1, 0, 2, 3, 4).reshape(b, s, hq, d)


# ----------------------------------------------------------- decode step
# §Perf A/B switch: blockwise (flash-decoding) KV traversal for long
# caches — avoids materializing (T, Smax) fp32 score tensors per layer.
DECODE_FLASH = True
DECODE_FLASH_MIN_LEN = 4096
DECODE_FLASH_BLOCK = 2048


def decode_attend_blockwise(q, k_cache, v_cache, lengths, pad=None, *,
                            window: int = 0, softcap: float = 0.0,
                            block_kv: int = DECODE_FLASH_BLOCK):
    """Flash-decoding in jnp: scan KV blocks with an online softmax.
    Same signature/semantics as ``decode_attend``; this is also the
    XLA-path mirror of kernels/verify_attn."""
    b, t, hq, d = q.shape
    smax, hk = k_cache.shape[1], k_cache.shape[2]
    g = hq // hk
    bkv = min(block_kv, smax)
    if smax % bkv:
        return decode_attend(q, k_cache, v_cache, lengths, pad,
                             window=window, softcap=softcap)
    nkv = smax // bkv
    qf = q.reshape(b, t, hk, g, d)
    qpos = lengths[:, None] + jnp.arange(t)[None, :]          # (B, T)
    kb = k_cache.reshape(b, nkv, bkv, hk, d)
    vb = v_cache.reshape(b, nkv, bkv, hk, d)

    def step(carry, inputs):
        m, l, acc = carry
        kblk, vblk, ik = inputs                               # (B,bkv,hk,d)
        kpos = ik * bkv + jnp.arange(bkv)                     # (bkv,)
        sc = jnp.einsum("btkgd,bukd->bkgtu", qf, kblk,
                        preferred_element_type=jnp.float32) / jnp.sqrt(d)
        sc = _softcap(sc, softcap)
        msk = kpos[None, None, :] <= qpos[:, :, None]         # (B,T,bkv)
        if pad is not None:
            msk = msk & (kpos[None, None, :] >= pad[:, None, None])
        if window:
            msk = msk & (kpos[None, None, :] > qpos[:, :, None] - window)
        sc = jnp.where(msk[:, None, None], sc, NEG_INF)
        m_new = jnp.maximum(m, sc.max(axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgtu,bukd->bkgtd", p.astype(vblk.dtype), vblk,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hk, g, t), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hk, g, t), jnp.float32)
    a0 = jnp.zeros((b, hk, g, t, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4),
         jnp.arange(nkv)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, t, hq, d)
    return out.astype(q.dtype)


def decode_attend(q, k_cache, v_cache, lengths, pad=None, *, window: int = 0,
                  softcap: float = 0.0):
    """Decode/verify attention: T new queries per request vs. cached KV.

    q: (B, T, Hq, D) — queries for cache positions lengths[b] + [0..T).
    k_cache/v_cache: (B, Smax, Hk, D) with valid region [pad[b], lengths[b])
    (the T new tokens' k/v must already be written into the cache).
    """
    b, t, hq, d = q.shape
    smax = k_cache.shape[1]
    qpos = lengths[:, None] + jnp.arange(t)[None, :]           # (B, T)
    kpos = jnp.arange(smax)[None, None, :]                     # (1, 1, S)
    mask = kpos <= qpos[:, :, None]
    if pad is not None:
        mask = mask & (kpos >= pad[:, None, None])
    if window:
        mask = mask & (kpos > qpos[:, :, None] - window)
    return attend(q, k_cache, v_cache, mask[:, None, None], softcap=softcap)


def tree_offsets(width: int, gamma: int) -> jnp.ndarray:
    """Logical depth of each slot in a flattened draft-token tree block.

    The tree is `width` parallel chains of depth `gamma` sharing one root:
    slot 0 is the root token t0, slot(r, j) = 1 + r*gamma + (j-1) holds
    branch r's depth-j node (branch-major).  Returns (width*gamma + 1,)
    int32 depths: [0, 1..gamma, 1..gamma, ...].
    """
    idx = jnp.arange(width * gamma + 1)
    return jnp.where(idx == 0, 0, (idx - 1) % gamma + 1).astype(jnp.int32)


def tree_block_visible(qi, kslot, width: int, gamma: int):
    """Within-block tree-causal visibility: query slot ``qi`` sees key
    slot ``kslot`` iff the key is the shared root or a same-branch
    ancestor-or-self.  Both args broadcastable int arrays; static
    (width, gamma) so no mask tensors ever cross the kernel boundary."""
    t = width * gamma + 1
    same_branch = (kslot - 1) // gamma == (qi - 1) // gamma
    anc = (kslot - 1) % gamma <= (qi - 1) % gamma
    return (kslot == 0) | (
        (qi > 0) & (kslot > 0) & (kslot < t) & same_branch & anc)


def decode_attend_tree(q, k_cache, v_cache, lengths, pad=None, *,
                       tree: Tuple[int, int], window: int = 0,
                       softcap: float = 0.0):
    """Tree-masked verify attention: the T = width*gamma + 1 block rows
    (written at cache positions lengths + [0..T)) are a flattened draft
    tree; query slot i attends all committed history plus its own
    root-path ancestors only.  With width == 1 the mask degenerates to
    the linear ``decode_attend`` mask boolean-for-boolean."""
    width, gamma = tree
    b, t, hq, d = q.shape
    smax = k_cache.shape[1]
    off = tree_offsets(width, gamma)                           # (T,)
    qi = jnp.arange(t)[None, :, None]                          # (1, T, 1)
    kpos = jnp.arange(smax)[None, None, :]                     # (1, 1, S)
    length_b = lengths[:, None, None]
    kslot = kpos - length_b                                    # (B, 1, S)
    committed = kpos < length_b
    if pad is not None:
        committed = committed & (kpos >= pad[:, None, None])
    in_block = (kpos >= length_b) & (kpos < length_b + t)
    mask = committed | (in_block
                        & tree_block_visible(qi, kslot, width, gamma))
    if window:
        kdepth = jnp.where(kslot == 0, 0, (kslot - 1) % gamma + 1)
        k_logical = jnp.where(in_block, length_b + kdepth, kpos)
        q_logical = length_b + off[None, :, None]
        mask = mask & (k_logical > q_logical - window)
    return attend(q, k_cache, v_cache, mask[:, None, None], softcap=softcap)


def decode_attend_windowed(q, k_cache, v_cache, lengths, pad=None, *,
                           window: int, softcap: float = 0.0):
    """Sliding-window decode that only *reads* the last `window + T` cache
    entries (static slice size) — sub-quadratic long-context decode path."""
    b, t, hq, d = q.shape
    smax = k_cache.shape[1]
    span = window + t
    if span >= smax:
        return decode_attend(q, k_cache, v_cache, lengths, pad, window=window,
                             softcap=softcap)
    start = jnp.clip(lengths + t - span, 0, smax - span)       # (B,)

    def slice_one(cache, s0):
        return jax.lax.dynamic_slice_in_dim(cache, s0, span, axis=0)

    ks = jax.vmap(slice_one)(k_cache, start)                   # (B, span, Hk, D)
    vs = jax.vmap(slice_one)(v_cache, start)
    qpos = lengths[:, None] + jnp.arange(t)[None, :]           # (B, T) global
    kpos = start[:, None, None] + jnp.arange(span)[None, None, :]
    mask = (kpos <= qpos[:, :, None]) & (kpos > qpos[:, :, None] - window)
    if pad is not None:
        mask = mask & (kpos >= pad[:, None, None])
    return attend(q, ks, vs, mask[:, None, None], softcap=softcap)


# -------------------------------------------------------- module wrapper
def attn_specs(cfg: ModelConfig, *, cross: bool = False) -> dict:
    d, hq, hk, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    specs = {
        "wq": ParamSpec((d, hq, hd), (EMBED, HEADS, QKV)),
        "wk": ParamSpec((d, hk, hd), (EMBED, KV_HEADS, QKV)),
        "wv": ParamSpec((d, hk, hd), (EMBED, KV_HEADS, QKV)),
        "wo": ParamSpec((hq, hd, d), (HEADS, QKV, EMBED)),
    }
    if cross:
        specs["q_norm"] = ParamSpec((hd,), (QKV,), init="ones")
        specs["k_norm"] = ParamSpec((hd,), (QKV,), init="ones")
    return specs


def qkv_proj(params, x, dtype):
    from repro.models.hints import weight_gather as wg
    q = jnp.einsum("btd,dhk->bthk", x,
                   wg(params["wq"].astype(dtype), (None, "heads", None)))
    k = jnp.einsum("btd,dhk->bthk", x,
                   wg(params["wk"].astype(dtype), (None, "kv_heads", None)))
    v = jnp.einsum("btd,dhk->bthk", x,
                   wg(params["wv"].astype(dtype), (None, "kv_heads", None)))
    return q, k, v


def out_proj(params, o, dtype):
    from repro.models.hints import weight_gather as wg
    return jnp.einsum("bthk,hkd->btd", o,
                      wg(params["wo"].astype(dtype), ("heads", None, None)))


def self_attention_prefill(cfg: ModelConfig, params, x, positions, pad=None, *,
                           window: int = 0, causal: bool = True
                           ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Returns (out, (k, v)) — k/v retained for the KV cache.

    positions: (B, S) RoPE positions.  pad: optional (B,) left-pad widths —
    when given, the masked small-batch path is used (serving engine);
    when None, the flash/blockwise paths assume uniform arange positions.
    """
    dt = x.dtype
    q, k, v = qkv_proj(params, x, dt)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    s = x.shape[1]
    if pad is not None:
        kpos = jnp.arange(s)[None, None, :]
        qpos = jnp.arange(s)[None, :, None]
        msk = (kpos <= qpos) & (kpos >= pad[:, None, None])
        if not causal:
            msk = kpos >= pad[:, None, None]
        if window:
            msk = msk & (kpos > qpos - window)
        o = attend(q, k, v, msk[:, None, None], softcap=cfg.attn_logit_softcap)
    elif window and causal and s > window:
        o = windowed_prefill(q, k, v, window=window, block_q=cfg.attn_block_q,
                             softcap=cfg.attn_logit_softcap)
    elif s > cfg.attn_block_kv:
        o = flash_prefill(q, k, v, causal=causal, window=window,
                          block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv,
                          softcap=cfg.attn_logit_softcap)
    else:
        msk = causal_mask(s, s, 0)[None, None, None] if causal else \
            jnp.ones((1, 1, 1, s, s), bool)
        if window:
            kpos = jnp.arange(s)[None, :]
            qpos = jnp.arange(s)[:, None]
            msk = msk & (kpos > qpos - window)[None, None, None]
        o = attend(q, k, v, msk, softcap=cfg.attn_logit_softcap)
    return out_proj(params, o, dt), (k, v)


def self_attention_decode(cfg: ModelConfig, params, x, k_cache, v_cache,
                          lengths, pad=None, *, window: int = 0,
                          page_tbl=None, tree: Optional[Tuple[int, int]] = None):
    """x: (B, T, D) new tokens at cache positions lengths + [0..T).
    RoPE positions are lengths - pad + t (pad-adjusted true token index).
    Writes the new K/V into the cache functionally and attends.

    Paged mode (``page_tbl`` given): k_cache/v_cache are page *pools*
    (num_pages + 1, P, Hk, D) and writes/reads go through the (B, n_tbl)
    block table.  The attention itself runs on a gathered dense
    (B, n_tbl * P) view through the *same* dispatch below, so the paged
    path is structurally the dense computation over identical valid
    bytes — bitwise-equal outputs (garbage keys are masked to the same
    exact-zero softmax weight on both paths).

    Tree mode (``tree=(width, gamma)``): the T = width*gamma + 1 rows are
    a flattened draft tree (slot 0 root, then branch-major chains); RoPE
    positions use each slot's logical depth, the K/V scatter is
    unchanged (flat slots lengths + [0..T)), and attention runs the
    tree-causal mask so every branch scores in this single pass."""
    from repro.core import paging
    dt = x.dtype
    b, t, _ = x.shape
    q, k, v = qkv_proj(params, x, dt)
    if tree is not None:
        rope_pos = lengths[:, None] + tree_offsets(*tree)[None, :]
    else:
        rope_pos = lengths[:, None] + jnp.arange(t)[None, :]
    if pad is not None:
        rope_pos = rope_pos - pad[:, None]
    q = apply_rope(q, rope_pos, cfg.rope_theta)
    k = apply_rope(k, rope_pos, cfg.rope_theta)
    # scatter new kv into cache at per-request offsets
    if page_tbl is not None:
        k_pool = paging.scatter_kv_paged(k_cache, page_tbl, k, lengths)
        v_pool = paging.scatter_kv_paged(v_cache, page_tbl, v, lengths)
        k_cache = paging.gather_view(k_pool, page_tbl)
        v_cache = paging.gather_view(v_pool, page_tbl)
    else:
        k_pool = k_cache = scatter_kv(k_cache, k, lengths)
        v_pool = v_cache = scatter_kv(v_cache, v, lengths)
    if tree is not None:
        o = decode_attend_tree(q, k_cache, v_cache, lengths, pad,
                               tree=tree, window=window,
                               softcap=cfg.attn_logit_softcap)
    elif window and k_cache.shape[1] > 4 * (window + t):
        o = decode_attend_windowed(q, k_cache, v_cache, lengths, pad,
                                   window=window,
                                   softcap=cfg.attn_logit_softcap)
    elif DECODE_FLASH and k_cache.shape[1] >= DECODE_FLASH_MIN_LEN:
        o = decode_attend_blockwise(q, k_cache, v_cache, lengths, pad,
                                    window=window,
                                    softcap=cfg.attn_logit_softcap)
    else:
        o = decode_attend(q, k_cache, v_cache, lengths, pad, window=window,
                          softcap=cfg.attn_logit_softcap)
    return out_proj(params, o, dt), (k_pool, v_pool)


def scatter_kv(cache, new, lengths):
    """cache: (B, Smax, Hk, D); new: (B, T, Hk, D); write at lengths[b]+t."""
    from repro.models.hints import hint
    b, t = new.shape[0], new.shape[1]
    bidx = jnp.arange(b)[:, None].repeat(t, 1)             # (B, T)
    sidx = lengths[:, None] + jnp.arange(t)[None, :]       # (B, T)
    out = cache.at[bidx, sidx].set(new.astype(cache.dtype))
    # pin the scatter result to the cache layout — stops SPMD from
    # rematerializing the cache to a replicated layout per layer [§Perf]
    return hint(out, ("batch", "kv_seq", "kv_heads", "qkv"))


# ---------------------------------------------------------- cross attn
def cross_attention(cfg: ModelConfig, params, x, mem_k, mem_v):
    """x: (B, T, D); mem_k/v: (B, M, Hk, D) precomputed memory KV."""
    dt = x.dtype
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(dt))
    m = mem_k.shape[1]
    mask = jnp.ones((1, 1, 1, x.shape[1], m), bool)
    o = attend(q, mem_k, mem_v, mask, softcap=cfg.attn_logit_softcap)
    return out_proj(params, o, dt)


def cross_memory_kv(params, mem, dtype):
    """Project memory embeddings (B, M, D) to cross-attn K/V once."""
    k = jnp.einsum("bmd,dhk->bmhk", mem, params["wk"].astype(dtype))
    v = jnp.einsum("bmd,dhk->bmhk", mem, params["wv"].astype(dtype))
    return k, v
