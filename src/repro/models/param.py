"""Parameter-spec system: declare params as (shape, logical axes, init),
materialize them with a PRNG key, and derive sharding from the same tree.

This keeps model code functional (pure pytrees of jnp arrays), makes
``jax.eval_shape``-based dry-runs trivial, and gives one source of truth
for logical-axis sharding rules.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declarative parameter: shape + logical axis names + init scheme."""
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones | embed | scaled
    scale: float = 1.0
    dtype: Any = jnp.float32

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _fan_in(shape: Tuple[int, ...]) -> int:
    # Last axis is the output axis by convention (x @ W with W (in, out));
    # for >2D weights everything but the last axis is fan-in.
    if len(shape) <= 1:
        return shape[0] if shape else 1
    return int(np.prod(shape[:-1]))


def init_leaf(key, spec: ParamSpec):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "alog":
        # Mamba A_log: log(1..N) along the last axis, tiled over the rest.
        n = spec.shape[-1]
        row = jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))
        return jnp.broadcast_to(row, spec.shape).astype(spec.dtype)
    if spec.init == "embed":
        return (jax.random.normal(key, spec.shape) * 0.02 * spec.scale
                ).astype(spec.dtype)
    # normal / scaled: truncated-normal fan-in scaling.  A leading "layers"
    # stack axis is not part of the fan-in.
    shape = spec.shape[1:] if (spec.axes and spec.axes[0] == "layers") \
        else spec.shape
    std = spec.scale / math.sqrt(_fan_in(shape))
    return (jax.random.truncated_normal(key, -2.0, 2.0, spec.shape) * std
            ).astype(spec.dtype)


def init_params(key, specs):
    """Materialize a pytree of ParamSpec into a pytree of arrays."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [init_leaf(k, s) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(specs):
    """ShapeDtypeStruct pytree matching ``init_params`` (no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs, is_leaf=is_spec)


def logical_axes(specs):
    """Pytree of logical-axis tuples aligned with the param pytree."""
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def map_specs(fn: Callable[[ParamSpec], Any], specs):
    return jax.tree.map(fn, specs, is_leaf=is_spec)


def count_params(specs) -> int:
    return sum(int(np.prod(s.shape)) for s in
               jax.tree.leaves(specs, is_leaf=is_spec))


def param_bytes(specs, bytes_per_param: int = 4) -> int:
    return count_params(specs) * bytes_per_param


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)
