"""Model configuration for the repro transformer zoo.

A single frozen dataclass describes every assigned architecture family:
dense / MoE / MLA / hybrid(attn+mamba) / SSM(rwkv6) / VLM(cross-attn) /
audio(enc-dec).  Layer heterogeneity is expressed as a repeating *block
pattern* so that model forward passes can ``lax.scan`` over stacked
homogeneous parameter groups (compile-time hygiene on CPU and TPU alike).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp

# Mixer kinds usable inside a block pattern.
ATTN = "attn"          # GQA self attention (RoPE)
ATTN_SW = "attn_sw"    # sliding-window self attention
MLA = "mla"            # DeepSeek multi-head latent attention
MAMBA = "mamba"        # Mamba-1 selective SSM
RWKV6 = "rwkv6"        # RWKV-6 (Finch) time mix
CROSS = "cross"        # cross attention (VLM image / enc-dec memory)

# FFN kinds.
FFN_SWIGLU = "swiglu"
FFN_GELU = "gelu"      # starcoder2 / whisper style
FFN_MOE = "moe"


@dataclasses.dataclass(frozen=True)
class BlockDef:
    """One layer inside a repeating superblock."""
    mixer: str = ATTN
    ffn: str = FFN_SWIGLU
    cross: bool = False   # additional cross-attn sub-layer (enc-dec decoder)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | hybrid | ssm | vlm | audio
    citation: str = ""

    # Core dims.
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    vocab_size: int = 1024

    # Layer pattern: ``pattern`` repeated ``num_layers // len(pattern)`` times,
    # with ``prologue`` dense layers before it (DeepSeek's first-k-dense).
    pattern: Tuple[BlockDef, ...] = (BlockDef(),)
    prologue: Tuple[BlockDef, ...] = ()

    # Attention.
    rope_theta: float = 10000.0
    window: int = 0               # 0 = full attention; >0 = sliding window
    attn_logit_softcap: float = 0.0

    # MoE.
    num_experts: int = 0
    experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0             # per-expert hidden dim (falls back to d_ff)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # MLA (DeepSeek-V3).
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # Mamba.
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0        # 0 -> ceil(d_model / 16)

    # RWKV6.
    rwkv_head_dim: int = 64

    # VLM (cross-attention to image patch embeddings).
    num_image_tokens: int = 0

    # Audio enc-dec (whisper): encoder layers with bidirectional attention;
    # decoder = ``num_layers`` causal layers with cross attention.
    encoder_layers: int = 0
    decoder_len: int = 256        # teacher-forced decoder length in training

    # Multi-token prediction (DeepSeek MTP) — optional extra head depth.
    mtp_depth: int = 0

    # Numerics / training.
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # EAGLE-3 capture layers (low/mid/high); -1 → auto from num_layers.
    capture_layers: Tuple[int, int, int] = (-1, -1, -1)

    # Kernel selection: pure-jnp reference by default (dry-run safe);
    # flips in the Pallas kernels on real TPU.
    use_pallas: bool = False
    # Blockwise (flash-style) jnp attention for long sequences.
    attn_block_q: int = 512
    attn_block_kv: int = 1024
    # RWKV / linear-attention chunk length.
    chunk_len: int = 64

    def __post_init__(self):
        body = self.num_layers - len(self.prologue)
        if self.pattern and body % len(self.pattern) != 0:
            raise ValueError(
                f"{self.name}: body layers {body} not divisible by pattern "
                f"{len(self.pattern)}")

    # ---- derived ----
    @property
    def act_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def weight_dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def num_pattern_repeats(self) -> int:
        return (self.num_layers - len(self.prologue)) // len(self.pattern)

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def moe_hidden(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.mamba_dt_rank or -(-self.d_model // 16)

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def captures(self) -> Tuple[int, int, int]:
        """Indices of the low/mid/high hidden-state capture layers (EAGLE-3)."""
        lo, mid, hi = self.capture_layers
        n = self.num_layers
        if lo < 0:
            lo = min(2, n - 1)
        if mid < 0:
            mid = n // 2
        if hi < 0:
            hi = max(n - 3, 0)
        return (lo, mid, hi)

    @property
    def layer_kinds(self) -> Tuple[BlockDef, ...]:
        """Flattened per-layer block defs, prologue first."""
        return self.prologue + self.pattern * self.num_pattern_repeats

    def param_count(self) -> int:
        """Approximate parameter count (reported in benchmarks/docs)."""
        from repro.models import transformer  # local import, avoids cycle
        from repro.models.param import count_params
        return count_params(transformer.param_specs(self))

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed experts in use)."""
        total = self.param_count()
        if not self.num_experts:
            return total
        # Remove inactive expert weights.
        kinds = self.layer_kinds
        n_moe = sum(1 for b in kinds if b.ffn == FFN_MOE)
        per_expert = 3 * self.d_model * self.moe_hidden
        inactive = n_moe * (self.num_experts - self.experts_per_tok) * per_expert
        return total - inactive


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family variant for CPU smoke tests (≤2 layers, d≤512, ≤4 experts)."""
    changes = dict(
        num_layers=max(len(cfg.prologue) + len(cfg.pattern), 2)
        if (cfg.prologue or len(cfg.pattern) > 1) else 2,
        d_model=min(cfg.d_model, 128),
        num_heads=min(cfg.num_heads, 4),
        num_kv_heads=min(cfg.num_kv_heads, 2),
        head_dim=min(cfg.head_dim, 32),
        d_ff=min(cfg.d_ff, 256),
        vocab_size=min(cfg.vocab_size, 512),
        num_image_tokens=min(cfg.num_image_tokens, 16) if cfg.num_image_tokens else 0,
        encoder_layers=min(cfg.encoder_layers, 2) if cfg.encoder_layers else 0,
        decoder_len=min(cfg.decoder_len, 32),
        chunk_len=16,
        attn_block_q=64,
        attn_block_kv=64,
        dtype="float32",
    )
    if cfg.num_experts:
        changes.update(num_experts=4, experts_per_tok=min(cfg.experts_per_tok, 2),
                       moe_d_ff=min(cfg.moe_hidden, 128))
    if cfg.q_lora_rank or cfg.kv_lora_rank:
        changes.update(q_lora_rank=64, kv_lora_rank=64, qk_nope_head_dim=32,
                       qk_rope_head_dim=16, v_head_dim=32)
    if cfg.window:
        changes["window"] = min(cfg.window, 64)
    # Shrink prologue to at most 1 layer to keep tiny models tiny.
    if cfg.prologue:
        changes["prologue"] = cfg.prologue[:1]
        changes["num_layers"] = 1 + len(cfg.pattern)
    # mamba dims scale with d_model automatically via properties.
    kvh = changes["num_kv_heads"]
    nh = changes["num_heads"]
    if nh % kvh:
        changes["num_kv_heads"] = 1
    changes.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **changes)
