"""RWKV-6 ("Finch", arXiv:2404.05892) time-mix with data-dependent decay.

Prefill uses the chunked linear-attention form: within a chunk the decayed
inner products are exact matmuls (log-decays clamped for fp32 stability),
across chunks a lax.scan carries the (H, K, V) state. Decode advances the
recurrence per token over the verify block and returns per-step states for
speculative rollback.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.param import ParamSpec
from repro.models.layers import EMBED, HEADS, QKV, STATE

LOG_W_MIN = -5.0   # per-step decay clamp: w in [e^-5, 1)
CHUNK = 16         # intra-chunk matmul keeps exponents < 16*5 = 80 < ln(f32max)


def rwkv_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    h, k = cfg.rwkv_heads, cfg.rwkv_head_dim
    lora = max(32, d // 32)
    return {
        # token-shift interpolation weights per stream
        "mu_r": ParamSpec((d,), (EMBED,), init="zeros"),
        "mu_k": ParamSpec((d,), (EMBED,), init="zeros"),
        "mu_v": ParamSpec((d,), (EMBED,), init="zeros"),
        "mu_g": ParamSpec((d,), (EMBED,), init="zeros"),
        "mu_w": ParamSpec((d,), (EMBED,), init="zeros"),
        "w_r": ParamSpec((d, h, k), (EMBED, HEADS, QKV)),
        "w_k": ParamSpec((d, h, k), (EMBED, HEADS, QKV)),
        "w_v": ParamSpec((d, h, k), (EMBED, HEADS, QKV)),
        "w_g": ParamSpec((d, h, k), (EMBED, HEADS, QKV)),
        # data-dependent decay LoRA (the Finch headline feature)
        "w0": ParamSpec((h, k), (HEADS, QKV), init="zeros"),
        "w_lora_a": ParamSpec((d, 64), (EMBED, STATE), scale=0.1),
        "w_lora_b": ParamSpec((64, h, k), (STATE, HEADS, QKV), scale=0.1),
        "u_bonus": ParamSpec((h, k), (HEADS, QKV), init="zeros"),
        "ln_x": ParamSpec((h, k), (HEADS, QKV), init="ones"),
        "w_o": ParamSpec((h, k, d), (HEADS, QKV, EMBED)),
    }


def _streams(cfg: ModelConfig, params, x, x_prev):
    """Token-shifted projection streams. x: (B, T, D); x_prev: (B, T, D)
    where x_prev[t] = x[t-1] (first position taken from the shift cache)."""
    from repro.models.hints import weight_gather as wg
    dt = x.dtype

    def lerp(mu):
        m = jax.nn.sigmoid(params[mu].astype(dt))
        return x + (x_prev - x) * m

    def proj(name):
        return wg(params[name].astype(dt), (None, HEADS, None))

    r = jnp.einsum("btd,dhk->bthk", lerp("mu_r"), proj("w_r"))
    k = jnp.einsum("btd,dhk->bthk", lerp("mu_k"), proj("w_k"))
    v = jnp.einsum("btd,dhk->bthk", lerp("mu_v"), proj("w_v"))
    g = jnp.einsum("btd,dhk->bthk", lerp("mu_g"), proj("w_g"))
    xw = lerp("mu_w")
    lora = jnp.einsum("bts,shk->bthk",
                      jnp.tanh(xw @ params["w_lora_a"].astype(dt)),
                      params["w_lora_b"].astype(dt))
    logw = -jnp.exp(params["w0"].astype(jnp.float32)
                    + lora.astype(jnp.float32))            # (B,T,H,K) < 0
    logw = jnp.clip(logw, LOG_W_MIN, -1e-4)
    return r, k, v, g, logw


def _read_out(cfg: ModelConfig, params, wkv, r, g):
    """wkv: (B,T,H,V) attention read; apply per-head norm, gate, out proj."""
    dt = r.dtype
    x32 = wkv.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + 1e-5) * params["ln_x"].astype(jnp.float32)
    y = y.astype(dt) * jax.nn.silu(g)
    from repro.models.hints import weight_gather as wg
    return jnp.einsum("bthk,hkd->btd", y,
                      wg(params["w_o"].astype(dt), (HEADS, None, None)))


def rwkv_prefill(cfg: ModelConfig, params, x, pad=None
                 ) -> Tuple[jnp.ndarray, dict]:
    """x: (B, S, D). Returns (out, state={"s": (B,H,K,V), "shift": (B,1,D)}).
    pad: optional (B,) left-pad widths; padded steps leave the state
    untouched (decay 1, key/value 0)."""
    dt = x.dtype
    b, s_orig, d = x.shape
    h, kd = cfg.rwkv_heads, cfg.rwkv_head_dim
    if pad is not None:
        # zero padded positions so the token shift of the first real
        # token sees 0, exactly like the unpadded case
        vx = (jnp.arange(s_orig)[None, :] >= pad[:, None])[..., None]
        x = jnp.where(vx, x, 0.0)
    c = CHUNK
    rpad = (-s_orig) % c          # right-pad to a chunk multiple
    x_in = jnp.pad(x, ((0, 0), (0, rpad), (0, 0))) if rpad else x
    s = s_orig + rpad
    x_prev = jnp.pad(x_in, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, g, logw = _streams(cfg, params, x_in, x_prev)
    valid = jnp.arange(s)[None, :] < s_orig
    if pad is not None:
        valid = valid & (jnp.arange(s)[None, :] >= pad[:, None])
    if pad is not None or rpad:
        vm = valid[..., None, None]
        logw = jnp.where(vm, logw, 0.0)   # neutral steps: w=1, k=v=0
        k = jnp.where(vm, k, 0.0)
        v = jnp.where(vm, v, 0.0)
    nc = s // c
    u = params["u_bonus"].astype(jnp.float32)

    def chunk(s_in, blk):
        rc, kc, vc, lwc = blk                        # (C,B,H,K) / (C,B,H,V)
        rc = rc.astype(jnp.float32)
        kc = kc.astype(jnp.float32)
        vc = vc.astype(jnp.float32)
        cum = jnp.cumsum(lwc, axis=0)                # inclusive  (C,B,H,K)
        cum_ex = cum - lwc                           # exclusive
        q_dec = rc * jnp.exp(cum_ex)                 # decayed queries
        k_dec = kc * jnp.exp(-cum)                   # inverse-decayed keys
        # inter-chunk read from carried state
        inter = jnp.einsum("cbhk,bhkv->cbhv", q_dec, s_in)
        # intra-chunk strictly-causal attention
        att = jnp.einsum("cbhk,dbhk->bhcd", q_dec, k_dec)
        mask = jnp.tril(jnp.ones((c, c), bool), k=-1)
        att = att * mask[None, None]
        intra = jnp.einsum("bhcd,dbhv->cbhv", att, vc)
        diag = jnp.einsum("cbhk,cbhk,cbhv->cbhv",
                          rc, u[None, None] * kc, vc)
        # state update: S_out = diag(prod w) S_in + sum_s decay(s->C) k_s v_s
        k_tail = kc * jnp.exp(cum[-1][None] - cum)   # decay from s to chunk end
        s_out = (jnp.exp(cum[-1])[..., None] * s_in
                 + jnp.einsum("cbhk,cbhv->bhkv", k_tail, vc))
        return s_out, inter + intra + diag

    def resh(t):  # (B,S,H,*) -> (nc, C, B, H, *)
        return t.transpose(1, 0, 2, 3).reshape(nc, c, b, h, t.shape[-1])

    s0 = jnp.zeros((b, h, kd, kd), jnp.float32)
    s_fin, wkv = jax.lax.scan(chunk, s0, (resh(r), resh(k), resh(v), resh(logw)))
    wkv = wkv.reshape(s, b, h, kd).transpose(1, 0, 2, 3)         # (B,S,H,V)
    out = _read_out(cfg, params, wkv, r, g)[:, :s_orig]
    return out, {"s": s_fin, "shift": x[:, s_orig - 1:s_orig, :]}


def rwkv_decode(cfg: ModelConfig, params, x, state) -> Tuple[jnp.ndarray, dict]:
    """x: (B, T, D) verify block; per-step states returned for rollback."""
    dt = x.dtype
    b, t, d = x.shape
    x_prev = jnp.concatenate([state["shift"].astype(dt), x[:, :-1]], axis=1)
    r, k, v, g, logw = _streams(cfg, params, x, x_prev)
    u = params["u_bonus"].astype(jnp.float32)

    def step(s_in, inp):
        rt, kt, vt, lwt, xt = inp                   # (B,H,K) ... (B,D)
        rt = rt.astype(jnp.float32)
        kt = kt.astype(jnp.float32)
        vt = vt.astype(jnp.float32)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        read = s_in + u[None, :, :, None] * kv
        wkv = jnp.einsum("bhk,bhkv->bhv", rt, read)
        s_out = jnp.exp(lwt)[..., None] * s_in + kv
        return s_out, (wkv, s_out, xt)

    xs = (r.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), logw.transpose(1, 0, 2, 3),
          x.transpose(1, 0, 2))
    s_fin, (wkvs, s_steps, x_steps) = jax.lax.scan(step, state["s"], xs)
    wkv = wkvs.transpose(1, 0, 2, 3)                             # (B,T,H,V)
    out = _read_out(cfg, params, wkv, r, g)
    states = {"s": s_steps.transpose(1, 0, 2, 3, 4),             # (B,T,H,K,V)
              "shift": x_steps.transpose(1, 0, 2)[:, :, None, :]}  # (B,T,1,D)
    return out, states
