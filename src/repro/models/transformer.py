"""Unified functional transformer covering all assigned families.

The model is a sequence of *groups*; each group is a repeating pattern of
heterogeneous layers scanned over its repeat count with stacked parameters
(`lax.scan` keeps HLO size flat in depth — compile-time hygiene for the
61–72-layer assigned archs).

Entry points:
  * ``param_specs`` / ``init``          — declarative params (+ logical axes)
  * ``forward_train``                   — teacher-forced LM loss (remat +
                                          microbatch grad-accum lives in
                                          repro.training.trainer)
  * ``prefill``                         — prompt pass → last logits, KV/SSM
                                          cache, EAGLE-3 capture states
  * ``decode_step``                     — γ+1-token speculative verify block
  * ``commit_cache``                    — per-request acceptance rollback
  * ``init_cache`` / ``cache_axes``     — decode-state construction/sharding
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba as mam
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models.config import (ATTN, ATTN_SW, CROSS, MAMBA, MLA, RWKV6,
                                 FFN_MOE, BlockDef, ModelConfig)
from repro.models.layers import (LAYERS, embed, embed_specs, ffn,
                                 ffn_specs, head_specs, lm_head, rmsnorm,
                                 rmsnorm_specs)
from repro.models.param import ParamSpec, init_params, map_specs

# Logical axis names for cache/activation sharding.
BATCH = "batch"
KV_SEQ = "kv_seq"
ACT_SEQ = "act_seq"


# ===================================================================== specs
def layer_specs(cfg: ModelConfig, blk: BlockDef) -> dict:
    d = cfg.d_model
    s: Dict[str, Any] = {"norm1": rmsnorm_specs(d)}
    if blk.mixer in (ATTN, ATTN_SW):
        s["mix"] = attn.attn_specs(cfg)
    elif blk.mixer == MLA:
        s["mix"] = mla_mod.mla_specs(cfg)
    elif blk.mixer == CROSS:
        s["mix"] = attn.attn_specs(cfg, cross=True)
    elif blk.mixer == MAMBA:
        s["mix"] = mam.mamba_specs(cfg)
    elif blk.mixer == RWKV6:
        s["mix"] = rwkv_mod.rwkv_specs(cfg)
    else:
        raise ValueError(blk.mixer)
    if blk.cross:
        s["norm_c"] = rmsnorm_specs(d)
        s["cross"] = attn.attn_specs(cfg, cross=True)
    s["norm2"] = rmsnorm_specs(d)
    if blk.ffn == FFN_MOE:
        s["moe"] = moe_mod.moe_specs(cfg)
    else:
        s["ffn"] = ffn_specs(cfg, blk.ffn)
    return s


def stack_specs(specs, n: int):
    return map_specs(
        lambda p: ParamSpec((n,) + p.shape, (LAYERS,) + p.axes, p.init,
                            p.scale, p.dtype), specs)


def model_groups(cfg: ModelConfig) -> List[Tuple[str, Tuple[BlockDef, ...], int]]:
    """Decoder groups as (name, pattern, repeats)."""
    gs = []
    if cfg.prologue:
        gs.append(("pre", (cfg.prologue[0],), len(cfg.prologue)))
    gs.append(("body", cfg.pattern, cfg.num_pattern_repeats))
    return gs


def param_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    specs: Dict[str, Any] = {
        "embed": embed_specs(cfg),
        "final_norm": rmsnorm_specs(d),
    }
    if not cfg.tie_embeddings:
        specs["head"] = head_specs(cfg)
    for name, pattern, repeats in model_groups(cfg):
        specs[name] = {f"pos{i}": stack_specs(layer_specs(cfg, blk), repeats)
                       for i, blk in enumerate(pattern)}
    if cfg.encoder_layers:
        enc_blk = BlockDef(mixer=ATTN, ffn=cfg.pattern[0].ffn)
        specs["enc"] = {"pos0": stack_specs(layer_specs(cfg, enc_blk),
                                            cfg.encoder_layers)}
        specs["enc_norm"] = rmsnorm_specs(d)
    return specs


def init(cfg: ModelConfig, key):
    return init_params(key, param_specs(cfg))


# ============================================================== layer apply
def _place(x, max_len: int):
    """Pad a (B, S, ...) prefill cache tensor out to (B, max_len, ...)."""
    s = x.shape[1]
    if s == max_len:
        return x
    if s > max_len:
        raise ValueError(f"prefill len {s} > max_len {max_len}")
    return jnp.pad(x, ((0, 0), (0, max_len - s)) + ((0, 0),) * (x.ndim - 2))


def apply_layer_prefill(cfg: ModelConfig, blk: BlockDef, p, x, positions, pad,
                        mem, max_len: int, causal: bool, want_cache: bool,
                        moe_impl: str):
    """Returns (x, cache_entry, aux_loss)."""
    dt = x.dtype
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    entry: Dict[str, Any] = {}
    if blk.mixer in (ATTN, ATTN_SW):
        out, (k, v) = attn.self_attention_prefill(
            cfg, p["mix"], h, positions, pad, window=cfg.window, causal=causal)
        if want_cache:
            entry = {"k": _place(k, max_len), "v": _place(v, max_len)}
    elif blk.mixer == MLA:
        out, (ckv, kr) = mla_mod.mla_prefill(cfg, p["mix"], h, positions, pad)
        if want_cache:
            entry = {"ckv": _place(ckv, max_len), "kr": _place(kr, max_len)}
    elif blk.mixer == CROSS:
        mk, mv = attn.cross_memory_kv(p["mix"], mem, dt)
        out = attn.cross_attention(cfg, p["mix"], h, mk, mv)
        if want_cache:
            entry = {"mk": mk, "mv": mv}
    elif blk.mixer == MAMBA:
        out, st = mam.mamba_prefill(cfg, p["mix"], h, pad)
        if want_cache:
            entry = st
    elif blk.mixer == RWKV6:
        out, st = rwkv_mod.rwkv_prefill(cfg, p["mix"], h, pad)
        if want_cache:
            entry = st
    else:
        raise ValueError(blk.mixer)
    x = x + out
    if blk.cross:
        hc = rmsnorm(p["norm_c"], x, cfg.norm_eps)
        mk, mv = attn.cross_memory_kv(p["cross"], mem, dt)
        x = x + attn.cross_attention(cfg, p["cross"], hc, mk, mv)
        if want_cache:
            entry["xmk"], entry["xmv"] = mk, mv
    h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
    if blk.ffn == FFN_MOE:
        out2, aux = moe_mod.moe(cfg, p["moe"], h2, moe_impl)
    else:
        out2, aux = ffn(p["ffn"], h2, blk.ffn), jnp.float32(0.0)
    return x + out2, entry, aux


def apply_layer_decode(cfg: ModelConfig, blk: BlockDef, p, x, entry, lengths,
                       pad, moe_impl: str, page_tbl=None, tree=None):
    """Returns (x, new_entry, aux). SSM entries gain a per-step T axis.
    With ``page_tbl``, attention entries are page pools written/read
    through the shared block table (see ``core.paging``).  With
    ``tree=(width, gamma)``, the block rows are a flattened draft tree
    scored under the tree-causal mask (attention mixers only — see
    ``tree_check``)."""
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if blk.mixer in (ATTN, ATTN_SW):
        out, (kc, vc) = attn.self_attention_decode(
            cfg, p["mix"], h, entry["k"], entry["v"], lengths, pad,
            window=cfg.window, page_tbl=page_tbl, tree=tree)
        new = dict(entry, k=kc, v=vc)
    elif blk.mixer == MLA:
        out, (ckv, kr) = mla_mod.mla_decode(
            cfg, p["mix"], h, entry["ckv"], entry["kr"], lengths, pad)
        new = dict(entry, ckv=ckv, kr=kr)
    elif blk.mixer == CROSS:
        out = attn.cross_attention(cfg, p["mix"], h, entry["mk"], entry["mv"])
        new = entry
    elif blk.mixer == MAMBA:
        out, states = mam.mamba_decode(cfg, p["mix"], h, entry)
        new = states
    elif blk.mixer == RWKV6:
        out, states = rwkv_mod.rwkv_decode(cfg, p["mix"], h, entry)
        new = states
    else:
        raise ValueError(blk.mixer)
    x = x + out
    if blk.cross:
        hc = rmsnorm(p["norm_c"], x, cfg.norm_eps)
        x = x + attn.cross_attention(cfg, p["cross"], hc, entry["xmk"],
                                     entry["xmv"])
    h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
    if blk.ffn == FFN_MOE:
        out2, aux = moe_mod.moe(cfg, p["moe"], h2, moe_impl)
    else:
        out2, aux = ffn(p["ffn"], h2, blk.ffn), jnp.float32(0.0)
    return x + out2, new, aux


# ============================================================= group runner
def _update_caps(caps, cap_targets, lidx, x):
    if caps is None:
        return None
    for j, tgt in enumerate(cap_targets):
        caps = caps.at[j].set(jnp.where(lidx == tgt, x, caps[j]))
    return caps


def run_group_prefill(cfg, group_params, pattern, repeats, x, positions, pad,
                      mem, base_idx: int, cap_targets, max_len, causal,
                      want_cache, want_caps, moe_impl, remat=False):
    """Scan the group. Returns (x, cache_group, caps, aux)."""
    P = len(pattern)

    def body(carry, xs):
        x, caps, aux = carry
        i, p_slice = xs
        entries = {}
        for pi, blk in enumerate(pattern):
            if remat:
                def layer_fn(p, x, positions, pad, mem, _blk=blk):
                    return apply_layer_prefill(
                        cfg, _blk, p, x, positions, pad, mem, max_len,
                        causal, want_cache, moe_impl)
                fn = jax.checkpoint(
                    layer_fn,
                    policy=jax.checkpoint_policies.nothing_saveable)
                x, entry, a = fn(p_slice[f"pos{pi}"], x, positions, pad, mem)
            else:
                x, entry, a = apply_layer_prefill(
                    cfg, blk, p_slice[f"pos{pi}"], x, positions, pad, mem,
                    max_len, causal, want_cache, moe_impl)
            aux = aux + a
            lidx = base_idx + i * P + pi
            caps = _update_caps(caps, cap_targets, lidx, x)
            entries[f"pos{pi}"] = entry
        return (x, caps, aux), entries

    caps0 = None
    if want_caps:
        caps0 = jnp.zeros((len(cap_targets),) + x.shape, x.dtype)
    aux0 = jnp.float32(0.0)
    (x, caps, aux), cache_group = jax.lax.scan(
        body, (x, caps0, aux0), (jnp.arange(repeats), group_params))
    return x, cache_group, caps, aux


def run_group_decode(cfg, group_params, pattern, repeats, x, cache_group,
                     lengths, pad, base_idx: int, cap_targets, want_caps,
                     moe_impl, page_tbl=None, tree=None):
    P = len(pattern)

    def body(carry, xs):
        x, caps, aux = carry
        i, p_slice, c_slice = xs
        new_entries = {}
        for pi, blk in enumerate(pattern):
            x, entry, a = apply_layer_decode(
                cfg, blk, p_slice[f"pos{pi}"], x, c_slice[f"pos{pi}"],
                lengths, pad, moe_impl, page_tbl=page_tbl, tree=tree)
            aux = aux + a
            lidx = base_idx + i * P + pi
            caps = _update_caps(caps, cap_targets, lidx, x)
            new_entries[f"pos{pi}"] = entry
        return (x, caps, aux), new_entries

    caps0 = None
    if want_caps:
        caps0 = jnp.zeros((len(cap_targets),) + x.shape, x.dtype)
    (x, caps, aux), new_cache = jax.lax.scan(
        body, (x, caps0, jnp.float32(0.0)),
        (jnp.arange(repeats), group_params, cache_group))
    return x, new_cache, caps, aux


# ================================================================ entry pts
def _caps_to_features(caps):
    """(3, B, T, D) -> (B, T, 3D) EAGLE-3 concatenated capture features."""
    if caps is None:
        return None
    n, b, t, d = caps.shape
    return caps.transpose(1, 2, 0, 3).reshape(b, t, n * d)


def encode(cfg: ModelConfig, params, frames):
    """Audio encoder (whisper): frames (B, S, D) pre-embedded by the stub
    frontend -> memory (B, S, D). Bidirectional, no cache."""
    x = frames.astype(cfg.act_dtype)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    enc_blk = BlockDef(mixer=ATTN, ffn=cfg.pattern[0].ffn)
    x, _, _, _ = run_group_prefill(
        cfg, params["enc"], (enc_blk,), cfg.encoder_layers, x, positions,
        None, None, 0, (), x.shape[1], causal=False, want_cache=False,
        want_caps=False, moe_impl="sort")
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _memory(cfg: ModelConfig, params, extra):
    if cfg.encoder_layers:
        return encode(cfg, params, extra["frames"])
    if cfg.num_image_tokens:
        return extra["image_embeds"].astype(cfg.act_dtype)
    return None


def prefill(cfg: ModelConfig, params, tokens, extra=None, *,
            max_len: Optional[int] = None, pad=None, moe_impl: str = "sort",
            want_caps: bool = True):
    """Prompt pass. Returns dict(logits (B,V) last-position, cache,
    captures (B,S,3D), aux)."""
    b, s = tokens.shape
    max_len = max_len or s
    mem = _memory(cfg, params, extra or {})
    x = embed(params["embed"], tokens, cfg.act_dtype)
    if pad is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    else:
        positions = jnp.maximum(jnp.arange(s)[None, :] - pad[:, None], 0)
    cap_targets = cfg.captures
    cache: Dict[str, Any] = {}
    caps_all = []
    base = 0
    aux = jnp.float32(0.0)
    for name, pattern, repeats in model_groups(cfg):
        x, cgroup, caps, a = run_group_prefill(
            cfg, params[name], pattern, repeats, x, positions, pad, mem,
            base, cap_targets, max_len, causal=True, want_cache=True,
            want_caps=want_caps, moe_impl=moe_impl)
        cache[name] = cgroup
        if want_caps:
            caps_all.append(caps)
        base += len(pattern) * repeats
        aux = aux + a
    # merge capture buffers across groups (each target hit in exactly one)
    caps = None
    if want_caps:
        caps = caps_all[0]
        for c in caps_all[1:]:
            caps = caps + c
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_head(params.get("head"), params["embed"], x[:, -1],
                     cfg.tie_embeddings)
    if pad is None:
        lengths = jnp.full((b,), s, jnp.int32)
        pad_arr = jnp.zeros((b,), jnp.int32)
    else:
        lengths = jnp.full((b,), s, jnp.int32)
        pad_arr = pad.astype(jnp.int32)
    cache["lengths"] = lengths
    cache["pad"] = pad_arr
    return {"logits": logits.astype(jnp.float32),
            "cache": cache,
            "captures": _caps_to_features(caps),
            "aux": aux}


def decode_step(cfg: ModelConfig, params, cache, tokens, *,
                moe_impl: str = "sort", want_caps: bool = True, tree=None):
    """Verify/decode block: tokens (B, T) at cache positions
    lengths + [0..T). Returns dict(logits (B,T,V), cache (uncommitted),
    captures (B,T,3D)).  With ``tree=(width, gamma)`` the block is a
    flattened draft tree (T = width*gamma + 1) scored in one
    tree-masked pass."""
    b, t = tokens.shape
    lengths, pad = cache["lengths"], cache["pad"]
    page_tbl = cache.get("page_tbl")
    x = embed(params["embed"], tokens, cfg.act_dtype)
    cap_targets = cfg.captures
    new_cache: Dict[str, Any] = {"lengths": lengths, "pad": pad}
    if page_tbl is not None:
        new_cache["page_tbl"] = page_tbl
    caps_all = []
    base = 0
    for name, pattern, repeats in model_groups(cfg):
        x, cgroup, caps, _ = run_group_decode(
            cfg, params[name], pattern, repeats, x, cache[name], lengths,
            pad, base, cap_targets, want_caps, moe_impl,
            page_tbl=page_tbl, tree=tree)
        new_cache[name] = cgroup
        if want_caps:
            caps_all.append(caps)
        base += len(pattern) * repeats
    caps = None
    if want_caps:
        caps = caps_all[0]
        for c in caps_all[1:]:
            caps = caps + c
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_head(params.get("head"), params["embed"], x,
                     cfg.tie_embeddings)
    return {"logits": logits.astype(jnp.float32),
            "cache": new_cache,
            "captures": _caps_to_features(caps)}


def commit_cache(cfg: ModelConfig, cache, n_accept):
    """Accept ``n_accept`` (B,) tokens out of the T-token verify block:
    advance lengths and select the surviving SSM states (rollback)."""
    new = {"lengths": cache["lengths"] + n_accept, "pad": cache["pad"]}
    if "page_tbl" in cache:
        new["page_tbl"] = cache["page_tbl"]
    idx = jnp.maximum(n_accept - 1, 0)
    for name, pattern, repeats in model_groups(cfg):
        group = cache[name]
        out_group = {}
        for pi, blk in enumerate(pattern):
            entry = group[f"pos{pi}"]
            if blk.mixer in (MAMBA, RWKV6):
                # leaves are (R, B, T, ...) -> select accepted step
                def pick(leaf):
                    ix = idx.reshape((1, -1) + (1,) * (leaf.ndim - 2))
                    return jnp.take_along_axis(leaf, ix, axis=2)[:, :, 0]
                entry = jax.tree.map(pick, entry)
            out_group[f"pos{pi}"] = entry
        new[name] = out_group
    return new


# ================================================================= training
def forward_train(cfg: ModelConfig, params, batch, *, moe_impl: str = "sort",
                  remat: bool = True):
    """Teacher-forced LM loss. batch: {"tokens" (B,S), "targets" (B,S),
    optional "image_embeds"/"frames"}. Returns (loss, metrics)."""
    tokens = batch["tokens"]
    targets = batch["targets"]
    b, s = tokens.shape
    mem = _memory(cfg, params, batch)
    x = embed(params["embed"], tokens, cfg.act_dtype)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    aux = jnp.float32(0.0)
    base = 0
    for name, pattern, repeats in model_groups(cfg):
        x, _, _, a = run_group_prefill(
            cfg, params[name], pattern, repeats, x, positions, None, mem,
            base, (), s, causal=True, want_cache=False, want_caps=False,
            moe_impl=moe_impl, remat=remat)
        base += len(pattern) * repeats
        aux = aux + a
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_head(params.get("head"), params["embed"], x,
                     cfg.tie_embeddings).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    mask = (targets >= 0).astype(jnp.float32)
    tgt = jnp.maximum(targets, 0)
    ll = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    ce = ((logz - ll) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux,
                  "accuracy": ((logits.argmax(-1) == tgt) * mask).sum()
                  / jnp.maximum(mask.sum(), 1.0)}


# ============================================================ cache init/ax
def _entry_shape(cfg: ModelConfig, blk: BlockDef, b: int, max_len: int,
                 mem_len: int):
    """(shapes, logical axes) template for one layer's cache entry."""
    hd, hk = cfg.head_dim, cfg.num_kv_heads
    dt = cfg.act_dtype
    if blk.mixer in (ATTN, ATTN_SW):
        sh = {"k": ((b, max_len, hk, hd), dt), "v": ((b, max_len, hk, hd), dt)}
        ax = {"k": (BATCH, KV_SEQ, "kv_heads", "qkv"),
              "v": (BATCH, KV_SEQ, "kv_heads", "qkv")}
    elif blk.mixer == MLA:
        sh = {"ckv": ((b, max_len, cfg.kv_lora_rank), dt),
              "kr": ((b, max_len, cfg.qk_rope_head_dim), dt)}
        ax = {"ckv": (BATCH, KV_SEQ, "latent"),
              "kr": (BATCH, KV_SEQ, "qkv")}
    elif blk.mixer == CROSS:
        sh = {"mk": ((b, mem_len, hk, hd), dt), "mv": ((b, mem_len, hk, hd), dt)}
        ax = {"mk": (BATCH, None, "kv_heads", "qkv"),
              "mv": (BATCH, None, "kv_heads", "qkv")}
    elif blk.mixer == MAMBA:
        di, n, dc = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
        sh = {"h": ((b, di, n), jnp.float32), "conv": ((b, dc - 1, di), dt)}
        ax = {"h": (BATCH, "mlp", "state"), "conv": (BATCH, None, "mlp")}
    elif blk.mixer == RWKV6:
        h, k = cfg.rwkv_heads, cfg.rwkv_head_dim
        sh = {"s": ((b, h, k, k), jnp.float32),
              "shift": ((b, 1, cfg.d_model), dt)}
        ax = {"s": (BATCH, "heads", "qkv", "qkv"),
              "shift": (BATCH, None, None)}
    else:
        raise ValueError(blk.mixer)
    if blk.cross:
        sh["xmk"] = ((b, mem_len, hk, hd), dt)
        sh["xmv"] = ((b, mem_len, hk, hd), dt)
        ax["xmk"] = (BATCH, None, "kv_heads", "qkv")
        ax["xmv"] = (BATCH, None, "kv_heads", "qkv")
    return sh, ax


def _mem_len(cfg: ModelConfig, seq_for_mem: int = 0) -> int:
    if cfg.num_image_tokens:
        return cfg.num_image_tokens
    if cfg.encoder_layers:
        return seq_for_mem
    return 0


def paged_check(cfg: ModelConfig, max_len: int, page_size: int):
    """Validate a paged-cache request: paging covers attention K/V
    pools only, so every mixer must be ATTN/ATTN_SW, and the lane
    window must tile into whole pages."""
    if page_size <= 0:
        raise ValueError(f"page_size must be positive, got {page_size}")
    if max_len % page_size:
        raise ValueError(
            f"max_len {max_len} must be a multiple of page_size "
            f"{page_size}")
    for _, pattern, _ in model_groups(cfg):
        for blk in pattern:
            if blk.mixer not in (ATTN, ATTN_SW):
                raise ValueError(
                    f"paged KV cache supports attention mixers only; "
                    f"config has {blk.mixer!r}")


def tree_check(cfg: ModelConfig):
    """Validate a tree-speculation request: the tree verify pass scores
    all branches in one block and commits only the accepted root path,
    which requires per-position K/V rollback — attention mixers only
    (SSM/RWKV commit picks one step state along the block T axis, which
    is path-order-dependent under a tree)."""
    for _, pattern, _ in model_groups(cfg):
        for blk in pattern:
            if blk.mixer not in (ATTN, ATTN_SW):
                raise ValueError(
                    f"tree speculation supports attention mixers only; "
                    f"config has {blk.mixer!r}")


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               mem_len: int = 0, *, page_size: int = 0,
               num_pages: int = 0) -> dict:
    """Zero-initialized decode cache (used directly by dry-run input_specs).

    With ``page_size > 0`` the attention K/V leaves are page *pools*
    shaped (repeats, num_pages + 1, page_size, Hk, D) — page
    ``num_pages`` is the trash page — plus one shared block table
    ``page_tbl`` (batch, max_len // page_size) initialized to all-trash
    (no lane maps any real page until the allocator reserves for it).
    """
    if page_size > 0:
        paged_check(cfg, max_len, page_size)
    cache: Dict[str, Any] = {
        "lengths": jnp.zeros((batch,), jnp.int32),
        "pad": jnp.zeros((batch,), jnp.int32),
    }
    if page_size > 0:
        cache["page_tbl"] = jnp.full(
            (batch, max_len // page_size), num_pages, jnp.int32)
    for name, pattern, repeats in model_groups(cfg):
        group = {}
        for pi, blk in enumerate(pattern):
            if page_size > 0:
                hd, hk = cfg.head_dim, cfg.num_kv_heads
                sh = {k: ((num_pages + 1, page_size, hk, hd), cfg.act_dtype)
                      for k in ("k", "v")}
            else:
                sh, _ = _entry_shape(cfg, blk, batch, max_len, mem_len)
            group[f"pos{pi}"] = {
                k: jnp.zeros((repeats,) + shape, dtype)
                for k, (shape, dtype) in sh.items()}
        cache[name] = group
    return cache


def cache_abstract(cfg: ModelConfig, batch: int, max_len: int,
                   mem_len: int = 0) -> dict:
    """ShapeDtypeStruct pytree mirroring ``init_cache`` (no allocation)."""
    return jax.eval_shape(
        lambda: init_cache(cfg, batch, max_len, mem_len))


def cache_axes(cfg: ModelConfig) -> dict:
    """Logical axes pytree aligned with ``init_cache`` output."""
    axes: Dict[str, Any] = {"lengths": (BATCH,), "pad": (BATCH,)}
    for name, pattern, repeats in model_groups(cfg):
        group = {}
        for pi, blk in enumerate(pattern):
            _, ax = _entry_shape(cfg, blk, 1, 1, 1)
            group[f"pos{pi}"] = {k: (LAYERS,) + a for k, a in ax.items()}
        axes[name] = group
    return axes
