"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576, MoE 16e top-2 — Mamba+attn 1:7 interleave, MoE every other
layer.  [arXiv:2403.19887]

Superblock of 8: attention at position 4, Mamba elsewhere; MoE replaces
the MLP on every other (odd) layer.  72 = 9 superblocks.
"""
from repro.models.config import (ATTN, FFN_MOE, FFN_SWIGLU, MAMBA, BlockDef,
                                 ModelConfig, reduced)


def _blk(i: int) -> BlockDef:
    mixer = ATTN if i == 4 else MAMBA
    ffn = FFN_MOE if i % 2 == 1 else FFN_SWIGLU
    return BlockDef(mixer, ffn)


CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    citation="arXiv:2403.19887",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    pattern=tuple(_blk(i) for i in range(8)),
    num_experts=16,
    experts_per_tok=2,
    moe_d_ff=24576,          # Jamba experts use the full MLP width
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    rope_theta=10000.0,
)

REDUCED = reduced(
    CONFIG,
    num_layers=2,
    pattern=(BlockDef(MAMBA, FFN_SWIGLU), BlockDef(ATTN, FFN_MOE)),
)
