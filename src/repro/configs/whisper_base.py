"""whisper-base [audio] — 6L d_model=512 8H (kv=8) d_ff=2048 vocab=51865 —
enc-dec, conv frontend (stub).  [arXiv:2212.04356]

The mel-spectrogram + conv feature extractor is STUBBED per the assignment:
``input_specs`` provides pre-embedded frames (B, S, d_model).  The model is
the 6-layer bidirectional encoder + 6-layer causal decoder with cross
attention.  long_500k is SKIPPED for this arch (decoder is architecturally
capped; see DESIGN.md §Shape skips).
"""
from repro.models.config import ATTN, FFN_GELU, BlockDef, ModelConfig, reduced

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    citation="arXiv:2212.04356",
    num_layers=6,            # decoder layers
    encoder_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    pattern=(BlockDef(ATTN, FFN_GELU, cross=True),),
    decoder_len=448,         # whisper max target positions
    rope_theta=10000.0,
)

REDUCED = reduced(CONFIG)
