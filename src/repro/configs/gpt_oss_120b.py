"""gpt-oss-120b — the TIDE paper's primary target model (OpenAI, 2025,
arXiv:2508.10925): 36L d_model=2880 64H (GQA kv=8, head_dim 64), MoE 128
experts top-4, alternating sliding-window (128) / full attention layers,
vocab ~201k.  Used by the paper-faithful benchmarks (Figs. 5–9, Tables 1–5).
"""
from repro.models.config import (ATTN, FFN_MOE, BlockDef,
                                 ModelConfig, reduced)

CONFIG = ModelConfig(
    name="gpt-oss-120b",
    family="moe",
    citation="arXiv:2508.10925",
    num_layers=36,
    d_model=2880,
    num_heads=64,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2880,
    vocab_size=201088,
    pattern=(BlockDef(ATTN, FFN_MOE), BlockDef(ATTN, FFN_MOE)),
    num_experts=128,
    experts_per_tok=4,
    moe_d_ff=2880,
    rope_theta=150000.0,
)

REDUCED = reduced(CONFIG, num_layers=2,
                  pattern=(BlockDef(ATTN, FFN_MOE),))
