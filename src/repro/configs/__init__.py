"""Architecture config registry.

Each assigned architecture lives in its own module exporting ``CONFIG``
(the exact assigned spec, citation in ``citation``) and ``REDUCED`` (a
tiny same-family variant for CPU smoke tests).  ``get(name)`` /
``get_reduced(name)`` look them up; ``ARCHS`` lists all assigned ids.
"""
from __future__ import annotations

import importlib

ARCHS = [
    "llama_3_2_vision_11b",
    "glm4_9b",
    "phi3_medium_14b",
    "deepseek_v3_671b",
    "jamba_1_5_large_398b",
    "starcoder2_15b",
    "whisper_base",
    "granite_moe_3b_a800m",
    "rwkv6_3b",
    "starcoder2_7b",
    # the paper's own primary target model (gpt-oss-120b), for the
    # paper-faithful benchmarks
    "gpt_oss_120b",
    # tiny live-demo target used by examples/ and the CPU engine tests
    "tide_tiny",
]

_ALIASES = {
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "glm4-9b": "glm4_9b",
    "phi3-medium-14b": "phi3_medium_14b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "starcoder2-15b": "starcoder2_15b",
    "whisper-base": "whisper_base",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "rwkv6-3b": "rwkv6_3b",
    "starcoder2-7b": "starcoder2_7b",
    "gpt-oss-120b": "gpt_oss_120b",
    "tide-tiny": "tide_tiny",
}


def _module(name: str):
    key = _ALIASES.get(name, name.replace("-", "_"))
    return importlib.import_module(f"repro.configs.{key}")


def get(name: str):
    return _module(name).CONFIG


def get_reduced(name: str):
    return _module(name).REDUCED


def assigned() -> list:
    """The ten assigned architecture ids (canonical dashed form)."""
    return [a for a in _ALIASES if a not in ("gpt-oss-120b", "tide-tiny")]
