"""tide-tiny — a ~6M-parameter dense target model that runs end-to-end on
CPU.  Used by examples/ and the live TIDE engine tests/benchmarks (the
paper's Fig. 5/6/9 dynamics are reproduced at this scale)."""
from repro.models.config import ATTN, FFN_SWIGLU, BlockDef, ModelConfig, reduced

CONFIG = ModelConfig(
    name="tide-tiny",
    family="dense",
    citation="(live-demo model, this repo)",
    num_layers=4,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=512,
    vocab_size=512,
    pattern=(BlockDef(ATTN, FFN_SWIGLU),),
    dtype="float32",
    chunk_len=16,
    attn_block_q=64,
    attn_block_kv=128,
)

REDUCED = reduced(CONFIG)
