"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40e top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from repro.models.config import FFN_MOE, BlockDef, ModelConfig, reduced

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    pattern=(BlockDef("attn", FFN_MOE),),
    num_experts=40,
    experts_per_tok=8,
    moe_d_ff=512,
    rope_theta=10000.0,
)

REDUCED = reduced(CONFIG)
