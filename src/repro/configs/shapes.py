"""Assigned input shapes and per-(arch × shape) input specs.

Decode shapes lower ``serve_step`` (one speculative verify block against a
``seq_len`` KV cache); train lowers ``train_step``; prefill lowers the
prompt pass.  ``input_specs`` returns ShapeDtypeStruct stand-ins only —
no device allocation (the dry-run pattern).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig, RWKV6, MAMBA, ATTN, MLA

# Sliding window used for the dense/moe/vlm long-context decode variant.
LONG_CONTEXT_WINDOW = 8192
SPEC_BLOCK = 4           # γ + 1 tokens per verify block (paper: γ = 3)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def _mixers(cfg: ModelConfig):
    return {b.mixer for b in cfg.layer_kinds}


def is_subquadratic(cfg: ModelConfig) -> bool:
    """True if every mixer is O(1)-state or windowed."""
    mix = _mixers(cfg)
    if mix <= {RWKV6, MAMBA}:
        return True
    return bool(cfg.window)


def applicable(cfg: ModelConfig, shape_name: str) -> Tuple[bool, str]:
    """Whether this (arch, shape) pair runs, and why not if skipped.

    Rules (DESIGN.md §Shape skips): long_500k skipped only for whisper-base
    (architecturally capped decoder); dense/moe/vlm archs run long_500k with
    a sliding-window attention variant (see ``shape_cfg``)."""
    if shape_name == "long_500k" and cfg.family == "audio":
        return False, ("audio decoder is positionally capped (448); no 500k "
                       "decode regime exists for this arch")
    return True, ""


def shape_cfg(cfg: ModelConfig, shape_name: str) -> ModelConfig:
    """Shape-adapted config: long_500k forces sub-quadratic attention for
    archs with full-attention mixers (flagged [sw] in the roofline table)."""
    if shape_name == "long_500k" and not is_subquadratic(cfg):
        has_attn = ATTN in _mixers(cfg) or MLA in _mixers(cfg)
        if has_attn:
            return dataclasses.replace(cfg, window=LONG_CONTEXT_WINDOW)
    return cfg


def _token_spec(b, s):
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def _extras_spec(cfg: ModelConfig, b: int, s: int) -> Dict:
    dt = cfg.act_dtype
    if cfg.family == "audio":
        return {"frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)}
    if cfg.num_image_tokens:
        return {"image_embeds": jax.ShapeDtypeStruct(
            (b, cfg.num_image_tokens, cfg.d_model), dt)}
    return {}


def mem_len_for(cfg: ModelConfig, enc_seq: int = 0) -> int:
    if cfg.num_image_tokens:
        return cfg.num_image_tokens
    if cfg.encoder_layers:
        # whisper-base encodes 30 s -> 1500 frames; decode shapes use this
        return enc_seq or 1504
    return 0


def input_specs(cfg: ModelConfig, shape_name: str,
                gamma: int = SPEC_BLOCK - 1) -> Dict:
    """Model-input ShapeDtypeStructs for the entry point of this shape.

    train  -> {"batch": {tokens, targets, extras...}}
    prefill-> {"tokens", "extra"}
    decode -> {"tokens" (B, γ+1), "cache" (abstract)}
    """
    shp = SHAPES[shape_name]
    cfg = shape_cfg(cfg, shape_name)
    b, s = shp.global_batch, shp.seq_len
    if shp.kind == "train":
        if cfg.family == "audio":
            dl = cfg.decoder_len
            batch = {"tokens": _token_spec(b, dl), "targets": _token_spec(b, dl)}
        else:
            batch = {"tokens": _token_spec(b, s), "targets": _token_spec(b, s)}
        batch.update(_extras_spec(cfg, b, s))
        return {"batch": batch}
    if shp.kind == "prefill":
        out = {"tokens": _token_spec(b, s), "extra": _extras_spec(cfg, b, s)}
        if cfg.family == "audio":
            # the decoder consumes BOS-ish prompt; encoder consumes frames
            out["tokens"] = _token_spec(b, min(s, cfg.decoder_len))
        return out
    # decode: γ+1-token verify block against a seq_len-deep cache.
    # headroom of 16 keeps max_len divisible by the 16-way model axis so
    # the kv_seq sharding rule applies (divisibility auto-drop otherwise).
    max_len = s + 16
    cache = T.cache_abstract(cfg, b, max_len, mem_len_for(cfg))
    return {"tokens": _token_spec(b, gamma + 1), "cache": cache}
