"""llama-3.2-vision-11b [vlm] — 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256, cross-attn image layers every 5th layer.
[hf:meta-llama/Llama-3.2-11B-Vision]

The vision frontend (ViT encoder + projector) is a STUB per the assignment:
``input_specs`` provides precomputed patch embeddings (B, M, d_model); the
model here is the language backbone with interleaved cross-attention layers.
"""
from repro.models.config import (ATTN, CROSS, FFN_SWIGLU, BlockDef,
                                 ModelConfig, reduced)

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    citation="hf:meta-llama/Llama-3.2-11B-Vision",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    # every 5th layer is a cross-attention (image) layer: 8 of 40
    pattern=(BlockDef(ATTN, FFN_SWIGLU),) * 4 + (BlockDef(CROSS, FFN_SWIGLU),),
    rope_theta=500000.0,
    num_image_tokens=4096,   # 4 tiles x 1024 patches (stubbed frontend)
)

REDUCED = reduced(
    CONFIG,
    num_layers=2,
    pattern=(BlockDef(ATTN, FFN_SWIGLU), BlockDef(CROSS, FFN_SWIGLU)),
)
