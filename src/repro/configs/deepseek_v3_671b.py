"""deepseek-v3-671b [moe] — 61L d_model=7168 128H (GQA kv=128) d_ff=2048
vocab=129280, MoE 256e top-8 — MLA, 1 shared + 256 routed top-8, MTP.
[arXiv:2412.19437]

MLA dims follow the DeepSeek-V3 report: q_lora 1536, kv_lora 512,
qk_nope 128, qk_rope 64, v 128.  First 3 layers are dense (d_ff 18432 in
the report; the assigned spec's d_ff=2048 is the per-expert MoE hidden,
kept as ``moe_d_ff``; the dense prologue uses the report's 18432).
The MTP module is exposed via ``mtp_depth=1`` and implemented as an
optional extra predict layer in ``repro.core.eagle`` (DeepSeek's MTP is
the paper's own EAGLE-style analogue).
"""
from repro.models.config import (FFN_MOE, FFN_SWIGLU, MLA, BlockDef,
                                 ModelConfig, reduced)

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    citation="arXiv:2412.19437",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=18432,              # dense prologue FFN
    vocab_size=129280,
    prologue=(BlockDef(MLA, FFN_SWIGLU),) * 3,
    pattern=(BlockDef(MLA, FFN_MOE),),
    num_experts=256,
    experts_per_tok=8,
    num_shared_experts=1,
    moe_d_ff=2048,           # assigned per-expert hidden
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    rope_theta=10000.0,
    mtp_depth=1,
)

REDUCED = reduced(CONFIG)
