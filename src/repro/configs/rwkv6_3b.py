"""rwkv6-3b [ssm] — 32L d_model=2560 (attention-free) d_ff=8960 vocab=65536 —
Finch, data-dependent decay.  [arXiv:2404.05892]

O(1)-state decode: runs long_500k natively (no attention window needed).
"""
from repro.models.config import FFN_SWIGLU, RWKV6, BlockDef, ModelConfig, reduced

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    citation="arXiv:2404.05892",
    num_layers=32,
    d_model=2560,
    num_heads=40,            # 2560 / 64 time-mix heads
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    pattern=(BlockDef(RWKV6, FFN_SWIGLU),),
    rwkv_head_dim=64,
)

REDUCED = reduced(CONFIG, rwkv_head_dim=32, num_heads=4, num_kv_heads=4)
