"""starcoder2-7b [dense] — 32L d_model=4608 36H (GQA kv=4) d_ff=18432
vocab=49152 — GQA, RoPE.  [arXiv:2402.19173]"""
from repro.models.config import ATTN, FFN_GELU, BlockDef, ModelConfig, reduced

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    citation="arXiv:2402.19173",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49152,
    pattern=(BlockDef(ATTN, FFN_GELU),),
    rope_theta=100000.0,
)

REDUCED = reduced(CONFIG, num_heads=4, num_kv_heads=2)
