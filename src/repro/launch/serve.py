"""Serving launcher: runs the full TIDE system (adaptive speculative
decoding + online draft training) on a reduced config, live on the local
device(s).  ``--dryrun`` lowers the full config's speculative serve step
on the production mesh instead.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch tide-tiny --requests 48
  PYTHONPATH=src python -m repro.launch.serve --arch tide-tiny --continuous
  PYTHONPATH=src python -m repro.launch.serve --arch tide-tiny --tree-width 4
  PYTHONPATH=src python -m repro.launch.serve --arch deepseek-v3-671b --dryrun

``--continuous`` serves a ragged Poisson arrival trace through the
continuous-batching ``serve_stream`` loop (in-flight slot refill)
instead of run-to-completion waves, and reports goodput, slot
occupancy, and TTFT/latency percentiles.

Every ``ServingConfig`` field has a flag here (and a flat
``TideConfig`` mirror) — ``build_parser``/``config_from_args`` are the
one mapping, asserted total by tests/test_config_mirror.py.
"""
from __future__ import annotations

import argparse
import time


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tide-tiny")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gamma", type=int, default=3)
    ap.add_argument("--max-len", type=int, default=0,
                    help="engine cache length (0 = auto: 96 for waves, "
                         "160 for --continuous)")
    ap.add_argument("--max-new-tokens", type=int, default=32)
    ap.add_argument("--sample", action="store_true",
                    help="per-request-keyed sampled decoding instead of "
                         "greedy argmax")
    ap.add_argument("--superstep-rounds", type=int, default=8,
                    help="speculative rounds fused per superstep "
                         "dispatch (0 = per-step reference loop)")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="stop token id (default: budget-only stop)")
    ap.add_argument("--accept-ema", type=float, default=0.9,
                    help="acceptance-length EMA decay for the Eq. 5 gate")
    ap.add_argument("--seed", type=int, default=0,
                    help="engine base seed (per-request sampling streams)")
    ap.add_argument("--tree-width", type=int, default=0,
                    help=">=1: tree speculation — draft W top-k "
                         "branches, each gamma deep, verified in one "
                         "tree-masked target pass; the longest accepted "
                         "root path commits (1 = degenerate tree, "
                         "bitwise equal to the chain; 0 = chain)")
    ap.add_argument("--pretrain-steps", type=int, default=120)
    ap.add_argument("--no-adaptive", action="store_true")
    ap.add_argument("--continuous", action="store_true",
                    help="serve a ragged Poisson arrival trace with "
                         "in-flight slot refill instead of waves")
    ap.add_argument("--async-train", action="store_true",
                    help="decoupled draft training: background service, "
                         "zero-sync versioned deploys + draft-cache "
                         "re-seed (default: synchronous drain at "
                         "completion boundaries)")
    ap.add_argument("--gate-arrivals", action="store_true",
                    help="replay trace arrival timestamps (idle "
                         "supersteps in gaps) instead of serving the "
                         "trace as a backlog; implies --continuous")
    ap.add_argument("--idle-wait-s", type=float, default=0.005,
                    help="max host sleep per gated-arrival idle tick")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked refill prefill width (multiple of 8; "
                         "0 = one-shot): bounds the stall a long prompt "
                         "injects into resident decode lanes to one "
                         "chunk per superstep gap")
    ap.add_argument("--page-size", type=int, default=0,
                    help=">0: paged KV cache — target/draft caches "
                         "become block-table page pools with admission-"
                         "time reservations and COW prompt-prefix "
                         "sharing (must divide max_len; 0 = dense)")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="page-pool size (0 = the dense footprint, "
                         "batch * max_len / page_size)")
    ap.add_argument("--no-share-prefix", action="store_true",
                    help="disable COW prompt-prefix page sharing")
    ap.add_argument("--reseed-window", type=int, default=None,
                    help="deploy-time draft-cache re-seed ring size "
                         "(default: 32 under --async-train, else 0; "
                         "paged engines re-seed through the lanes' "
                         "block-table rows in place)")
    ap.add_argument("--policy",
                    choices=["fifo", "priority", "deadline", "wedf"],
                    default="fifo",
                    help="admission policy: fifo (arrival order), "
                         "priority (highest Request.priority first), "
                         "deadline (EDF over Request.deadline — the "
                         "latency-SLO policy), or wedf (EDF with the "
                         "deadline relaxed by priority weight); implies "
                         "--continuous for non-fifo choices")
    ap.add_argument("--preempt", choices=["none", "deadline"],
                    default="none",
                    help="preemption policy (docs/overload.md): deadline "
                         "spills the loosest resident lane to host when "
                         "a tighter-deadline candidate is deferred "
                         "against a full batch, restoring it byte-"
                         "identically once a lane frees (superstep "
                         "mode only)")
    ap.add_argument("--shed", choices=["none", "expired", "queue"],
                    default="none",
                    help="load-shedding policy: expired drops queued "
                         "requests whose deadline already passed; queue "
                         "bounds the arrived queue depth, dropping the "
                         "loosest deadlines first")
    ap.add_argument("--shed-queue-depth", type=int, default=64,
                    help="arrived-queue depth bound for --shed queue")
    ap.add_argument("--commit", choices=["cohort", "eager"],
                    default="cohort",
                    help="chunk-pipeline commit policy: cohort (default; "
                         "an admission batch's pipelines land together, "
                         "densest decode rounds) or eager (each pipeline "
                         "commits when its prefill finishes — better "
                         "short-prompt TTFT under mixed bursts)")
    ap.add_argument("--admission-lookahead", type=int, default=64,
                    help="queue reorder window for non-FIFO admission")
    ap.add_argument("--spec-park", type=int, default=0,
                    help=">0: park speculation + signal capture after N "
                         "consecutive gated-off rounds; resume via "
                         "periodic forced-speculation acceptance probes")
    ap.add_argument("--spec-probe-interval", type=int, default=8,
                    help="parked dispatches between acceptance probes")
    ap.add_argument("--trainer-threads", type=int, default=0,
                    help=">0: bound the async trainer's host-thread "
                         "contention with serving by deprioritizing the "
                         "training thread at the OS scheduler (the "
                         "in-process XLA pool is shared, so a hard "
                         "per-client thread cap needs the out-of-"
                         "process trainer — see ROADMAP)")
    # ---- disaggregation (repro/fleet; docs/disaggregation.md): not
    #      ServingConfig knobs — they select process/fleet topology
    #      around unchanged engines (FleetConfig; asserted total by
    #      tests/test_config_mirror.py)
    ap.add_argument("--fleet-replicas", type=int, default=0,
                    help=">0: serve through a data-parallel fleet of N "
                         "engine replicas behind a front-end router, "
                         "fed by one shared trainer over the draft-"
                         "version bus (0 = single engine)")
    ap.add_argument("--trainer-endpoint", default=None,
                    metavar="ENDPOINT",
                    help="run draft training out of process on its own "
                         "XLA client: 'spawn' forks a private trainer "
                         "subprocess; unix:/path or tcp:host:port "
                         "connect to a running "
                         "`python -m repro.fleet.trainer_main`")
    ap.add_argument("--fleet-route", choices=["least", "rr"],
                    default="least",
                    help="fleet request routing: least (cost-estimate "
                         "least-loaded, default) or rr (round-robin)")
    ap.add_argument("--dryrun", action="store_true")
    ap.add_argument("--shape", default="decode_32k")
    # ---- observability (repro/obs): main()-consumed, not ServingConfig
    #      knobs — the engine takes built tracer/recorder collaborators
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome/Perfetto trace-event JSON of "
                         "the run's host-side spans (superstep dispatch/"
                         "unpack, prefill chunks, train cycles, deploys) "
                         "to PATH at exit; chrome://tracing or ui."
                         "perfetto.dev loads it")
    ap.add_argument("--metrics-interval", type=float, default=0.0,
                    metavar="N",
                    help=">0: print a Prometheus-text metrics snapshot "
                         "(serving.*/train.*/paging.*/spec.* registry) "
                         "every N seconds from a background thread")
    ap.add_argument("--flight-record", action="store_true",
                    help="enable the per-request flight recorder and "
                         "print a timeline digest for the slowest "
                         "requests at exit")
    return ap


def config_from_args(args):
    """Assemble the ``ServingConfig`` the parsed flags name (the
    testable flag → config-field mapping; ``completion_sink`` is the
    one field with no flag — it is a host callback, not a knob)."""
    from repro.serving.policy import ServingConfig

    continuous = (getattr(args, "continuous", False) or args.gate_arrivals
                  or args.policy != "fifo")
    reseed = args.reseed_window
    if reseed is None:
        reseed = 32 if getattr(args, "async_train", False) else 0
    return ServingConfig(
        gamma=args.gamma, batch_size=args.batch,
        max_len=args.max_len or (160 if continuous else 96),
        greedy=not args.sample,
        superstep_rounds=args.superstep_rounds,
        eos_id=args.eos_id, ema=args.accept_ema, seed=args.seed,
        admission=args.policy, commit=args.commit,
        admission_lookahead=args.admission_lookahead,
        preempt=args.preempt, shed=args.shed,
        shed_queue_depth=args.shed_queue_depth,
        gate_arrivals=args.gate_arrivals, idle_wait_s=args.idle_wait_s,
        prefill_chunk=args.prefill_chunk,
        page_size=args.page_size, num_pages=args.num_pages,
        share_prefix=not args.no_share_prefix,
        spec_park_patience=args.spec_park,
        spec_probe_interval=args.spec_probe_interval,
        reseed_window=reseed, trainer_threads=args.trainer_threads,
        tree_width=args.tree_width)


def fleet_config_from_args(args):
    """Assemble the ``FleetConfig`` the disaggregation flags name (the
    testable flag → config-field mapping, same contract as
    ``config_from_args``).  Returns None when no fleet/remote-trainer
    topology was requested."""
    from repro.fleet import FleetConfig

    if not getattr(args, "fleet_replicas", 0) \
            and getattr(args, "trainer_endpoint", None) is None:
        return None
    return FleetConfig(replicas=args.fleet_replicas,
                       trainer_endpoint=args.trainer_endpoint,
                       route=args.fleet_route)


def main():
    args = build_parser().parse_args()

    if args.dryrun:
        import os
        import subprocess
        import sys
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch",
               args.arch, "--shape", args.shape]
        raise SystemExit(subprocess.call(cmd, env=dict(
            os.environ, PYTHONPATH=os.environ.get("PYTHONPATH", "src"))))

    import jax
    import numpy as np

    import repro.configs as configs
    from repro.core.adaptive import analytic_tpu_profile
    from repro.core.tide import TideConfig, TideSystem
    from repro.data.workloads import (Phase, WorkloadStream, arrival_trace,
                                      make_domains, training_corpus)
    from repro.models import transformer as T
    from repro.training.trainer import pretrain_target

    cfg = configs.get(args.arch) if args.arch == "tide-tiny" \
        else configs.get_reduced(args.arch)
    if cfg.family in ("audio", "vlm"):
        raise SystemExit(f"live demo serves text-only archs; {cfg.family} "
                         "frontends are stubbed (use --dryrun)")
    print(f"serving {cfg.name} ({cfg.param_count()/1e6:.2f}M params)")
    params = T.init(cfg, jax.random.key(0))

    domains = make_domains(cfg.vocab_size, ["science", "code"],
                           branchings=[2, 3], seed=3)
    corpus = np.concatenate([
        training_corpus(domains["science"], 64, 48, 1),
        training_corpus(domains["code"], 64, 48, 2)])
    print(f"pretraining target {args.pretrain_steps} steps...")
    params, losses = pretrain_target(cfg, params, corpus,
                                     steps=args.pretrain_steps, lr=3e-3)
    print(f"  loss {losses[0]:.2f} -> {losses[-1]:.2f}")

    n = args.requests
    args.continuous = (args.continuous or args.gate_arrivals
                       or args.policy != "fifo")
    scfg = config_from_args(args)
    from repro.obs import ObsConfig
    obs = ObsConfig(trace=args.trace_out is not None,
                    trace_path=args.trace_out,
                    record=args.flight_record)
    tc = TideConfig(serving=scfg,
                    n_threshold=4, signal_window=16,
                    adaptive_spec=not args.no_adaptive,
                    async_train=args.async_train,
                    obs=obs, fleet=fleet_config_from_args(args))
    profile = analytic_tpu_profile(cfg, chips=1)
    if tc.fleet is not None and tc.fleet.replicas > 0:
        return _main_fleet(args, cfg, params, tc, profile, domains)
    sys_ = TideSystem(cfg, params, tc, profile=profile)
    stop_metrics = _start_metrics_printer(sys_, args.metrics_interval)
    t0 = time.perf_counter()
    if args.continuous:
        # ragged budgets never exceed the user's --max-new-tokens cap
        mx = max(args.max_new_tokens, 1)
        # non-FIFO policies need SLO-annotated traces: a bimodal
        # loose/tight deadline mix for EDF, random priority classes
        slo = {}
        if args.policy == "deadline":
            slo = dict(deadline_slack=(8.0, 16.0), tight_frac=0.3,
                       tight_slack=(0.5, 2.0))
        elif args.policy == "priority":
            slo = dict(priority_levels=3)
        trace = arrival_trace(
            domains, n, mode="poisson", rate=16.0,
            max_new_range=(min(8, mx), mx),
            schedule=[Phase("science", n // 2), Phase("code", n - n // 2)],
            seed=1, **slo)
        sys_.run_stream(sys_.requests_from_trace(trace))
    else:
        stream = WorkloadStream(domains, [Phase("science", n // 2),
                                          Phase("code", n - n // 2)],
                                seed=1)
        sys_.run(stream.batches(args.batch),
                 max_new_tokens=args.max_new_tokens)
    if args.async_train:
        # finish any training the stream's signals still owe, then stop
        # the service thread cleanly
        sys_.service.drain()
        sys_.close()
    stop_metrics()
    s = sys_.summary()
    print(f"\n== TIDE summary ({time.perf_counter()-t0:.1f}s wall) ==")
    for k, v in s.items():
        print(f"  {k}: {v:.3f}" if isinstance(v, float) else f"  {k}: {v}")
    if args.async_train:
        print(f"  service: {sys_.service.stats()}")
    tl = sys_.engine.stats.timeline
    q = max(len(tl) // 4, 1)
    first = np.mean([x["accept_len"] for x in tl[:q]])
    last = np.mean([x["accept_len"] for x in tl[-q:]])
    print(f"  accept_len trend: {first:.2f} -> {last:.2f} "
          f"(draft adapted online, paper Fig. 5)")
    if args.trace_out:
        doc = sys_.export_trace()
        print(f"  trace: {len(doc['traceEvents'])} events -> "
              f"{args.trace_out}")
    if args.flight_record:
        _print_flight_digest(sys_.recorder)


def _main_fleet(args, cfg, params, tc, profile, domains):
    """Fleet serving path (--fleet-replicas N): route an arrival trace
    across N data-parallel replicas fed by one shared (optionally
    out-of-process) trainer, and print the aggregate fleet summary."""
    import time as _time

    from repro.data.workloads import Phase, arrival_trace
    from repro.fleet.router import ServingFleet
    from repro.serving.request import Request

    n = args.requests
    mx = max(args.max_new_tokens, 1)
    trace = arrival_trace(
        domains, n, mode="poisson", rate=16.0,
        max_new_range=(min(8, mx), mx),
        schedule=[Phase("science", n // 2), Phase("code", n - n // 2)],
        seed=1)
    reqs = [Request(prompt=ev.prompt, domain=ev.domain,
                    max_new_tokens=ev.max_new_tokens, arrives_at=ev.t)
            for ev in trace]
    fleet = ServingFleet(cfg, params, tc, profile=profile)
    t0 = _time.perf_counter()
    fleet.serve(reqs)
    fleet.service.drain()
    fleet.close()
    s = fleet.summary()
    print(f"\n== fleet summary ({_time.perf_counter()-t0:.1f}s wall, "
          f"{s['replicas']} replicas) ==")
    for k, v in s.items():
        print(f"  {k}: {v:.3f}" if isinstance(v, float) else f"  {k}: {v}")


def _start_metrics_printer(sys_, interval: float):
    """Background Prometheus-text snapshot printer (--metrics-interval).
    Reads only host-side registry state — callback gauges and counters —
    so it never perturbs serving.  Returns a stop() callable."""
    if interval <= 0:
        return lambda: None
    import threading
    stop = threading.Event()

    def loop():
        while not stop.wait(interval):
            print(f"\n-- metrics @{time.strftime('%H:%M:%S')} --")
            print(sys_.metrics.to_prometheus(), end="")

    t = threading.Thread(target=loop, name="tide-metrics", daemon=True)
    t.start()

    def stop_fn():
        stop.set()
        t.join(timeout=5.0)

    return stop_fn


def _print_flight_digest(recorder, worst: int = 3):
    """Per-request flight-recorder digest: the ``worst`` highest-latency
    completed requests, with their event timelines."""
    tls = sorted(recorder.timelines(),
                 key=lambda tl: tl.get("latency_s") or 0.0, reverse=True)
    print(f"\n== flight recorder ({len(tls)} requests) ==")
    for tl in tls[:worst]:
        print(f"  rid={tl['rid']} sid={tl['sid']} domain={tl['domain']} "
              f"ttft={tl.get('ttft_s')} latency={tl.get('latency_s')}")
        for ev in tl["events"]:
            extra = {k: v for k, v in ev.items()
                     if k not in ("kind", "round", "t")}
            print(f"    r{ev['round']:>5} t={ev['t']:.3f}s {ev['kind']}"
                  + (f" {extra}" if extra else ""))


if __name__ == "__main__":
    main()
