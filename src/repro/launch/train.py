"""Training launcher.

Two modes:
  * demo (default): runs real steps of a reduced config on the local
    device(s) — a live, verifiable training loop.
  * --dryrun: delegates to launch/dryrun.py semantics for the full config
    on the production mesh (lower+compile only).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch glm4-9b --steps 20
  PYTHONPATH=src python -m repro.launch.train --arch deepseek-v3-671b --dryrun
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tide-tiny")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", choices=["adamw", "adafactor"],
                    default="adamw")
    ap.add_argument("--dryrun", action="store_true")
    ap.add_argument("--shape", default="train_4k")
    args = ap.parse_args()

    if args.dryrun:
        # re-exec through the dry-run module so XLA_FLAGS is set first
        import os
        import subprocess
        import sys
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch",
               args.arch, "--shape", args.shape]
        raise SystemExit(subprocess.call(cmd, env=dict(
            os.environ, PYTHONPATH=os.environ.get("PYTHONPATH", "src"))))

    import repro.configs as configs
    from repro.data.workloads import make_domains, training_corpus
    from repro.models import transformer as T
    from repro.training.optimizer import adafactor, adamw
    from repro.training.trainer import make_train_step

    cfg = configs.get_reduced(args.arch)
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.2f}M params "
          f"on {jax.devices()}")
    params = T.init(cfg, jax.random.key(0))
    opt = adamw(lr=args.lr) if args.optimizer == "adamw" else \
        adafactor(lr=args.lr)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt, n_micro=1, remat=False))

    dom = make_domains(cfg.vocab_size, ["train"], seed=0)["train"]
    corpus = training_corpus(dom, 4 * args.batch, args.seq + 1, seed=1)
    extra = {}
    if cfg.family == "audio":
        extra["frames"] = jnp.zeros((args.batch, args.seq, cfg.d_model),
                                    cfg.act_dtype)
    if cfg.num_image_tokens:
        extra["image_embeds"] = jnp.zeros(
            (args.batch, cfg.num_image_tokens, cfg.d_model), cfg.act_dtype)

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for it in range(args.steps):
        sel = rng.integers(0, corpus.shape[0], size=args.batch)
        batch = {"tokens": jnp.asarray(corpus[sel][:, :-1]),
                 "targets": jnp.asarray(corpus[sel][:, 1:]), **extra}
        params, opt_state, m = step_fn(params, opt_state, batch,
                                       jnp.int32(it))
        if it % max(args.steps // 10, 1) == 0:
            print(f"step {it:4d}  loss {float(m['loss']):.4f}  "
                  f"acc {float(m['accuracy']):.3f}  "
                  f"gnorm {float(m['grad_norm']):.2f}")
    dt = time.perf_counter() - t0
    print(f"done: {args.steps} steps in {dt:.1f}s "
          f"({args.steps / dt:.2f} steps/s)")


if __name__ == "__main__":
    main()
