"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds-per-step:

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = Σ per-op traffic / (chips × link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.  Collective
traffic is parsed from the compiled HLO text: per op we take the result
byte size with ring-schedule multipliers (all-reduce 2(n−1)/n, gather /
scatter / all-to-all (n−1)/n, permute 1) and the replica-group size n
parsed per op.

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (per chip, one direction)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# first dtype[dims] token on the line = the (payload) result shape; async
# start ops have tuple results whose first component is the payload
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, float]
    top: Optional[List[Dict]] = None       # largest contributors

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


# ---------------------------------------------------- trip-aware parsing
# HLO text is per-computation; ops inside a while body execute
# trip_count times (scan over layers/microbatches/KV blocks).  Build a
# per-computation execution multiplier from `backend_config=
# {"known_trip_count":{"n":"R"}}` + body/calls edges.
_COMP_HDR_RE = re.compile(r"^(?:ENTRY )?%?([\w.\-]+) \(.*\) -> .* \{")
_WHILE_RE = re.compile(
    r"body=%([\w.\-]+).*?known_trip_count\":\{\"n\":\"(\d+)\"")
_WHILE_NOCOUNT_RE = re.compile(r" while\(.*body=%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")


def computation_multipliers(hlo_text: str) -> Dict[str, float]:
    """name -> estimated execution count of each HLO computation."""
    current = "ENTRY"
    entry = "ENTRY"
    edges = []          # (parent_comp, child_comp, multiplier)
    for line in hlo_text.splitlines():
        m = _COMP_HDR_RE.match(line.strip())
        if m and line.rstrip().endswith("{"):
            current = m.group(1)
            if line.strip().startswith("ENTRY"):
                entry = current
            continue
        if " while(" in line:
            trip = 1
            mw = _WHILE_RE.search(line)
            if mw:
                body, trip = mw.group(1), int(mw.group(2))
            else:
                mb = _WHILE_NOCOUNT_RE.search(line)
                if not mb:
                    continue
                body = mb.group(1)
            edges.append((current, body, trip))
            mc = _COND_RE.search(line)
            if mc:
                edges.append((current, mc.group(1), trip))
        for mc in _CALLS_RE.finditer(line):
            edges.append((current, mc.group(1), 1))
    mult: Dict[str, float] = {"ENTRY": 1.0, entry: 1.0}
    # propagate (graph is a DAG of computations; iterate to fixpoint)
    for _ in range(30):
        changed = False
        for parent, child, k in edges:
            p = mult.get(parent)
            if p is None:
                continue
            v = p * k
            if mult.get(child, 0.0) < v:
                mult[child] = v
                changed = True
        if not changed:
            break
    return mult


def _line_multiplier(mult: Dict[str, float], comp: str) -> float:
    return mult.get(comp, 1.0)


def _iter_lines_with_comp(hlo_text: str):
    current = "ENTRY"
    for line in hlo_text.splitlines():
        m = _COMP_HDR_RE.match(line.strip())
        if m and line.rstrip().endswith("{"):
            current = m.group(1)
            continue
        yield current, line


# `%x = f32[...] convert(%y)` — the CPU backend emulates bf16 matmuls by
# upcasting whole operands to f32; TPU MXUs consume bf16 natively, so
# this traffic is discounted from the TPU memory term.  Operand dtypes
# are not printed inline, so the direction heuristic is by result dtype;
# only tensors >= 1 MB are counted (small f32 converts are legitimate
# numerics that TPU also performs).
_CONVERT_RE = re.compile(r"= (f32|bf16|f16)\[([0-9,]*)\]\S* convert\(")
_CONVERT_MIN_BYTES = 1e6


def _fusion_bodies(hlo_text: str) -> set:
    """Computations that are fusion bodies (ops inside them are fused —
    intermediate converts there cost no HBM traffic)."""
    bodies = set()
    for line in hlo_text.splitlines():
        if " fusion(" in line or "kind=k" in line:
            for m in _CALLS_RE.finditer(line):
                bodies.add(m.group(1))
    return bodies


def parse_convert_overhead(hlo_text: str) -> float:
    """Bytes of precision-emulation converts (read + write), trip-aware.

    Counts (a) top-level convert ops in entry/loop computations and
    (b) fusions whose body is a pure convert (``wrapped_convert_*``) —
    both materialize their output.  Converts *inside* other fusions are
    register-level and free."""
    mult = computation_multipliers(hlo_text)
    fused = _fusion_bodies(hlo_text)
    total = 0.0
    for comp, line in _iter_lines_with_comp(hlo_text):
        m = _CONVERT_RE.search(line)
        is_conv_fusion = (" fusion(" in line
                          and "wrapped_convert" in line)
        if not m and not is_conv_fusion:
            continue
        if m and comp in fused and not comp.startswith("wrapped_convert"):
            continue                      # fused interior convert: free
        if is_conv_fusion and not m:
            m = _SHAPE_RE.search(line)
            if not m:
                continue
        dtype, dims = m.groups()
        out_b = _shape_bytes(dtype, dims)
        if out_b < _CONVERT_MIN_BYTES:
            continue
        k = _line_multiplier(mult, comp)
        if dtype == "f32":
            total += (out_b + out_b / 2) * k     # bf16 read + f32 write
        else:
            total += (out_b + out_b * 2) * k     # f32 read + bf16 write
    return total


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Trip-aware: a collective inside a scanned layer loop counts once
    per iteration (execution multipliers from computation_multipliers)."""
    mult = computation_multipliers(hlo_text)
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    traffic: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    top: List[Dict] = []
    for comp, line in _iter_lines_with_comp(hlo_text):
        if "-done(" in line:
            continue          # count start ops only (async pairs)
        kind = None
        for c in _COLLECTIVES:
            if f" {c}(" in line or f" {c}-start(" in line:
                kind = c
                break
        if kind is None:
            continue
        m = _SHAPE_RE.search(line)
        if not m:
            continue
        dtype, dims = m.groups()
        size = _shape_bytes(dtype, dims)
        n = 1
        g = _GROUPS_RE.search(line)
        if g:
            n = len(g.group(1).split(","))
        else:
            g2 = _GROUPS_V2_RE.search(line)
            if g2:
                n = int(g2.group(2))
        n = max(n, 2)
        if kind == "all-reduce":
            factor = 2.0 * (n - 1) / n
        elif kind == "collective-permute":
            factor = 1.0
        else:
            factor = (n - 1) / n
        k = _line_multiplier(mult, comp)
        counts[kind] += int(k)
        # `size` is the per-shard result size (HLO shapes in SPMD are
        # per-device); traffic is what each chip moves over ICI
        contrib = size * factor * k
        traffic[kind] += contrib
        top.append({"kind": kind, "bytes": contrib, "mult": k,
                    "shape": f"{dtype}[{dims}]", "comp": comp[:40]})
    top.sort(key=lambda d: -d["bytes"])
    return CollectiveStats(counts, traffic, top[:8])


@dataclasses.dataclass
class Roofline:
    """All quantities are PER DEVICE: after SPMD partitioning the compiled
    module is the per-device program, so ``cost_analysis`` flops/bytes and
    HLO shapes are already per-chip."""
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device bytes accessed
    collective_bytes: float      # per-chip ICI traffic
    chips: int
    collectives: Optional[CollectiveStats] = None
    convert_bytes: float = 0.0   # CPU-backend bf16-emulation traffic

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        """TPU memory term: HLO bytes minus the CPU backend's bf16→f32
        emulation converts (absent on TPU; see parse_convert_overhead).
        The estimate is itself approximate (operand dtypes are not in the
        HLO text), so the subtraction is floored at 15% of the raw bytes
        — both §Perf A/B sides use the same accounting."""
        return max(self.hbm_bytes - self.convert_bytes,
                   0.15 * self.hbm_bytes) / HBM_BW

    @property
    def memory_raw_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> Dict:
        d = {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes, "chips": self.chips,
            "convert_bytes": self.convert_bytes,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "memory_raw_s": self.memory_raw_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "step_s": self.step_s,
        }
        if self.collectives:
            d["collective_counts"] = self.collectives.counts
            d["collective_traffic"] = self.collectives.bytes_by_kind
            d["collective_top"] = self.collectives.top
        return d


def analyze(compiled, mesh_chips: int) -> Roofline:
    """Extract roofline terms from a jax compiled object."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):            # older jax: list per device
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    try:
        text = compiled.as_text()
    except Exception:
        text = ""
    coll = parse_collectives(text)
    conv = parse_convert_overhead(text)
    return Roofline(flops=flops, hbm_bytes=nbytes,
                    collective_bytes=coll.total_bytes, chips=mesh_chips,
                    collectives=coll, convert_bytes=conv)


def model_flops(cfg, shape_kind: str, tokens: int) -> float:
    """'Useful' model FLOPs (6·N·D train, 2·N_active·D inference), whole
    program.  Compare per chip: model_flops / chips vs. HLO flops."""
    n_act = cfg.active_param_count()
    if shape_kind == "train":
        return 6.0 * n_act * tokens
    return 2.0 * n_act * tokens


def memory_analysis_dict(compiled) -> Dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out
