"""Production mesh construction.

Single pod: (data=16, model=16) — 256 chips of TPU v5e.
Multi-pod:  (pod=2, data=16, model=16) — 512 chips across 2 pods; the
``pod`` axis carries data parallelism whose collectives cross DCN/ICI
pod boundaries.

Defined as functions (never module-level constants) so importing this
module touches no jax device state — required because the dry-run must
set XLA_FLAGS before the first jax call while tests/benches see 1 device.
"""
from __future__ import annotations

import jax

SINGLE_POD = (16, 16)
SINGLE_POD_AXES = ("data", "model")
MULTI_POD = (2, 16, 16)
MULTI_POD_AXES = ("pod", "data", "model")


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = MULTI_POD_AXES if multi_pod else SINGLE_POD_AXES
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — run "
            "under launch/dryrun.py (it sets "
            "--xla_force_host_platform_device_count)")
    import numpy as np
    dev = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)


def make_demo_mesh(shape=(1, 1), axes=("data", "model")):
    """1-device mesh for CPU tests of the sharded code paths."""
    import numpy as np
    dev = np.asarray(jax.devices()[:1]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)


def chips(mesh) -> int:
    return mesh.devices.size
