"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) combo
and extract memory/cost/collective analysis for the roofline report.

MUST set XLA_FLAGS before any jax import — done in the first two lines.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape decode_32k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
      --out experiments/dryrun
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

# ruff: noqa: E402
import argparse
import dataclasses
import json
import time
import traceback
from typing import Dict

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.configs import shapes as shp
from repro.core import eagle, speculative as spec
from repro.launch import mesh as mesh_mod
from repro.launch import roofline as rf
from repro.launch import sharding as sh
from repro.models import transformer as T
from repro.models import param as P
from repro.models.config import ModelConfig
from repro.training.optimizer import adafactor
from repro.training.trainer import make_train_step

GAMMA = 3


def _abstract_params(cfg: ModelConfig, specs=None):
    specs = specs or T.param_specs(cfg)
    dt = cfg.weight_dtype
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dt), specs,
        is_leaf=P.is_spec)


def _param_shardings(cfg, mesh, rules, specs=None):
    specs = specs or T.param_specs(cfg)
    ab = _abstract_params(cfg, specs)
    axes = P.logical_axes(specs)
    return sh.logical_to_sharding(ab, axes, mesh, rules), ab


def _cache_shardings(cfg, mesh, rules, cache_ab):
    axes = T.cache_axes(cfg)
    return sh.logical_to_sharding(cache_ab, axes, mesh, rules)


def _bf16(cfg: ModelConfig) -> ModelConfig:
    """Dry-run numerics policy: bf16 weights + activations (the HBM-budget
    math in EXPERIMENTS.md; Adafactor keeps optimizer state O(d))."""
    return dataclasses.replace(cfg, dtype="bfloat16",
                               param_dtype="bfloat16")


# ================================================================ builders
def build_train(cfg: ModelConfig, mesh, shape_name: str, rules, moe_impl,
                n_micro_override: int = 0):
    specs_in = shp.input_specs(cfg, shape_name)
    batch_ab = specs_in["batch"]
    b = batch_ab["tokens"].shape[0]
    dp = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            dp *= mesh.devices.shape[mesh.axis_names.index(ax)]
    n_micro = n_micro_override or max(b // dp, 1)
    opt = adafactor()
    step = make_train_step(cfg, opt, n_micro=n_micro, moe_impl=moe_impl,
                           remat=True)
    pspecs = T.param_specs(cfg)
    param_sh, param_ab = _param_shardings(cfg, mesh, rules, pspecs)
    opt_ab = jax.eval_shape(opt.init, param_ab)
    # adafactor state: vr drops the last param axis, vc the second-to-last
    paxes = P.logical_axes(pspecs)

    def state_axes(ax):
        ax = tuple(ax)
        return {"vr": ax[:-1], "vc": ax[:-2] + ax[-1:]} if len(ax) >= 2 \
            else {"v": ax}
    oaxes = jax.tree.map(state_axes, paxes,
                         is_leaf=lambda x: isinstance(x, tuple))
    opt_sh = sh.logical_to_sharding(opt_ab, oaxes, mesh, rules)
    batch_sh = sh.tree_sharding_for_tokens(batch_ab, mesh, rules)
    step_ab = jax.ShapeDtypeStruct((), jnp.int32)
    jitted = jax.jit(step, in_shardings=(param_sh, opt_sh, batch_sh,
                                         sh.replicated(mesh)),
                     donate_argnums=(0, 1))
    return jitted, (param_ab, opt_ab, batch_ab, step_ab)


def build_prefill(cfg: ModelConfig, mesh, shape_name: str, rules, moe_impl):
    specs_in = shp.input_specs(cfg, shape_name)
    tokens_ab, extra_ab = specs_in["tokens"], specs_in["extra"]

    def prefill_fn(params, tokens, extra):
        return T.prefill(cfg, params, tokens, extra=extra,
                         max_len=tokens.shape[1], moe_impl=moe_impl,
                         want_caps=True)

    param_sh, param_ab = _param_shardings(cfg, mesh, rules)
    tok_sh = sh.tree_sharding_for_tokens(tokens_ab, mesh, rules)
    ex_sh = sh.tree_sharding_for_tokens(extra_ab, mesh, rules)
    jitted = jax.jit(prefill_fn, in_shardings=(param_sh, tok_sh, ex_sh))
    return jitted, (param_ab, tokens_ab, extra_ab)


def build_serve(cfg: ModelConfig, mesh, shape_name: str, rules, moe_impl,
                baseline: bool = False):
    """Speculative serve step (paper-faithful) or plain autoregressive
    baseline step (--baseline)."""
    specs_in = shp.input_specs(cfg, shape_name, gamma=GAMMA)
    cache_ab = specs_in["cache"]
    b = specs_in["tokens"].shape[0]
    max_len = cache_ab["lengths"].shape  # noqa  (lengths is (B,))
    dcfg = eagle.draft_config(cfg)
    smax = jax.tree.leaves(cache_ab["body"])[0].shape[2] \
        if "body" in cache_ab else 0
    # draft cache spans the same horizon
    dcache_ab = eagle.draft_cache_abstract(dcfg, b, smax)

    if baseline:
        def step_fn(tparams, cache, token, seed):
            key = jax.random.fold_in(jax.random.key(0), seed)
            out = spec.plain_decode_step(cfg, tparams, cache, token,
                                         greedy=True, key=key,
                                         moe_impl=moe_impl)
            return {"token": out["token"], "cache": out["cache"],
                    "captures": out["captures"]}

        param_sh, param_ab = _param_shardings(cfg, mesh, rules)
        cache_sh = _cache_shardings(cfg, mesh, rules, cache_ab)
        tok_ab = jax.ShapeDtypeStruct((b,), jnp.int32)
        jitted = jax.jit(step_fn, in_shardings=(
            param_sh, cache_sh, sh.tree_sharding_for_tokens(tok_ab, mesh,
                                                            rules),
            sh.replicated(mesh)), donate_argnums=(1,))
        return jitted, (param_ab, cache_ab, tok_ab,
                        jax.ShapeDtypeStruct((), jnp.int32))

    carry_ab = spec.SpecCarry(
        feats=jax.ShapeDtypeStruct((b, GAMMA + 1, 3 * cfg.d_model),
                                   cfg.act_dtype),
        tokens=jax.ShapeDtypeStruct((b, GAMMA + 1), jnp.int32),
        advance=jax.ShapeDtypeStruct((b,), jnp.int32))

    def step_fn(tparams, dparams, cache, dcache, carry, seed):
        key = jax.random.fold_in(jax.random.key(0), seed)
        out = spec.spec_decode_step(cfg, dcfg, tparams, dparams, cache,
                                    dcache, carry, gamma=GAMMA, greedy=True,
                                    key=key, moe_impl=moe_impl)
        return {"tokens": out["tokens"], "n_commit": out["n_commit"],
                "cache": out["cache"], "dcache": out["dcache"],
                "carry": out["carry"], "captures": out["captures"],
                "accept_mask": out["accept_mask"]}

    param_sh, param_ab = _param_shardings(cfg, mesh, rules)
    dspecs = eagle.draft_specs(dcfg)
    dparam_ab = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dcfg.weight_dtype), dspecs,
        is_leaf=P.is_spec)
    dparam_sh = sh.logical_to_sharding(dparam_ab, P.logical_axes(dspecs),
                                       mesh, rules)
    cache_sh = _cache_shardings(cfg, mesh, rules, cache_ab)
    dcache_sh = sh.logical_to_sharding(dcache_ab, eagle.draft_cache_axes(),
                                       mesh, rules)
    carry_sh = spec.SpecCarry(
        feats=sh.tree_sharding_for_tokens(carry_ab.feats, mesh, rules),
        tokens=sh.tree_sharding_for_tokens(carry_ab.tokens, mesh, rules),
        advance=sh.tree_sharding_for_tokens(carry_ab.advance, mesh, rules))
    jitted = jax.jit(step_fn, in_shardings=(
        param_sh, dparam_sh, cache_sh, dcache_sh, carry_sh,
        sh.replicated(mesh)), donate_argnums=(2, 3))
    return jitted, (param_ab, dparam_ab, cache_ab, dcache_ab, carry_ab,
                    jax.ShapeDtypeStruct((), jnp.int32))


RULESETS = {
    "base": sh.BASE_RULES,
    "ep": sh.EXPERT_PARALLEL_RULES,
    "ws": sh.SERVE_WEIGHT_STATIONARY,
    "longctx": sh.LONG_CONTEXT_RULES,
}


def default_rules(cfg: ModelConfig, kind: str) -> str:
    """Paper-faithful deployment defaults: FSDP/ZeRO for training; TP
    weight-stationary serving (SGLang-style), with expert parallelism over
    the data axis for MoE archs (their dense TP shard alone exceeds v5e
    HBM at 671B/398B scale)."""
    if kind == "train":
        return "base"
    return "ep" if cfg.num_experts else "ws"


# ================================================================== driver
def run_pair(arch: str, shape_name: str, *, multi_pod: bool,
             rules_name: str = "auto", moe_impl: str = "sort",
             baseline: bool = False, hints: bool = True,
             mixed_attn: bool = True, chunk: int = 0,
             n_micro: int = 0, force_wg: bool = False) -> Dict:
    ok, reason = shp.applicable(configs.get(arch), shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": reason,
                "multi_pod": multi_pod}
    cfg = _bf16(shp.shape_cfg(configs.get(arch), shape_name))
    if chunk:
        cfg = dataclasses.replace(cfg, chunk_len=chunk)
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    kind = shp.SHAPES[shape_name].kind
    if rules_name == "auto":
        rules_name = default_rules(cfg, kind)
    rules = RULESETS[rules_name]
    if force_wg:
        rules = dict(rules, **{"__weight_gather__": True})
    from repro.models import attention as attn_mod
    from repro.models import hints as hints_mod
    import contextlib
    attn_mod.MIXED_PRECISION = mixed_attn
    hint_ctx = (hints_mod.activate(mesh, rules) if hints
                else contextlib.nullcontext())
    t0 = time.perf_counter()
    with mesh, hint_ctx:
        if kind == "train":
            jitted, args = build_train(cfg, mesh, shape_name, rules,
                                       moe_impl, n_micro_override=n_micro)
        elif kind == "prefill":
            jitted, args = build_prefill(cfg, mesh, shape_name, rules,
                                         moe_impl)
        else:
            jitted, args = build_serve(cfg, mesh, shape_name, rules,
                                       moe_impl, baseline=baseline)
        lowered = jitted.lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    roof = rf.analyze(compiled, mesh.devices.size)
    mem = rf.memory_analysis_dict(compiled)
    shape = shp.SHAPES[shape_name]
    tokens = (shape.global_batch * shape.seq_len if kind != "decode"
              else shape.global_batch * (GAMMA + 1))
    if kind == "train" and cfg.family == "audio":
        tokens = shape.global_batch * (cfg.decoder_len + shape.seq_len)
    mf = rf.model_flops(cfg, kind, tokens)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "multi_pod": multi_pod, "rules": rules_name, "moe_impl": moe_impl,
        "baseline": baseline, "kind": kind,
        "hints": hints, "mixed_attn": mixed_attn,
        "window": cfg.window,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "roofline": roof.as_dict(),
        "memory": mem,
        "model_flops": mf,
        "useful_flops_ratio": mf / max(roof.flops, 1.0),
        "params_b": round(cfg.param_count() / 1e9, 3),
        "active_params_b": round(cfg.active_param_count() / 1e9, 3),
    }
    if mem.get("argument_size_in_bytes") is not None:
        # Resident bytes per device: weights + optimizer state + caches +
        # outputs (donated outputs alias args).  This is the hard HBM
        # floor; temps are upper-bounded by the CPU backend's analysis,
        # which does NOT model cross-iteration buffer reuse in scans
        # (microbatch/layer loops) and so overcounts roughly by the trip
        # count — recorded as temp_upper_bound for reference only.
        resident = (mem.get("argument_size_in_bytes", 0)
                    + mem.get("output_size_in_bytes", 0)
                    - mem.get("alias_size_in_bytes", 0))
        result["resident_bytes"] = resident
        result["temp_upper_bound_bytes"] = mem.get("temp_size_in_bytes", 0)
        result["fits_16g_hbm_resident"] = bool(resident < 16e9)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["off", "on", "both"],
                    default="off")
    ap.add_argument("--rules", default="auto",
                    choices=["auto"] + list(RULESETS))
    ap.add_argument("--moe-impl", default="sort",
                    choices=["sort", "einsum", "shard_map"])
    ap.add_argument("--baseline", action="store_true",
                    help="plain autoregressive decode instead of the "
                         "speculative serve step")
    ap.add_argument("--no-hints", action="store_true",
                    help="disable activation-sharding hints (§Perf A/B)")
    ap.add_argument("--fp32-attn", action="store_true",
                    help="baseline fp32-upcast attention (§Perf A/B)")
    ap.add_argument("--no-flash-decode", action="store_true",
                    help="baseline full-score decode attention (§Perf A/B)")
    ap.add_argument("--chunk", type=int, default=0,
                    help="override cfg.chunk_len (mamba/rwkv scan chunk)")
    ap.add_argument("--micro", type=int, default=0,
                    help="override grad-accum microbatch count (§Perf)")
    ap.add_argument("--force-wg", action="store_true",
                    help="enable use-site weight gathering even for "
                         "training rules (§Perf H-C3 A/B)")
    ap.add_argument("--tag", default="",
                    help="extra tag appended to output filenames")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = configs.assigned() if (args.all or not args.arch) \
        else [args.arch]
    shapes = list(shp.SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    pods = {"off": [False], "on": [True], "both": [False, True]}[
        args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in pods:
                tag = (f"{arch}_{shape_name}_{'2pod' if mp else '1pod'}"
                       f"_{args.rules}"
                       + ("_baseline" if args.baseline else "")
                       + (f"_{args.tag}" if args.tag else ""))
                path = os.path.join(args.out, tag + ".json")
                t0 = time.perf_counter()
                try:
                    from repro.models import attention as _attn
                    _attn.DECODE_FLASH = not args.no_flash_decode
                    res = run_pair(arch, shape_name, multi_pod=mp,
                                   rules_name=args.rules,
                                   moe_impl=args.moe_impl,
                                   baseline=args.baseline,
                                   hints=not args.no_hints,
                                   mixed_attn=not args.fp32_attn,
                                   chunk=args.chunk, n_micro=args.micro,
                                   force_wg=args.force_wg)
                    status = ("SKIP " + res["skipped"]) if "skipped" in res \
                        else (f"ok {res['roofline']['dominant']}-bound "
                              f"step={res['roofline']['step_s']:.4f}s")
                except Exception as e:  # noqa
                    failures += 1
                    res = {"arch": arch, "shape": shape_name,
                           "multi_pod": mp, "error": str(e),
                           "traceback": traceback.format_exc()}
                    status = f"FAIL {type(e).__name__}: {str(e)[:120]}"
                with open(path, "w") as f:
                    json.dump(res, f, indent=2)
                print(f"[{time.perf_counter() - t0:7.1f}s] {tag}: {status}",
                      flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
