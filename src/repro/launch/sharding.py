"""Logical-axis → mesh-axis sharding rules (MaxText-style).

One rule table maps every logical axis name used by the param specs and
cache/activation trees onto mesh axes; ``logical_to_sharding`` applies the
table with per-dimension divisibility auto-drop (a 40-expert dim on a
16-way axis replicates instead of erroring), so the same model code
lowers on any mesh.

Baseline policy (paper-faithful TP serving + FSDP/ZeRO training):
  batch / kv_seq activations  → ("pod","data") / "model"
  weight TP dims (mlp, heads, vocab, experts) → "model"
  weight FSDP dim (embed)     → "data"      (ZeRO-style, gathered at use)
Alternative policies (used by the §Perf hillclimbs) are expressed as rule
overrides, e.g. expert-parallel serving moves "experts" → "data".
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Union[None, str, Tuple[str, ...]]

# ------------------------------------------------------------- rule tables
BASE_RULES: Dict[str, Axes] = {
    # §Perf H-C3 switch: use-site weight gathering (ZeRO-3 style).
    # Measured on train shapes: improves the memory term ~3x but the
    # per-microbatch re-gathers cost more collective time than the
    # activation all-reduces they replace — OFF for training rules.
    "__weight_gather__": False,
    # activations / cache
    "batch": ("pod", "data"),
    "tokens": ("pod", "data"),   # flattened (B·T) token rows
    "act_seq": None,
    "kv_seq": "model",
    # weights
    "embed": "data",          # FSDP / ZeRO shard
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "qkv": None,
    "vocab": "model",
    "experts": "model",
    "latent": None,
    "layers": None,
    "state": None,
    "conv": None,
    "mem": None,
}

# Hillclimb variants (§Perf): expert parallelism over the data axis frees
# the model axis for TP inside each expert; weight-stationary serving
# drops the FSDP gather.
EXPERT_PARALLEL_RULES = dict(BASE_RULES, experts="data", embed=None,
                             **{"__weight_gather__": True})
SERVE_WEIGHT_STATIONARY = dict(BASE_RULES, embed=None,
                               **{"__weight_gather__": True})
# Sequence-parallel long-context: shard the KV sequence over both axes.
LONG_CONTEXT_RULES = dict(BASE_RULES, kv_seq=("data", "model"), batch="pod")


def _axis_sizes(mesh) -> Dict[str, int]:
    # works for both Mesh and AbstractMesh
    return dict(zip(mesh.axis_names, mesh.axis_sizes))


def _resolve(rule: Axes, dim: int, mesh_sizes: Dict[str, int],
             used: set) -> Optional[Tuple[str, ...]]:
    """Pick the longest usable prefix of the rule's axes: every axis must
    exist in the mesh, be unused so far in this spec, and the product must
    divide the dim."""
    if rule is None:
        return None
    axes = (rule,) if isinstance(rule, str) else tuple(rule)
    axes = tuple(a for a in axes if a in mesh_sizes and a not in used)
    while axes:
        prod = int(np.prod([mesh_sizes[a] for a in axes]))
        if dim % prod == 0:
            return axes
        axes = axes[:-1]
    return None


def spec_for(shape: Sequence[int], logical: Sequence[Optional[str]],
             mesh: Mesh, rules: Dict[str, Axes]) -> P:
    sizes = _axis_sizes(mesh)
    used: set = set()
    out = []
    for dim, name in zip(shape, logical):
        rule = rules.get(name) if name else None
        axes = _resolve(rule, dim, sizes, used)
        if axes:
            used.update(axes)
            out.append(axes if len(axes) > 1 else axes[0])
        else:
            out.append(None)
    return P(*out)


def logical_to_sharding(abstract_tree, axes_tree, mesh: Mesh,
                        rules: Optional[Dict[str, Axes]] = None):
    """abstract_tree: ShapeDtypeStruct pytree; axes_tree: aligned pytree of
    logical-axis tuples. Returns a pytree of NamedSharding."""
    rules = rules or BASE_RULES
    ab_leaves, treedef = jax.tree.flatten(abstract_tree)
    # axes leaves are tuples — flatten only down to the abstract tree's
    # leaf positions so the tuples survive as leaves
    ax_leaves = treedef.flatten_up_to(axes_tree)
    out = [NamedSharding(mesh, spec_for(ab.shape, ax, mesh, rules))
           for ab, ax in zip(ab_leaves, ax_leaves)]
    return jax.tree.unflatten(treedef, out)


def batch_sharding(mesh: Mesh, batch: int,
                   rules: Optional[Dict[str, Axes]] = None) -> NamedSharding:
    """Sharding for a (B, ...) host-side input tensor."""
    rules = rules or BASE_RULES
    spec = spec_for((batch,), ("batch",), mesh, rules)
    return NamedSharding(mesh, P(spec[0]))


def token_sharding(mesh: Mesh, shape, rules=None) -> NamedSharding:
    rules = rules or BASE_RULES
    spec = spec_for(shape, ("batch",) + (None,) * (len(shape) - 1), mesh,
                    rules)
    return NamedSharding(mesh, spec)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def tree_sharding_for_tokens(tree, mesh: Mesh, rules=None):
    """Batch-shard every leaf of an input dict on its leading dim."""
    def one(x):
        ax = ("batch",) + (None,) * (len(x.shape) - 1)
        return NamedSharding(mesh, spec_for(x.shape, ax, mesh,
                                            rules or BASE_RULES))
    return jax.tree.map(one, tree)
