"""Slot-level admission control for continuous batching.

The ``Scheduler`` owns the mapping between device batch lanes ("slots")
and live requests.  The serving engine asks it, between decode
supersteps, which finished slots can be refilled from the pending
queue; the engine then writes the new prompts into the resident device
state without tearing it down (``ServingEngine.serve_stream``).

Requests are admitted in arrival order (the queue is FIFO and is topped
up lazily from the request iterator, so an unbounded stream never has to
be materialized).  Arrival *timestamps* are bookkeeping only — the
scheduler does not gate admission on wall-clock arrival times; a trace
is replayed as fast as the engine can drain it (the goodput measurement
of ``benchmarks/bench_continuous.py``).
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Iterable, Iterator, List, Optional, Tuple

from repro.serving.request import Request


class Scheduler:
    """FIFO admission queue + slot occupancy for one serving engine."""

    def __init__(self, batch_size: int,
                 requests: Optional[Iterable[Request]] = None):
        self.batch = batch_size
        self.slots: List[Optional[Request]] = [None] * batch_size
        self._queue: Deque[Request] = deque()
        self._iter: Optional[Iterator[Request]] = (
            iter(requests) if requests is not None else None)
        self._exhausted = requests is None
        self.admitted = 0
        self.completed: List[Request] = []

    # ------------------------------------------------------------ queue
    def submit(self, req: Request):
        self._queue.append(req)

    def _pull(self) -> bool:
        """Top the queue up with one request from the iterator."""
        if self._exhausted:
            return False
        try:
            self._queue.append(next(self._iter))
            return True
        except StopIteration:
            self._exhausted = True
            return False

    def has_pending(self) -> bool:
        return bool(self._queue) or (not self._exhausted and self._pull())

    def has_work(self) -> bool:
        """True while any slot is occupied or any request waits."""
        return any(s is not None for s in self.slots) or self.has_pending()

    # ------------------------------------------------------------ slots
    def release_finished(self) -> List[Request]:
        """Free every slot whose request has finished; returns them in
        slot order (the engine records latency stats before calling)."""
        freed = []
        for i, r in enumerate(self.slots):
            if r is not None and r.finish_t is not None:
                self.slots[i] = None
                self.completed.append(r)
                freed.append(r)
        return freed

    def admit(self) -> List[Tuple[int, Request]]:
        """Fill free slots from the pending queue (FIFO).  Returns the
        (slot, request) assignments made — the engine's refill batch."""
        out = []
        for i, r in enumerate(self.slots):
            if r is not None:
                continue
            if not self._queue and not self._pull():
                break
            req = self._queue.popleft()
            self.slots[i] = req
            self.admitted += 1
            out.append((i, req))
        return out
