"""Slot-level admission control for continuous batching.

The ``Scheduler`` owns the mapping between device batch lanes ("slots")
and live requests.  The serving engine asks it, between decode
supersteps, which finished slots can be refilled from the pending
queue; the engine then writes the new prompts into the resident device
state without tearing it down (``ServingEngine.serve_stream``).

Admission *order* is delegated to a ``serving.policy.AdmissionPolicy``:
the default ``FifoAdmission`` admits in arrival order with the queue
topped up lazily from the request iterator (one pull only when the
queue is empty, so an unbounded stream is never materialized — the
pre-policy byte-parity behavior); reordering policies
(``PriorityAdmission``, ``DeadlineAdmission``) declare a ``lookahead``
window the scheduler keeps materialized and pick among the admissible
candidates per freed slot.  Orthogonally, two arrival modes:

  * **backlog** (default) — arrival timestamps are bookkeeping only; a
    trace is replayed as fast as the engine can drain it (the goodput
    measurement of ``benchmarks/bench_continuous.py``).
  * **arrival gating** (``gate_arrivals=True``) — a request with
    ``arrives_at`` set (seconds since stream start) is held back until
    its arrival time; with all slots idle and the queue empty the
    engine emits *idle supersteps* instead of dispatching, which is
    exactly the slack the decoupled draft trainer consumes on
    single-device hosts.  Under strict-order policies (FIFO) the queue
    head gates later arrivals; reordering policies admit any arrived
    candidate.

Chunked prefill: with the engine's ``prefill_chunk`` enabled,
``refill_groups`` partitions each admission batch into per-width refill
pipelines so several refills' chunks pipeline through the same
inter-superstep gaps and a short prompt never rides a long-tail
prompt's multi-chunk pipeline (see ``ServingEngine``).

Endless streams: by default every completed request is retained in
``completed`` (the engine's return value).  Pass a ``completion_sink``
callback to stream completions out instead — host retention then stays
O(batch) no matter how long the stream runs.
"""
from __future__ import annotations

import time
from collections import deque
from typing import (Callable, Deque, Dict, Iterable, Iterator, List,
                    Optional, Tuple)

from repro.obs.trace import NULL_TRACER
from repro.serving.policy import AdmissionPolicy, FifoAdmission
from repro.serving.request import Request


class Scheduler:
    """Policy-driven admission queue + slot occupancy for one engine."""

    def __init__(self, batch_size: int,
                 requests: Optional[Iterable[Request]] = None, *,
                 policy: Optional[AdmissionPolicy] = None,
                 gate_arrivals: bool = False,
                 clock: Callable[[], float] = time.perf_counter,
                 completion_sink: Optional[Callable[[Request], None]]
                 = None,
                 admission_guard: Optional[
                     Callable[[Request, List[Request]], bool]] = None,
                 tracer=None):
        self.batch = batch_size
        self.policy = policy if policy is not None else FifoAdmission()
        # host-side observability: admission instants + queue-depth
        # counter samples (null by default — a no-op attribute check)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # resource veto consulted per candidate during ``admit`` (paged
        # serving passes the page-pool guard): guard(candidate,
        # already-accepted-this-round) -> False defers the candidate.
        # Strict-order policies defer the rest of the round with it
        # (admitting past the FIFO head would reorder); reordering
        # policies skip it and keep probing the lookahead window
        self.admission_guard = admission_guard
        self.slots: List[Optional[Request]] = [None] * batch_size
        self._queue: Deque[Request] = deque()
        self._iter: Optional[Iterator[Request]] = (
            iter(requests) if requests is not None else None)
        self._exhausted = requests is None
        self.gate_arrivals = gate_arrivals
        self._clock = clock
        self._t0 = clock()
        self.admitted = 0
        self.completed: List[Request] = []
        self.sink = completion_sink

    # ------------------------------------------------------------ queue
    def submit(self, req: Request):
        self._queue.append(req)

    def _now(self) -> float:
        return self._clock() - self._t0

    def _pull(self) -> bool:
        """Top the queue up with one request from the iterator."""
        if self._exhausted:
            return False
        try:
            req = next(self._iter)
        except StopIteration:
            self._exhausted = True
            return False
        if self.gate_arrivals and req.arrives_at is not None:
            # re-anchor the latency clock to the gated arrival instant
            # (materialization time would charge queueing that the
            # trace says hasn't happened yet)
            req.arrival_t = self._t0 + req.arrives_at
        self._queue.append(req)
        return True

    def _arrived(self, req: Request) -> bool:
        if not self.gate_arrivals or req.arrives_at is None:
            return True
        return req.arrives_at <= self._now()

    def _fill(self):
        """Top the queue up to the policy's lookahead window (at least
        one entry).  FIFO's lookahead of 0 keeps the pre-policy lazy
        pull: exactly one request is materialized, only when the queue
        is empty."""
        want = max(self.policy.lookahead, 1)
        while len(self._queue) < want and self._pull():
            pass

    def _admissible(self) -> List[int]:
        """Queue indices the policy may admit right now.  Strict-order
        policies expose only the head (and only once it has arrived);
        reordering policies expose every arrived entry in the window."""
        self._fill()
        if not self._queue:
            return []
        if self.policy.strict_order:
            return [0] if self._arrived(self._queue[0]) else []
        return [i for i, r in enumerate(self._queue) if self._arrived(r)]

    def has_pending(self) -> bool:
        """A request is admissible right now (per the admission policy
        and arrival gating)."""
        return bool(self._admissible())

    def more_coming(self) -> bool:
        """Requests remain that are not yet admissible (future arrivals
        or an unexhausted iterator)."""
        return bool(self._queue) or not self._exhausted

    def next_arrival_in(self) -> Optional[float]:
        """Seconds until some request becomes admissible; 0.0 if one
        already is; None if the stream is exhausted."""
        self._fill()
        if not self._queue:
            return None
        if self._admissible():
            return 0.0
        if self.policy.strict_order:
            return max(self._queue[0].arrives_at - self._now(), 0.0)
        nxt = min(r.arrives_at for r in self._queue
                  if r.arrives_at is not None)
        return max(nxt - self._now(), 0.0)

    def has_work(self) -> bool:
        """True while any slot is occupied or any request is admissible."""
        return any(s is not None for s in self.slots) or self.has_pending()

    def queue_view(self) -> List[Request]:
        """The arrived queue entries (window topped up first) — the
        read-only view shed policies rank over."""
        self._fill()
        return [r for r in self._queue if self._arrived(r)]

    def peek_next(self) -> Optional[Request]:
        """The request ``admit`` would pick next, without removing it —
        the preemption tier compares its deadline against resident
        lanes' to decide whether evicting one is worth it."""
        cands = self._admissible()
        if not cands:
            return None
        pick = cands[self.policy.select(
            [self._queue[j] for j in cands], self._now())]
        return self._queue[pick]

    # ------------------------------------------------------------ slots
    def retire(self, req: Request):
        """Route one finished request through the completion path (the
        sink when configured, else the ``completed`` list)."""
        if self.sink is not None:
            self.sink(req)
        else:
            self.completed.append(req)

    def release_finished(self) -> List[Request]:
        """Free every slot whose request has finished; returns them in
        slot order (the engine records latency stats before calling).
        With a ``completion_sink``, completions stream to the callback
        instead of accumulating in ``completed``."""
        freed = []
        for i, r in enumerate(self.slots):
            if r is not None and r.finish_t is not None:
                self.slots[i] = None
                self.retire(r)
                freed.append(r)
        return freed

    def evict(self, slot: int) -> Request:
        """Preemption: clear an *unfinished* resident from its slot
        (the engine has already spilled its device state) and return
        it.  The request stays live — it re-enters via the engine's
        SpillStore restore path, never through the admission queue."""
        req = self.slots[slot]
        assert req is not None, f"evict of empty slot {slot}"
        self.slots[slot] = None
        if self.tracer.enabled:
            self.tracer.instant("sched.evict", slot=slot, rid=req.rid)
        return req

    def shed(self, victims: List[Request]):
        """Load shedding: drop queued requests (already finished/marked
        by the engine) from the pending queue and route them through
        the completion path."""
        ids = {id(r) for r in victims}
        if not ids:
            return
        self._queue = deque(r for r in self._queue if id(r) not in ids)
        for r in victims:
            self.retire(r)
        if self.tracer.enabled:
            self.tracer.instant("sched.shed", n=len(victims),
                                rids=[r.rid for r in victims])

    def admit(self) -> List[Tuple[int, Request]]:
        """Fill free slots from the pending queue (admission order per
        the policy; gated on arrival time when enabled).  Returns the
        (slot, request) assignments made — the engine's refill batch.
        Each admitted request is stamped with ``admit_t`` (prefill
        starts now — the TTFT clock origin; the injected ``clock`` so
        latency stats never mix clock domains under a fake clock).  An
        ``admission_guard`` (paged serving's page-pool check) can veto
        the round's next candidate: under a strict-order policy the
        round then stops (FIFO order must not be violated by admitting
        past the head); a reordering policy skips the vetoed candidate
        and keeps trying the rest of its lookahead window, so one
        over-wide pick can't head-of-line-block smaller arrived
        candidates that would fit.  Deferred requests stay queued in
        policy order and retry once capacity frees."""
        out = []
        now = self._clock()
        for i, r in enumerate(self.slots):
            if r is not None:
                continue
            req = self._pick_fitting(out)
            if req is None:
                break
            req.admit_t = now
            self.slots[i] = req
            self.admitted += 1
            out.append((i, req))
        return self._admit_trace(out)

    def _pick_fitting(self, accepted: List[Tuple[int, Request]]
                      ) -> Optional[Request]:
        """Policy-pick one admissible request that passes the admission
        guard, removing it from the queue.  Strict-order policies get at
        most one guard probe (a veto defers the round); reordering
        policies retry the remaining candidates with the vetoed ones
        excluded — bounded by the lookahead window the queue is already
        capped at."""
        vetoed: set = set()
        while True:
            cands = [j for j in self._admissible() if j not in vetoed]
            if not cands:
                return None
            pick = cands[self.policy.select(
                [self._queue[j] for j in cands], self._now())]
            req = self._queue[pick]
            if (self.admission_guard is not None
                    and not self.admission_guard(
                        req, [q for _, q in accepted])):
                if self.policy.strict_order:
                    return None
                vetoed.add(pick)
                continue
            del self._queue[pick]
            return req

    def _admit_trace(self, out: List[Tuple[int, Request]]
                     ) -> List[Tuple[int, Request]]:
        if out and self.tracer.enabled:
            self.tracer.instant("sched.admit", n=len(out),
                                rids=[r.rid for _, r in out])
            self.tracer.counter("sched.queue_depth",
                                depth=len(self._queue))
        return out

    @staticmethod
    def refill_groups(admitted: List[Tuple[int, Request]],
                      prefill_chunk: int) -> List[List[Tuple[int, Request]]]:
        """Chunk-aware partition of one admission batch into refill
        pipelines.

        The legacy one-shot refill pads every co-admitted prompt to the
        longest one, so a short-chat request that happens to free a slot
        alongside a long-tail prompt pays the long prompt's full prefill
        width (and, chunked, would ride its whole multi-superstep
        pipeline).  With chunking enabled the engine instead runs one
        chunk pipeline per *padded-width bucket*: requests whose prompts
        bucket to the same width (multiples of 8, the refill shape
        bucket) share a pipeline; different buckets pipeline
        independently, their chunks interleaving through the same
        inter-superstep gaps.  Admission order is preserved within and
        across groups (slot assignment already happened in ``admit``),
        so scheduling stays FIFO — this only shapes the refill ops."""
        if prefill_chunk <= 0:
            return [admitted] if admitted else []
        groups: Dict[int, List[Tuple[int, Request]]] = {}
        for slot, req in admitted:
            width = max(8, -(-len(req.prompt) // 8) * 8)
            groups.setdefault(width, []).append((slot, req))
        return list(groups.values())
