"""Serving request/response types."""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import List, Optional

_ids = itertools.count()


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 48
    domain: str = ""
    rid: int = dataclasses.field(default_factory=lambda: next(_ids))
    arrival_t: float = dataclasses.field(default_factory=time.perf_counter)
    # filled by the engine
    generated: List[int] = dataclasses.field(default_factory=list)
    finish_t: Optional[float] = None

    @property
    def done(self) -> bool:
        # finish_t covers early termination (EOS) before the token budget
        return (self.finish_t is not None
                or len(self.generated) >= self.max_new_tokens)

    def finish(self):
        if self.finish_t is None:
            self.finish_t = time.perf_counter()
            del self.generated[self.max_new_tokens:]
