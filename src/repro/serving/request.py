"""Serving request/response types."""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import List, Optional

_ids = itertools.count()


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 48
    domain: str = ""
    rid: int = dataclasses.field(default_factory=lambda: next(_ids))
    arrival_t: float = dataclasses.field(default_factory=time.perf_counter)
    # trace arrival offset (seconds since stream start); admission is
    # held until then when the scheduler runs with gate_arrivals
    arrives_at: Optional[float] = None
    # ---- SLO annotations (consumed by admission policies; ignored by
    # the default FIFO policy, so they are free to carry everywhere)
    # admission preference: higher admits first under PriorityAdmission
    priority: int = 0
    # completion deadline for DeadlineAdmission's EDF order.  Units are
    # whatever the workload measures service in — wall seconds since
    # stream start for gated traces, or deterministic executed-round
    # units (compare ``finish_round``) for the SLO benchmarks — EDF
    # only needs a consistent total order
    deadline: Optional[float] = None
    # filled by the engine
    generated: List[int] = dataclasses.field(default_factory=list)
    # slot-admission instant (scheduler stamp): the TTFT clock starts
    # here, so a chunk-prefilled request is charged for its whole
    # multi-superstep prefill, never credited for queueing it skipped
    admit_t: Optional[float] = None
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    # deterministic twins of the wall-clock stamps: the engine's
    # executed-round count (``stats.steps``) at slot admission / first
    # token / completion.  Scheduling benchmarks gate on these instead
    # of wall time — the round schedule of a greedy stream is a pure
    # function of the admission order, so SLO wins (deadline hit rate,
    # eager-commit TTFT = ``first_token_round - admit_round``) are
    # reproducible on noisy shared hosts
    admit_round: Optional[int] = None
    first_token_round: Optional[int] = None
    finish_round: Optional[int] = None
    # engine-assigned sampling-stream id (admission ordinal): the
    # per-request PRNG fold-in key, identical for a given stream across
    # every scheduling policy — what makes sampled decoding
    # scheduling-invariant.  Preemption spills/restores the sid (and
    # the per-lane step counter), so a restored request keeps drawing
    # from the same PRNG stream — sampled byte-parity across eviction
    sid: Optional[int] = None
    # ---- overload accounting (preemption / load shedding)
    # times this request was preempted off a lane into the SpillStore
    evictions: int = 0
    # set when a shed policy dropped the request instead of serving it;
    # a shed request is finished with whatever it generated so far
    # (usually nothing) and never re-admitted
    shed: bool = False

    @property
    def done(self) -> bool:
        # finish_t covers early termination (EOS) before the token budget
        return (self.finish_t is not None
                or len(self.generated) >= self.max_new_tokens)

    @property
    def ttft(self) -> Optional[float]:
        """Time to first token (seconds since slot *admission*, falling
        back to arrival when the request never went through a
        scheduler), as observed by the host — under the fused superstep
        the first token materializes with the next superstep's
        telemetry, so this includes up to one superstep of pipelining
        lag, and under chunked prefill it spans every chunk of the
        prompt (the clock starts when prefill starts, not when the last
        chunk commits)."""
        if self.first_token_t is None:
            return None
        start = self.admit_t if self.admit_t is not None else self.arrival_t
        return self.first_token_t - start

    @property
    def latency(self) -> Optional[float]:
        """End-to-end completion latency (seconds since arrival)."""
        if self.finish_t is None:
            return None
        return self.finish_t - self.arrival_t

    def finish(self, now: Optional[float] = None):
        """Mark completion.  ``now`` lets the engine stamp ``finish_t``
        from its injected clock (one clock domain for arrival/admit/
        first-token/finish — fake-clock tests and latency stats depend
        on it); bare calls fall back to the wall clock."""
        if self.finish_t is None:
            self.finish_t = time.perf_counter() if now is None else now
            del self.generated[self.max_new_tokens:]


def inert_request() -> Request:
    """A pre-finished zero-budget placeholder: pads partial waves and
    unoccupied slots so every device batch lane has a definite (masked)
    state.  Never returned to callers."""
    r = Request(prompt=[0], max_new_tokens=0)
    r.finish()
    return r
