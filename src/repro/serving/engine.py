"""TIDE Inference Serving Engine — continuous batching over a fused
on-device decode superstep.

Architecture (slot lifecycle):

  * The device holds B resident batch lanes ("slots"): target KV/SSM
    cache, EAGLE draft cache, and the superstep carry/state.  Decode
    runs as a jitted **superstep** — ``lax.scan`` over K speculative
    rounds in one compiled function (``core.speculative.decode_superstep``)
    with the Eq. 5 speculate-vs-plain choice, token commit/EOS/budget
    masks, acceptance-EMA, and per-round ``extract_pack`` signal
    compaction all in-graph.  One device→host sync per K rounds.
  * A host-side ``serving.scheduler.Scheduler`` owns slot admission:
    ``serve_stream(request_iter)`` keeps the engine resident across an
    entire request stream, and between supersteps **refills** finished
    slots from the pending queue — no wave teardown, no convoy effect
    from one long request holding B-1 idle lanes.
  * A refill is a jitted per-slot op: the new prompt is prefilled and
    its cache lanes are written into the *live* device state
    (``speculative.scatter_target_cache`` / ``eagle.scatter_draft_rows``
    — gather+where with fixed shapes), and that slot's superstep carry
    (position, budget, EOS flag, acceptance bookkeeping) is reset
    in-graph (``speculative.refill_superstep_state``).  Refill batches
    over all slots freed in the same gap.
  * Pipelining is preserved: superstep t+1 is dispatched *before*
    superstep t's telemetry is pulled to the host; completions observed
    in t schedule refills that are enqueued behind t+1 and take effect
    in t+2.  The refilled requests' first tokens ride along with the
    next telemetry pull, so refill adds **zero** extra host syncs.
    ``ServingStats``/timeline and the Algorithm 1 controller decisions
    are reconstructed host-side from per-round device telemetry
    (``TrainingController.observe_gated`` keeps the measurement sequence
    identical to the per-step loop).

``serve_wave`` is a thin compatibility wrapper over ``serve_stream``
(a stream containing exactly one wave); waves smaller than the engine
batch are padded with inert zero-budget slots.  ``superstep_rounds=0``
selects the legacy per-step host loop, kept as the parity reference —
with greedy decoding every scheduling policy emits byte-identical
per-request token streams (tests/test_continuous.py,
tests/test_superstep.py).  Under sampled decoding the two modes match
on refill-free streams; refill timing differs by design (the stepwise
loop refills instantly, the superstep pipeline with one-superstep lag),
so sampled streams are only guaranteed identical per-request when
greedy.

All device steps are jitted with fixed shapes; per-request raggedness is
handled with masks (pads, finished requests), and refill prompt lengths
are bucketed to multiples of 8 to bound recompilation.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import eagle, speculative as spec
from repro.core.adaptive import AdaptiveDrafter
from repro.core.controller import Decision, TrainingController
from repro.core.signals import SignalExtractor
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving.request import Request, inert_request
from repro.serving.scheduler import Scheduler


@dataclasses.dataclass
class ServingStats:
    """Engine counters.  ``tokens_out`` counts exactly the tokens that
    survive in ``Request.generated`` after ``Request.finish()``'s budget
    truncation — the first sampled token included — so it always equals
    the sum of emitted stream lengths."""
    tokens_out: int = 0
    steps: int = 0
    spec_steps: int = 0
    dispatches: int = 0      # decode-step/superstep launches (sync points)
    refills: int = 0         # slots refilled in-flight (async, no sync)
    completed: int = 0
    wall_s: float = 0.0
    accept_len_sum: float = 0.0
    accept_len_n: int = 0
    lane_rounds: int = 0      # batch lanes x executed rounds
    busy_lane_rounds: int = 0  # lanes that committed >=1 token that round
    ttfts: List[float] = dataclasses.field(default_factory=list)
    latencies: List[float] = dataclasses.field(default_factory=list)
    timeline: List[Dict] = dataclasses.field(default_factory=list)

    @property
    def accept_len(self) -> float:
        return self.accept_len_sum / max(self.accept_len_n, 1)

    @property
    def throughput(self) -> float:
        return self.tokens_out / max(self.wall_s, 1e-9)

    @property
    def occupancy(self) -> float:
        """Fraction of lane-rounds that committed tokens — the slot
        utilization continuous batching exists to maximize."""
        return self.busy_lane_rounds / max(self.lane_rounds, 1)

    def _pct(self, xs: List[float], q: float) -> float:
        return float(np.percentile(np.asarray(xs), q)) if xs else 0.0

    @property
    def ttft_p50(self) -> float:
        return self._pct(self.ttfts, 50)

    @property
    def latency_p50(self) -> float:
        return self._pct(self.latencies, 50)

    @property
    def latency_p95(self) -> float:
        return self._pct(self.latencies, 95)


# Back-compat alias (pre-continuous-batching name).
EngineStats = ServingStats


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, dcfg: ModelConfig,
                 dparams, *, gamma: int = 3, max_len: int = 160,
                 batch_size: int = 4, greedy: bool = True,
                 drafter: Optional[AdaptiveDrafter] = None,
                 controller: Optional[TrainingController] = None,
                 extractor: Optional[SignalExtractor] = None,
                 ema: float = 0.9, seed: int = 0,
                 superstep_rounds: int = 8,
                 eos_id: Optional[int] = None):
        self.cfg, self.dcfg = cfg, dcfg
        self.params, self.dparams = params, dparams
        self.gamma, self.max_len, self.batch = gamma, max_len, batch_size
        self.greedy = greedy
        self.drafter = drafter
        self.controller = controller
        self.extractor = extractor
        self.accept_ema = 1.0
        self._ema = ema
        self.superstep_rounds = superstep_rounds
        self.eos_id = eos_id
        self.stats = ServingStats()
        self._key = jax.random.key(seed)
        # refills draw from their own chain: the superstep's round chain
        # lives on device (SuperstepState.key_data) and cannot be forked
        # host-side without a sync, so both engine modes consume this
        # dedicated host chain for refill first-token sampling instead
        self._refill_key = jax.random.key(seed + 104729)
        self._build_steps()

    # ------------------------------------------------------------ jit fns
    def _build_steps(self):
        cfg, dcfg, gamma = self.cfg, self.dcfg, self.gamma

        @jax.jit
        def _prefill(params, tokens, pad):
            return T.prefill(cfg, params, tokens, max_len=self.max_len,
                             pad=pad)

        @jax.jit
        def _seed_draft(params, dparams, dcache, caps, tokens, pad):
            return eagle.seed_prompt_pairs(dcfg, dparams, params["embed"],
                                           dcache, caps, tokens, pad)

        @jax.jit
        def _spec_step(params, dparams, cache, dcache, carry, key):
            return spec.spec_decode_step(
                cfg, dcfg, params, dparams, cache, dcache, carry,
                gamma=gamma, greedy=self.greedy, key=key)

        @jax.jit
        def _plain_step(params, cache, carry, key):
            return spec.plain_step_from_carry(cfg, params, cache, carry,
                                              gamma=gamma,
                                              greedy=self.greedy, key=key)

        decay = self._ema

        @jax.jit
        def _ema_step(ema, ell):
            # same compiled f32 mul-add as the superstep's in-scan EMA:
            # numpy emulation differs by an FMA ulp, which could flip an
            # Eq. 5 threshold compare between the two engine modes
            return decay * ema + (1.0 - decay) * ell

        self._prefill_fn = _prefill
        self._seed_fn = _seed_draft
        self._spec_fn = _spec_step
        self._plain_fn = _plain_step
        self._ema_fn = _ema_step

        def _refill_core(params, dparams, cache, dcache, toks, pad, mask,
                         src, key):
            """Prefill a refill batch of R new prompts and write their
            lanes into the live device state.  ``mask``/``src`` are the
            host-built (B,) lane map (padded refill rows are simply
            never gathered).  Returns the updated (cache, dcache), the
            R-batch prefill carry, and the R first sampled tokens."""
            pre = T.prefill(cfg, params, toks, max_len=self.max_len,
                            pad=pad)
            if self.greedy:
                first = pre["logits"].argmax(-1).astype(jnp.int32)
            else:
                first = jax.random.categorical(
                    key, pre["logits"]).astype(jnp.int32)
            rdc = eagle.seed_refill_cache(dcfg, dparams, params["embed"],
                                          pre["captures"], toks, pad,
                                          self.max_len)
            cache = spec.scatter_target_cache(cache, pre["cache"], mask,
                                              src)
            dcache = eagle.scatter_draft_rows(dcache, rdc, mask, src)
            carry_r = spec.init_carry(cfg, dcfg, pre, first, gamma)
            return cache, dcache, carry_r, first

        @jax.jit
        def _refill_superstep(params, dparams, cache, dcache, state,
                              max_new, toks, pad, mask, src, budgets,
                              key):
            cache, dcache, carry_r, first = _refill_core(
                params, dparams, cache, dcache, toks, pad, mask, src,
                key)
            state = spec.refill_superstep_state(
                state, carry_r, first, budgets, mask, src,
                eos_id=self.eos_id)
            max_new = jnp.where(mask, jnp.take(budgets, src), max_new)
            return cache, dcache, state, max_new, first

        @jax.jit
        def _refill_stepwise(params, dparams, cache, dcache, carry, toks,
                             pad, mask, src, key):
            cache, dcache, carry_r, first = _refill_core(
                params, dparams, cache, dcache, toks, pad, mask, src,
                key)
            carry = spec.scatter_carry(carry, carry_r, mask, src)
            return cache, dcache, carry, first

        self._refill_ss_fn = _refill_superstep
        self._refill_step_fn = _refill_stepwise

        self._superstep_fn = None
        if self.superstep_rounds > 0:
            table = None
            if self.drafter is not None:
                table = jnp.asarray(self.drafter.threshold_table(self.batch))
            ss = functools.partial(
                spec.decode_superstep, cfg, dcfg,
                rounds=self.superstep_rounds, gamma=gamma,
                greedy=self.greedy, ema_decay=self._ema,
                eos_id=self.eos_id,
                collect_signals=self.extractor is not None)

            @jax.jit
            def _superstep(params, dparams, cache, dcache, state, max_new):
                return ss(params, dparams, cache, dcache, state, max_new,
                          table)

            self._superstep_fn = _superstep

    def deploy_draft(self, dparams):
        """Hot-swap the draft (no target reload — TIDE's C2).  Under
        ``serve_stream`` the swap lands between supersteps, mid-stream.

        Caveat: lanes resident at swap time keep draft-cache K/V built
        by the *old* draft until they retire (their captures are gone,
        so they cannot be re-seeded).  Token streams stay correct — the
        target verifies every draft — but those lanes' acceptance length
        may dip until refilled, briefly muddying the acceptance-EMA.
        Wave mode is unaffected (the draft cache is rebuilt per wave)."""
        self.dparams = dparams

    def _next_key(self):
        self._key, k = jax.random.split(self._key)
        return k

    def _next_refill_key(self):
        self._refill_key, k = jax.random.split(self._refill_key)
        return k

    # -------------------------------------------------- request accounting
    def _finish(self, r: Request):
        if r.finish_t is None:
            r.finish()
            self.stats.completed += 1
            if r.latency is not None:
                self.stats.latencies.append(r.latency)

    def _commit_first(self, r: Request, tok: int):
        """Commit a freshly (pre)filled slot's first sampled token."""
        if r.finish_t is not None:       # inert padding / pre-finished
            return
        if r.max_new_tokens < 1:
            self._finish(r)
            return
        r.generated.append(tok)
        if r.first_token_t is None:
            r.first_token_t = time.perf_counter()
            self.stats.ttfts.append(r.ttft)
        self.stats.tokens_out += 1
        if self.eos_id is not None and tok == self.eos_id:
            self._finish(r)

    # ------------------------------------------------------------- prologue
    def _prologue(self, requests: List[Request]):
        """Pad + prefill + draft seed for one full batch of B slots.
        Returns the initial device serving state (cache, dcache, carry,
        first_token)."""
        b = self.batch
        plen = max(len(r.prompt) for r in requests)
        toks = np.zeros((b, plen), np.int32)
        pad = np.zeros((b,), np.int32)
        for i, r in enumerate(requests):
            pad[i] = plen - len(r.prompt)
            toks[i, pad[i]:] = r.prompt
        toks_j, pad_j = jnp.asarray(toks), jnp.asarray(pad)
        pre = self._prefill_fn(self.params, toks_j, pad_j)
        first = self._pick(pre["logits"])
        cache = pre["cache"]
        dcache = eagle.init_draft_cache(self.dcfg, b, self.max_len)
        dcache = self._seed_fn(self.params, self.dparams, dcache,
                               pre["captures"], toks_j, pad_j)
        carry = spec.init_carry(self.cfg, self.dcfg, pre, first, self.gamma)
        return cache, dcache, carry, first

    # ------------------------------------------------------------- serving
    def serve_wave(self, requests: List[Request]) -> List[Request]:
        """Serve one wave to completion (compat wrapper over
        ``serve_stream``).  Waves smaller than the engine batch are
        padded internally with inert zero-budget slots.  Mutates and
        returns the requests."""
        assert len(requests) <= self.batch, \
            f"wave of {len(requests)} exceeds engine batch {self.batch}"
        self.serve_stream(requests)
        return requests

    def serve_stream(self, requests: Iterable[Request], *,
                     on_complete: Optional[Callable[[Request], None]] = None
                     ) -> List[Request]:
        """Serve an entire request stream with in-flight slot refill.

        Pulls lazily from ``requests`` (any iterable), keeps the device
        state resident, and refills slots as requests finish.
        ``on_complete`` fires on the host once per finished request (at
        telemetry-drain boundaries) — the TIDE system uses it to poll
        the training controller mid-stream.  Returns the completed
        requests in completion order."""
        sched = Scheduler(self.batch, requests)
        t0 = time.perf_counter()
        if not sched.admit():
            return []
        reqs0 = [r if r is not None else inert_request()
                 for r in sched.slots]
        cache, dcache, carry, first = self._prologue(reqs0)
        first_np = np.asarray(first)
        for i, r in enumerate(reqs0):
            self._commit_first(r, int(first_np[i]))
        if self._superstep_fn is not None:
            self._stream_superstep(sched, reqs0, cache, dcache, carry,
                                   first, t0, on_complete)
        else:
            self._stream_stepwise(sched, cache, dcache, carry, t0,
                                  on_complete)
        if self.extractor is not None:
            self.extractor.flush()
        self.stats.wall_s += time.perf_counter() - t0
        return sched.completed

    def _retire_and_admit(self, sched: Scheduler, on_complete):
        """Release finished slots, then admit pending requests into them.
        Returns the new (slot, request) assignments to refill."""
        for r in sched.release_finished():
            if on_complete is not None:
                on_complete(r)
        return sched.admit()

    def _refill_arrays(self, admitted: List[Tuple[int, Request]]):
        """Host-side packing of a refill batch, shape-bucketed to bound
        jit retraces to (log2 B widths) x (few prompt-length buckets):
        the row count is padded to the next power of two (pad rows
        replicate row 0 and are never gathered — the (B,) mask/src lane
        map is built here, so they cannot touch live state) and the
        prompt width to a multiple of 8 (which also guarantees >=2
        columns for the draft seed)."""
        plen = max(len(r.prompt) for _, r in admitted)
        plen = max(8, -(-plen // 8) * 8)
        n = len(admitted)
        width = 1
        while width < n:
            width *= 2
        toks = np.zeros((width, plen), np.int32)
        pad = np.zeros((width,), np.int32)
        budgets = np.zeros((width,), np.int32)
        for row, (_, r) in enumerate(admitted):
            pad[row] = plen - len(r.prompt)
            toks[row, pad[row]:] = r.prompt
            budgets[row] = r.max_new_tokens
        toks[n:] = toks[0]
        pad[n:] = pad[0]
        mask = np.zeros((self.batch,), bool)
        src = np.zeros((self.batch,), np.int32)
        for row, (slot, _) in enumerate(admitted):
            mask[slot] = True
            src[slot] = row
        return (jnp.asarray(toks), jnp.asarray(pad), jnp.asarray(mask),
                jnp.asarray(src), jnp.asarray(budgets))

    # ----------------------------------------------- superstep hot path
    @staticmethod
    def _materialize(prev):
        """Pull telemetry to host; the bulky packed signal buffers stay
        device-side and are fetched lazily in ``_unpack_superstep`` only
        if the controller actually has collection enabled."""
        return {k: v if k.startswith("sig_") else np.asarray(v)
                for k, v in prev.items()}

    def _stream_superstep(self, sched, reqs0, cache, dcache, carry, first,
                          t0, on_complete):
        max_new = jnp.asarray([r.max_new_tokens for r in reqs0], jnp.int32)
        active0 = jnp.asarray([r.finish_t is None for r in reqs0], bool)
        state = spec.init_superstep_state(
            carry, first, self._key, accept_ema=self.accept_ema,
            eos_id=self.eos_id, active0=active0)
        # one-superstep double buffer: superstep t+1 is dispatched before
        # t's telemetry is pulled, so the D2H sync overlaps device
        # compute; refills scheduled after draining t are enqueued behind
        # t+1 and take effect in t+2, their first tokens riding along
        # with t's... drained record ("refill" attachment below)
        pending = None
        stall = 0
        while True:
            dispatched = False
            if sched.has_work():
                out = self._superstep_fn(self.params, self.dparams, cache,
                                         dcache, state, max_new)
                self.stats.dispatches += 1
                cache, dcache, state = (out["cache"], out["dcache"],
                                        out["state"])
                prev, pending = pending, {"rounds": out["rounds"],
                                          "slots": list(sched.slots),
                                          "refill": None}
                dispatched = True
            else:
                prev, pending = pending, None
            if prev is None:
                if not dispatched:
                    break
                continue
            progressed = self._drain(prev, t0)
            admitted = self._retire_and_admit(sched, on_complete)
            if admitted:
                args = self._refill_arrays(admitted)
                cache, dcache, state, max_new, fdev = self._refill_ss_fn(
                    self.params, self.dparams, cache, dcache, state,
                    max_new, *args, self._next_refill_key())
                self.stats.refills += len(admitted)
                if pending is not None:
                    # first tokens materialize with the next telemetry
                    # pull — zero extra host syncs
                    pending["refill"] = (fdev, admitted)
                else:
                    first_np = np.asarray(fdev)
                    for row, (_, req) in enumerate(admitted):
                        self._commit_first(req, int(first_np[row]))
            # defensive stall guard: every drained superstep must either
            # commit rounds, retire requests, or admit new ones
            stall = 0 if (progressed or admitted) else stall + 1
            if stall > 4:
                raise RuntimeError(
                    "serve_stream made no progress over 5 supersteps "
                    "(device/host slot state diverged)")
        self._key = jax.random.wrap_key_data(state.key_data)

    def _drain(self, rec, t0) -> bool:
        """Unpack one in-flight superstep record: replay its telemetry,
        then commit the first tokens of any refill that was enqueued
        behind it.  Returns True if any round was valid (progress)."""
        ys = self._materialize(rec["rounds"])
        rids = [r.rid if r is not None else -1 for r in rec["slots"]]
        progressed = self._unpack_superstep(ys, rec["slots"], rids, t0)
        if rec["refill"] is not None:
            fdev, admitted = rec["refill"]
            first_np = np.asarray(fdev)
            for row, (_, req) in enumerate(admitted):
                self._commit_first(req, int(first_np[row]))
        return progressed

    def _unpack_superstep(self, ys, requests, rids, t0) -> bool:
        """Replay one superstep's host-side bookkeeping from device
        telemetry: token commit, stats/timeline, Algorithm 1 controller
        and packed-signal ingestion.  ``requests`` is the per-slot
        residency snapshot taken at dispatch (None = free lane).
        Returns True if any round was valid (i.e. the superstep did
        work; False means every lane was already done at entry)."""
        valid = ys["valid"]
        sig_np = None            # lazily-fetched packed signal buffers
        any_valid = False
        for r in range(valid.shape[0]):
            if not valid[r]:
                break
            any_valid = True
            use_spec = bool(ys["use_spec"][r])
            ell = float(ys["ell"][r])
            alpha = float(ys["alpha"][r])
            n_eff = ys["n_eff"][r]
            toks = ys["tokens"][r]
            active_after = ys["active_after"][r]
            for i, req in enumerate(requests):
                if req is None:
                    continue
                n = int(n_eff[i])
                if n:
                    req.generated.extend(int(t) for t in toks[i, :n])
                if not active_after[i] and req.finish_t is None:
                    self._finish(req)
            busy = int((n_eff > 0).sum())
            self.stats.tokens_out += int(n_eff.sum())
            self.stats.steps += 1
            self.stats.spec_steps += int(use_spec)
            self.stats.accept_len_sum += ell
            self.stats.accept_len_n += 1
            self.stats.lane_rounds += len(requests)
            self.stats.busy_lane_rounds += busy
            self.accept_ema = float(ys["ema"][r])
            if self.drafter is not None:
                self.drafter.enabled = use_spec
            decision = Decision.NONE
            if self.controller is not None:
                decision = self.controller.observe_gated(
                    alpha, int(ys["n_sig"][r]))
                if self.extractor is not None:
                    self.extractor.enabled = \
                        self.controller.collection_enabled
            if (self.extractor is not None and self.extractor.enabled
                    and "sig_feats" in ys):
                if sig_np is None:
                    sig_np = tuple(np.asarray(ys[k]) for k in
                                   ("sig_feats", "sig_tokens",
                                    "sig_counts"))
                self.extractor.ingest_packed(
                    rids, sig_np[0][r], sig_np[1][r], sig_np[2][r])
            self.stats.timeline.append({
                "t": time.perf_counter() - t0, "spec": use_spec,
                "accept_len": ell, "alpha": alpha,
                "decision": decision.value, "busy_lanes": busy,
            })
        return any_valid

    # ------------------------------------------ per-step reference loop
    def _stream_stepwise(self, sched, cache, dcache, carry, t0,
                         on_complete):
        b = self.batch
        slots = list(sched.slots)
        active = np.array([r is not None and r.finish_t is None
                           for r in slots], bool)
        while True:
            admitted = self._retire_and_admit(sched, on_complete)
            if admitted:
                args = self._refill_arrays(admitted)
                cache, dcache, carry, fdev = self._refill_step_fn(
                    self.params, self.dparams, cache, dcache, carry,
                    args[0], args[1], args[2], args[3],
                    self._next_refill_key())
                self.stats.refills += len(admitted)
                first_np = np.asarray(fdev)
                for row, (slot, req) in enumerate(admitted):
                    self._commit_first(req, int(first_np[row]))
                    active[slot] = req.finish_t is None
                slots = list(sched.slots)
            if not active.any():
                if sched.has_work():
                    continue     # residents all EOS'd at refill; admit more
                break
            use_spec = True
            if self.drafter is not None:
                use_spec = self.drafter.update(int(active.sum()),
                                               self.accept_ema)
            self.stats.dispatches += 1
            if use_spec:
                out = self._spec_fn(self.params, self.dparams, cache,
                                    dcache, carry, self._next_key())
                cache, dcache, carry = (out["cache"], out["dcache"],
                                        out["carry"])
                n_commit = np.asarray(out["n_commit"])
                toks_np = np.asarray(out["tokens"])
                # f32 arithmetic exactly as the fused superstep computes
                # in-graph, so the Eq. 5 threshold compare can never
                # straddle a rounding boundary between the two modes
                na = np.float32(active.sum())
                ell32 = np.float32(
                    np.float32(n_commit[active].sum()) / na)
                alpha = float(np.float32(
                    np.float32((n_commit[active] - 1).sum()) / na)
                    / np.float32(self.gamma))
                ell = float(ell32)
                self.accept_ema = float(
                    self._ema_fn(jnp.float32(self.accept_ema),
                                 jnp.float32(ell32)))
                self.stats.spec_steps += 1
            else:
                out = self._plain_fn(self.params, cache, carry,
                                     self._next_key())
                cache, carry = out["cache"], out["carry"]
                n_commit = np.ones((b,), np.int32)
                toks_np = np.asarray(out["tokens"])
                alpha = 0.0
                ell = 1.0
            n_eff = np.zeros((b,), np.int32)
            eos_hit = np.zeros((b,), bool)
            for i, r in enumerate(slots):
                if r is None or not active[i]:
                    continue
                n = min(int(n_commit[i]),
                        max(r.max_new_tokens - len(r.generated), 0))
                if self.eos_id is not None:
                    eos_pos = np.flatnonzero(
                        toks_np[i, :n] == self.eos_id)
                    if eos_pos.size:
                        n = int(eos_pos[0]) + 1
                        eos_hit[i] = True
                n_eff[i] = n
            if self.extractor is not None:
                # only tokens actually kept (post EOS/budget cut) become
                # training signals
                rids = [r.rid if r is not None else -1 for r in slots]
                mask = (np.arange(toks_np.shape[1])[None, :]
                        < n_eff[:, None])
                self.extractor.offer(rids, out["captures"], out["tokens"],
                                     jnp.asarray(mask))

            for i, r in enumerate(slots):
                if r is None or not active[i]:
                    continue
                r.generated.extend(int(t) for t in toks_np[i, :n_eff[i]])
                if eos_hit[i] or r.done:
                    self._finish(r)
                    active[i] = False
            self.stats.tokens_out += int(n_eff.sum())
            self.stats.steps += 1
            self.stats.accept_len_sum += ell
            self.stats.accept_len_n += 1
            self.stats.lane_rounds += b
            busy = int((n_eff > 0).sum())
            self.stats.busy_lane_rounds += busy
            n_sig = int(n_commit[active].sum()) if active.any() else 0
            decision = Decision.NONE
            if self.controller is not None:
                decision = self.controller.observe_gated(alpha, n_sig)
                if self.extractor is not None:
                    self.extractor.enabled = \
                        self.controller.collection_enabled
            self.stats.timeline.append({
                "t": time.perf_counter() - t0, "spec": use_spec,
                "accept_len": ell, "alpha": alpha,
                "decision": decision.value, "busy_lanes": busy,
            })

    def _pick(self, logits):
        if self.greedy:
            return logits.argmax(-1).astype(jnp.int32)
        return jax.random.categorical(self._next_key(), logits
                                      ).astype(jnp.int32)
