"""TIDE Inference Serving Engine — fused on-device decode superstep.

Wave-scheduled continuous batching: a wave of B requests is left-padded
to a common prefill length, prefilled once, then decoded by a jitted
**superstep** — ``lax.scan`` over K speculative rounds inside one
compiled function (``core.speculative.decode_superstep``).  Everything
the old per-step loop did on the host now happens in-graph:

  * the Adaptive Drafter's speculate-vs-plain choice (Eq. 5) is a
    device-side threshold-table lookup selected with ``lax.cond``
    (``core.adaptive.accept_threshold_table`` / ``drafter_decide``),
  * the acceptance-length EMA feeding that choice updates in-graph,
  * per-request token commit (max-token clamp, optional EOS cut,
    active-mask update) runs on masks in the scan body,
  * accepted-position training signals are compacted per round by the
    ``extract_pack`` kernel, so one packed (counts, feats, tokens)
    buffer crosses to the host per superstep.

``serve_wave`` is reduced to superstep dispatch + deferred host unpack:
superstep t+1 is dispatched *before* superstep t's telemetry is pulled
to the host (JAX async dispatch), so the single device→host sync per K
rounds overlaps with device compute — the Fig. 3 overlap at superstep
granularity, with the per-token host overhead measured by
``benchmarks/bench_hotloop.py``.  ``EngineStats``/timeline and the
Algorithm 1 controller decisions are reconstructed host-side from the
per-round device telemetry (``TrainingController.observe_gated`` keeps
the measurement sequence identical to the per-step loop).

``superstep_rounds=0`` selects the legacy per-step host loop, kept as
the parity reference (tests/test_superstep.py asserts byte-identical
token streams and SignalStore contents between the two).

All device steps are jitted with fixed shapes; per-request raggedness is
handled with masks (pads, finished requests).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import eagle, speculative as spec
from repro.core.adaptive import AdaptiveDrafter
from repro.core.controller import Decision, TrainingController
from repro.core.signals import SignalExtractor
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving.request import Request


@dataclasses.dataclass
class EngineStats:
    tokens_out: int = 0
    steps: int = 0
    spec_steps: int = 0
    dispatches: int = 0      # device-program launches the host blocked on
    wall_s: float = 0.0
    accept_len_sum: float = 0.0
    accept_len_n: int = 0
    timeline: List[Dict] = dataclasses.field(default_factory=list)

    @property
    def accept_len(self) -> float:
        return self.accept_len_sum / max(self.accept_len_n, 1)

    @property
    def throughput(self) -> float:
        return self.tokens_out / max(self.wall_s, 1e-9)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, dcfg: ModelConfig,
                 dparams, *, gamma: int = 3, max_len: int = 160,
                 batch_size: int = 4, greedy: bool = True,
                 drafter: Optional[AdaptiveDrafter] = None,
                 controller: Optional[TrainingController] = None,
                 extractor: Optional[SignalExtractor] = None,
                 ema: float = 0.9, seed: int = 0,
                 superstep_rounds: int = 8,
                 eos_id: Optional[int] = None):
        self.cfg, self.dcfg = cfg, dcfg
        self.params, self.dparams = params, dparams
        self.gamma, self.max_len, self.batch = gamma, max_len, batch_size
        self.greedy = greedy
        self.drafter = drafter
        self.controller = controller
        self.extractor = extractor
        self.accept_ema = 1.0
        self._ema = ema
        self.superstep_rounds = superstep_rounds
        self.eos_id = eos_id
        self.stats = EngineStats()
        self._key = jax.random.key(seed)
        self._build_steps()

    # ------------------------------------------------------------ jit fns
    def _build_steps(self):
        cfg, dcfg, gamma = self.cfg, self.dcfg, self.gamma

        @jax.jit
        def _prefill(params, tokens, pad):
            return T.prefill(cfg, params, tokens, max_len=self.max_len,
                             pad=pad)

        @jax.jit
        def _seed_draft(params, dparams, dcache, caps, tokens, pad):
            b, s, _ = caps.shape
            dcache = dict(dcache, pad=pad)
            _, _, dcache = eagle.draft_extend(
                dcfg, dparams, params["embed"], dcache,
                caps[:, :s - 1], tokens[:, 1:],
                jnp.full((b,), s - 1, jnp.int32))
            return dcache

        @jax.jit
        def _spec_step(params, dparams, cache, dcache, carry, key):
            return spec.spec_decode_step(
                cfg, dcfg, params, dparams, cache, dcache, carry,
                gamma=gamma, greedy=self.greedy, key=key)

        @jax.jit
        def _plain_step(params, cache, carry, key):
            return spec.plain_step_from_carry(cfg, params, cache, carry,
                                              gamma=gamma,
                                              greedy=self.greedy, key=key)

        decay = self._ema

        @jax.jit
        def _ema_step(ema, ell):
            # same compiled f32 mul-add as the superstep's in-scan EMA:
            # numpy emulation differs by an FMA ulp, which could flip an
            # Eq. 5 threshold compare between the two engine modes
            return decay * ema + (1.0 - decay) * ell

        self._prefill_fn = _prefill
        self._seed_fn = _seed_draft
        self._spec_fn = _spec_step
        self._plain_fn = _plain_step
        self._ema_fn = _ema_step

        self._superstep_fn = None
        if self.superstep_rounds > 0:
            table = None
            if self.drafter is not None:
                table = jnp.asarray(self.drafter.threshold_table(self.batch))
            ss = functools.partial(
                spec.decode_superstep, cfg, dcfg,
                rounds=self.superstep_rounds, gamma=gamma,
                greedy=self.greedy, ema_decay=self._ema,
                eos_id=self.eos_id,
                collect_signals=self.extractor is not None)

            @jax.jit
            def _superstep(params, dparams, cache, dcache, state, max_new):
                return ss(params, dparams, cache, dcache, state, max_new,
                          table)

            self._superstep_fn = _superstep

    def deploy_draft(self, dparams):
        """Hot-swap the draft (no target reload — TIDE's C2)."""
        self.dparams = dparams

    def _next_key(self):
        self._key, k = jax.random.split(self._key)
        return k

    # ------------------------------------------------------------- waves
    def _prologue(self, requests: List[Request]):
        """Pad + prefill + draft seed for one wave.  Returns the initial
        device serving state (cache, dcache, carry, first_token)."""
        b = self.batch
        plen = max(len(r.prompt) for r in requests)
        toks = np.zeros((b, plen), np.int32)
        pad = np.zeros((b,), np.int32)
        for i, r in enumerate(requests):
            pad[i] = plen - len(r.prompt)
            toks[i, pad[i]:] = r.prompt
        toks_j, pad_j = jnp.asarray(toks), jnp.asarray(pad)
        pre = self._prefill_fn(self.params, toks_j, pad_j)
        first = self._pick(pre["logits"])
        cache = pre["cache"]
        dcache = eagle.init_draft_cache(self.dcfg, b, self.max_len)
        dcache = self._seed_fn(self.params, self.dparams, dcache,
                               pre["captures"], toks_j, pad_j)
        carry = spec.init_carry(self.cfg, self.dcfg, pre, first, self.gamma)
        return cache, dcache, carry, first

    def serve_wave(self, requests: List[Request]) -> List[Request]:
        """Serve one wave to completion. Mutates and returns requests."""
        assert len(requests) == self.batch
        t0 = time.perf_counter()
        cache, dcache, carry, first = self._prologue(requests)
        first_np = np.asarray(first)
        for i, r in enumerate(requests):
            r.generated.append(int(first_np[i]))
            if self.eos_id is not None and int(first_np[i]) == self.eos_id:
                r.finish()

        if self._superstep_fn is not None:
            self._serve_superstep(requests, cache, dcache, carry, first, t0)
        else:
            self._serve_stepwise(requests, cache, dcache, carry, t0)
        if self.extractor is not None:
            self.extractor.flush()
        self.stats.wall_s += time.perf_counter() - t0
        return requests

    # ----------------------------------------------- superstep hot path
    @staticmethod
    def _materialize(prev):
        """Pull telemetry to host; the bulky packed signal buffers stay
        device-side and are fetched lazily in ``_unpack_superstep`` only
        if the controller actually has collection enabled."""
        return {k: v if k.startswith("sig_") else np.asarray(v)
                for k, v in prev.items()}

    def _serve_superstep(self, requests, cache, dcache, carry, first, t0):
        K = self.superstep_rounds
        rids = [r.rid for r in requests]
        max_new = jnp.asarray([r.max_new_tokens for r in requests],
                              jnp.int32)
        state = spec.init_superstep_state(
            carry, first, self._key, accept_ema=self.accept_ema,
            eos_id=self.eos_id)
        max_steps = max(r.max_new_tokens for r in requests) + 2
        limit = -(-max_steps // K) + 1
        all_done = False
        # one-superstep double buffer (local: the payload must never
        # outlive this wave): superstep t+1 is dispatched before t's
        # telemetry is pulled, so the D2H sync overlaps device compute
        pending = None
        for _ in range(limit):
            if all_done:
                break
            out = self._superstep_fn(self.params, self.dparams, cache,
                                     dcache, state, max_new)
            self.stats.dispatches += 1
            cache, dcache, state = (out["cache"], out["dcache"],
                                    out["state"])
            prev, pending = pending, out["rounds"]
            if prev is not None:
                all_done = self._unpack_superstep(
                    self._materialize(prev), requests, rids, t0)
        if pending is not None:
            self._unpack_superstep(self._materialize(pending), requests,
                                   rids, t0)
        self._key = jax.random.wrap_key_data(state.key_data)

    def _unpack_superstep(self, ys, requests, rids, t0) -> bool:
        """Replay one superstep's host-side bookkeeping from device
        telemetry: token commit, stats/timeline, Algorithm 1 controller
        and packed-signal ingestion.  Returns True when every request
        had finished by the end of the superstep."""
        valid = ys["valid"]
        sig_np = None            # lazily-fetched packed signal buffers
        all_done = True          # no valid rounds -> wave was already done
        for r in range(valid.shape[0]):
            if not valid[r]:
                break
            use_spec = bool(ys["use_spec"][r])
            ell = float(ys["ell"][r])
            alpha = float(ys["alpha"][r])
            n_eff = ys["n_eff"][r]
            toks = ys["tokens"][r]
            active_after = ys["active_after"][r]
            for i, req in enumerate(requests):
                n = int(n_eff[i])
                if n:
                    req.generated.extend(int(t) for t in toks[i, :n])
                if not active_after[i] and req.finish_t is None:
                    req.finish()
            self.stats.tokens_out += int(n_eff.sum())
            self.stats.steps += 1
            self.stats.spec_steps += int(use_spec)
            self.stats.accept_len_sum += ell
            self.stats.accept_len_n += 1
            self.accept_ema = float(ys["ema"][r])
            if self.drafter is not None:
                self.drafter.enabled = use_spec
            decision = Decision.NONE
            if self.controller is not None:
                decision = self.controller.observe_gated(
                    alpha, int(ys["n_sig"][r]))
                if self.extractor is not None:
                    self.extractor.enabled = \
                        self.controller.collection_enabled
            if (self.extractor is not None and self.extractor.enabled
                    and "sig_feats" in ys):
                if sig_np is None:
                    sig_np = tuple(np.asarray(ys[k]) for k in
                                   ("sig_feats", "sig_tokens",
                                    "sig_counts"))
                self.extractor.ingest_packed(
                    rids, sig_np[0][r], sig_np[1][r], sig_np[2][r])
            self.stats.timeline.append({
                "t": time.perf_counter() - t0, "spec": use_spec,
                "accept_len": ell, "alpha": alpha,
                "decision": decision.value,
            })
            all_done = not bool(active_after.any())
        return all_done

    # ------------------------------------------ per-step reference loop
    def _serve_stepwise(self, requests, cache, dcache, carry, t0):
        b = self.batch
        active = np.array([r.finish_t is None for r in requests], bool)
        max_steps = max(r.max_new_tokens for r in requests) + 2
        rids = [r.rid for r in requests]
        for _ in range(max_steps):
            if not active.any():
                break
            use_spec = True
            if self.drafter is not None:
                use_spec = self.drafter.update(int(active.sum()),
                                               self.accept_ema)
            self.stats.dispatches += 1
            if use_spec:
                out = self._spec_fn(self.params, self.dparams, cache,
                                    dcache, carry, self._next_key())
                cache, dcache, carry = (out["cache"], out["dcache"],
                                        out["carry"])
                n_commit = np.asarray(out["n_commit"])
                toks_np = np.asarray(out["tokens"])
                # f32 arithmetic exactly as the fused superstep computes
                # in-graph, so the Eq. 5 threshold compare can never
                # straddle a rounding boundary between the two modes
                na = np.float32(active.sum())
                ell32 = np.float32(
                    np.float32(n_commit[active].sum()) / na)
                alpha = float(np.float32(
                    np.float32((n_commit[active] - 1).sum()) / na)
                    / np.float32(self.gamma))
                ell = float(ell32)
                self.accept_ema = float(
                    self._ema_fn(jnp.float32(self.accept_ema),
                                 jnp.float32(ell32)))
                self.stats.spec_steps += 1
            else:
                out = self._plain_fn(self.params, cache, carry,
                                     self._next_key())
                cache, carry = out["cache"], out["carry"]
                n_commit = np.ones((b,), np.int32)
                toks_np = np.asarray(out["tokens"])
                alpha = 0.0
                ell = 1.0
            n_eff = np.zeros((b,), np.int32)
            eos_hit = np.zeros((b,), bool)
            for i, r in enumerate(requests):
                if not active[i]:
                    continue
                n = min(int(n_commit[i]),
                        max(r.max_new_tokens - len(r.generated), 0))
                if self.eos_id is not None:
                    eos_pos = np.flatnonzero(
                        toks_np[i, :n] == self.eos_id)
                    if eos_pos.size:
                        n = int(eos_pos[0]) + 1
                        eos_hit[i] = True
                n_eff[i] = n
            if self.extractor is not None:
                # only tokens actually kept (post EOS/budget cut) become
                # training signals
                mask = (np.arange(toks_np.shape[1])[None, :]
                        < n_eff[:, None])
                self.extractor.offer(rids, out["captures"], out["tokens"],
                                     jnp.asarray(mask))

            for i, r in enumerate(requests):
                if not active[i]:
                    continue
                r.generated.extend(int(t) for t in toks_np[i, :n_eff[i]])
                if eos_hit[i] or r.done:
                    r.finish()
                    active[i] = False
            self.stats.tokens_out += int(n_eff.sum())
            self.stats.steps += 1
            self.stats.accept_len_sum += ell
            self.stats.accept_len_n += 1
            n_sig = int(n_commit[active].sum()) if active.any() else 0
            decision = Decision.NONE
            if self.controller is not None:
                decision = self.controller.observe_gated(alpha, n_sig)
                if self.extractor is not None:
                    self.extractor.enabled = \
                        self.controller.collection_enabled
            self.stats.timeline.append({
                "t": time.perf_counter() - t0, "spec": use_spec,
                "accept_len": ell, "alpha": alpha,
                "decision": decision.value,
            })

    def _pick(self, logits):
        if self.greedy:
            return logits.argmax(-1).astype(jnp.int32)
        return jax.random.categorical(self._next_key(), logits
                                      ).astype(jnp.int32)
