"""TIDE Inference Serving Engine (paper Fig. 1/2, left box).

Wave-scheduled continuous batching: a wave of B requests is left-padded to
a common prefill length, prefilled once, then speculatively decoded with
the Adaptive Drafter deciding per-step whether to speculate (Eq. 5
threshold) and the Acceptance Length Monitor feeding Algorithm 1.  The
Training Signal Extractor captures accepted-position features with
one-step-deferred device→host transfer (async-dispatch overlap, Fig. 3).

All device steps are jitted with fixed shapes; per-request raggedness is
handled with masks (pads, finished requests).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import eagle, speculative as spec
from repro.core.adaptive import AdaptiveDrafter
from repro.core.controller import Decision, TrainingController
from repro.core.signals import SignalExtractor
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving.request import Request


@dataclasses.dataclass
class EngineStats:
    tokens_out: int = 0
    steps: int = 0
    spec_steps: int = 0
    wall_s: float = 0.0
    accept_len_sum: float = 0.0
    accept_len_n: int = 0
    timeline: List[Dict] = dataclasses.field(default_factory=list)

    @property
    def accept_len(self) -> float:
        return self.accept_len_sum / max(self.accept_len_n, 1)

    @property
    def throughput(self) -> float:
        return self.tokens_out / max(self.wall_s, 1e-9)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, dcfg: ModelConfig,
                 dparams, *, gamma: int = 3, max_len: int = 160,
                 batch_size: int = 4, greedy: bool = True,
                 drafter: Optional[AdaptiveDrafter] = None,
                 controller: Optional[TrainingController] = None,
                 extractor: Optional[SignalExtractor] = None,
                 ema: float = 0.9, seed: int = 0):
        self.cfg, self.dcfg = cfg, dcfg
        self.params, self.dparams = params, dparams
        self.gamma, self.max_len, self.batch = gamma, max_len, batch_size
        self.greedy = greedy
        self.drafter = drafter
        self.controller = controller
        self.extractor = extractor
        self.accept_ema = 1.0
        self._ema = ema
        self.stats = EngineStats()
        self._key = jax.random.key(seed)
        self._build_steps()

    # ------------------------------------------------------------ jit fns
    def _build_steps(self):
        cfg, dcfg, gamma = self.cfg, self.dcfg, self.gamma

        @jax.jit
        def _prefill(params, tokens, pad):
            return T.prefill(cfg, params, tokens, max_len=self.max_len,
                             pad=pad)

        @jax.jit
        def _seed_draft(params, dparams, dcache, caps, tokens, pad):
            b, s, _ = caps.shape
            dcache = dict(dcache, pad=pad)
            _, _, dcache = eagle.draft_extend(
                dcfg, dparams, params["embed"], dcache,
                caps[:, :s - 1], tokens[:, 1:],
                jnp.full((b,), s - 1, jnp.int32))
            return dcache

        @jax.jit
        def _spec_step(params, dparams, cache, dcache, carry, key):
            return spec.spec_decode_step(
                cfg, dcfg, params, dparams, cache, dcache, carry,
                gamma=gamma, greedy=self.greedy, key=key)

        @jax.jit
        def _plain_step(params, cache, token, key):
            return spec.plain_decode_step(cfg, params, cache, token,
                                          greedy=self.greedy, key=key)

        self._prefill_fn = _prefill
        self._seed_fn = _seed_draft
        self._spec_fn = _spec_step
        self._plain_fn = _plain_step

    def deploy_draft(self, dparams):
        """Hot-swap the draft (no target reload — TIDE's C2)."""
        self.dparams = dparams

    def _next_key(self):
        self._key, k = jax.random.split(self._key)
        return k

    # ------------------------------------------------------------- waves
    def serve_wave(self, requests: List[Request]) -> List[Request]:
        """Serve one wave to completion. Mutates and returns requests."""
        assert len(requests) == self.batch
        t0 = time.perf_counter()
        b = self.batch
        plen = max(len(r.prompt) for r in requests)
        toks = np.zeros((b, plen), np.int32)
        pad = np.zeros((b,), np.int32)
        for i, r in enumerate(requests):
            pad[i] = plen - len(r.prompt)
            toks[i, pad[i]:] = r.prompt
        toks_j, pad_j = jnp.asarray(toks), jnp.asarray(pad)
        pre = self._prefill_fn(self.params, toks_j, pad_j)
        first = self._pick(pre["logits"])
        cache = pre["cache"]
        dcache = eagle.init_draft_cache(self.dcfg, b, self.max_len)
        dcache = self._seed_fn(self.params, self.dparams, dcache,
                               pre["captures"], toks_j, pad_j)
        carry = spec.init_carry(self.cfg, self.dcfg, pre, first, self.gamma)
        for i, r in enumerate(requests):
            r.generated.append(int(first[i]))

        active = np.ones((b,), bool)
        token_plain = first
        max_steps = max(r.max_new_tokens for r in requests) + 2
        rids = [r.rid for r in requests]
        for _ in range(max_steps):
            if not active.any():
                break
            use_spec = True
            if self.drafter is not None:
                use_spec = self.drafter.update(int(active.sum()),
                                               self.accept_ema)
            if use_spec:
                out = self._spec_fn(self.params, self.dparams, cache,
                                    dcache, carry, self._next_key())
                cache, dcache, carry = (out["cache"], out["dcache"],
                                        out["carry"])
                n_commit = np.asarray(out["n_commit"])
                toks_np = np.asarray(out["tokens"])
                alpha = float((n_commit[active] - 1).mean()) / self.gamma
                ell = float(n_commit[active].mean())
                self.accept_ema = (self._ema * self.accept_ema
                                   + (1 - self._ema) * ell)
                self.stats.spec_steps += 1
                if self.extractor is not None:
                    mask = np.asarray(out["accept_mask"]) \
                        & active[:, None]
                    self.extractor.offer(rids, out["captures"],
                                         out["tokens"],
                                         jnp.asarray(mask))
            else:
                out = self._plain_fn(self.params, cache, token_plain,
                                     self._next_key())
                cache = out["cache"]
                token_plain = out["token"]
                toks_np = np.asarray(token_plain)[:, None]
                n_commit = np.ones((b,), np.int32)
                alpha = 0.0
                ell = 1.0
                # re-sync the spec carry so speculation can resume later:
                # pending pair = (capture of the committed token, token)
                caps = out["captures"]                      # (B, 1, 3D)
                gp1 = self.gamma + 1
                feats = jnp.zeros((b, gp1, caps.shape[-1]), caps.dtype
                                  ).at[:, 0].set(caps[:, 0])
                tokp = jnp.zeros((b, gp1), jnp.int32
                                 ).at[:, 0].set(token_plain)
                carry = spec.SpecCarry(feats, tokp,
                                       jnp.ones((b,), jnp.int32))
                if self.extractor is not None:
                    mask = jnp.asarray(active[:, None])
                    self.extractor.offer(rids, caps, toks_np, mask)

            new_tokens = 0
            for i, r in enumerate(requests):
                if not active[i]:
                    continue
                n = int(n_commit[i])
                r.generated.extend(int(t) for t in toks_np[i, :n])
                new_tokens += min(n, r.max_new_tokens -
                                  (len(r.generated) - n))
                if r.done:
                    r.finish()
                    active[i] = False
            self.stats.tokens_out += max(new_tokens, 0)
            self.stats.steps += 1
            self.stats.accept_len_sum += ell
            self.stats.accept_len_n += 1
            n_sig = int(n_commit[active].sum()) if active.any() else 0
            decision = Decision.NONE
            if self.controller is not None:
                collecting_before = self.controller.collection_enabled
                decision = self.controller.observe(
                    alpha, n_sig if collecting_before else 0)
                if self.extractor is not None:
                    self.extractor.enabled = \
                        self.controller.collection_enabled
            self.stats.timeline.append({
                "t": time.perf_counter() - t0, "spec": use_spec,
                "accept_len": ell, "alpha": alpha,
                "decision": decision.value,
            })
        if self.extractor is not None:
            self.extractor.flush()
        self.stats.wall_s += time.perf_counter() - t0
        return requests

    def _pick(self, logits):
        if self.greedy:
            return logits.argmax(-1).astype(jnp.int32)
        return jax.random.categorical(self._next_key(), logits
                                      ).astype(jnp.int32)
